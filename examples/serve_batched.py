"""Scenario: batched serving with prefill + KV-cache decode.

    PYTHONPATH=src python examples/serve_batched.py --arch chatglm3-6b

Runs the reduced variant of any assigned architecture through the serving
path (prefill a batch of prompts, decode autoregressively) — exactly the
computation the decode_32k / long_500k dry-run shapes lower at scale.
"""
import argparse

from repro.launch import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="chatglm3-6b")
    args = ap.parse_args()
    import sys
    sys.argv = ["serve", "--arch", args.arch, "--reduced", "--batch", "4",
                "--prompt-len", "32", "--gen", "16"]
    serve.main()


if __name__ == "__main__":
    main()
