"""Batched GP serving through the request scheduler, fleet persistence
included — the production serving loop in ~40 lines.

    PYTHONPATH=src python examples/serve_batched.py

Fit a fleet once, `save()` it, `load()` it back the way a serving process
would (no refit — bit-identical factors), then serve a ragged request
stream through `to_server()`: a one-tenant `ServingScheduler`
(docs/serving_scheduler.md) packs requests continuously into a ladder of
pre-compiled batch slots (zero recompiles after warmup) and resolves each
request through a Future.

(The LM prefill/decode scenario this example used to run lives on in
`repro.launch.serve --arch ... --reduced`; see the README legacy note.)
"""
import tempfile

import jax
jax.config.update("jax_enable_x64", True)
import numpy as np

from repro.core.gp import pack, stripe_partition
from repro.data import gp_sample_field, random_inputs
from repro.fleet import FleetConfig, GPFleet

M = 8
key = jax.random.PRNGKey(0)
true_theta = pack([1.2, 0.3], 1.3, 0.1)

# --- fit once, persist, reload (what a serving process does at boot) ------
X = random_inputs(key, M * 128)
_, y = gp_sample_field(jax.random.PRNGKey(1), X, true_theta)
Xp, yp = stripe_partition(X, y, M)
cfg = FleetConfig(num_agents=M, trainer="dec-apx", admm_iters=40,
                  method="rbcm", chunk=64, dac_iters=120)
ckpt = tempfile.mkdtemp(prefix="gp_fleet_")
GPFleet(cfg).fit(Xp, yp).save(ckpt)
fleet = GPFleet.load(ckpt)                   # fresh engine, no refit
print(f"fleet: M={M}, trainer={cfg.trainer}, method={cfg.method}, "
      f"reloaded from {ckpt}")

# --- a ragged request stream through the serving scheduler ----------------
rng = np.random.default_rng(0)
requests = [random_inputs(jax.random.fold_in(key, 100 + i),
                          int(rng.integers(1, 65)))
            for i in range(24)]
with fleet.to_server(batch=64, max_wait_ms=2.0) as door:
    futures = [door.submit(r) for r in requests]
    answers = [f.result() for f in futures]

st = door.stats
assert all(a[0].shape[0] == r.shape[0] for a, r in zip(answers, requests))
print(f"served {st.requests} requests / {st.queries} queries in "
      f"{st.batches} micro-batches of 64 "
      f"(padding {100 * st.padding_fraction:.1f}%, "
      f"engine busy {st.engine_seconds * 1e3:.1f} ms)")
