"""Streaming fleet in ~40 lines: observe online, predict online, and change
fleet membership on the fly — all through the `GPFleet` facade.

    PYTHONPATH=src python examples/online_stream.py

A fleet of M=4 agents starts from a batch fit, then keeps observing a
drifting field through sliding windows (incremental rank-1 Cholesky
updates, never a refactorization), serves DEC-rBCM predictions between
observations via engine factor hot-swaps, and finally survives an agent
joining and another leaving — the consensus graph is re-wired and the
engine re-traced on the live fleet.
"""
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp

from repro.core.gp import pack, stripe_partition
from repro.data import gp_sample_field, random_inputs
from repro.fleet import FleetConfig, GPFleet

M, W = 4, 48
key = jax.random.PRNGKey(0)
true_theta = pack([1.2, 0.3], 1.3, 0.1)

# --- seed the windows from an initial batch -------------------------------
X = random_inputs(key, M * W)
_, y = gp_sample_field(jax.random.PRNGKey(1), X, true_theta)
Xp, yp = stripe_partition(X, y, M)
cfg = FleetConfig(num_agents=M, method="rbcm", online=True,
                  chunk=16, dac_iters=120)
# serve from the true hyperparameters (train=False) — the streaming story
fleet = GPFleet(cfg).fit(Xp, yp, log_theta0=true_theta, train=False)
Xs = random_inputs(jax.random.PRNGKey(2), 32)

# --- live loop: every round each agent observes, then the fleet serves ----
for t in range(12):
    k = jax.random.fold_in(key, 100 + t)
    xs = random_inputs(k, M)
    _, ys = gp_sample_field(jax.random.fold_in(k, 1), xs, true_theta)
    fleet.observe(xs, ys)                    # O(W^2)/agent + factor hot-swap
    mean, var, _ = fleet.predict(Xs)         # reuses the compiled predict
print(f"after 12 rounds: windows full at "
      f"{int(fleet.window_counts[0])}/{W}, "
      f"avg predictive std {float(jnp.sqrt(var).mean()):.3f}")

# --- membership: one agent joins with data, another leaves ----------------
Xj = random_inputs(jax.random.PRNGKey(7), 20)
_, yj = gp_sample_field(jax.random.PRNGKey(8), Xj, true_theta)
fleet.join(Xj, yj)                           # attaches to the path tail
mean, _, _ = fleet.predict(Xs)
print(f"agent joined: fleet M={fleet.num_agents}, "
      f"mean[0]={float(mean[0]):+.3f}")

fleet.leave(1)                               # interior node; graph re-chained
mean, _, _ = fleet.predict(Xs)
print(f"agent left:   fleet M={fleet.num_agents}, "
      f"mean[0]={float(mean[0]):+.3f}")
