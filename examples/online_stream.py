"""Streaming fleet in ~50 lines: observe online, predict online, and change
fleet membership on the fly.

    PYTHONPATH=src python examples/online_stream.py

A fleet of M=4 agents starts from a batch fit, then keeps observing a
drifting field through sliding windows (incremental rank-1 Cholesky
updates, never a refactorization), serves DEC-rBCM predictions between
observations via engine factor hot-swaps, and finally survives an agent
joining and another leaving — the consensus graph is re-wired and the
engine re-traced on the live fleet.
"""
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp

from repro.core.consensus import path_graph
from repro.core.gp import pack, stripe_partition
from repro.core.online import from_batch, join, leave, observe_fleet
from repro.core.prediction import PredictionEngine
from repro.data import gp_sample_field, random_inputs

M, W = 4, 48
key = jax.random.PRNGKey(0)
true_theta = pack([1.2, 0.3], 1.3, 0.1)

# --- seed the windows from an initial batch -------------------------------
X = random_inputs(key, M * W)
_, y = gp_sample_field(jax.random.PRNGKey(1), X, true_theta)
Xp, yp = stripe_partition(X, y, M)
state = from_batch(true_theta, Xp, yp)
A = path_graph(M)
eng = PredictionEngine(state.to_fitted(), A, chunk=16, dac_iters=120)
Xs = random_inputs(jax.random.PRNGKey(2), 32)

# --- live loop: every round each agent observes, then the fleet serves ----
ingest = jax.jit(observe_fleet)
for t in range(12):
    k = jax.random.fold_in(key, 100 + t)
    xs = random_inputs(k, M)
    _, ys = gp_sample_field(jax.random.fold_in(k, 1), xs, true_theta)
    state = ingest(state, xs, ys)            # O(W^2) per agent, no refit
    eng.swap_experts(state.to_fitted())      # reuses the compiled predict
    mean, var, _ = eng.predict("rbcm", Xs)
print(f"after 12 rounds: windows full at {int(state.count[0])}/{W}, "
      f"avg predictive std {float(jnp.sqrt(var).mean()):.3f}")

# --- membership: one agent joins with data, another leaves ----------------
Xj = random_inputs(jax.random.PRNGKey(7), 20)
_, yj = gp_sample_field(jax.random.PRNGKey(8), Xj, true_theta)
state, A = join(state, A, Xj, yj)            # attaches to the path tail
eng.rewire(A, fitted=state.to_fitted())      # new M -> fresh traces
mean, _, _ = eng.predict("rbcm", Xs)
print(f"agent joined: fleet M={state.num_agents}, mean[0]={float(mean[0]):+.3f}")

state, A = leave(state, A, 1)                # interior node; graph re-chained
eng.rewire(A, fitted=state.to_fitted())
mean, _, _ = eng.predict("rbcm", Xs)
print(f"agent left:   fleet M={state.num_agents}, mean[0]={float(mean[0]):+.3f}")
