"""Quickstart: the whole decentralized GP lifecycle in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py

A fleet of M=6 agents on a path graph observes a spatial field. One
`FleetConfig` declares the lifecycle — DEC-gapx-GP training (closed-form
decentralized ADMM on augmented datasets, paper Alg. 4) and DEC-NN-grBCM
prediction (consistent aggregation + CBNN nearest-neighbor selection) —
and `GPFleet` runs it: no raw-data pooling, neighbor-wise messages only.
"""
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp

from repro.core.gp import pack, predict_full, stripe_partition
from repro.data import random_inputs, gp_sample_field
from repro.fleet import FleetConfig, GPFleet

M = 6
key = jax.random.PRNGKey(0)

# --- the world: a latent spatial field sampled from a known GP ------------
true_theta = pack([1.2, 0.3], 1.3, 0.1)
X = random_inputs(key, 1800)
f, y = gp_sample_field(jax.random.PRNGKey(1), X, true_theta)

# --- each agent keeps a private stripe of observations --------------------
Xp, yp = stripe_partition(X, y, M)

# --- the lifecycle, declared once -----------------------------------------
cfg = FleetConfig(num_agents=M, graph="path",       # strongly connected
                  trainer="dec-gapx", admm_iters=120,
                  method="nn_grbcm", dac_iters=200, eta_nn=0.1)
fleet = GPFleet(cfg).fit(Xp, yp, key=jax.random.PRNGKey(2))

theta_hat = fleet.log_theta
print("true  theta:", [round(float(v), 3) for v in jnp.exp(true_theta)])
print("DEC-gapx-GP:", [round(float(v), 3) for v in jnp.exp(theta_hat)],
      f"(consensus residual "
      f"{float(fleet.train_info['residuals'][-1]):.1e})")

# --- decentralized prediction: DEC-NN-grBCM --------------------------------
Xs = random_inputs(jax.random.PRNGKey(3), 50)
mean, var, pinfo = fleet.predict(Xs)
m_full, _ = predict_full(theta_hat, Xp.reshape(-1, 2), yp.reshape(-1), Xs)
rmse = float(jnp.sqrt(jnp.mean((mean - m_full) ** 2)))
print(f"predicted {Xs.shape[0]} sites | RMSE vs FULL-GP {rmse:.4f} | "
      f"mean CBNN agents {float(pinfo['mask'].sum(0).mean()):.1f}/{M} | "
      f"avg predictive std {float(jnp.sqrt(var).mean()):.3f}")
