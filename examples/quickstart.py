"""Quickstart: decentralized GP training + prediction in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

A fleet of M=6 agents on a path graph observes a spatial field. They train
GP hyperparameters with DEC-gapx-GP (closed-form decentralized ADMM on
augmented datasets, paper Alg. 4) and predict with DEC-grBCM + CBNN
(consistent aggregation, nearest-neighbor selection) — no raw-data pooling,
neighbor-wise messages only.
"""
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp

from repro.core.gp import pack, stripe_partition, communication_dataset, augment
from repro.core.consensus import path_graph
from repro.core.training import train_dec_gapx_gp
from repro.core.prediction import dec_nn_grbcm
from repro.data import random_inputs, gp_sample_field

M = 6
key = jax.random.PRNGKey(0)

# --- the world: a latent spatial field sampled from a known GP ------------
true_theta = pack([1.2, 0.3], 1.3, 0.1)
X = random_inputs(key, 1800)
f, y = gp_sample_field(jax.random.PRNGKey(1), X, true_theta)

# --- each agent keeps a private stripe of observations --------------------
Xp, yp = stripe_partition(X, y, M)
A = path_graph(M)                       # strongly connected, not complete

# --- grBCM-style communication dataset (sample -> flood -> augment) -------
Xc, yc = communication_dataset(jax.random.PRNGKey(2), Xp, yp)
Xa, ya = augment(Xp, yp, Xc, yc)

# --- decentralized training: DEC-gapx-GP (Theorem 1 closed form) ----------
theta0 = pack([2.0, 0.5], 1.0, 1.0)
thetas, info = train_dec_gapx_gp(theta0, Xa, ya, A, iters=120)
theta_hat = jnp.mean(thetas, axis=0)
print("true  theta:", [round(float(v), 3) for v in jnp.exp(true_theta)])
print("DEC-gapx-GP:", [round(float(v), 3) for v in jnp.exp(theta_hat)],
      f"(consensus residual {float(info['residuals'][-1]):.1e})")

# --- decentralized prediction: DEC-NN-grBCM --------------------------------
from repro.core.gp import predict_full

Xs = random_inputs(jax.random.PRNGKey(3), 50)
mean, var, pinfo = dec_nn_grbcm(theta_hat, Xa, ya, Xc, yc, Xs, A,
                                eta_nn=0.1, Xp=Xp)
m_full, _ = predict_full(theta_hat, Xp.reshape(-1, 2), yp.reshape(-1), Xs)
rmse = float(jnp.sqrt(jnp.mean((mean - m_full) ** 2)))
print(f"predicted {Xs.shape[0]} sites | RMSE vs FULL-GP {rmse:.4f} | "
      f"mean CBNN agents {float(pinfo['mask'].sum(0).mean()):.1f}/{M} | "
      f"avg predictive std {float(jnp.sqrt(var).mean()):.3f}")
