"""A full closed-loop multi-robot mission, replayed from one config.

    PYTHONPATH=src python examples/multi_robot_mission.py [--scenario chaos]
    PYTHONPATH=src python examples/multi_robot_mission.py --config my.json

M robots traverse a latent sampled field along seeded trajectories,
stream observations into their sliding windows, periodically drift-retrain
hyperparameters with decentralized ADMM (factor-preserving hot-swaps:
serving never retraces), answer queries mid-mission through the
continuous-batching scheduler, and absorb the scenario's chaos plan —
dropout/rejoin, degraded consensus, stragglers, injected failures. The
whole story derives from one seed-complete `ScenarioConfig`: run it twice
and the replay digest matches bit for bit (the integration pack in
tests/test_scenario.py asserts exactly this).
"""
import argparse

import jax

jax.config.update("jax_enable_x64", True)

from repro.scenario import ScenarioConfig, preset, run_scenario  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="chaos",
                    help="preset: smoke | mission | chaos")
    ap.add_argument("--config", default=None,
                    help="ScenarioConfig JSON file (overrides --scenario)")
    args = ap.parse_args()

    if args.config is not None:
        with open(args.config) as fh:
            cfg = ScenarioConfig.from_json(fh.read())
    else:
        cfg = preset(args.scenario)

    print(f"mission: {cfg.num_agents} robots on a {cfg.graph} graph, "
          f"{cfg.steps} steps, window {cfg.window}, "
          f"drift every {cfg.drift_every}, "
          f"{len(cfg.dropouts)} dropout(s), edge_loss={cfg.edge_loss}")
    result = run_scenario(cfg, csv=print)

    c = result.curves
    print(f"\naccuracy : rmse {c['rmse'][0]:.3f} -> {c['rmse'][-1]:.3f}, "
          f"nll {c['nll'][0]:.3f} -> {c['nll'][-1]:.3f}")
    if result.drift_nll:
        print(f"drift    : eval NLL per ADMM epoch "
              f"{[round(v, 3) for v in result.drift_nll]}")
    print(f"serving  : {result.serving['completed']}/"
          f"{result.serving['submitted']} completed, "
          f"{result.serving['dropped']} dropped, "
          f"{result.serving['failed']} failed, "
          f"p50 {result.serving['p50_ms']:.1f} ms, "
          f"p99 {result.serving['p99_ms']:.1f} ms")
    if result.membership:
        print(f"chaos    : membership events {result.membership}, "
              f"recompiles at steps {result.recompile_steps}")
    print(f"end state: {result.health['num_agents']} agents, connected="
          f"{result.health['graph_connected']}, hung futures="
          f"{result.hung_futures}")
    print(f"replay   : digest {result.replay_digest()[:16]}… "
          f"(same config => same digest, bit for bit)")


if __name__ == "__main__":
    main()
