"""Scenario: multi-robot ocean-temperature mapping (paper §6.2).

    PYTHONPATH=src python examples/field_mapping.py

A fleet of M surface vehicles maps an SST-like field. Compares every
decentralized aggregation family on RMSE/NLPD and reports the CBNN agent
reduction — a compact reproduction of the paper's Fig. 15 comparison.
"""
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
import numpy as np

from repro.core.gp import (pack, stripe_partition, communication_dataset,
                           augment)
from repro.core.consensus import path_graph, complete_graph
from repro.core.prediction import (dec_nn_gpoe, dec_nn_rbcm, dec_nn_grbcm,
                                   dec_npae_star, dec_nn_npae)
from repro.core.training import train_dec_gapx_gp
from repro.data import grid_inputs, sst_like_field

M = 10
key = jax.random.PRNGKey(0)
Xall = grid_inputs(64, 0.0, 1.0)
f_true, y_all = sst_like_field(Xall, key=key)
idx = jax.random.permutation(key, Xall.shape[0])
X, y = Xall[idx[:3000]], y_all[idx[:3000]]
Xs, fs = Xall[idx[3000:3080]], f_true[idx[3000:3080]]

Xp, yp = stripe_partition(X, y, M)
A = path_graph(M)
Xc, yc = communication_dataset(jax.random.PRNGKey(1), Xp, yp)
Xa, ya = augment(Xp, yp, Xc, yc)

thetas, _ = train_dec_gapx_gp(pack([0.5, 0.5], 1.0, 0.5), Xa, ya, A, iters=80)
lt = jnp.mean(thetas, axis=0)
print("hyperparameters (DEC-gapx-GP):",
      [round(float(v), 3) for v in jnp.exp(lt)])


def report(name, mean, var, mask=None):
    rmse = float(jnp.sqrt(jnp.mean((mean - fs) ** 2)))
    nlpd = float(jnp.mean(0.5 * jnp.log(2 * jnp.pi * var)
                          + 0.5 * (fs - mean) ** 2 / var))
    nn = "" if mask is None else \
        f"  CBNN {float(mask.sum(0).mean()):.1f}/{M} agents"
    print(f"{name:14s} RMSE {rmse:.4f}  NLPD {nlpd:7.3f}{nn}")


eta = 0.1
m, v, i = dec_nn_gpoe(lt, Xp, yp, Xs, A, eta)
report("DEC-NN-gPoE", m, v, i["mask"])
m, v, i = dec_nn_rbcm(lt, Xp, yp, Xs, A, eta)
report("DEC-NN-rBCM", m, v, i["mask"])
m, v, i = dec_nn_grbcm(lt, Xa, ya, Xc, yc, Xs, A, eta, Xp=Xp)
report("DEC-NN-grBCM", m, v, i["mask"])
m, v, i = dec_npae_star(lt, Xp, yp, Xs, complete_graph(M), jor_iters=3000)
report("DEC-NPAE*", m, v)
m, v, i = dec_nn_npae(lt, Xp, yp, Xs, A, eta, dale_iters=1500)
report("DEC-NN-NPAE", m, v, i["mask"])
print("\n(paper Table 8: DEC-NN-grBCM best overall; DEC-NPAE* accurate but "
      "communication-heavy; DEC-NN-NPAE carries approximation error)")
