"""End-to-end driver: federated LM training with the paper's technique.

    PYTHONPATH=src python examples/federated_lm.py [--steps 300]

Trains a ~small reduced LM for a few hundred steps two ways:
  1. centralized baseline (Adam, gradient all-reduce semantics)
  2. DEC-ADMM (generalized DEC-apx-GP, eq. 34): 4 agents, private data
     shards, ring messages only — the paper's federated-learning promise
     carried to transformer training.
Prints the loss trajectories and the inter-agent consensus residual.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.lm_data import MarkovLMData
from repro.models import lm
from repro.launch.steps import make_train_step, make_federated_train_step
from repro.optim import adam


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--agents", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    key = jax.random.PRNGKey(0)
    params0 = lm.init_params(cfg, key)
    n = sum(int(jnp.size(p)) for p in jax.tree.leaves(params0))
    print(f"model: {cfg.name} ({n/1e6:.1f}M params), "
          f"{args.steps} steps, {args.agents} agents")

    # ---- centralized baseline ----
    opt = adam(3e-3)
    step = jax.jit(make_train_step(cfg, opt))
    params, opt_state = params0, opt.init(params0)
    data = MarkovLMData(cfg.vocab_size, seed=0)
    t0 = time.time()
    base_losses = []
    for s in range(args.steps):
        toks, labels = data.batch(args.batch * args.agents, args.seq)
        batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}
        params, opt_state, loss, _ = step(params, opt_state, batch)
        base_losses.append(float(loss))
    print(f"centralized Adam : loss {base_losses[0]:.3f} -> "
          f"{base_losses[-1]:.3f}  ({time.time()-t0:.0f}s)")

    # ---- the paper's technique ----
    M = args.agents
    fed = jax.jit(make_federated_train_step(cfg, n_agents=M, rho=0.05,
                                            kappa=30.0))
    params_st = jax.tree.map(lambda t: jnp.broadcast_to(t, (M,) + t.shape),
                             params0)
    duals = jax.tree.map(jnp.zeros_like, params_st)
    datas = [MarkovLMData(cfg.vocab_size, seed=0, agent=a) for a in range(M)]
    t0 = time.time()
    fed_losses = []
    for s in range(args.steps):
        bs = []
        for d in datas:
            toks, labels = d.batch(args.batch, args.seq)
            bs.append({"tokens": jnp.asarray(toks),
                       "labels": jnp.asarray(labels)})
        batch_st = jax.tree.map(lambda *xs: jnp.stack(xs), *bs)
        params_st, duals, loss = fed(params_st, duals, batch_st)
        fed_losses.append(float(loss))
    dis = max(float(jnp.max(jnp.abs(x - jnp.mean(x, 0))))
              for x in jax.tree.leaves(params_st))
    print(f"DEC-ADMM (eq.34) : loss {fed_losses[0]:.3f} -> "
          f"{fed_losses[-1]:.3f}  consensus residual {dis:.2e}  "
          f"({time.time()-t0:.0f}s)")
    print("\nNOTE: DEC-ADMM is a first-order proximal method (no Adam "
          "preconditioning) — the paper's trade: slower convergence for "
          "zero raw-data/gradient exchange (Assumption 2).")


if __name__ == "__main__":
    main()
