"""Seeded, replayable fault plans for the fleet (the chaos model).

A `FaultPlan` is an immutable description of every fault a run injects:
agent dropout/rejoin schedules, per-edge message loss, NaN-corrupted
payloads, straggler delays, and injected predict failures. It carries a
seed and derives every stochastic schedule from `np.random.default_rng`
on that seed, so a chaos run is a REGRESSION TEST: the same plan replays
the same faults, round for round, call for call.

The plan's fields split into two groups:

  consensus faults   dropouts / edge_loss / nan_agents — change the
                     numbers a prediction computes. The engines consume
                     them through `alive_schedule` / `edge_schedule` /
                     `corrupt_mask` and run the degraded consensus path
                     (core/consensus/degraded.py) with an explicit
                     degradation flag.
  serving faults     straggle_every / straggle_ms / fail_every — change
                     the TIMING or availability of a predict call, never
                     its value. Injected on the scheduler dispatch path
                     by `repro.chaos.wrap_predict_fn`.

`plan.consensus_free` is the contract the bitwise-unchanged acceptance
test leans on: a plan with no consensus faults dispatches to the exact
(pre-existing) consensus traces, not an all-alive masked variant — the
masked and exact formulations agree mathematically but not bit for bit.

Round indices are CONSENSUS-ROUND indices (0-based DAC sweeps within one
prediction); `membership_events` (inject.py) reinterprets the same
dropout schedule at fleet-step granularity for online membership chaos.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Tuple

import numpy as np


class FaultInjected(RuntimeError):
    """A fault injected by a FaultPlan (transient by construction: the
    retry path re-invokes the call under the next call index)."""


@dataclass(frozen=True)
class Dropout:
    """Agent `agent` stops exchanging consensus messages at round `at`
    (inclusive) and rejoins at round `until` (exclusive; None = never).

    A dropped agent freezes its local consensus state and neither sends
    nor receives — its row/column of the adjacency is zeroed for the
    affected rounds. `at=0` models an agent that was dead before the
    prediction started (exact masked aggregation); `at>0` models mid-run
    churn (honest degraded estimate, flagged)."""
    agent: int
    at: int = 0
    until: int | None = None


@dataclass(frozen=True)
class FaultPlan:
    """One run's faults, derived deterministically from `seed`."""
    seed: int = 0
    dropouts: Tuple[Dropout, ...] = ()
    edge_loss: float = 0.0        # iid per-edge, per-round message loss prob
    nan_agents: Tuple[int, ...] = ()   # agents with NaN-corrupted payloads
    straggle_every: int = 0       # every k-th predict call sleeps ...
    straggle_ms: float = 0.0      # ... this long (serving-path fault)
    fail_every: int = 0           # every k-th predict call raises

    def __post_init__(self):
        if not 0.0 <= self.edge_loss < 1.0:
            raise ValueError(f"edge_loss must be in [0, 1), got "
                             f"{self.edge_loss}")
        if self.straggle_every < 0 or self.fail_every < 0:
            raise ValueError("straggle_every / fail_every must be >= 0")
        # normalize to tuples so plans constructed from lists hash/compare
        object.__setattr__(self, "dropouts", tuple(
            d if isinstance(d, Dropout) else Dropout(*d)
            for d in self.dropouts))
        object.__setattr__(self, "nan_agents",
                           tuple(int(a) for a in self.nan_agents))

    # -- classification ------------------------------------------------------

    @property
    def consensus_free(self) -> bool:
        """True when the plan cannot change any computed value — only
        timing/availability (stragglers, injected call failures). The
        engines serve such plans on the EXACT consensus traces, so
        results are bitwise identical to fault-free serving."""
        return (not self.dropouts and self.edge_loss == 0.0
                and not self.nan_agents)

    @property
    def empty(self) -> bool:
        """True when the plan injects nothing at all."""
        return (self.consensus_free and self.straggle_every == 0
                and self.fail_every == 0 and self.straggle_ms == 0.0)

    # -- consensus-fault schedules (all host-side numpy, seeded) -------------

    def alive_schedule(self, num_agents: int, iters: int) -> np.ndarray:
        """(iters, M) float mask: alive[t, i] = 1 iff agent i exchanges
        messages in consensus round t."""
        alive = np.ones((iters, num_agents), dtype=np.float64)
        for d in self.dropouts:
            if not 0 <= d.agent < num_agents:
                raise ValueError(f"dropout agent {d.agent} not in fleet "
                                 f"of {num_agents}")
            hi = iters if d.until is None else min(int(d.until), iters)
            alive[int(d.at):hi, d.agent] = 0.0
        return alive

    def final_alive(self, num_agents: int, iters: int) -> np.ndarray:
        """(M,) bool: alive at the readout round (the last sweep)."""
        if iters <= 0:
            return np.ones(num_agents, dtype=bool)
        return self.alive_schedule(num_agents, iters)[-1] > 0.0

    def edge_schedule(self, num_agents: int, iters: int) -> np.ndarray | None:
        """(iters, M, M) symmetric 0/1 edge-survival masks drawn iid from
        `seed` (None when edge_loss == 0). Symmetric loss — a lost edge
        drops the message in BOTH directions — keeps every masked
        exchange conservative (the degraded estimator relies on it)."""
        if self.edge_loss == 0.0:
            return None
        rng = np.random.default_rng(self.seed)
        keep = rng.random((iters, num_agents, num_agents)) >= self.edge_loss
        upper = np.triu(keep, 1)
        return (upper + np.transpose(upper, (0, 2, 1))).astype(np.float64)

    def corrupt_mask(self, num_agents: int) -> np.ndarray:
        """(M,) bool: agents whose consensus payloads are NaN-corrupted
        (the degraded path's finite-scrub detects and excludes them)."""
        mask = np.zeros(num_agents, dtype=bool)
        for a in self.nan_agents:
            if not 0 <= a < num_agents:
                raise ValueError(f"nan agent {a} not in fleet of "
                                 f"{num_agents}")
            mask[a] = True
        return mask
