"""Fault-plan injection hooks for the serving and membership layers.

The consensus-layer hooks live in the engines (they need to participate
in jit traces); this module carries the HOST-SIDE hooks:

  wrap_predict_fn   deterministic straggler delays and injected transient
                    failures on the scheduler dispatch path. The wrapper
                    keeps a thread-safe call counter, so under a fixed
                    request schedule the k-th dispatch always sees the
                    same fault — chaos runs replay.
  membership_events the plan's dropout schedule reinterpreted at fleet-
                    step granularity: (step, "leave"/"rejoin", agent)
                    events a scenario driver feeds to GPFleet.leave /
                    GPFleet.join between serving steps.
"""
from __future__ import annotations

import threading
import time

from .faults import FaultInjected, FaultPlan


def wrap_predict_fn(predict_fn, plan: FaultPlan, *, sleep=time.sleep):
    """Wrap a scheduler predict_fn with the plan's serving faults.

    Call indices are 1-based: with `fail_every=k` every k-th call raises
    `FaultInjected` BEFORE touching the engine (a transient failure the
    scheduler's retry path absorbs — the retry advances the call counter,
    so it succeeds unless k == 1); with `straggle_every=k` every k-th
    call sleeps `straggle_ms` first (a straggler the watchdog can see).
    Consensus faults are NOT injected here — pass the plan to
    `GPFleet.predict(fault_plan=...)` for those.
    """
    counter = {"n": 0}
    lock = threading.Lock()

    def chaotic(Xs):
        with lock:
            counter["n"] += 1
            n = counter["n"]
        if plan.fail_every and n % plan.fail_every == 0:
            raise FaultInjected(
                f"injected transient failure (call {n}, "
                f"fail_every={plan.fail_every})")
        if plan.straggle_every and n % plan.straggle_every == 0 \
                and plan.straggle_ms > 0.0:
            sleep(plan.straggle_ms * 1e-3)
        return predict_fn(Xs)

    chaotic.calls = counter        # test/diagnostic read surface
    return chaotic


def membership_events(plan: FaultPlan, num_agents: int,
                      steps: int) -> list[tuple[int, str, int]]:
    """The plan's dropouts as fleet-step membership events.

    Returns [(step, "leave" | "rejoin", agent), ...] sorted by step —
    `Dropout(agent, at, until)` leaves at step `at` and (when `until`
    is set within the horizon) rejoins at step `until`. Agent ids refer
    to the ORIGINAL numbering; a driver applying them must track index
    shifts across leaves (GPFleet renumbers on leave).
    """
    events = []
    for d in plan.dropouts:
        if not 0 <= d.agent < num_agents:
            raise ValueError(f"dropout agent {d.agent} not in fleet of "
                             f"{num_agents}")
        if d.at < steps:
            events.append((int(d.at), "leave", int(d.agent)))
        if d.until is not None and d.until < steps:
            events.append((int(d.until), "rejoin", int(d.agent)))
    events.sort()
    return events
