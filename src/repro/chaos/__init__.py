"""repro.chaos: seeded, replayable fault injection for the fleet.

    plan = FaultPlan(seed=7, dropouts=(Dropout(agent=2, at=0),),
                     straggle_every=3, straggle_ms=50.0)
    mean, var, info = fleet.predict(Xs, fault_plan=plan,
                                    allow_degraded=True)
    assert info["degraded"]

Consensus faults (dropouts, edge loss, NaN payloads) run the degraded
consensus path with explicit flags; serving faults (stragglers, injected
failures) ride `wrap_predict_fn` on the scheduler dispatch path. See
docs/robustness.md for the fault model and degradation semantics.
"""
from .faults import Dropout, FaultInjected, FaultPlan
from .inject import membership_events, wrap_predict_fn

__all__ = [
    "FaultPlan", "Dropout", "FaultInjected",
    "wrap_predict_fn", "membership_events",
]
