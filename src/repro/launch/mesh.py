"""Mesh construction (functions, never module-level constants — importing
this module must not touch jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) ("data", "model") = 256 chips.
    Multi-pod:  (2, 16, 16) ("pod", "data", "model") = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many local devices exist (CPU tests)."""
    return jax.make_mesh((data, model), ("data", "model"))


def data_axes(mesh) -> tuple:
    """The batch/FSDP axes present in this mesh ('pod' first if it exists)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def make_agent_mesh(num_agents: int, *, max_devices: int | None = None):
    """1-D mesh over the 'agents' axis for GP fleet serving (ShardedEngine).

    Uses the LARGEST local device count that divides `num_agents` (the
    sharded engine requires ndev | M), optionally capped at `max_devices`.
    Falls back to a single-device mesh when nothing larger divides — the
    sharded program is still valid there (ring collectives degenerate to
    identity), which is what keeps single-device CI runs meaningful.
    """
    avail = len(jax.devices())
    if max_devices is not None:
        avail = min(avail, max_devices)
    ndev = max(d for d in range(1, avail + 1) if num_agents % d == 0)
    return jax.make_mesh((ndev,), ("agents",))
