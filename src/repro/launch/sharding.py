"""Sharding policy: logical parameter/state axes -> PartitionSpec.

Two clients: the legacy LM scaffolding (MaxText-style logical axis rules
below) and the GP serving fleet (`gp_fleet_specs` / `shard_gp_fleet` — the
agent-axis layout consumed by core/prediction/sharded.ShardedEngine).

MaxText-style logical axis rules with divisibility fallbacks (DESIGN.md §6):

  vocab                      -> model   (replicate if V % 16 != 0)
  embed / embed_out / vocab_fsdp-ish dims -> (pod, data)  [ZeRO-3 / FSDP]
  heads / kv_heads           -> model   (replicate if not divisible — phi3,
                                         whisper, chatglm kv, xlstm)
  ffn / experts / mamba_inner(2) -> model  (tensor / expert parallel)
  batch                      -> (pod, data)
  kv_seq                     -> data    ONLY for the long-context decode shape
                                         (sequence-sharded cache)
  everything else            -> replicated

A rule only applies when the dim is divisible by the product of the mesh axis
sizes; combined (pod, data) falls back to data alone, then to replication.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def _axis_size(mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 0


def _try(mesh, dim: int, *axis_names, used=()):
    """Largest prefix-combination of (unused) axis_names that divides dim."""
    names = [a for a in axis_names if _axis_size(mesh, a) and a not in used]
    while names:
        prod = 1
        for a in names:
            prod *= _axis_size(mesh, a)
        if dim % prod == 0:
            return tuple(names) if len(names) > 1 else names[0]
        names = names[1:]          # drop 'pod' first, then 'data'
    return None


# default logical-axis -> candidate mesh axes (overridable per-run by a
# `policy` dict, e.g. {"batch": ("pod","data","model"), "ffn": ()} for
# pure-DP small models — see EXPERIMENTS.md §Perf pair A)
DEFAULT_RULES = {
    "vocab": ("model",),
    "embed": ("pod", "data"), "embed_out": ("pod", "data"),
    "enc_seq": ("pod", "data"), "dec_seq": ("pod", "data"),
    "heads": ("model",), "kv_heads": ("model",),
    "ffn": ("model",), "experts": ("model",),
    "mamba_inner": ("model",), "mamba_inner2": ("model",),
    "batch": ("pod", "data"),
}


def spec_for_axes(mesh, axes: tuple, shape: tuple, *,
                  shard_kv_seq: bool = False, policy=None) -> P:
    """Map one leaf's logical axes + shape to a PartitionSpec."""
    entries = []
    used = set()
    rules = dict(DEFAULT_RULES)
    if policy:
        rules.update(policy)

    def place(cand):
        if cand is None:
            return None
        flat = cand if isinstance(cand, tuple) else (cand,)
        if any(a in used for a in flat):
            return None
        used.update(flat)
        return cand

    for name, dim in zip(axes, shape):
        cand = None
        if name in rules:
            cand = _try(mesh, dim, *rules[name], used=used)
        elif name == "kv_seq" and shard_kv_seq:
            # decode shapes: the cache dominates memory; shard its sequence
            # over every mesh axis the batch didn't claim (KV heads rarely
            # divide the model axis — sequence sharding is the TPU answer,
            # GSPMD inserts the partial-softmax reductions)
            cand = _try(mesh, dim, "pod", "data", "model", used=used)
        entries.append(place(cand))
    return P(*entries)


def _is_axes(x):
    return isinstance(x, tuple)


def tree_specs(mesh, axes_tree, shape_tree, *, shard_kv_seq: bool = False,
               policy=None):
    """PartitionSpec pytree from parallel (axes, shapes) pytrees."""
    return jax.tree.map(
        lambda ax, sh: spec_for_axes(mesh, ax, sh.shape,
                                     shard_kv_seq=shard_kv_seq, policy=policy),
        axes_tree, shape_tree, is_leaf=_is_axes)


def named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def with_sharding(mesh, shape_tree, spec_tree):
    """Attach shardings to ShapeDtypeStructs (dry-run inputs)."""
    return jax.tree.map(
        lambda sds, s: jax.ShapeDtypeStruct(sds.shape, sds.dtype,
                                            sharding=NamedSharding(mesh, s)),
        shape_tree, spec_tree,
        is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, P)))


# ---------------------------------------------------------------------------
# optimizer-state specs
# ---------------------------------------------------------------------------

def adam_state_specs(param_specs):
    return {"step": P(), "m": param_specs, "v": param_specs}


# ---------------------------------------------------------------------------
# GP fleet serving (agent-axis sharding; see core/prediction/sharded.py and
# docs/serving_sharded.md)
# ---------------------------------------------------------------------------

def gp_fleet_specs(fitted, axis_name: str = "agents"):
    """PartitionSpec pytree for a `FittedExperts` fleet: per-agent leaves
    sharded over `axis_name`, hyperparameters replicated. Thin re-export of
    the policy that lives next to the engine (core.prediction.expert_specs)
    so launchers resolve every sharding decision through this module."""
    from ..core.prediction import expert_specs
    return expert_specs(fitted, axis_name)


def shard_gp_fleet(mesh, fitted, axis_name: str = "agents",
                   replicate: bool = False):
    """Place a fitted GP fleet on `mesh` (NamedSharding device_put).

    `replicate=True` is for the 1-agent grBCM communication expert, which
    every device needs in full."""
    from ..core.prediction import shard_experts
    return shard_experts(fitted, mesh, axis_name, replicate=replicate)


def adafactor_state_specs(param_specs, param_shapes, min_dim_factored=128):
    def stat_spec(spec, sds):
        sh = sds.shape
        if len(sh) >= 2 and sh[-1] >= min_dim_factored \
                and sh[-2] >= min_dim_factored:
            return {"vr": P(*spec[:-1]) if len(spec) else P(),
                    "vc": P(*(tuple(spec[:-2]) + (spec[-1],))) if len(spec) >= 2
                    else P()}
        return {"v": spec}

    stats = jax.tree.map(stat_spec, param_specs, param_shapes,
                         is_leaf=lambda x: isinstance(x, P))
    return {"step": P(), "stats": stats}
