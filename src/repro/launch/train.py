"""Training launcher: real steps on whatever devices exist.

  PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
      --reduced --steps 50 --batch 8 --seq 128 [--consensus dec_admm]

--reduced runs the smoke-scale variant (CPU-friendly); full configs expect a
TPU pod. --consensus dec_admm activates the paper's decentralized ADMM
training (one parameter opinion per agent, ring messages only).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..data.lm_data import MarkovLMData
from ..models import lm, encdec
from ..checkpoint import save_checkpoint
from .steps import make_train_step, make_federated_train_step, pick_optimizer


def make_batch(cfg, data, batch: int, seq: int, key):
    toks, labels = data.batch(batch, seq)
    out = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}
    if cfg.encdec:
        out["frames"] = 0.1 * jax.random.normal(
            key, (batch, cfg.enc_seq, cfg.d_model), jnp.float32)
    if cfg.vis_tokens:
        out["embeds"] = 0.1 * jax.random.normal(
            key, (batch, cfg.vis_tokens, cfg.d_model), jnp.float32)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--consensus", default="allreduce",
                    choices=["allreduce", "dec_admm"])
    ap.add_argument("--agents", type=int, default=4)
    ap.add_argument("--rho", type=float, default=0.1)
    ap.add_argument("--kappa", type=float, default=None,
                    help="default: 1/lr (the ADMM proximal term acts as the"
                         " inverse step size)")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.vis_tokens:
        args.seq = max(args.seq, cfg.vis_tokens + 16)
    mod = encdec if cfg.encdec else lm
    key = jax.random.PRNGKey(0)
    params = mod.init_params(cfg, key)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"{cfg.name}: {n_params/1e6:.1f}M params, consensus={args.consensus}")

    if args.consensus == "allreduce":
        optimizer, _ = pick_optimizer(cfg, args.lr)
        step_fn = jax.jit(make_train_step(cfg, optimizer))
        opt_state = optimizer.init(params)
        data = MarkovLMData(cfg.vocab_size, seed=0)
        t0 = time.time()
        for s in range(args.steps):
            batch = make_batch(cfg, data, args.batch, args.seq,
                               jax.random.fold_in(key, s))
            params, opt_state, loss, _ = step_fn(params, opt_state, batch)
            if s % args.log_every == 0 or s == args.steps - 1:
                print(f"step {s:4d} loss {float(loss):.4f} "
                      f"({time.time()-t0:.1f}s)", flush=True)
    else:
        M = args.agents
        kappa = args.kappa if args.kappa is not None else 1.0 / args.lr
        step_fn = jax.jit(make_federated_train_step(
            cfg, n_agents=M, rho=args.rho, kappa=kappa))
        stack = lambda t: jnp.broadcast_to(t, (M,) + t.shape)
        params_st = jax.tree.map(stack, params)
        duals = jax.tree.map(jnp.zeros_like, params_st)
        datas = [MarkovLMData(cfg.vocab_size, seed=0, agent=a)
                 for a in range(M)]
        t0 = time.time()
        for s in range(args.steps):
            batches = [make_batch(cfg, d, args.batch, args.seq,
                                  jax.random.fold_in(key, s * M + a))
                       for a, d in enumerate(datas)]
            batch_st = jax.tree.map(lambda *xs: jnp.stack(xs), *batches)
            params_st, duals, loss = step_fn(params_st, duals, batch_st)
            if s % args.log_every == 0 or s == args.steps - 1:
                dis = max(float(jnp.max(jnp.abs(x - jnp.mean(x, 0))))
                          for x in jax.tree.leaves(params_st))
                print(f"step {s:4d} loss {float(loss):.4f} "
                      f"disagreement {dis:.2e} ({time.time()-t0:.1f}s)",
                      flush=True)
        params = jax.tree.map(lambda t: jnp.mean(t, 0), params_st)

    if args.ckpt:
        path = save_checkpoint(args.ckpt, args.steps, params)
        print("saved", path)


if __name__ == "__main__":
    main()
