import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init). Everything below is ordinary.

import argparse
import json
import re
import sys
import time
import traceback

import jax

from ..configs import ARCH_IDS, get_config
from .mesh import make_production_mesh
from .steps import SHAPES, build, shape_supported

# named sharding-policy overrides (launch/sharding.py DEFAULT_RULES keys).
# "dp": pure data parallelism — small models (EXPERIMENTS.md §Perf pair A):
# the model axis joins batch/FSDP, tensor-parallel rules disabled.
POLICIES = {
    "default": None,
    "dp": {"batch": ("pod", "data", "model"),
           "embed": ("pod", "data", "model"),
           "embed_out": ("pod", "data", "model"),
           "heads": (), "kv_heads": (), "ffn": (), "vocab": (),
           "mamba_inner": (), "mamba_inner2": ()},
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-operand bytes of every collective op in (post-SPMD) HLO.

    These are GLOBAL bytes (the op as written moves its result shape per
    participating device group); we report per-op totals and let the roofline
    divide by chips x link bandwidth."""
    out = {c: 0 for c in COLLECTIVES}
    count = {c: 0 for c in COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        if s.startswith("%") or s.startswith("ROOT"):
            for c in COLLECTIVES:
                if f" {c}(" in s or f" {c}-start(" in s:
                    lhs = s.split(" = ", 1)
                    if len(lhs) == 2:
                        out[c] += _shape_bytes(lhs[1].split(c)[0])
                        count[c] += 1
                    break
    return {"bytes": out, "count": count,
            "total_bytes": int(sum(out.values()))}


def run_one(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
            quick_fail: bool = False, policy: str = "default") -> dict:
    cfg = get_config(arch)
    rec = {"arch": arch, "shape": shape_name, "policy": policy,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    if not shape_supported(cfg, shape_name):
        rec["status"] = "skipped (DESIGN.md §5 gate)"
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        step, example_inputs, cfg2 = build(cfg, shape_name, mesh,
                                           policy=POLICIES[policy])
        lowered = jax.jit(step).lower(*example_inputs)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        rec["status"] = "ok"
        rec["lower_s"] = round(t1 - t0, 1)
        rec["compile_s"] = round(t2 - t1, 1)
        try:
            mem = compiled.memory_analysis()
            rec["memory"] = {
                k: int(getattr(mem, k))
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "alias_size_in_bytes",
                          "generated_code_size_in_bytes")
                if hasattr(mem, k)}
        except Exception as e:                        # CPU backend gaps
            rec["memory_error"] = str(e)
        try:
            cost = compiled.cost_analysis()
            rec["cost"] = {k: float(v) for k, v in cost.items()
                           if isinstance(v, (int, float))
                           and k in ("flops", "bytes accessed",
                                     "bytes accessed output", "transcendentals")}
        except Exception as e:
            rec["cost_error"] = str(e)
        try:
            rec["collectives"] = collective_bytes(compiled.as_text())
        except Exception as e:
            rec["collectives_error"] = str(e)
    except Exception as e:
        rec["status"] = "FAILED"
        rec["error"] = "".join(traceback.format_exception_only(e)).strip()
        rec["traceback"] = traceback.format_exc()[-4000:]
        if quick_fail:
            raise
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = "" if policy == "default" else f"_{policy}"
        fn = f"{arch}_{shape_name}_{rec['mesh']}{suffix}.json".replace("/", "-")
        with open(os.path.join(out_dir, fn), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser(description="Multi-pod dry-run: lower + "
                                 "compile every (arch x shape x mesh)")
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all",
                    help=f"one of {list(SHAPES)} or 'all'")
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--policy", default="default", choices=list(POLICIES))
    ap.add_argument("--quick-fail", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    n_fail = 0
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                rec = run_one(arch, shape_name, mp, args.out,
                              args.quick_fail, policy=args.policy)
                line = (f"{rec['arch']:28s} {rec['shape']:12s} "
                        f"{rec['mesh']:8s} {rec['status']}")
                if rec["status"] == "ok":
                    mem = rec.get("memory", {})
                    tot = (mem.get("argument_size_in_bytes", 0)
                           + mem.get("temp_size_in_bytes", 0))
                    fl = rec.get("cost", {}).get("flops", 0)
                    cb = rec.get("collectives", {}).get("total_bytes", 0)
                    line += (f"  mem/dev={tot/2**30:.2f}GiB flops={fl:.3g} "
                             f"coll={cb/2**30:.2f}GiB "
                             f"compile={rec['compile_s']}s")
                elif rec["status"] == "FAILED":
                    n_fail += 1
                    line += "  " + rec["error"][:160]
                print(line, flush=True)
    if n_fail:
        print(f"{n_fail} FAILURES", flush=True)
        sys.exit(1)
    print("ALL OK", flush=True)


if __name__ == "__main__":
    main()
