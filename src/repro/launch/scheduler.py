"""Request-level serving scheduler: continuous batching, multi-tenant.

The v1 `FrontDoor` collected fixed-shape micro-batches behind one queue —
fine for one fleet, wrong for a service: every request waited behind the
batch barrier, and a second fleet needed a second process. This module is
the front door v2, structured like an LLM serving engine's
`add_request`/`step` loop (aphrodite/vLLM style), adapted to GP fleets
whose unit of work is a *query row* instead of a token:

  add_request(Xq, tenant=..., deadline_ms=..., priority=...) -> Future
      clients enqueue ragged (Nq_i, D) query arrays at any time and get a
      Future of (mean (Nq_i,), var (Nq_i,)) immediately.
  step()
      packs the next batch SLOT for one tenant and runs it. Slots are
      fixed-geometry (a short ladder of chunk-aligned sizes, each compiled
      once), but their *contents* are continuous: whatever requests are
      pending join the next slot immediately — a request never waits for a
      full batch to assemble, and a large request streams across several
      slots. Tenants are interleaved round-robin, so many resident
      `GPFleet`s (different configs, checkpoints, windows) share one
      process and one device, each serving from its own jit cache.

Scheduling policy, per tenant:

  priority      higher-priority requests are packed first (FIFO within a
                priority level).
  deadline      a request past its deadline at packing time is either
                DROPPED (its Future raises `DeadlineExceeded`; default) or
                DE-PRIORITIZED (served only when no in-deadline work is
                pending) — `deadline_policy="drop" | "deprioritize"`.
                Work that already started streaming is always finished.
  admission     `queue_depth` bounds the *queued* (undispatched) query
                rows. Over the bound, `add_request` either BLOCKS
                (backpressure, `admission="block"`) or raises
                `SchedulerSaturated` (`admission="reject"` — what an
                open-loop load generator wants to measure).

Slot geometry and the jit cache: a tenant's `slots` ladder is quantized
(chunk-aligned, doubling) so a dispatch runs a right-sized compiled
program instead of padding to the full batch — log-many geometries total,
each traced once at registration (`warm=True`), zero recompiles while
serving (asserted via the engines' jit-cache miss counters in
tests/test_scheduler.py). Backlogs round DOWN the ladder (`pick_slot`),
unless the next slot up would be >= 75% occupied — then they round up and
clear the backlog in one padded dispatch. Under load every program runs
at or near full occupancy and padding stays bounded.

Locking: `_lock` guards queues and lifecycle; packing happens under it,
the engine call does NOT (submits keep flowing while a slot computes).
`add_request`'s backpressure wait is a Condition wait — it releases the
lock, and `close()` wakes every waiter — so a blocked submitter can never
stall shutdown (the v1 `submit`-holds-lock-while-`put`-blocks bug is
structurally impossible here).

Observability (repro.obs, docs/observability.md): every `TenantStats`
counter mirrors into the metrics registry as a `tenant`-labeled series
(gp_requests_total, gp_queries_total, ...), request latency rides a
bounded histogram sketch instead of a sample deque, and each request
carries a `Span` through queue -> pack -> dispatch -> device -> stitch
whose per-stage timings land in gp_request_stage_seconds and — when a
`span_log` is configured — in a JSONL event per request. All timing uses
`time.perf_counter()` (monotonic, highest resolution); disabling the
registry reduces every hook to an early-return.

`GPFleet.to_server()` returns a one-tenant scheduler; `launch.frontdoor.
FrontDoor` is the v1-compatible shim over the same machinery.
"""
from __future__ import annotations

import heapq
import os
import threading
import time
from collections import deque
from concurrent.futures import Future

import jax
import jax.numpy as jnp
import numpy as np

from ..obs import Histogram, MetricsRegistry, Span, SpanLog, default_registry

__all__ = [
    "ServingScheduler", "Tenant", "TenantStats",
    "DeadlineExceeded", "SchedulerClosed", "SchedulerSaturated",
    "SchedulerStalled", "slot_ladder", "pick_slot",
]


class SchedulerClosed(RuntimeError):
    """add_request after close() (or while close() is tearing down)."""


class SchedulerStalled(RuntimeError):
    """A dispatched slot exceeded the scheduler's stall timeout: the
    watchdog failed its in-flight Futures, quarantined the tenant, and
    failed the tenant's queued work so no client ever hangs on a wedged
    predict_fn. The tenant un-quarantines if the stuck call returns."""


class SchedulerSaturated(RuntimeError):
    """Admission control rejected the request (queue_depth exceeded,
    admission="reject")."""


class DeadlineExceeded(RuntimeError):
    """The request passed its deadline before any of it was scheduled
    (deadline_policy="drop")."""


def slot_ladder(align: int, max_slot: int) -> tuple[int, ...]:
    """Quantized slot geometries: align, 2*align, 4*align, ... up to
    max_slot (always included). Log-many sizes — each is one compiled
    program — while `pick_slot`'s packing keeps every dispatch above
    `align` pending rows at >= 75% occupancy (usually 100%)."""
    align, max_slot = int(align), int(max_slot)
    if align <= 0 or max_slot <= 0:
        raise ValueError(f"slot geometry must be positive, got "
                         f"align={align}, max_slot={max_slot}")
    if max_slot <= align:
        return (max_slot,)
    sizes = []
    s = align
    while s < max_slot:
        sizes.append(s)
        s *= 2
    sizes.append(max_slot)
    return tuple(sizes)


def pick_slot(slots: tuple[int, ...], n_rows: int,
              pad_budget: float = 0.25) -> int:
    """Best slot for `n_rows` pending rows: an exact ladder fit when one
    exists; otherwise round UP to the next slot when it would still be at
    least `1 - pad_budget` occupied (clear the whole backlog now, padding
    bounded); otherwise the largest slot BELOW the backlog (dispatch it
    100% occupied, the remainder rides the next step); otherwise — fewer
    pending rows than the smallest slot — the smallest slot, padded.

    Rounding DOWN by default is what makes the ladder pay off under load:
    a 133-row backlog on a (32..256) ladder dispatches a full 128-row
    program now instead of a 256-row program carrying 123 pad rows, so
    steady-state padding stays near zero and effective capacity stays at
    the compiled programs' rows/s instead of decaying with occupancy. The
    bounded round-up handles the saturation edge: at 107 pending rows,
    strictly rounding down dispatches a 64-slot program (serving 60% of
    the backlog at the small program's worse rows/s plus a full
    per-dispatch overhead for the remainder) and the scheduler can lock
    into chasing its own queue; padding 21 rows into a 128 slot clears
    the backlog in one dispatch for a bounded 16% occupancy loss."""
    if n_rows >= slots[-1]:
        return slots[-1]
    down = up = None
    for s in slots:
        if s == n_rows:
            return s
        if s < n_rows:
            down = s
        else:
            up = s
            break
    if down is None:
        return slots[0]
    if (up - n_rows) / up <= pad_budget:
        return up
    return down


# counter field -> registry metric name (the per-tenant labeled mirror)
_STAT_COUNTERS = {
    "requests": ("gp_requests_total", "requests accepted"),
    "queries": ("gp_queries_total", "real (client) query rows served"),
    "batches": ("gp_batches_total", "slots dispatched"),
    "padded_queries": ("gp_padded_queries_total",
                       "pad rows dispatched alongside real rows"),
    "dropped": ("gp_deadline_dropped_total",
                "requests dropped past their deadline"),
    "rejected": ("gp_rejected_total", "admission-control rejections"),
    "lapsed": ("gp_lapsed_total",
               "past-deadline requests de-prioritized but served"),
    "completed": ("gp_completed_total", "requests answered"),
    "retried": ("gp_retried_total",
                "slot dispatches retried after a transient failure"),
    "isolated": ("gp_isolated_total",
                 "requests answered by a per-rider isolation re-run after "
                 "their shared slot failed"),
    "stalled": ("gp_stalled_total",
                "watchdog interventions (stalled dispatches failed)"),
}
# private always-on registry backing each TenantStats' local sketch (direct
# Histogram construction: the instance is NOT registered/exported — the
# exported copy is the shared registry's tenant-labeled histogram)
_LOCAL = MetricsRegistry(enabled=True)


class TenantStats:
    """Per-tenant serving counters + a bounded request-latency sketch.

    `queries` counts real (client) rows served, `padded_queries` the pad
    rows dispatched alongside them; `batches` counts slots. `dropped` are
    deadline drops, `rejected` admission rejections, `lapsed` past-deadline
    requests de-prioritized (but eventually served).

    Latency samples land in a fixed-bucket histogram (`repro.obs`) — O(1)
    memory at any request count, percentiles within the bucket ratio
    (~19%) of exact — and every counter mirrors into the scheduler's
    metrics registry as a `tenant`-labeled series (docs/observability.md
    lists the names). The local counts here remain the authoritative read
    surface; the registry mirror is what exporters scrape.
    """

    def __init__(self, tenant: str = "default",
                 registry: MetricsRegistry | None = None):
        self.tenant = tenant
        self._registry = registry if registry is not None \
            else default_registry()
        self._lock = threading.Lock()
        self._counts = {f: 0 for f in _STAT_COUNTERS}
        self._engine_seconds = 0.0
        self._lat = Histogram("latency_seconds", "", _LOCAL)
        reg = self._registry
        self._mirror = {f: reg.counter(name, help)
                        for f, (name, help) in _STAT_COUNTERS.items()}
        self._mirror_engine = reg.counter(
            "gp_engine_seconds_total", "engine-busy seconds")
        self._mirror_lat = reg.histogram(
            "gp_request_latency_seconds", "end-to-end request latency")
        self._mirror_stage = reg.histogram(
            "gp_request_stage_seconds", "per-stage request time "
            "(queue|pack|dispatch|device|stitch)")
        self._gauge_pad = reg.gauge(
            "gp_padding_fraction", "pad rows / dispatched rows")

    # -- mutation (scheduler-internal) --------------------------------------

    def count(self, field: str, n: int = 1):
        with self._lock:
            self._counts[field] += n
        self._mirror[field].inc(n, tenant=self.tenant)

    def add_engine_seconds(self, dt: float):
        with self._lock:
            self._engine_seconds += dt
        self._mirror_engine.inc(dt, tenant=self.tenant)

    def record_latency(self, seconds: float):
        self._lat.observe(seconds)
        self._mirror_lat.observe(seconds, tenant=self.tenant)
        self.count("completed")

    def record_stages(self, stages: dict[str, float]):
        for stage, dt in stages.items():
            self._mirror_stage.observe(dt, tenant=self.tenant, stage=stage)

    def update_gauges(self):
        self._gauge_pad.set(self.padding_fraction, tenant=self.tenant)

    # -- read surface (v1-compatible) ---------------------------------------

    def _get(self, field: str) -> int:
        with self._lock:
            return self._counts[field]

    @property
    def requests(self) -> int:
        return self._get("requests")

    @property
    def queries(self) -> int:
        return self._get("queries")

    @property
    def batches(self) -> int:
        return self._get("batches")

    @property
    def padded_queries(self) -> int:
        return self._get("padded_queries")

    @property
    def dropped(self) -> int:
        return self._get("dropped")

    @property
    def rejected(self) -> int:
        return self._get("rejected")

    @property
    def lapsed(self) -> int:
        return self._get("lapsed")

    @property
    def completed(self) -> int:
        return self._get("completed")

    @property
    def retried(self) -> int:
        return self._get("retried")

    @property
    def isolated(self) -> int:
        return self._get("isolated")

    @property
    def stalled(self) -> int:
        return self._get("stalled")

    @property
    def engine_seconds(self) -> float:
        with self._lock:
            return self._engine_seconds

    @property
    def padding_fraction(self) -> float:
        with self._lock:
            total = self._counts["queries"] + self._counts["padded_queries"]
            return self._counts["padded_queries"] / total if total else 0.0

    def latency_ms(self, *quantiles: float) -> tuple[float, ...]:
        """Request-latency percentiles in ms, e.g. stats.latency_ms(50, 99)
        -> (p50, p99). NaN when nothing completed yet."""
        return tuple(self._lat.quantile(q / 100.0) * 1e3 for q in quantiles)

    def __repr__(self):
        with self._lock:
            counts = dict(self._counts)
        return f"TenantStats({self.tenant!r}, {counts})"


class _Request:
    """One in-flight request; `off` rows are already reserved into slots,
    `parts` holds the per-slot answer slices until all `n` rows return."""
    __slots__ = ("Xq", "n", "fut", "priority", "deadline", "arrival", "seq",
                 "off", "parts", "lapsed", "span")

    def __init__(self, Xq, fut, priority, deadline, arrival, seq, span=None):
        self.Xq = Xq
        self.n = Xq.shape[0]
        self.fut = fut
        self.priority = priority
        self.deadline = deadline
        self.arrival = arrival
        self.seq = seq
        self.off = 0
        self.parts: list = []
        self.lapsed = False
        self.span = span

    @property
    def sort_key(self):
        return (-self.priority, self.seq)


class Tenant:
    """One resident serving target: a predict_fn plus its slot geometry,
    queues, and policies. Created through `ServingScheduler.add_tenant` /
    `add_fleet`."""

    def __init__(self, name: str, predict_fn, slots, *, queue_depth: int,
                 admission: str, deadline_policy: str, max_wait_s: float,
                 registry: MetricsRegistry | None = None, retries: int = 2,
                 retry_backoff_ms: float = 1.0, isolate: bool = True):
        if admission not in ("block", "reject"):
            raise ValueError(f"admission must be 'block' or 'reject', "
                             f"got {admission!r}")
        if deadline_policy not in ("drop", "deprioritize"):
            raise ValueError(f"deadline_policy must be 'drop' or "
                             f"'deprioritize', got {deadline_policy!r}")
        slots = tuple(sorted(int(s) for s in slots))
        if not slots or slots[0] <= 0:
            raise ValueError(f"slots must be positive sizes, got {slots}")
        self.name = name
        self.predict_fn = predict_fn
        self.slots = slots
        self.queue_depth = int(queue_depth)
        self.admission = admission
        self.deadline_policy = deadline_policy
        self.max_wait_s = float(max_wait_s)
        self.retries = int(retries)
        self.retry_backoff_ms = float(retry_backoff_ms)
        self.isolate = bool(isolate)
        self.stats = TenantStats(name, registry=registry)
        # scheduling state (all guarded by the scheduler's _lock)
        self.heap: list = []          # (sort_key, _Request) in-deadline work
        self.lapsed: deque = deque()  # past-deadline, deprioritized FIFO
        self.carry: _Request | None = None   # partially-packed request
        self.pending_rows: int = 0    # queued (undispatched) rows
        self.oldest: float | None = None     # arrival of oldest pending
        # fault-tolerance state (also guarded by the scheduler's _lock)
        self.inflight = False         # a packed slot is inside predict_fn
        self.inflight_since: float | None = None
        self.inflight_riders: list | None = None
        self.quarantined = False      # watchdog benched this tenant

    # -- queue state helpers (call with the scheduler lock held) ------------

    def _has_pending(self) -> bool:
        return self.pending_rows > 0

    def _refresh_oldest(self):
        arrivals = [r.arrival for _, r in self.heap]
        arrivals += [r.arrival for r in self.lapsed]
        if self.carry is not None:
            arrivals.append(self.carry.arrival)
        self.oldest = min(arrivals) if arrivals else None

    def _dispatchable(self, now: float) -> bool:
        if not self._has_pending():
            return False
        if self.pending_rows >= self.slots[-1]:
            return True
        return (self.oldest is not None
                and now - self.oldest >= self.max_wait_s)

    def _wait_deadline(self) -> float | None:
        """Absolute monotonic time at which pending work must dispatch."""
        if not self._has_pending() or self.oldest is None:
            return None
        return self.oldest + self.max_wait_s


class ServingScheduler:
    """Continuous-batching, multi-tenant request scheduler (front door v2).

        sched = ServingScheduler(max_wait_ms=2.0)
        sched.add_fleet("maps", fleet_a)
        sched.add_fleet("robots", fleet_b, method="nn_rbcm")
        fut = sched.add_request(Xq, tenant="maps", deadline_ms=50.0)
        mean, var = fut.result()
        sched.close()             # or use as a context manager

    A background worker drives `step()`; construct with `autostart=False`
    to drive it manually (deterministic tests). `submit` is an alias of
    `add_request` so a one-tenant scheduler is a drop-in for the v1
    FrontDoor surface (`GPFleet.to_server()` returns exactly that).

    `registry` (default: the process-wide `repro.obs.default_registry()`)
    receives the tenant-labeled counter/histogram mirror; `span_log` (a
    path or `repro.obs.SpanLog`) exports one JSONL event per finished
    request with the per-stage span timings.
    """

    def __init__(self, *, max_wait_ms: float = 2.0, autostart: bool = True,
                 registry: MetricsRegistry | None = None, span_log=None,
                 stall_timeout_ms: float | None = None):
        self.max_wait_s = float(max_wait_ms) * 1e-3
        self.registry = registry if registry is not None \
            else default_registry()
        self._own_span_log = isinstance(span_log, (str, os.PathLike))
        self.span_log: SpanLog | None = (
            SpanLog(span_log) if self._own_span_log else span_log)
        self._tenants: dict[str, Tenant] = {}
        self._order: list[str] = []
        self._rr = 0                      # round-robin cursor into _order
        self._seq = 0
        self._lock = threading.Lock()
        self._work = threading.Condition(self._lock)    # new work / close
        self._space = threading.Condition(self._lock)   # queue space freed
        self._closing = False
        self._draining = False
        self._worker: threading.Thread | None = None
        self._worker_gen = 0          # bumped when the watchdog respawns
        self._autostart = bool(autostart)
        if autostart:
            self._spawn_worker_locked()
        # stall watchdog: fails in-flight Futures of a dispatch that has
        # been inside predict_fn longer than the timeout (see _watchdog)
        self.stall_timeout_s = (None if stall_timeout_ms is None
                                else float(stall_timeout_ms) * 1e-3)
        self._wd_stop = threading.Event()
        self._watchdog: threading.Thread | None = None
        if self.stall_timeout_s is not None:
            self._watchdog = threading.Thread(
                target=self._watchdog_loop, name="gp-scheduler-watchdog",
                daemon=True)
            self._watchdog.start()

    def _spawn_worker_locked(self):
        self._worker_gen += 1
        self._worker = threading.Thread(
            target=self._worker_loop, args=(self._worker_gen,),
            name=f"gp-scheduler-{self._worker_gen}", daemon=True)
        self._worker.start()

    def _tracing(self) -> bool:
        return self.span_log is not None or self.registry.enabled

    def _emit(self, event: dict):
        if self.span_log is not None:
            self.span_log.emit(event)

    # -- tenant registration -------------------------------------------------

    def add_tenant(self, name: str, predict_fn, *, slots,
                   queue_depth: int = 1024, admission: str = "block",
                   deadline_policy: str = "drop",
                   max_wait_ms: float | None = None,
                   warm_example=None, retries: int = 2,
                   retry_backoff_ms: float = 1.0,
                   isolate: bool = True) -> Tenant:
        """Register a serving target.

        predict_fn((S, D)) -> (mean (S,), var (S,), ...) for every S in
        `slots`. `warm_example` (a (D,) row, or (n, D) array whose first
        row is used) pre-compiles every slot geometry NOW so serving never
        traces; pass None to let the first dispatches compile lazily.

        Failure policy: a slot whose predict_fn raises is retried
        `retries` times with exponential backoff (retry_backoff_ms * 2^k);
        if it still fails and `isolate=True`, each rider is re-run ALONE in
        the smallest fitting slot so one poisoned request cannot fail its
        batch-mates — only riders that fail solo get the exception.
        """
        tenant = Tenant(name, predict_fn, slots, queue_depth=queue_depth,
                        admission=admission, deadline_policy=deadline_policy,
                        max_wait_s=(self.max_wait_s if max_wait_ms is None
                                    else float(max_wait_ms) * 1e-3),
                        registry=self.registry, retries=retries,
                        retry_backoff_ms=retry_backoff_ms, isolate=isolate)
        with self._lock:
            if self._closing:
                raise SchedulerClosed("scheduler is closed")
            if name in self._tenants:
                raise ValueError(f"tenant {name!r} already registered")
            self._tenants[name] = tenant
            self._order.append(name)
        if warm_example is not None:
            self.warm(name, warm_example)
        return tenant

    def add_fleet(self, name: str, fleet, *, method: str | None = None,
                  max_slot: int | None = None, continuous: bool = True,
                  queue_depth: int = 1024, admission: str = "block",
                  deadline_policy: str = "drop",
                  max_wait_ms: float | None = None,
                  warm: bool = True, retries: int = 2,
                  retry_backoff_ms: float = 1.0,
                  isolate: bool = True, fault_plan=None) -> Tenant:
        """Register a fitted `GPFleet` as a tenant.

        Slot geometry derives from the fleet: align = engine chunk,
        ceiling = the method registry's `max_slot` capability (capped by
        `max_slot` here). `continuous=True` serves the quantized ladder
        (right-sized slots, the v2 behavior); `continuous=False` pins the
        single fixed geometry the v1 FrontDoor used.

        `fault_plan` (repro.chaos.FaultPlan) runs the tenant under chaos:
        consensus faults ride `GPFleet.predict(fault_plan=...,
        allow_degraded=True)` — warm-up compiles the degraded traces, so
        the zero-recompile contract still holds — while the plan's serving
        faults (stragglers, injected failures) wrap the predict_fn on the
        dispatch path AFTER warm-up (`repro.chaos.wrap_predict_fn`).
        """
        align, reg_max = fleet.slot_geometry(method)
        hi = reg_max if max_slot is None else int(max_slot)
        slots = slot_ladder(align, hi) if continuous else (hi,)
        if fault_plan is None:
            predict_fn = (lambda Xs: fleet.predict(Xs, method=method))
        else:
            predict_fn = (lambda Xs: fleet.predict(
                Xs, method=method, fault_plan=fault_plan,
                allow_degraded=True))
        example = None
        if warm:
            example = np.zeros((1, int(fleet.config.input_dim)),
                               dtype=fleet.fitted.Xp.dtype)
        tenant = self.add_tenant(name, predict_fn, slots=slots,
                                 queue_depth=queue_depth,
                                 admission=admission,
                                 deadline_policy=deadline_policy,
                                 max_wait_ms=max_wait_ms,
                                 warm_example=example, retries=retries,
                                 retry_backoff_ms=retry_backoff_ms,
                                 isolate=isolate)
        if fault_plan is not None and not fault_plan.empty:
            # serving faults start AFTER warm-up so registration cannot be
            # failed or slowed by the plan's own injections
            from ..chaos import wrap_predict_fn
            tenant.predict_fn = wrap_predict_fn(tenant.predict_fn,
                                                fault_plan)
        # pull-style gauge: the engine's trace count, sampled at collect
        # time — "recompiles after warmup" is this minus its post-warm value
        self.registry.gauge(
            "gp_jit_cache_misses",
            "engine trace count (distinct compiled programs)").set_fn(
            lambda: float(fleet.jit_cache_misses), tenant=name)
        # pull-style gauge: queued (undispatched) rows per tenant, sampled
        # at collect time — the backlog signal autoscalers/dashboards watch
        self.registry.gauge(
            "gp_tenant_queued_rows",
            "queued (undispatched) request rows per tenant").set_fn(
            lambda: float(tenant.pending_rows), tenant=name)
        return tenant

    def warm(self, name: str, example) -> None:
        """Compile every slot geometry of tenant `name` against `example`
        (a (D,) row or an (n, D) array) so serving hits a warm jit cache."""
        t = self._get(name)
        row = np.asarray(example)
        row = row[0] if row.ndim == 2 else row
        for s in t.slots:
            batch = np.broadcast_to(row, (s, row.shape[-1]))
            out = t.predict_fn(jnp.asarray(batch))
            jax.block_until_ready(out[0])

    def _get(self, name: str | None) -> Tenant:
        if name is None:
            if len(self._tenants) != 1:
                raise ValueError(
                    f"tenant= is required when {len(self._tenants)} tenants "
                    f"are registered ({sorted(self._tenants)})")
            return next(iter(self._tenants.values()))
        t = self._tenants.get(name)
        if t is None:
            raise KeyError(f"unknown tenant {name!r}; registered: "
                           f"{sorted(self._tenants)}")
        return t

    @property
    def tenants(self) -> tuple[str, ...]:
        return tuple(self._order)

    @property
    def tenant_stats(self) -> dict[str, TenantStats]:
        return {n: t.stats for n, t in self._tenants.items()}

    @property
    def stats(self) -> TenantStats:
        """The single tenant's stats (v1 FrontDoor compat). For multi-
        tenant schedulers use `tenant_stats[name]`."""
        if len(self._tenants) != 1:
            raise ValueError("stats is single-tenant sugar; use "
                             "tenant_stats for multi-tenant schedulers")
        return next(iter(self._tenants.values())).stats

    # -- client side ---------------------------------------------------------

    def add_request(self, Xq, *, tenant: str | None = None,
                    priority: int = 0,
                    deadline_ms: float | None = None) -> Future:
        """Enqueue one (Nq, D) request -> Future of (mean (Nq,), var (Nq,)).

        Raises `SchedulerClosed` after close(); over `queue_depth` either
        blocks (admission="block") or raises `SchedulerSaturated`.
        Higher `priority` packs first; `deadline_ms` is relative to now
        (see the tenant's deadline_policy for what expiry means).
        """
        Xq = np.asarray(Xq)
        if Xq.ndim != 2:
            raise ValueError(f"request must be (Nq, D), got {Xq.shape}")
        if Xq.shape[0] == 0:
            raise ValueError("request must contain at least one query row")
        t = self._get(tenant)
        now = time.perf_counter()
        deadline = None if deadline_ms is None else now + deadline_ms * 1e-3
        fut: Future = Future()
        span = Span("request", t=now, tenant=t.name,
                    priority=int(priority)) if self._tracing() else None
        with self._lock:
            if self._closing:
                raise SchedulerClosed("scheduler is closed")
            if t.quarantined:
                raise SchedulerStalled(
                    f"tenant {t.name!r} is quarantined: its predict_fn "
                    f"stalled past the watchdog timeout and has not "
                    f"returned")
            while t.pending_rows + Xq.shape[0] > t.queue_depth:
                if t.admission == "reject":
                    t.stats.count("rejected")
                    raise SchedulerSaturated(
                        f"tenant {t.name!r} queue is full "
                        f"({t.pending_rows} rows >= depth {t.queue_depth})")
                # backpressure: wait WITHOUT the lock (Condition.wait
                # releases it) so close() and the packer both get through
                self._space.wait()
                if self._closing:
                    raise SchedulerClosed("scheduler closed while waiting "
                                          "for queue space")
            self._seq += 1
            req = _Request(Xq, fut, int(priority), deadline, now, self._seq,
                           span=span)
            if span is not None:
                span.labels["seq"] = req.seq
            heapq.heappush(t.heap, (req.sort_key, req))
            t.pending_rows += req.n
            if t.oldest is None or now < t.oldest:
                t.oldest = now
            self._work.notify_all()
        t.stats.count("requests")
        return fut

    # v1 FrontDoor-compatible alias (GPFleet.to_server returns a scheduler)
    submit = add_request

    # -- scheduling core -----------------------------------------------------

    def _next_tenant_locked(self, now: float, force: bool) -> Tenant | None:
        """Round-robin over tenants with dispatchable work (any pending
        work when force/draining)."""
        n = len(self._order)
        for i in range(n):
            name = self._order[(self._rr + i) % n]
            t = self._tenants[name]
            if t.inflight or t.quarantined:
                # inflight: a (possibly zombie) thread is already inside
                # this tenant's predict_fn; quarantined: the watchdog
                # benched it until that call returns
                continue
            ok = t._has_pending() if (force or self._draining) \
                else t._dispatchable(now)
            if ok:
                self._rr = (self._rr + i + 1) % n
                return t
        return None

    def _pop_locked(self, t: Tenant, now: float, dropped: list):
        """Next request to pack, honoring carry > priority > lapsed order
        and the deadline policy. Returns None when nothing is packable."""
        if t.carry is not None:
            req, t.carry = t.carry, None
            return req
        while t.heap:
            _, req = heapq.heappop(t.heap)
            if (req.deadline is not None and now > req.deadline
                    and req.off == 0):
                if t.deadline_policy == "drop":
                    t.pending_rows -= req.n
                    dropped.append(req)
                    continue
                if not req.lapsed:
                    req.lapsed = True
                    t.stats.count("lapsed")
                t.lapsed.append(req)
                continue
            return req
        if t.lapsed:
            return t.lapsed.popleft()
        return None

    def _pack_locked(self, t: Tenant, now: float, dropped: list):
        """Reserve up to one slot of rows from tenant `t`'s queues.
        Returns (riders, slot) — riders are (request, start_row, n_rows)
        triples — or None if every pending request was dropped."""
        slot = pick_slot(t.slots, t.pending_rows)
        riders = []
        rows = 0
        while rows < slot:
            req = self._pop_locked(t, now, dropped)
            if req is None:
                break
            take = min(req.n - req.off, slot - rows)
            riders.append((req, req.off, take))
            req.off += take
            rows += take
            t.pending_rows -= take
            if req.off < req.n:       # slot filled mid-request: carry over
                t.carry = req
                break
        t._refresh_oldest()
        if riders or dropped:      # either way rows left the queue
            self._space.notify_all()
        if not riders:
            return None
        return riders, slot

    def step(self, *, force: bool = False) -> bool:
        """Pack and serve ONE slot for the next tenant in round-robin
        order. Returns True if a slot was dispatched. `force` dispatches
        partial slots immediately (drain / manual stepping)."""
        now = time.perf_counter()
        dropped: list[_Request] = []
        with self._lock:
            t = self._next_tenant_locked(now, force)
            plan = None if t is None else self._pack_locked(t, now, dropped)
            if plan is not None:
                # mark in-flight UNDER the pack lock so the watchdog sees
                # the dispatch the moment it can exist
                t.inflight = True
                t.inflight_since = time.perf_counter()
                t.inflight_riders = list(plan[0])
        # futures resolve OUTSIDE the lock: done-callbacks may re-enter
        # (submit a follow-up request) without deadlocking
        for req in dropped:
            t.stats.count("dropped")
            if req.span is not None:
                req.span.advance("queue")
                self._emit(req.span.event("deadline_dropped", rows=req.n))
            if not req.fut.cancelled():
                req.fut.set_exception(DeadlineExceeded(
                    f"request missed its deadline by "
                    f"{(now - req.deadline) * 1e3:.1f} ms before scheduling"))
        if plan is None:
            return False
        self._execute(t, *plan, t_pack0=now)
        return True

    def _predict_slot(self, t: Tenant, batch, rows: int, retries: int):
        """Run one slot batch through predict_fn with retry-on-failure
        (exponential backoff). Returns host arrays (mean, var, t_disp,
        t_dev); raises the LAST exception once retries are exhausted.
        device->host transfer stays inside the guard: deferred runtime
        errors surface here, failing the dispatch and not the worker."""
        attempt = 0
        while True:
            try:
                out = t.predict_fn(jnp.asarray(batch))
                mean, var = out[0], out[1]
                t_disp = time.perf_counter()   # async dispatch returned
                jax.block_until_ready(mean)
                t_dev = time.perf_counter()
                return (np.asarray(mean)[:rows], np.asarray(var)[:rows],
                        t_disp, t_dev)
            except Exception:
                if attempt >= retries:
                    raise
                t.stats.count("retried")
                time.sleep(t.retry_backoff_ms * (2.0 ** attempt) * 1e-3)
                attempt += 1

    def _fail_riders(self, t: Tenant, riders, exc):
        for req, _, _ in riders:
            if req.span is not None:
                req.span.advance("stitch")
                self._emit(req.span.event("error", rows=req.n))
            if not req.fut.done():     # done(): watchdog may have beaten us
                req.fut.set_exception(exc)

    def _deliver(self, t: Tenant, riders, mean, var, slot: int, dt: float):
        """Fan a served slot's answers back out to its riders and account
        the dispatch (called WITHOUT the lock)."""
        rows = sum(k for _, _, k in riders)
        off = 0
        done = time.perf_counter()
        for req, _, k in riders:
            req.parts.append((mean[off:off + k], var[off:off + k]))
            off += k
            if sum(p[0].shape[0] for p in req.parts) == req.n:
                m = np.concatenate([p[0] for p in req.parts])
                v = np.concatenate([p[1] for p in req.parts])
                if req.span is not None:
                    req.span.advance("stitch")
                    t.stats.record_latency(req.span.elapsed)
                    t.stats.record_stages(req.span.stages)
                    self._emit(req.span.event(
                        "ok", rows=req.n, slots=len(req.parts)))
                else:
                    t.stats.record_latency(done - req.arrival)
                if not req.fut.done():
                    req.fut.set_result((m, v))
            elif req.span is not None:
                req.span.advance("stitch")     # next slot waits in "queue"
        t.stats.count("queries", rows)
        t.stats.count("padded_queries", slot - rows)
        t.stats.count("batches")
        t.stats.add_engine_seconds(dt)
        t.stats.update_gauges()

    def _isolate_riders(self, t: Tenant, riders, exc):
        """Per-rider failure isolation: the shared slot failed after
        retries, so re-run each rider ALONE (smallest fitting slot, single
        attempt). Healthy riders get answers; only the poisoned one(s)
        get the exception."""
        for rider in riders:
            req, a, k = rider
            sub = req.Xq[a:a + k]
            slot = next((s for s in t.slots if s >= k), t.slots[-1])
            batch = sub if k == slot else np.concatenate(
                [sub, np.repeat(sub[-1:], slot - k, axis=0)])
            t0 = time.perf_counter()
            try:
                mean, var, _, t_dev = self._predict_slot(t, batch, k, 0)
            except Exception as sub_exc:
                self._fail_riders(t, [rider], sub_exc)
            else:
                t.stats.count("isolated")
                self._deliver(t, [rider], mean, var, slot, t_dev - t0)

    def _execute(self, t: Tenant, riders, slot: int, *,
                 t_pack0: float | None = None):
        """Run one packed slot through the tenant's predict_fn and fan the
        answers back out (called WITHOUT the lock)."""
        if t_pack0 is None:
            t_pack0 = time.perf_counter()
        try:
            parts = [req.Xq[a:a + k] for req, a, k in riders]
            rows = sum(k for _, _, k in riders)
            batch = np.concatenate(parts, axis=0)
            if rows < slot:
                # edge-replicate: pad rows are a served workload, never X=0
                batch = np.concatenate(
                    [batch, np.repeat(batch[-1:], slot - rows, axis=0)])
            t0 = time.perf_counter()
            for req, _, _ in riders:
                if req.span is not None:
                    # a multi-slot request re-enters "queue" after each
                    # slot's stitch, so stages stay contiguous across slots
                    req.span.advance("queue", t_pack0)
                    req.span.advance("pack", t0)
            try:
                mean, var, t_disp, t_dev = self._predict_slot(
                    t, batch, rows, t.retries)
            except Exception as exc:
                if t.isolate and len(riders) > 1:
                    self._isolate_riders(t, riders, exc)
                else:
                    self._fail_riders(t, riders, exc)
                return
            for req, _, _ in riders:
                if req.span is not None:
                    req.span.advance("dispatch", t_disp)
                    req.span.advance("device", t_dev)
            self._deliver(t, riders, mean, var, slot, t_dev - t0)
        finally:
            with self._lock:
                t.inflight = False
                t.inflight_since = None
                t.inflight_riders = None
                if t.quarantined:
                    # the stalled call came back (its riders were already
                    # failed by the watchdog): the tenant can serve again
                    t.quarantined = False
                self._work.notify_all()

    # -- worker / lifecycle --------------------------------------------------

    def _worker_loop(self, gen: int | None = None):
        while True:
            with self._lock:
                if self._closing:
                    return
                if gen is not None and gen != self._worker_gen:
                    return     # superseded by a watchdog-spawned worker
                now = time.perf_counter()
                timeout = None
                ready = False
                for t in self._tenants.values():
                    if t.inflight or t.quarantined:
                        continue
                    if t._dispatchable(now):
                        ready = True
                        break
                    wd = t._wait_deadline()
                    if wd is not None:
                        remaining = max(1e-4, wd - now)
                        timeout = remaining if timeout is None \
                            else min(timeout, remaining)
                if not ready:
                    self._work.wait(timeout=timeout)
                    if self._closing:
                        return
                    if gen is not None and gen != self._worker_gen:
                        return
            self.step()

    def _watchdog_loop(self):
        """Fail the Futures of any dispatch stuck inside predict_fn past
        `stall_timeout_s`, quarantine the tenant (until the stuck call
        returns), fail its queued work, and respawn the worker so OTHER
        tenants keep serving. The stuck thread itself cannot be killed —
        when it eventually returns, `_execute`'s `fut.done()` guards make
        its late answers no-ops."""
        poll = max(self.stall_timeout_s / 4.0, 1e-3)
        while not self._wd_stop.wait(poll):
            now = time.perf_counter()
            stalled = []
            with self._lock:
                if self._closing:
                    return
                for t in self._tenants.values():
                    if not (t.inflight and not t.quarantined
                            and t.inflight_since is not None):
                        continue
                    age = now - t.inflight_since
                    if age <= self.stall_timeout_s:
                        continue
                    t.quarantined = True
                    riders = list(t.inflight_riders or [])
                    queued = []
                    if t.carry is not None:
                        queued.append(t.carry)
                        t.carry = None
                    queued += [r for _, r in t.heap]
                    queued += list(t.lapsed)
                    t.heap.clear()
                    t.lapsed.clear()
                    t.pending_rows = 0
                    t.oldest = None
                    stalled.append((t, riders, queued, age))
                if stalled:
                    self._space.notify_all()
                    respawn = (self._worker is not None
                               and not self._closing)
                    if respawn:
                        self._spawn_worker_locked()
            for t, riders, queued, age in stalled:
                t.stats.count("stalled")
                exc = SchedulerStalled(
                    f"tenant {t.name!r} dispatch stalled for "
                    f"{age * 1e3:.0f} ms (> stall_timeout "
                    f"{self.stall_timeout_s * 1e3:.0f} ms); in-flight and "
                    f"queued requests failed, tenant quarantined")
                self._fail_riders(t, riders, exc)
                for req in queued:
                    if req.span is not None:
                        req.span.advance("queue")
                        self._emit(req.span.event("stalled", rows=req.n))
                    if not req.fut.done():
                        req.fut.set_exception(exc)

    def pending(self) -> int:
        """Total undispatched query rows across tenants."""
        with self._lock:
            return sum(t.pending_rows for t in self._tenants.values())

    def _sweep_leftovers_locked(self) -> list:
        """Remove and return every queued request (call with _lock held)."""
        leftovers = []
        for t in self._tenants.values():
            if t.carry is not None:
                leftovers.append(t.carry)
                t.carry = None
            leftovers += [r for _, r in t.heap]
            leftovers += list(t.lapsed)
            t.heap.clear()
            t.lapsed.clear()
            t.pending_rows = 0
            t.oldest = None
        return leftovers

    def close(self, *, drain: bool = True, timeout: float | None = 30.0):
        """Stop accepting requests — BOUNDED: returns within ~`timeout`
        seconds even with a wedged predict_fn or a quarantined tenant.

        drain=True (default) serves everything pending first; whatever is
        still queued at the deadline (stuck tenants, timeout hit) is
        failed with `SchedulerClosed` — no Future is ever left hanging.
        drain=False cancels every queued Future immediately.
        `timeout=None` restores the unbounded v1 wait."""
        with self._lock:
            if self._closing:
                return
            self._closing = True
            self._draining = drain
            self._work.notify_all()
            self._space.notify_all()
        deadline = None if timeout is None \
            else time.perf_counter() + float(timeout)
        self._wd_stop.set()
        if self._watchdog is not None:
            self._watchdog.join(timeout=None if deadline is None
                                else max(0.0, deadline - time.perf_counter()))
        if self._worker is not None:
            self._worker.join(timeout=None if deadline is None
                              else max(0.0, deadline - time.perf_counter()))
        if drain:
            while deadline is None or time.perf_counter() < deadline:
                if not self.step(force=True):
                    break
            with self._lock:
                leftovers = self._sweep_leftovers_locked()
                # anything still in-flight here belongs to a thread that
                # did not come back before the deadline: fail its riders
                # too (the fut.done() guards turn a late answer into a
                # no-op) so close() never strands a Future
                for t in self._tenants.values():
                    if t.inflight and t.inflight_riders:
                        leftovers += [req for req, _, _ in
                                      t.inflight_riders]
            for req in leftovers:
                if not req.fut.done():
                    req.fut.set_exception(SchedulerClosed(
                        "scheduler close(drain=True) could not serve this "
                        "request before the close timeout (stalled or "
                        "quarantined tenant)"))
        else:
            with self._lock:
                leftovers = self._sweep_leftovers_locked()
            for req in leftovers:
                # a partially-served request cannot be cancelled (its
                # Future may already have riders waiting on streamed rows
                # that will never come) — fail it explicitly instead
                if req.off > 0:
                    if not req.fut.done():
                        req.fut.set_exception(SchedulerClosed(
                            "scheduler closed mid-request (drain=False)"))
                else:
                    req.fut.cancel()
        if self._own_span_log and self.span_log is not None:
            self.span_log.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
