"""Serving launcher: batched prefill + autoregressive decode.

  PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
      --reduced --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..models import lm, encdec
from .steps import make_prefill_step, make_decode_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mod = encdec if cfg.encdec else lm
    key = jax.random.PRNGKey(0)
    params = mod.init_params(cfg, key)
    B, P, G = args.batch, args.prompt_len, args.gen
    max_len = P + G + cfg.vis_tokens + 1

    prompts = jax.random.randint(key, (B, P), 0, cfg.vocab_size)
    prefill = jax.jit(make_prefill_step(cfg, max_len=max_len))
    decode = jax.jit(make_decode_step(cfg))

    t0 = time.time()
    if cfg.encdec:
        frames = 0.1 * jax.random.normal(key, (B, cfg.enc_seq, cfg.d_model))
        logits, cache, enc_out = prefill(params, frames, prompts)
    elif cfg.vis_tokens:
        embeds = 0.1 * jax.random.normal(key, (B, cfg.vis_tokens, cfg.d_model))
        logits, cache = prefill(params, prompts, embeds)
    else:
        logits, cache = prefill(params, prompts)
    t_prefill = time.time() - t0

    out_tokens = []
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    t0 = time.time()
    for i in range(G):
        out_tokens.append(np.asarray(tok)[:, 0])
        if cfg.encdec:
            logits, cache = decode(params, cache, enc_out, tok)
        else:
            logits, cache = decode(params, cache, tok)
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits[:, -1] / args.temperature)[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    t_dec = time.time() - t0
    gen = np.stack(out_tokens, 1)
    print(f"{cfg.name}: prefill {B}x{P} in {t_prefill:.2f}s; "
          f"decoded {G} tokens/seq in {t_dec:.2f}s "
          f"({B*G/max(t_dec,1e-9):.1f} tok/s)")
    print("sample generations (token ids):")
    for b in range(min(B, 2)):
        print(" ", gen[b][:16].tolist())


if __name__ == "__main__":
    main()
