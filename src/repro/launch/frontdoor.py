"""v1 front-door compat shim over the request-level scheduler.

The original `FrontDoor` was a single queue feeding fixed-shape
micro-batches to one `predict_fn`. That machinery now lives in
`repro.launch.scheduler.ServingScheduler` — continuous slot packing,
multi-tenant round-robin, priorities, deadlines, admission control. This
module keeps the v1 surface (`FrontDoor(predict_fn, batch)`, `submit`,
`close`, `stats`, context manager) as a one-tenant scheduler pinned to a
single fixed slot geometry, so existing callers and tests see byte-for-
byte the old behavior:

  * every dispatch runs the one `(batch, D)` compiled program (zero
    recompiles after the first), padding the tail by edge-replication;
  * `submit` blocks for backpressure at `queue_depth` queued query rows
    and raises `RuntimeError` (`SchedulerClosed`) after `close()`;
  * `stats` is the tenant's `TenantStats`, a superset of the old
    `FrontDoorStats` (same fields + drop/reject/latency counters).

The v1 bug where `submit()` held the lifecycle lock across a blocking
queue `put()` — letting a backpressured submitter stall `close()` — is
gone structurally: the scheduler's admission wait is a Condition wait
that releases the lock, and `close()` wakes every waiter.

New code should use `ServingScheduler` (or `GPFleet.to_server()`, which
returns one) directly; this shim exists so v1 call sites keep working.
"""
from __future__ import annotations

from concurrent.futures import Future

from repro.launch.scheduler import ServingScheduler, TenantStats

# v1 importers expect the stats type under this name
FrontDoorStats = TenantStats

__all__ = ["FrontDoor", "FrontDoorStats"]


class FrontDoor:
    """Micro-batching request front door over a `predict_fn` (v1 API).

    predict_fn(Xs (batch, D)) -> (mean (batch,), var (batch,), info); bind
    the method name with functools.partial, e.g.
    `FrontDoor(partial(eng.predict, "rbcm"), batch=256)`.

    Equivalent to a one-tenant `ServingScheduler` with the single slot
    geometry `(batch,)`; `queue_depth` bounds queued query ROWS (v1
    counted whole requests — rows is the resource the engine actually
    spends, and it is what the scheduler's admission control meters).
    """

    def __init__(self, predict_fn, batch: int, *, max_wait_ms: float = 2.0,
                 queue_depth: int = 1024):
        self.predict_fn = predict_fn
        self.batch = int(batch)
        self._sched = ServingScheduler(max_wait_ms=max_wait_ms)
        self._tenant = self._sched.add_tenant(
            "default", predict_fn, slots=(self.batch,),
            queue_depth=queue_depth, admission="block")

    @property
    def stats(self) -> TenantStats:
        return self._tenant.stats

    def submit(self, Xq) -> Future:
        """Enqueue one request (Nq, D) -> Future of (mean (Nq,), var (Nq,)).

        Raises RuntimeError after close(). Blocks (backpressure) when
        queue_depth query rows are already waiting.
        """
        return self._sched.add_request(Xq)

    def close(self, *, drain: bool = True):
        """Stop accepting requests; by default serve everything pending."""
        self._sched.close(drain=drain)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
