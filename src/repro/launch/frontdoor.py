"""Async micro-batching front door: request queue -> fixed-shape batches ->
de-batched per-request futures.

The serving engines (`PredictionEngine`, `ShardedEngine`) want fixed query
shapes — one compiled program per batch geometry — while clients submit
ragged requests at arbitrary times. `FrontDoor` bridges the two:

  submit(Xq) -> Future          clients enqueue (Nq_i, D) query arrays and
                                immediately get a Future of (mean, var)
  collector thread              drains the queue, coalescing requests until
                                a full micro-batch of `batch` queries is
                                pending or `max_wait_ms` has passed since
                                the oldest undispatched request (latency
                                bound under light load)
  dispatch                      concatenates pending requests, pads the tail
                                to the fixed `batch` shape (edge-replicating
                                the last real query), runs `predict_fn` once
                                per batch, slices the answers back per
                                request, and resolves the futures

Every dispatch hits the engine's jit cache for the same compiled program —
zero recompiles after the first batch regardless of request sizes. The
routed CBNN path composes by passing `ShardedEngine.predict_routed` as
`predict_fn` (routing happens per micro-batch inside the engine).

This is an in-process front door (the paper's multi-robot deployments and
our benchmarks drive it directly); an RPC server would own a FrontDoor and
call submit per connection. `GPFleet.to_server()` is the one-line way to
put a fitted fleet behind one.
"""
from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class FrontDoorStats:
    """Serving counters (read after close): batches dispatched, queries
    served, zero-padding fraction, wall time inside the engine."""
    requests: int = 0
    queries: int = 0
    batches: int = 0
    padded_queries: int = 0
    engine_seconds: float = 0.0
    _lock: threading.Lock = field(default_factory=threading.Lock,
                                  repr=False)

    @property
    def padding_fraction(self) -> float:
        total = self.queries + self.padded_queries
        return self.padded_queries / total if total else 0.0


class FrontDoor:
    """Micro-batching request front door over a `predict_fn`.

    predict_fn(Xs (batch, D)) -> (mean (batch,), var (batch,), info); bind
    the method name with functools.partial, e.g.
    `FrontDoor(partial(eng.predict, "rbcm"), batch=256)`.
    """

    def __init__(self, predict_fn, batch: int, *, max_wait_ms: float = 2.0,
                 queue_depth: int = 1024):
        self.predict_fn = predict_fn
        self.batch = int(batch)
        self.max_wait_s = float(max_wait_ms) * 1e-3
        self.stats = FrontDoorStats()
        self._queue: queue.Queue = queue.Queue(maxsize=queue_depth)
        self._closing = threading.Event()
        # serializes the closed-check + enqueue in submit against close()
        # setting the flag: once close holds this lock and sets _closing, no
        # submit can slip a request past the final drain
        self._lifecycle = threading.Lock()
        self._leftover: list = []    # collector's undispatched items at exit
        self._worker = threading.Thread(target=self._collector_loop,
                                        name="gp-frontdoor", daemon=True)
        self._worker.start()

    # -- client side ---------------------------------------------------------

    def submit(self, Xq) -> Future:
        """Enqueue one request (Nq, D) -> Future of (mean (Nq,), var (Nq,)).

        Raises RuntimeError after close(). Blocks (backpressure) when the
        queue is at queue_depth.
        """
        Xq = np.asarray(Xq)
        if Xq.ndim != 2:
            raise ValueError(f"request must be (Nq, D), got {Xq.shape}")
        fut: Future = Future()
        with self._lifecycle:
            if self._closing.is_set():
                raise RuntimeError("front door is closed")
            self._queue.put((Xq, fut))
        with self.stats._lock:
            self.stats.requests += 1
        return fut

    def close(self, *, drain: bool = True):
        """Stop accepting requests; by default serve everything pending."""
        with self._lifecycle:
            self._closing.set()
        self._worker.join()
        pending = self._leftover + self._take_pending()
        self._leftover = []
        if drain:
            self._dispatch(pending)
        else:
            for _, fut in pending:
                fut.cancel()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # -- collector side ------------------------------------------------------

    def _take_pending(self):
        pending = []
        while True:
            try:
                pending.append(self._queue.get_nowait())
            except queue.Empty:
                return pending

    def _collector_loop(self):
        pending: list = []
        n_pending = 0
        oldest = None
        while not self._closing.is_set():
            timeout = self.max_wait_s if oldest is None else \
                max(1e-4, oldest + self.max_wait_s - time.monotonic())
            try:
                item = self._queue.get(timeout=timeout)
                if oldest is None:
                    oldest = time.monotonic()
                pending.append(item)
                n_pending += item[0].shape[0]
            except queue.Empty:
                pass
            full = n_pending >= self.batch
            expired = oldest is not None and \
                time.monotonic() - oldest >= self.max_wait_s
            if pending and (full or expired):
                self._dispatch(pending)
                pending, n_pending, oldest = [], 0, None
        # closing: hand locally-held items to close() for the drain through
        # a plain list — re-putting into the bounded queue could block
        # forever with no consumer left
        self._leftover = pending

    def _dispatch(self, pending):
        """Coalesce -> fixed-shape batches -> engine -> de-batch."""
        if not pending:
            return
        arrays = [Xq for Xq, _ in pending]
        sizes = [a.shape[0] for a in arrays]
        allq = np.concatenate(arrays, axis=0)
        total = allq.shape[0]
        n_batches = -(-total // self.batch)
        pad = n_batches * self.batch - total
        if pad:
            # edge-replicate so padded rows are a served workload, not X=0
            allq = np.concatenate([allq, np.repeat(allq[-1:], pad, axis=0)])
        batches = allq.reshape(n_batches, self.batch, allq.shape[1])
        means, variances = [], []
        t0 = time.monotonic()
        try:
            for b in batches:
                mean, var, _ = self.predict_fn(jnp.asarray(b))
                means.append(mean)
                variances.append(var)
            jax.block_until_ready(means[-1])
            dt = time.monotonic() - t0
            # device->host conversion can surface deferred runtime errors
            # from EARLIER batches; keep it inside the guard so a failure
            # fails the riders instead of killing the collector thread
            mean = np.concatenate([np.asarray(m) for m in means])[:total]
            var = np.concatenate([np.asarray(v) for v in variances])[:total]
        except Exception as exc:  # fail every rider, not just the first
            for _, fut in pending:
                fut.set_exception(exc)
            return
        offs = np.concatenate([[0], np.cumsum(sizes)])
        for (Xq, fut), a, b in zip(pending, offs[:-1], offs[1:]):
            fut.set_result((mean[a:b], var[a:b]))
        with self.stats._lock:
            self.stats.queries += total
            self.stats.padded_queries += pad
            self.stats.batches += n_batches
            self.stats.engine_seconds += dt
