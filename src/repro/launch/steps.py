"""Jit-able train / prefill / decode steps + per-(arch x shape) input specs.

This is the deployment surface: `build(cfg, shape_name, mesh)` returns the
step function, fully-sharded example inputs (ShapeDtypeStructs — nothing is
allocated), so callers can either `.lower().compile()` (dry-run) or feed real
arrays (training runs, tests).

The paper's technique enters through `consensus`: "allreduce" is the
centralized baseline (GSPMD gradient reduction); "dec_admm" runs the
generalized DEC-apx-GP update (core/federated.py) with one parameter opinion
per consensus-axis member exchanged ring-wise (collective-permute).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..models import lm, encdec
from ..models.act_sharding import use_mesh
from ..models.common import axes_tree, shapes_tree
from ..optim import adam, adafactor, apply_updates
from . import sharding as shd

SHAPES = {
    "train_4k": dict(kind="train", seq=4_096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32_768, batch=32),
    "decode_32k": dict(kind="decode", seq=32_768, batch=128),
    "long_500k": dict(kind="decode", seq=524_288, batch=1, long=True),
}

# long_500k gate (DESIGN.md §5): sub-quadratic archs run as-is; dense archs
# run the sliding-window variant; whisper (enc-dec audio) skips.
LONG_OK_NATIVE = {"jamba-v0.1-52b", "xlstm-350m"}
LONG_SKIP = {"whisper-small"}
LONG_WINDOW = 8_192

# gradient-accumulation factor for train_4k (saved-residual memory control);
# tuned so L * B_loc/micro * S * d * 2B stays well under 16 GB/chip HBM.
MICROBATCH = {
    "dbrx-132b": 8,
    "llama4-maverick-400b-a17b": 8,
    "internvl2-76b": 16,
    "jamba-v0.1-52b": 4,
    "granite-3-8b": 4,
    "phi3-medium-14b": 4,
    "chatglm3-6b": 2,
    "whisper-small": 8,     # 12 heads % 16 -> attention replicated on model;
                            # microbatching bounds the replicated activations
}


def shape_supported(cfg, shape_name: str) -> bool:
    if shape_name == "long_500k" and cfg.name in LONG_SKIP:
        return False
    return True


def cfg_for_shape(cfg, shape_name: str):
    """Per-shape config adjustments (window variant, remat for training)."""
    if shape_name == "train_4k":
        cfg = cfg.with_overrides(remat=True)
    if shape_name == "long_500k" and cfg.name not in LONG_OK_NATIVE:
        cfg = cfg.with_overrides(window=LONG_WINDOW)
    if cfg.encdec and shape_name in ("decode_32k", "long_500k", "prefill_32k"):
        seq = SHAPES[shape_name]["seq"]
        if cfg.max_seq < seq + 1:
            cfg = cfg.with_overrides(max_seq=seq + 1)
    return cfg


def pick_optimizer(cfg, lr=1e-4):
    # llama4-400b's fp32 adam state (8 B/param) exceeds 16 GB/chip at 256
    # chips; adafactor's factored stats fit (DESIGN.md §6).
    if cfg.name.startswith("llama4"):
        return adafactor(lr), "adafactor"
    return adam(lr), "adam"


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def make_train_step(cfg, optimizer, microbatch: int = 1):
    """microbatch > 1: gradient accumulation — scan over micro-slices of the
    batch, f32 grad accumulator. Bounds the per-layer saved-residual memory
    (B_loc * S * d * L / microbatch), which is what actually limits the
    40-80 layer archs at 65k tokens/device (DESIGN.md §6)."""
    loss = encdec.loss_fn if cfg.encdec else lm.loss_fn
    from ..models.act_sharding import constrain

    def train_step(params, opt_state, batch):
        grad_fn = jax.value_and_grad(
            lambda p, b: loss(cfg, p, b), has_aux=True)
        if microbatch == 1:
            (l, metrics), grads = grad_fn(params, batch)
        else:
            def split(t):
                t = t.reshape((microbatch, t.shape[0] // microbatch)
                              + t.shape[1:])
                return constrain(t, (None, "batch") + (None,) * (t.ndim - 2))
            mb = jax.tree.map(split, batch)

            def acc_step(gacc, b):
                (l, m), g = grad_fn(params, b)
                gacc = jax.tree.map(
                    lambda a, gi: a + gi.astype(jnp.float32) / microbatch,
                    gacc, g)
                return gacc, l

            gacc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)
            grads, ls = jax.lax.scan(acc_step, gacc0, mb)
            l, metrics = jnp.mean(ls), {}
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, l, metrics

    return train_step


def make_prefill_step(cfg, max_len: int):
    if cfg.encdec:
        def prefill(params, frames, tokens):
            enc_out = encdec.encode(cfg, params, frames)
            cache = encdec.init_decode_cache(cfg, tokens.shape[0], max_len,
                                             params["embed"].dtype)
            logits, cache = encdec.decode(cfg, params, tokens, enc_out,
                                          cache=cache, logits_slice=1)
            return logits, cache, enc_out
        return prefill

    def prefill(params, tokens, embeds=None):
        cache = lm.init_decode_cache(cfg, tokens.shape[0], max_len,
                                     params["embed"].dtype)
        logits, _, cache = lm.forward(cfg, params, tokens, embeds=embeds,
                                      cache=cache, logits_slice=1)
        return logits, cache
    return prefill


def make_decode_step(cfg):
    if cfg.encdec:
        def decode(params, cache, enc_out, tokens):
            logits, cache = encdec.decode(cfg, params, tokens, enc_out,
                                          cache=cache)
            return logits, cache
        return decode

    def decode(params, cache, tokens):
        logits, _, cache = lm.forward(cfg, params, tokens, cache=cache)
        return logits, cache
    return decode


# ---------------------------------------------------------------------------
# federated (paper technique) train step — generalized DEC-apx-GP (eq. 34)
# ---------------------------------------------------------------------------

def make_federated_train_step(cfg, *, n_agents: int, rho: float = 1.0,
                              kappa: float = 10.0, exchange: bool = True):
    """Each of the `n_agents` consensus-axis members keeps its own parameter
    opinion theta_i and dual p_i; one step = local grad + ring ADMM update.
    params/duals carry a leading (n_agents,) dim (sharded over 'pod' or
    'data'); batch carries (n_agents, B_local, S).

    exchange=False builds the LOCAL-ONLY variant (no neighbor messages, no
    dual update — a pure proximal-gradient step with the same step size).
    Alternating k-1 local steps with one exchange step implements periodic
    consensus ("LocalADMM", EXPERIMENTS.md §Perf pair C): collective bytes
    drop by k at a quantified consensus-error cost."""
    loss = encdec.loss_fn if cfg.encdec else lm.loss_fn

    def step(params_stacked, duals, batch_stacked):
        def local_loss(p, b):
            return loss(cfg, p, b)
        (ls, _), grads = jax.vmap(
            jax.value_and_grad(local_loss, has_aux=True))(
                params_stacked, batch_stacked)

        deg = 2.0 if n_agents > 2 else 1.0

        def upd(th, pdual, g):
            if exchange:
                if n_agents > 2:
                    nbr = jnp.roll(th, 1, axis=0) + jnp.roll(th, -1, axis=0)
                else:
                    nbr = jnp.roll(th, 1, axis=0)
                p_next = pdual + rho * (deg * th - nbr)              # (34a)
                th_next = (rho * nbr - g.astype(th.dtype)
                           + (kappa + deg * rho) * th - p_next) \
                    / (kappa + 2.0 * deg * rho)                      # (34b)
            else:
                # local prox step, same effective step size, no messages
                p_next = pdual
                th_next = th - g.astype(th.dtype) / (kappa + 2.0 * deg * rho)
            return th_next.astype(th.dtype), p_next

        out = jax.tree.map(upd, params_stacked, duals, grads)
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_duals = jax.tree.map(lambda t: t[1], out,
                                 is_leaf=lambda x: isinstance(x, tuple))
        return new_params, new_duals, jnp.mean(ls)

    return step


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct, sharded — zero allocation)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def param_structs(cfg, dtype=jnp.bfloat16):
    mod = encdec if cfg.encdec else lm
    defs = mod.param_defs(cfg)
    return shapes_tree(defs, dtype), axes_tree(defs)


def param_specs(cfg, mesh, dtype=jnp.bfloat16):
    shapes, axes = param_structs(cfg, dtype)
    return shapes, shd.tree_specs(mesh, axes, shapes)


def batch_structs(cfg, shape_name: str, dtype=jnp.bfloat16):
    """(shapes, logical_axes) for the train/prefill token batch."""
    info = SHAPES[shape_name]
    B, S = info["batch"], info["seq"]
    tok_ax = ("batch", "seq")
    if cfg.encdec:
        shapes = {"frames": _sds((B, cfg.enc_seq, cfg.d_model), dtype),
                  "tokens": _sds((B, S), jnp.int32),
                  "labels": _sds((B, S), jnp.int32)}
        axes = {"frames": ("batch", "enc_seq_act", "embed_act"),
                "tokens": tok_ax, "labels": tok_ax}
    elif cfg.vis_tokens:
        s_text = S - cfg.vis_tokens
        shapes = {"tokens": _sds((B, s_text), jnp.int32),
                  "labels": _sds((B, s_text), jnp.int32),
                  "embeds": _sds((B, cfg.vis_tokens, cfg.d_model), dtype)}
        axes = {"tokens": tok_ax, "labels": tok_ax,
                "embeds": ("batch", "vis_act", "embed_act")}
    else:
        shapes = {"tokens": _sds((B, S), jnp.int32),
                  "labels": _sds((B, S), jnp.int32)}
        axes = {"tokens": tok_ax, "labels": tok_ax}
    return shapes, axes


def cache_structs(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    mod = encdec if cfg.encdec else lm
    shapes = jax.eval_shape(
        lambda: mod.init_decode_cache(cfg, batch, max_len, dtype))
    axes = mod.cache_axes(cfg)
    return shapes, axes


def build(cfg, shape_name: str, mesh, dtype=jnp.bfloat16, lr=1e-4,
          policy=None):
    """Returns (step_fn, example_inputs tuple of sharded ShapeDtypeStructs).

    step signatures:
      train  : (params, opt_state, batch)
      prefill: (params, [frames,] tokens[, embeds])
      decode : (params, cache, [enc_out,] tokens)
    """
    cfg = cfg_for_shape(cfg, shape_name)
    info = SHAPES[shape_name]
    kind = info["kind"]
    B, S = info["batch"], info["seq"]
    shard_seq = kind == "decode"   # cache-sequence sharding (sharding.py)

    def _meshed(fn):
        def wrapped(*a, **kw):
            with use_mesh(mesh, shard_kv_seq=shard_seq, policy=policy):
                return fn(*a, **kw)
        return wrapped

    p_shapes, p_axes = param_structs(cfg, dtype)
    p_specs = shd.tree_specs(mesh, p_axes, p_shapes, policy=policy)
    params_in = shd.with_sharding(mesh, p_shapes, p_specs)

    if kind == "train":
        optimizer, opt_name = pick_optimizer(cfg, lr)
        step = _meshed(make_train_step(cfg, optimizer,
                                       microbatch=MICROBATCH.get(cfg.name, 1)))
        opt_shapes = jax.eval_shape(optimizer.init, p_shapes)
        if opt_name == "adam":
            opt_specs = shd.adam_state_specs(p_specs)
        else:
            opt_specs = shd.adafactor_state_specs(p_specs, p_shapes)
        opt_in = shd.with_sharding(mesh, opt_shapes, opt_specs)
        b_shapes, b_axes = batch_structs(cfg, shape_name, dtype)
        b_specs = shd.tree_specs(mesh, b_axes, b_shapes, policy=policy)
        batch_in = shd.with_sharding(mesh, b_shapes, b_specs)
        return step, (params_in, opt_in, batch_in), cfg

    if kind == "prefill":
        step = _meshed(make_prefill_step(cfg, max_len=S + 1))
        b_shapes, b_axes = batch_structs(cfg, shape_name, dtype)
        b_specs = shd.tree_specs(mesh, b_axes, b_shapes, policy=policy)
        b_in = shd.with_sharding(mesh, b_shapes, b_specs)
        if cfg.encdec:
            return step, (params_in, b_in["frames"], b_in["tokens"]), cfg
        if cfg.vis_tokens:
            return step, (params_in, b_in["tokens"], b_in["embeds"]), cfg
        return step, (params_in, b_in["tokens"]), cfg

    # decode: one new token against a cache of S entries
    step = _meshed(make_decode_step(cfg))
    c_shapes, c_axes = cache_structs(cfg, B, S, dtype)
    c_specs = shd.tree_specs(mesh, c_axes, c_shapes, shard_kv_seq=shard_seq,
                             policy=policy)
    cache_in = shd.with_sharding(mesh, c_shapes, c_specs)
    tok = jax.ShapeDtypeStruct(
        (B, 1), jnp.int32,
        sharding=shd.named(mesh, shd.spec_for_axes(
            mesh, ("batch", "seq"), (B, 1))))
    if cfg.encdec:
        enc_spec = shd.spec_for_axes(mesh, ("batch", "enc_seq_act",
                                            "embed_act"),
                                     (B, cfg.enc_seq, cfg.d_model))
        enc_in = jax.ShapeDtypeStruct((B, cfg.enc_seq, cfg.d_model), dtype,
                                      sharding=shd.named(mesh, enc_spec))
        return step, (params_in, cache_in, enc_in, tok), cfg
    return step, (params_in, cache_in, tok), cfg
