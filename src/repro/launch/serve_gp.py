"""GP serving launcher: fit the fleet once, cache factors, then serve
prediction requests through the jit-cached engines — replicated
(`PredictionEngine`), agent-sharded across devices (`ShardedEngine`,
`--sharded`), or CBNN-routed subsets of the sharded fleet (`--routed`).

  PYTHONPATH=src python -m repro.launch.serve_gp --agents 8 --per-agent 128 \
      --method rbcm --requests 64 --batch 256 --chunk 128

Serving front door (the engine layer each path uses is in parentheses):

  default         ragged requests are coalesced host-side, micro-batched to
                  a FIXED batch shape (one compiled program — zero
                  recompiles after warmup), pushed through
                  `PredictionEngine.predict` (the `*_cached` /
                  `*_from_moments` serving stack), and de-batched back into
                  per-request answers.
  --sharded       same front door, but the fleet is sharded over the agent
                  axis of a local device mesh (`launch.mesh.make_agent_mesh`
                  + `core.prediction.ShardedEngine`): per-agent moments run
                  shard-locally, cross-agent sums ride the device-ring
                  collectives (paper eq. 35 on the ICI ring).
  --routed        CBNN query routing on the sharded fleet (nn_* methods,
                  paper §5.2 eq. 39): each micro-batch is routed so every
                  query is served by the single shard holding its
                  most-correlated experts — the "subset of agents perform
                  predictions" serving mode.
  --async-door    replaces the synchronous loop with the
                  `launch.frontdoor.FrontDoor` collector thread: requests
                  are SUBMITTED as they arrive and resolved through
                  futures, with micro-batches cut by size or by the
                  --max-wait-ms latency bound.

`--compare-uncached` also times the per-call path (re-factorizing every
agent's kernel matrix per request — the pre-engine behaviour) on the same
micro-batches and reports the speedup.

`--online` switches to the streaming front door: the fleet keeps OBSERVING
while it serves. Between prediction micro-batches every agent ingests
`--observe-every` fresh observations through the incremental O(W^2)
rank-1 factor updates (core.online), and the engine hot-swaps the new
factors with `swap_experts` — the compiled prediction programs are reused
across swaps (zero recompiles after warmup, asserted at exit).
"""
from __future__ import annotations

import argparse
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..core.consensus import path_graph
from ..core.gp import augment, communication_dataset, pack, stripe_partition
from ..core.online import from_batch, observe_fleet
from ..core.prediction import (PredictionEngine, ShardedEngine, fit_experts,
                               dec_poe, dec_gpoe, dec_bcm, dec_rbcm)
from ..core.training import train_dec_apx_gp
from ..data import random_inputs, gp_sample_field
from .frontdoor import FrontDoor
from .mesh import make_agent_mesh

_LEGACY = {"poe": dec_poe, "gpoe": dec_gpoe, "bcm": dec_bcm, "rbcm": dec_rbcm}


def build_fleet(key, M: int, per_agent: int, train_iters: int):
    """Synthetic fleet: sample a GP field, stripe-partition, (optionally)
    train hyperparameters with the paper's DEC-apx-GP."""
    lt_true = pack([1.2, 0.3], 1.3, 0.1)
    X = random_inputs(key, M * per_agent)
    _, y = gp_sample_field(jax.random.fold_in(key, 1), X, lt_true)
    Xp, yp = stripe_partition(X, y, M)
    lt = lt_true
    if train_iters:
        thetas, _ = train_dec_apx_gp(lt_true, Xp, yp, path_graph(M),
                                     iters=train_iters)
        lt = jnp.mean(thetas, axis=0)
    return lt, Xp, yp


def request_stream(key, n_requests: int, max_size: int):
    """Ragged prediction requests (what a front door actually receives)."""
    rng = np.random.default_rng(0)
    sizes = rng.integers(1, max_size + 1, size=n_requests)
    return [random_inputs(jax.random.fold_in(key, 100 + i), int(s))
            for i, s in enumerate(sizes)]


def micro_batches(requests, batch: int):
    """Concatenate ragged requests and cut into fixed-size micro-batches
    (tail zero-padded) so every engine call hits the same compiled program.
    Returns (batches (n, batch, D), total_queries, slices per request)."""
    sizes = [int(r.shape[0]) for r in requests]
    allq = jnp.concatenate(requests, axis=0)
    total = allq.shape[0]
    pad = (-total) % batch
    allq = jnp.pad(allq, ((0, pad), (0, 0)))
    offs = np.concatenate([[0], np.cumsum(sizes)])
    slices = [(int(a), int(b)) for a, b in zip(offs[:-1], offs[1:])]
    return allq.reshape(-1, batch, allq.shape[1]), total, slices


def serve_online(args, lt, Xp, yp, eng, batches, total):
    """Interleaved observe/predict loop: the live-fleet serving front door.

    Observation events ride the incremental O(W^2) rank-1 updates
    (core.online.observe_fleet, one jit program); prediction micro-batches
    ride the engine's per-method jit cache. `swap_experts` bridges the two
    WITHOUT recompiling — the factors are a traced argument of the
    compiled predict, so swapping state costs nothing but the dispatch.
    """
    M = Xp.shape[0]
    state = from_batch(lt, Xp, yp)
    eng.swap_experts(state.to_fitted())
    ingest = jax.jit(observe_fleet)
    stream_key = jax.random.PRNGKey(42)

    def fresh(k):
        xs = random_inputs(jax.random.fold_in(k, 0), M)
        ys = jax.random.normal(jax.random.fold_in(k, 1), (M,), xs.dtype)
        return xs, ys

    # warmup compiles the TWO programs the whole stream reuses
    xs, ys = fresh(stream_key)
    jax.block_until_ready(ingest(state, xs, ys).L)
    jax.block_until_ready(eng.predict(args.method, batches[0])[0])
    compiled = dict(eng._compiled)

    n_obs = 0
    t0 = time.time()
    means = []
    for i, b in enumerate(batches):
        for j in range(args.observe_every):
            stream_key = jax.random.fold_in(stream_key, i * 131 + j)
            xs, ys = fresh(stream_key)
            state = ingest(state, xs, ys)
            n_obs += M
        eng.swap_experts(state.to_fitted())
        m, v, _ = eng.predict(args.method, b)
        means.append(m)
    jax.block_until_ready(means[-1])
    dt = time.time() - t0
    assert all(eng._compiled[k] is compiled[k] for k in compiled), \
        "hot swap recompiled a prediction program"
    print(f"online {args.method}: served {total} queries + ingested "
          f"{n_obs} observations in {dt*1e3:.1f} ms "
          f"({total/dt:.0f} q/s, {n_obs/dt:.0f} obs/s, window={Xp.shape[1]}, "
          f"0 recompiles after warmup)")


def serve_async(args, predict, requests):
    """Serve the request stream through the FrontDoor collector thread.

    Requests are submitted as fast as clients produce them and resolved via
    futures; the collector cuts fixed-shape micro-batches by size or by the
    --max-wait-ms latency bound, so the engine's jit cache still sees one
    compiled program. Warmup happens on the first dispatched batch.
    """
    t0 = time.time()
    with FrontDoor(predict, args.batch,
                   max_wait_ms=args.max_wait_ms) as door:
        futures = [door.submit(r) for r in requests]
        answers = [f.result() for f in futures]
    dt = time.time() - t0
    st = door.stats
    assert all(a[0].shape[0] == r.shape[0]
               for a, r in zip(answers, requests))
    print(f"async {args.method}: {st.requests} requests / {st.queries} "
          f"queries in {dt*1e3:.1f} ms ({st.queries/dt:.0f} q/s end-to-end, "
          f"{st.batches} micro-batches of {args.batch}, "
          f"padding {100*st.padding_fraction:.1f}%, "
          f"engine busy {st.engine_seconds*1e3:.1f} ms)")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--agents", type=int, default=8)
    ap.add_argument("--per-agent", type=int, default=256,
                    help="Ni; factor caching pays off as Ni grows (O(Ni^3) "
                         "refactorization per request on the uncached path)")
    ap.add_argument("--method", default="rbcm",
                    choices=sorted(PredictionEngine.METHODS))
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--batch", type=int, default=256,
                    help="micro-batch size (fixed compiled shape)")
    ap.add_argument("--chunk", type=int, default=128,
                    help="engine query-tile size")
    ap.add_argument("--dac-iters", type=int, default=100)
    ap.add_argument("--train-iters", type=int, default=0,
                    help="DEC-apx-GP rounds (0 = use true hyperparameters)")
    ap.add_argument("--no-stream", action="store_true",
                    help="disable the streaming rbf_matvec mean path")
    ap.add_argument("--sharded", action="store_true",
                    help="shard the fleet over the agent axis of a local "
                         "device mesh (ShardedEngine; DAC-family methods)")
    ap.add_argument("--routed", action="store_true",
                    help="CBNN query routing on the sharded fleet: serve "
                         "each query from the shard holding its most-"
                         "correlated experts (nn_* methods; implies "
                         "--sharded)")
    ap.add_argument("--eta-nn", type=float, default=0.1,
                    help="CBNN participation threshold (paper eq. 39)")
    ap.add_argument("--async-door", action="store_true",
                    help="serve through the FrontDoor collector thread "
                         "(submit/Future API) instead of the synchronous "
                         "loop")
    ap.add_argument("--max-wait-ms", type=float, default=2.0,
                    help="async front door latency bound: max time a "
                         "request waits for its micro-batch to fill")
    ap.add_argument("--compare-uncached", action="store_true")
    ap.add_argument("--online", action="store_true",
                    help="interleave observe and predict streams: sliding-"
                         "window experts, incremental factor updates, "
                         "hot-swapped into the engine between micro-batches")
    ap.add_argument("--observe-every", type=int, default=4,
                    help="fleet-wide observations ingested between "
                         "prediction micro-batches (online mode)")
    args = ap.parse_args(argv)
    if args.online and "grbcm" in args.method:
        ap.error("--online maintains base experts only; grbcm variants "
                 "need separately refit augmented/communication experts")
    if args.routed:
        args.sharded = True
        if not args.method.startswith("nn_"):
            ap.error("--routed serves the CBNN nn_* methods")
    if args.sharded and args.method not in ShardedEngine.METHODS:
        ap.error(f"--sharded serves the DAC family {ShardedEngine.METHODS}; "
                 "NPAE-family methods stay on the replicated engine")

    M = args.agents
    key = jax.random.PRNGKey(0)
    lt, Xp, yp = build_fleet(key, M, args.per_agent, args.train_iters)
    A = path_graph(M)

    t0 = time.time()
    fitted = jax.jit(fit_experts)(lt, Xp, yp)
    fitted_aug = fitted_comm = None
    if "grbcm" in args.method:
        # grBCM aggregates AUGMENTED experts against the communication expert
        Xc, yc = communication_dataset(jax.random.fold_in(key, 2), Xp, yp)
        Xa, ya = augment(Xp, yp, Xc, yc)
        fitted_aug = jax.jit(fit_experts)(lt, Xa, ya)
        fitted_comm = jax.jit(fit_experts)(lt, Xc[None], yc[None])
    jax.block_until_ready(fitted.L)
    t_fit = time.time() - t0
    if args.sharded:
        mesh = make_agent_mesh(M)
        eng = ShardedEngine(fitted, mesh, chunk=args.chunk,
                            dac_iters=args.dac_iters, eta_nn=args.eta_nn,
                            fitted_aug=fitted_aug, fitted_comm=fitted_comm,
                            stream_mean=not args.no_stream)
        mode = (f"sharded over {eng.ndev} device(s)"
                + (", CBNN-routed" if args.routed else ""))
    else:
        eng = PredictionEngine(fitted, A, chunk=args.chunk,
                               dac_iters=args.dac_iters, eta_nn=args.eta_nn,
                               fitted_aug=fitted_aug,
                               fitted_comm=fitted_comm,
                               stream_mean=not args.no_stream)
        mode = "replicated"

    requests = request_stream(key, args.requests, args.batch)
    batches, total, slices = micro_batches(requests, args.batch)
    print(f"fleet: M={M} agents x Ni={args.per_agent} points ({mode}); "
          f"factors cached in {t_fit*1e3:.1f} ms")
    print(f"queue: {args.requests} requests, {total} queries "
          f"-> {batches.shape[0]} micro-batches of {args.batch}")

    if args.online:
        serve_online(args, lt, Xp, yp, eng, batches, total)
        return

    predict = (partial(eng.predict_routed, args.method) if args.routed
               else partial(eng.predict, args.method))
    if args.async_door:
        serve_async(args, predict, requests)
        return

    # warmup compiles the one program all micro-batches reuse
    jax.block_until_ready(predict(batches[0])[0])
    t0 = time.time()
    means = []
    for b in batches:
        m, v, _ = predict(b)
        means.append(m)
    jax.block_until_ready(means[-1])
    dt = time.time() - t0
    flat = jnp.concatenate(means)
    answers = [flat[a:b] for a, b in slices]       # de-batched per request
    print(f"{args.method}: served {total} queries in {dt*1e3:.1f} ms "
          f"({total/dt:.0f} q/s, {len(batches)/dt:.1f} batches/s, "
          f"stream_mean={not args.no_stream}); "
          f"last request -> {answers[-1].shape[0]} predictions")

    if args.compare_uncached and args.method in _LEGACY:
        legacy = _LEGACY[args.method]
        fn = jax.jit(lambda Xq: legacy(lt, Xp, yp, Xq, A,
                                       iters=args.dac_iters)[:2])
        jax.block_until_ready(fn(batches[0]))
        t0 = time.time()
        for b in batches:
            out = fn(b)
        jax.block_until_ready(out)
        dt_un = time.time() - t0
        print(f"uncached per-call path: {total/dt_un:.0f} q/s "
              f"-> engine speedup {dt_un/dt:.2f}x")


if __name__ == "__main__":
    main()
