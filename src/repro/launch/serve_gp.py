"""GP serving launcher — a thin CLI overlay on `repro.fleet.GPFleet`.

Flags fill in a `FleetConfig`; the facade owns the lifecycle (train ->
factor-cache -> engine -> serve). `--method` / `--trainer` choices and the
capability checks derive from the fleet registries, so the CLI cannot
drift from what the engines actually support — an invalid combination
(e.g. `--sharded` with an NPAE-family method) is rejected with the
registry's explanation instead of a shape crash.

  PYTHONPATH=src python -m repro.launch.serve_gp --agents 8 --per-agent 128 \
      --method rbcm --requests 64 --batch 256 --chunk 128

Serving modes (the engine layer each path uses is in parentheses):

  default         ragged requests are coalesced host-side, micro-batched to
                  a FIXED batch shape (one compiled program — zero
                  recompiles after warmup), pushed through
                  `GPFleet.predict` (PredictionEngine), and de-batched back
                  into per-request answers.
  --sharded       the fleet sharded over the agent axis of a local device
                  mesh (ShardedEngine): per-agent moments run shard-
                  locally, cross-agent sums ride the device-ring
                  collectives (paper eq. 35 on the ICI ring).
  --routed        CBNN query routing on the sharded fleet (nn_* methods,
                  paper §5.2 eq. 39): each query served by the shard
                  holding its most-correlated experts.
  --async-door    serve through `GPFleet.to_server` (the one-tenant
                  serving scheduler): requests are SUBMITTED as they
                  arrive and resolved through futures, slots cut by size
                  or the --max-wait-ms latency bound.
  --scheduler     the request-level `ServingScheduler`: continuous slot
                  batching with admission control, priorities and
                  deadlines (--deadline-ms / --deadline-policy /
                  --priority), and MULTIPLE resident fleets in one
                  process — each `--tenant NAME=SPEC` (SPEC a method name
                  for a synthetic fleet, or a GPFleet.save checkpoint
                  dir) serves from its own jit cache, round-robined.
                  `--loadgen RATE --duration S` drives it open-loop with
                  Poisson arrivals per tenant instead of a fixed request
                  list (admission switches to reject, so saturation shows
                  up as rejected counts, not a blocked generator).
  --online        the streaming front door: between prediction micro-
                  batches every agent ingests --observe-every fresh
                  observations through `GPFleet.observe` (incremental
                  O(W^2) rank-1 factor updates, zero-recompile hot swaps).

Fitted-fleet persistence:

  --save-fleet DIR        after fitting, `GPFleet.save` the factors +
                          config + consensus graph to DIR
  --from-checkpoint DIR   skip building/fitting entirely: `GPFleet.load`
                          DIR and serve it — a fresh process serves
                          bit-identical predictions without refitting

`--compare-uncached` also times the legacy per-call path (re-factorizing
every agent's kernel matrix per request — the registry's `legacy_call`
reference for the served method) on the same micro-batches and reports
the engine speedup.
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..core.gp import pack, stripe_partition
from ..core.prediction import PredictionEngine
from ..data import gp_sample_field, random_inputs
from ..fleet import (FleetConfig, GPFleet, get_method, method_names,
                     trainer_names, validate_config)
from ..obs import prometheus_text, start_metrics_server

# centralized references (engine-only, not fleet methods) stay servable on
# the replicated path; everything else comes from the registry
_CEN_METHODS = tuple(m for m in PredictionEngine.METHODS
                     if m.startswith("cen_"))
_TRUE_THETA = ([1.2, 0.3], 1.3, 0.1)


def build_data(key, M: int, per_agent: int):
    """Synthetic fleet data: sample a GP field, stripe-partition."""
    lt_true = pack(*_TRUE_THETA)
    X = random_inputs(key, M * per_agent)
    _, y = gp_sample_field(jax.random.fold_in(key, 1), X, lt_true)
    return stripe_partition(X, y, M)


def request_stream(key, n_requests: int, max_size: int):
    """Ragged prediction requests (what a front door actually receives)."""
    rng = np.random.default_rng(0)
    sizes = rng.integers(1, max_size + 1, size=n_requests)
    return [random_inputs(jax.random.fold_in(key, 100 + i), int(s))
            for i, s in enumerate(sizes)]


def micro_batches(requests, batch: int):
    """Concatenate ragged requests and cut into fixed-size micro-batches
    (tail zero-padded) so every engine call hits the same compiled program.
    Returns (batches (n, batch, D), total_queries, slices per request)."""
    sizes = [int(r.shape[0]) for r in requests]
    allq = jnp.concatenate(requests, axis=0)
    total = allq.shape[0]
    pad = (-total) % batch
    allq = jnp.pad(allq, ((0, pad), (0, 0)))
    offs = np.concatenate([[0], np.cumsum(sizes)])
    slices = [(int(a), int(b)) for a, b in zip(offs[:-1], offs[1:])]
    return allq.reshape(-1, batch, allq.shape[1]), total, slices


def serve_online(args, fleet: GPFleet, method, batches, total):
    """Interleaved observe/predict loop: the live-fleet serving front door.

    Observation events ride `GPFleet.observe` (incremental O(W^2) rank-1
    updates, one jit program); prediction micro-batches ride the engine's
    per-method jit cache. The factor hot-swap between the two costs nothing
    but the dispatch — compiled programs are reused across swaps (asserted
    at exit)."""
    M = fleet.num_agents
    stream_key = jax.random.PRNGKey(42)

    def fresh(k):
        xs = random_inputs(jax.random.fold_in(k, 0), M)
        ys = jax.random.normal(jax.random.fold_in(k, 1), (M,), xs.dtype)
        return xs, ys

    # warmup compiles the TWO programs the whole stream reuses; the ingest
    # warmup is rolled back so serving starts from the fitted/restored
    # windows exactly (the compiled program stays cached on the fleet)
    xs, ys = fresh(stream_key)
    state0, fitted0 = fleet._online_state, fleet.fitted
    fleet.observe(xs, ys)
    fleet._online_state, fleet.fitted = state0, fitted0
    jax.block_until_ready(fleet.predict(batches[0], method=method)[0])
    compiled = dict(fleet.engine._compiled)

    n_obs = 0
    t0 = time.perf_counter()
    means = []
    for i, b in enumerate(batches):
        for j in range(args.observe_every):
            stream_key = jax.random.fold_in(stream_key, i * 131 + j)
            fleet.observe(*fresh(stream_key))
            n_obs += M
        m, v, _ = fleet.predict(b, method=method)
        means.append(m)
    jax.block_until_ready(means[-1])
    dt = time.perf_counter() - t0
    assert all(fleet.engine._compiled[k] is compiled[k] for k in compiled), \
        "hot swap recompiled a prediction program"
    W = fleet.fitted.Xp.shape[1]
    print(f"online {method}: served {total} queries + ingested "
          f"{n_obs} observations in {dt*1e3:.1f} ms "
          f"({total/dt:.0f} q/s, {n_obs/dt:.0f} obs/s, window={W}, "
          f"0 recompiles after warmup)")


def serve_async(args, fleet: GPFleet, method, requests):
    """Serve the request stream through `GPFleet.to_server` (the FrontDoor
    collector thread): submitted as fast as clients produce them, resolved
    via futures, micro-batches cut by size or the --max-wait-ms bound."""
    t0 = time.perf_counter()
    with fleet.to_server(args.batch, max_wait_ms=args.max_wait_ms,
                         method=method) as door:
        futures = [door.submit(r) for r in requests]
        answers = [f.result() for f in futures]
    dt = time.perf_counter() - t0
    st = door.stats
    assert all(a[0].shape[0] == r.shape[0]
               for a, r in zip(answers, requests))
    print(f"async {method}: {st.requests} requests / {st.queries} "
          f"queries in {dt*1e3:.1f} ms ({st.queries/dt:.0f} q/s end-to-end, "
          f"{st.batches} micro-batches of {args.batch}, "
          f"padding {100*st.padding_fraction:.1f}%, "
          f"engine busy {st.engine_seconds*1e3:.1f} ms)")


def _tenant_fleet(args, key, spec: str, ap):
    """--tenant SPEC -> (fleet, served method). SPEC is a GPFleet.save
    checkpoint dir (served with its saved config) or a method name (a
    synthetic fleet built from the launcher flags)."""
    if os.path.isdir(spec):
        fleet = GPFleet.load(spec)
        return fleet, fleet.config.method
    method = spec
    cfg_method = method[4:] if method.startswith("cen_") else method
    if cfg_method not in method_names():
        ap.error(f"--tenant spec {spec!r} is neither a checkpoint dir nor "
                 f"a registered method ({sorted(method_names())})")
    try:
        cfg = FleetConfig(num_agents=args.agents, method=cfg_method,
                          chunk=args.chunk, dac_iters=args.dac_iters,
                          eta_nn=args.eta_nn, stream_mean=not args.no_stream,
                          sparse_m=args.sparse_m,
                          inducing_init=args.inducing_init)
        validate_config(cfg)
    except (ValueError, KeyError) as e:
        ap.error(str(e))
    Xp, yp = build_data(key, args.agents, args.per_agent)
    fleet = GPFleet(cfg).fit(Xp, yp, key=jax.random.fold_in(key, 2),
                             log_theta0=pack(*_TRUE_THETA), train=False)
    return fleet, method


def build_fault_plan(args, ap):
    """--fault-* flags -> a seeded `repro.chaos.FaultPlan` (None when no
    fault flag is set). Dropout specs are AGENT:AT[:UNTIL] in consensus
    rounds (AT=0 models an agent dead before the prediction starts)."""
    from ..chaos import Dropout, FaultPlan
    dropouts = []
    for spec in args.fault_dropout or ():
        parts = spec.split(":")
        if not 1 <= len(parts) <= 3:
            ap.error(f"--fault-dropout wants AGENT[:AT[:UNTIL]], "
                     f"got {spec!r}")
        try:
            dropouts.append(Dropout(
                int(parts[0]),
                at=int(parts[1]) if len(parts) > 1 else 0,
                until=int(parts[2]) if len(parts) > 2 else None))
        except ValueError:
            ap.error(f"--fault-dropout fields must be integers, "
                     f"got {spec!r}")
    try:
        plan = FaultPlan(seed=args.fault_seed,
                         dropouts=tuple(dropouts),
                         edge_loss=args.fault_edge_loss,
                         nan_agents=tuple(args.fault_nan_agent or ()),
                         straggle_every=args.fault_straggle_every,
                         straggle_ms=args.fault_straggle_ms,
                         fail_every=args.fault_fail_every)
    except ValueError as e:
        ap.error(str(e))
    return None if plan.empty else plan


def serve_scheduler(args, fleet: GPFleet, method, key, ap):
    """Serve through the request-level `ServingScheduler`: every --tenant
    is a resident fleet with its own compiled programs, interleaved
    round-robin in ONE process; per-tenant p50/p99 and the zero-recompile
    check are reported at exit.

    With --fault-* flags the whole run goes through a seeded
    `repro.chaos.FaultPlan`: consensus faults serve degraded (flagged)
    predictions, serving faults (stragglers / injected failures) exercise
    the scheduler's retry, isolation, and watchdog paths. The exit
    contract under chaos is: every Future resolves (zero hung), failures
    are TYPED, and serving still adds zero traces."""
    from concurrent.futures import TimeoutError as FutureTimeout
    from .scheduler import (DeadlineExceeded, SchedulerSaturated,
                            SchedulerStalled, ServingScheduler)
    plan = build_fault_plan(args, ap)
    if args.tenant:
        tenants: dict = {}
        for item in args.tenant:
            if "=" not in item:
                ap.error(f"--tenant wants NAME=SPEC, got {item!r}")
            name, spec = item.split("=", 1)
            if name in tenants:
                ap.error(f"duplicate tenant name {name!r}")
            tenants[name] = _tenant_fleet(
                args, jax.random.fold_in(key, 7 + len(tenants)), spec, ap)
    else:
        tenants = {"default": (fleet, method)}

    sched = ServingScheduler(max_wait_ms=args.max_wait_ms,
                             span_log=args.trace_log,
                             stall_timeout_ms=args.stall_timeout_ms)
    admission = "reject" if args.loadgen else "block"
    for name, (fl, m) in tenants.items():
        sched.add_fleet(name, fl, method=m, max_slot=args.batch,
                        admission=admission,
                        deadline_policy=args.deadline_policy,
                        fault_plan=plan)
    # registration warmed every slot; serving must add zero traces
    misses0 = {n: fl.jit_cache_misses for n, (fl, _) in tenants.items()}

    rng = np.random.default_rng(0)
    names = list(tenants)
    futs = []
    rejected = 0
    t0 = time.perf_counter()
    if args.loadgen:
        # open-loop Poisson arrivals at --loadgen req/s PER TENANT for
        # --duration seconds: submits happen on schedule regardless of
        # completions, so overload appears as rejections + p99 growth
        events = []
        for name in names:
            t = rng.exponential(1.0 / args.loadgen)
            while t < args.duration:
                events.append((t, name))
                t += rng.exponential(1.0 / args.loadgen)
        events.sort()
        for i, (at, name) in enumerate(events):
            lag = at - (time.perf_counter() - t0)
            if lag > 0:
                time.sleep(lag)
            n = int(rng.integers(1, max(2, args.batch // 2) + 1))
            Xq = random_inputs(jax.random.fold_in(key, 500 + i), n)
            try:
                futs.append(sched.add_request(
                    Xq, tenant=name, priority=args.priority,
                    deadline_ms=args.deadline_ms))
            except SchedulerSaturated:
                rejected += 1
            except SchedulerStalled:
                rejected += 1      # tenant quarantined by the watchdog
    else:
        for i in range(args.requests):
            name = names[i % len(names)]
            n = int(rng.integers(1, args.batch + 1))
            Xq = random_inputs(jax.random.fold_in(key, 500 + i), n)
            futs.append(sched.add_request(Xq, tenant=name,
                                          priority=args.priority,
                                          deadline_ms=args.deadline_ms))
    served = dropped = failed = hung = 0
    for f in futs:
        try:
            f.result(timeout=600)
            served += 1
        except DeadlineExceeded:
            dropped += 1
        except FutureTimeout:
            hung += 1              # a Future that never resolved: the bug
        except Exception:
            failed += 1            # typed failure (injected/stalled/chaos)
    sched.close()
    dt = time.perf_counter() - t0
    drive = (f"open-loop Poisson {args.loadgen:.0f} req/s/tenant x "
             f"{args.duration:.1f} s" if args.loadgen
             else f"{args.requests} requests")
    print(f"scheduler: {len(tenants)} tenant(s), {drive} -> "
          f"{served} served / {dropped} past-deadline / {rejected} rejected "
          f"/ {failed} failed / {hung} hung in {dt*1e3:.1f} ms")
    assert hung == 0, f"{hung} futures never resolved"
    if plan is not None:
        print(f"fault plan: {plan}")
    for name, (fl, m) in tenants.items():
        st = sched.tenant_stats[name]
        p50, p99 = st.latency_ms(50, 99)
        recompiles = fl.jit_cache_misses - misses0[name]
        print(f"  {name} ({m}): {st.requests} req / {st.queries} q in "
              f"{st.batches} slots, padding {100*st.padding_fraction:.1f}%, "
              f"p50 {p50:.2f} ms, p99 {p99:.2f} ms, dropped {st.dropped}, "
              f"lapsed {st.lapsed}, rejected {st.rejected}, "
              f"retried {st.retried}, isolated {st.isolated}, "
              f"stalled {st.stalled}, "
              f"engine busy {st.engine_seconds*1e3:.1f} ms, "
              f"{recompiles} recompiles after warmup")
    bad = [n for n, (fl, _) in tenants.items()
           if fl.jit_cache_misses != misses0[n]]
    assert not bad, f"serving recompiled for tenants {bad}"
    if args.trace_log:
        print(f"request trace (JSONL spans) -> {args.trace_log}")


def compare_uncached(args, fleet: GPFleet, method, batches, total, dt):
    """Time the legacy per-call path (registry `legacy_call`: refactorizes
    every agent's kernel per request) on the same micro-batches."""
    spec = get_method(method)
    cfg = fleet.config
    lt, f = fleet.log_theta, fleet.fitted
    if not hasattr(f, "yp"):
        # sparse experts keep Titsias factors, not the raw (Xp, yp) the
        # per-call reference signature wants
        print(f"--compare-uncached: skipped for {method} (sparse experts "
              f"do not carry the raw per-agent datasets)")
        return
    Xc = yc = Xa = ya = None
    if fleet._comm_data is not None:
        Xc, yc, Xa, ya = fleet._comm_data
    elif spec.needs_augmented_data:
        # checkpoints persist the fitted experts, not the raw communication
        # datasets the per-call reference signature wants
        print(f"--compare-uncached: skipped for {method} (the legacy "
              f"per-call path needs the raw communication datasets, which "
              f"a loaded checkpoint does not carry)")
        return
    fn = jax.jit(lambda Xq: spec.legacy_call(cfg, lt, f.Xp, f.yp, Xq,
                                             fleet.A, Xc, yc, Xa, ya)[:2])
    jax.block_until_ready(fn(batches[0]))
    t0 = time.perf_counter()
    for b in batches:
        out = fn(b)
    jax.block_until_ready(out)
    dt_un = time.perf_counter() - t0
    print(f"uncached per-call path: {total/dt_un:.0f} q/s "
          f"-> engine speedup {dt_un/dt:.2f}x")


def build_config(args, ap) -> FleetConfig:
    """CLI flags -> FleetConfig (the validated, serializable description
    the facade consumes). Invalid combos fail here with registry errors."""
    method = args.method if args.method is not None else "rbcm"
    if method.startswith("cen_"):
        # centralized references ride the replicated engine; the config
        # keeps the DECENTRALIZED counterpart so validation/persistence
        # stay uniform AND the facade builds the same experts (cen_grbcm
        # needs the augmented/communication experts like grbcm does)
        base = method[4:]
        method = base if base in method_names() else "rbcm"
    try:
        cfg = FleetConfig(
            num_agents=args.agents,
            trainer=args.trainer,
            admm_iters=args.train_iters or FleetConfig.admm_iters,
            fact_steps=args.train_iters or FleetConfig.fact_steps,
            method=method,
            chunk=args.chunk,
            dac_iters=args.dac_iters,
            eta_nn=args.eta_nn,
            stream_mean=not args.no_stream,
            sharded=args.sharded,
            routed=args.routed,
            online=args.online,
            sparse_m=args.sparse_m,
            inducing_init=args.inducing_init,
        )
        validate_config(cfg)
        return cfg
    except (ValueError, KeyError) as e:
        ap.error(str(e))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--agents", type=int, default=8)
    ap.add_argument("--per-agent", type=int, default=256,
                    help="Ni; factor caching pays off as Ni grows (O(Ni^3) "
                         "refactorization per request on the uncached path)")
    ap.add_argument("--method", default=None,
                    type=lambda s: s if s.startswith("cen_")
                    else s.replace("-", "_"),
                    choices=sorted(method_names()) + sorted(_CEN_METHODS),
                    help="prediction method (fleet registry name, hyphens "
                         "accepted: npae-sparse == npae_sparse; default "
                         "rbcm, or the saved config with --from-checkpoint)")
    ap.add_argument("--trainer", default="dec-apx",
                    choices=sorted(trainer_names()),
                    help="training loop (fleet registry name)")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--batch", type=int, default=256,
                    help="micro-batch size (fixed compiled shape)")
    ap.add_argument("--chunk", type=int, default=128,
                    help="engine query-tile size")
    ap.add_argument("--dac-iters", type=int, default=100)
    ap.add_argument("--train-iters", type=int, default=0,
                    help="training rounds (0 = use true hyperparameters)")
    ap.add_argument("--no-stream", action="store_true",
                    help="disable the streaming rbf_matvec mean path")
    ap.add_argument("--sharded", action="store_true",
                    help="shard the fleet over the agent axis of a local "
                         "device mesh (ShardedEngine; DAC-family methods)")
    ap.add_argument("--routed", action="store_true",
                    help="CBNN query routing on the sharded fleet (nn_* "
                         "methods; implies --sharded)")
    ap.add_argument("--eta-nn", type=float, default=0.1,
                    help="CBNN participation threshold (paper eq. 39)")
    ap.add_argument("--sparse-m", type=int, default=None, metavar="M",
                    help="per-agent inducing count: fit/serve sparse "
                         "pseudo-representation experts (core.sparse) "
                         "instead of the dense O(Ni^2) factors; required "
                         "by the sparse trainers and method npae-sparse")
    ap.add_argument("--inducing-init", default="stride",
                    choices=("stride", "random"),
                    help="inducing-point initialization for --sparse-m "
                         "fleets (docs/sparse_experts.md)")
    ap.add_argument("--async-door", action="store_true",
                    help="serve through the FrontDoor collector thread "
                         "(submit/Future API) instead of the synchronous "
                         "loop")
    ap.add_argument("--max-wait-ms", type=float, default=2.0,
                    help="async front door latency bound: max time a "
                         "request waits for its micro-batch to fill")
    ap.add_argument("--scheduler", action="store_true",
                    help="serve through the request-level ServingScheduler "
                         "(continuous slot batching, multi-tenant)")
    ap.add_argument("--tenant", action="append", metavar="NAME=SPEC",
                    help="register a resident fleet on the scheduler "
                         "(repeatable). SPEC: a method name (synthetic "
                         "fleet from the launcher flags) or a "
                         "GPFleet.save checkpoint dir; without --tenant "
                         "the launcher fleet serves as tenant 'default'")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request deadline; expiry follows "
                         "--deadline-policy")
    ap.add_argument("--deadline-policy", choices=("drop", "deprioritize"),
                    default="drop",
                    help="past-deadline work is dropped (Future raises "
                         "DeadlineExceeded) or served only when no "
                         "in-deadline work is pending")
    ap.add_argument("--priority", type=int, default=0,
                    help="request priority (higher packs first)")
    ap.add_argument("--loadgen", type=float, default=None, metavar="RATE",
                    help="scheduler mode: open-loop Poisson load at RATE "
                         "req/s per tenant instead of a fixed request list")
    ap.add_argument("--duration", type=float, default=2.0,
                    help="loadgen run length in seconds")
    ap.add_argument("--metrics-port", type=int, default=None, metavar="PORT",
                    help="serve GET /metrics (Prometheus text) and /statusz "
                         "(registry snapshot JSON) on PORT for the run "
                         "(0 = ephemeral port, printed at startup)")
    ap.add_argument("--metrics-dump", default=None, metavar="PATH",
                    help="at exit, write the Prometheus text dump of the "
                         "metrics registry to PATH")
    ap.add_argument("--trace-log", default=None, metavar="PATH",
                    help="scheduler mode: append one JSONL span event per "
                         "request (per-stage timings) to PATH")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="chaos: seed for the replayable FaultPlan RNG "
                         "(edge loss draws)")
    ap.add_argument("--fault-dropout", action="append", default=None,
                    metavar="AGENT[:AT[:UNTIL]]",
                    help="chaos: drop AGENT at consensus round AT "
                         "(default 0), rejoining at UNTIL (default: never); "
                         "repeatable")
    ap.add_argument("--fault-edge-loss", type=float, default=0.0,
                    help="chaos: per-round probability each live edge "
                         "silently drops its message")
    ap.add_argument("--fault-nan-agent", action="append", type=int,
                    default=None, metavar="AGENT",
                    help="chaos: AGENT emits NaN payloads (scrubbed by the "
                         "degraded engine); repeatable")
    ap.add_argument("--fault-straggle-every", type=int, default=0,
                    metavar="N",
                    help="chaos: every Nth scheduler dispatch sleeps "
                         "--fault-straggle-ms before the engine call")
    ap.add_argument("--fault-straggle-ms", type=float, default=0.0)
    ap.add_argument("--fault-fail-every", type=int, default=0, metavar="N",
                    help="chaos: every Nth scheduler dispatch raises "
                         "FaultInjected (exercises retry/isolation)")
    ap.add_argument("--stall-timeout-ms", type=float, default=None,
                    help="scheduler watchdog: fail in-flight futures of a "
                         "dispatch stalled longer than this with "
                         "SchedulerStalled and quarantine the tenant")
    ap.add_argument("--compare-uncached", action="store_true")
    ap.add_argument("--online", action="store_true",
                    help="interleave observe and predict streams (sliding-"
                         "window experts, incremental factor updates, "
                         "hot-swapped between micro-batches)")
    ap.add_argument("--observe-every", type=int, default=4,
                    help="fleet-wide observations ingested between "
                         "prediction micro-batches (online mode)")
    ap.add_argument("--save-fleet", default=None, metavar="DIR",
                    help="after fitting, persist the fleet (factors + "
                         "config + graph) with GPFleet.save")
    ap.add_argument("--from-checkpoint", default=None, metavar="DIR",
                    help="GPFleet.load a saved fleet and serve it without "
                         "refitting (build/train flags are ignored; "
                         "--sharded/--routed deployment overrides are "
                         "honored)")
    args = ap.parse_args(argv)
    if args.routed:
        args.sharded = True
    if (args.tenant or args.loadgen) and not args.scheduler:
        ap.error("--tenant/--loadgen belong to scheduler serving; add "
                 "--scheduler")
    if args.trace_log and not args.scheduler:
        ap.error("--trace-log belongs to scheduler serving; add --scheduler")
    chaos_flags = (args.fault_dropout or args.fault_nan_agent
                   or args.fault_edge_loss or args.fault_straggle_every
                   or args.fault_fail_every
                   or args.stall_timeout_ms is not None)
    if chaos_flags and not args.scheduler:
        ap.error("--fault-*/--stall-timeout-ms belong to scheduler "
                 "serving; add --scheduler")

    server = None
    if args.metrics_port is not None:
        server = start_metrics_server(args.metrics_port)
        print(f"metrics: http://127.0.0.1:{server.port}/metrics "
              f"(+ /statusz)")
    try:
        _serve(args, ap)
    finally:
        if args.metrics_dump:
            with open(args.metrics_dump, "w") as fh:
                fh.write(prometheus_text())
            print(f"metrics dump (Prometheus text) -> {args.metrics_dump}")
        if server is not None:
            server.stop()


def _serve(args, ap):
    """Dispatch to the serving mode the flags selected (factored out of
    `main` so the metrics endpoint/dump wrap every mode uniformly)."""
    key = jax.random.PRNGKey(0)

    # multi-tenant scheduler serving builds its own fleets per --tenant
    # spec; the single-fleet build below would be dead work
    if args.scheduler and args.tenant:
        serve_scheduler(args, None, None, key, ap)
        return

    t0 = time.perf_counter()
    if args.from_checkpoint:
        fleet = GPFleet.load(args.from_checkpoint)
        method = args.method or fleet.config.method
        if args.online and not fleet.config.online:
            ap.error("--online: this checkpoint was not saved from an "
                     "online fleet (no window state to resume); refit "
                     "with --online --save-fleet")
        # a --method override is FOLDED INTO the config before any
        # deployment override, so shard()/routing validate against the
        # method actually being served — same registry validation as the
        # build path, clear errors, never a traceback mid-serving
        if method.startswith("cen_"):
            if fleet.config.sharded or args.sharded or args.routed:
                ap.error("centralized cen_* references serve on the "
                         "replicated engine only")
        else:
            try:
                fleet.config = fleet.config.replace(method=method)
                validate_config(fleet.config)
            except ValueError as e:
                ap.error(str(e))
        if args.sharded or args.routed:
            # deployment overrides are honored, not silently dropped
            try:
                fleet.shard(routed=args.routed or None)
            except ValueError as e:
                ap.error(str(e))
        if "grbcm" in method and fleet.fitted_aug is None:
            ap.error(f"checkpoint carries no augmented/communication "
                     f"experts for {method}; save the fleet with a "
                     f"grbcm method configured")
        if args.save_fleet:
            print(f"fleet re-saved -> {fleet.save(args.save_fleet)}")
        M = fleet.num_agents
        per_agent = fleet.fitted.Xp.shape[1]
        built = f"loaded from {args.from_checkpoint} (no refit)"
    else:
        cfg = build_config(args, ap)
        method = args.method or cfg.method
        if method.startswith("cen_") and (args.sharded or args.online):
            ap.error("centralized cen_* references serve on the "
                     "replicated engine only")
        M, per_agent = args.agents, args.per_agent
        Xp, yp = build_data(key, M, per_agent)
        fleet = GPFleet(cfg)
        # the synthetic-fleet launcher always starts from the TRUE
        # (data-generating) theta: --train-iters 0 serves it directly,
        # --train-iters N runs ADMM initialized there (the pre-facade
        # build_fleet behavior, kept so benchmark numbers are comparable)
        fleet.fit(Xp, yp, key=jax.random.fold_in(key, 2),
                  log_theta0=pack(*_TRUE_THETA),
                  train=bool(args.train_iters))
        built = f"fitted in {(time.perf_counter()-t0)*1e3:.1f} ms"
        if args.save_fleet:
            path = fleet.save(args.save_fleet)
            print(f"fleet saved -> {path}")
    if fleet.config.sharded:
        mode = (f"sharded over {fleet.engine.ndev} device(s)"
                + (", CBNN-routed" if fleet.config.routed else ""))
    else:
        mode = "replicated"

    print(f"fleet: M={M} agents x Ni={per_agent} points ({mode}); {built}")
    if args.scheduler:
        serve_scheduler(args, fleet, method, key, ap)
        return

    requests = request_stream(key, args.requests, args.batch)
    batches, total, slices = micro_batches(requests, args.batch)
    print(f"queue: {args.requests} requests, {total} queries "
          f"-> {batches.shape[0]} micro-batches of {args.batch}")

    # mode follows the CLI flag: an online checkpoint loaded WITHOUT
    # --online batch-serves its restored factors untouched (the streaming
    # loop ingests synthetic observations, mutating the windows)
    if args.online:
        serve_online(args, fleet, method, batches, total)
        return

    if args.async_door:
        serve_async(args, fleet, method, requests)
        return

    # warmup compiles the one program all micro-batches reuse
    jax.block_until_ready(fleet.predict(batches[0], method=method)[0])
    t0 = time.perf_counter()
    means = []
    for b in batches:
        m, v, _ = fleet.predict(b, method=method)
        means.append(m)
    jax.block_until_ready(means[-1])
    dt = time.perf_counter() - t0
    flat = jnp.concatenate(means)
    answers = [flat[a:b] for a, b in slices]       # de-batched per request
    print(f"{method}: served {total} queries in {dt*1e3:.1f} ms "
          f"({total/dt:.0f} q/s, {len(batches)/dt:.1f} batches/s, "
          f"stream_mean={fleet.config.stream_mean}); "
          f"last request -> {answers[-1].shape[0]} predictions")

    if args.compare_uncached and not method.startswith("cen_"):
        compare_uncached(args, fleet, method, batches, total, dt)


if __name__ == "__main__":
    main()
