"""The latent ground-truth field a mission traverses.

One seeded random-Fourier-feature draw from the GP prior, kept as a
CONTINUOUS function instead of a gridded sample: the driver evaluates the
same draw at trajectory positions (observations), at held-out eval points
(the accuracy-over-time curves compare predictions against the noiseless
latent f), and at any replayed position bit-identically. Same RFF
construction as `repro.data.synthetic.gp_sample_field`'s large-N branch —
for the SE kernel the spectral density is Gaussian with std sqrt(2)/l per
dimension — but with the weights held so f can be re-evaluated anywhere.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.gp.kernel import pack, unpack


class LatentField:
    """f ~ GP(0, k_SE(theta)) via F random Fourier features; `observe`
    adds the field's N(0, sigma_eps^2) sensor noise."""

    def __init__(self, key, log_theta, features: int = 256, dtype=None):
        if dtype is None:   # widest available float (x64 when enabled)
            dtype = jnp.zeros(0).dtype if not jax.config.jax_enable_x64 \
                else jnp.float64
        log_theta = jnp.asarray(log_theta, dtype)
        ls, sigma_f, sigma_eps = unpack(log_theta)
        D = ls.shape[0]
        kw, kb, kf = jax.random.split(key, 3)
        self.log_theta = log_theta
        self.sigma_f = sigma_f
        self.sigma_eps = sigma_eps
        self.W = jax.random.normal(kw, (features, D), dtype) \
            * (jnp.sqrt(2.0) / ls)[None, :]
        self.b = jax.random.uniform(kb, (features,), dtype, 0.0,
                                    2.0 * jnp.pi)
        self.w = jax.random.normal(kf, (features,), dtype)

    def f(self, X) -> jax.Array:
        """Noiseless latent field at X (n, D) -> (n,)."""
        X = jnp.asarray(X, self.W.dtype)
        F = self.W.shape[0]
        phi = jnp.sqrt(2.0 / F) * jnp.cos(X @ self.W.T + self.b[None, :])
        return self.sigma_f * (phi @ self.w)

    def observe(self, key, X) -> jax.Array:
        """Noisy sensor reading y = f(X) + N(0, sigma_eps^2)."""
        fx = self.f(X)
        return fx + self.sigma_eps * jax.random.normal(key, fx.shape,
                                                       fx.dtype)


def make_field(cfg) -> LatentField:
    """The scenario's field: one draw, derived from cfg.seed alone."""
    lt = pack(list(cfg.field_theta[:-2]), cfg.field_theta[-2],
              cfg.field_theta[-1])
    return LatentField(jax.random.fold_in(jax.random.PRNGKey(cfg.seed), 0),
                       lt, features=cfg.field_features)
