"""The closed-loop mission driver: one deterministic, replayable run.

`run_scenario(cfg)` executes the full multi-robot story against a seeded
latent field (field.py) along seeded trajectories (trajectories.py):

  per fleet step t:
    1. membership chaos — the fault plan's dropout windows, reinterpreted
       at fleet-step granularity (`membership_events`), feed
       `GPFleet.leave` / `GPFleet.join` (rejoiners backfill their window
       from the path stretch they sensed while out of contact);
    2. observe — every live agent streams its position's sensor reading
       into its sliding window (O(W^2) rank-1 factor update + engine
       hot-swap, zero recompiles);
    3. drift-retrain — every `drift_every` steps the fleet re-runs the
       configured decentralized ADMM trainer on the live windows
       (`GPFleet.drift`: factor-preserving theta hot-swap, serving never
       retraces);
    4. serve — `queries_per_step` ragged requests enter the continuous-
       batching scheduler front door; the driver pumps `step(force=True)`
       synchronously, so dispatch order (and with it the whole serving-
       fault injection sequence) is deterministic. The scheduler path
       carries the scenario's serving plan: degraded consensus
       (edge loss / NaN payloads), stragglers, injected failures.
    5. measure — RMSE / NLL of clean predictions against the NOISELESS
       latent field on a fixed held-out eval set, fleet size, and the
       degraded fraction of dispatched batches.

The driver is single-threaded by construction (`autostart=False`
scheduler, no watchdog): every numeric the mission produces — curves,
membership timeline, drift NLLs — is a pure function of the config, and
`ScenarioResult.replay_digest()` fingerprints exactly that deterministic
subset (wall-clock serving metrics like latency quantiles and deadline
drops are reported but excluded). tests/test_scenario.py replays configs
and compares digests bit for bit.
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ..chaos import membership_events
from ..fleet import GPFleet
from ..launch.scheduler import DeadlineExceeded, ServingScheduler
from .config import ScenarioConfig
from .field import make_field
from .trajectories import agent_paths

__all__ = ["ScenarioResult", "run_scenario", "validate_bench"]


@dataclass
class ScenarioResult:
    """One mission's outcome: accuracy-over-time curves, the chaos /
    membership timeline, serving statistics, and end-state invariants."""
    config: dict
    curves: dict           # step / rmse / nll / alive / degraded_fraction
    drift_steps: list      # fleet steps where ADMM drift-retrain ran
    drift_nll: list        # eval NLL right after each drift epoch
    membership: list       # (step, "leave" | "rejoin", original agent id)
    recompile_steps: list  # steps where the engine traced new programs
    serving: dict          # submitted/completed/dropped/failed/p50/p99 ...
    hung_futures: int      # futures still unresolved after close(drain)
    jit_cache_misses: int  # engine trace count at mission end
    health: dict           # GPFleet.health() at mission end

    def replay_digest(self) -> str:
        """SHA-256 over the DETERMINISTIC mission outputs (accuracy
        curves bit-for-bit via float hex, fleet-size curve, membership
        timeline, drift epochs). Wall-clock serving metrics (latencies,
        deadline drops) are excluded: they measure the machine, not the
        mission."""
        payload = {
            "step": [int(v) for v in self.curves["step"]],
            "rmse": [float(v).hex() for v in self.curves["rmse"]],
            "nll": [float(v).hex() for v in self.curves["nll"]],
            "alive": [int(v) for v in self.curves["alive"]],
            "drift_steps": [int(v) for v in self.drift_steps],
            "drift_nll": [float(v).hex() for v in self.drift_nll],
            "membership": [[int(s), k, int(a)]
                           for s, k, a in self.membership],
        }
        return hashlib.sha256(
            json.dumps(payload, sort_keys=True).encode()).hexdigest()

    def to_bench(self) -> dict:
        """The BENCH_scenario.json "scenario" section (validate_bench
        checks this shape)."""
        return {
            "config": self.config,
            "curves": self.curves,
            "drift": {"step": list(self.drift_steps),
                      "nll": list(self.drift_nll)},
            "serving": dict(self.serving),
            "invariants": {
                "hung_futures": int(self.hung_futures),
                "recompile_steps": list(self.recompile_steps),
                "membership": [list(m) for m in self.membership],
                "jit_cache_misses": int(self.jit_cache_misses),
                "graph_connected": bool(self.health["graph_connected"]),
                "final_agents": int(self.health["num_agents"]),
                "replay_digest": self.replay_digest(),
            },
        }


_CURVE_KEYS = ("step", "rmse", "nll", "alive", "degraded_fraction")
_SERVING_KEYS = ("submitted", "completed", "dropped", "failed", "retried",
                 "p50_ms", "p99_ms")
_INVARIANT_KEYS = ("hung_futures", "recompile_steps", "membership",
                   "jit_cache_misses", "graph_connected", "final_agents",
                   "replay_digest")


def validate_bench(doc: dict) -> None:
    """Schema check for a BENCH_scenario.json document (the CI smoke and
    the test pack both call this). Raises ValueError with the first
    problem found; returns None when the document is well-formed."""
    if "scenario" not in doc:
        raise ValueError("missing top-level 'scenario' section")
    sc = doc["scenario"]
    for k in ("config", "curves", "drift", "serving", "invariants"):
        if k not in sc:
            raise ValueError(f"scenario section missing {k!r}")
    ScenarioConfig.from_dict(sc["config"])   # config must round-trip
    curves = sc["curves"]
    lengths = set()
    for k in _CURVE_KEYS:
        if k not in curves or not isinstance(curves[k], list):
            raise ValueError(f"curves missing list {k!r}")
        lengths.add(len(curves[k]))
    if lengths == {0} or len(lengths) != 1:
        raise ValueError(f"curve lists must share one non-zero length, "
                         f"got lengths {sorted(lengths)}")
    drift = sc["drift"]
    if set(drift) != {"step", "nll"} or len(drift["step"]) != \
            len(drift["nll"]):
        raise ValueError("drift section needs equal-length step/nll lists")
    for k in _SERVING_KEYS:
        if k not in sc["serving"]:
            raise ValueError(f"serving section missing {k!r}")
    inv = sc["invariants"]
    for k in _INVARIANT_KEYS:
        if k not in inv:
            raise ValueError(f"invariants section missing {k!r}")
    if not (isinstance(inv["hung_futures"], int)
            and inv["hung_futures"] >= 0):
        raise ValueError("hung_futures must be a non-negative int")
    digest = inv["replay_digest"]
    if not (isinstance(digest, str) and len(digest) == 64
            and all(c in "0123456789abcdef" for c in digest)):
        raise ValueError("replay_digest must be a sha256 hex string")


def _classify(futures):
    """(completed, dropped, failed) across resolved futures."""
    completed = dropped = failed = 0
    for fut in futures:
        if not fut.done():
            continue
        if fut.cancelled():
            failed += 1
            continue
        exc = fut.exception()
        if exc is None:
            completed += 1
        elif isinstance(exc, DeadlineExceeded):
            dropped += 1
        else:
            failed += 1
    return completed, dropped, failed


def run_scenario(cfg: ScenarioConfig, *, csv=None) -> ScenarioResult:
    """Execute one closed-loop mission. See the module docstring for the
    per-step protocol; `csv` (a print-like callable) gets one progress
    line per accuracy-curve sample."""
    log = csv if csv is not None else (lambda line: None)
    key = jax.random.PRNGKey(cfg.seed)
    field = make_field(cfg)
    paths = agent_paths(cfg)
    M, T, D = paths.shape
    w = cfg.warmup_obs
    dtype = field.W.dtype

    # world observations: precomputed for every (agent, time) so dropped
    # robots keep sensing along their paths and replay never depends on
    # the chaos plan
    f_all = np.asarray(field.f(paths.reshape(-1, D))).reshape(M, T)
    noise_key = jax.random.fold_in(key, 1)
    ys = np.empty((M, T), dtype=np.asarray(f_all).dtype)
    for a in range(M):
        eps = jax.random.normal(jax.random.fold_in(noise_key, a), (T,),
                                dtype)
        ys[a] = f_all[a] + float(field.sigma_eps) * np.asarray(eps)

    # initial fit: decentralized ADMM from the (misspecified) theta0 on
    # the warm-up stretch of every trajectory, windows seeded from it
    fleet = GPFleet(cfg.fleet_config())
    fleet.fit(paths[:, :w], ys[:, :w])

    # held-out ground-truth eval set (fixed geometry: one compiled trace)
    Xe = jax.random.uniform(jax.random.fold_in(key, 2),
                            (cfg.eval_points, D), dtype, cfg.lo, cfg.hi)
    fe = np.asarray(field.f(Xe))

    def evaluate():
        mean, var, _ = fleet.predict(Xe)
        mean, var = np.asarray(mean), np.asarray(var)
        rmse = float(np.sqrt(np.mean((mean - fe) ** 2)))
        nll = float(np.mean(0.5 * np.log(2.0 * np.pi * var)
                            + 0.5 * (fe - mean) ** 2 / var))
        return rmse, nll

    # front door: synchronous (autostart=False) so dispatch order — and
    # the serving-fault injection sequence riding it — replays exactly
    sched = ServingScheduler(autostart=False, max_wait_ms=0.0)
    sched.add_fleet("mission", fleet, max_slot=cfg.max_slot,
                    deadline_policy=cfg.deadline_policy,
                    fault_plan=cfg.serving_plan(), warm=True)

    # prime the clean eval trace, then baseline the trace counter:
    # anything compiled past here is a recompile the result accounts for
    evaluate()
    misses_prev = fleet.jit_cache_misses

    events = membership_events(cfg.membership_plan(), M, cfg.steps)
    ev_by_step: dict[int, list] = {}
    for st, kind, agent in events:
        ev_by_step.setdefault(st, []).append((kind, agent))

    ids = list(range(M))          # original agent id per current fleet index
    futures = []
    curves = {k: [] for k in _CURVE_KEYS}
    drift_steps: list[int] = []
    drift_nll: list[float] = []
    membership_log: list[tuple[int, str, int]] = []
    recompile_steps: list[int] = []
    stats = sched.tenant_stats["mission"]
    degr_prev = fleet.health()["degraded_predictions"]
    batch_prev = stats.batches
    query_key = jax.random.fold_in(key, 3)

    for t in range(cfg.steps):
        # 1. membership chaos (leaves before rejoins at the same step)
        for kind, orig in ev_by_step.get(t, []):
            if kind == "leave" and orig in ids and len(ids) > 2:
                fleet.leave(ids.index(orig))
                ids.remove(orig)
                membership_log.append((t, "leave", orig))
            elif kind == "rejoin" and orig not in ids:
                s0 = max(0, w + t - cfg.warmup_obs)
                fleet.join(paths[orig, s0:w + t], ys[orig, s0:w + t])
                ids.append(orig)
                membership_log.append((t, "rejoin", orig))

        # 2. every live robot observes its current position
        fleet.observe(paths[ids, w + t], ys[ids, w + t])

        # 3. drift-retrain on the live windows (zero-recompile hot-swap)
        if cfg.drift_every and (t + 1) % cfg.drift_every == 0 \
                and int(jnp.min(fleet.window_counts)) >= 2:
            fleet.drift(iters=cfg.drift_iters)
            drift_steps.append(t)
            drift_nll.append(evaluate()[1])

        # 4. mid-mission queries through the scheduler front door
        kq = jax.random.fold_in(query_key, t)
        for j in range(cfg.queries_per_step):
            Xq = np.asarray(jax.random.uniform(
                jax.random.fold_in(kq, j), (cfg.query_rows, D), dtype,
                cfg.lo, cfg.hi))
            futures.append(sched.add_request(Xq,
                                             deadline_ms=cfg.deadline_ms))
        while sched.step(force=True):
            pass

        # 5. accuracy-over-time + serving-health curves
        if t % cfg.eval_every == 0 or t == cfg.steps - 1:
            rmse, nll = evaluate()
            degr = fleet.health()["degraded_predictions"]
            batches = stats.batches
            frac = ((degr - degr_prev) / (batches - batch_prev)
                    if batches > batch_prev else 0.0)
            degr_prev, batch_prev = degr, batches
            curves["step"].append(t)
            curves["rmse"].append(rmse)
            curves["nll"].append(nll)
            curves["alive"].append(len(ids))
            curves["degraded_fraction"].append(float(frac))
            log(f"scenario,step={t},alive={len(ids)},rmse={rmse:.4f},"
                f"nll={nll:.4f},degraded={frac:.2f}")

        misses = fleet.jit_cache_misses
        if misses > misses_prev:
            recompile_steps.append(t)
            misses_prev = misses

    while sched.step(force=True):
        pass
    sched.close(drain=True, timeout=60.0)

    hung = sum(1 for fut in futures if not fut.done())
    completed, dropped, failed = _classify(futures)
    p50, p99 = stats.latency_ms(50, 99)
    serving = {
        "submitted": len(futures), "completed": completed,
        "dropped": dropped, "failed": failed, "retried": stats.retried,
        "p50_ms": float(p50), "p99_ms": float(p99),
    }
    return ScenarioResult(
        config=cfg.to_dict(), curves=curves, drift_steps=drift_steps,
        drift_nll=drift_nll, membership=membership_log,
        recompile_steps=recompile_steps, serving=serving,
        hung_futures=hung, jit_cache_misses=fleet.jit_cache_misses,
        health=fleet.health())
