"""Closed-loop multi-robot scenario harness (replayable integration pack).

One seed-complete, JSON round-trippable `ScenarioConfig` describes a full
mission — M agents traversing a latent sampled field, streaming window
observations, drift-retraining with decentralized ADMM, answering routed
queries through the serving scheduler, absorbing a seeded chaos plan —
and `run_scenario` replays it bit-identically (same config => same
`ScenarioResult.replay_digest()`). The same config ships three ways:
`examples/multi_robot_mission.py`, `benchmarks/bench_scenario.py`
(BENCH_scenario.json), and the `tests/test_scenario.py` invariant pack.
See docs/scenario.md.
"""
from .config import ScenarioConfig, preset
from .driver import ScenarioResult, run_scenario, validate_bench
from .field import LatentField, make_field
from .trajectories import agent_paths

__all__ = [
    "ScenarioConfig", "preset",
    "ScenarioResult", "run_scenario", "validate_bench",
    "LatentField", "make_field", "agent_paths",
]
