"""Seeded agent trajectories: smooth momentum walks over the mission area.

Every agent's FULL path (warm-up + mission) is precomputed host-side from
`np.random.default_rng` seeded by (cfg.seed, agent id), which buys three
scenario invariants for free:

  - replay: same config => bit-identical paths, no matter what the chaos
    plan does to the fleet;
  - membership independence: a dropped robot keeps moving along its path
    (it stops communicating, not driving), so a rejoin resumes seamlessly
    at its CURRENT position and can backfill its window from the stretch
    it sensed while out of contact;
  - seed sensitivity: a different seed re-draws every path (asserted by
    the two-seed test).

The walk itself: a random start in [lo, hi]^D, a persistent heading
diffused by `turn_std` Gaussian turns, fixed `step_size` steps, and
reflection at the area boundary — a cheap stand-in for the waypoint
missions of the multi-robot papers (PAPERS.md 1805.09266, 2502.05301).
"""
from __future__ import annotations

import numpy as np


def agent_paths(cfg) -> np.ndarray:
    """(M, warmup_obs + steps, D) float64 positions, agent-seeded."""
    M, D = cfg.num_agents, cfg.input_dim
    T = cfg.warmup_obs + cfg.steps
    lo, hi = float(cfg.lo), float(cfg.hi)
    paths = np.empty((M, T, D), dtype=np.float64)
    for a in range(M):
        rng = np.random.default_rng([int(cfg.seed), 0x7A11, a])
        pos = rng.uniform(lo, hi, D)
        heading = rng.normal(size=D)
        heading /= np.linalg.norm(heading)
        for t in range(T):
            paths[a, t] = pos
            heading = heading + cfg.turn_std * rng.normal(size=D)
            heading /= max(np.linalg.norm(heading), 1e-12)
            pos = pos + cfg.step_size * heading
            # reflect off the area boundary (and fold the heading with it)
            for d in range(D):
                if pos[d] < lo:
                    pos[d] = 2 * lo - pos[d]
                    heading[d] = -heading[d]
                elif pos[d] > hi:
                    pos[d] = 2 * hi - pos[d]
                    heading[d] = -heading[d]
    return paths
