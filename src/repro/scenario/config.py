"""ScenarioConfig: one seed-complete description of a closed-loop mission.

A scenario is a deterministic multi-robot story: M agents traverse a
latent sampled field along seeded trajectories, stream observations into
their sliding windows, periodically drift-retrain hyperparameters with
decentralized ADMM, answer queries mid-mission through the serving
scheduler, and absorb a seeded chaos plan (dropout/rejoin, degraded
consensus, stragglers, injected failures). EVERYTHING stochastic derives
from the two seeds carried here (`seed` for the world — field draw,
trajectories, observation noise, query positions — and `fault_seed` for
the chaos plan), so a config replays bit-identically: same config =>
identical trajectories, observations, membership timeline, and
accuracy-over-time curves (tests/test_scenario.py asserts it).

The config is frozen and JSON round-trippable (`to_json`/`from_json`
restore an `==` config), which is what lets one ScenarioConfig ship three
ways: `examples/multi_robot_mission.py`, `benchmarks/bench_scenario.py
--scenario` (BENCH_scenario.json), and the pytest integration pack.

Chaos fields map onto `repro.chaos.FaultPlan` in two disjoint plans:

  membership_plan()   the dropout windows, reinterpreted at FLEET-STEP
                      granularity (`membership_events`) and fed to
                      `GPFleet.leave`/`join` by the driver — dropout is
                      a robot leaving the consensus graph mid-mission.
  serving_plan()      edge_loss / nan_agents (degraded consensus on the
                      scheduler's predict path) + stragglers / injected
                      failures (`wrap_predict_fn` on dispatch). Dropouts
                      deliberately do NOT ride this plan — they already
                      shrank the fleet through membership.

`nan_agents` cannot be combined with `dropouts`: payload-corruption
indices refer to the CURRENT fleet, and leaves renumber agents, which
would silently corrupt a different robot than the one named.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass

from ..chaos import Dropout, FaultPlan
from ..fleet import FleetConfig

_TOPOLOGIES = ("path", "cycle", "complete")


@dataclass(frozen=True)
class ScenarioConfig:
    # -- determinism ---------------------------------------------------------
    seed: int = 0                 # world seed: field, paths, noise, queries
    fault_seed: int = 0           # chaos seed (repro.chaos.FaultPlan)

    # -- fleet ---------------------------------------------------------------
    num_agents: int = 4
    input_dim: int = 2
    graph: str = "cycle"          # consensus topology: path | cycle | complete
    trainer: str = "dec-apx"      # drift-retrain loop (registry name)
    method: str = "rbcm"          # serving method (registry name)
    theta0: tuple = (1.2, 1.2, 1.0, 0.3)   # deliberately misspecified start
    window: int = 24              # sliding-window size W
    chunk: int = 16               # engine query-tile size
    dac_iters: int = 100
    admm_iters: int = 10          # initial (warm-up) fit budget
    rho: float = 500.0
    kappa: float = 5_000.0

    # -- latent ground-truth field ------------------------------------------
    field_theta: tuple = (0.8, 0.8, 1.3, 0.1)   # (l_1..l_D, sf, se) linear
    field_features: int = 256     # RFF features of the sampled field
    lo: float = 0.0               # mission area [lo, hi]^D
    hi: float = 2.0

    # -- mission timeline ----------------------------------------------------
    warmup_obs: int = 6           # per-agent observations before step 0
    steps: int = 12               # closed-loop fleet steps
    step_size: float = 0.3        # trajectory step length
    turn_std: float = 0.6         # heading diffusion (momentum walk)
    drift_every: int = 4          # ADMM retrain cadence in steps (0: never)
    drift_iters: int = 6          # ADMM iterations per drift epoch
    eval_every: int = 1           # accuracy-curve cadence in steps
    eval_points: int = 48         # held-out ground-truth eval set size

    # -- serving (scheduler front door) --------------------------------------
    queries_per_step: int = 2
    query_rows: int = 5           # rows per mid-mission request
    max_slot: int = 32            # slot-ladder ceiling
    deadline_ms: float | None = None
    deadline_policy: str = "drop"

    # -- chaos ---------------------------------------------------------------
    dropouts: tuple = ()          # (agent, at_step, until_step|None) triples
    edge_loss: float = 0.0        # degraded consensus on the serving path
    nan_agents: tuple = ()        # NaN-corrupted payloads (no dropouts)
    straggle_every: int = 0       # every k-th scheduler dispatch sleeps ...
    straggle_ms: float = 0.0      # ... this long
    fail_every: int = 0           # every k-th dispatch raises (transient)

    def __post_init__(self):
        if self.graph not in _TOPOLOGIES:
            raise ValueError(f"graph must be one of {_TOPOLOGIES}, got "
                             f"{self.graph!r}")
        for name, th in (("theta0", self.theta0),
                         ("field_theta", self.field_theta)):
            if len(th) != self.input_dim + 2:
                raise ValueError(
                    f"{name} must have input_dim + 2 = {self.input_dim + 2} "
                    f"entries (l_1..l_D, sigma_f, sigma_eps), got {len(th)}")
            object.__setattr__(self, name, tuple(float(v) for v in th))
        if self.num_agents < 2:
            raise ValueError("a multi-robot scenario needs >= 2 agents")
        if self.steps < 1 or self.warmup_obs < 2:
            raise ValueError("steps >= 1 and warmup_obs >= 2 required")
        if self.warmup_obs > self.window:
            raise ValueError(f"warmup_obs {self.warmup_obs} exceeds window "
                             f"{self.window} (warm-up data would be evicted "
                             f"before the mission starts)")
        if not 0.0 <= self.edge_loss < 1.0:
            raise ValueError(f"edge_loss must be in [0, 1), got "
                             f"{self.edge_loss}")
        # normalize dropouts to hashable (agent, at, until) int triples
        norm = []
        for d in self.dropouts:
            a, at, until = (d.agent, d.at, d.until) \
                if isinstance(d, Dropout) else tuple(d)
            norm.append((int(a), int(at),
                         None if until is None else int(until)))
        object.__setattr__(self, "dropouts", tuple(norm))
        object.__setattr__(self, "nan_agents",
                           tuple(int(a) for a in self.nan_agents))
        for a, at, until in self.dropouts:
            if not 0 <= a < self.num_agents:
                raise ValueError(f"dropout agent {a} not in fleet of "
                                 f"{self.num_agents}")
            if at < 0 or (until is not None and until <= at):
                raise ValueError(f"dropout window at={at} until={until} is "
                                 f"empty or negative")
        if self.nan_agents and self.dropouts:
            raise ValueError(
                "nan_agents cannot be combined with dropouts: leaves "
                "renumber agents, so a payload-corruption index would "
                "silently point at a different robot mid-mission")
        if len({a for a, _, _ in self.dropouts}) > self.num_agents - 2:
            raise ValueError(
                "dropouts may not name more than num_agents - 2 distinct "
                "agents (the mission must keep a >= 2-agent fleet)")

    def replace(self, **kw) -> "ScenarioConfig":
        return dataclasses.replace(self, **kw)

    # -- derived configs -----------------------------------------------------

    def fleet_config(self) -> FleetConfig:
        """The streaming FleetConfig this scenario drives."""
        return FleetConfig(
            input_dim=self.input_dim, theta0=self.theta0,
            num_agents=self.num_agents, graph=self.graph,
            trainer=self.trainer, method=self.method,
            rho=self.rho, kappa=self.kappa, admm_iters=self.admm_iters,
            chunk=self.chunk, dac_iters=self.dac_iters,
            online=True, window=self.window)

    def membership_plan(self) -> FaultPlan:
        """Dropout windows only — the driver feeds
        `membership_events(plan, M, steps)` into GPFleet.leave/join."""
        return FaultPlan(seed=self.fault_seed, dropouts=tuple(
            Dropout(a, at, until) for a, at, until in self.dropouts))

    def serving_plan(self) -> FaultPlan | None:
        """Consensus degradation + serving faults for the scheduler path
        (None when this scenario serves clean)."""
        plan = FaultPlan(seed=self.fault_seed, edge_loss=self.edge_loss,
                         nan_agents=self.nan_agents,
                         straggle_every=self.straggle_every,
                         straggle_ms=self.straggle_ms,
                         fail_every=self.fail_every)
        return None if plan.empty else plan

    # -- serialization -------------------------------------------------------

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "ScenarioConfig":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown ScenarioConfig fields "
                             f"{sorted(unknown)} (config saved by a newer "
                             f"version?)")
        d = dict(d)
        for k in ("theta0", "field_theta", "nan_agents"):
            if k in d:
                d[k] = tuple(d[k])
        if "dropouts" in d:
            d["dropouts"] = tuple(tuple(t) for t in d["dropouts"])
        return cls(**d)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2, sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "ScenarioConfig":
        return cls.from_dict(json.loads(s))


# -- named presets (the three shipping surfaces share these) -----------------

def preset(name: str) -> ScenarioConfig:
    """Named mission presets.

      smoke    seconds-scale clean mission (CI tier-1 / bench --smoke)
      mission  the default closed-loop story: longer traversal, drift
               retrains, mid-mission queries, no chaos
      chaos    mission + one dropout/rejoin, degraded consensus edge
               loss, a straggler cadence, and injected transient failures
    """
    base = ScenarioConfig()
    presets = {
        "smoke": base.replace(steps=8, warmup_obs=5, window=16,
                              dac_iters=60, admm_iters=6, drift_every=3,
                              drift_iters=4, eval_points=32,
                              field_features=128, queries_per_step=1,
                              query_rows=4, max_slot=16),
        # the long mission serves gpoe: rBCM's precision-summing grows
        # overconfident far from the trajectories as windows fill (NLL
        # degrades even as RMSE halves); gpoe's normalized weights keep
        # the NLL story monotone across drift epochs
        "mission": base.replace(steps=24, num_agents=6, window=32,
                                drift_every=6, method="gpoe"),
        "chaos": base.replace(
            steps=16, num_agents=5, window=24, drift_every=5,
            dropouts=((1, 4, 10),), edge_loss=0.05,
            straggle_every=5, straggle_ms=10.0, fail_every=7,
            deadline_ms=5_000.0),
    }
    if name not in presets:
        raise ValueError(f"unknown scenario preset {name!r}; one of "
                         f"{sorted(presets)}")
    return presets[name]
