from .adam import adam, sgd, apply_updates
from .adafactor import adafactor
from .schedules import constant, cosine, warmup_cosine

__all__ = ["adam", "sgd", "adafactor", "apply_updates",
           "constant", "cosine", "warmup_cosine"]
