"""Adafactor (Shazeer & Stern 2018) with factored second moments.

Used for the largest MoE configs (llama4-maverick, dbrx) where Adam's
8 bytes/param of fp32 state would not fit 16 GB/chip HBM at 256 chips.
Factored stats store O(rows+cols) per matrix instead of O(rows*cols).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .adam import Optimizer, _lr_at


def adafactor(lr, eps: float = 1e-30, clip_threshold: float = 1.0,
              decay: float = 0.8, min_dim_factored: int = 128) -> Optimizer:
    def factored(p):
        return p.ndim >= 2 and p.shape[-1] >= min_dim_factored and \
            p.shape[-2] >= min_dim_factored

    def init(params):
        def stat(p):
            if factored(p):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"step": jnp.zeros((), jnp.int32),
                "stats": jax.tree.map(stat, params,
                                      is_leaf=lambda x: isinstance(x, jnp.ndarray))}

    def update(grads, state, params=None):
        step = state["step"] + 1
        beta2 = 1.0 - step.astype(jnp.float32) ** (-decay)
        lr_t = _lr_at(lr, step)

        def upd(g, s):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if "vr" in s:
                vr = beta2 * s["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * s["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                denom = (vr / jnp.mean(vr, axis=-1, keepdims=True))[..., None] \
                    * vc[..., None, :]
                u = g / jnp.sqrt(denom + eps)
                ns = {"vr": vr, "vc": vc}
            else:
                v = beta2 * s["v"] + (1 - beta2) * g2
                u = g / jnp.sqrt(v + eps)
                ns = {"v": v}
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return -lr_t * u, ns

        flat_g, treedef = jax.tree.flatten(grads)
        flat_s = treedef.flatten_up_to(state["stats"])
        outs = [upd(g, s) for g, s in zip(flat_g, flat_s)]
        updates = treedef.unflatten([o[0] for o in outs])
        stats = treedef.unflatten([o[1] for o in outs])
        return updates, {"step": step, "stats": stats}

    return Optimizer(init, update)
