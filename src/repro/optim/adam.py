"""Minimal pytree optimizers (optax-style (init, update) pairs), built in-repo.

All optimizers return *updates* (deltas to add to params); `apply_updates`
applies them. Gradient transformations compose functionally.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable  # (grads, state, params) -> (updates, state)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def _lr_at(lr, step):
    return lr(step) if callable(lr) else lr


def sgd(lr, momentum: float = 0.0) -> Optimizer:
    def init(params):
        mu = jax.tree.map(jnp.zeros_like, params) if momentum else None
        return {"step": jnp.zeros((), jnp.int32), "mu": mu}

    def update(grads, state, params=None):
        step = state["step"] + 1
        lr_t = _lr_at(lr, step)
        if momentum:
            mu = jax.tree.map(lambda m, g: momentum * m + g, state["mu"], grads)
            updates = jax.tree.map(lambda m: -lr_t * m, mu)
        else:
            mu = None
            updates = jax.tree.map(lambda g: -lr_t * g, grads)
        return updates, {"step": step, "mu": mu}

    return Optimizer(init, update)


def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0, grad_clip: float | None = None,
         state_dtype=jnp.float32) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, state_dtype)
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
        }

    def update(grads, state, params=None):
        step = state["step"] + 1
        if grad_clip is not None:
            gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                                 for g in jax.tree.leaves(grads)))
            scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-12))
            grads = jax.tree.map(lambda g: g * scale, grads)
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(state_dtype),
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2)
                         * jnp.square(g.astype(state_dtype)), state["v"], grads)
        bc1 = 1 - b1 ** step.astype(state_dtype)
        bc2 = 1 - b2 ** step.astype(state_dtype)
        lr_t = _lr_at(lr, step)

        def upd(m_, v_, p):
            u = -lr_t * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay:
                u = u - lr_t * weight_decay * p.astype(state_dtype)
            return u

        params_for_wd = params if params is not None else state["m"]
        updates = jax.tree.map(upd, m, v, params_for_wd)
        return updates, {"step": step, "m": m, "v": v}

    return Optimizer(init, update)
