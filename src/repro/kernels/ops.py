"""Jit'd public wrappers around the Pallas kernels: padding to tile-aligned
shapes, dtype handling, CPU interpret-mode fallback, and the pure-jnp path
used under pjit dry-runs (use_pallas=False).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import ref
from .cholupdate import cholupdate_pallas
from .nll_grad import nll_grad_pallas
from .rbf_gram import rbf_gram_pallas
from .rbf_matvec import rbf_matvec_pallas
from .flash_attention import flash_attention_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x, mult, axis):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@partial(jax.jit, static_argnames=("with_noise", "use_pallas", "interpret",
                                   "bn", "bm"))
def rbf_gram(x1, x2, lengthscales, sigma_f, noise=0.0, with_noise: bool = False,
             use_pallas: bool | None = None, interpret: bool | None = None,
             bn: int = 256, bm: int = 256):
    """Public RBF Gram op. x1 (N,D), x2 (M,D) -> (N,M).

    `with_noise=True` adds noise^2 on the global diagonal (square case)."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not use_pallas:
        return ref.rbf_gram_ref(x1, x2, lengthscales, sigma_f,
                                noise if with_noise else 0.0)
    if interpret is None:
        interpret = not _on_tpu()
    N, M = x1.shape[0], x2.shape[0]
    a = _pad_to((x1 / lengthscales).astype(jnp.float32), 8, 1)
    b = _pad_to((x2 / lengthscales).astype(jnp.float32), 8, 1)
    bn_ = min(bn, max(8, N)); bm_ = min(bm, max(8, M))
    a = _pad_to(a, bn_, 0)
    b = _pad_to(b, bm_, 0)
    out = rbf_gram_pallas(a, b, jnp.asarray(sigma_f) ** 2,
                          jnp.asarray(noise) ** 2, with_noise=with_noise,
                          bn=bn_, bm=bm_, interpret=interpret)
    return out[:N, :M]


@partial(jax.jit, static_argnames=("causal", "window", "use_pallas",
                                   "interpret", "bq", "bk"))
def flash_attention(q, k, v, causal: bool = True, window: int | None = None,
                    use_pallas: bool | None = None, interpret: bool | None = None,
                    bq: int = 256, bk: int = 256):
    """Public attention op. q (B,H,Sq,D), k/v (B,KH,Sk,D)."""
    Sq, Sk = q.shape[2], k.shape[2]

    def _divisor_block(n, cap):
        b = min(cap, n)
        while n % b:
            b -= 1
        return b

    if use_pallas is None:
        use_pallas = _on_tpu()
    if not use_pallas:
        # chunked jnp flash (custom VJP): same memory behaviour as the TPU
        # kernel — O(S*chunk) transients, backward recomputes chunk scores
        from .flash_jnp import flash_attention_jnp
        return flash_attention_jnp(q, k, v, causal, window,
                                   _divisor_block(Sk, 1024))
    if interpret is None:
        interpret = not _on_tpu()
    bq_ = _divisor_block(Sq, bq)
    bk_ = _divisor_block(Sk, bk)
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  bq=bq_, bk=bk_, interpret=interpret)


@partial(jax.jit, static_argnames=("use_pallas", "interpret", "bn", "bm"))
def rbf_matvec(x1, x2, v, lengthscales, sigma_f, use_pallas: bool | None = None,
               interpret: bool | None = None, bn: int = 256, bm: int = 256):
    """Fused k(X1,X2) @ v — O(N+M) memory (streaming prediction mean)."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not use_pallas:
        return ref.rbf_matvec_ref(x1, x2, v, lengthscales, sigma_f)
    if interpret is None:
        interpret = not _on_tpu()
    N, M = x1.shape[0], x2.shape[0]
    a = _pad_to((x1 / lengthscales).astype(jnp.float32), 8, 1)
    b = _pad_to((x2 / lengthscales).astype(jnp.float32), 8, 1)
    bn_ = min(bn, max(8, N)); bm_ = min(bm, max(8, M))
    a = _pad_to(a, bn_, 0)
    b = _pad_to(b, bm_, 0)
    vp = _pad_to(v.astype(jnp.float32), bm_, 0)   # zero-pad: no contribution
    out = rbf_matvec_pallas(a, b, vp, jnp.asarray(sigma_f) ** 2,
                            bn=bn_, bm=bm_, interpret=interpret)
    return out[:N]


@partial(jax.jit, static_argnames=("bn", "use_pallas", "interpret"))
def kmn_stats(Z, X, y, lengthscales, sigma_f, bn: int = 4096,
              use_pallas: bool | None = None,
              interpret: bool | None = None):
    """Blocked Titsias statistics B = Kmn @ Knm (m, m), b = Kmn @ y (m,)
    for Kmn = k(Z, X) — the one O(N) pass of a sparse-expert fit
    (core.sparse.fit_sparse_experts).

    X (N, D) is streamed one (m, bn) kernel panel at a time through the
    same Gram math as `rbf_gram` (Pallas on TPU, jnp elsewhere), so
    transient memory is O(m bn) at any N. The padded tail reuses the
    zero-weight idiom of `rbf_matvec`: pad columns are multiplied by a 0
    weight before the panel products, so they contribute to neither
    statistic.
    """
    N, D = X.shape
    bn_ = min(bn, max(1, N))
    Xb = _pad_to(X, bn_, 0)
    wb = _pad_to(jnp.ones((N,), X.dtype), bn_, 0)
    yb = _pad_to(y.astype(X.dtype), bn_, 0)
    nblk = Xb.shape[0] // bn_
    blocks = (Xb.reshape(nblk, bn_, D), wb.reshape(nblk, bn_),
              yb.reshape(nblk, bn_))

    def body(carry, blk):
        B, b = carry
        Xi, wi, yi = blk
        Kb = rbf_gram(Z, Xi, lengthscales, sigma_f, use_pallas=use_pallas,
                      interpret=interpret) * wi[None, :]
        return (B + Kb @ Kb.T, b + Kb @ yi), None

    m = Z.shape[0]
    init = (jnp.zeros((m, m), X.dtype), jnp.zeros((m,), X.dtype))
    (B, b), _ = jax.lax.scan(body, init, blocks)
    return B, b


@partial(jax.jit, static_argnames=("use_pallas", "interpret", "bn", "bm"))
def nll_grad_fused(log_theta, d2u, inner, K=None, use_pallas: bool | None = None,
                   interpret: bool | None = None, bn: int = 256,
                   bm: int = 256):
    """Fused trace-identity NLL gradient (paper eq. 4) in log-theta coords.

    Given the once-per-fit unscaled diff^2 stack `d2u` (D, N, N) from
    core.training.cache and the Cholesky-derived `inner` = C^-1 - alpha
    alpha^T (N, N) of the current iteration, returns dNLL/dlog_theta (D+2,)
    in ONE pass: K is rebuilt tile-by-tile in registers and all D+2 trace
    components accumulate without materializing the (D+2, N, N) derivative
    stack — O(N^2) gradient memory instead of O(D N^2), one read of
    d2u/inner instead of D+2.

    `K` optionally reuses an already-materialized kernel matrix on the jnp
    path (the caller computed it for the Cholesky anyway); the Pallas path
    ignores it — the in-register rebuild is cheaper than streaming another
    (N, N) operand from HBM.

    Like the other TPU kernels the Pallas path COMPUTES in float32, so the
    auto default only engages it for float32 inputs: float64 callers (x64
    training, where the 1e-6 fused-vs-autodiff equivalence is asserted)
    keep the dtype-exact jnp path unless they force use_pallas=True.
    """
    if use_pallas is None:
        use_pallas = _on_tpu() and d2u.dtype == jnp.float32
    if not use_pallas:
        return ref.nll_grad_fused_ref(log_theta, d2u, inner, K=K, bn=bn)
    if interpret is None:
        interpret = not _on_tpu()
    D, N = d2u.shape[0], d2u.shape[1]
    theta = jnp.exp(log_theta)
    ls, sigma_f, sigma_eps = theta[:-2], theta[-2], theta[-1]
    bn_ = min(bn, max(8, N)); bm_ = min(bm, max(8, N))
    d2p = _pad_to(_pad_to(d2u.astype(jnp.float32), bn_, 1), bm_, 2)
    innerp = _pad_to(_pad_to(inner.astype(jnp.float32), bn_, 0), bm_, 1)
    params = jnp.concatenate([(1.0 / ls**2), sigma_f[None] ** 2]) \
        .astype(jnp.float32).reshape(1, D + 1)
    rows = nll_grad_pallas(d2p, innerp, params, bn=bn_, bm=bm_,
                           interpret=interpret)
    sums = jnp.sum(rows, axis=0).astype(d2u.dtype)
    return jnp.concatenate([sums[:D] / ls**2, sums[D:D + 1],
                            (sigma_eps**2 * sums[D + 1:D + 2])])


@partial(jax.jit, static_argnames=("downdate", "use_pallas", "interpret",
                                   "bk", "shift"))
def cholupdate(L, x, downdate: bool = False, use_pallas: bool | None = None,
               interpret: bool | None = None, bk: int = 256,
               shift: int = 0):
    """Rank-1 Cholesky update/downdate chol(L L^T +/- x x^T) — O(n^2).

    L (n, n) lower-triangular, x (n,). Padded columns get a unit diagonal
    and a zero x entry, which the column sweep provably leaves untouched,
    so tile alignment never perturbs the factor. The pure-jnp path keeps
    the input dtype (float64-safe); the Pallas path COMPUTES in float32
    like the other TPU kernels but casts back to L.dtype — callers that
    persist the factor in a pytree (core/online) rely on the dtype being
    preserved.

    `shift=k` (static) updates the trailing block L[k:, k:] with x[k:] and
    returns it moved k slots up-left (fused on the jnp path; the trailing
    k rows/cols of the result are stale — see ref.cholupdate_ref).
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not use_pallas:
        return ref.cholupdate_ref(L, x, downdate, bk, shift)
    if interpret is None:
        interpret = not _on_tpu()
    if shift:
        # Pallas path: update the trailing block, then one block move
        # (HBM bandwidth makes the extra copy cheap on TPU)
        n = L.shape[0]
        sub = cholupdate(L[shift:, shift:], x[shift:], downdate,
                         use_pallas, interpret, bk)
        return L.at[:n - shift, :n - shift].set(sub.astype(L.dtype))
    n = L.shape[0]
    # the Pallas kernel holds two (n, bk) panels in VMEM — cap the panel
    # width (the jnp path takes `bk` as given)
    bk_ = min(bk, 128, max(8, n))
    pad = (-n) % bk_
    Lp = jnp.pad(L.astype(jnp.float32), ((0, pad), (0, pad)))
    if pad:
        tail = jnp.arange(n, n + pad)
        Lp = Lp.at[tail, tail].set(1.0)
    xp = _pad_to(x.astype(jnp.float32), bk_, 0)
    sign = -1.0 if downdate else 1.0
    out = cholupdate_pallas(Lp, xp, sign, bk=bk_, interpret=interpret)
    return out[:n, :n].astype(L.dtype)
