"""Chunked flash attention in pure jnp with a flash-style custom VJP.

This is the non-Pallas execution path (CPU tests, pjit dry-runs): same online
-softmax algorithm as kernels/flash_attention.py, O(S * chunk) memory instead
of O(S^2), and a custom backward that saves only (out, lse) and recomputes
chunk scores — matching what the TPU kernel's backward does. Without this,
dry-run memory analysis would misrepresent the TPU target by tens of GB.

Semantics match ref.flash_attention_ref: GQA (H % KH == 0), causal masking
with queries right-aligned to the key timeline, optional sliding window.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _chunk_count(s, c):
    return (s + c - 1) // c


def _mask(q_pos, k_pos, causal, window):
    mq = q_pos[..., :, None]
    mk = k_pos[..., None, :]
    m = jnp.ones(jnp.broadcast_shapes(mq.shape, mk.shape), jnp.bool_)
    if causal:
        m = m & (mk <= mq)
    if window is not None:
        m = m & (mk > mq - window)
    return m


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention_jnp(q, k, v, causal=True, window=None, chunk=1024,
                        scale=None):
    out, _ = _flash_fwd_impl(q, k, v, causal, window, chunk, scale)
    return out


def _flash_fwd_impl(q, k, v, causal, window, chunk, scale):
    B, H, Sq, D = q.shape
    KH, Sk = k.shape[1], k.shape[2]
    g = H // KH
    sc = scale if scale is not None else 1.0 / D ** 0.5
    nc = _chunk_count(Sk, chunk)
    q32 = q.astype(jnp.float32)
    q_pos = jnp.arange(Sq) + (Sk - Sq)

    def body(carry, ic):
        acc, m, l = carry
        ks = jax.lax.dynamic_slice_in_dim(k, ic * chunk, chunk, 2)
        vs = jax.lax.dynamic_slice_in_dim(v, ic * chunk, chunk, 2)
        ks = jnp.repeat(ks, g, axis=1).astype(jnp.float32)
        vs = jnp.repeat(vs, g, axis=1).astype(jnp.float32)
        s = jnp.einsum("bhqd,bhkd->bhqk", q32, ks) * sc
        k_pos = ic * chunk + jnp.arange(chunk)
        msk = _mask(q_pos, k_pos, causal, window) & (k_pos < Sk)[None, :]
        s = jnp.where(msk[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, vs)
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((B, H, Sq, D), jnp.float32)
    m0 = jnp.full((B, H, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), jnp.arange(nc))
    l_safe = jnp.maximum(l, 1e-30)
    out = (acc / l_safe[..., None]).astype(q.dtype)
    lse = m + jnp.log(l_safe)
    return out, lse


def _flash_fwd(q, k, v, causal, window, chunk, scale):
    out, lse = _flash_fwd_impl(q, k, v, causal, window, chunk, scale)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, chunk, scale, res, dout):
    q, k, v, out, lse = res
    B, H, Sq, D = q.shape
    KH, Sk = k.shape[1], k.shape[2]
    g = H // KH
    sc = scale if scale is not None else 1.0 / D ** 0.5
    nc = _chunk_count(Sk, chunk)
    q32 = q.astype(jnp.float32)
    do32 = dout.astype(jnp.float32)
    delta = jnp.sum(do32 * out.astype(jnp.float32), axis=-1)   # (B,H,Sq)
    q_pos = jnp.arange(Sq) + (Sk - Sq)

    def body(dq, ic):
        ks = jax.lax.dynamic_slice_in_dim(k, ic * chunk, chunk, 2)
        vs = jax.lax.dynamic_slice_in_dim(v, ic * chunk, chunk, 2)
        ksr = jnp.repeat(ks, g, axis=1).astype(jnp.float32)
        vsr = jnp.repeat(vs, g, axis=1).astype(jnp.float32)
        s = jnp.einsum("bhqd,bhkd->bhqk", q32, ksr) * sc
        k_pos = ic * chunk + jnp.arange(chunk)
        msk = _mask(q_pos, k_pos, causal, window) & (k_pos < Sk)[None, :]
        s = jnp.where(msk[None, None], s, NEG_INF)
        p = jnp.exp(s - lse[..., None])                         # (B,H,q,k)
        dp = jnp.einsum("bhqd,bhkd->bhqk", do32, vsr)
        ds = p * (dp - delta[..., None]) * sc
        dq = dq + jnp.einsum("bhqk,bhkd->bhqd", ds, ksr)
        dvc = jnp.einsum("bhqk,bhqd->bhkd", p, do32)            # (B,H,k,D)
        dkc = jnp.einsum("bhqk,bhqd->bhkd", ds, q32)
        # GQA: sum gradient over the q-head group
        dvc = dvc.reshape(B, KH, g, chunk, D).sum(2)
        dkc = dkc.reshape(B, KH, g, chunk, D).sum(2)
        return dq, (dkc, dvc)

    dq0 = jnp.zeros((B, H, Sq, D), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(body, dq0, jnp.arange(nc))
    dk = jnp.moveaxis(dks, 0, 2).reshape(B, KH, nc * chunk, D)[:, :, :Sk]
    dv = jnp.moveaxis(dvs, 0, 2).reshape(B, KH, nc * chunk, D)[:, :, :Sk]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention_jnp.defvjp(_flash_fwd, _flash_bwd)
