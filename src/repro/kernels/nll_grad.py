"""Pallas TPU kernel: one-pass fused NLL gradient via the trace identity.

The training hot-spot (paper eq. 4): every ADMM iteration needs, per agent,

  dNLL/dlog_theta_j = 0.5 tr{ (C^-1 - alpha alpha^T) dC/dtheta_j } * theta_j

The seed evaluated this either by autodiffing `nll` (re-deriving the pairwise
geometry of X every iteration and paying the Cholesky VJP) or by
`nll_grad_analytic` (materializing the (D+2, N, N) derivative stack of
`cov_grads`). This kernel takes the once-per-fit UNSCALED diff^2 stack
d2u[d] = (x_d - x'_d)^2 (core.training.cache) and the Cholesky-derived
inner = C^-1 - alpha alpha^T, and accumulates every gradient component in
ONE streaming pass over the N x N plane:

  per (bn, bm) tile:  d2s = sum_d d2u[d] / l_d^2          (VPU FMA)
                      K   = sigma_f^2 * exp(-d2s)          (rebuilt in
                            registers — cheaper than streaming K from HBM)
                      W   = inner ⊙ K
                      acc[d] += sum W ⊙ d2u[d]             (lengthscales)
                      acc[D] += sum W                      (sigma_f)
                      acc[D+1] += sum 1{i==j} inner        (sigma_eps trace)

Gradient memory drops from O(D N^2) (the cov_grads stack) to the O(N^2)
inputs that already exist, and the D+2 separate contraction passes fuse
into one read of d2u/inner. The kernel emits one partial-sum row per grid
row (accumulated across the j sweep in VMEM); the wrapper reduces rows and
applies the chain rule to log-theta coordinates.

Zero-padding is exact: padded entries of `inner` are 0, so W and the trace
mask contribute nothing regardless of what K evaluates to there.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128


def _nll_grad_kernel(params_ref, d2u_ref, inner_ref, out_ref, *, bn: int,
                     bm: int, D: int):
    i = pl.program_id(0)
    j = pl.program_id(1)
    inner = inner_ref[...]                           # (bn, bm) f32
    d2u = d2u_ref[...]                               # (D, bn, bm) f32
    d2s = params_ref[0, 0] * d2u[0]
    for d in range(1, D):
        d2s += params_ref[0, d] * d2u[d]
    k = params_ref[0, D] * jnp.exp(-d2s)             # sigma_f^2 exp(-d2s)
    w = inner * k
    vals = [jnp.sum(w * d2u[d]) for d in range(D)]   # lengthscale components
    vals.append(jnp.sum(w))                          # sigma_f component
    rows = i * bn + jax.lax.broadcasted_iota(jnp.int32, (bn, bm), 0)
    cols = j * bm + jax.lax.broadcasted_iota(jnp.int32, (bn, bm), 1)
    vals.append(jnp.sum(jnp.where(rows == cols, inner, 0.0)))   # tr(inner)
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, LANES), 1)
    row = jnp.zeros((1, LANES), jnp.float32)
    for idx, v in enumerate(vals):                   # D static and small
        row = jnp.where(lane == idx, v, row)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = row

    @pl.when(j > 0)
    def _acc():
        out_ref[...] += row


def nll_grad_pallas(d2u: jax.Array, inner: jax.Array, params: jax.Array,
                    bn: int = 256, bm: int = 256,
                    interpret: bool = False) -> jax.Array:
    """d2u (D, Nr, Nc) f32, inner (Nr, Nc) f32 with Nr % bn == 0,
    Nc % bm == 0 (ops.py zero-pads); params (1, D+1) f32 =
    [1/l_1^2, ..., 1/l_D^2, sigma_f^2] (may be traced).

    Returns (Nr // bn, 128) f32 partial-sum rows; lanes 0..D-1 hold
    sum W ⊙ d2u[d], lane D holds sum W, lane D+1 holds tr(inner).
    """
    D, Nr, Nc = d2u.shape
    if D + 2 > LANES:
        raise ValueError(f"D={D} too large for one accumulator row")
    grid = (Nr // bn, Nc // bm)
    kernel = functools.partial(_nll_grad_kernel, bn=bn, bm=bm, D=D)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, D + 1), lambda i, j: (0, 0)),
            pl.BlockSpec((D, bn, bm), lambda i, j: (0, i, j)),
            pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, LANES), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Nr // bn, LANES), jnp.float32),
        interpret=interpret,
    )(params, d2u, inner)
