"""Pure-jnp oracles for every Pallas kernel (ground truth for allclose tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rbf_gram_ref(x1, x2, lengthscales, sigma_f, noise: float = 0.0):
    """sigma_f^2 exp(-sum_d (x1_d - x2_d)^2 / l_d^2) (+ noise^2 I if square).

    x1 (N, D), x2 (M, D) -> (N, M). Matches core.gp.kernel.se_kernel.
    """
    a = x1 / lengthscales
    b = x2 / lengthscales
    d2 = (jnp.sum(a * a, -1)[:, None] + jnp.sum(b * b, -1)[None, :]
          - 2.0 * a @ b.T)
    K = sigma_f**2 * jnp.exp(-jnp.maximum(d2, 0.0))
    if noise:
        n = min(x1.shape[0], x2.shape[0])
        K = K + noise**2 * jnp.eye(x1.shape[0], x2.shape[0], dtype=K.dtype)
    return K


def flash_attention_ref(q, k, v, causal: bool = True, scale: float | None = None,
                        window: int | None = None):
    """Reference attention. q (B,H,Sq,D), k/v (B,KH,Sk,D) with H % KH == 0.

    `window` enables sliding-window causal attention (keys within `window`
    positions behind the query). Query positions are right-aligned to the key
    timeline (decode: Sq=1 attends to the full cache).
    """
    B, H, Sq, D = q.shape
    KH, Sk = k.shape[1], k.shape[2]
    g = H // KH
    if scale is None:
        scale = 1.0 / jnp.sqrt(D).astype(q.dtype)
    k = jnp.repeat(k, g, axis=1)
    v = jnp.repeat(v, g, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    q_pos = jnp.arange(Sq) + (Sk - Sq)
    k_pos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask = mask & (k_pos[None, :] <= q_pos[:, None])
    if window is not None:
        mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    w = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bhkd->bhqd", w, v)


def rbf_matvec_ref(x1, x2, v, lengthscales, sigma_f):
    """k(X1, X2) @ v without the kernel (oracle materializes the Gram)."""
    return rbf_gram_ref(x1, x2, lengthscales, sigma_f) @ v


def nll_grad_fused_ref(log_theta, d2u, inner, K=None, bn: int = 256):
    """Fused trace-identity NLL gradient — blocked jnp mirror of nll_grad.py.

    d2u (D, N, N) is the once-per-fit UNSCALED diff^2 stack, inner (N, N)
    is C^-1 - alpha alpha^T. Returns dNLL/dlog_theta (D+2,) without ever
    materializing the (D+2, N, N) derivative stack of cov_grads: row blocks
    of size `bn` are streamed with lax.map (sequential => O(D * bn * N)
    transients at any N), each block contributing all D+2 components at
    once. `K` optionally reuses an already-materialized kernel matrix (the
    ADMM iteration built it for the Cholesky anyway); when absent, K is
    rebuilt blockwise from d2u — exactly what the Pallas kernel does in
    registers.

    Component algebra (the 2's of dC/dtheta cancel the identity's 0.5):
      d/dlog l_d    = sum W ⊙ d2u[d] / l_d^2        with W = inner ⊙ K
      d/dlog sf     = sum W
      d/dlog se     = sigma_eps^2 * tr(inner)
    """
    D, n = d2u.shape[0], d2u.shape[1]
    theta = jnp.exp(log_theta)
    ls, sigma_f, sigma_eps = theta[:-2], theta[-2], theta[-1]
    inv_l2 = 1.0 / ls**2
    tr = jnp.trace(inner)

    def block_sums(d2u_b, inner_b, K_b):
        if K_b is None:
            K_b = sigma_f**2 * jnp.exp(-jnp.einsum("d,dij->ij", inv_l2,
                                                   d2u_b))
        W = inner_b * K_b
        return jnp.concatenate([jnp.einsum("dij,ij->d", d2u_b, W),
                                jnp.sum(W)[None]])

    n_blocks = -(-n // bn)
    if n_blocks == 1:
        sums = block_sums(d2u, inner, K)
    else:
        pad = n_blocks * bn - n
        # zero-padded rows of `inner` null every contribution
        d2u_p = jnp.pad(d2u, ((0, 0), (0, pad), (0, 0)))
        inner_p = jnp.pad(inner, ((0, pad), (0, 0)))
        d2u_b = d2u_p.reshape(D, n_blocks, bn, n).transpose(1, 0, 2, 3)
        inner_b = inner_p.reshape(n_blocks, bn, n)
        if K is None:
            sums = jax.lax.map(lambda a: block_sums(a[0], a[1], None),
                               (d2u_b, inner_b))
        else:
            K_b = jnp.pad(K, ((0, pad), (0, 0))).reshape(n_blocks, bn, n)
            sums = jax.lax.map(lambda a: block_sums(*a),
                               (d2u_b, inner_b, K_b))
        sums = jnp.sum(sums, axis=0)
    return jnp.concatenate([sums[:D] * inv_l2, sums[D:D + 1],
                            (sigma_eps**2 * tr)[None]])


def cholupdate_ref(L, x, downdate: bool = False, bk: int = 128,
                   shift: int = 0):
    """Rank-1 Cholesky update/downdate: chol(L L^T + sign x x^T) in O(n^2).

    Blocked LINPACK column sweep (Givens rotations for the update,
    hyperbolic for the downdate), the jnp mirror of the Pallas panel
    schedule in cholupdate.py. Columns are processed in `bk`-wide panels
    over STATIC slices (the Python loop unrolls into the jit), each panel a
    lax.scan over its columns carrying only the rotated rank-1 vector. Two
    hot-path properties:

      panel skip — a panel whose x entries are all zero applies only
      identity rotations, so it is skipped behind a lax.cond without
      touching its columns. Callers exploit this: padding (identity
      diagonal, zero x) is provably untouched, and a rotation vector that
      is zero up to position p (evicting/inserting window slot p in
      core/online) only ever sweeps the trailing panels.

      maskless steps with deferred scaling — within a step, entries ABOVE
      the current column's diagonal are never read again by construction
      (step t reads x[t] and writes only information consumed at indices
      > t), so the sweep skips the tail masking entirely; the garbage it
      leaves lives only in the panel's top (b, b) triangle, zeroed with
      one small triu per panel. Each emitted column is kept UNSCALED (the
      1/c_t division is applied panel-wide after the scan), shaving one
      full vector pass per column off the hot loop.

    `shift=k` (static) runs the update on the trailing block L[k:, k:]
    with x[k:] and writes the result k slots up-left — the fused
    evict-the-oldest move of core/online's sliding window, for free: a
    panel's shifted destination covers only columns strictly left of every
    later panel's reads. Rows/cols n-k .. n-1 of the output hold stale
    values the caller must refresh (the sentinel row/column).

    Downdates assume L L^T - x x^T stays positive definite; the sqrt
    argument is clamped to the dtype tiny so a marginally indefinite
    downdate degrades instead of producing NaNs.
    """
    n = L.shape[0]
    sign = -1.0 if downdate else 1.0
    tiny = jnp.finfo(L.dtype).tiny

    for k0 in range(shift, n, bk):
        b = min(bk, n - k0)
        panel = L[k0:, k0:k0 + b]                          # (m, b) static
        xs = x[k0:]

        def process(args, b=b):
            panel, xs = args

            def step(xc, inp):
                t, col = inp
                Lkk = col[t]
                xk = xc[t]
                r = jnp.sqrt(jnp.maximum(Lkk * Lkk + sign * xk * xk, tiny))
                c = r / Lkk
                s = xk / Lkk
                u = (col + (sign * s) * xc).at[t].set(r * c)   # newcol * c
                xc = c * xc - (s / c) * u
                return xc, (u, c)

            xs, (cols, cs) = jax.lax.scan(step, xs, (jnp.arange(b), panel.T))
            cols = cols / cs[:, None]
            cols = cols.at[:, :b].set(jnp.triu(cols[:, :b]))
            return cols.T, xs

        panel, xs = jax.lax.cond(jnp.any(xs[:b] != 0.0), process,
                                 lambda args: args, (panel, xs))
        L = L.at[k0 - shift:n - shift, k0 - shift:k0 + b - shift].set(panel)
        x = x.at[k0:].set(xs)
    return L
