"""Pure-jnp oracles for every Pallas kernel (ground truth for allclose tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rbf_gram_ref(x1, x2, lengthscales, sigma_f, noise: float = 0.0):
    """sigma_f^2 exp(-sum_d (x1_d - x2_d)^2 / l_d^2) (+ noise^2 I if square).

    x1 (N, D), x2 (M, D) -> (N, M). Matches core.gp.kernel.se_kernel.
    """
    a = x1 / lengthscales
    b = x2 / lengthscales
    d2 = (jnp.sum(a * a, -1)[:, None] + jnp.sum(b * b, -1)[None, :]
          - 2.0 * a @ b.T)
    K = sigma_f**2 * jnp.exp(-jnp.maximum(d2, 0.0))
    if noise:
        n = min(x1.shape[0], x2.shape[0])
        K = K + noise**2 * jnp.eye(x1.shape[0], x2.shape[0], dtype=K.dtype)
    return K


def flash_attention_ref(q, k, v, causal: bool = True, scale: float | None = None,
                        window: int | None = None):
    """Reference attention. q (B,H,Sq,D), k/v (B,KH,Sk,D) with H % KH == 0.

    `window` enables sliding-window causal attention (keys within `window`
    positions behind the query). Query positions are right-aligned to the key
    timeline (decode: Sq=1 attends to the full cache).
    """
    B, H, Sq, D = q.shape
    KH, Sk = k.shape[1], k.shape[2]
    g = H // KH
    if scale is None:
        scale = 1.0 / jnp.sqrt(D).astype(q.dtype)
    k = jnp.repeat(k, g, axis=1)
    v = jnp.repeat(v, g, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    q_pos = jnp.arange(Sq) + (Sk - Sq)
    k_pos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask = mask & (k_pos[None, :] <= q_pos[:, None])
    if window is not None:
        mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    w = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bhkd->bhqd", w, v)


def rbf_matvec_ref(x1, x2, v, lengthscales, sigma_f):
    """k(X1, X2) @ v without the kernel (oracle materializes the Gram)."""
    return rbf_gram_ref(x1, x2, lengthscales, sigma_f) @ v
