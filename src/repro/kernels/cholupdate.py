"""Pallas TPU kernel: blocked rank-1 Cholesky update / downdate.

Given L lower-triangular with L L^T = A, computes L' with

  L' L'^T = A + sign * x x^T        (sign = +1 update, -1 downdate)

in O(n^2) — the streaming-GP primitive that replaces the O(n^3)
refactorization when an observation is appended to or evicted from an
agent's window (core/online). The column sweep is the LINPACK
Givens/hyperbolic-rotation recurrence; columns are processed in panels of
`bk` so each grid step owns one (n, bk) VMEM-resident panel while the
rotated rank-1 vector x is carried across panels in a VMEM scratch
accumulator (same sequential-grid + scratch-carry schedule as
rbf_matvec's accumulator).

Per column k:  r   = sqrt(L_kk^2 + sign * x_k^2)
               c,s = r / L_kk,  x_k / L_kk
               L'_{tail,k} = (L_{tail,k} + sign * s * x_tail) / c
               x_tail      = c * x_tail - s * L'_{tail,k}

Zero x_k leaves column k untouched (c=1, s=0), which ops.py exploits to
pad to tile-aligned shapes with an identity diagonal, and core/online
exploits to restrict the rotation to the trailing sub-block of a factor.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(sign_ref, x_ref, l_ref, out_ref, x_acc, *, bk, n):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        x_acc[...] = x_ref[...]

    sign = sign_ref[0, 0]
    panel = l_ref[...]                                   # (n, bk)
    x = x_acc[...]                                       # (n, 1)
    rows = jax.lax.broadcasted_iota(jnp.int32, (n, 1), 0)

    def body(t, carry):
        panel, x = carry
        k = j * bk + t
        col = jax.lax.dynamic_slice_in_dim(panel, t, 1, axis=1)   # (n, 1)
        at_k = (rows == k).astype(panel.dtype)
        Lkk = jnp.sum(col * at_k)
        xk = jnp.sum(x * at_k)
        r = jnp.sqrt(jnp.maximum(Lkk * Lkk + sign * xk * xk, 1e-30))
        c = r / Lkk
        s = xk / Lkk
        below = rows > k
        newcol = jnp.where(below, (col + sign * s * x) / c, col)
        newcol = jnp.where(rows == k, r, newcol)
        x = jnp.where(below, c * x - s * newcol, x)
        panel = jax.lax.dynamic_update_slice_in_dim(panel, newcol, t, axis=1)
        return panel, x

    panel, x = jax.lax.fori_loop(0, bk, body, (panel, x))
    out_ref[...] = panel
    x_acc[...] = x


def cholupdate_pallas(L, x, sign, bk: int = 128, interpret: bool = False):
    """L (n, n) float32 lower-triangular, x (n,), n % bk == 0 (ops.py pads
    with a unit diagonal). Returns the updated factor (n, n) float32."""
    n = L.shape[0]
    params = jnp.asarray(sign, jnp.float32).reshape(1, 1)
    out = pl.pallas_call(
        functools.partial(_kernel, bk=bk, n=n),
        grid=(n // bk,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda j: (0, 0)),
            pl.BlockSpec((n, 1), lambda j: (0, 0)),
            pl.BlockSpec((n, bk), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((n, bk), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((n, 1), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(params, x.reshape(n, 1).astype(jnp.float32), L)
    return out
