"""Pallas TPU kernel: blocked flash attention (online softmax) with GQA,
causal and sliding-window masking.

The transformer-side hot-spot for the assigned architectures. Classic
FlashAttention-2 TPU schedule:
  grid = (B, H, Sq/BQ, Sk/BK), dims (parallel, parallel, parallel, arbitrary)
  scratch: VMEM accumulators acc (BQ, D) f32, m and l (BQ,) f32 carried
  across the KV (innermost, sequential) grid dimension.
GQA is handled in the KV BlockSpec index_map (kv head = q head // group) so
grouped KV is never materialized at H heads.

VMEM per step ~= BQ*D(q) + BK*D(k) + BK*D(v) + BQ*BK(logits) + BQ*D(acc),
with BQ=BK=256, D=128: ~0.7 MB f32 — well inside the ~16 MB/core budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: int | None,
                  bq: int, bk: int, sq: int, sk: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)              # (BQ, D)
    k = k_ref[0, 0].astype(jnp.float32)              # (BK, D)
    v = v_ref[0, 0].astype(jnp.float32)              # (BK, D)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    # global positions; queries right-aligned to the key timeline
    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) \
        + (sk - sq)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window is not None:
        mask = mask & (k_pos > q_pos - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    correction = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_ref[...] = l_ref[...] * correction + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * correction[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_cur

    @pl.when(ik == nk - 1)
    def _final():
        o_ref[0, 0] = (acc_ref[...] / l_ref[...][:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, causal: bool = True,
                           scale: float | None = None,
                           window: int | None = None,
                           bq: int = 256, bk: int = 256,
                           interpret: bool = False):
    """q (B,H,Sq,D), k/v (B,KH,Sk,D), H % KH == 0; Sq % bq == Sk % bk == 0
    (ops.py pads). Returns (B,H,Sq,D) in q.dtype."""
    B, H, Sq, D = q.shape
    KH, Sk = k.shape[1], k.shape[2]
    g = H // KH
    if scale is None:
        scale = 1.0 / float(D) ** 0.5
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    grid = (B, H, Sq // bq, Sk // bk)
    kernel = functools.partial(
        _flash_kernel, scale=float(scale), causal=causal, window=window,
        bq=bq, bk=bk, sq=Sq, sk=Sk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, iq, ik: (b, h // g, ik, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, iq, ik: (b, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),   # acc
            pltpu.VMEM((bq,), jnp.float32),     # m (running max)
            pltpu.VMEM((bq,), jnp.float32),     # l (running denom)
        ],
        interpret=interpret,
    )(q, k, v)
