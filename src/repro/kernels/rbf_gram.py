"""Pallas TPU kernel: tiled RBF (separable squared-exponential) Gram matrix.

The paper's compute hot-spot: every NLL/gradient evaluation and every local
prediction builds k(X, X') — O(N^2 D) work feeding O(N^3) factorizations.

TPU adaptation (DESIGN.md §2): the distance matrix is computed via the
||a||^2 + ||b||^2 - 2 a b^T expansion so the dominant term is a (BN, D) x
(D, BM) matmul on the MXU; tiles are 128-aligned to match MXU/VREG lanes and
sized so (a_tile, b_tile, out_tile) fit comfortably in VMEM:
  default BN = BM = 256, D padded to a multiple of 8 —
  VMEM footprint = 2*256*Dp*4 + 256*256*4 ~= 0.8 MB for D <= 64.

Inputs arrive pre-scaled by 1/lengthscale (done in ops.py — O(ND), fused by
XLA); the kernel computes sigma_f^2 exp(-d2) and adds noise^2 on the global
diagonal (grid-position aware).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rbf_gram_kernel(params_ref, a_ref, b_ref, out_ref, *, bn: int, bm: int,
                     with_noise: bool):
    i = pl.program_id(0)
    j = pl.program_id(1)
    sigma_f2 = params_ref[0, 0]
    a = a_ref[...]                                   # (BN, Dp) f32
    b = b_ref[...]                                   # (BM, Dp) f32
    an = jnp.sum(a * a, axis=1)                      # (BN,)
    bn_ = jnp.sum(b * b, axis=1)                     # (BM,)
    ab = jax.lax.dot_general(a, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # MXU
    d2 = jnp.maximum(an[:, None] + bn_[None, :] - 2.0 * ab, 0.0)
    k = sigma_f2 * jnp.exp(-d2)
    if with_noise:
        noise2 = params_ref[0, 1]
        rows = i * bn + jax.lax.broadcasted_iota(jnp.int32, (bn, bm), 0)
        cols = j * bm + jax.lax.broadcasted_iota(jnp.int32, (bn, bm), 1)
        k = jnp.where(rows == cols, k + noise2, k)
    out_ref[...] = k


def rbf_gram_pallas(a_scaled: jax.Array, b_scaled: jax.Array, sigma_f2,
                    noise2=0.0, with_noise: bool = False, bn: int = 256,
                    bm: int = 256, interpret: bool = False) -> jax.Array:
    """a_scaled (N, Dp), b_scaled (M, Dp) pre-scaled by 1/l; N % bn == 0,
    M % bm == 0 (ops.py pads). sigma_f2/noise2 may be traced scalars.
    Returns (N, M) float32."""
    N, Dp = a_scaled.shape
    M = b_scaled.shape[0]
    grid = (N // bn, M // bm)
    params = jnp.stack([jnp.asarray(sigma_f2, jnp.float32),
                        jnp.asarray(noise2, jnp.float32)]).reshape(1, 2)
    kernel = functools.partial(_rbf_gram_kernel, bn=bn, bm=bm,
                               with_noise=with_noise)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 2), lambda i, j: (0, 0)),
            pl.BlockSpec((bn, Dp), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, Dp), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((N, M), jnp.float32),
        interpret=interpret,
    )(params, a_scaled, b_scaled)
