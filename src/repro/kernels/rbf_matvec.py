"""Pallas TPU kernel: fused RBF Gram-matrix x vector product.

Computes  out = k(X1, X2) @ v  WITHOUT materializing the (N, M) Gram matrix —
the streaming form of the paper's prediction mean k_*^T (C^-1 y): once the
training solve caches alpha = C^-1 y, every prediction batch is a fused
Gram-matvec with O(N + M) memory instead of O(N*M). Flash-attention-style
schedule: grid (N/BN, M/BM) with the M dimension sequential, accumulating
into a VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(params_ref, a_ref, b_ref, v_ref, out_ref, acc_ref, *, bn, bm):
    j = pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    sigma_f2 = params_ref[0, 0]
    a = a_ref[...]                                   # (BN, Dp)
    b = b_ref[...]                                   # (BM, Dp)
    v = v_ref[...]                                   # (BM, 1)
    an = jnp.sum(a * a, axis=1)
    bn_ = jnp.sum(b * b, axis=1)
    ab = jax.lax.dot_general(a, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    d2 = jnp.maximum(an[:, None] + bn_[None, :] - 2.0 * ab, 0.0)
    k = sigma_f2 * jnp.exp(-d2)                      # (BN, BM)
    acc_ref[...] += jax.lax.dot_general(
        k, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(j == nj - 1)
    def _done():
        out_ref[...] = acc_ref[...]


def rbf_matvec_pallas(a_scaled, b_scaled, v, sigma_f2, bn: int = 256,
                      bm: int = 256, interpret: bool = False):
    """a_scaled (N, Dp), b_scaled (M, Dp) pre-scaled by 1/l; v (M,).
    N % bn == 0, M % bm == 0 (ops.py pads). Returns (N,) float32."""
    N, Dp = a_scaled.shape
    M = b_scaled.shape[0]
    params = jnp.asarray(sigma_f2, jnp.float32).reshape(1, 1)
    out = pl.pallas_call(
        functools.partial(_kernel, bn=bn, bm=bm),
        grid=(N // bn, M // bm),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((bn, Dp), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, Dp), lambda i, j: (j, 0)),
            pl.BlockSpec((bm, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bn, 1), jnp.float32)],
        interpret=interpret,
    )(params, a_scaled, b_scaled, v.reshape(M, 1).astype(jnp.float32))
    return out[:, 0]
