"""String-keyed registries for the fleet lifecycle: trainers and methods.

One table per axis of the paper:

  TRAINERS — the 6 ADMM training loops of §4 (plus FACT-GP and the sharded
  eq. 34 execution mode), each behind a UNIFORM adapter
  `spec.run(cfg, log_theta0, Xp, yp, A, mesh=None, grad_fn=None, diag=False)
      -> (log_theta (K,), thetas (M, K), info)`
  that forwards the FleetConfig's ADMM parameters to the legacy loop
  unchanged (facade-trained theta is bitwise the legacy theta). `diag=True`
  threads the loops' per-iteration diagnostics capture (primal/dual
  residuals, per-agent NLL, theta trajectories) into info["diagnostics"]
  for `repro.obs.TraceRecorder` — see GPFleet.fit(trace=...).

  METHODS — the 13 decentralized prediction methods of §5 plus the low-rank
  `npae_sparse` serving path (core.sparse), with per-entry CAPABILITY flags:
    shardable             servable by ShardedEngine (DAC family; the dense
                          NPAE family needs strongly-complete exchange —
                          its low-rank counterpart npae_sparse DOES shard)
    routable              servable by CBNN query routing (nn_* DAC methods)
    online_safe           accepts `OnlineExperts.to_fitted()` hot-swaps
                          (grbcm variants need separately refit augmented /
                          communication experts the online path does not
                          maintain)
    needs_augmented_data  requires the grBCM communication dataset
                          (fitted_aug + fitted_comm, paper eq. 16-17)
    sparse                servable from sparse pseudo-representation experts
                          (FleetConfig(sparse_m=...), core.sparse): every
                          moment-based method is; the dense NPAE trio needs
                          the O(Ni) per-agent factors it compresses away
  plus `spec.legacy(...)`, the original per-call free function, and
  `spec.legacy_call(cfg, ...)`, a uniform adapter over its signature — so
  engine dispatch, CLI choices, capability validation, and the equivalence
  test suite all derive from THIS table instead of hard-coded lists.

Registry completeness against the engines (`PredictionEngine.METHODS`,
`ShardedEngine.METHODS`) is asserted by tests/test_fleet.py: a method added
to an engine without a registry entry — or vice versa — fails the suite.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax.numpy as jnp

from ..core.prediction import decentralized as dec
from ..core.sparse import (dec_npae_sparse, make_sparse_grad,
                           select_inducing, train_fact_sparse)
from ..core.training import (train_apx_gp, train_c_gp, train_dec_apx_gp,
                             train_dec_apx_gp_sharded, train_dec_c_gp,
                             train_dec_gapx_gp, train_fact_gp, train_gapx_gp)

# ---------------------------------------------------------------------------
# Trainers
# ---------------------------------------------------------------------------


class TrainerSpec(NamedTuple):
    """One registered training loop.

    `run` is the uniform adapter (see module docstring); `needs_graph`
    trainers consume the consensus adjacency, `needs_mesh` trainers run
    under shard_map on a device mesh, `needs_augmented_data` trainers expect
    (Xp, yp) to already be the augmented datasets D_{+i}.
    """
    name: str
    run: Callable
    paper: str
    needs_graph: bool = False
    needs_mesh: bool = False
    needs_augmented_data: bool = False


def _run_fact(cfg, lt0, Xp, yp, A, mesh=None, grad_fn=None, diag=False):
    # FACT-GP's full NLL history is already its diagnostic; diag is a no-op
    lt, vals = train_fact_gp(lt0, Xp, yp, steps=cfg.fact_steps,
                             lr=cfg.fact_lr)
    M = Xp.shape[0]
    return lt, jnp.broadcast_to(lt, (M, lt.shape[0])), {"nll": vals}


def _run_c(cfg, lt0, Xp, yp, A, mesh=None, grad_fn=None, diag=False):
    z, thetas, hist = train_c_gp(lt0, Xp, yp, rho=cfg.rho,
                                 iters=cfg.admm_iters,
                                 nested_iters=cfg.nested_iters,
                                 nested_lr=cfg.nested_lr, grad_fn=grad_fn,
                                 diag=diag)
    return z, thetas, hist


def _run_apx(cfg, lt0, Xp, yp, A, mesh=None, grad_fn=None, diag=False):
    z, thetas, hist = train_apx_gp(lt0, Xp, yp, rho=cfg.rho,
                                   L=cfg.lipschitz, iters=cfg.admm_iters,
                                   grad_fn=grad_fn, diag=diag)
    return z, thetas, hist


def _run_gapx(cfg, lt0, Xp, yp, A, mesh=None, grad_fn=None, diag=False):
    z, thetas, hist = train_gapx_gp(lt0, Xp, yp, rho=cfg.rho,
                                    L=cfg.lipschitz, iters=cfg.admm_iters,
                                    grad_fn=grad_fn, diag=diag)
    return z, thetas, hist


def _run_dec_c(cfg, lt0, Xp, yp, A, mesh=None, grad_fn=None, diag=False):
    thetas, info = train_dec_c_gp(lt0, Xp, yp, A, rho=cfg.rho,
                                  iters=cfg.admm_iters,
                                  nested_iters=cfg.nested_iters,
                                  nested_lr=cfg.nested_lr, grad_fn=grad_fn,
                                  diag=diag)
    return jnp.mean(thetas, axis=0), thetas, info


def _run_dec_apx(cfg, lt0, Xp, yp, A, mesh=None, grad_fn=None,
                 diag=False):
    thetas, info = train_dec_apx_gp(lt0, Xp, yp, A, rho=cfg.rho,
                                    kappa=cfg.kappa, iters=cfg.admm_iters,
                                    grad_fn=grad_fn, diag=diag)
    return jnp.mean(thetas, axis=0), thetas, info


def _run_dec_gapx(cfg, lt0, Xp, yp, A, mesh=None, grad_fn=None,
                  diag=False):
    thetas, info = train_dec_gapx_gp(lt0, Xp, yp, A, rho=cfg.rho,
                                     kappa=cfg.kappa, iters=cfg.admm_iters,
                                     grad_fn=grad_fn, diag=diag)
    return jnp.mean(thetas, axis=0), thetas, info


def _run_dec_apx_sharded(cfg, lt0, Xp, yp, A, mesh=None, grad_fn=None,
                         diag=False):
    # the sharded loop has no separate diag mode: its residuals series is
    # always captured on-device (satellite cost: one pmean/pmax per round)
    M = Xp.shape[0]
    if mesh is None:
        from ..launch.mesh import make_agent_mesh
        mesh = make_agent_mesh(M, max_devices=cfg.max_shard_devices)
    ndev = int(mesh.shape["agents"])
    if ndev != M:
        raise ValueError(
            f"trainer 'dec-apx-sharded' runs ONE agent per mesh member "
            f"(cycle graph over the device ring) but the mesh has {ndev} "
            f"device(s) for {M} agents; use trainer 'dec-apx' (simulated "
            f"mode, any device count) or provide an {M}-device mesh")
    thetas, info = train_dec_apx_gp_sharded(mesh, "agents", lt0, Xp, yp,
                                            rho=cfg.rho, kappa=cfg.kappa,
                                            iters=cfg.admm_iters,
                                            grad_fn=grad_fn)
    return jnp.mean(thetas, axis=0), thetas, info


def _run_fact_sparse(cfg, lt0, Xp, yp, A, mesh=None, grad_fn=None,
                     diag=False):
    # collapsed-ELBO FACT counterpart: joint Adam over (theta, Z); the
    # optimized inducing sets ride info["Z"] so GPFleet caches the sparse
    # factors from the SAME Z the bound was tightened over
    Z0 = select_inducing(Xp, cfg.sparse_m, cfg.inducing_init)
    lt, Z, vals = train_fact_sparse(lt0, Xp, yp, Z0, steps=cfg.fact_steps,
                                    lr=cfg.fact_lr, jitter=cfg.jitter)
    M = Xp.shape[0]
    return lt, jnp.broadcast_to(lt, (M, lt.shape[0])), {"nll": vals, "Z": Z}


def _run_dec_apx_sparse(cfg, lt0, Xp, yp, A, mesh=None, grad_fn=None,
                        diag=False):
    # eq. 34 ADMM with the O(Ni m^2) collapsed-ELBO local gradient swapped
    # in through the SAME grad_fn hook custom kernels use — warm-startable
    # from exact ADMM theta by passing that theta as lt0
    if grad_fn is None:
        grad_fn = make_sparse_grad(cfg.sparse_m, jitter=cfg.jitter)
    thetas, info = train_dec_apx_gp(lt0, Xp, yp, A, rho=cfg.rho,
                                    kappa=cfg.kappa, iters=cfg.admm_iters,
                                    grad_fn=grad_fn, diag=diag)
    return jnp.mean(thetas, axis=0), thetas, info


TRAINERS: dict[str, TrainerSpec] = {s.name: s for s in (
    TrainerSpec("fact", _run_fact, "§2.3.1 (FACT-GP baseline)"),
    TrainerSpec("c", _run_c, "eq. 24"),
    TrainerSpec("apx", _run_apx, "eq. 26"),
    TrainerSpec("gapx", _run_gapx, "Alg. 1", needs_augmented_data=True),
    TrainerSpec("dec-c", _run_dec_c, "eq. 30", needs_graph=True),
    TrainerSpec("dec-apx", _run_dec_apx, "eq. 34 (Thm. 1)",
                needs_graph=True),
    TrainerSpec("dec-gapx", _run_dec_gapx, "Alg. 4", needs_graph=True,
                needs_augmented_data=True),
    TrainerSpec("dec-apx-sharded", _run_dec_apx_sharded,
                "eq. 34 under shard_map (device-ring cycle graph)",
                needs_mesh=True),
    TrainerSpec("fact-sparse", _run_fact_sparse,
                "§2.3.1 x Titsias 2009 (collapsed ELBO, joint theta + Z)"),
    TrainerSpec("dec-apx-sparse", _run_dec_apx_sparse,
                "eq. 34 with the collapsed-ELBO O(Ni m^2) local gradient",
                needs_graph=True),
)}

SPARSE_TRAINERS = ("fact-sparse", "dec-apx-sparse")


def trainer_names() -> tuple[str, ...]:
    return tuple(TRAINERS)


def get_trainer(name: str) -> TrainerSpec:
    spec = TRAINERS.get(name)
    if spec is None:
        raise KeyError(f"unknown trainer {name!r}; registered trainers: "
                       f"{sorted(TRAINERS)}")
    return spec


# ---------------------------------------------------------------------------
# Prediction methods
# ---------------------------------------------------------------------------


class MethodSpec(NamedTuple):
    """One registered prediction method (see module docstring for flags).

    `legacy` is the original per-call free function (reference semantics);
    `legacy_call(cfg, log_theta, Xp, yp, Xs, A, Xc, yc, Xa, ya)` invokes it
    with the FleetConfig's consensus parameters — the uniform signature the
    equivalence tests and `--compare-uncached` use.
    """
    name: str
    paper: str
    family: str                       # "dac" | "npae" | "sparse"
    legacy: Callable
    legacy_call: Callable
    shardable: bool = False
    routable: bool = False
    online_safe: bool = True
    needs_augmented_data: bool = False
    # servable from sparse pseudo-representation experts (sparse_m fleets):
    # every moment/score-based method is; the dense NPAE trio is not
    sparse: bool = True
    # largest query-batch slot a serving scheduler should compile for this
    # method: the NPAE family's per-query (M, M) solves make big batches
    # memory-heavy, the DAC family tiles flat in the batch size
    max_slot: int = 1024


def _call_dac(fn):
    def call(cfg, lt, Xp, yp, Xs, A, Xc=None, yc=None, Xa=None, ya=None):
        return fn(lt, Xp, yp, Xs, A, iters=cfg.dac_iters)
    return call


def _call_grbcm(cfg, lt, Xp, yp, Xs, A, Xc=None, yc=None, Xa=None, ya=None):
    return dec.dec_grbcm(lt, Xa, ya, Xc, yc, Xs, A, iters=cfg.dac_iters)


def _call_npae(cfg, lt, Xp, yp, Xs, A, Xc=None, yc=None, Xa=None, ya=None):
    return dec.dec_npae(lt, Xp, yp, Xs, A, jor_iters=cfg.jor_iters,
                        dac_iters=cfg.dac_iters, jitter=cfg.npae_jitter)


def _call_npae_star(cfg, lt, Xp, yp, Xs, A, Xc=None, yc=None, Xa=None,
                    ya=None):
    return dec.dec_npae_star(lt, Xp, yp, Xs, A, jor_iters=cfg.jor_iters,
                             dac_iters=cfg.dac_iters, pm_iters=cfg.pm_iters,
                             jitter=cfg.npae_jitter)


def _call_nn(fn):
    def call(cfg, lt, Xp, yp, Xs, A, Xc=None, yc=None, Xa=None, ya=None):
        return fn(lt, Xp, yp, Xs, A, cfg.eta_nn, iters=cfg.dac_iters)
    return call


def _call_nn_grbcm(cfg, lt, Xp, yp, Xs, A, Xc=None, yc=None, Xa=None,
                   ya=None):
    return dec.dec_nn_grbcm(lt, Xa, ya, Xc, yc, Xs, A, cfg.eta_nn,
                            iters=cfg.dac_iters, Xp=Xp)


def _call_nn_npae(cfg, lt, Xp, yp, Xs, A, Xc=None, yc=None, Xa=None,
                  ya=None):
    return dec.dec_nn_npae(lt, Xp, yp, Xs, A, cfg.eta_nn,
                           dale_iters=cfg.dale_iters,
                           jitter=cfg.npae_jitter)


def _call_npae_sparse(cfg, lt, Xp, yp, Xs, A, Xc=None, yc=None, Xa=None,
                      ya=None):
    return dec_npae_sparse(lt, Xp, yp, Xs, cfg.sparse_m,
                           inducing_init=cfg.inducing_init,
                           jitter=cfg.jitter, npae_jitter=cfg.npae_jitter)


METHODS: dict[str, MethodSpec] = {s.name: s for s in (
    MethodSpec("poe", "Alg. 5, eq. 12-13", "dac", dec.dec_poe,
               _call_dac(dec.dec_poe), shardable=True),
    MethodSpec("gpoe", "Alg. 6, eq. 12-13", "dac", dec.dec_gpoe,
               _call_dac(dec.dec_gpoe), shardable=True),
    MethodSpec("bcm", "Alg. 7, eq. 14-15", "dac", dec.dec_bcm,
               _call_dac(dec.dec_bcm), shardable=True),
    MethodSpec("rbcm", "Alg. 8, eq. 14-15", "dac", dec.dec_rbcm,
               _call_dac(dec.dec_rbcm), shardable=True),
    MethodSpec("grbcm", "Alg. 9, eq. 16-17", "dac", dec.dec_grbcm,
               _call_grbcm, shardable=True, online_safe=False,
               needs_augmented_data=True),
    MethodSpec("npae", "Alg. 10, eq. 18-21", "npae", dec.dec_npae,
               _call_npae, max_slot=256, sparse=False),
    MethodSpec("npae_star", "Alg. 11-12 (PM omega*)", "npae",
               dec.dec_npae_star, _call_npae_star, max_slot=256,
               sparse=False),
    MethodSpec("nn_poe", "Alg. 13, eq. 39", "dac", dec.dec_nn_poe,
               _call_nn(dec.dec_nn_poe), shardable=True, routable=True),
    MethodSpec("nn_gpoe", "Alg. 14, eq. 39", "dac", dec.dec_nn_gpoe,
               _call_nn(dec.dec_nn_gpoe), shardable=True, routable=True),
    MethodSpec("nn_bcm", "Alg. 15, eq. 39", "dac", dec.dec_nn_bcm,
               _call_nn(dec.dec_nn_bcm), shardable=True, routable=True),
    MethodSpec("nn_rbcm", "Alg. 16, eq. 39", "dac", dec.dec_nn_rbcm,
               _call_nn(dec.dec_nn_rbcm), shardable=True, routable=True),
    MethodSpec("nn_grbcm", "Alg. 17, eq. 39", "dac", dec.dec_nn_grbcm,
               _call_nn_grbcm, shardable=True, routable=True,
               online_safe=False, needs_augmented_data=True),
    MethodSpec("nn_npae", "Alg. 18, eq. 39", "npae", dec.dec_nn_npae,
               _call_nn_npae, max_slot=256, sparse=False),
    MethodSpec("npae_sparse", "Alg. 10 from Titsias low-rank factors "
               "(core.sparse.lowrank)", "sparse", dec_npae_sparse,
               _call_npae_sparse, shardable=True, online_safe=False,
               max_slot=256),
)}


def method_names() -> tuple[str, ...]:
    return tuple(METHODS)


def get_method(name: str) -> MethodSpec:
    # CLI convention writes method names with hyphens ("npae-sparse");
    # registry keys are the engine dispatch names (underscores)
    spec = METHODS.get(name.replace("-", "_"))
    if spec is None:
        raise KeyError(f"unknown prediction method {name!r}; registered "
                       f"methods: {sorted(METHODS)}")
    return spec


# ---------------------------------------------------------------------------
# Capability validation (GPFleet construction and the serve_gp CLI)
# ---------------------------------------------------------------------------


def validate_config(cfg) -> None:
    """Reject capability-invalid FleetConfig combinations with a clear
    error (instead of the shape crash / silent drift they used to cause)."""
    get_trainer(cfg.trainer)
    spec = get_method(cfg.method)
    if cfg.routed and not cfg.sharded:
        raise ValueError("routed serving runs on the sharded fleet; set "
                         "sharded=True (or drop routed)")
    if cfg.sharded and not spec.shardable:
        shardable = sorted(n for n, s in METHODS.items() if s.shardable)
        raise ValueError(
            f"method {cfg.method!r} ({spec.family} family) is not servable "
            f"on the agent-sharded engine — the dense NPAE family needs "
            f"strongly-complete exchange and stays replicated; its low-rank "
            f"counterpart 'npae_sparse' (FleetConfig(sparse_m=...)) does "
            f"shard. Shardable methods: {shardable}")
    if cfg.routed and not spec.routable:
        routable = sorted(n for n, s in METHODS.items() if s.routable)
        raise ValueError(
            f"method {cfg.method!r} is not servable by CBNN query routing; "
            f"routable methods: {routable}")
    if cfg.online and not spec.online_safe:
        raise ValueError(
            f"method {cfg.method!r} is not online-safe: the streaming path "
            f"maintains base experts only, and grbcm variants need "
            f"separately refit augmented/communication experts")
    if cfg.sharded and cfg.cache_cross:
        raise ValueError("the NPAE cross-Gram cache (cache_cross=True) has "
                         "no agent-sharded layout; drop one of the two")
    # -- sparse pseudo-representation rules ---------------------------------
    if cfg.trainer in SPARSE_TRAINERS and cfg.sparse_m is None:
        raise ValueError(
            f"trainer {cfg.trainer!r} fits sparse pseudo-representation "
            f"experts and needs the per-agent inducing count: set "
            f"FleetConfig(sparse_m=...)")
    if spec.family == "sparse" and cfg.sparse_m is None:
        raise ValueError(
            f"method {cfg.method!r} serves from sparse pseudo-"
            f"representation experts; set FleetConfig(sparse_m=...)")
    if cfg.sparse_m is not None:
        if not spec.sparse:
            ok = sorted(n for n, s in METHODS.items() if s.sparse)
            raise ValueError(
                f"method {cfg.method!r} needs the dense O(Ni) per-agent "
                f"factors and cannot serve from sparse pseudo-"
                f"representation experts (sparse_m={cfg.sparse_m}); "
                f"sparse-capable methods: {ok}")
        if cfg.online:
            raise ValueError(
                "sparse_m and online are mutually exclusive: the sliding-"
                "window path maintains dense rank-1 Cholesky updates, not "
                "inducing-point statistics")
        if cfg.cache_cross:
            raise ValueError(
                "cache_cross caches the dense NPAE cross-Gram; sparse "
                "fleets never need it — npae_sparse assembles the cross-"
                "covariance from low-rank factors (docs/sparse_experts.md)")
