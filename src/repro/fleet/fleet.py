"""GPFleet: the single agent-facing facade for the whole fleet lifecycle.

    cfg = FleetConfig(num_agents=8, trainer="dec-apx", method="rbcm")
    fleet = GPFleet(cfg).fit(Xp, yp)        # ADMM training + factor caching
    mean, var, info = fleet.predict(Xs)     # jit-cached, query-tiled serving
    fleet.save("ckpt/")                     # fitted factors + config + graph
    ...
    fleet = GPFleet.load("ckpt/")           # fresh process: serve WITHOUT
    mean2, var2, _ = fleet.predict(Xs)      # refitting, bit-identical

Lifecycle verbs and the subsystems they drive (all pre-existing — the
facade adds dispatch and state management, never new numerics):

  fit()        trainer registry -> the §4 ADMM loops -> `fit_experts`
               (grBCM communication/augmented datasets built when the
               trainer or method needs them; `config.sparse_m` caches
               O(Ni m^2) sparse pseudo-representations — `core.sparse` —
               instead of the dense O(Ni^2) factors)
  predict()    method registry -> `PredictionEngine` (replicated) /
               `ShardedEngine` (agent-sharded mesh; `predict_routed` when
               config.routed) — compiled programs cached per method
  observe()    `core.online` sliding-window experts: O(W^2) rank-1 factor
               updates hot-swapped into the engine, zero recompiles
  join()/leave()  dynamic membership: window state + consensus graph +
               engine rewire in one step
  shard()      move a fitted fleet onto the agent-sharded engine in place
  save()/load()   `checkpoint.io` round trip of FittedExperts + FleetConfig
               + consensus graph (+ online window state)
  to_server()  a one-tenant `launch.scheduler.ServingScheduler` over this
               fleet (continuous slot batching; `submit`/`stats` keep the
               v1 FrontDoor surface). Multi-tenant serving registers many
               fleets on one scheduler via `ServingScheduler.add_fleet`.
  metrics()    `repro.obs` default-registry snapshot + a fleet-shape block
               (docs/observability.md); `fit(trace=TraceRecorder())`
               records per-iteration training diagnostics the same way.

Capability validation happens at CONSTRUCTION (fleet/registry.py
`validate_config`): a sharded NPAE-family fleet or a routed non-nn_* fleet
is rejected with a clear error before any array work.
"""
from __future__ import annotations

import json
import os
from functools import partial

import jax
import jax.numpy as jnp

from ..checkpoint.io import restore, save_checkpoint
from ..obs import default_registry
from ..core.consensus import (complete_graph, connected_components,
                              cycle_graph, is_connected, path_graph,
                              random_connected_graph)
from ..core.gp import augment, communication_dataset, pack
from ..core.online import (OnlineExperts, from_batch, join, leave,
                           observe_fleet, refit)
from ..core.prediction import (FittedExperts, PredictionEngine, ShardedEngine,
                               fit_experts)
from ..core.sparse import (SparseExperts, fit_sparse_experts,
                           select_inducing)
from ..launch.scheduler import ServingScheduler
from .config import FleetConfig
from .registry import get_method, get_trainer, validate_config

_FLEET_MANIFEST = "fleet.json"
_FORMAT_VERSION = 1


class FleetDegraded(RuntimeError):
    """A prediction came back in DEGRADED mode (dropped agents, network
    partition, scrubbed payloads) and the caller did not opt in with
    `predict(..., allow_degraded=True)`. The degradation census is on
    `.info`; the (finite, flagged) result itself is on `.result`."""

    def __init__(self, message: str, info: dict | None = None,
                 result=None):
        super().__init__(message)
        self.info = info or {}
        self.result = result


def _build_graph(cfg: FleetConfig):
    if cfg.graph == "path":
        return path_graph(cfg.num_agents)
    if cfg.graph == "cycle":
        return cycle_graph(cfg.num_agents)
    if cfg.graph == "complete":
        return complete_graph(cfg.num_agents)
    return random_connected_graph(cfg.num_agents, cfg.graph_p,
                                  seed=cfg.graph_seed)


class GPFleet:
    """Config-driven facade over training, serving, streaming, persistence.

    Construction validates the config against the registries and builds the
    consensus graph; `fit` (or `load`) populates the fitted state; every
    serving verb dispatches through the lazily built engine. The underlying
    engines/free functions remain public — the facade is sugar plus
    lifecycle glue, not a wall.
    """

    def __init__(self, config: FleetConfig | None = None, *, A=None,
                 mesh=None):
        cfg = config if config is not None else FleetConfig()
        validate_config(cfg)
        self.config = cfg
        self.A = A if A is not None else _build_graph(cfg)
        if self.A.shape[0] != cfg.num_agents:
            raise ValueError(f"adjacency for {self.A.shape[0]} agents vs "
                             f"config.num_agents={cfg.num_agents}")
        self.mesh = mesh
        # fitted state (populated by fit / load)
        self.log_theta = None          # consensus hyperparameters (K,)
        self.thetas = None             # per-agent trained thetas (M, K)
        self.train_info = None
        self.fitted: FittedExperts | None = None
        self.fitted_aug: FittedExperts | None = None
        self.fitted_comm: FittedExperts | None = None
        self._online_state: OnlineExperts | None = None
        self._comm_data = None         # (Xc, yc, Xa, ya) when built
        self._engine = None
        self._ingest = None
        self._last_degraded = None     # census of the last degraded predict

    # -- properties ----------------------------------------------------------

    @property
    def num_agents(self) -> int:
        return self.config.num_agents

    @property
    def is_fitted(self) -> bool:
        return self.fitted is not None

    @property
    def window_counts(self):
        """(M,) real observations per agent's sliding window, or None for
        batch (non-online) fleets."""
        return None if self._online_state is None \
            else self._online_state.count

    @property
    def engine(self):
        """The serving engine (built on first use, cached until the fleet
        changes shape: refit, shard, rewire)."""
        if self._engine is None:
            self._engine = self._build_engine()
        return self._engine

    def _require_fitted(self, verb: str):
        if self.fitted is None:
            raise RuntimeError(f"{verb} needs a fitted fleet — call fit() "
                               f"or load() first")

    # -- fit -----------------------------------------------------------------

    def _needs_comm_data(self, train: bool) -> bool:
        """Communication/augmented datasets are built only when consumed:
        by an augmented-data trainer that will actually run, or by a
        grbcm-family serving method."""
        return ((train and get_trainer(self.config.trainer)
                 .needs_augmented_data)
                or get_method(self.config.method).needs_augmented_data)

    def _build_comm_data(self, Xp, yp, key):
        Xc, yc = communication_dataset(key, Xp, yp)
        Xa, ya = augment(Xp, yp, Xc, yc)
        self._comm_data = (Xc, yc, Xa, ya)
        return self._comm_data

    def fit(self, Xp, yp, *, key=None, log_theta0=None, grad_fn=None,
            train: bool = True, trace=None) -> "GPFleet":
        """Train hyperparameters (trainer registry) and cache the serving
        factors. Returns self (chainable).

        Xp (M, Ni, D), yp (M, Ni) — M must equal config.num_agents.
        `key` seeds the grBCM communication dataset when the trainer or
        method needs one (default PRNGKey(0): deterministic).
        `train=False` skips training and serves from `log_theta0` (default:
        config.theta0) — the "true hyperparameters known" scenario.
        `trace` (a `repro.obs.TraceRecorder`) switches the trainer's
        diagnostics mode on (`diag=True`: per-iteration NLL, primal/dual
        residuals, theta trajectory carried through the scan) and records
        the resulting info dict on the recorder after the fit.
        """
        cfg = self.config
        Xp, yp = jnp.asarray(Xp), jnp.asarray(yp)
        if Xp.shape[0] != cfg.num_agents:
            raise ValueError(
                f"data for {Xp.shape[0]} agents vs config.num_agents="
                f"{cfg.num_agents}; set FleetConfig(num_agents=...) to the "
                f"fleet you partitioned")
        if Xp.shape[-1] != cfg.input_dim:
            raise ValueError(f"data input_dim {Xp.shape[-1]} vs config."
                             f"input_dim={cfg.input_dim}")
        if key is None:
            key = jax.random.PRNGKey(0)
        lt0 = jnp.asarray(log_theta0) if log_theta0 is not None else pack(
            list(cfg.theta0[:-2]), cfg.theta0[-2],
            cfg.theta0[-1]).astype(Xp.dtype)

        spec = get_trainer(cfg.trainer)
        Xc = yc = Xa = ya = None
        if self._needs_comm_data(train):
            Xc, yc, Xa, ya = self._build_comm_data(Xp, yp, key)
        if not train:
            self.log_theta = lt0
            self.thetas = jnp.broadcast_to(
                lt0, (cfg.num_agents, lt0.shape[0]))
            self.train_info = {}
        else:
            Xt, yt = (Xa, ya) if spec.needs_augmented_data else (Xp, yp)
            self.log_theta, self.thetas, self.train_info = spec.run(
                cfg, lt0, Xt, yt, self.A, mesh=self.mesh, grad_fn=grad_fn,
                diag=trace is not None)
            if trace is not None:
                trace.record(cfg.trainer, self.train_info,
                             num_agents=cfg.num_agents, method=cfg.method)
        self._cache_factors(Xp, yp)
        return self

    def _fit_sparse(self, lt, Xp, yp, Z=None):
        cfg = self.config
        if Z is None:
            Z = select_inducing(Xp, cfg.sparse_m, cfg.inducing_init)
        return jax.jit(partial(fit_sparse_experts,
                               jitter=cfg.jitter))(lt, Xp, yp, Z)

    def _cache_factors(self, Xp, yp):
        """Factorize the trained fleet once (fit_experts / sparse
        pseudo-representations / online windows) and invalidate the
        engine."""
        cfg, lt = self.config, self.log_theta
        if cfg.online:
            self._online_state = from_batch(lt, Xp, yp, window=cfg.window,
                                            jitter=cfg.jitter)
            self.fitted = self._online_state.to_fitted()
        elif cfg.sparse_m is not None:
            # the fact-sparse trainer jointly optimized the inducing sets;
            # reuse THOSE so serving sees the Z the bound was tightened over
            Z = self.train_info.get("Z") \
                if isinstance(self.train_info, dict) else None
            self.fitted = self._fit_sparse(lt, Xp, yp, Z)
        else:
            self.fitted = jax.jit(partial(
                fit_experts, jitter=cfg.jitter,
                cache_cross=cfg.cache_cross))(lt, Xp, yp)
        if get_method(cfg.method).needs_augmented_data:
            Xc, yc, Xa, ya = self._comm_data
            if cfg.sparse_m is not None:
                self.fitted_aug = self._fit_sparse(lt, Xa, ya)
                self.fitted_comm = self._fit_sparse(lt, Xc[None], yc[None])
            else:
                self.fitted_aug = jax.jit(fit_experts)(lt, Xa, ya)
                self.fitted_comm = jax.jit(fit_experts)(
                    lt, Xc[None], yc[None])
        self._engine = None

    # -- serving -------------------------------------------------------------

    def _build_engine(self):
        self._require_fitted("serving")
        cfg = self.config
        if cfg.sharded:
            if self.mesh is None:
                from ..launch.mesh import make_agent_mesh
                self.mesh = make_agent_mesh(cfg.num_agents,
                                            max_devices=cfg.max_shard_devices)
            return ShardedEngine(self.fitted, self.mesh, chunk=cfg.chunk,
                                 dac_iters=cfg.dac_iters, eta_nn=cfg.eta_nn,
                                 consensus=cfg.consensus,
                                 npae_jitter=cfg.npae_jitter,
                                 fitted_aug=self.fitted_aug,
                                 fitted_comm=self.fitted_comm,
                                 stream_mean=cfg.stream_mean)
        return PredictionEngine(self.fitted, self.A, chunk=cfg.chunk,
                                dac_iters=cfg.dac_iters,
                                jor_iters=cfg.jor_iters,
                                dale_iters=cfg.dale_iters,
                                pm_iters=cfg.pm_iters, eta_nn=cfg.eta_nn,
                                npae_jitter=cfg.npae_jitter,
                                fitted_aug=self.fitted_aug,
                                fitted_comm=self.fitted_comm,
                                stream_mean=cfg.stream_mean)

    def predict(self, Xs, method: str | None = None, *, fault_plan=None,
                allow_degraded: bool = False):
        """Serve one query batch -> (mean (Nt,), var (Nt,), info).

        `method` overrides config.method for this call (must satisfy the
        same capability constraints); `cen_*` centralized references pass
        through to the replicated engine.

        `fault_plan` (repro.chaos.FaultPlan) injects the plan's consensus
        faults: the engine serves over the surviving subgraph and flags the
        result with info["degraded"]=True (see PredictionEngine.predict).
        Degraded results are returned only under `allow_degraded=True`;
        otherwise the (finite, flagged) result is wrapped in a typed
        `FleetDegraded` so a caller can never mistake a partial-fleet
        answer for a healthy one. Consensus divergence always raises
        `ConsensusDiverged` regardless of `allow_degraded`.
        """
        self._require_fitted("predict")
        cfg = self.config
        method = method if method is not None else cfg.method
        method = method.replace("-", "_")   # CLI convention ("npae-sparse")
        if fault_plan is not None and not fault_plan.consensus_free \
                and cfg.sharded:
            raise ValueError(
                "fault plans with consensus faults serve on the replicated "
                "engine only (ShardedEngine consensus runs on the device "
                "ring, which has no degraded mode)")
        if not method.startswith("cen_"):
            spec = get_method(method)
            if (cfg.sharded and not spec.shardable) or \
                    (cfg.sparse_m is not None and not spec.sparse) or \
                    (spec.family == "sparse" and cfg.sparse_m is None):
                validate_config(cfg.replace(method=method))  # clear error
            if spec.needs_augmented_data and self.fitted_aug is None:
                raise ValueError(
                    f"method {method!r} needs the grBCM augmented/"
                    f"communication experts; fit with "
                    f"FleetConfig(method={method!r}) so they are built")
        else:
            if cfg.sharded:
                raise ValueError("centralized cen_* references serve on "
                                 "the replicated engine only")
            if "grbcm" in method and self.fitted_aug is None:
                raise ValueError(
                    f"method {method!r} needs the grBCM augmented/"
                    f"communication experts; fit with a grbcm method "
                    f"configured so they are built")
        if cfg.routed and method.startswith("nn_"):
            return self.engine.predict_routed(method, Xs)
        if fault_plan is None:
            return self.engine.predict(method, Xs)
        mean, var, info = self.engine.predict(method, Xs,
                                              fault_plan=fault_plan)
        if info.get("degraded"):
            self._last_degraded = {k: info[k] for k in
                                   ("alive_agents", "excluded_agents",
                                    "n_components", "scrubbed_agents")}
            if not allow_degraded:
                raise FleetDegraded(
                    f"prediction served in degraded mode "
                    f"({info['alive_agents']}/{self.num_agents} agents "
                    f"alive, {info['scrubbed_agents']} scrubbed) — pass "
                    f"allow_degraded=True to accept flagged partial-fleet "
                    f"results", info=info, result=(mean, var))
        return mean, var, info

    def shard(self, mesh=None, *, routed: bool | None = None) -> "GPFleet":
        """Move serving onto the agent-sharded engine (in place).

        Validates method capability first; `routed` switches CBNN query
        routing on/off at the same time. Returns self.
        """
        cfg = self.config.replace(
            sharded=True,
            routed=self.config.routed if routed is None else routed)
        validate_config(cfg)
        self.config = cfg
        if mesh is not None:
            self.mesh = mesh
        self._engine = None
        return self

    def slot_geometry(self, method: str | None = None) -> tuple[int, int]:
        """(align, max_slot) for serving schedulers packing this fleet:
        slots are multiples of the engine chunk up to the method registry's
        `max_slot` capability (NPAE-family per-query (M, M) solves cap out
        earlier than the flat-tiling DAC family)."""
        cfg = self.config
        method = method if method is not None else cfg.method
        base = method[4:] if method.startswith("cen_") else method
        return int(cfg.chunk), int(get_method(base).max_slot)

    @property
    def jit_cache_misses(self) -> int:
        """The serving engine's trace count (distinct compiled programs).
        Flat across requests => zero recompiles; 0 before first serve."""
        return 0 if self._engine is None else self._engine.jit_cache_misses

    def health(self) -> dict:
        """Point-in-time fleet health: shape, consensus-graph connectivity,
        degraded/diverged serving totals (from the engine's `repro.obs`
        counters), and the census of the last degraded prediction. Cheap —
        host-side graph analysis only, no device work — and safe to poll
        from a watchdog or a /healthz handler."""
        labels = connected_components(self.A)
        h = {
            "num_agents": self.num_agents,
            "is_fitted": self.is_fitted,
            "sharded": self.config.sharded,
            "graph_connected": bool(is_connected(self.A)),
            "graph_components": int(len(set(labels.tolist()))),
            "degraded_predictions": 0.0,
            "diverged_predictions": 0.0,
            "last_degraded": self._last_degraded,
        }
        eng = self._engine
        if eng is not None and hasattr(eng, "_degraded_total"):
            h["degraded_predictions"] = sum(
                v for _, v in eng._degraded_total.collect())
            h["diverged_predictions"] = sum(
                v for _, v in eng._diverged_total.collect())
        return h

    def metrics(self) -> dict:
        """Observability snapshot: the process-wide `repro.obs` default
        registry (counters/gauges/histograms — serving schedulers and the
        engines' trace counters write here when metrics are enabled) plus a
        `fleet` block describing THIS fleet (shape, method, engine trace
        count). Prometheus-format export of the same registry comes from
        `repro.obs.prometheus_text()` / `serve_gp --metrics-port`."""
        snap = default_registry().snapshot()
        snap["fleet"] = {
            "num_agents": self.config.num_agents,
            "trainer": self.config.trainer,
            "method": self.config.method,
            "sharded": self.config.sharded,
            "is_fitted": self.is_fitted,
            "jit_cache_misses": self.jit_cache_misses,
        }
        return snap

    def to_server(self, batch: int = 256, *, max_wait_ms: float = 2.0,
                  method: str | None = None, queue_depth: int = 1024,
                  continuous: bool = True, warm: bool = True,
                  admission: str = "block", deadline_policy: str = "drop"
                  ) -> ServingScheduler:
        """A started one-tenant `ServingScheduler` over this fleet: submit
        (Nq, D) requests, get Futures of (mean, var); use as a context
        manager to drain on exit. `continuous=True` (default) serves the
        quantized slot ladder up to `batch` rows — partial loads run
        right-sized compiled programs; `continuous=False` reproduces the
        v1 fixed-batch FrontDoor geometry. `warm=True` pre-compiles every
        slot so the request path never traces."""
        self._require_fitted("to_server")
        sched = ServingScheduler(max_wait_ms=max_wait_ms)
        sched.add_fleet("default", self, method=method, max_slot=int(batch),
                        continuous=continuous, queue_depth=queue_depth,
                        admission=admission, deadline_policy=deadline_policy,
                        warm=warm)
        return sched

    # -- streaming / membership ----------------------------------------------

    def _require_online(self, verb: str) -> OnlineExperts:
        self._require_fitted(verb)
        if self._online_state is None:
            raise RuntimeError(
                f"{verb} needs a streaming fleet — construct with "
                f"FleetConfig(online=True) before fit()")
        return self._online_state

    def observe(self, xs, ys) -> "GPFleet":
        """Ingest one observation per agent (xs (M, D), ys (M,)) through the
        O(W^2) rank-1 factor updates and hot-swap the engine's served
        factors — zero recompiles. Returns self."""
        state = self._require_online("observe")
        if self._ingest is None:
            self._ingest = jax.jit(observe_fleet)
        self._online_state = self._ingest(state, xs, ys)
        self.fitted = self._online_state.to_fitted()
        if self._engine is not None:
            self._engine.swap_experts(self.fitted)
        return self

    def drift(self, *, grad_fn=None, iters: int | None = None) -> dict:
        """Re-run the configured decentralized trainer on the LIVE sliding
        windows and hot-swap the retrained factors into the serving engine
        — the drift-adaptation loop: stream with `observe`, periodically
        `drift` so the hyperparameters track the data the windows hold now.

        Training uses the filled window prefix shared by every agent
        (`min(window_counts)` observations; sentinel slots never enter the
        likelihood), warm-starts from the current consensus theta, and
        `iters` caps this epoch's ADMM budget (default config.admm_iters).
        The refreshed window factors are refit at the new theta and swapped
        in place (`swap_experts`): same shapes, ZERO recompiles — serving
        never retraces across a drift epoch. Returns the trainer info dict.
        """
        state = self._require_online("drift")
        n = int(jnp.min(state.count))
        if n < 2:
            raise RuntimeError(
                f"drift needs >= 2 observations in every agent's window "
                f"(min count is {n}) — stream more data with observe() "
                f"first")
        spec = get_trainer(self.config.trainer)
        if spec.needs_augmented_data:
            raise ValueError(
                f"trainer {self.config.trainer!r} needs augmented/"
                f"communication datasets, which sliding windows do not "
                f"carry — streaming fleets drift with a plain-data trainer")
        cfg = self.config if iters is None \
            else self.config.replace(admm_iters=int(iters))
        Xt, yt = state.Xw[:, :n], state.yw[:, :n]
        self.log_theta, self.thetas, info = spec.run(
            cfg, self.log_theta, Xt, yt, self.A, mesh=self.mesh,
            grad_fn=grad_fn)
        self._online_state = refit(state._replace(
            log_theta=self.log_theta.astype(state.log_theta.dtype)))
        self.fitted = self._online_state.to_fitted()
        if self._engine is not None:
            self._engine.swap_experts(self.fitted)
        return info

    def join(self, X_new=None, y_new=None, neighbors=None) -> "GPFleet":
        """One agent joins the streaming fleet (window seeded from X_new /
        y_new); consensus graph attached, engine re-traced on the new M."""
        state = self._require_online("join")
        if self.config.sharded:
            raise ValueError("membership changes serve on the replicated "
                             "engine (ShardedEngine shards are fixed at "
                             "construction)")
        self._online_state, self.A = join(state, self.A, X_new, y_new,
                                          neighbors=neighbors)
        self._after_membership_change()
        return self

    def leave(self, agent: int) -> "GPFleet":
        """Agent `agent` leaves; former neighbors are re-chained so the
        consensus graph stays connected."""
        state = self._require_online("leave")
        if self.config.sharded:
            raise ValueError("membership changes serve on the replicated "
                             "engine (ShardedEngine shards are fixed at "
                             "construction)")
        self._online_state, self.A = leave(state, self.A, agent)
        self._after_membership_change()
        return self

    def _after_membership_change(self):
        self.fitted = self._online_state.to_fitted()
        self.config = self.config.replace(
            num_agents=self._online_state.num_agents)
        if self._engine is not None:
            self._engine.rewire(self.A, fitted=self.fitted)

    # -- persistence ---------------------------------------------------------

    def _state_tree(self):
        tree = {"A": self.A, "log_theta": self.log_theta,
                "thetas": self.thetas, "fitted": self.fitted}
        if self.fitted_aug is not None:
            tree["fitted_aug"] = self.fitted_aug
        if self.fitted_comm is not None:
            tree["fitted_comm"] = self.fitted_comm
        if self._online_state is not None:
            tree["count"] = self._online_state.count
            tree["jitter"] = self._online_state.jitter
        return tree

    def save(self, ckpt_dir: str, step: int = 0) -> str:
        """Persist the fitted fleet: factors + config + consensus graph (+
        online window state). A fresh process `GPFleet.load`s it and serves
        bit-identical predictions WITHOUT refitting."""
        self._require_fitted("save")
        tree = self._state_tree()
        path = save_checkpoint(ckpt_dir, step, tree)
        # leaf shapes/dtypes live in checkpoint.io's manifest.json (written
        # by save_checkpoint above); fleet.json adds only what io cannot
        # know — the config and which optional components exist
        manifest = {
            "format": _FORMAT_VERSION,
            "config": self.config.to_dict(),
            "step": step,
            "components": {
                "fitted_aug": self.fitted_aug is not None,
                "fitted_comm": self.fitted_comm is not None,
                "fitted_kcross": self.fitted.Kcross is not None,
                "aug_kcross": (self.fitted_aug is not None
                               and self.fitted_aug.Kcross is not None),
                "online": self._online_state is not None,
                "sparse": isinstance(self.fitted, SparseExperts),
                "aug_sparse": isinstance(self.fitted_aug, SparseExperts),
                "comm_sparse": isinstance(self.fitted_comm, SparseExperts),
            },
        }
        # atomic publish: fleet.json is the load() entry point, so it is
        # written LAST and via tmp+rename — a crash mid-save leaves either
        # the previous complete checkpoint or a directory load() rejects,
        # never a half-written manifest over fresh arrays
        mpath = os.path.join(ckpt_dir, _FLEET_MANIFEST)
        tmp = mpath + ".tmp"
        with open(tmp, "w") as f:
            json.dump(manifest, f, indent=2, sort_keys=True)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, mpath)
        return path

    @staticmethod
    def _template(ckpt_dir: str, manifest) -> dict:
        """ShapeDtypeStruct tree matching the saved state — what
        checkpoint.io.restore validates the stored leaves against.

        The tree STRUCTURE comes from fleet.json's component map; the leaf
        shapes/dtypes come from checkpoint.io's manifest.json (the single
        copy of the leaf specs, written by save_checkpoint)."""
        comp = manifest["components"]
        with open(os.path.join(ckpt_dir, "manifest.json")) as f:
            io_manifest = json.load(f)
        if io_manifest.get("step") != manifest["step"]:
            raise ValueError(
                f"checkpoint manifests disagree: fleet.json is for step "
                f"{manifest['step']} but manifest.json describes step "
                f"{io_manifest.get('step')} (mixed checkpoint directory?)")

        def fe(kcross):
            return FittedExperts(0, 0, 0, 0, 0, Kcross=0 if kcross else None)

        def se():
            return SparseExperts(0, 0, 0, 0, 0, 0)

        # sparse flags default False: checkpoints written before the sparse
        # subsystem load unchanged
        tree = {"A": 0, "log_theta": 0, "thetas": 0,
                "fitted": se() if comp.get("sparse", False)
                else fe(comp["fitted_kcross"])}
        if comp["fitted_aug"]:
            tree["fitted_aug"] = se() if comp.get("aug_sparse", False) \
                else fe(comp["aug_kcross"])
        if comp["fitted_comm"]:
            tree["fitted_comm"] = se() if comp.get("comm_sparse", False) \
                else fe(False)
        if comp["online"]:
            tree["count"] = 0
            tree["jitter"] = 0
        paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
        specs = io_manifest["leaves"]
        leaves = []
        for kp, _ in paths:
            key = jax.tree_util.keystr(kp)
            if key not in specs:
                raise ValueError(f"checkpoint manifest is missing leaf "
                                 f"{key!r} (corrupted or truncated "
                                 f"checkpoint?)")
            leaves.append(jax.ShapeDtypeStruct(
                tuple(specs[key]["shape"]), specs[key]["dtype"]))
        return jax.tree_util.tree_unflatten(treedef, leaves)

    @classmethod
    def load(cls, ckpt_dir: str, *, mesh=None, config: FleetConfig | None
             = None) -> "GPFleet":
        """Reconstruct a fitted fleet from `save()` output: no refitting,
        served predictions are bit-identical to the saving process.

        `config` overrides the persisted config (e.g. flip `sharded=True`
        to serve a replicated-saved fleet on a mesh) — overrides are
        validated against the registries like any other config.
        """
        mpath = os.path.join(ckpt_dir, _FLEET_MANIFEST)
        if not os.path.exists(mpath):
            raise FileNotFoundError(
                f"{mpath!r} not found — not a GPFleet.save() checkpoint")
        with open(mpath) as f:
            manifest = json.load(f)
        if manifest.get("format", 0) > _FORMAT_VERSION:
            raise ValueError(
                f"fleet checkpoint format {manifest['format']} is newer "
                f"than this code ({_FORMAT_VERSION})")
        saved_cfg = FleetConfig.from_dict(manifest["config"])
        cfg = config if config is not None else saved_cfg
        tree = restore(ckpt_dir, cls._template(ckpt_dir, manifest),
                       step=manifest["step"])
        tree = jax.tree.map(jnp.asarray, tree)
        fleet = cls(cfg, A=tree["A"], mesh=mesh)
        fleet.log_theta = tree["log_theta"]
        fleet.thetas = tree["thetas"]
        fleet.train_info = {}
        fleet.fitted = tree["fitted"]
        fleet.fitted_aug = tree.get("fitted_aug")
        fleet.fitted_comm = tree.get("fitted_comm")
        if manifest["components"]["online"]:
            f = fleet.fitted
            fleet._online_state = OnlineExperts(
                f.log_theta, f.Xp, f.yp, f.L, f.alpha, tree["count"],
                tree["jitter"])
        if (get_method(cfg.method).needs_augmented_data
                and fleet.fitted_aug is None):
            raise ValueError(
                f"checkpoint has no augmented/communication experts but "
                f"method {cfg.method!r} needs them; refit with the grbcm "
                f"method configured")
        return fleet
