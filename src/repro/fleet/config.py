"""FleetConfig: one declarative description of a GP fleet's whole lifecycle.

Every knob the ad-hoc entry points used to take as positional arguments or
CLI flags lives here — kernel hyperparameters, data partition, consensus
graph topology, the trainer name with its ADMM parameters, the prediction
method with its consensus-iteration parameters, and the serving switches
(sharding, routing, online windows). `GPFleet` consumes a config; the
`serve_gp` CLI is a thin overlay that fills one in; `save()` serializes it
next to the fitted factors so a fleet can be reconstructed by a fresh
process.

The DEFAULTS reproduce `repro.configs.paper_gp.CONFIG` (the paper's §6
experiment configuration) exactly — asserted by tests/test_fleet.py — so
`FleetConfig()` is always the canonical paper setup.

The dataclass is frozen and all fields are hashable Python scalars/tuples,
so it is registered as a STATIC pytree node (no array leaves): a FleetConfig
can ride through `jax.jit` closures and pytree utilities without triggering
retraces beyond its own equality.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass

import jax

_GRAPHS = ("path", "cycle", "complete", "random")
_CONSENSUS = ("dac", "exact")
_INDUCING_INITS = ("stride", "random")


@dataclass(frozen=True)
class FleetConfig:
    # -- kernel hyperparameters (linear space, paper convention) ------------
    input_dim: int = 2
    theta0: tuple = (2.0, 0.5, 1.0, 1.0)   # (l_1..l_D, sigma_f, sigma_eps)

    # -- partition / graph topology -----------------------------------------
    num_agents: int = 4                    # paper fleets: 4, 10, 20, 40
    graph: str = "path"                    # path | cycle | complete | random
    graph_p: float = 0.5                   # edge probability (graph="random")
    graph_seed: int = 0

    # -- trainer (registry name) + ADMM parameters --------------------------
    trainer: str = "dec-apx"
    rho: float = 500.0
    kappa: float = 5_000.0
    lipschitz: float = 5_000.0             # L of apx-GP / gapx-GP (eq. 26)
    admm_iters: int = 100                  # paper: s_end = 100
    nested_iters: int = 10                 # c-GP / DEC-c-GP inner GD steps
    nested_lr: float = 1e-5
    fact_steps: int = 200                  # FACT-GP Adam steps
    fact_lr: float = 0.05

    # -- prediction method (registry name) + consensus parameters -----------
    method: str = "rbcm"
    chunk: int = 256                       # engine query-tile size
    dac_iters: int = 200
    jor_iters: int = 500
    dale_iters: int = 2_000
    pm_iters: int = 100
    eta_nn: float = 0.1                    # CBNN threshold (paper eq. 39)
    npae_jitter: float = 1e-6
    jitter: float = 1e-8                   # factorization jitter
    stream_mean: bool = False              # fused rbf_matvec mean path
    cache_cross: bool = False              # NPAE cross-Gram cache

    # -- serving switches ----------------------------------------------------
    sharded: bool = False                  # agent axis over a device mesh
    routed: bool = False                   # CBNN query routing (nn_* only)
    consensus: str = "dac"                 # sharded ring: dac | exact
    max_shard_devices: int | None = None

    # -- online / streaming switches ----------------------------------------
    online: bool = False                   # sliding-window experts
    window: int | None = None              # W (None: window = Ni)

    # -- sparse pseudo-representation experts (core.sparse) -----------------
    sparse_m: int | None = None            # inducing points per agent
    inducing_init: str = "stride"          # stride | random

    def __post_init__(self):
        if self.graph not in _GRAPHS:
            raise ValueError(f"graph must be one of {_GRAPHS}, "
                             f"got {self.graph!r}")
        if self.consensus not in _CONSENSUS:
            raise ValueError(f"consensus must be one of {_CONSENSUS}, "
                             f"got {self.consensus!r}")
        if len(self.theta0) != self.input_dim + 2:
            raise ValueError(
                f"theta0 must have input_dim + 2 = {self.input_dim + 2} "
                f"entries (l_1..l_D, sigma_f, sigma_eps), "
                f"got {len(self.theta0)}")
        if self.inducing_init not in _INDUCING_INITS:
            raise ValueError(
                f"inducing_init must be one of {_INDUCING_INITS}, "
                f"got {self.inducing_init!r}")
        if self.sparse_m is not None and self.sparse_m < 1:
            raise ValueError(f"sparse_m must be a positive inducing count, "
                             f"got {self.sparse_m}")
        # CLI convention writes method names with hyphens ("npae-sparse");
        # engine dispatch keys use underscores — normalize once here
        object.__setattr__(self, "method", self.method.replace("-", "_"))

    def replace(self, **kw) -> "FleetConfig":
        return dataclasses.replace(self, **kw)

    # -- serialization (rides GPFleet.save / load) --------------------------

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "FleetConfig":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown FleetConfig fields {sorted(unknown)} "
                             f"(config saved by a newer version?)")
        d = dict(d)
        if "theta0" in d:
            d["theta0"] = tuple(d["theta0"])
        return cls(**d)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2, sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "FleetConfig":
        return cls.from_dict(json.loads(s))


jax.tree_util.register_static(FleetConfig)
