"""repro.fleet — the single public API for the GP fleet lifecycle.

    FleetConfig   declarative config (kernel theta, partition, graph,
                  trainer + ADMM params, method + consensus params,
                  sharding/routing/online switches); defaults reproduce
                  the paper's §6 configuration (configs/paper_gp.py)
    GPFleet       the facade: fit / predict / observe / join / leave /
                  shard / save / load / to_server
    registries    TRAINERS (the §4 ADMM family) and METHODS (the 13 §5
                  prediction methods) with per-entry capability flags —
                  dispatch, CLI choices, and test parametrization all
                  derive from these tables

See docs/fleet_api.md for the lifecycle walkthrough and the migration
table from the legacy free-function surface (which remains public and
unchanged underneath).
"""
from .config import FleetConfig
from .fleet import FleetDegraded, GPFleet
from .registry import (METHODS, TRAINERS, MethodSpec, TrainerSpec,
                       get_method, get_trainer, method_names, trainer_names,
                       validate_config)

__all__ = [
    "FleetConfig", "GPFleet", "FleetDegraded",
    "METHODS", "TRAINERS", "MethodSpec", "TrainerSpec",
    "get_method", "get_trainer", "method_names", "trainer_names",
    "validate_config",
]
