"""Synthetic data generation (paper §6.1 and the SST substitute, DESIGN.md §2).

`gp_sample_field` draws from the exact GP prior when N is small and falls back
to a random-Fourier-feature (RFF) approximation for large N (an RFF draw with
enough features is statistically indistinguishable from an exact draw and is
O(N*F) instead of O(N^3)).

`sst_like_field` builds the SST stand-in: a smooth multi-scale 2-D field with a
meandering front, normalized like the paper's 400x400 km Atlantic patch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.gp.kernel import se_kernel, unpack


def grid_inputs(n_side: int, lo=0.0, hi=2.0, dtype=jnp.float64) -> jax.Array:
    xs = jnp.linspace(lo, hi, n_side, dtype=dtype)
    X1, X2 = jnp.meshgrid(xs, xs, indexing="ij")
    return jnp.stack([X1.ravel(), X2.ravel()], axis=1)


def gp_sample_field(key, X, log_theta, exact_max_n: int = 4096,
                    rff_features: int = 4096):
    """Draw f ~ GP(0, k) at inputs X and add N(0, sigma_eps^2) noise -> y."""
    ls, sigma_f, sigma_eps = unpack(log_theta)
    kf, kw, kb, kn = jax.random.split(key, 4)
    n, D = X.shape
    if n <= exact_max_n:
        # float32 needs a much larger diagonal shift: at a few hundred
        # near-duplicate random inputs the SE Gram matrix is singular to
        # float32 precision and cholesky returns silent NaN (which then
        # poisons every downstream consumer of y); scaled by sigma_f^2 so
        # it tracks the Gram diagonal, it acts as a nugget well below
        # sigma_eps
        jit = 1e-8 if X.dtype == jnp.float64 else 1e-3 * sigma_f ** 2
        K = se_kernel(X, X, log_theta) + jit * jnp.eye(n, dtype=X.dtype)
        L = jnp.linalg.cholesky(K)
        f = L @ jax.random.normal(kf, (n,), X.dtype)
    else:
        # RFF for k(x,x') = sf^2 exp(-sum d^2/l^2): spectral density is Gaussian
        # with std sqrt(2)/l per dim.
        W = jax.random.normal(kw, (rff_features, D), X.dtype) \
            * (jnp.sqrt(2.0) / ls)[None, :]
        b = jax.random.uniform(kb, (rff_features,), X.dtype, 0.0, 2 * jnp.pi)
        phi = jnp.sqrt(2.0 / rff_features) * jnp.cos(X @ W.T + b[None, :])
        w = jax.random.normal(kf, (rff_features,), X.dtype)
        f = sigma_f * (phi @ w)
    y = f + sigma_eps * jax.random.normal(kn, (n,), X.dtype)
    return f, y


def sst_like_field(X: jax.Array, noise_std: float = 0.5,
                   key: jax.Array | None = None):
    """SST stand-in on [0,1]^2: warm-to-cold gradient + meandering front + eddies.

    Returns (f, y). Paper adds N(0, 0.25) iid noise (std 0.5) — same default.
    """
    x, z = X[:, 0], X[:, 1]
    front = 0.45 + 0.08 * jnp.sin(4.0 * jnp.pi * x) + 0.05 * jnp.cos(9.0 * x)
    f = (
        2.2 * jnp.tanh((front - z) * 9.0)              # Gulf-Stream-like front
        + 0.8 * jnp.sin(3.1 * x) * jnp.cos(2.3 * z)    # mesoscale structure
        + 0.4 * jnp.sin(7.9 * x + 1.3) * jnp.sin(6.1 * z + 0.7)  # eddies
        + 0.15 * jnp.cos(15.0 * x) * jnp.cos(13.0 * z)
    )
    if key is None:
        key = jax.random.PRNGKey(0)
    y = f + noise_std * jax.random.normal(key, f.shape, f.dtype)
    return f, y


def random_inputs(key, n: int, D: int = 2, lo=0.0, hi=2.0, dtype=jnp.float64):
    return jax.random.uniform(key, (n, D), dtype, lo, hi)
