from .synthetic import (grid_inputs, gp_sample_field, sst_like_field,
                        random_inputs)

__all__ = ["grid_inputs", "gp_sample_field", "sst_like_field", "random_inputs"]
