"""Synthetic-but-structured LM token pipeline.

Offline container -> no real corpus; we generate a learnable Markov-ish
stream (mixture of n-gram rules + noise) so that training loss MEASURABLY
decreases — a pure-uniform stream would give no learning signal and make the
end-to-end example meaningless. Deterministic per (seed, agent) so federated
agents hold DISTINCT local shards (paper Assumption: disjoint local data).
"""
from __future__ import annotations

import numpy as np


class MarkovLMData:
    def __init__(self, vocab_size: int, seed: int = 0, order: int = 2,
                 determinism: float = 0.8, agent: int = 0):
        self.V = vocab_size
        self.rng = np.random.default_rng(seed * 1000 + agent)
        # shared transition structure across agents (same language), agent-
        # specific sampling (disjoint documents)
        struct = np.random.default_rng(seed)
        self.order = order
        self.det = determinism
        self.table = struct.integers(0, vocab_size, size=(vocab_size, order))

    def batch(self, batch_size: int, seq_len: int):
        """Returns (tokens, labels) int32 (B, S); labels = next token."""
        B, S = batch_size, seq_len
        toks = np.empty((B, S + 1), np.int32)
        toks[:, 0] = self.rng.integers(0, self.V, B)
        rand = self.rng.random((B, S))
        noise = self.rng.integers(0, self.V, (B, S))
        for t in range(S):
            prev = toks[:, t]
            nxt = self.table[prev % self.V, t % self.order]
            toks[:, t + 1] = np.where(rand[:, t] < self.det, nxt, noise[:, t])
        return toks[:, :-1], toks[:, 1:]
