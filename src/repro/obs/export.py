"""Exporters over the metrics registry: Prometheus text format + HTTP.

  prometheus_text(reg)        the text exposition format (counters with
                              _total names as-is, histograms as cumulative
                              le= buckets + _sum/_count).
  parse_prometheus_text(s)    minimal parser -> {name: [(labels, value)]},
                              used by CI smoke and tests to assert the
                              dump round-trips.
  MetricsServer               stdlib ThreadingHTTPServer on a daemon
                              thread: GET /metrics (Prometheus text) and
                              GET /statusz (the registry snapshot as
                              JSON). `serve_gp --metrics-port` starts one.

No third-party dependencies — the wire formats are plain text/JSON.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

from .metrics import MetricsRegistry, default_registry

__all__ = ["prometheus_text", "parse_prometheus_text", "MetricsServer",
           "start_metrics_server"]


def _fmt_labels(labels: dict) -> str:
    if not labels:
        return ""
    inner = ",".join(
        '{}="{}"'.format(k, str(v).replace("\\", "\\\\").replace('"', '\\"'))
        for k, v in sorted(labels.items()))
    return "{" + inner + "}"


def _fmt_value(v: float) -> str:
    if v != v:
        return "NaN"
    if v == float("inf"):
        return "+Inf"
    if v == float("-inf"):
        return "-Inf"
    return repr(float(v))


def prometheus_text(registry: MetricsRegistry | None = None) -> str:
    """Render every series in the Prometheus text exposition format."""
    reg = registry if registry is not None else default_registry()
    lines = []
    for m in reg.metrics():
        if m.help:
            lines.append(f"# HELP {m.name} {m.help}")
        lines.append(f"# TYPE {m.name} {m.kind}")
        if m.kind == "histogram":
            for labels, s in m.collect():
                cum = 0
                for bound, c in zip(m.buckets, s["counts"]):
                    cum += c
                    ll = dict(labels, le=_fmt_value(bound))
                    lines.append(f"{m.name}_bucket{_fmt_labels(ll)} {cum}")
                cum += s["overflow"]
                ll = dict(labels, le="+Inf")
                lines.append(f"{m.name}_bucket{_fmt_labels(ll)} {cum}")
                lines.append(f"{m.name}_sum{_fmt_labels(labels)} "
                             f"{_fmt_value(s['sum'])}")
                lines.append(f"{m.name}_count{_fmt_labels(labels)} "
                             f"{s['count']}")
        else:
            for labels, v in m.collect():
                lines.append(f"{m.name}{_fmt_labels(labels)} "
                             f"{_fmt_value(v)}")
    return "\n".join(lines) + "\n"


def parse_prometheus_text(text: str) -> dict[str, list[tuple[dict, float]]]:
    """Minimal exposition-format parser: {metric: [(labels, value)]}.

    Raises ValueError on malformed sample lines — what the CI smoke step
    runs against the `--metrics-dump` artifact to prove the dump parses.
    """
    out: dict[str, list[tuple[dict, float]]] = {}
    for lineno, line in enumerate(text.splitlines(), 1):
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        name, labels, rest = line, {}, None
        if "{" in line:
            name, rest = line.split("{", 1)
            labelstr, rest = rest.rsplit("}", 1)
            for item in _split_labels(labelstr):
                if "=" not in item:
                    raise ValueError(f"line {lineno}: bad label {item!r}")
                k, v = item.split("=", 1)
                if len(v) < 2 or v[0] != '"' or v[-1] != '"':
                    raise ValueError(f"line {lineno}: unquoted label "
                                     f"value {v!r}")
                labels[k] = v[1:-1].replace('\\"', '"').replace("\\\\", "\\")
        else:
            parts = line.split(None, 1)
            if len(parts) != 2:
                raise ValueError(f"line {lineno}: no value in {line!r}")
            name, rest = parts
        try:
            value = float(rest.strip().replace("+Inf", "inf"))
        except ValueError as e:
            raise ValueError(f"line {lineno}: bad value {rest!r}") from e
        out.setdefault(name.strip(), []).append((labels, value))
    return out


def _split_labels(s: str) -> list[str]:
    """Split `a="x",b="y,z"` on commas outside quotes."""
    items, cur, inq, esc = [], [], False, False
    for ch in s:
        if esc:
            cur.append(ch)
            esc = False
        elif ch == "\\":
            cur.append(ch)
            esc = True
        elif ch == '"':
            cur.append(ch)
            inq = not inq
        elif ch == "," and not inq:
            items.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        items.append("".join(cur))
    return [i for i in (x.strip() for x in items) if i]


class MetricsServer:
    """HTTP scrape endpoint over a registry, on a daemon thread.

        srv = MetricsServer(port=9109).start()
        ... GET http://127.0.0.1:9109/metrics   (Prometheus text)
        ... GET http://127.0.0.1:9109/statusz   (snapshot JSON)
        srv.stop()

    port=0 binds an ephemeral port (tests); the bound port is `srv.port`
    after `start()`.
    """

    def __init__(self, port: int = 0, host: str = "127.0.0.1",
                 registry: MetricsRegistry | None = None):
        self.registry = registry if registry is not None \
            else default_registry()
        self._host = host
        self._port = int(port)
        self._httpd: ThreadingHTTPServer | None = None
        self._thread: threading.Thread | None = None

    @property
    def port(self) -> int:
        return self._httpd.server_address[1] if self._httpd else self._port

    def start(self) -> "MetricsServer":
        reg = self.registry

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self):
                if self.path.split("?")[0] == "/metrics":
                    body = prometheus_text(reg).encode()
                    ctype = "text/plain; version=0.0.4; charset=utf-8"
                elif self.path.split("?")[0] == "/statusz":
                    body = json.dumps(reg.snapshot(), indent=2,
                                      sort_keys=True).encode()
                    ctype = "application/json"
                else:
                    self.send_error(404, "try /metrics or /statusz")
                    return
                self.send_response(200)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *args):     # keep scrapes off stderr
                pass

        self._httpd = ThreadingHTTPServer((self._host, self._port), Handler)
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        name="gp-metrics", daemon=True)
        self._thread.start()
        return self

    def stop(self):
        if self._httpd is not None:
            self._httpd.shutdown()
            self._httpd.server_close()
            self._thread.join()
            self._httpd = self._thread = None

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop()


def start_metrics_server(port: int = 0, *, host: str = "127.0.0.1",
                         registry: MetricsRegistry | None = None
                         ) -> MetricsServer:
    """Convenience: construct + start a MetricsServer."""
    return MetricsServer(port=port, host=host, registry=registry).start()
