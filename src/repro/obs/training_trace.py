"""TraceRecorder: the host-side tap for training/consensus diagnostics.

The ADMM loops (core.training.admm_*) carry diagnostics through their
`lax.scan` outputs when called with `diag=True` — per-iteration NLL,
primal/dual residuals, max consensus disagreement, and the theta
trajectory, all computed ON DEVICE with no host callbacks in the hot
path. The recorder ingests the finished info dict AFTER the jitted loop
returns (one device->host transfer per fit, not per iteration), and the
engines' DAC/JOR per-round residual captures land the same way.

    rec = TraceRecorder()
    fleet.fit(Xp, yp, trace=rec)           # GPFleet threads diag=True
    rec.last()["nll"]                      # (iters, M) per-agent NLL
    rec.summary()                          # final-iteration scalars
    rec.to_jsonl("train_trace.jsonl")      # one line per recorded trace

docs/observability.md explains how to read a trace (what converging
primal/dual residuals look like, per the source paper's §4 story).
"""
from __future__ import annotations

import json

import numpy as np

__all__ = ["TraceRecorder"]

# array-valued diagnostic keys the recorder pulls to host numpy
_ARRAY_KEYS = ("residuals", "primal_residuals", "dual_residuals", "nll",
               "theta_trajectory", "z_history", "dac_residuals",
               "jor_residuals")


class TraceRecorder:
    """Accumulates named diagnostic traces (training runs, consensus
    rounds) as host numpy arrays. Thread-compatible for the single-writer
    pattern the fit path uses; not a concurrent sink."""

    def __init__(self):
        self.traces: list[dict] = []

    def record(self, name: str, info: dict, **meta) -> dict:
        """Ingest one loop's info dict. Array diagnostics (residuals, nll,
        theta trajectories, ...) are copied to host; other entries are
        kept as metadata when JSON-able. Returns the stored entry."""
        entry: dict = {"name": name, **meta}
        src = dict(info.get("diagnostics") or {})
        for k in _ARRAY_KEYS:
            if k in info and k not in src:
                src[k] = info[k]
        for k, v in src.items():
            try:
                entry[k] = np.asarray(v)
            except Exception:
                entry[k] = v
        self.traces.append(entry)
        return entry

    def last(self) -> dict | None:
        return self.traces[-1] if self.traces else None

    def summary(self) -> list[dict]:
        """Per-trace final-iteration scalars: the convergence endpoint of
        each recorded run (final residuals, final mean NLL, iterations)."""
        out = []
        for t in self.traces:
            s: dict = {"name": t["name"]}
            for k, v in t.items():
                if not isinstance(v, np.ndarray) or v.size == 0:
                    continue
                if k == "theta_trajectory":
                    s["iters"] = int(v.shape[0])
                    continue
                if v.ndim == 1:
                    s[f"final_{k}"] = float(v[-1])
                    s.setdefault("iters", int(v.shape[0]))
                elif k == "nll" and v.ndim == 2:
                    s["final_nll_mean"] = float(np.mean(v[-1]))
                    s["final_nll_max"] = float(np.max(v[-1]))
            out.append(s)
        return out

    def to_jsonl(self, path: str) -> str:
        """One JSON line per trace; arrays become (nested) lists."""
        with open(path, "w") as fh:
            for t in self.traces:
                rec = {}
                for k, v in t.items():
                    if isinstance(v, np.ndarray):
                        rec[k] = np.asarray(v, dtype=np.float64).tolist()
                    else:
                        try:
                            json.dumps(v)
                            rec[k] = v
                        except TypeError:
                            rec[k] = repr(v)
                fh.write(json.dumps(rec, sort_keys=True) + "\n")
        return path

    def __len__(self) -> int:
        return len(self.traces)
