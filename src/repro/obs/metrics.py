"""Thread-safe metrics registry: counters, gauges, histograms.

The repo's telemetry used to be ad-hoc attributes scattered per subsystem
(`TenantStats` counters inside the scheduler, `_trace_count` hand-threaded
through both engines, benchmark timers re-implemented per script). This
module is the one dependency-free home for all of it:

  Counter    monotone totals, e.g. gp_requests_total{tenant="maps"}.
  Gauge      point-in-time values; `set_fn` registers a callable sampled
             at collection time (how engine recompile counts are exported
             without polling threads).
  Histogram  fixed geometric buckets + count/sum/min/max per series. The
             bucket ratio (default 2**0.25 ~ 1.19) bounds the relative
             error of interpolated quantiles, and memory is O(buckets)
             per series — this replaces `TenantStats`' unbounded
             200k-sample latency deque.

Every metric holds LABELED series: `c.inc(tenant="maps", method="rbcm")`
creates/updates the series keyed by that label set. All mutation is
guarded by a per-metric lock; reads take the same lock and copy, so
snapshots are consistent under concurrent scheduler/worker writes
(tests/test_obs.py hammers this with racing threads).

Disabled registries make every write a cheap early-return — serving with
metrics off costs one attribute read per call site and never touches jit
tracing (the zero-overhead guard in tests/test_obs.py).

See docs/observability.md for the metric catalog and exporter formats.
"""
from __future__ import annotations

import bisect
import math
import threading

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "default_registry", "default_latency_buckets",
]


def default_latency_buckets(lo: float = 1e-6, hi: float = 60.0,
                            ratio: float = 2.0 ** 0.25) -> tuple[float, ...]:
    """Geometric bucket upper bounds spanning [lo, hi] seconds.

    The ratio between adjacent bounds caps the relative error of
    `Histogram.quantile` at ratio - 1 (~19% at the default) while keeping
    the ladder ~100 buckets long — constant memory at any sample count.
    """
    bounds = []
    b = lo
    while b < hi:
        bounds.append(b)
        b *= ratio
    bounds.append(hi)
    return tuple(bounds)


def _label_key(labels: dict) -> tuple:
    return tuple(sorted(labels.items()))


class _Metric:
    """Base: named metric holding labeled series behind one lock."""
    kind = "untyped"

    def __init__(self, name: str, help: str, registry: "MetricsRegistry"):
        self.name = name
        self.help = help
        self._registry = registry
        self._lock = threading.Lock()
        self._series: dict = {}

    @property
    def enabled(self) -> bool:
        return self._registry.enabled

    def labelsets(self) -> list[dict]:
        with self._lock:
            return [dict(k) for k in self._series]


class Counter(_Metric):
    """Monotonically increasing total per label set."""
    kind = "counter"

    def inc(self, value: float = 1.0, **labels):
        if not self._registry.enabled:
            return
        if value < 0:
            raise ValueError(f"counter {self.name} cannot decrease "
                             f"(inc {value})")
        k = _label_key(labels)
        with self._lock:
            self._series[k] = self._series.get(k, 0.0) + value

    def value(self, **labels) -> float:
        with self._lock:
            return float(self._series.get(_label_key(labels), 0.0))

    def collect(self) -> list[tuple[dict, float]]:
        with self._lock:
            return [(dict(k), float(v)) for k, v in self._series.items()]


class Gauge(_Metric):
    """Point-in-time value per label set; `set_fn` samples a callable at
    collection time (pull-style gauges over live objects)."""
    kind = "gauge"

    def set(self, value: float, **labels):
        if not self._registry.enabled:
            return
        with self._lock:
            self._series[_label_key(labels)] = float(value)

    def set_fn(self, fn, **labels):
        """Register `fn() -> float` to be evaluated on every collect —
        registered even when the registry is disabled (registration is a
        wiring step, not a hot-path write)."""
        with self._lock:
            self._series[_label_key(labels)] = fn

    def value(self, **labels) -> float:
        with self._lock:
            v = self._series.get(_label_key(labels), float("nan"))
        return float(v()) if callable(v) else float(v)

    def collect(self) -> list[tuple[dict, float]]:
        with self._lock:
            items = list(self._series.items())
        return [(dict(k), float(v() if callable(v) else v))
                for k, v in items]


class _HistSeries:
    __slots__ = ("counts", "overflow", "count", "sum", "min", "max")

    def __init__(self, n_buckets: int):
        self.counts = [0] * n_buckets
        self.overflow = 0
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf


class Histogram(_Metric):
    """Fixed-bucket distribution sketch per label set.

    `observe(v)` lands v in the first bucket with bound >= v (overflow
    past the last bound); `quantile(q)` interpolates linearly inside the
    selected bucket, with the tracked exact min/max tightening the edge
    buckets. Error is bounded by the bucket ratio, independent of sample
    count — unlike a sample reservoir there is nothing to evict.
    """
    kind = "histogram"

    def __init__(self, name, help, registry, buckets=None):
        super().__init__(name, help, registry)
        self.buckets = tuple(float(b) for b in (
            buckets if buckets is not None else default_latency_buckets()))
        if list(self.buckets) != sorted(set(self.buckets)):
            raise ValueError(f"histogram {name}: bucket bounds must be "
                             f"strictly increasing")

    def observe(self, value: float, **labels):
        if not self._registry.enabled:
            return
        value = float(value)
        i = bisect.bisect_left(self.buckets, value)
        k = _label_key(labels)
        with self._lock:
            s = self._series.get(k)
            if s is None:
                s = self._series[k] = _HistSeries(len(self.buckets))
            if i < len(self.buckets):
                s.counts[i] += 1
            else:
                s.overflow += 1
            s.count += 1
            s.sum += value
            s.min = min(s.min, value)
            s.max = max(s.max, value)

    def _get(self, labels) -> _HistSeries | None:
        return self._series.get(_label_key(labels))

    def count(self, **labels) -> int:
        with self._lock:
            s = self._get(labels)
            return 0 if s is None else s.count

    def sum(self, **labels) -> float:
        with self._lock:
            s = self._get(labels)
            return 0.0 if s is None else s.sum

    def quantile(self, q: float, **labels) -> float:
        """q in [0, 1]. NaN on an empty series. Relative error is bounded
        by the bucket ratio (bucket-linear interpolation)."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile wants q in [0, 1], got {q}")
        with self._lock:
            s = self._get(labels)
            if s is None or s.count == 0:
                return float("nan")
            counts = list(s.counts) + [s.overflow]
            total, lo_exact, hi_exact = s.count, s.min, s.max
        target = q * total
        cum = 0.0
        for i, c in enumerate(counts):
            if c == 0:
                continue
            if cum + c >= target:
                lo = self.buckets[i - 1] if i > 0 else lo_exact
                hi = self.buckets[i] if i < len(self.buckets) else hi_exact
                lo = max(lo, lo_exact)
                hi = min(hi, hi_exact)
                if hi <= lo:
                    return float(lo)
                frac = (target - cum) / c
                return float(lo + frac * (hi - lo))
            cum += c
        return float(hi_exact)

    def quantiles(self, *qs: float, **labels) -> tuple[float, ...]:
        return tuple(self.quantile(q, **labels) for q in qs)

    def collect(self) -> list[tuple[dict, dict]]:
        """[(labels, {"count", "sum", "min", "max", "counts", "overflow"})]
        — counts aligned with `self.buckets`."""
        with self._lock:
            return [(dict(k),
                     {"count": s.count, "sum": s.sum,
                      "min": (None if s.count == 0 else s.min),
                      "max": (None if s.count == 0 else s.max),
                      "counts": list(s.counts), "overflow": s.overflow})
                    for k, s in self._series.items()]


class MetricsRegistry:
    """Get-or-create home for named metrics.

        reg = MetricsRegistry()
        reg.counter("gp_requests_total", "requests").inc(tenant="maps")
        reg.histogram("gp_request_latency_seconds").observe(0.004, tenant="maps")
        snap = reg.snapshot()          # JSON-able dict of every series

    `enabled=False` (or `reg.disable()`) turns every write into an
    early-return; reads and `snapshot()` keep working on whatever was
    recorded. The process-wide instance is `default_registry()`; tests
    and embedded schedulers pass their own for isolation.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = bool(enabled)
        self._lock = threading.Lock()
        self._metrics: dict[str, _Metric] = {}

    def enable(self):
        self.enabled = True

    def disable(self):
        self.enabled = False

    def _get_or_create(self, cls, name, help, **kw) -> _Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, help, self, **kw)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise TypeError(f"metric {name!r} already registered as "
                                f"{m.kind}, requested {cls.kind}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_create(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get_or_create(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets=None) -> Histogram:
        return self._get_or_create(Histogram, name, help, buckets=buckets)

    def metrics(self) -> list[_Metric]:
        with self._lock:
            return list(self._metrics.values())

    def reset(self):
        """Drop every metric (tests; NOT thread-safe vs concurrent writers
        holding metric references)."""
        with self._lock:
            self._metrics.clear()

    def snapshot(self) -> dict:
        """JSON-able dict of every series; histograms summarized as
        count/sum/min/max plus interpolated p50/p90/p99."""
        out: dict = {}
        for m in self.metrics():
            series = []
            if m.kind == "histogram":
                for labels, s in m.collect():
                    p50, p90, p99 = m.quantiles(0.5, 0.9, 0.99, **labels)
                    series.append({
                        "labels": labels, "count": s["count"],
                        "sum": s["sum"], "min": s["min"], "max": s["max"],
                        "p50": _nan_none(p50), "p90": _nan_none(p90),
                        "p99": _nan_none(p99)})
            else:
                series = [{"labels": labels, "value": v}
                          for labels, v in m.collect()]
            out[m.name] = {"kind": m.kind, "help": m.help, "series": series}
        return out


def _nan_none(v: float):
    return None if v != v else v


_DEFAULT = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    """The process-wide registry every subsystem writes to by default."""
    return _DEFAULT
