"""Request spans: contiguous per-stage timing for scheduler requests.

A `Span` is created at `add_request` and advanced at each stage boundary
of the serving pipeline (queue -> pack -> dispatch -> device -> stitch).
`advance(stage, t)` charges `t - t_last` to `stage` and moves the marker,
so the stages tile the request's lifetime exactly: their sum IS the
end-to-end latency, by construction (the <= 5% acceptance bound in
docs/observability.md holds with zero slack). A request that streams
across several slots re-enters "queue" after each slot's "stitch" — the
inter-slot wait is queueing, and the accounting stays contiguous.

`SpanLog` is the JSONL sink: one line per finished request (see
docs/observability.md for the event schema), safe for concurrent emits.
"""
from __future__ import annotations

import json
import threading
import time

__all__ = ["Span", "SpanLog", "read_spans"]

# canonical stage order of the scheduler pipeline (docs/observability.md)
STAGES = ("queue", "pack", "dispatch", "device", "stitch")


class Span:
    """Per-request stage accumulator (monotonic perf_counter timebase)."""
    __slots__ = ("name", "labels", "t_start", "t_last", "stages")

    def __init__(self, name: str, t: float | None = None, **labels):
        now = time.perf_counter() if t is None else t
        self.name = name
        self.labels = labels
        self.t_start = now
        self.t_last = now
        self.stages: dict[str, float] = {}

    def advance(self, stage: str, t: float | None = None) -> float:
        """Charge the time since the previous boundary to `stage`."""
        now = time.perf_counter() if t is None else t
        dt = now - self.t_last
        self.stages[stage] = self.stages.get(stage, 0.0) + dt
        self.t_last = now
        return dt

    @property
    def elapsed(self) -> float:
        return self.t_last - self.t_start

    def event(self, outcome: str = "ok", **extra) -> dict:
        """The JSONL record for this span (times in ms)."""
        return {
            "event": "request",
            "span": self.name,
            **self.labels,
            "outcome": outcome,
            "e2e_ms": self.elapsed * 1e3,
            "stages_ms": {k: v * 1e3 for k, v in self.stages.items()},
            **extra,
        }


class SpanLog:
    """Append-only JSONL event sink, one `json.dumps` line per emit.

    Accepts a path (opened append) or any object with `write`. `emit` is
    thread-safe; `close` flushes and closes owned files only.
    """

    def __init__(self, path_or_file):
        self._lock = threading.Lock()
        if hasattr(path_or_file, "write"):
            self._fh = path_or_file
            self._owned = False
            self.path = getattr(path_or_file, "name", None)
        else:
            self.path = str(path_or_file)
            self._fh = open(self.path, "a")
            self._owned = True

    def emit(self, event: dict):
        line = json.dumps(event, sort_keys=True)
        with self._lock:
            self._fh.write(line + "\n")

    def close(self):
        with self._lock:
            self._fh.flush()
            if self._owned:
                self._fh.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def read_spans(path: str) -> list[dict]:
    """Parse a SpanLog JSONL file back into event dicts (skips blanks)."""
    events = []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if line:
                events.append(json.loads(line))
    return events
