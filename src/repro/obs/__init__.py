"""repro.obs — the unified, dependency-free observability subsystem.

One layer for the telemetry every other subsystem feeds:

  metrics.py         thread-safe registry: counters, gauges, histograms
                     with geometric-bucket latency sketches (replaces the
                     scheduler's unbounded latency deque); labeled series
                     (tenant, method, slot, shard); `default_registry()`.
  tracing.py         per-request `Span`s through the scheduler pipeline
                     (queue -> pack -> dispatch -> device -> stitch) and
                     the `SpanLog` JSONL sink.
  training_trace.py  `TraceRecorder`, the host-side tap for the ADMM
                     loops' scan-carried diagnostics (per-iteration NLL,
                     primal/dual residuals, theta trajectories) and the
                     engines' DAC/JOR per-round residual capture.
  export.py          Prometheus text dump + parser, and the
                     `/metrics` + `/statusz` HTTP endpoint behind
                     `serve_gp --metrics-port`.

The public surface below is frozen in tools/check_api.py; the catalog of
metric names and span stages is docs/observability.md.
"""
from .export import (MetricsServer, parse_prometheus_text, prometheus_text,
                     start_metrics_server)
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      default_latency_buckets, default_registry)
from .tracing import Span, SpanLog, read_spans
from .training_trace import TraceRecorder

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "default_latency_buckets", "default_registry",
    "Span", "SpanLog", "read_spans",
    "TraceRecorder",
    "prometheus_text", "parse_prometheus_text",
    "MetricsServer", "start_metrics_server",
]
