"""Pytree checkpointing: flattened leaves in a .npz + structure manifest.

Single-host implementation (one .npz per step); on a real multi-host pod each
host would write its addressable shards (process_index suffix) — the format
already namespaces by flattened key so that extension is additive.
"""
from __future__ import annotations

import json
import os
import re

import jax
import numpy as np


def _flatten(tree):
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(kp): np.asarray(v) for kp, v in paths}


def _atomic_publish(tmp_path: str, final_path: str):
    """fsync + rename so a crash mid-save leaves the previous complete file
    (or nothing), never a truncated one. POSIX rename is atomic within a
    filesystem; both paths live in the checkpoint directory."""
    with open(tmp_path, "rb") as f:
        os.fsync(f.fileno())
    os.replace(tmp_path, final_path)


def save_checkpoint(ckpt_dir: str, step: int, tree) -> str:
    """Write the step's leaves (.npz) and manifest.json ATOMICALLY: each
    file lands via temp + rename, arrays before manifest, so every state a
    reader can observe is loadable — either the previous checkpoint intact
    or the new one complete; `restore` rejects the in-between states."""
    os.makedirs(ckpt_dir, exist_ok=True)
    leaves = _flatten(tree)
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    np.savez(path + ".tmp.npz", **leaves)      # np.savez appends .npz itself
    _atomic_publish(path + ".tmp.npz", path)
    treedef = jax.tree.structure(tree)
    mpath = os.path.join(ckpt_dir, "manifest.json")
    with open(mpath + ".tmp", "w") as f:
        json.dump({"treedef": str(treedef), "step": step,
                   "leaves": {k: {"shape": list(v.shape),
                                  "dtype": str(v.dtype)}
                              for k, v in leaves.items()}}, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(mpath + ".tmp", mpath)
    return path


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for fn in os.listdir(ckpt_dir)
             if (m := re.match(r"step_(\d+)\.npz$", fn))]
    return max(steps) if steps else None


def load_checkpoint(ckpt_dir: str, step: int, like_tree):
    """Restore into the structure of `like_tree` (shape/dtype template).

    Unvalidated fast path — a missing leaf surfaces as a bare KeyError and
    shape/dtype drift is NOT detected (a reshaped template silently receives
    the stored array). Prefer `restore`, which checks the stored leaf set
    against the template and fails with a full report.
    """
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    data = np.load(path)
    paths = jax.tree_util.tree_flatten_with_path(like_tree)[0]
    leaves = [data[jax.tree_util.keystr(kp)] for kp, _ in paths]
    treedef = jax.tree.structure(like_tree)
    return jax.tree.unflatten(treedef, leaves)


def restore(ckpt_dir: str, template, step: int | None = None):
    """Validated restore: load `step` (default: latest) into the structure
    of `template` and CHECK every leaf against it.

    The manifest's `str(treedef)` cannot reconstruct a pytree — the caller
    must know the structure — so the contract is: the caller supplies a
    template (arrays or jax.ShapeDtypeStruct leaves) and this function
    guarantees the checkpoint actually matches it. Mismatches fail loudly
    with a full report instead of a bare KeyError / silent shape drift:

      * a template leaf missing from the checkpoint,
      * a stored leaf the template does not expect (structure drift),
      * shape or dtype disagreement on any leaf.

    Returns the template structure with leaves replaced by the stored
    arrays.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint steps in {ckpt_dir!r}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    if not os.path.exists(path):
        raise FileNotFoundError(f"checkpoint {path!r} does not exist")
    data = np.load(path)
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    want = {jax.tree_util.keystr(kp): v for kp, v in paths}
    errors = []
    missing = sorted(set(want) - set(data.files))
    extra = sorted(set(data.files) - set(want))
    if missing:
        errors.append(f"leaves missing from checkpoint: {missing}")
    if extra:
        errors.append(f"stored leaves the template does not expect: {extra}")
    for key in sorted(set(want) & set(data.files)):
        tmpl, stored = want[key], data[key]
        t_shape, t_dtype = tuple(tmpl.shape), np.dtype(tmpl.dtype)
        if t_shape != stored.shape:
            errors.append(f"{key}: template shape {t_shape} != stored "
                          f"{stored.shape}")
        elif t_dtype != stored.dtype:
            errors.append(f"{key}: template dtype {t_dtype} != stored "
                          f"{stored.dtype}")
    if errors:
        raise ValueError(
            f"checkpoint {path!r} does not match the template:\n  "
            + "\n  ".join(errors))
    leaves = [data[jax.tree_util.keystr(kp)] for kp, _ in paths]
    return jax.tree.unflatten(treedef, leaves)
