"""Pytree checkpointing: flattened leaves in a .npz + structure manifest.

Single-host implementation (one .npz per step); on a real multi-host pod each
host would write its addressable shards (process_index suffix) — the format
already namespaces by flattened key so that extension is additive.
"""
from __future__ import annotations

import json
import os
import re

import jax
import numpy as np


def _flatten(tree):
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(kp): np.asarray(v) for kp, v in paths}


def save_checkpoint(ckpt_dir: str, step: int, tree) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    leaves = _flatten(tree)
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    np.savez(path, **leaves)
    treedef = jax.tree.structure(tree)
    with open(os.path.join(ckpt_dir, "manifest.json"), "w") as f:
        json.dump({"treedef": str(treedef), "step": step}, f)
    return path


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for fn in os.listdir(ckpt_dir)
             if (m := re.match(r"step_(\d+)\.npz$", fn))]
    return max(steps) if steps else None


def load_checkpoint(ckpt_dir: str, step: int, like_tree):
    """Restore into the structure of `like_tree` (shape/dtype template)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    data = np.load(path)
    paths = jax.tree_util.tree_flatten_with_path(like_tree)[0]
    leaves = [data[jax.tree_util.keystr(kp)] for kp, _ in paths]
    treedef = jax.tree.structure(like_tree)
    return jax.tree.unflatten(treedef, leaves)
