"""Decentralized GP prediction (paper §5): the 13 methods at three layers.

  per-call wrappers   dec_* / cbnn_* / local_moments / npae_terms — original
                      raw-data signatures, refactorize every call (reference
                      semantics the engines are tested against)
  `*_cached`          consume precomputed Cholesky factors (FittedExperts)
  `*_from_moments` /  the consensus + aggregation cores on precomputed local
  `*_from_terms`      quantities (what both engines feed)

Serving front-ends: PredictionEngine (replicated fleet, all 13 methods +
centralized references) and ShardedEngine (fleet sharded over the agent
axis of a device mesh, DAC family + CBNN query routing).

The agent-facing lifecycle API over all of this is `repro.fleet`
(FleetConfig + GPFleet): method names and per-method capability flags
(shardable / routable / online-safe / needs-augmented-data) live in its
`METHODS` registry, which tests assert stays in lockstep with the engine
METHODS tuples here. This module's surface is frozen by
tools/check_api.py.
"""
from .local import (local_moments, npae_terms, chol_factors, cross_gram,
                    local_moments_cached, npae_terms_cached, stream_means)
from .aggregation import poe, gpoe, bcm, rbcm, grbcm, npae
from .cbnn import (cbnn_scores, cbnn_mask, cbnn_scores_cached,
                   cbnn_mask_cached)
from .decentralized import (dec_poe, dec_gpoe, dec_bcm, dec_rbcm, dec_grbcm,
                            dec_npae, dec_npae_star, dec_nn_poe, dec_nn_gpoe,
                            dec_nn_bcm, dec_nn_rbcm, dec_nn_grbcm,
                            dec_nn_npae, dec_poe_from_moments,
                            dec_gpoe_from_moments, dec_bcm_from_moments,
                            dec_rbcm_from_moments, dec_grbcm_from_moments,
                            dec_npae_from_terms, dec_npae_star_from_terms,
                            dec_nn_npae_from_terms)
from .engine import (FittedExperts, fit_experts, map_query_tiles,
                     PredictionEngine)
from .sharded import (ShardedEngine, expert_specs, replicated_specs,
                      shard_experts)

__all__ = [
    "local_moments", "npae_terms", "chol_factors", "cross_gram",
    "local_moments_cached", "npae_terms_cached", "stream_means",
    "poe", "gpoe", "bcm", "rbcm", "grbcm", "npae",
    "cbnn_scores", "cbnn_mask", "cbnn_scores_cached", "cbnn_mask_cached",
    "dec_poe", "dec_gpoe", "dec_bcm", "dec_rbcm", "dec_grbcm",
    "dec_npae", "dec_npae_star", "dec_nn_poe", "dec_nn_gpoe",
    "dec_nn_bcm", "dec_nn_rbcm", "dec_nn_grbcm", "dec_nn_npae",
    "dec_poe_from_moments", "dec_gpoe_from_moments", "dec_bcm_from_moments",
    "dec_rbcm_from_moments", "dec_grbcm_from_moments", "dec_npae_from_terms",
    "dec_npae_star_from_terms", "dec_nn_npae_from_terms",
    "FittedExperts", "fit_experts", "map_query_tiles", "PredictionEngine",
    "ShardedEngine", "expert_specs", "replicated_specs", "shard_experts",
]
