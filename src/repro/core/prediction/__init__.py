from .local import (local_moments, npae_terms, chol_factors, cross_gram,
                    local_moments_cached, npae_terms_cached, stream_means)
from .aggregation import poe, gpoe, bcm, rbcm, grbcm, npae
from .cbnn import (cbnn_scores, cbnn_mask, cbnn_scores_cached,
                   cbnn_mask_cached)
from .decentralized import (dec_poe, dec_gpoe, dec_bcm, dec_rbcm, dec_grbcm,
                            dec_npae, dec_npae_star, dec_nn_poe, dec_nn_gpoe,
                            dec_nn_bcm, dec_nn_rbcm, dec_nn_grbcm,
                            dec_nn_npae, dec_poe_from_moments,
                            dec_gpoe_from_moments, dec_bcm_from_moments,
                            dec_rbcm_from_moments, dec_grbcm_from_moments,
                            dec_npae_from_terms, dec_npae_star_from_terms,
                            dec_nn_npae_from_terms)
from .engine import (FittedExperts, fit_experts, map_query_tiles,
                     PredictionEngine)

__all__ = [
    "local_moments", "npae_terms", "chol_factors", "cross_gram",
    "local_moments_cached", "npae_terms_cached", "stream_means",
    "poe", "gpoe", "bcm", "rbcm", "grbcm", "npae",
    "cbnn_scores", "cbnn_mask", "cbnn_scores_cached", "cbnn_mask_cached",
    "dec_poe", "dec_gpoe", "dec_bcm", "dec_rbcm", "dec_grbcm",
    "dec_npae", "dec_npae_star", "dec_nn_poe", "dec_nn_gpoe",
    "dec_nn_bcm", "dec_nn_rbcm", "dec_nn_grbcm", "dec_nn_npae",
    "dec_poe_from_moments", "dec_gpoe_from_moments", "dec_bcm_from_moments",
    "dec_rbcm_from_moments", "dec_grbcm_from_moments", "dec_npae_from_terms",
    "dec_npae_star_from_terms", "dec_nn_npae_from_terms",
    "FittedExperts", "fit_experts", "map_query_tiles", "PredictionEngine",
]
