from .local import local_moments, npae_terms
from .aggregation import poe, gpoe, bcm, rbcm, grbcm, npae
from .cbnn import cbnn_scores, cbnn_mask
from .decentralized import (dec_poe, dec_gpoe, dec_bcm, dec_rbcm, dec_grbcm,
                            dec_npae, dec_npae_star, dec_nn_poe, dec_nn_gpoe,
                            dec_nn_bcm, dec_nn_rbcm, dec_nn_grbcm, dec_nn_npae)

__all__ = [
    "local_moments", "npae_terms",
    "poe", "gpoe", "bcm", "rbcm", "grbcm", "npae",
    "cbnn_scores", "cbnn_mask",
    "dec_poe", "dec_gpoe", "dec_bcm", "dec_rbcm", "dec_grbcm",
    "dec_npae", "dec_npae_star", "dec_nn_poe", "dec_nn_gpoe",
    "dec_nn_bcm", "dec_nn_rbcm", "dec_nn_grbcm", "dec_nn_npae",
]
