"""Local GP sub-model moments (paper eq. 10-11) and NPAE local quantities
(eq. 18-19), vmapped over the agent axis.

Two layers (see prediction/engine.py for the serving front-end):

  factor level — `chol_factors` computes each agent's Cholesky L_i and weight
  vector alpha_i = C_i^{-1} y_i ONCE after training; the `*_cached` functions
  consume precomputed factors, so repeated query batches never re-factorize
  the (Ni, Ni) kernel matrices. This is the Rulliere et al.-style fit-once /
  serve-many split every nested-aggregation implementation assumes.

  per-call wrappers — `local_moments` / `npae_terms` keep the original
  fit-and-predict-in-one-call signatures; they are the reference path the
  cached engine is tested against.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...kernels.ops import rbf_matvec
from ..gp.kernel import se_kernel, unpack


def _chol(X, log_theta, jitter=1e-8):
    ls, sigma_f, sigma_eps = unpack(log_theta)
    n = X.shape[0]
    C = se_kernel(X, X, log_theta) + (sigma_eps**2 + jitter) * jnp.eye(n, dtype=X.dtype)
    return jnp.linalg.cholesky(C)


def chol_factors(log_theta, Xp, yp, jitter=1e-8):
    """Per-agent factors, computed once after training.

    Xp (M, Ni, D), yp (M, Ni) -> (L (M, Ni, Ni), alpha (M, Ni)) with
    L_i = chol(K(X_i, X_i) + sigma_eps^2 I) and alpha_i = C_i^{-1} y_i.
    """
    def one(Xi, yi):
        L = _chol(Xi, log_theta, jitter)
        return L, jax.scipy.linalg.cho_solve((L, True), yi)

    return jax.vmap(one)(Xp, yp)


def stream_means(log_theta, Xp, alpha, Xs):
    """Per-agent posterior means (the eq. 10 mean term) via the fused
    Gram-matvec kernel — `*_cached` engine layer, mean-only hot path.

    mu_i = k(Xs, X_i) alpha_i with O(Ni + Nt) transient memory — the
    streaming Pallas path on TPU (kernels.rbf_matvec), jnp reference on CPU.
    Returns (M, Nt).
    """
    ls, sigma_f, _ = unpack(log_theta)
    mu = jax.vmap(lambda Xi, ai: rbf_matvec(Xs, Xi, ai, ls, sigma_f))(Xp, alpha)
    return mu.astype(Xs.dtype)


def local_moments_cached(log_theta, Xp, L, alpha, Xs,
                         stream_mean: bool = False):
    """Local GP moments (eq. 10-11) from precomputed factors — the
    `*_cached` engine layer. mu_i, var_i at test points -> (M, Nt) each.

    `stream_mean=True` routes the mean term through the fused Gram-matvec
    (the serving hot path); the variance term still needs the triangular
    solve against the cached factor either way.
    """
    _, sigma_f, _ = unpack(log_theta)
    kss = sigma_f**2

    def one(Xi, Li, ai):
        ks = se_kernel(Xi, Xs, log_theta)                       # (Ni, Nt)
        v = jax.scipy.linalg.solve_triangular(Li, ks, lower=True)
        var = jnp.maximum(kss - jnp.sum(v * v, axis=0), 1e-12)
        return ks.T @ ai, var

    if stream_mean:
        # XLA dead-code-eliminates the unused dense mean matmul here.
        var = jax.vmap(lambda Xi, Li, ai: one(Xi, Li, ai)[1])(Xp, L, alpha)
        return stream_means(log_theta, Xp, alpha, Xs), var
    return jax.vmap(one)(Xp, L, alpha)


def cross_gram(log_theta, Xp):
    """All cross-agent Gram blocks K(X_i, X_j) -> (M, M, Ni, Ni).

    O(M^2 Ni^2) memory — `fit_experts(cache_cross=True)` guards the
    estimate before materializing; `npae_terms_cached` consumes it to skip
    the per-query-batch cross-covariance assembly (the NPAE serving
    bottleneck, see ROADMAP).
    """
    return jax.vmap(lambda Xi: jax.vmap(
        lambda Xj: se_kernel(Xi, Xj, log_theta))(Xp))(Xp)


def npae_terms_cached(log_theta, Xp, L, alpha, Xs, Kcross=None):
    """NPAE aggregation terms (paper eq. 18-21 context) from cached factors.

    Returns (mu (M,Nt), k_A (M,Nt), C_A (Nt,M,M)) where
      [k_A]_i      = k_{i,*}^T C_i^-1 k_{i,*}                       (eq. 18)
      [C_A]_ij     = k_{i,*}^T C_i^-1 K(X_i,X_j) C_j^-1 k_{j,*}, i != j
      [C_A]_ii     = [k_A]_i
    NOTE: the paper's eq. (19) literally reads C_ij C_ij^-1 (= I), an obvious
    typo; we implement the Rulliere et al. / Bachoc et al. covariance
    Cov(mu_i, mu_j) above. Off-diagonal blocks use the noise-free K(X_i, X_j)
    because measurement noise is iid across disjoint local datasets.

    `Kcross` (M, M, Ni, Ni), when given (see `cross_gram` /
    `fit_experts(cache_cross=True)`), replaces the per-call off-diagonal
    Gram assembly — the dominant NPAE serving cost at large Ni.
    """
    M = Xp.shape[0]

    def solve_one(Xi, Li, ai):
        ks = se_kernel(Xi, Xs, log_theta)                       # (Ni, Nt)
        w = jax.scipy.linalg.cho_solve((Li, True), ks)          # C_i^-1 k_i*
        mu = ks.T @ ai                                           # (Nt,)
        kA = jnp.sum(ks * w, axis=0)                             # (Nt,)
        return mu, kA, w

    mu, kA, W = jax.vmap(solve_one)(Xp, L, alpha)                # W (M, Ni, Nt)

    def cross(i, j):
        Kij = (se_kernel(Xp[i], Xp[j], log_theta) if Kcross is None
               else Kcross[i, j])                                # (Ni, Nj)
        return jnp.einsum("it,ij,jt->t", W[i], Kij, W[j])        # (Nt,)

    idx = jnp.arange(M)
    CA = jax.vmap(lambda i: jax.vmap(lambda j: cross(i, j))(idx))(idx)  # (M,M,Nt)
    CA = jnp.moveaxis(CA, -1, 0)                                 # (Nt, M, M)
    # exact diagonal = k_A (includes the C_i^-1 through-noise path once)
    CA = CA.at[:, idx, idx].set(kA.T)
    return mu, kA, CA


def local_moments(log_theta, Xp, yp, Xs, jitter=1e-8):
    """Per-call wrapper (factorize-then-predict) for eq. 10-11.
    Xp (M,Ni,D), Xs (Nt,D) -> (mu, var), each (M, Nt)."""
    L, alpha = chol_factors(log_theta, Xp, yp, jitter)
    return local_moments_cached(log_theta, Xp, L, alpha, Xs)


def npae_terms(log_theta, Xp, yp, Xs, jitter=1e-8):
    """Per-call wrapper around `npae_terms_cached` (see its docstring)."""
    L, alpha = chol_factors(log_theta, Xp, yp, jitter)
    return npae_terms_cached(log_theta, Xp, L, alpha, Xs)
