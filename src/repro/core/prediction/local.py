"""Local GP sub-model moments (paper eq. 10-11) and NPAE local quantities
(eq. 18-19), vmapped over the agent axis."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..gp.kernel import se_kernel, unpack


def _chol(X, log_theta, jitter=1e-8):
    ls, sigma_f, sigma_eps = unpack(log_theta)
    n = X.shape[0]
    C = se_kernel(X, X, log_theta) + (sigma_eps**2 + jitter) * jnp.eye(n, dtype=X.dtype)
    return jnp.linalg.cholesky(C)


def local_moments(log_theta, Xp, yp, Xs, jitter=1e-8):
    """mu_i, var_i at test points. Xp (M,Ni,D), Xs (Nt,D) -> (M,Nt) each."""
    _, sigma_f, _ = unpack(log_theta)
    kss = sigma_f**2

    def one(Xi, yi):
        L = _chol(Xi, log_theta, jitter)
        ks = se_kernel(Xi, Xs, log_theta)                       # (Ni, Nt)
        alpha = jax.scipy.linalg.cho_solve((L, True), yi)
        mu = ks.T @ alpha
        v = jax.scipy.linalg.solve_triangular(L, ks, lower=True)
        var = kss - jnp.sum(v * v, axis=0)
        return mu, jnp.maximum(var, 1e-12)

    return jax.vmap(one)(Xp, yp)


def npae_terms(log_theta, Xp, yp, Xs, jitter=1e-8):
    """NPAE aggregation terms (paper eq. 18-21 context).

    Returns (mu (M,Nt), k_A (M,Nt), C_A (Nt,M,M)) where
      [k_A]_i      = k_{i,*}^T C_i^-1 k_{i,*}                       (eq. 18)
      [C_A]_ij     = k_{i,*}^T C_i^-1 K(X_i,X_j) C_j^-1 k_{j,*}, i != j
      [C_A]_ii     = [k_A]_i
    NOTE: the paper's eq. (19) literally reads C_ij C_ij^-1 (= I), an obvious
    typo; we implement the Rulliere et al. / Bachoc et al. covariance
    Cov(mu_i, mu_j) above. Off-diagonal blocks use the noise-free K(X_i, X_j)
    because measurement noise is iid across disjoint local datasets.
    """
    M = Xp.shape[0]

    def solve_one(Xi, yi):
        L = _chol(Xi, log_theta, jitter)
        ks = se_kernel(Xi, Xs, log_theta)                       # (Ni, Nt)
        w = jax.scipy.linalg.cho_solve((L, True), ks)           # C_i^-1 k_i*
        alpha = jax.scipy.linalg.cho_solve((L, True), yi)
        mu = ks.T @ alpha                                        # (Nt,)
        kA = jnp.sum(ks * w, axis=0)                             # (Nt,)
        return mu, kA, w

    mu, kA, W = jax.vmap(solve_one)(Xp, yp)                      # W (M, Ni, Nt)

    def cross(i, j):
        Kij = se_kernel(Xp[i], Xp[j], log_theta)                 # (Ni, Nj)
        return jnp.einsum("it,ij,jt->t", W[i], Kij, W[j])        # (Nt,)

    idx = jnp.arange(M)
    CA = jax.vmap(lambda i: jax.vmap(lambda j: cross(i, j))(idx))(idx)  # (M,M,Nt)
    CA = jnp.moveaxis(CA, -1, 0)                                 # (Nt, M, M)
    # exact diagonal = k_A (includes the C_i^-1 through-noise path once)
    CA = CA.at[:, idx, idx].set(kA.T)
    return mu, kA, CA
