"""Covariance-based nearest-neighbor agent selection (paper §5.2, eq. 39).

[k_mu,*]_i = k_{i,*}^T C_i^-1 k_{i,*} measures the statistical correlation of
agent i's dataset to the query point; agents below eta_NN sit out the
aggregation. Computed from purely local quantities (Assumption 2 holds).
Note eq. (39) coincides with the NPAE cross-covariance (eq. 18).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..gp.kernel import se_kernel, unpack
from .local import _chol


def cbnn_scores(log_theta, Xp, Xs, jitter=1e-8):
    """(M, Nt) correlation scores [k_mu,*]_i per agent per query."""
    def one(Xi):
        L = _chol(Xi, log_theta, jitter)
        ks = se_kernel(Xi, Xs, log_theta)
        w = jax.scipy.linalg.cho_solve((L, True), ks)
        return jnp.sum(ks * w, axis=0)
    return jax.vmap(one)(Xp)


def cbnn_mask(log_theta, Xp, Xs, eta_nn: float, jitter=1e-8):
    """Boolean participation mask (M, Nt); guarantees >= 1 agent per query."""
    scores = cbnn_scores(log_theta, Xp, Xs, jitter)
    mask = scores >= eta_nn
    # never let a query end up with zero experts: keep the best agent
    best = jnp.argmax(scores, axis=0)
    mask = mask.at[best, jnp.arange(Xs.shape[0])].set(True)
    return mask, scores
