"""Covariance-based nearest-neighbor agent selection (paper §5.2, eq. 39).

[k_mu,*]_i = k_{i,*}^T C_i^-1 k_{i,*} measures the statistical correlation of
agent i's dataset to the query point; agents below eta_NN sit out the
aggregation. Computed from purely local quantities (Assumption 2 holds).
Note eq. (39) coincides with the NPAE cross-covariance (eq. 18), which also
means the score equals sigma_f^2 - var_i: CBNN selects exactly the agents
whose local posterior variance at the query is smallest.

Like prediction.local, this is split into a factor-cached layer (`*_cached`,
reusing each agent's Cholesky across query batches — see prediction/engine)
and thin per-call wrappers with the original signatures. The agent-sharded
serving engine (prediction/sharded.py) computes the scores shard-locally and
closes the >= 1-agent guarantee with an exact ring max
(consensus.ring_allmax), which is why `_mask_from_scores` keeps the
best-score agents via a max comparison rather than a positional argmax.

Layers:
  cbnn_scores_cached / cbnn_mask_cached — factor-cached (engine serving path)
  cbnn_scores / cbnn_mask               — per-call wrappers (refactorize)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..gp.kernel import se_kernel
from .local import _chol


def cbnn_scores_cached(log_theta, Xp, L, Xs):
    """(M, Nt) correlation scores [k_mu,*]_i (eq. 39) from precomputed
    factors — the `*_cached` engine layer (no refactorization per call)."""
    def one(Xi, Li):
        ks = se_kernel(Xi, Xs, log_theta)
        w = jax.scipy.linalg.cho_solve((Li, True), ks)
        return jnp.sum(ks * w, axis=0)

    return jax.vmap(one)(Xp, L)


def _mask_from_scores(scores, eta_nn: float):
    """Threshold scores (eq. 39); guarantee >= 1 agent per query.

    The guarantee keeps every agent achieving the per-query maximum score
    (ties — a measure-zero event on real data — keep all tied agents).
    Max-equality rather than argmax so the sharded engine can reproduce the
    mask exactly from shard-local scores plus one exact ring max.
    """
    best = scores >= jnp.max(scores, axis=0, keepdims=True)
    return (scores >= eta_nn) | best


def cbnn_mask_cached(log_theta, Xp, L, Xs, eta_nn: float):
    """Boolean participation mask (M, Nt) from precomputed factors
    (`*_cached` engine layer); returns (mask, scores)."""
    scores = cbnn_scores_cached(log_theta, Xp, L, Xs)
    return _mask_from_scores(scores, eta_nn), scores


def cbnn_scores(log_theta, Xp, Xs, jitter=1e-8):
    """(M, Nt) correlation scores [k_mu,*]_i (eq. 39) per agent per query.
    Per-call wrapper: factorizes every agent, then scores."""
    L = jax.vmap(lambda Xi: _chol(Xi, log_theta, jitter))(Xp)
    return cbnn_scores_cached(log_theta, Xp, L, Xs)


def cbnn_mask(log_theta, Xp, Xs, eta_nn: float, jitter=1e-8):
    """Boolean participation mask (M, Nt) (eq. 39 thresholded at eta_nn);
    guarantees >= 1 agent per query. Per-call wrapper."""
    scores = cbnn_scores(log_theta, Xp, Xs, jitter)
    return _mask_from_scores(scores, eta_nn), scores
