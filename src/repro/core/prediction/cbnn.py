"""Covariance-based nearest-neighbor agent selection (paper §5.2, eq. 39).

[k_mu,*]_i = k_{i,*}^T C_i^-1 k_{i,*} measures the statistical correlation of
agent i's dataset to the query point; agents below eta_NN sit out the
aggregation. Computed from purely local quantities (Assumption 2 holds).
Note eq. (39) coincides with the NPAE cross-covariance (eq. 18).

Like prediction.local, this is split into a factor-cached layer (`*_cached`,
reusing each agent's Cholesky across query batches — see prediction/engine)
and thin per-call wrappers with the original signatures.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..gp.kernel import se_kernel
from .local import _chol


def cbnn_scores_cached(log_theta, Xp, L, Xs):
    """(M, Nt) correlation scores [k_mu,*]_i from precomputed factors."""
    def one(Xi, Li):
        ks = se_kernel(Xi, Xs, log_theta)
        w = jax.scipy.linalg.cho_solve((Li, True), ks)
        return jnp.sum(ks * w, axis=0)

    return jax.vmap(one)(Xp, L)


def _mask_from_scores(scores, eta_nn: float):
    """Threshold scores; guarantee >= 1 agent per query (keep the best)."""
    mask = scores >= eta_nn
    best = jnp.argmax(scores, axis=0)
    mask = mask.at[best, jnp.arange(scores.shape[1])].set(True)
    return mask


def cbnn_mask_cached(log_theta, Xp, L, Xs, eta_nn: float):
    """Boolean participation mask (M, Nt) from precomputed factors."""
    scores = cbnn_scores_cached(log_theta, Xp, L, Xs)
    return _mask_from_scores(scores, eta_nn), scores


def cbnn_scores(log_theta, Xp, Xs, jitter=1e-8):
    """(M, Nt) correlation scores [k_mu,*]_i per agent per query."""
    L = jax.vmap(lambda Xi: _chol(Xi, log_theta, jitter))(Xp)
    return cbnn_scores_cached(log_theta, Xp, L, Xs)


def cbnn_mask(log_theta, Xp, Xs, eta_nn: float, jitter=1e-8):
    """Boolean participation mask (M, Nt); guarantees >= 1 agent per query."""
    scores = cbnn_scores(log_theta, Xp, Xs, jitter)
    return _mask_from_scores(scores, eta_nn), scores
