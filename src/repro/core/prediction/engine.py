"""Factor-cached, query-tiled prediction engine — the serving hot path.

The paper's decentralized prediction methods (§5, Algs. 5-18) all consume the
same per-agent local quantities. The per-call functions re-factorize each
agent's (Ni, Ni) kernel matrix on EVERY request and materialize the full
(Nt, M, M) NPAE covariance tensor all at once, so prediction cannot scale in
the number of queries. Nested-aggregation practice (Rulliere et al.; the
grBCM line of Liu et al.) fits the experts once and serves from cached
factors; this module does the same:

  FittedExperts   — per-agent Cholesky L_i and weights alpha_i = C_i^{-1} y_i,
                    computed once after training (`fit_experts`). A jit-able
                    pytree (NamedTuple of arrays).
  map_query_tiles — lax.map over fixed-size query chunks: sequential tiles
                    bound peak memory at O(chunk * M^2) for the NPAE family
                    and O(chunk * M) for the PoE family at ANY Nt.
  PredictionEngine — serving front-end: all 13 decentralized methods plus the
                    centralized references behind one jit-cached `predict`.
                    With `stream_mean=True` posterior means ride the fused
                    Gram-matvec Pallas kernel (kernels.rbf_matvec).

This engine runs the fleet REPLICATED on one device (and is the only server
of the NPAE family, whose per-query (M, M) solves need strongly-complete
exchange). Its multi-device sibling is prediction/sharded.ShardedEngine:
the same FittedExperts sharded over the agent axis of a mesh, consensus on
the device ring, plus CBNN query routing (docs/serving_sharded.md).

Equivalence with the per-call paths is covered by tests/test_engine.py
(<= 1e-6 for every method).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

import numpy as np

from ...obs import default_registry
from ..consensus.degraded import ConsensusDiverged, dac_masked_sums
from ..consensus.graph import connected_components
from ..gp.kernel import unpack
from . import aggregation as agg
from ..sparse import (SparseExperts, npae_terms_lowrank,
                      sparse_moments_cached, sparse_scores)
from .cbnn import _mask_from_scores, cbnn_mask_cached
from .decentralized import (dec_poe_from_moments, dec_gpoe_from_moments,
                            dec_bcm_from_moments, dec_rbcm_from_moments,
                            dec_grbcm_from_moments, dec_npae_from_terms,
                            dec_npae_star_from_terms, dec_nn_npae_from_terms)
from .local import (chol_factors, cross_gram, local_moments_cached,
                    npae_terms_cached, stream_means)


class FittedExperts(NamedTuple):
    """Per-agent state computed once after training (a jit-able pytree)."""
    log_theta: jax.Array   # (D+2,)
    Xp: jax.Array          # (M, Ni, D)
    yp: jax.Array          # (M, Ni)
    L: jax.Array           # (M, Ni, Ni)  chol(K(X_i, X_i) + sigma_eps^2 I)
    alpha: jax.Array       # (M, Ni)      C_i^{-1} y_i
    Kcross: jax.Array | None = None   # (M, M, Ni, Ni) cross-agent Gram
    #                                   blocks (fit_experts cache_cross=True)

    @property
    def num_agents(self) -> int:
        return self.Xp.shape[0]

    @property
    def prior_var(self) -> jax.Array:
        _, sigma_f, _ = unpack(self.log_theta)
        return sigma_f**2


def fit_experts(log_theta, Xp, yp, jitter: float = 1e-8,
                cache_cross: bool = False,
                cross_cache_limit_mb: float = 1024.0) -> FittedExperts:
    """Factorize every agent's kernel matrix ONCE; reused by all methods.

    `cache_cross=True` additionally precomputes the (M, M, Ni, Ni)
    cross-agent Gram blocks the NPAE family re-assembles on every query
    batch, trading O(M^2 Ni^2) memory for the dominant per-request cost.
    The estimate is guarded against `cross_cache_limit_mb` at trace time
    (shapes are static); raise the limit explicitly for big fleets. Note
    `cache_cross` is a Python-level flag: under jit, close over it
    (functools.partial) rather than passing it as a traced argument.
    """
    L, alpha = chol_factors(log_theta, Xp, yp, jitter)
    Kcross = None
    if cache_cross:
        M, Ni = Xp.shape[0], Xp.shape[1]
        est_bytes = M * M * Ni * Ni * jnp.dtype(Xp.dtype).itemsize
        if est_bytes / 2**20 > cross_cache_limit_mb:
            raise ValueError(
                f"cache_cross would materialize {est_bytes:,} bytes "
                f"({est_bytes / 2**20:.2f} MB) of cross-agent Gram blocks "
                f"(M={M}, Ni={Ni}) > limit {cross_cache_limit_mb:.0f} MB; "
                f"raise cross_cache_limit_mb, serve without the cache, or "
                f"serve the NPAE family from sparse pseudo-representations "
                f"instead — FleetConfig(sparse_m=...) with method "
                f"'npae_sparse' needs no cross-Gram at all "
                f"(docs/sparse_experts.md)")
        Kcross = cross_gram(log_theta, Xp)
    return FittedExperts(log_theta, Xp, yp, L, alpha, Kcross)


def map_query_tiles(tile_fn, Xs, chunk: int):
    """Apply `tile_fn((chunk, D)) -> (per_query_tree, reduced_tree)` over
    fixed-size query tiles with lax.map (sequential => bounded peak memory).

    per_query_tree leaves must have leading axis `chunk`; they are stitched
    along the query axis and the padding tail is stripped. reduced_tree
    leaves are combined with an elementwise max over tiles (residual
    semantics: report the worst tile).
    """
    Nt, D = Xs.shape
    n_tiles = -(-Nt // chunk)
    pad = n_tiles * chunk - Nt
    # edge-replicate the tail: padded slots duplicate the LAST REAL query, so
    # the max-reduced residuals describe the served workload, never a
    # synthetic X=0 point
    padded = jnp.pad(Xs, ((0, pad), (0, 0)), mode="edge")
    if n_tiles == 1:
        # single tile: skip the scan (lets XLA fuse across the whole batch)
        perq, reduced = tile_fn(padded)
        return jax.tree.map(lambda a: a[:Nt], perq), reduced
    perq, reduced = jax.lax.map(tile_fn, padded.reshape(n_tiles, chunk, D))
    perq = jax.tree.map(
        lambda a: a.reshape((n_tiles * chunk,) + a.shape[2:])[:Nt], perq)
    reduced = jax.tree.map(lambda a: jnp.max(a, axis=0), reduced)
    return perq, reduced


_DAC_CORES = {"poe": dec_poe_from_moments, "gpoe": dec_gpoe_from_moments,
              "bcm": dec_bcm_from_moments, "rbcm": dec_rbcm_from_moments}


class PredictionEngine:
    """Serving front-end over FittedExperts: jit-cached, query-tiled methods.

    Decentralized: poe gpoe bcm rbcm grbcm npae npae_star and the CBNN
    variants nn_poe nn_gpoe nn_bcm nn_rbcm nn_grbcm nn_npae.
    Centralized references: cen_poe cen_gpoe cen_bcm cen_rbcm cen_grbcm
    cen_npae.

    The grbcm variants additionally require `fitted_aug` (augmented experts)
    and `fitted_comm` (the communication expert as a 1-agent FittedExperts) —
    paper eq. 16-17; CBNN scores always come from the BASE local datasets
    (eq. 39 is defined on D_i).

    One compiled program per (method, query-batch geometry): repeated
    requests with the same Nt reuse the jit cache, and `chunk`-sized tiles
    bound peak memory at any Nt. Configuration attributes are baked at first
    `predict` per method — mutate the engine only through `swap_experts`
    (same-shape factor hot-swap, keeps every compiled program: the experts
    are a traced ARGUMENT of the cached jits) and `rewire` (membership /
    topology change, drops the compiled cache because A and M are baked
    into the traces).
    """

    METHODS = ("poe", "gpoe", "bcm", "rbcm", "grbcm", "npae", "npae_star",
               "nn_poe", "nn_gpoe", "nn_bcm", "nn_rbcm", "nn_grbcm",
               "nn_npae", "npae_sparse", "cen_poe", "cen_gpoe", "cen_bcm",
               "cen_rbcm", "cen_grbcm", "cen_npae")

    # exact-NPAE members that need the dense cross-Gram and therefore can
    # never serve from SparseExperts (npae_sparse is their low-rank stand-in)
    _DENSE_ONLY = ("npae", "npae_star", "nn_npae", "cen_npae")

    def __init__(self, fitted: FittedExperts, A, *, chunk: int = 256,
                 dac_iters: int = 200, jor_iters: int = 500,
                 dale_iters: int = 2000, pm_iters: int = 100,
                 eta_nn: float = 0.1, npae_jitter: float = 1e-6,
                 fitted_aug: FittedExperts | None = None,
                 fitted_comm: FittedExperts | None = None,
                 stream_mean: bool = False, degraded_tol: float = 1e-2):
        self.fitted = fitted
        self.A = A
        self.chunk = int(chunk)
        self.dac_iters = int(dac_iters)
        self.jor_iters = int(jor_iters)
        self.dale_iters = int(dale_iters)
        self.pm_iters = int(pm_iters)
        self.eta_nn = float(eta_nn)
        self.npae_jitter = float(npae_jitter)
        self.fitted_aug = fitted_aug
        self.fitted_comm = fitted_comm
        self.stream_mean = bool(stream_mean)
        self.degraded_tol = float(degraded_tol)
        self.diagnostics = False
        self._compiled: dict[str, object] = {}
        self._chaos_cache: dict = {}      # FaultPlan -> derived mask arrays
        self._trace_count = 0
        reg = default_registry()
        self._traces_total = reg.counter(
            "gp_jit_traces_total", "engine traces (compiled programs), by "
            "engine and method")
        self._degraded_total = reg.counter(
            "gp_degraded_predictions_total", "predictions served in degraded "
            "mode (dropped agents / partitions / scrubbed payloads)")
        self._diverged_total = reg.counter(
            "gp_consensus_diverged_total", "predictions that raised "
            "ConsensusDiverged (residual or finiteness guard)")
        self._scrubbed_gauge = reg.gauge(
            "gp_scrubbed_payloads", "agents with non-finite consensus "
            "payloads scrubbed in the last degraded prediction")
        self._alive_gauge = reg.gauge(
            "gp_alive_agents", "agents alive at the last degraded "
            "prediction's readout")

    # -- per-tile computation ------------------------------------------------

    def _moments(self, f, Xq):
        """Local expert moments — isinstance dispatch is what lets every
        PoE/BCM/CBNN aggregation serve dense and sparse fleets from the
        same engine (the shapes differ, the (M, Nt) contract does not)."""
        if isinstance(f, SparseExperts):
            return sparse_moments_cached(f.log_theta, f.Z, f.Lmm, f.LS, f.c,
                                         Xq, stream_mean=self.stream_mean)
        return local_moments_cached(f.log_theta, f.Xp, f.L, f.alpha, Xq,
                                    stream_mean=self.stream_mean)

    def _mask(self, f, Xq):
        """CBNN participation mask (eq. 39) from dense or sparse factors —
        both score forms equal sigma_f^2 - var_i, so eta_nn thresholds are
        directly comparable across expert representations."""
        if isinstance(f, SparseExperts):
            return _mask_from_scores(
                sparse_scores(f.log_theta, f.Z, f.Lmm, f.LS, Xq),
                self.eta_nn)
        return cbnn_mask_cached(f.log_theta, f.Xp, f.L, Xq, self.eta_nn)[0]

    def _terms(self, f: FittedExperts, Xq):
        return npae_terms_cached(f.log_theta, f.Xp, f.L, f.alpha, Xq,
                                 Kcross=f.Kcross)

    def _tile(self, method: str, f, fa, fc, Xq, chaos=None):
        A, pv = self.A, f.prior_var
        nn = method.startswith("nn_")
        base = method[3:] if nn else method
        mask = None
        if nn:
            mask = self._mask(f, Xq)
        red = {}
        dac_fn = None

        def degrade(mu, var, m):
            """Chaos payload stage: inject the plan's NaN corruption, then
            SCRUB — non-finite per-agent payloads are zeroed, excluded
            from the participation mask, and counted — so corruption can
            never reach the aggregation arithmetic silently."""
            mu = jnp.where(chaos["corrupt"][:, None], jnp.nan, mu)
            ok = jnp.isfinite(mu) & jnp.isfinite(var)
            eligible = chaos["payload"][:, None] > 0
            red["scrubbed"] = jnp.sum(jnp.any(~ok & eligible, axis=1)
                                      ).astype(mu.dtype)
            m2 = chaos["payload"][:, None] * ok.astype(mu.dtype)
            if m is not None:
                m2 = m2 * jnp.broadcast_to(m, mu.shape).astype(mu.dtype)
            return jnp.where(ok, mu, 0.0), jnp.where(ok, var, pv), m2

        if chaos is not None:
            dac_fn = lambda w0, A_, iters: dac_masked_sums(
                w0, A_, chaos["alive_seq"], chaos["readout"],
                chaos["n_relay"], edge_seq=chaos.get("edge_seq"))

        if base in _DAC_CORES:
            mu, var = self._moments(f, Xq)
            if chaos is not None:
                mu, var, mask = degrade(mu, var, mask)
            mean, v, info = _DAC_CORES[base](mu, var, pv, A,
                                             iters=self.dac_iters, mask=mask,
                                             dac_fn=dac_fn)
            red["dac_residual"] = info["dac_residuals"][-1]
            if self.diagnostics:
                # full per-round trajectory; max-reduced elementwise over
                # tiles (worst tile per round), so shape stays (dac_iters,)
                red["dac_residuals"] = info["dac_residuals"]
        elif base == "grbcm":
            mu_a, var_a = self._moments(fa, Xq)
            mu_c, var_c = self._moments(fc, Xq)
            if chaos is not None:
                # the communication expert is a serving-host dataset, not a
                # fleet member — only the augmented experts take faults
                mu_a, var_a, mask = degrade(mu_a, var_a, mask)
            mean, v, info = dec_grbcm_from_moments(
                mu_a, var_a, mu_c[0], var_c[0], A, iters=self.dac_iters,
                mask=mask, dac_fn=dac_fn)
            red["dac_residual"] = info["dac_residuals"][-1]
            if self.diagnostics:
                red["dac_residuals"] = info["dac_residuals"]
        elif method == "nn_npae":
            mu, kA, CA = self._terms(f, Xq)
            A_dale, readout = A, None
            if chaos is not None:
                mu, _, mask = degrade(mu, jnp.zeros_like(mu) + pv, mask)
                A_dale, readout = chaos["A_live"], chaos["readout"]
            mean, v, info = dec_nn_npae_from_terms(
                mask, mu, kA, CA, pv, A_dale, dale_iters=self.dale_iters,
                jitter=self.npae_jitter, readout=readout)
            red["dale_residual"] = info["dale_residual"]
        elif method in ("npae", "npae_star"):
            mu, kA, CA = self._terms(f, Xq)
            if chaos is not None:
                mu, _, mask = degrade(mu, jnp.zeros_like(mu) + pv, mask)
            core = (dec_npae_from_terms if method == "npae"
                    else partial(dec_npae_star_from_terms,
                                 pm_iters=self.pm_iters))
            mean, v, info = core(mu, kA, CA, pv, A, jor_iters=self.jor_iters,
                                 dac_iters=self.dac_iters,
                                 jitter=self.npae_jitter,
                                 with_residuals=self.diagnostics,
                                 mask=mask, dac_fn=dac_fn)
            red["dac_residual"] = info["dac_residuals"][-1]
            red["jor_residual"] = info["jor_residual"]
            if self.diagnostics:
                red["dac_residuals"] = info["dac_residuals"]
                red["jor_residuals"] = info["jor_residuals"]
        elif method == "npae_sparse":
            # low-rank NPAE: cross-covariance through the pseudo-points,
            # solved by the SAME aggregation core as the exact family
            mu, kA, CA = npae_terms_lowrank(f.log_theta, f.Z, f.Lmm, f.LS,
                                            f.c, Xq)
            mean, v = agg.npae(mu, kA, CA, pv, jitter=self.npae_jitter)
        elif method == "cen_npae":
            mu, kA, CA = self._terms(f, Xq)
            mean, v = agg.npae(mu, kA, CA, pv)
        elif method == "cen_grbcm":
            mu_a, var_a = self._moments(fa, Xq)
            mu_c, var_c = self._moments(fc, Xq)
            mean, v = agg.grbcm(mu_a, var_a, mu_c[0], var_c[0])
        elif method in ("cen_poe", "cen_gpoe", "cen_bcm", "cen_rbcm"):
            mu, var = self._moments(f, Xq)
            fn = getattr(agg, method[4:])
            args = (mu, var, pv) if method in ("cen_bcm", "cen_rbcm") \
                else (mu, var)
            mean, v = fn(*args)
        else:
            raise ValueError(f"unknown prediction method {method!r}")

        perq = {"mean": mean, "var": v}
        if mask is not None:
            perq["mask_t"] = mask.T                       # query axis leads
        return perq, red

    # -- serving entry point -------------------------------------------------

    def _run(self, method, f, fa, fc, Xs, chaos=None):
        # executes at TRACE time only: jit replays the compiled program on
        # cache hits without re-entering this body, so the counter advances
        # exactly once per new (method, query geometry) — the scheduler's
        # zero-recompile-after-warmup contract is asserted against it
        self._trace_count += 1
        self._traces_total.inc(engine="replicated", method=method)
        return map_query_tiles(
            lambda Xq: self._tile(method, f, fa, fc, Xq, chaos=chaos),
            Xs, self.chunk)

    @property
    def jit_cache_misses(self) -> int:
        """Number of traces so far == distinct (method, query geometry)
        pairs served. Flat across requests => every dispatch reused a
        compiled program."""
        return self._trace_count

    def set_diagnostics(self, flag: bool):
        """Toggle consensus-diagnostics capture: when on, `predict`'s info
        carries the FULL per-round DAC residual trajectory ("dac_residuals",
        worst tile per round) alongside the final scalars. The flag is
        baked into traces, so toggling drops the compiled cache — leave it
        off on serving paths and flip it for TraceRecorder runs."""
        flag = bool(flag)
        if flag != self.diagnostics:
            self.diagnostics = flag
            self._compiled.clear()

    def warm_slots(self, method: str, slots, *, input_dim: int | None = None,
                   dtype=None, fault_plan=None):
        """Pre-trace `method` for every query-batch geometry in `slots`
        so a serving scheduler packing requests into those slots never
        compiles on the request path. Pass the serving `fault_plan` to
        also warm the degraded-consensus traces it will dispatch to."""
        D = self.fitted.Xp.shape[-1] if input_dim is None else int(input_dim)
        dt = self.fitted.Xp.dtype if dtype is None else dtype
        for s in slots:
            try:
                out = self.predict(method, jnp.zeros((int(s), D), dt),
                                   fault_plan=fault_plan)
            except ConsensusDiverged:
                # the degraded trace is compiled before the host-side result
                # guard fires; a divergence on the synthetic warm batch is
                # not a serving failure
                continue
            jax.block_until_ready(out[0])

    def _chaos_arrays(self, plan):
        """Derive the traced fault arrays + degradation metadata for a
        consensus-faulty FaultPlan (host side, cached per plan).

        readout = the largest connected component of live agents at the
        final round; payload = its members that were ALSO alive at round 0
        (only they contribute local models). Passing these as traced
        ARGUMENTS keeps one compiled degraded program per (method,
        geometry) shared by every plan."""
        cached = self._chaos_cache.get(plan)
        if cached is not None:
            return cached
        M = self.fitted.num_agents
        dt = self.fitted.Xp.dtype
        alive = plan.alive_schedule(M, self.dac_iters)      # (iters, M)
        final = alive[-1] > 0.0
        if not final.any():
            raise ConsensusDiverged(
                "fault plan drops every agent before readout")
        labels = connected_components(self.A, alive=final)
        uniq, counts = np.unique(labels[final], return_counts=True)
        comp = final & (labels == uniq[np.argmax(counts)])  # ties -> lowest
        payload = (alive[0] > 0.0) & comp
        if not payload.any():
            raise ConsensusDiverged(
                "no surviving agent holds a round-0 payload")
        # live-subgraph adjacency for DALE (nn_npae), with self-loops on
        # EVERY zero-degree node (dead ones too): avg = (A@Q)/deg must stay
        # finite everywhere — a single NaN row poisons the matmul (0*NaN)
        A_live = np.asarray(self.A, dtype=np.float64) * np.outer(final, final)
        iso = np.flatnonzero(A_live.sum(axis=1) == 0)
        A_live[iso, iso] = 1.0
        chaos = {
            "alive_seq": jnp.asarray(alive, dt),
            "readout": jnp.asarray(comp, dt),
            "payload": jnp.asarray(payload, dt),
            "corrupt": jnp.asarray(plan.corrupt_mask(M)),
            "n_relay": jnp.asarray(float(payload.sum()), dt),
            "A_live": jnp.asarray(A_live, dt),
        }
        edge = plan.edge_schedule(M, self.dac_iters)
        if edge is not None:
            chaos["edge_seq"] = jnp.asarray(edge, dt)
        meta = {"degraded": True,
                "alive_agents": int(final.sum()),
                "excluded_agents": int(M - payload.sum()),
                "n_components": int(uniq.size)}
        self._chaos_cache[plan] = (chaos, meta)
        return chaos, meta

    def predict(self, method: str, Xs, fault_plan=None):
        """Serve one query batch -> (mean (Nt,), var (Nt,), info).

        info carries the worst-tile consensus residuals, and the CBNN mask
        (M, Nt) for nn_* methods.

        `fault_plan` (repro.chaos.FaultPlan) injects the plan's consensus
        faults and serves over the surviving subgraph. The result is then
        either honestly DEGRADED — finite, computed over the largest live
        component, flagged with info["degraded"]=True and the component
        census — or a typed `ConsensusDiverged` (non-finite output, or a
        consensus residual above `degraded_tol`); never silently wrong.
        A consensus-free plan (stragglers/fail-injection only) dispatches
        to the exact traces: bitwise identical to fault_plan=None.
        """
        if method not in self.METHODS:
            raise ValueError(f"unknown prediction method {method!r}; "
                             f"one of {self.METHODS}")
        if ("grbcm" in method and (self.fitted_aug is None
                                   or self.fitted_comm is None)):
            raise ValueError("grbcm methods need fitted_aug and fitted_comm")
        sparse = isinstance(self.fitted, SparseExperts)
        if sparse and method in self._DENSE_ONLY:
            raise ValueError(
                f"{method} needs the dense O(M^2 Ni^2) cross-Gram and is "
                f"not servable from sparse pseudo-representation experts; "
                f"use 'npae_sparse' (the low-rank NPAE path)")
        if method == "npae_sparse" and not sparse:
            raise ValueError(
                "npae_sparse serves from SparseExperts only — fit with "
                "FleetConfig(sparse_m=...) (or fit_sparse_experts) to build "
                "the pseudo-representation factors")
        chaos = meta = None
        if fault_plan is not None and not fault_plan.consensus_free:
            if method.startswith("cen_"):
                raise ValueError(
                    f"{method}: centralized references do not run consensus "
                    f"and cannot serve a fault plan with consensus faults")
            if method == "npae_sparse":
                raise ValueError(
                    "npae_sparse runs exact collectives (no averaging "
                    "consensus) and cannot serve a fault plan with "
                    "consensus faults")
            chaos, meta = self._chaos_arrays(fault_plan)
        run = self._compiled.get(method)
        if run is None:
            run = jax.jit(partial(self._run, method))
            self._compiled[method] = run
        if chaos is None:
            perq, red = run(self.fitted, self.fitted_aug, self.fitted_comm,
                            Xs)
        else:
            perq, red = run(self.fitted, self.fitted_aug, self.fitted_comm,
                            Xs, chaos)
        info = dict(red)
        mask_t = perq.pop("mask_t", None)
        if mask_t is not None:
            info["mask"] = mask_t.T
        mean, var = perq["mean"], perq["var"]
        if chaos is not None:
            scrubbed = int(info.pop("scrubbed", 0))
            # guard the NETWORK consensus residuals (DAC/DALE) — the part
            # degradation perturbs. The per-query JOR solve is the same
            # masked math as the exact path and its residual is
            # data-scale-dependent; it stays reported in info, unguarded.
            residual = max((float(info[k]) for k in
                            ("dac_residual", "dale_residual") if k in info),
                           default=0.0)
            finite = (bool(np.isfinite(np.asarray(mean)).all())
                      and bool(np.isfinite(np.asarray(var)).all()))
            if not finite or not np.isfinite(residual) \
                    or residual > self.degraded_tol:
                self._diverged_total.inc(method=method)
                raise ConsensusDiverged(
                    f"{method}: degraded consensus did not converge "
                    f"(residual={residual:.3e}, tol={self.degraded_tol:.1e},"
                    f" finite={finite}) under fault plan {fault_plan!r}")
            self._degraded_total.inc(method=method)
            self._scrubbed_gauge.set(scrubbed)
            self._alive_gauge.set(meta["alive_agents"])
            info.update(meta)
            info["scrubbed_agents"] = scrubbed
        return mean, var, info

    def swap_experts(self, fitted: FittedExperts,
                     fitted_aug: FittedExperts | None = None,
                     fitted_comm: FittedExperts | None = None):
        """Hot-swap the served factors WITHOUT recompilation.

        The experts pytree is an argument of every compiled program, so a
        same-structure, same-shape replacement (the streaming case:
        `OnlineExperts.to_fitted()` after observe/evict events) reuses the
        jit cache. Raises if the structure/shapes changed — that is a
        membership change; use `rewire`.
        """
        def spec(t):
            leaves, treedef = jax.tree.flatten(t)
            return treedef, [(a.shape, jnp.asarray(a).dtype) for a in leaves]

        for name, new, old in (("fitted", fitted, self.fitted),
                               ("fitted_aug", fitted_aug, self.fitted_aug),
                               ("fitted_comm", fitted_comm,
                                self.fitted_comm)):
            if new is None:
                continue
            if old is not None and spec(new) != spec(old):
                raise ValueError(
                    f"swap_experts: {name} structure/shapes changed (agent "
                    f"membership or window geometry) — use rewire()")
        self.fitted = fitted
        if fitted_aug is not None:
            self.fitted_aug = fitted_aug
        if fitted_comm is not None:
            self.fitted_comm = fitted_comm

    def rewire(self, A, fitted: FittedExperts | None = None,
               fitted_aug: FittedExperts | None = None,
               fitted_comm: FittedExperts | None = None):
        """Apply a membership/topology change (core.online.join / leave):
        new adjacency and optionally a new fleet. Drops every compiled
        program — the consensus protocols bake A (and M) into the trace,
        so this is also what re-syncs DAC/JOR/DALE to the new graph."""
        experts = fitted if fitted is not None else self.fitted
        if experts.num_agents != A.shape[0]:
            raise ValueError(
                f"rewire: {experts.num_agents} fitted agents vs "
                f"adjacency for {A.shape[0]}")
        self.A = A
        if fitted is not None:
            self.fitted = fitted
        if fitted_aug is not None:
            self.fitted_aug = fitted_aug
        if fitted_comm is not None:
            self.fitted_comm = fitted_comm
        self._compiled.clear()
        self._chaos_cache.clear()   # masks/readout are derived from A and M

    def posterior_means_streamed(self, Xs):
        """Per-agent streamed posterior means (M, Nt) via the fused
        Gram-matvec kernel — the O(Ni + Nt) mean-only hot path."""
        f = self.fitted
        w = f.c if isinstance(f, SparseExperts) else f.alpha
        return stream_means(f.log_theta, f.Xp, w, Xs)
