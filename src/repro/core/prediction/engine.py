"""Factor-cached, query-tiled prediction engine — the serving hot path.

The paper's decentralized prediction methods (§5, Algs. 5-18) all consume the
same per-agent local quantities. The per-call functions re-factorize each
agent's (Ni, Ni) kernel matrix on EVERY request and materialize the full
(Nt, M, M) NPAE covariance tensor all at once, so prediction cannot scale in
the number of queries. Nested-aggregation practice (Rulliere et al.; the
grBCM line of Liu et al.) fits the experts once and serves from cached
factors; this module does the same:

  FittedExperts   — per-agent Cholesky L_i and weights alpha_i = C_i^{-1} y_i,
                    computed once after training (`fit_experts`). A jit-able
                    pytree (NamedTuple of arrays).
  map_query_tiles — lax.map over fixed-size query chunks: sequential tiles
                    bound peak memory at O(chunk * M^2) for the NPAE family
                    and O(chunk * M) for the PoE family at ANY Nt.
  PredictionEngine — serving front-end: all 13 decentralized methods plus the
                    centralized references behind one jit-cached `predict`.
                    With `stream_mean=True` posterior means ride the fused
                    Gram-matvec Pallas kernel (kernels.rbf_matvec).

This engine runs the fleet REPLICATED on one device (and is the only server
of the NPAE family, whose per-query (M, M) solves need strongly-complete
exchange). Its multi-device sibling is prediction/sharded.ShardedEngine:
the same FittedExperts sharded over the agent axis of a mesh, consensus on
the device ring, plus CBNN query routing (docs/serving_sharded.md).

Equivalence with the per-call paths is covered by tests/test_engine.py
(<= 1e-6 for every method).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ...obs import default_registry
from ..gp.kernel import unpack
from . import aggregation as agg
from .cbnn import cbnn_mask_cached
from .decentralized import (dec_poe_from_moments, dec_gpoe_from_moments,
                            dec_bcm_from_moments, dec_rbcm_from_moments,
                            dec_grbcm_from_moments, dec_npae_from_terms,
                            dec_npae_star_from_terms, dec_nn_npae_from_terms)
from .local import (chol_factors, cross_gram, local_moments_cached,
                    npae_terms_cached, stream_means)


class FittedExperts(NamedTuple):
    """Per-agent state computed once after training (a jit-able pytree)."""
    log_theta: jax.Array   # (D+2,)
    Xp: jax.Array          # (M, Ni, D)
    yp: jax.Array          # (M, Ni)
    L: jax.Array           # (M, Ni, Ni)  chol(K(X_i, X_i) + sigma_eps^2 I)
    alpha: jax.Array       # (M, Ni)      C_i^{-1} y_i
    Kcross: jax.Array | None = None   # (M, M, Ni, Ni) cross-agent Gram
    #                                   blocks (fit_experts cache_cross=True)

    @property
    def num_agents(self) -> int:
        return self.Xp.shape[0]

    @property
    def prior_var(self) -> jax.Array:
        _, sigma_f, _ = unpack(self.log_theta)
        return sigma_f**2


def fit_experts(log_theta, Xp, yp, jitter: float = 1e-8,
                cache_cross: bool = False,
                cross_cache_limit_mb: float = 1024.0) -> FittedExperts:
    """Factorize every agent's kernel matrix ONCE; reused by all methods.

    `cache_cross=True` additionally precomputes the (M, M, Ni, Ni)
    cross-agent Gram blocks the NPAE family re-assembles on every query
    batch, trading O(M^2 Ni^2) memory for the dominant per-request cost.
    The estimate is guarded against `cross_cache_limit_mb` at trace time
    (shapes are static); raise the limit explicitly for big fleets. Note
    `cache_cross` is a Python-level flag: under jit, close over it
    (functools.partial) rather than passing it as a traced argument.
    """
    L, alpha = chol_factors(log_theta, Xp, yp, jitter)
    Kcross = None
    if cache_cross:
        M, Ni = Xp.shape[0], Xp.shape[1]
        est_mb = M * M * Ni * Ni * jnp.dtype(Xp.dtype).itemsize / 2**20
        if est_mb > cross_cache_limit_mb:
            raise ValueError(
                f"cache_cross would materialize {est_mb:.2f} MB of cross-"
                f"agent Gram blocks (M={M}, Ni={Ni}) > limit "
                f"{cross_cache_limit_mb:.0f} MB; raise "
                f"cross_cache_limit_mb or serve without the cache")
        Kcross = cross_gram(log_theta, Xp)
    return FittedExperts(log_theta, Xp, yp, L, alpha, Kcross)


def map_query_tiles(tile_fn, Xs, chunk: int):
    """Apply `tile_fn((chunk, D)) -> (per_query_tree, reduced_tree)` over
    fixed-size query tiles with lax.map (sequential => bounded peak memory).

    per_query_tree leaves must have leading axis `chunk`; they are stitched
    along the query axis and the padding tail is stripped. reduced_tree
    leaves are combined with an elementwise max over tiles (residual
    semantics: report the worst tile).
    """
    Nt, D = Xs.shape
    n_tiles = -(-Nt // chunk)
    pad = n_tiles * chunk - Nt
    # edge-replicate the tail: padded slots duplicate the LAST REAL query, so
    # the max-reduced residuals describe the served workload, never a
    # synthetic X=0 point
    padded = jnp.pad(Xs, ((0, pad), (0, 0)), mode="edge")
    if n_tiles == 1:
        # single tile: skip the scan (lets XLA fuse across the whole batch)
        perq, reduced = tile_fn(padded)
        return jax.tree.map(lambda a: a[:Nt], perq), reduced
    perq, reduced = jax.lax.map(tile_fn, padded.reshape(n_tiles, chunk, D))
    perq = jax.tree.map(
        lambda a: a.reshape((n_tiles * chunk,) + a.shape[2:])[:Nt], perq)
    reduced = jax.tree.map(lambda a: jnp.max(a, axis=0), reduced)
    return perq, reduced


_DAC_CORES = {"poe": dec_poe_from_moments, "gpoe": dec_gpoe_from_moments,
              "bcm": dec_bcm_from_moments, "rbcm": dec_rbcm_from_moments}


class PredictionEngine:
    """Serving front-end over FittedExperts: jit-cached, query-tiled methods.

    Decentralized: poe gpoe bcm rbcm grbcm npae npae_star and the CBNN
    variants nn_poe nn_gpoe nn_bcm nn_rbcm nn_grbcm nn_npae.
    Centralized references: cen_poe cen_gpoe cen_bcm cen_rbcm cen_grbcm
    cen_npae.

    The grbcm variants additionally require `fitted_aug` (augmented experts)
    and `fitted_comm` (the communication expert as a 1-agent FittedExperts) —
    paper eq. 16-17; CBNN scores always come from the BASE local datasets
    (eq. 39 is defined on D_i).

    One compiled program per (method, query-batch geometry): repeated
    requests with the same Nt reuse the jit cache, and `chunk`-sized tiles
    bound peak memory at any Nt. Configuration attributes are baked at first
    `predict` per method — mutate the engine only through `swap_experts`
    (same-shape factor hot-swap, keeps every compiled program: the experts
    are a traced ARGUMENT of the cached jits) and `rewire` (membership /
    topology change, drops the compiled cache because A and M are baked
    into the traces).
    """

    METHODS = ("poe", "gpoe", "bcm", "rbcm", "grbcm", "npae", "npae_star",
               "nn_poe", "nn_gpoe", "nn_bcm", "nn_rbcm", "nn_grbcm",
               "nn_npae", "cen_poe", "cen_gpoe", "cen_bcm", "cen_rbcm",
               "cen_grbcm", "cen_npae")

    def __init__(self, fitted: FittedExperts, A, *, chunk: int = 256,
                 dac_iters: int = 200, jor_iters: int = 500,
                 dale_iters: int = 2000, pm_iters: int = 100,
                 eta_nn: float = 0.1, npae_jitter: float = 1e-6,
                 fitted_aug: FittedExperts | None = None,
                 fitted_comm: FittedExperts | None = None,
                 stream_mean: bool = False):
        self.fitted = fitted
        self.A = A
        self.chunk = int(chunk)
        self.dac_iters = int(dac_iters)
        self.jor_iters = int(jor_iters)
        self.dale_iters = int(dale_iters)
        self.pm_iters = int(pm_iters)
        self.eta_nn = float(eta_nn)
        self.npae_jitter = float(npae_jitter)
        self.fitted_aug = fitted_aug
        self.fitted_comm = fitted_comm
        self.stream_mean = bool(stream_mean)
        self.diagnostics = False
        self._compiled: dict[str, object] = {}
        self._trace_count = 0
        self._traces_total = default_registry().counter(
            "gp_jit_traces_total", "engine traces (compiled programs), by "
            "engine and method")

    # -- per-tile computation ------------------------------------------------

    def _moments(self, f: FittedExperts, Xq):
        return local_moments_cached(f.log_theta, f.Xp, f.L, f.alpha, Xq,
                                    stream_mean=self.stream_mean)

    def _terms(self, f: FittedExperts, Xq):
        return npae_terms_cached(f.log_theta, f.Xp, f.L, f.alpha, Xq,
                                 Kcross=f.Kcross)

    def _tile(self, method: str, f, fa, fc, Xq):
        A, pv = self.A, f.prior_var
        nn = method.startswith("nn_")
        base = method[3:] if nn else method
        mask = None
        if nn:
            mask, _ = cbnn_mask_cached(f.log_theta, f.Xp, f.L, Xq,
                                       self.eta_nn)
        red = {}

        if base in _DAC_CORES:
            mu, var = self._moments(f, Xq)
            mean, v, info = _DAC_CORES[base](mu, var, pv, A,
                                             iters=self.dac_iters, mask=mask)
            red["dac_residual"] = info["dac_residuals"][-1]
            if self.diagnostics:
                # full per-round trajectory; max-reduced elementwise over
                # tiles (worst tile per round), so shape stays (dac_iters,)
                red["dac_residuals"] = info["dac_residuals"]
        elif base == "grbcm":
            mu_a, var_a = self._moments(fa, Xq)
            mu_c, var_c = self._moments(fc, Xq)
            mean, v, info = dec_grbcm_from_moments(
                mu_a, var_a, mu_c[0], var_c[0], A, iters=self.dac_iters,
                mask=mask)
            red["dac_residual"] = info["dac_residuals"][-1]
            if self.diagnostics:
                red["dac_residuals"] = info["dac_residuals"]
        elif method == "nn_npae":
            mu, kA, CA = self._terms(f, Xq)
            mean, v, info = dec_nn_npae_from_terms(
                mask, mu, kA, CA, pv, A, dale_iters=self.dale_iters,
                jitter=self.npae_jitter)
            red["dale_residual"] = info["dale_residual"]
        elif method in ("npae", "npae_star"):
            mu, kA, CA = self._terms(f, Xq)
            core = (dec_npae_from_terms if method == "npae"
                    else partial(dec_npae_star_from_terms,
                                 pm_iters=self.pm_iters))
            mean, v, info = core(mu, kA, CA, pv, A, jor_iters=self.jor_iters,
                                 dac_iters=self.dac_iters,
                                 jitter=self.npae_jitter,
                                 with_residuals=self.diagnostics)
            red["dac_residual"] = info["dac_residuals"][-1]
            red["jor_residual"] = info["jor_residual"]
            if self.diagnostics:
                red["dac_residuals"] = info["dac_residuals"]
                red["jor_residuals"] = info["jor_residuals"]
        elif method == "cen_npae":
            mu, kA, CA = self._terms(f, Xq)
            mean, v = agg.npae(mu, kA, CA, pv)
        elif method == "cen_grbcm":
            mu_a, var_a = self._moments(fa, Xq)
            mu_c, var_c = self._moments(fc, Xq)
            mean, v = agg.grbcm(mu_a, var_a, mu_c[0], var_c[0])
        elif method in ("cen_poe", "cen_gpoe", "cen_bcm", "cen_rbcm"):
            mu, var = self._moments(f, Xq)
            fn = getattr(agg, method[4:])
            args = (mu, var, pv) if method in ("cen_bcm", "cen_rbcm") \
                else (mu, var)
            mean, v = fn(*args)
        else:
            raise ValueError(f"unknown prediction method {method!r}")

        perq = {"mean": mean, "var": v}
        if mask is not None:
            perq["mask_t"] = mask.T                       # query axis leads
        return perq, red

    # -- serving entry point -------------------------------------------------

    def _run(self, method, f, fa, fc, Xs):
        # executes at TRACE time only: jit replays the compiled program on
        # cache hits without re-entering this body, so the counter advances
        # exactly once per new (method, query geometry) — the scheduler's
        # zero-recompile-after-warmup contract is asserted against it
        self._trace_count += 1
        self._traces_total.inc(engine="replicated", method=method)
        return map_query_tiles(lambda Xq: self._tile(method, f, fa, fc, Xq),
                               Xs, self.chunk)

    @property
    def jit_cache_misses(self) -> int:
        """Number of traces so far == distinct (method, query geometry)
        pairs served. Flat across requests => every dispatch reused a
        compiled program."""
        return self._trace_count

    def set_diagnostics(self, flag: bool):
        """Toggle consensus-diagnostics capture: when on, `predict`'s info
        carries the FULL per-round DAC residual trajectory ("dac_residuals",
        worst tile per round) alongside the final scalars. The flag is
        baked into traces, so toggling drops the compiled cache — leave it
        off on serving paths and flip it for TraceRecorder runs."""
        flag = bool(flag)
        if flag != self.diagnostics:
            self.diagnostics = flag
            self._compiled.clear()

    def warm_slots(self, method: str, slots, *, input_dim: int | None = None,
                   dtype=None):
        """Pre-trace `method` for every query-batch geometry in `slots`
        so a serving scheduler packing requests into those slots never
        compiles on the request path."""
        D = self.fitted.Xp.shape[-1] if input_dim is None else int(input_dim)
        dt = self.fitted.Xp.dtype if dtype is None else dtype
        for s in slots:
            out = self.predict(method, jnp.zeros((int(s), D), dt))
            jax.block_until_ready(out[0])

    def predict(self, method: str, Xs):
        """Serve one query batch -> (mean (Nt,), var (Nt,), info).

        info carries the worst-tile consensus residuals, and the CBNN mask
        (M, Nt) for nn_* methods.
        """
        if method not in self.METHODS:
            raise ValueError(f"unknown prediction method {method!r}; "
                             f"one of {self.METHODS}")
        if ("grbcm" in method and (self.fitted_aug is None
                                   or self.fitted_comm is None)):
            raise ValueError("grbcm methods need fitted_aug and fitted_comm")
        run = self._compiled.get(method)
        if run is None:
            run = jax.jit(partial(self._run, method))
            self._compiled[method] = run
        perq, red = run(self.fitted, self.fitted_aug, self.fitted_comm, Xs)
        info = dict(red)
        mask_t = perq.pop("mask_t", None)
        if mask_t is not None:
            info["mask"] = mask_t.T
        return perq["mean"], perq["var"], info

    def swap_experts(self, fitted: FittedExperts,
                     fitted_aug: FittedExperts | None = None,
                     fitted_comm: FittedExperts | None = None):
        """Hot-swap the served factors WITHOUT recompilation.

        The experts pytree is an argument of every compiled program, so a
        same-structure, same-shape replacement (the streaming case:
        `OnlineExperts.to_fitted()` after observe/evict events) reuses the
        jit cache. Raises if the structure/shapes changed — that is a
        membership change; use `rewire`.
        """
        def spec(t):
            leaves, treedef = jax.tree.flatten(t)
            return treedef, [(a.shape, jnp.asarray(a).dtype) for a in leaves]

        for name, new, old in (("fitted", fitted, self.fitted),
                               ("fitted_aug", fitted_aug, self.fitted_aug),
                               ("fitted_comm", fitted_comm,
                                self.fitted_comm)):
            if new is None:
                continue
            if old is not None and spec(new) != spec(old):
                raise ValueError(
                    f"swap_experts: {name} structure/shapes changed (agent "
                    f"membership or window geometry) — use rewire()")
        self.fitted = fitted
        if fitted_aug is not None:
            self.fitted_aug = fitted_aug
        if fitted_comm is not None:
            self.fitted_comm = fitted_comm

    def rewire(self, A, fitted: FittedExperts | None = None,
               fitted_aug: FittedExperts | None = None,
               fitted_comm: FittedExperts | None = None):
        """Apply a membership/topology change (core.online.join / leave):
        new adjacency and optionally a new fleet. Drops every compiled
        program — the consensus protocols bake A (and M) into the trace,
        so this is also what re-syncs DAC/JOR/DALE to the new graph."""
        experts = fitted if fitted is not None else self.fitted
        if experts.num_agents != A.shape[0]:
            raise ValueError(
                f"rewire: {experts.num_agents} fitted agents vs "
                f"adjacency for {A.shape[0]}")
        self.A = A
        if fitted is not None:
            self.fitted = fitted
        if fitted_aug is not None:
            self.fitted_aug = fitted_aug
        if fitted_comm is not None:
            self.fitted_comm = fitted_comm
        self._compiled.clear()

    def posterior_means_streamed(self, Xs):
        """Per-agent streamed posterior means (M, Nt) via the fused
        Gram-matvec kernel — the O(Ni + Nt) mean-only hot path."""
        f = self.fitted
        return stream_means(f.log_theta, f.Xp, f.alpha, Xs)
