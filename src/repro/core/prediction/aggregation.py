"""Centralized aggregation of GP experts (paper §2.3.2): PoE, gPoE (eq.
12-13), BCM, rBCM (eq. 14-15), grBCM (eq. 16-17), NPAE (eq. 20-21). These
are the server-side references the decentralized methods must converge to
(zero approximation error for DAC-based ones).

Engine layer: these closed forms sit at the `*_from_moments` altitude —
they consume precomputed per-agent moments, never raw data. The replicated
engine serves them as the `cen_*` methods; the sharded engine's routed mode
evaluates the same masked sums block-locally (network sums restricted to a
shard-local mask coincide with block sums).

All take per-agent moments (M, Nt) and an optional agent mask (M,) or (M, Nt)
— the mask is what CBNN produces (eq. 39); masked-out agents contribute
nothing and M_eff = sum(mask).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _mask_of(mu, mask):
    if mask is None:
        return jnp.ones_like(mu)
    return jnp.broadcast_to(mask if mask.ndim == mu.ndim else mask[:, None],
                            mu.shape).astype(mu.dtype)


def poe(mu, var, mask=None):
    """PoE (eq. 12-13), beta_i = 1."""
    m = _mask_of(mu, mask)
    prec = jnp.sum(m / var, axis=0)
    mean = jnp.sum(m * mu / var, axis=0) / prec
    return mean, 1.0 / prec


def gpoe(mu, var, mask=None):
    """gPoE (eq. 12-13), beta_i = 1/M (average weight, Deisenroth & Ng)."""
    m = _mask_of(mu, mask)
    M_eff = jnp.sum(m, axis=0)
    beta = m / M_eff
    prec = jnp.sum(beta / var, axis=0)
    mean = jnp.sum(beta * mu / var, axis=0) / prec
    return mean, 1.0 / prec


def bcm(mu, var, prior_var, mask=None):
    """BCM (eq. 14-15), beta_i = 1."""
    m = _mask_of(mu, mask)
    M_eff = jnp.sum(m, axis=0)
    prec = jnp.sum(m / var, axis=0) + (1.0 - M_eff) / prior_var
    mean = jnp.sum(m * mu / var, axis=0) / prec
    return mean, 1.0 / prec


def rbcm(mu, var, prior_var, mask=None):
    """rBCM (eq. 14-15), beta_i = 0.5(log prior_var - log var_i)."""
    m = _mask_of(mu, mask)
    beta = 0.5 * (jnp.log(prior_var) - jnp.log(var)) * m
    prec = jnp.sum(beta / var, axis=0) + (1.0 - jnp.sum(beta, axis=0)) / prior_var
    mean = jnp.sum(beta * mu / var, axis=0) / prec
    return mean, 1.0 / prec


def grbcm(mu_aug, var_aug, mu_c, var_c, mask=None):
    """grBCM (eq. 16-17): experts use augmented moments; the communication
    expert (mu_c, var_c) anchors consistency. beta_1 = 1,
    beta_i = 0.5(log var_c - log var_{+i}) for i >= 2."""
    m = _mask_of(mu_aug, mask)
    beta = 0.5 * (jnp.log(var_c)[None] - jnp.log(var_aug))
    beta = beta.at[0].set(1.0) * m
    sum_beta = jnp.sum(beta, axis=0)
    prec = jnp.sum(beta / var_aug, axis=0) + (1.0 - sum_beta) / var_c
    mean = (jnp.sum(beta * mu_aug / var_aug, axis=0)
            - (sum_beta - 1.0) * mu_c / var_c) / prec
    return mean, 1.0 / prec


def npae(mu, kA, CA, prior_var, mask=None, jitter=1e-8):
    """NPAE (eq. 20-21): mu = k_A^T C_A^-1 mu ; var = k** - k_A^T C_A^-1 k_A.

    mu, kA (M, Nt); CA (Nt, M, M). A mask restricts aggregation to selected
    agents by zeroing their rows/cols and placing 1 on excluded diagonals
    (decouples the excluded block — used by DEC-NN-NPAE).

    `jitter` is RELATIVE to the mean diagonal. C_A here is typically
    well-conditioned (cond ~1e3-1e4 on the paper's setups), and a relative
    1e-6 measurably perturbs the direct Cholesky solve; 1e-8 keeps the solve
    tight in float64. A relative nudge below the dtype's ulp would round away
    entirely (1e-8 is a no-op on float32 diagonals), so the effective jitter
    is floored at 8*eps(dtype) — float32 callers keep ~1e-6-scale guarding.
    (The iterative JOR/DALE paths in `decentralized` keep their own, larger,
    jitter.)
    """
    M, Nt = mu.shape
    if mask is not None:
        mkT = _mask_of(mu, mask).T                           # (Nt, M)
        eye = jnp.eye(M, dtype=mu.dtype)
        # zero cross terms with excluded agents; unit diagonal decouples them
        CA = CA * (mkT[:, :, None] * mkT[:, None, :]) \
            + eye[None] * (1.0 - mkT)[:, None, :]
        kA = kA * mkT.T
        mu = mu * mkT.T

    rel = jnp.maximum(jitter, 8 * jnp.finfo(CA.dtype).eps)

    def solve_one(C, k, m):
        scale = jnp.mean(jnp.diagonal(C))
        C = C + (1e-12 + rel * scale) * jnp.eye(M, dtype=C.dtype)
        L = jnp.linalg.cholesky(C)
        qm = jax.scipy.linalg.cho_solve((L, True), m)
        qk = jax.scipy.linalg.cho_solve((L, True), k)
        return k @ qm, k @ qk

    mean, kck = jax.vmap(solve_one)(CA, kA.T, mu.T)          # (Nt,), (Nt,)
    var = jnp.maximum(prior_var - kck, 1e-12)
    return mean, var
