"""Agent-sharded serving: the fleet distributed over a device mesh, with
CBNN query routing (paper §5.2, eq. 39) as a serving-time throughput lever.

`PredictionEngine` runs every agent replicated on one device. This module
shards `FittedExperts` over the agent axis of a 1-D device mesh and runs the
whole DAC family (Algs. 5-9 and their CBNN nn_* variants, Algs. 13-17)
inside `shard_map`:

  per-agent moments  — each device computes `local_moments_cached` /
                       `cbnn_scores_cached` for its OWN block of M/ndev
                       agents only (the `*_cached` engine layer, eq. 10-11 /
                       eq. 39), so per-query FLOPs parallelize over devices.
  cross-agent sums   — the three PoE/BCM consensus payloads (eq. 12-17) are
                       reduced over the device ring with the SAME neighbor-
                       only message pattern as training's
                       `dec_apx_gp_sharded_step`: either `dac_sharded`
                       (paper eq. 35 on the device ring; default) or the
                       exact finite `ring_allsum` protocol
                       (`consensus="exact"`).
  CBNN masks         — scores are computed shard-locally; the >= 1-agent
                       guarantee needs one global number per query (the max
                       score), closed with an exact `ring_allmax`. Masks are
                       multiplicative (shapes stay static): excluded agents
                       contribute zero to every payload, exactly like the
                       simulated-network semantics in prediction.decentralized.

Two serving modes:

  `ShardedEngine.predict(method, Xs)` — full-fleet consensus. Equivalent to
  the replicated `PredictionEngine` output to <= 1e-6 once both consensus
  protocols are run to convergence (tests/test_sharded_serving.py).

  `ShardedEngine.predict_routed(method, Xs)` — CBNN query ROUTING (nn_*
  methods): each query is dispatched (host-side, by nearest agent centroid)
  to the single shard holding its most-correlated experts and served from
  that block alone — local scores, local mask, local masked aggregation, NO
  cross-device collectives, and only Nt/ndev queries of work per device.
  This realizes the paper's "subset of agents perform predictions" as a
  throughput win; it equals the full nn_* aggregate exactly whenever the
  thresholded participant set lives inside the routed shard (tight eta_nn),
  and is a documented approximation otherwise (info carries per-query
  participant counts so callers can audit).

The dense NPAE family (Algs. 10-12, 18) needs per-query (M, M) solves over
cross-agent Gram terms — strongly-complete exchange of O(Ni)-sized state —
and stays on the replicated engine; `ShardedEngine` rejects it explicitly.
The LOW-RANK counterpart `npae_sparse` DOES shard: sparse pseudo-
representation experts (core.sparse) compress each agent's contribution to
(m, q) Nystrom factors, which `ring_allgather` exchanges exactly in
ndev - 1 neighbor hops; every shard then assembles the identical full
cross-covariance with `cross_lowrank` and runs the same `aggregation.npae`
solve as the replicated engine — sharded == replicated by construction.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from ...obs import default_registry
from ..consensus.dac import (dac_sharded, dac_sharded_residual,
                             ring_allgather, ring_allmax, ring_allsum)
from ..sparse import (SparseExperts, cross_lowrank, sparse_moments_cached,
                      sparse_npae_factors, sparse_scores)
from .aggregation import npae
from .cbnn import _mask_from_scores, cbnn_scores_cached
from .decentralized import (_grbcm_beta, _grbcm_posterior, _poe_beta,
                            _poe_posterior, _poe_summands)
from .engine import FittedExperts, map_query_tiles
from .local import local_moments_cached

_BETA_MODE = {"poe": "one", "gpoe": "avg", "bcm": "one", "rbcm": "entropy"}
_BCM_CORRECTION = {"poe": False, "gpoe": False, "bcm": True, "rbcm": True}


def expert_specs(fitted, axis_name: str):
    """PartitionSpecs sharding the agent axis of every per-agent leaf
    (polymorphic over FittedExperts / core.sparse.SparseExperts).

    log_theta is replicated (it is fleet-shared after consensus training).
    The NPAE cross-Gram cache is never sharded — the exact NPAE family is
    not servable on the agent-sharded path (see module docstring) — so
    Kcross must be None; sparse fleets never carry one.
    """
    a = P(axis_name)
    if isinstance(fitted, SparseExperts):
        return SparseExperts(log_theta=P(), Z=a, Lmm=a, LS=a, c=a, tr_corr=a)
    if fitted.Kcross is not None:
        raise ValueError(
            "expert_specs: Kcross (the NPAE cross-Gram cache) has no "
            "agent-sharded layout; refit with cache_cross=False")
    return FittedExperts(log_theta=P(), Xp=a, yp=a, L=a, alpha=a, Kcross=None)


def replicated_specs(fitted):
    """All-replicated specs (the 1-agent grBCM communication expert)."""
    return jax.tree.map(lambda _: P(), fitted)


def shard_experts(fitted, mesh, axis_name: str = "agents",
                  *, replicate: bool = False):
    """Place a fitted fleet on `mesh`: agent axis sharded over `axis_name`
    (or fully replicated for the communication expert)."""
    specs = replicated_specs(fitted) if replicate \
        else expert_specs(fitted, axis_name)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), fitted, specs)


def _strip_kcross(fitted):
    """Drop the (un-shardable) NPAE cross-Gram cache from a dense fleet;
    sparse fleets carry no such cache and pass through untouched."""
    if isinstance(fitted, FittedExperts) and fitted.Kcross is not None:
        return fitted._replace(Kcross=None)
    return fitted


class ShardedEngine:
    """Serving front-end with the fleet sharded over the agent axis.

    Mirrors `PredictionEngine.predict` for the DAC family:
    poe gpoe bcm rbcm grbcm and the CBNN variants nn_poe nn_gpoe nn_bcm
    nn_rbcm nn_grbcm, plus `predict_routed` for CBNN query routing. The
    number of devices on `mesh`'s `axis_name` must divide the agent count;
    each device owns a contiguous block of M/ndev agents (the stripe layout
    `gp.stripe_partition` produces, so blocks are spatially coherent and
    routing is meaningful).

    The communication graph of the sharded consensus is the DEVICE RING
    (ppermute neighbors), not a user-supplied adjacency: its DAC fixed point
    is the same network average, so converged outputs match the replicated
    engine on any connected graph. `consensus="exact"` replaces the DAC
    iteration with the finite ring_allsum protocol (exact sums in ndev - 1
    hops; still neighbor-only messages).

    Like `PredictionEngine`, one program is compiled per (method, batch
    geometry) and the experts pytree is a traced argument, so
    `swap_experts` hot-swaps factors with zero recompiles.
    """

    METHODS = ("poe", "gpoe", "bcm", "rbcm", "grbcm", "nn_poe", "nn_gpoe",
               "nn_bcm", "nn_rbcm", "nn_grbcm", "npae_sparse")

    def __init__(self, fitted, mesh, *,
                 axis_name: str = "agents", chunk: int = 256,
                 dac_iters: int = 200, eta_nn: float = 0.1,
                 consensus: str = "dac", npae_jitter: float = 1e-6,
                 fitted_aug=None, fitted_comm=None,
                 stream_mean: bool = False):
        if axis_name not in mesh.axis_names:
            raise ValueError(f"mesh has no axis {axis_name!r}")
        if consensus not in ("dac", "exact"):
            raise ValueError(f"consensus must be 'dac' or 'exact', "
                             f"got {consensus!r}")
        self.mesh = mesh
        self.axis_name = axis_name
        self.ndev = int(mesh.shape[axis_name])
        M = fitted.num_agents
        if M % self.ndev:
            raise ValueError(f"{M} agents do not shard over {self.ndev} "
                             f"devices (need ndev | M)")
        self.chunk = int(chunk)
        self.dac_iters = int(dac_iters)
        self.eta_nn = float(eta_nn)
        self.consensus = consensus
        self.npae_jitter = float(npae_jitter)
        self.stream_mean = bool(stream_mean)
        # the NPAE cross-Gram cache has no sharded consumer; drop it rather
        # than force callers to refit (sparse fleets never carry one)
        self.fitted = shard_experts(_strip_kcross(fitted), mesh, axis_name)
        self.fitted_aug = None if fitted_aug is None else \
            shard_experts(_strip_kcross(fitted_aug), mesh, axis_name)
        self.fitted_comm = None if fitted_comm is None else \
            shard_experts(fitted_comm, mesh, axis_name, replicate=True)
        # per-agent centroids drive host-side query routing (nearest agent
        # -> owning shard); tiny, so they live on the host
        self._centroids = np.asarray(jnp.mean(fitted.Xp, axis=1))
        self._rep = NamedSharding(mesh, P())
        self.diagnostics = False
        self._compiled: dict[tuple, object] = {}
        self._trace_count = 0
        self._traces_total = default_registry().counter(
            "gp_jit_traces_total", "engine traces (compiled programs), by "
            "engine and method")

    # -- shard-local tile computation ---------------------------------------

    def _moments(self, f, Xq, *, stream_mean: bool = False):
        """Per-agent posterior moments for the local block, polymorphic over
        dense (O(Ni) alpha/L) and sparse (O(m) pseudo-representation)
        experts — the dispatch that lets every DAC-family method serve
        unchanged from either representation."""
        if isinstance(f, SparseExperts):
            return sparse_moments_cached(f.log_theta, f.Z, f.Lmm, f.LS, f.c,
                                         Xq, stream_mean=stream_mean)
        return local_moments_cached(f.log_theta, f.Xp, f.L, f.alpha, Xq,
                                    stream_mean=stream_mean)

    def _local_mask(self, f, Xq, *, ring: bool):
        """CBNN mask for THIS device's agent block (Mb, chunk).

        ring=True closes the >= 1-agent guarantee globally (exact ring max
        of the per-device best scores — full-consensus mode); ring=False
        keeps the guarantee within the local block (routed mode)."""
        if isinstance(f, SparseExperts):
            scores = sparse_scores(f.log_theta, f.Z, f.Lmm, f.LS, Xq)
        else:
            scores = cbnn_scores_cached(f.log_theta, f.Xp, f.L, Xq)
        if not ring:
            return _mask_from_scores(scores, self.eta_nn)
        gmax = ring_allmax(jnp.max(scores, axis=0), self.axis_name)
        return (scores >= self.eta_nn) | (scores >= gmax[None])

    def _local_payloads(self, method: str, f, fa, fc, gidx, Xq, mask, *,
                        ring: bool):
        """Per-agent consensus payloads for the local block -> ((Mb, chunk,
        3) summands, mu_c, var_c). The SAME `_poe_beta` / `_poe_summands`
        formulas as the replicated cores, evaluated on the block.

        ring=True is full-fleet mode (gpoe's M_eff is the network-wide
        participant count, closed with an exact ring sum); ring=False is
        routed mode, where every device serves DIFFERENT queries — a ring
        sum would mix unrelated queries' counts — and the participant count
        is the block-local mask sum by construction."""
        base = method[3:] if method.startswith("nn_") else method
        if base == "grbcm":
            mu, var = self._moments(fa, Xq, stream_mean=self.stream_mean)
            mu_c, var_c = self._moments(fc, Xq)
            mu_c, var_c = mu_c[0], var_c[0]
            m = jnp.ones_like(mu) if mask is None else mask.astype(mu.dtype)
            beta = _grbcm_beta(var, var_c, m, gidx)
        else:
            mu, var = self._moments(f, Xq, stream_mean=self.stream_mean)
            m = jnp.ones_like(mu) if mask is None else mask.astype(mu.dtype)
            if base == "gpoe":
                # eq. 12 'avg' weights need the participant count; mask
                # counts are small integers, so the exact ring sum
                # reproduces the replicated M_eff bit-for-bit
                M_eff = jnp.sum(m, axis=0)
                if ring:
                    M_eff = ring_allsum(M_eff, self.axis_name)
            else:
                M_eff = None
            beta = _poe_beta(var, f.prior_var, m, M_eff, _BETA_MODE[base])
            mu_c = var_c = None
        return _poe_summands(beta, mu, var), mu_c, var_c

    def _posterior(self, method: str, sums, prior_var, mu_c, var_c):
        base = method[3:] if method.startswith("nn_") else method
        if base == "grbcm":
            return _grbcm_posterior(sums[..., 0], sums[..., 1], sums[..., 2],
                                    mu_c, var_c)
        return _poe_posterior(sums[..., 0], sums[..., 1], sums[..., 2],
                              prior_var, _BCM_CORRECTION[base])

    def _full_tile(self, method, f, fa, fc, gidx, Xq):
        """One query tile, full-fleet mode: local payloads + ring consensus."""
        ax = self.axis_name
        nn = method.startswith("nn_")
        mask = self._local_mask(f, Xq, ring=True) if nn else None
        w0, mu_c, var_c = self._local_payloads(method, f, fa, fc, gidx, Xq,
                                               mask, ring=True)
        part = jnp.sum(w0, axis=0)                      # (chunk, 3) partial
        res_traj = None
        if self.consensus == "exact":
            sums = ring_allsum(part, ax)
            res = jnp.zeros((), Xq.dtype)
            if self.diagnostics:
                res_traj = jnp.zeros((self.dac_iters,), Xq.dtype)
        elif self.diagnostics:
            # diagnostics mode: per-round maximin spread trajectory, at the
            # cost of two extra collectives per DAC round
            w, res_traj = dac_sharded(part, ax, self.dac_iters,
                                      with_residuals=True)
            res = res_traj[-1]
            sums = self.ndev * w
        else:
            w = dac_sharded(part, ax, self.dac_iters)   # ~ total / ndev
            res = dac_sharded_residual(w, ax)
            sums = self.ndev * w
        # devices fold ring messages in different orders; pmean makes the
        # result exactly replicated so it can exit through a P() out_spec
        sums = jax.lax.pmean(sums, ax)
        mean, v = self._posterior(method, sums, f.prior_var, mu_c, var_c)
        perq = {"mean": mean, "var": v}
        if nn:
            perq["mask_t"] = mask.T                     # (chunk, Mb)
        red = {"dac_residual": jax.lax.pmax(res, ax)}
        if res_traj is not None:
            red["dac_residuals"] = res_traj
        return perq, red

    def _sparse_npae_tile(self, f, Xq):
        """One query tile of the sharded low-rank NPAE path (npae_sparse).

        Each device computes its OWN block's Nystrom factors (mu, kA, U)
        from the sparse pseudo-representation, then `ring_allgather`
        exchanges the (m, q)-sized factors and inducing sets exactly —
        ndev - 1 neighbor hops, index placement so every shard holds
        bit-identical copies. From there the full (q, M, M) cross-
        covariance and the per-query NPAE solve are the SAME code the
        replicated engine runs (`cross_lowrank` + `aggregation.npae`), so
        sharded == replicated by construction, not by convergence. No
        averaging consensus is involved, hence a zero dac_residual."""
        ax = self.axis_name
        mu_b, kA_b, U_b = sparse_npae_factors(f.log_theta, f.Z, f.Lmm,
                                              f.LS, f.c, Xq)
        M = self.ndev * f.Z.shape[0]
        Z = ring_allgather(f.Z, ax).reshape((M,) + f.Z.shape[1:])
        mu = ring_allgather(mu_b, ax).reshape(M, -1)
        kA = ring_allgather(kA_b, ax).reshape(M, -1)
        U = ring_allgather(U_b, ax).reshape((M,) + U_b.shape[1:])
        CA = cross_lowrank(f.log_theta, Z, U, kA)
        mean, v = npae(mu, kA, CA, f.prior_var, jitter=self.npae_jitter)
        return ({"mean": mean, "var": v},
                {"dac_residual": jnp.zeros((), Xq.dtype)})

    def _routed_tile(self, method, f, fa, fc, gidx, Xq):
        """One query tile, routed mode: this device's block ONLY — local
        mask (>= 1 guarantee within the block) and local masked
        aggregation; zero collectives. Network sums restricted to a mask
        that lives inside this block coincide with the block-local sums, so
        this equals the full nn_* aggregate whenever routing captured every
        selected agent."""
        mask = self._local_mask(f, Xq, ring=False)
        w0, mu_c, var_c = self._local_payloads(method, f, fa, fc, gidx, Xq,
                                               mask, ring=False)
        sums = jnp.sum(w0, axis=0)
        mean, v = self._posterior(method, sums, f.prior_var, mu_c, var_c)
        return {"mean": mean, "var": v,
                "n_selected": jnp.sum(mask, axis=0)}, {}

    # -- compiled programs ---------------------------------------------------

    def _specs(self, grb: bool):
        fspec = expert_specs(self.fitted, self.axis_name)
        if not grb:
            return (fspec,)
        return (fspec, expert_specs(self.fitted_aug, self.axis_name),
                replicated_specs(self.fitted_comm))

    def _make_full(self, method: str):
        ax = self.axis_name
        grb = "grbcm" in method
        nn = method.startswith("nn_")
        sp = method == "npae_sparse"
        perq_specs = {"mean": P(), "var": P()}
        if nn:
            perq_specs["mask_t"] = P(None, ax)
        red_specs = {"dac_residual": P()}
        if self.diagnostics and not sp:
            # npae_sparse runs exact collectives only — there is no DAC
            # trajectory to capture
            red_specs["dac_residuals"] = P()
        out_specs = (perq_specs, red_specs)

        def fn(*args):
            # trace-time only (see PredictionEngine._run): one increment per
            # new (full, method, query geometry) program
            self._trace_count += 1
            self._traces_total.inc(engine="sharded", method=method)
            f, *rest = args
            fa, fc = (rest[0], rest[1]) if grb else (None, None)
            Xs = rest[-1]
            if sp:
                return map_query_tiles(
                    lambda Xq: self._sparse_npae_tile(f, Xq), Xs, self.chunk)
            Mb = f.Xp.shape[0]
            gidx = jax.lax.axis_index(ax) * Mb + jnp.arange(Mb)
            return map_query_tiles(
                lambda Xq: self._full_tile(method, f, fa, fc, gidx, Xq),
                Xs, self.chunk)

        # ppermute chains inside lax.map defeat static replication checking;
        # replication of the P() outputs is established by pmean/pmax above
        prog = shard_map(fn, mesh=self.mesh,
                         in_specs=self._specs(grb) + (P(),),
                         out_specs=out_specs, check_rep=False)
        return jax.jit(prog)

    def _make_routed(self, method: str):
        ax = self.axis_name
        grb = "grbcm" in method

        def fn(*args):
            self._trace_count += 1                       # trace-time only
            self._traces_total.inc(engine="sharded", method=method)
            f, *rest = args
            fa, fc = (rest[0], rest[1]) if grb else (None, None)
            Xr = rest[-1]                                # local (1, B, D)
            Mb = f.Xp.shape[0]
            gidx = jax.lax.axis_index(ax) * Mb + jnp.arange(Mb)
            perq, _ = map_query_tiles(
                lambda Xq: self._routed_tile(method, f, fa, fc, gidx, Xq),
                Xr[0], self.chunk)
            return perq                                  # leaves (B,)

        out_specs = {"mean": P(ax), "var": P(ax), "n_selected": P(ax)}
        prog = shard_map(fn, mesh=self.mesh,
                         in_specs=self._specs(grb) + (P(ax),),
                         out_specs=out_specs, check_rep=False)
        return jax.jit(prog)

    @property
    def jit_cache_misses(self) -> int:
        """Traces so far == distinct (mode, method, geometry) programs
        built. Flat across requests => every dispatch reused one."""
        return self._trace_count

    def set_diagnostics(self, flag: bool):
        """Toggle consensus-diagnostics capture: when on, full-fleet
        `predict` info additionally carries the per-round ring-DAC maximin
        spread trajectory ("dac_residuals", worst tile per round). Baked
        into traces — toggling drops the compiled cache; leave it off on
        serving paths."""
        flag = bool(flag)
        if flag != self.diagnostics:
            self.diagnostics = flag
            self._compiled.clear()

    def warm_slots(self, method: str, slots, *, input_dim: int | None = None,
                   dtype=None):
        """Pre-trace full-fleet `method` for every query-batch geometry in
        `slots` (serving schedulers call this at tenant registration)."""
        D = self.fitted.Xp.shape[-1] if input_dim is None else int(input_dim)
        dt = self.fitted.Xp.dtype if dtype is None else dtype
        for s in slots:
            out = self.predict(method, jnp.zeros((int(s), D), dt))
            jax.block_until_ready(out[0])

    def _experts_args(self, method: str):
        if "grbcm" in method:
            if self.fitted_aug is None or self.fitted_comm is None:
                raise ValueError(
                    "grbcm methods need fitted_aug and fitted_comm")
            return (self.fitted, self.fitted_aug, self.fitted_comm)
        return (self.fitted,)

    # -- serving entry points ------------------------------------------------

    def predict(self, method: str, Xs):
        """Full-fleet sharded serving -> (mean (Nt,), var (Nt,), info).

        Matches the replicated `PredictionEngine` (same method, converged
        consensus) to <= 1e-6 in f64. info carries the worst-tile ring-DAC
        residual and, for nn_* methods, the (M, Nt) CBNN mask.
        """
        if method not in self.METHODS:
            raise ValueError(
                f"unknown sharded method {method!r}; one of {self.METHODS} "
                f"(the dense NPAE family needs strongly-complete exchange "
                f"of O(Ni) factors and is served by the replicated "
                f"PredictionEngine; its low-rank counterpart 'npae_sparse' "
                f"DOES shard — fit with FleetConfig(sparse_m=...))")
        if method == "npae_sparse" and \
                not isinstance(self.fitted, SparseExperts):
            raise ValueError(
                "npae_sparse serves from SparseExperts only — fit with "
                "FleetConfig(sparse_m=...) / fit_sparse_experts")
        run = self._compiled.get(("full", method))
        if run is None:
            run = self._make_full(method)
            self._compiled[("full", method)] = run
        Xs = jax.device_put(Xs, self._rep)
        perq, red = run(*self._experts_args(method), Xs)
        info = dict(red)
        mask_t = perq.pop("mask_t", None)
        if mask_t is not None:
            info["mask"] = mask_t.T
        return perq["mean"], perq["var"], info

    def _route(self, Xs) -> np.ndarray:
        """Host-side CBNN routing proxy: nearest agent centroid -> owning
        shard. For stationary kernels the eq. 39 score decays with distance
        to the agent's data, so the centroid-nearest agent is the max-score
        agent away from stripe boundaries; the exact thresholding then
        happens shard-locally on the routed device."""
        Xs = np.asarray(Xs)
        d2 = ((Xs[:, None, :] - self._centroids[None, :, :]) ** 2).sum(-1)
        Mb = self._centroids.shape[0] // self.ndev
        return d2.argmin(axis=1) // Mb

    def predict_routed(self, method: str, Xs):
        """CBNN-routed serving (nn_* methods) -> (mean, var, info).

        Each query runs on ONE shard (nearest-centroid routing), against
        that shard's agent block only — 1/ndev of the per-agent work and no
        collectives. Exact vs `predict` when the eta_nn-selected agents all
        live in the routed block; info["n_selected"] and info["shard"] let
        callers audit the approximation.
        """
        if not method.startswith("nn_"):
            raise ValueError("predict_routed serves the CBNN nn_* methods; "
                             f"got {method!r}")
        if method not in self.METHODS:
            raise ValueError(f"unknown sharded method {method!r}")
        Xs = np.asarray(Xs)
        Nt, D = Xs.shape
        shard = self._route(Xs)
        counts = np.bincount(shard, minlength=self.ndev)
        # batch-per-shard is quantized to chunk * 2^k: the compiled-program
        # key depends on routing skew only through log-many geometries, so
        # a serving loop over same-sized micro-batches stays recompile-free
        # after the first few skews instead of recompiling per batch
        n_chunks = -(-max(int(counts.max()), 1) // self.chunk)
        B = self.chunk * (1 << (n_chunks - 1).bit_length())
        Xr = np.empty((self.ndev, B, D), dtype=Xs.dtype)
        slot = np.empty(Nt, dtype=np.int64)
        for g in range(self.ndev):
            qs = np.flatnonzero(shard == g)
            Xr[g, :qs.size] = Xs[qs]
            # pad with a point the block owns so padded rows stay in-region
            filler = Xs[qs[-1]] if qs.size else self._centroids[g * (
                self._centroids.shape[0] // self.ndev)]
            Xr[g, qs.size:] = filler
            slot[qs] = g * B + np.arange(qs.size)
        run = self._compiled.get(("routed", method, B))
        if run is None:
            run = self._make_routed(method)
            self._compiled[("routed", method, B)] = run
        Xr = jax.device_put(jnp.asarray(Xr),
                            NamedSharding(self.mesh, P(self.axis_name)))
        perq = run(*self._experts_args(method), Xr)
        info = {"shard": shard, "batch_per_shard": B,
                "n_selected": perq["n_selected"][slot]}
        return perq["mean"][slot], perq["var"][slot], info

    def swap_experts(self, fitted, fitted_aug=None, fitted_comm=None):
        """Hot-swap served factors (same shapes) without recompiling — the
        experts are traced arguments of every compiled program."""
        def shapes(t):
            return [(a.shape, a.dtype) for a in jax.tree.leaves(t)]

        # __init__ strips the (un-shardable) NPAE cross-Gram cache from the
        # served fleets; strip it from the candidates too so a refit carrying
        # Kcross compares same-shaped
        fitted = _strip_kcross(fitted)
        if fitted_aug is not None:
            fitted_aug = _strip_kcross(fitted_aug)
        for name, new, old in (("fitted", fitted, self.fitted),
                               ("fitted_aug", fitted_aug, self.fitted_aug),
                               ("fitted_comm", fitted_comm,
                                self.fitted_comm)):
            if new is not None and old is not None \
                    and shapes(new) != shapes(old):
                raise ValueError(f"swap_experts: {name} shapes changed — "
                                 f"rebuild the ShardedEngine")
        self.fitted = shard_experts(fitted, self.mesh, self.axis_name)
        self._centroids = np.asarray(jnp.mean(fitted.Xp, axis=1))
        if fitted_aug is not None:
            self.fitted_aug = shard_experts(fitted_aug, self.mesh,
                                            self.axis_name)
        if fitted_comm is not None:
            self.fitted_comm = shard_experts(fitted_comm, self.mesh,
                                             self.axis_name, replicate=True)
