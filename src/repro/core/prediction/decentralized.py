"""The paper's 13 decentralized GP prediction methods (§5).

DAC family (strongly connected graphs):
  DEC-PoE (Alg. 5), DEC-gPoE (Alg. 6), DEC-BCM (Alg. 7), DEC-rBCM (Alg. 8),
  DEC-grBCM (Alg. 9)
NPAE family (strongly complete for JOR/PM):
  DEC-NPAE (Alg. 10), DEC-NPAE* (Alg. 11-12, PM-estimated omega*)
CBNN nearest-neighbor family (Alg. 13-18):
  DEC-NN-{PoE, gPoE, BCM, rBCM, grBCM} (DAC on the CBNN subset)
  DEC-NN-NPAE (DALE, strongly connected suffices)

Simulated-network mode: excluded CBNN agents still relay DAC messages with a
zero contribution, which converges to sum_{selected}/M; multiplying by M
recovers the selected-agent sums exactly (Lemma 6 guarantees the deployed
subgraph variant stays connected; both give identical fixed points).

Every method returns (mean, var, info) where info carries the consensus
residuals so benchmarks can report communication rounds (paper Tables 5, 7).

Each method exists at two levels:
  `dec_*_from_moments` / `dec_*_from_terms` — the consensus + aggregation
  core on PRECOMPUTED local quantities. The factor-cached serving engine
  (prediction/engine.py) feeds these from `FittedExperts`.
  `dec_*` — per-call wrappers with the original raw-data signatures that
  recompute the local quantities each time.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..consensus.dac import dac
from ..consensus.jor import jor
from ..consensus.dale import dale
from ..consensus.power_method import optimal_omega
from ..gp.kernel import unpack
from .local import local_moments, npae_terms
from .cbnn import cbnn_mask


def _prior_var(log_theta):
    _, sigma_f, _ = unpack(log_theta)
    return sigma_f**2


def _dac_sums(w0: jax.Array, A: jax.Array, iters: int):
    """DAC -> per-agent average estimates; returns (M * avg) = network sums.

    w0 (M, K): K parallel consensuses. Output (K,) sums plus residual.
    """
    M = w0.shape[0]
    w, res = dac(w0, A, iters)
    return M * jnp.mean(w, axis=0), res


# ---------------------------------------------------------------------------
# DAC family — cores on precomputed moments
#
# The weight / per-agent-summand / posterior-assembly steps are split out so
# the agent-sharded serving engine (prediction/sharded.py) can evaluate the
# SAME formulas on shard-local agent blocks with ring reductions in place of
# the simulated DAC — formula parity between the two execution modes is by
# construction, not by parallel maintenance.
# ---------------------------------------------------------------------------

def _poe_beta(var, prior_var, m, M_eff, beta_mode: str):
    """Per-agent PoE-family weights beta_i (eq. 12-15). `m` is the CBNN
    participation mask as floats (all-ones when unmasked); `M_eff` the
    NETWORK-WIDE mask count per query (only consumed by beta_mode='avg')."""
    if beta_mode == "one":
        return m
    if beta_mode == "avg":
        return m / M_eff
    if beta_mode == "entropy":
        return 0.5 * (jnp.log(prior_var) - jnp.log(var)) * m
    raise ValueError(beta_mode)


def _poe_summands(beta, mu, var):
    """The three per-agent consensus payloads [beta mu / var, beta / var,
    beta] -> (..., Nt, 3). Network sums of these assemble every PoE/BCM
    posterior."""
    return jnp.stack([beta * mu / var, beta / var, beta], axis=-1)


def _poe_posterior(s_mu, s_prec, s_beta, prior_var, bcm_correction: bool):
    """Posterior from NETWORK SUMS of the `_poe_summands` payloads."""
    if bcm_correction:
        prec = s_prec + (1.0 - s_beta) / prior_var        # (15)
    else:
        prec = s_prec                                     # (13)
    return s_mu / prec, 1.0 / prec                        # (12)/(14)


def _grbcm_beta(var_aug, var_c, m, agent_index):
    """grBCM weights (eq. 16-17): beta_1 = 1 for the GLOBAL first augmented
    expert, entropy weights against the communication expert otherwise.
    `agent_index` carries global agent ids so a shard-local block can place
    the beta_1 = 1 row correctly."""
    beta = 0.5 * (jnp.log(var_c)[None] - jnp.log(var_aug))
    return jnp.where((agent_index == 0)[:, None], 1.0, beta) * m


def _grbcm_posterior(s_mu, s_prec, s_beta, mu_c, var_c):
    """grBCM posterior from network sums of the `_poe_summands` payloads on
    augmented-expert moments."""
    prec = s_prec + (1.0 - s_beta) / var_c                 # (17)
    mean = (s_mu - (s_beta - 1.0) * mu_c / var_c) / prec   # (16)
    return mean, 1.0 / prec


def _poe_family_from_moments(mu, var, prior_var, A, iters, beta_mode: str,
                             bcm_correction: bool, mask=None, dac_fn=None):
    m = jnp.ones_like(mu) if mask is None else \
        jnp.broadcast_to(mask, mu.shape).astype(mu.dtype)
    M_eff = jnp.sum(m, axis=0)                            # (Nt,)
    beta = _poe_beta(var, prior_var, m, M_eff, beta_mode)
    w0 = _poe_summands(beta, mu, var)                     # (M, Nt, 3)
    sums_fn = _dac_sums if dac_fn is None else dac_fn
    sums, res = sums_fn(w0.reshape(w0.shape[0], -1), A, iters)
    sums = sums.reshape(mu.shape[1], 3)
    mean, v = _poe_posterior(sums[:, 0], sums[:, 1], sums[:, 2], prior_var,
                             bcm_correction)
    return mean, v, {"dac_residuals": res}


def dec_poe_from_moments(mu, var, prior_var, A, iters=200, mask=None,
                         dac_fn=None):
    """DEC-PoE (Alg. 5) on precomputed local moments."""
    return _poe_family_from_moments(mu, var, prior_var, A, iters, "one",
                                    False, mask, dac_fn)


def dec_gpoe_from_moments(mu, var, prior_var, A, iters=200, mask=None,
                          dac_fn=None):
    """DEC-gPoE (Alg. 6) on precomputed local moments."""
    return _poe_family_from_moments(mu, var, prior_var, A, iters, "avg",
                                    False, mask, dac_fn)


def dec_bcm_from_moments(mu, var, prior_var, A, iters=200, mask=None,
                         dac_fn=None):
    """DEC-BCM (Alg. 7) on precomputed local moments."""
    return _poe_family_from_moments(mu, var, prior_var, A, iters, "one",
                                    True, mask, dac_fn)


def dec_rbcm_from_moments(mu, var, prior_var, A, iters=200, mask=None,
                          dac_fn=None):
    """DEC-rBCM (Alg. 8) on precomputed local moments."""
    return _poe_family_from_moments(mu, var, prior_var, A, iters, "entropy",
                                    True, mask, dac_fn)


def dec_grbcm_from_moments(mu_aug, var_aug, mu_c, var_c, A, iters=200,
                           mask=None, dac_fn=None):
    """DEC-grBCM (Alg. 9) core: three DACs on augmented-expert quantities.

    mu_aug/var_aug (M, Nt) are the AUGMENTED experts' moments; mu_c/var_c
    (Nt,) the communication expert's.

    `dac_fn` (signature of `_dac_sums`) swaps the consensus readout — the
    degraded-mode hook (core/consensus/degraded.dac_masked_sums). None
    keeps the exact path and its compiled traces byte-identical.
    """
    m = jnp.ones_like(mu_aug) if mask is None else \
        jnp.broadcast_to(mask, mu_aug.shape).astype(mu_aug.dtype)
    beta = _grbcm_beta(var_aug, var_c, m, jnp.arange(mu_aug.shape[0]))
    w0 = _poe_summands(beta, mu_aug, var_aug)
    sums_fn = _dac_sums if dac_fn is None else dac_fn
    sums, res = sums_fn(w0.reshape(w0.shape[0], -1), A, iters)
    sums = sums.reshape(mu_aug.shape[1], 3)
    mean, v = _grbcm_posterior(sums[:, 0], sums[:, 1], sums[:, 2], mu_c,
                               var_c)
    return mean, v, {"dac_residuals": res}


# ---------------------------------------------------------------------------
# DAC family — per-call wrappers
# ---------------------------------------------------------------------------

def dec_poe(log_theta, Xp, yp, Xs, A, iters=200, mask=None):
    mu, var = local_moments(log_theta, Xp, yp, Xs)
    return dec_poe_from_moments(mu, var, _prior_var(log_theta), A, iters, mask)


def dec_gpoe(log_theta, Xp, yp, Xs, A, iters=200, mask=None):
    mu, var = local_moments(log_theta, Xp, yp, Xs)
    return dec_gpoe_from_moments(mu, var, _prior_var(log_theta), A, iters,
                                 mask)


def dec_bcm(log_theta, Xp, yp, Xs, A, iters=200, mask=None):
    mu, var = local_moments(log_theta, Xp, yp, Xs)
    return dec_bcm_from_moments(mu, var, _prior_var(log_theta), A, iters, mask)


def dec_rbcm(log_theta, Xp, yp, Xs, A, iters=200, mask=None):
    mu, var = local_moments(log_theta, Xp, yp, Xs)
    return dec_rbcm_from_moments(mu, var, _prior_var(log_theta), A, iters,
                                 mask)


def dec_grbcm(log_theta, Xp_aug, yp_aug, Xc, yc, Xs, A, iters=200, mask=None):
    """DEC-grBCM (Alg. 9): three DACs on augmented-expert quantities."""
    mu_aug, var_aug = local_moments(log_theta, Xp_aug, yp_aug, Xs)
    mu_c, var_c = local_moments(log_theta, Xc[None], yc[None], Xs)
    return dec_grbcm_from_moments(mu_aug, var_aug, mu_c[0], var_c[0], A,
                                  iters, mask)


# ---------------------------------------------------------------------------
# NPAE family
# ---------------------------------------------------------------------------

def _masked_system(CA, mkT):
    """Decouple masked agents from a per-query NPAE system (CA (Nt, M, M),
    mkT (Nt, M)): masked rows/columns zeroed, diagonal set to 1, so the
    live block solves exactly the masked system and masked entries settle
    at 0. With mkT all-ones this is an elementwise *1 + 0 — the identity
    the CBNN and degraded paths share."""
    M = CA.shape[-1]
    eye = jnp.eye(M, dtype=CA.dtype)
    return CA * (mkT[:, :, None] * mkT[:, None, :]) \
        + eye[None] * (1.0 - mkT)[:, None, :]


def _npae_consensus(mu, kA, CA, prior_var, A, solver, dac_iters, mask=None,
                    dac_fn=None):
    """Shared scaffold: per-query linear solves then DAC to assemble dots.

    `mask` (M, Nt) 0/1 excludes agents from the system (decoupled rows,
    zeroed payloads); `dac_fn` swaps the consensus readout (`_dac_sums`
    signature) — the degraded-mode hooks. Both default to the exact path.
    """
    if mask is not None:
        mk = mask.astype(mu.dtype)
        CA = _masked_system(CA, mk.T)
        mu = mu * mk
        kA = kA * mk
    q_mu, q_k, solver_info = solver(CA, mu.T, kA.T)        # (Nt, M) each

    # each agent holds w_i = [k_A]_i * q_i ; DAC recovers the dot products
    w0 = jnp.stack([kA * q_mu.T, kA * q_k.T], axis=-1)     # (M, Nt, 2)
    sums_fn = _dac_sums if dac_fn is None else dac_fn
    sums, res = sums_fn(w0.reshape(w0.shape[0], -1), A, dac_iters)
    sums = sums.reshape(mu.shape[1], 2)
    mean = sums[:, 0]                                      # k_A^T C_A^-1 mu  (20)
    var = jnp.maximum(prior_var - sums[:, 1], 1e-12)       # (21)
    info = {"dac_residuals": res, **solver_info}
    return mean, var, info


def _rel_jitter(C, rel=1e-6):
    """Relative diagonal jitter: C_A can be near-singular when agents are
    weakly correlated to a query (paper's NPAE-family approximation error);
    scaling by the mean diagonal keeps JOR/Cholesky well-posed across data
    scales."""
    M = C.shape[-1]
    scale = jnp.mean(jnp.diagonal(C, axis1=-2, axis2=-1), axis=-1)
    return C + (1e-12 + rel * scale)[..., None, None] * jnp.eye(M, dtype=C.dtype)


def dec_npae_from_terms(mu, kA, CA, prior_var, A, jor_iters=500,
                        dac_iters=200, omega=None, jitter=1e-6,
                        with_residuals=False, mask=None, dac_fn=None):
    """DEC-NPAE (Alg. 10) core: JOR (strongly complete) + DAC on precomputed
    NPAE terms. Lemma 2 default omega = 2/M * 0.999.

    `with_residuals=True` (the engines' diagnostics mode) adds the full
    per-round JOR residual trajectory "jor_residuals" (jor_iters,) — the
    worst query per round — to info alongside the final "jor_residual".
    `mask`/`dac_fn` are the degraded-mode hooks (see `_npae_consensus`)."""
    M = mu.shape[0]
    om = (2.0 / M) * 0.999 if omega is None else omega

    def solver(CA, b_mu, b_k):

        def one(C, bm, bk):
            q, r = jor(_rel_jitter(C, jitter), jnp.stack([bm, bk], -1), om,
                       jor_iters)
            return q[:, 0], q[:, 1], r
        qm, qk, res = jax.vmap(one)(CA, b_mu, b_k)     # res (Nt, jor_iters)
        info = {"jor_residual": jnp.max(res[:, -1]), "omega": om}
        if with_residuals:
            info["jor_residuals"] = jnp.max(res, axis=0)
        return qm, qk, info

    return _npae_consensus(mu, kA, CA, prior_var, A, solver, dac_iters,
                           mask=mask, dac_fn=dac_fn)


def dec_npae_star_from_terms(mu, kA, CA, prior_var, A, jor_iters=500,
                             dac_iters=200, pm_iters=100, jitter=1e-6,
                             with_residuals=False, mask=None, dac_fn=None):
    """DEC-NPAE* (Alg. 12) core: PM/IPM estimate omega* = 2/(lmax+lmin) per
    query, then JOR with the optimal relaxation (Lemma 3).

    `with_residuals=True` adds the per-round "jor_residuals" trajectory
    (see dec_npae_from_terms); `mask`/`dac_fn` the degraded-mode hooks."""

    def solver(CA, b_mu, b_k):

        def one(C, bm, bk):
            H = _rel_jitter(C, jitter)
            om = optimal_omega(H, pm_iters)
            q, r = jor(H, jnp.stack([bm, bk], -1), om, jor_iters)
            return q[:, 0], q[:, 1], r, om
        qm, qk, res, oms = jax.vmap(one)(CA, b_mu, b_k)
        info = {"jor_residual": jnp.max(res[:, -1]), "omega": oms}
        if with_residuals:
            info["jor_residuals"] = jnp.max(res, axis=0)
        return qm, qk, info

    return _npae_consensus(mu, kA, CA, prior_var, A, solver, dac_iters,
                           mask=mask, dac_fn=dac_fn)


def dec_npae(log_theta, Xp, yp, Xs, A, jor_iters=500, dac_iters=200,
             omega=None, jitter=1e-6):
    """DEC-NPAE (Alg. 10): JOR (strongly complete) + DAC."""
    mu, kA, CA = npae_terms(log_theta, Xp, yp, Xs)
    return dec_npae_from_terms(mu, kA, CA, _prior_var(log_theta), A,
                               jor_iters, dac_iters, omega, jitter)


def dec_npae_star(log_theta, Xp, yp, Xs, A, jor_iters=500, dac_iters=200,
                  pm_iters=100, jitter=1e-6):
    """DEC-NPAE* (Alg. 12): PM-estimated omega*, then JOR — faster
    convergence (Lemma 3)."""
    mu, kA, CA = npae_terms(log_theta, Xp, yp, Xs)
    return dec_npae_star_from_terms(mu, kA, CA, _prior_var(log_theta), A,
                                    jor_iters, dac_iters, pm_iters, jitter)


# ---------------------------------------------------------------------------
# CBNN nearest-neighbor family
# ---------------------------------------------------------------------------

def dec_nn_poe(log_theta, Xp, yp, Xs, A, eta_nn, iters=200):
    mask, _ = cbnn_mask(log_theta, Xp, Xs, eta_nn)
    m, v, info = dec_poe(log_theta, Xp, yp, Xs, A, iters, mask=mask)
    return m, v, {**info, "mask": mask}


def dec_nn_gpoe(log_theta, Xp, yp, Xs, A, eta_nn, iters=200):
    mask, _ = cbnn_mask(log_theta, Xp, Xs, eta_nn)
    m, v, info = dec_gpoe(log_theta, Xp, yp, Xs, A, iters, mask=mask)
    return m, v, {**info, "mask": mask}


def dec_nn_bcm(log_theta, Xp, yp, Xs, A, eta_nn, iters=200):
    mask, _ = cbnn_mask(log_theta, Xp, Xs, eta_nn)
    m, v, info = dec_bcm(log_theta, Xp, yp, Xs, A, iters, mask=mask)
    return m, v, {**info, "mask": mask}


def dec_nn_rbcm(log_theta, Xp, yp, Xs, A, eta_nn, iters=200):
    mask, _ = cbnn_mask(log_theta, Xp, Xs, eta_nn)
    m, v, info = dec_rbcm(log_theta, Xp, yp, Xs, A, iters, mask=mask)
    return m, v, {**info, "mask": mask}


def dec_nn_grbcm(log_theta, Xp_aug, yp_aug, Xc, yc, Xs, A, eta_nn, iters=200,
                 Xp=None):
    """DEC-NN-grBCM (Alg. 17). CBNN scores use the *local* datasets (eq. 39
    is defined on D_i), participation applies to the augmented experts."""
    Xp_scores = Xp if Xp is not None else Xp_aug
    mask, _ = cbnn_mask(log_theta, Xp_scores, Xs, eta_nn)
    m, v, info = dec_grbcm(log_theta, Xp_aug, yp_aug, Xc, yc, Xs, A, iters,
                           mask=mask)
    return m, v, {**info, "mask": mask}


def dec_nn_npae_from_terms(mask, mu, kA, CA, prior_var, A, dale_iters=2000,
                           jitter=1e-6, readout=None):
    """DEC-NN-NPAE (Alg. 18) core: CBNN-masked NPAE system solved by DALE —
    strongly connected suffices.

    Masked agents are decoupled (unit diagonal rows in H, zero b), so DALE
    solves the selected block exactly; the prediction is assembled from any
    agent's converged full solution vector.

    `readout` (M,) 0/1 restricts which agents' solution copies are
    averaged — the degraded-mode hook: on a partitioned graph only the
    surviving component's copies converge to the right solution, so the
    caller passes its component mask (with a live subgraph as `A`).
    Default None averages every copy (the exact path, unchanged).
    """
    M, Nt = mu.shape
    mkT = mask.T.astype(mu.dtype)                           # (Nt, M)
    H = _rel_jitter(_masked_system(CA, mkT), jitter)
    kA_m = (kA * mask).T                                    # (Nt, M)
    mu_m = (mu * mask).T
    r = None if readout is None else readout.astype(mu.dtype)

    def one(Ht, bm, bk, kv):
        Qm, rm = dale(Ht, bm, A, dale_iters)
        Qk, rk = dale(Ht, bk, A, dale_iters)
        # every agent holds the full solution; average copies for robustness
        if r is None:
            qm = jnp.mean(Qm, axis=0)
            qk = jnp.mean(Qk, axis=0)
        else:
            qm = (r @ Qm) / jnp.maximum(jnp.sum(r), 1.0)
            qk = (r @ Qk) / jnp.maximum(jnp.sum(r), 1.0)
        return kv @ qm, kv @ qk, jnp.maximum(rm[-1], rk[-1])

    mean, kck, res = jax.vmap(one)(H, mu_m, kA_m, kA_m)
    var = jnp.maximum(prior_var - kck, 1e-12)
    return mean, var, {"dale_residual": jnp.max(res), "mask": mask}


def dec_nn_npae(log_theta, Xp, yp, Xs, A, eta_nn, dale_iters=2000,
                jitter=1e-6):
    """DEC-NN-NPAE (Alg. 18): CBNN + DALE on a strongly connected graph."""
    mask, _ = cbnn_mask(log_theta, Xp, Xs, eta_nn)
    mu, kA, CA = npae_terms(log_theta, Xp, yp, Xs)
    return dec_nn_npae_from_terms(mask, mu, kA, CA, _prior_var(log_theta), A,
                                  dale_iters, jitter)
