"""Sparse pseudo-representation experts (ROADMAP item 2): each agent
compresses its Ni points to m << Ni inducing inputs Z_i with Titsias-style
variational factors, dropping per-expert cost from O(Ni^3) to O(Ni m^2)
and inter-agent exchange from O(Ni) to O(m).

`SparseExperts` is the drop-in counterpart to
`prediction.engine.FittedExperts`: the same (M, ...) agent-leading pytree
contract, the same fit-once / serve-many split, consumed by the SAME
PredictionEngine/ShardedEngine through isinstance dispatch. Per agent i we
cache

  Lmm_i   = chol(K(Z_i, Z_i) + jit I)                    (m, m)
  LS_i    = chol(Sigma_i + jit I),
            Sigma_i = Kmm + sigma_eps^-2 Kmn Knm         (m, m)
  c_i     = sigma_eps^-2 Sigma_i^-1 Kmn y_i              (m,)
  tr_corr = tr(Knn) - tr(Kmm^-1 Kmn Knm)                 scalar

so the SGPR posterior at a query x is mu = k_xZ c and
var = sigma_f^2 - k_xZ^T (Kmm^-1 - Sigma^-1) k_xZ, and tr_corr is the
Titsias Qnn diagonal-correction trace (-> 0 as m -> Ni), the fidelity
diagnostic reported by bench_prediction's accuracy-vs-m sweep.

The only O(Ni) work is the one-time Kmn statistics, streamed through the
blocked `kernels.ops.kmn_stats` panel accumulation — the (Ni, Ni) Gram is
never materialized, which is what makes 100k+ points per agent fit.

IMPORT CONTRACT: this module must not import repro.core.prediction at
module level (prediction.engine imports us; see lowrank.dec_npae_sparse
for the lazy aggregation import).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ...kernels.ops import kmn_stats, rbf_matvec
from ..gp.kernel import se_kernel, unpack


class SparseExperts(NamedTuple):
    """Per-agent sparse factors, computed once after training (the
    SparseExperts <-> FittedExperts duality both engines dispatch on)."""
    log_theta: jax.Array   # (D+2,) shared hyperparameters
    Z: jax.Array           # (M, m, D) inducing inputs
    Lmm: jax.Array         # (M, m, m) chol(Kmm + jit I)
    LS: jax.Array          # (M, m, m) chol(Sigma + jit I)
    c: jax.Array           # (M, m)   posterior mean weights
    tr_corr: jax.Array     # (M,)     Titsias diagonal-correction trace

    @property
    def num_agents(self) -> int:
        return self.Z.shape[0]

    @property
    def prior_var(self):
        return jnp.exp(self.log_theta[-2]) ** 2

    @property
    def Xp(self):
        """Inducing inputs stand in for the training inputs everywhere the
        engines only need representative geometry (centroids, routing)."""
        return self.Z

    @property
    def Kcross(self):
        """Sparse experts never carry a dense cross-Gram cache — the
        low-rank NPAE path replaces it (lowrank.npae_terms_lowrank)."""
        return None


def select_inducing(Xp: jax.Array, m: int, method: str = "stride",
                    seed: int = 0) -> jax.Array:
    """Per-agent inducing inputs Z (M, m, D) from the training inputs.

    "stride"  — evenly strided subset (deterministic; distinct indices for
                m <= Ni, the m = Ni limit recovering the full set),
    "random"  — per-agent uniform subset without replacement
                (fold_in(seed, agent) so agents decorrelate).

    m is clamped to Ni so tiny fleets (grbcm communication experts, smoke
    runs) never index out of range.
    """
    M, N = Xp.shape[0], Xp.shape[1]
    m = min(int(m), N)
    if method == "stride":
        idx = np.round(np.linspace(0, N - 1, m)).astype(np.int32)
        return Xp[:, idx, :]
    if method == "random":
        key = jax.random.PRNGKey(seed)

        def one(i, Xi):
            p = jax.random.permutation(jax.random.fold_in(key, i), N)
            return Xi[p[:m]]

        return jax.vmap(one)(jnp.arange(M), Xp)
    raise ValueError(f"unknown inducing_init {method!r} "
                     f"(choices: 'stride', 'random')")


def _rel_jitter(sigma_f, dtype, jitter):
    """Jitter relative to the prior scale, floored at 8 eps — the same
    conditioning policy as aggregation.npae's per-query solve."""
    eps = jnp.finfo(dtype).eps
    return (jitter + 8.0 * eps) * sigma_f**2


def fit_sparse_experts(log_theta, Xp, yp, Z, jitter: float = 1e-8,
                       block: int = 4096) -> SparseExperts:
    """Factorize every agent's sparse model once. Xp (M, Ni, D),
    yp (M, Ni), Z (M, m, D) -> SparseExperts.

    Cost per agent: O(Ni m) kernel evaluations streamed in (m, block)
    panels (`kmn_stats`), O(Ni m^2) for the Kmn Knm accumulation, O(m^3)
    for the two Cholesky factors. No O(Ni^2) anywhere.
    """
    ls, sigma_f, sigma_eps = unpack(log_theta)
    jit_eff = _rel_jitter(sigma_f, Xp.dtype, jitter)
    m = Z.shape[1]
    eye = jnp.eye(m, dtype=Xp.dtype)

    def one(Zi, Xi, yi):
        Kmm = se_kernel(Zi, Zi, log_theta)
        B, b = kmn_stats(Zi, Xi, yi, ls, sigma_f, bn=block)
        Lmm = jnp.linalg.cholesky(Kmm + jit_eff * eye)
        # chol(Sigma) via the whitened form: Sigma = Kmm + B/sigma_eps^2 is
        # catastrophically ill-conditioned at large Ni (diagonal ~
        # Ni sigma_f^4 / sigma_eps^2 vs Kmm's ~jit floor — a direct chol
        # NaNs at Ni ~ 1e5), but W = Lmm^-1 B Lmm^-T / sigma_eps^2 gives
        # Bw = I + W with min-eig >= 1, and LS = Lmm chol(Bw) is an EXACT
        # lower-triangular factor of Sigma + jit I (same matrix, same
        # downstream triangular solves).
        W = jax.scipy.linalg.solve_triangular(Lmm, B, lower=True)
        W = jax.scipy.linalg.solve_triangular(Lmm, W.T, lower=True)
        W = 0.5 * (W + W.T) / sigma_eps**2
        # W's true eigenvalues are >= 0 (it is A A^T / sigma_eps^2 for
        # A = Lmm^-1 Kmn), but B's accumulation roundoff amplified through
        # Kmm's near-null space (cond(Lmm)^2) can push computed eigenvalues
        # of I + W well below 1 at Ni ~ 1e5 — project back onto the
        # feasible cone (eigenvalue floor at the provable minimum 1) so
        # the Cholesky always exists; a no-op when conditioning is benign.
        ew, V = jnp.linalg.eigh(eye + W)
        Bw = (V * jnp.maximum(ew, 1.0)) @ V.T
        LS = Lmm @ jnp.linalg.cholesky(Bw)
        c = jax.scipy.linalg.cho_solve((LS, True), b) / sigma_eps**2
        # qnn = tr(Kmm^-1 B) = tr(W) sigma_eps^2; the true correction is
        # >= 0 — clamp the roundoff that can push it slightly negative
        tr_corr = jnp.maximum(
            Xi.shape[0] * sigma_f**2 - jnp.trace(W) * sigma_eps**2, 0.0)
        return Lmm, LS, c, tr_corr

    Lmm, LS, c, tr_corr = jax.vmap(one)(Z, Xp, yp)
    return SparseExperts(log_theta, Z, Lmm, LS, c, tr_corr)


def sparse_moments_cached(log_theta, Z, Lmm, LS, c, Xs,
                          stream_mean: bool = False):
    """Local SGPR moments from cached sparse factors — the sparse analogue
    of `prediction.local.local_moments_cached`, feeding the SAME
    PoE/BCM/CBNN aggregation cores. Returns (mu, var), each (M, Nt).

    var = sigma_f^2 - k^T Kmm^-1 k + k^T Sigma^-1 k (the collapsed-bound
    posterior latent variance), floored at 1e-12 like the dense path.
    """
    ls, sigma_f, _ = unpack(log_theta)
    kss = sigma_f**2

    def one(Zi, Lmi, LSi, ci):
        ks = se_kernel(Zi, Xs, log_theta)                        # (m, Nt)
        v1 = jax.scipy.linalg.solve_triangular(Lmi, ks, lower=True)
        v2 = jax.scipy.linalg.solve_triangular(LSi, ks, lower=True)
        var = jnp.maximum(kss - jnp.sum(v1 * v1, axis=0)
                          + jnp.sum(v2 * v2, axis=0), 1e-12)
        return ks.T @ ci, var

    if stream_mean:
        var = jax.vmap(lambda Zi, Lmi, LSi, ci: one(Zi, Lmi, LSi, ci)[1])(
            Z, Lmm, LS, c)
        mu = jax.vmap(lambda Zi, ci: rbf_matvec(Xs, Zi, ci, ls, sigma_f))(
            Z, c).astype(Xs.dtype)
        return mu, var
    return jax.vmap(one)(Z, Lmm, LS, c)


def sparse_scores(log_theta, Z, Lmm, LS, Xs):
    """CBNN covariance scores (eq. 39 semantics: sigma_f^2 - var_i) from
    sparse factors -> (M, Nt); same scale as `cbnn.cbnn_scores_cached`, so
    the eta_nn thresholds and the >= max guarantee carry over unchanged."""
    def one(Zi, Lmi, LSi):
        ks = se_kernel(Zi, Xs, log_theta)
        v1 = jax.scipy.linalg.solve_triangular(Lmi, ks, lower=True)
        v2 = jax.scipy.linalg.solve_triangular(LSi, ks, lower=True)
        return jnp.sum(v1 * v1, axis=0) - jnp.sum(v2 * v2, axis=0)

    return jax.vmap(one)(Z, Lmm, LS)
