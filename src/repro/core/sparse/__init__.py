"""Sparse pseudo-representation experts (ROADMAP item 2): O(Ni m^2)
agents, collapsed-ELBO training, and the low-rank NPAE factors that let
the NPAE family shard (docs/sparse_experts.md).

Surface frozen by tools/check_api.py. Import order matters: experts and
trainer are prediction-free; lowrank defers its aggregation import
(prediction.engine imports this package).
"""
from .experts import (SparseExperts, select_inducing, fit_sparse_experts,
                      sparse_moments_cached, sparse_scores)
from .trainer import (sparse_nll, sparse_nlls, train_fact_sparse,
                      make_sparse_grad)
from .lowrank import (sparse_npae_factors, cross_lowrank,
                      npae_terms_lowrank, dec_npae_sparse)

__all__ = [
    "SparseExperts", "select_inducing", "fit_sparse_experts",
    "sparse_moments_cached", "sparse_scores",
    "sparse_nll", "sparse_nlls", "train_fact_sparse", "make_sparse_grad",
    "sparse_npae_factors", "cross_lowrank", "npae_terms_lowrank",
    "dec_npae_sparse",
]
