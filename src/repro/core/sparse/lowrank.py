"""Low-rank NPAE from sparse factors: the sharded-NPAE unlock.

Dense NPAE needs every cross-agent Gram block K(X_i, X_j) — O(M^2 Ni^2)
work and memory, which is why the exact NPAE family serves replicated
only. With sparse experts the cross-covariance of the expert means is
low-rank: per query t and agents i, j

  [C_A]_ij[t] = U_i[:, t]^T  K(Z_i, Z_j)  U_j[:, t],
  U_i = (Kmm_i^-1 - Sigma_i^-1) k(Z_i, x_t)          (m, q) per agent,

a double-Nystroem through the pseudo-points: O(M^2 m^2) per query tile,
and — decisive for sharding — each agent contributes only its (m, q)
factor U_i plus its m inducing points. A shard therefore serves the full
NPAE solve after ring-allgathering M small factors instead of exchanging
O(Ni)-sized data (consensus.dac.ring_allgather), registered as the
`npae_sparse` method with shardable=True.

The diagonal is set to the exact local k_A (same idiom as the dense
`npae_terms_cached`), and the final per-query solve is the SAME
`aggregation.npae` core in the replicated and sharded engines — which is
what makes sharded == replicated parity hold by construction.

IMPORT CONTRACT: `aggregation` is imported lazily inside
`dec_npae_sparse` — prediction.engine imports this package, so a
module-level import of any repro.core.prediction submodule would cycle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..gp.kernel import se_kernel
from .experts import SparseExperts, fit_sparse_experts, select_inducing


def sparse_npae_factors(log_theta, Z, Lmm, LS, c, Xs):
    """Per-agent low-rank NPAE factors at the query tile Xs (Nt, D).

    Returns (mu (M, Nt), kA (M, Nt), U (M, m, Nt)) with
    U_i = (Kmm^-1 - Sigma^-1) k(Z_i, Xs) and kA_i = k^T U_i — exactly the
    payload a shard exchanges (O(m Nt) per agent).
    """
    def one(Zi, Lmi, LSi, ci):
        ks = se_kernel(Zi, Xs, log_theta)                        # (m, Nt)
        U = (jax.scipy.linalg.cho_solve((Lmi, True), ks)
             - jax.scipy.linalg.cho_solve((LSi, True), ks))
        kA = jnp.sum(ks * U, axis=0)
        return ks.T @ ci, kA, U

    return jax.vmap(one)(Z, Lmm, LS, c)


def cross_lowrank(log_theta, Z, U, kA):
    """Assemble C_A (Nt, M, M) from allgathered factors: off-diagonals via
    the double-Nystroem U_i^T K(Z_i, Z_j) U_j, diagonal set to the exact
    local k_A. Pure function of the exchanged (Z, U, kA) — the replicated
    engine and every shard run this same assembly on identical inputs,
    which is the bit-identical-parity argument."""
    M = Z.shape[0]

    def cross(i, j):
        Kij = se_kernel(Z[i], Z[j], log_theta)                   # (m, m)
        return jnp.einsum("at,ab,bt->t", U[i], Kij, U[j])        # (Nt,)

    idx = jnp.arange(M)
    CA = jax.vmap(lambda i: jax.vmap(lambda j: cross(i, j))(idx))(idx)
    CA = jnp.moveaxis(CA, -1, 0)                                 # (Nt, M, M)
    return CA.at[:, idx, idx].set(kA.T)


def npae_terms_lowrank(log_theta, Z, Lmm, LS, c, Xs):
    """NPAE aggregation terms from sparse factors — the drop-in analogue of
    `prediction.local.npae_terms_cached` at O(M^2 m^2) per query instead of
    O(M^2 Ni^2). Returns (mu (M,Nt), kA (M,Nt), CA (Nt,M,M))."""
    mu, kA, U = sparse_npae_factors(log_theta, Z, Lmm, LS, c, Xs)
    return mu, kA, cross_lowrank(log_theta, Z, U, kA)


def dec_npae_sparse(log_theta, Xp, yp, Xs, m: int,
                    inducing_init: str = "stride", jitter: float = 1e-8,
                    npae_jitter: float = 1e-6, seed: int = 0,
                    experts: SparseExperts | None = None):
    """Per-call reference wrapper (fit-and-predict-in-one): sparse NPAE on
    raw data — the `legacy` entry the facade tests compare the engines
    against. Pass `experts` to reuse already-fitted factors.
    Returns (mean (Nt,), var (Nt,))."""
    from ..prediction.aggregation import npae   # lazy: avoid import cycle
    f = experts
    if f is None:
        Z = select_inducing(Xp, m, inducing_init, seed)
        f = fit_sparse_experts(log_theta, Xp, yp, Z, jitter=jitter)
    mu, kA, CA = npae_terms_lowrank(f.log_theta, f.Z, f.Lmm, f.LS, f.c, Xs)
    return npae(mu, kA, CA, f.prior_var, jitter=npae_jitter)
