"""Sparse variational training: the Titsias collapsed ELBO as a drop-in
local objective for BOTH trainer families.

  fact-sparse     — centralized FACT-GP workflow (factorized.train_fact_gp
                    pattern) on the summed collapsed bounds, jointly over
                    hyperparameters AND inducing inputs Z (Adam + scan).
                    Warm-startable: pass the exact ADMM theta as log_theta0.
  dec-apx-sparse  — decentralized ADMM (train_dec_apx_gp) with the local
                    NLL gradient swapped for the collapsed-ELBO gradient
                    through the existing `grad_fn` hook
                    (training.cache.make_local_grad custom-callable form):
                    each agent derives its Z from a strided subset of its
                    own data, so the eq. (34) update rule and the consensus
                    structure are untouched.

The bound (Titsias 2009, in the paper's kernel convention, as a NEGATIVE
log-likelihood to minimize):

  -ELBO_i = N/2 log 2pi + sum log diag(LB) + N log sigma_eps
            + (y^T y - c^T c)/(2 sigma_eps^2)            [data fit]
            + (tr(Knn) - tr(A A^T)) / (2 sigma_eps^2)    [Qnn correction]

with A = Lm^-1 Kmn, B = I + A A^T / sigma_eps^2, LB = chol(B),
c = LB^-1 A y, tr(Knn) = N sigma_f^2. At m = Ni the correction vanishes
and the bound equals the exact NLL.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ...optim import adam, apply_updates
from ..gp.kernel import se_kernel, unpack
from .experts import _rel_jitter


def sparse_nll(log_theta, Z, Xi, yi, jitter: float = 1e-8):
    """Negative collapsed ELBO for ONE agent. Z (m, D), Xi (N, D), yi (N,).

    Differentiable in both log_theta and Z — O(N m^2) per evaluation, no
    (N, N) matrix anywhere.
    """
    ls, sigma_f, sigma_eps = unpack(log_theta)
    N, m = Xi.shape[0], Z.shape[0]
    dtype = Xi.dtype
    Kmm = se_kernel(Z, Z, log_theta)
    Lm = jnp.linalg.cholesky(Kmm + _rel_jitter(sigma_f, dtype, jitter)
                             * jnp.eye(m, dtype=dtype))
    Kmn = se_kernel(Z, Xi, log_theta)
    A = jax.scipy.linalg.solve_triangular(Lm, Kmn, lower=True)   # (m, N)
    B = jnp.eye(m, dtype=dtype) + (A @ A.T) / sigma_eps**2
    LB = jnp.linalg.cholesky(B)
    cb = jax.scipy.linalg.solve_triangular(LB, A @ yi, lower=True)
    data_fit = (yi @ yi - (cb @ cb) / sigma_eps**2) / (2.0 * sigma_eps**2)
    qnn_corr = (N * sigma_f**2 - jnp.sum(A * A)) / (2.0 * sigma_eps**2)
    return (0.5 * N * jnp.log(2.0 * jnp.pi)
            + jnp.sum(jnp.log(jnp.diagonal(LB)))
            + N * jnp.log(sigma_eps) + data_fit + qnn_corr)


def sparse_nlls(log_theta, Z, Xp, yp, jitter: float = 1e-8):
    """-ELBO_i per agent with shared theta, per-agent Z (M, m, D)."""
    return jax.vmap(lambda Zi, Xi, yi: sparse_nll(log_theta, Zi, Xi, yi,
                                                  jitter))(Z, Xp, yp)


@partial(jax.jit, static_argnames=("steps",))
def train_fact_sparse(log_theta0, Xp, yp, Z0, steps: int = 200,
                      lr: float = 0.05, jitter: float = 1e-8):
    """fact-sparse: centralized Adam on sum_i -ELBO_i, JOINTLY over the
    shared log_theta and every agent's inducing inputs Z (M, m, D).

    Same communication pattern as FACT-GP (each agent ships its local
    gradient, the server broadcasts) — the theta gradient is (D+2,) and the
    Z gradient stays local to its agent. Returns (log_theta, Z, vals) with
    vals the per-step summed bound (GPFleet surfaces it as info["nll"]).
    """
    opt = adam(lr, state_dtype=log_theta0.dtype)

    def objective(params):
        lt, Z = params
        return jnp.sum(sparse_nlls(lt, Z, Xp, yp, jitter))

    grad_fn = jax.value_and_grad(objective)

    def body(carry, _):
        params, st = carry
        val, g = grad_fn(params)
        upd, st = opt.update(g, st, params)
        return (apply_updates(params, upd), st), val

    params0 = (log_theta0, Z0)
    (params, _), vals = jax.lax.scan(body, (params0, opt.init(params0)),
                                     None, length=steps)
    lt, Z = params
    return lt, Z, vals


def make_sparse_grad(m: int, jitter: float = 1e-8):
    """Custom per-agent gradient for the ADMM `grad_fn` hook (dec-apx-sparse):
    d(-ELBO_i)/dlog_theta with Z_i a strided subset of the agent's own data
    (deterministic, agent-local — no coordination needed inside the
    consensus loop; `inducing_init` only affects the serving-time Z).
    Signature matches the make_local_grad custom-callable contract:
    (log_theta, Xi, yi) -> (D+2,).
    """
    def grad_one(log_theta, Xi, yi):
        N = Xi.shape[0]
        idx = np.round(np.linspace(0, N - 1, min(int(m), N))).astype(np.int32)
        Z = Xi[idx]
        return jax.grad(sparse_nll)(log_theta, Z, Xi, yi, jitter)

    return grad_one
