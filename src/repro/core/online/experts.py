"""Streaming multi-agent GP experts: sliding windows with incremental factors.

The batch pipeline (`fit_experts`) factorizes each agent's (Ni, Ni) kernel
matrix once and freezes the fleet. Agents that keep observing would have to
pay the O(Ni^3) refactorization per new point. `OnlineExperts` instead keeps
a fixed-shape AGE-ORDERED window per agent (oldest observation in slot 0,
newest in slot count-1, empty slots a contiguous sentinel tail) and
maintains the Cholesky factor L_i and weight vector alpha_i = C_i^{-1} y_i
INCREMENTALLY, O(W^2) per event against O(W^3) for a refit:

  observe(state, agent, x, y)  — if the window is full, evict the oldest
      first; then APPEND at slot `count`: because everything below the
      insert slot is a sentinel, the new sub-diagonal column is exactly
      zero, so insertion is one blocked triangular solve for the new row
      plus a scalar sqrt — no trailing sweep at all. alpha follows by two
      blocked triangular solves.
  evict_oldest(state, agent)   — drop slot 0: one rank-1 Cholesky UPDATE
      of the trailing (W-1)^2 block with the evicted point's sub-diagonal
      column (kernels.ops.cholupdate — the O(W^2) column sweep), with the
      one-slot shift fused into the write. Age order makes the evicted row
      STATICALLY slot 0, so the sweep runs over static slices and its
      panel skip kicks in for partially filled windows.

Fixed shapes make every operation jit-able with a traced `agent` index:
empty slots are *sentinel observations* — pseudo-inputs placed
`_SENTINEL`-far from the data (so every kernel row k(x_sent, .) underflows
to exactly 0.0) with y = 0. The covariance row/column of a sentinel slot is
exactly e_p (sigma_f^2 + sigma_eps^2 + jitter), its Cholesky row/column is
e_p * s_diag, and its alpha entry is 0 — so `to_fitted()` hands the window
arrays straight to the batch `PredictionEngine` and every decentralized
method (PoE/BCM families, NPAE cross-covariances, CBNN scores) works
unchanged on the live fleet, sentinels contributing nothing.

Validity of the sentinel trick requires lengthscales << _SENTINEL (so the
cross-kernel underflows): exp(-x) is 0.0 below x ~ -750 in float64, and
(1e6 / l)^2 > 750 for any l < 3.6e4 — comfortably true for normalized
inputs. Sentinel coordinates stay pairwise _SENTINEL-separated by
construction: eviction shifts the tail down and appends a fresh sentinel
at `last coordinate + _SENTINEL` (see `_evict_oldest_shift`).

Slot order fixes the factorization order; the refit reference (`refit`)
uses the same slot order, so incremental factors are directly comparable
(the Cholesky factor of a PD matrix is unique).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ...kernels.ops import cholupdate
from ..gp.kernel import se_kernel, unpack

_SENTINEL = 1e6


def _sentinel_coords(W: int, D: int, dtype) -> jax.Array:
    """(W, D) pseudo-inputs, pairwise _SENTINEL-separated and _SENTINEL-far
    from any O(1) data point."""
    return jnp.broadcast_to(
        (_SENTINEL * jnp.arange(1, W + 1, dtype=dtype))[:, None], (W, D))


def _s_diag(log_theta, jitter):
    """Cholesky diagonal of an empty (sentinel) slot."""
    _, sigma_f, sigma_eps = unpack(log_theta)
    return jnp.sqrt(sigma_f**2 + sigma_eps**2 + jitter)


_SOLVE_BK = 256


def _fwd_solve(L, b):
    """Blocked forward substitution L sol = b (L lower). XLA's CPU
    triangular_solve is ~10x off streaming rate for a single rhs; static
    panel slices turn all but the (bk, bk) diagonal solves into gemvs."""
    n = L.shape[0]
    sol = jnp.zeros_like(b)
    for k0 in range(0, n, _SOLVE_BK):
        k1 = min(k0 + _SOLVE_BK, n)
        rhs = b[k0:k1] - L[k0:k1, :k0] @ sol[:k0]
        s = jax.scipy.linalg.solve_triangular(L[k0:k1, k0:k1], rhs,
                                              lower=True)
        sol = sol.at[k0:k1].set(s)
    return sol


def _bwd_solve(L, b):
    """Blocked back substitution L^T sol = b."""
    n = L.shape[0]
    sol = jnp.zeros_like(b)
    for k1 in range(n, 0, -_SOLVE_BK):
        k0 = max(0, k1 - _SOLVE_BK)
        # vector-matrix form reads L row-major (vs the strided L^T gemv);
        # transposing the small diagonal block beats the trans=1 path
        rhs = b[k0:k1] - sol[k1:] @ L[k1:, k0:k1]
        s = jax.scipy.linalg.solve_triangular(L[k0:k1, k0:k1].T, rhs,
                                              lower=False)
        sol = sol.at[k0:k1].set(s)
    return sol


def _cho_solve(L, b):
    """alpha = (L L^T)^{-1} b by the two blocked triangular solves."""
    return _bwd_solve(L, _fwd_solve(L, b))


class OnlineExperts(NamedTuple):
    """Per-agent streaming state (a jit-able fixed-shape pytree).

    Age-ordered window: slots [0, count) hold real observations oldest
    first; slots [count, W) are sentinels (see module docstring).
    """
    log_theta: jax.Array   # (D+2,)
    Xw: jax.Array          # (M, W, D) window inputs; sentinels when invalid
    yw: jax.Array          # (M, W)    window targets; 0 when invalid
    L: jax.Array           # (M, W, W) chol of the masked window covariance
    alpha: jax.Array       # (M, W)    C_i^{-1} y_i; 0 in sentinel slots
    count: jax.Array       # (M,) int32 — number of valid observations
    jitter: jax.Array      # () factorization jitter (module-wide constant)

    @property
    def num_agents(self) -> int:
        return self.Xw.shape[0]

    @property
    def window(self) -> int:
        return self.Xw.shape[1]

    @property
    def valid(self) -> jax.Array:
        """(M, W) bool — which slots hold real observations."""
        return jnp.arange(self.window)[None, :] < self.count[:, None]

    def to_fitted(self):
        """View as batch `FittedExperts` — serves through PredictionEngine
        unchanged (sentinel slots contribute exactly nothing)."""
        from ..prediction.engine import FittedExperts
        return FittedExperts(self.log_theta, self.Xw, self.yw, self.L,
                             self.alpha)


def init_online(log_theta, M: int, W: int, D: int, dtype=None,
                jitter: float = 1e-8) -> OnlineExperts:
    """Empty fleet: every slot a sentinel, factors exactly s_diag * I."""
    log_theta = jnp.asarray(log_theta)
    if dtype is None:
        dtype = log_theta.dtype
    log_theta = log_theta.astype(dtype)
    jit_arr = jnp.asarray(jitter, dtype)
    Xw = jnp.broadcast_to(_sentinel_coords(W, D, dtype)[None], (M, W, D))
    L = jnp.broadcast_to(
        (_s_diag(log_theta, jit_arr) * jnp.eye(W, dtype=dtype))[None],
        (M, W, W))
    return OnlineExperts(log_theta, Xw, jnp.zeros((M, W), dtype), L,
                         jnp.zeros((M, W), dtype),
                         jnp.zeros((M,), jnp.int32), jit_arr)


def _window_cov(log_theta, jitter, Xi, valid):
    """Masked window covariance: real block K + noise, sentinel rows/cols
    exactly e_p (sigma_f^2 + sigma_eps^2 + jitter) — the matrix the
    incremental updates maintain the factor of."""
    _, sigma_f, sigma_eps = unpack(log_theta)
    W = Xi.shape[0]
    v = valid.astype(Xi.dtype)
    K = se_kernel(Xi, Xi, log_theta) * v[:, None] * v[None, :]
    return (K + (sigma_eps**2 + jitter) * jnp.eye(W, dtype=Xi.dtype)
            + sigma_f**2 * jnp.diag(1.0 - v))


def refit(state: OnlineExperts) -> OnlineExperts:
    """O(W^3) from-scratch refactorization of every window — the reference
    the incremental path is tested/benchmarked against."""
    valid = state.valid

    def one(Xi, yi, vi):
        C = _window_cov(state.log_theta, state.jitter, Xi, vi)
        L = jnp.linalg.cholesky(C)
        return L, _cho_solve(L, yi * vi)

    L, alpha = jax.vmap(one)(state.Xw, state.yw, valid)
    return state._replace(L=L, alpha=alpha)


def from_batch(log_theta, Xp, yp, window: int | None = None,
               jitter: float = 1e-8) -> OnlineExperts:
    """Seed a streaming fleet from batch data given OLDEST FIRST (keeps the
    last `window` points per agent when the window is smaller)."""
    Xp, yp = jnp.asarray(Xp), jnp.asarray(yp)
    M, Ni, D = Xp.shape
    W = Ni if window is None else int(window)
    if W < Ni:
        Xp, yp = Xp[:, Ni - W:], yp[:, Ni - W:]
        Ni = W
    state = init_online(log_theta, M, W, D, dtype=Xp.dtype, jitter=jitter)
    state = state._replace(
        Xw=state.Xw.at[:, :Ni].set(Xp), yw=state.yw.at[:, :Ni].set(yp),
        count=jnp.full((M,), Ni, jnp.int32))
    return refit(state)


# -- per-agent incremental cores (vmap-able) --------------------------------

def _evict_oldest_shift(log_theta, jitter, Xw, yw, L):
    """Drop slot 0: the remaining points' factor is the rank-1 UPDATE of
    the trailing block with the evicted sub-diagonal column (the factor
    mass column 0 carried), written one slot up-left; slot W-1 becomes a
    fresh sentinel at `last coordinate + _SENTINEL` (keeps all sentinel
    coordinates pairwise _SENTINEL-separated — after a full-window evict
    it is the ONLY sentinel, otherwise it extends the monotone sentinel
    tail). A window that is already empty only rotates its sentinels."""
    W, D = Xw.shape
    # rank-1 update of the trailing block with the evicted sub-diagonal
    # column, written one slot up-left in the same sweep (shift=1); the
    # stale last row/column becomes the fresh sentinel
    L = cholupdate(L, L[:, 0], shift=1)
    evec = _s_diag(log_theta, jitter) * (jnp.arange(W) == W - 1)
    L = L.at[W - 1].set(evec).at[:, W - 1].set(evec)
    Xw = jnp.concatenate([Xw[1:], Xw[W - 1:] + _SENTINEL])
    yw = jnp.concatenate([yw[1:], jnp.zeros((1,), yw.dtype)])
    return Xw, yw, L


def _append_one(log_theta, jitter, Xw, yw, L, slot, x, y):
    """Write (x, y) into sentinel slot `slot` (everything below it is a
    sentinel, so the new sub-diagonal column is exactly zero): one blocked
    triangular solve for the new row, no trailing sweep."""
    W, D = Xw.shape
    _, sigma_f, sigma_eps = unpack(log_theta)
    idx = jnp.arange(W)
    x = x.astype(Xw.dtype)
    kvec = se_kernel(Xw, x[None], log_theta)[:, 0]          # sentinels -> 0.0
    c1 = jnp.where(idx < slot, kvec, 0.0)
    w = jnp.where(idx < slot, _fwd_solve(L, c1), 0.0)
    d2 = sigma_f**2 + sigma_eps**2 + jitter - jnp.sum(w * w)
    d = jnp.sqrt(jnp.maximum(d2, jnp.finfo(Xw.dtype).tiny))
    L = L.at[slot].set(w + d * (idx == slot))   # row: (w_{<slot}, d, 0...)
    Xw = Xw.at[slot].set(x)
    yw = yw.at[slot].set(y.astype(yw.dtype))
    return Xw, yw, L


def _observe_one(log_theta, jitter, Xw, yw, L, count, x, y):
    full = count >= Xw.shape[0]
    Xw, yw, L = jax.lax.cond(
        full,
        lambda a: _evict_oldest_shift(log_theta, jitter, *a),
        lambda a: a, (Xw, yw, L))
    count = jnp.where(full, count - 1, count)
    Xw, yw, L = _append_one(log_theta, jitter, Xw, yw, L, count, x, y)
    alpha = _cho_solve(L, yw)
    return Xw, yw, L, alpha, count + 1


def _evict_one(log_theta, jitter, Xw, yw, L, count):
    Xw, yw, L = jax.lax.cond(
        count > 0,
        lambda a: _evict_oldest_shift(log_theta, jitter, *a),
        lambda a: a, (Xw, yw, L))
    alpha = _cho_solve(L, yw)
    return Xw, yw, L, alpha, jnp.maximum(count - 1, 0)


def _scatter_agent(state: OnlineExperts, agent, parts) -> OnlineExperts:
    Xw, yw, L, alpha, count = parts
    return state._replace(
        Xw=state.Xw.at[agent].set(Xw), yw=state.yw.at[agent].set(yw),
        L=state.L.at[agent].set(L), alpha=state.alpha.at[agent].set(alpha),
        count=state.count.at[agent].set(count))


# -- public streaming API ----------------------------------------------------

def observe(state: OnlineExperts, agent, x, y) -> OnlineExperts:
    """Agent `agent` (traced index is fine) ingests one observation,
    evicting its oldest when the window is full. O(W^2)."""
    parts = _observe_one(state.log_theta, state.jitter, state.Xw[agent],
                         state.yw[agent], state.L[agent],
                         state.count[agent], x, y)
    return _scatter_agent(state, agent, parts)


def observe_fleet(state: OnlineExperts, xs, ys) -> OnlineExperts:
    """Every agent ingests one observation (xs (M, D), ys (M,)) — the
    vmapped hot path for synchronous streams."""
    Xw, yw, L, alpha, count = jax.vmap(
        _observe_one, in_axes=(None, None, 0, 0, 0, 0, 0, 0))(
            state.log_theta, state.jitter, state.Xw, state.yw, state.L,
            state.count, xs, ys)
    return state._replace(Xw=Xw, yw=yw, L=L, alpha=alpha, count=count)


def evict_oldest(state: OnlineExperts, agent) -> OnlineExperts:
    """Drop agent's oldest observation (no-op on an empty window)."""
    parts = _evict_one(state.log_theta, state.jitter, state.Xw[agent],
                       state.yw[agent], state.L[agent], state.count[agent])
    return _scatter_agent(state, agent, parts)
