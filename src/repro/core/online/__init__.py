"""Online/streaming GP subsystem: sliding-window experts with incremental
rank-1 Cholesky factor maintenance, and dynamic fleet membership.

See docs/online_gp.md for the update/downdate math, window semantics, the
join/leave protocol, and serving integration. The lifecycle facade
(`repro.fleet.GPFleet` with FleetConfig(online=True)) drives this module
through `observe` / `join` / `leave` and persists the window state in
`save()`/`load()`."""
from .experts import (OnlineExperts, evict_oldest, from_batch, init_online,
                      observe, observe_fleet, refit)
from .membership import join, leave

__all__ = [
    "OnlineExperts", "init_online", "from_batch", "refit",
    "observe", "observe_fleet", "evict_oldest", "join", "leave",
]
