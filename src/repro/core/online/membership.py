"""Dynamic agent membership for a streaming fleet.

Agents join and leave a live fleet. Both operations change the agent-axis
shape, so they run host-side (outside jit); the returned (state, A) pair
re-enters the jit world through `PredictionEngine.rewire` — the consensus
protocols (DAC/JOR/DALE) are stateless across predict calls, so re-syncing
them means exactly: new adjacency, new Perron weights, fresh compiled
traces. Connectivity is preserved by construction: a joiner attaches to at
least one existing agent, and a leaver's former neighbors are re-chained
(consensus over a disconnected graph silently averages per-component,
which would corrupt every DAC-family prediction).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..consensus.graph import attach_agent, is_connected, remove_agent
from .experts import OnlineExperts, from_batch, init_online


def join(state: OnlineExperts, A, X_new=None, y_new=None,
         neighbors=None):
    """Add one agent; returns (state', A') with M+1 agents.

    `X_new (n, D)` / `y_new (n,)` seed the joiner's window (last W points
    kept); omitted, it joins empty and warms up through `observe`.
    `neighbors` are the existing agents it can exchange messages with
    (default: the current last agent — extends a path/ring topology).
    """
    M, W, D = state.Xw.shape
    if neighbors is None:
        neighbors = (M - 1,)
    if X_new is not None:
        new = from_batch(state.log_theta, jnp.asarray(X_new)[None],
                         jnp.asarray(y_new)[None], window=W,
                         jitter=float(state.jitter))
    else:
        new = init_online(state.log_theta, 1, W, D, dtype=state.Xw.dtype,
                          jitter=float(state.jitter))
    merged = state._replace(
        Xw=jnp.concatenate([state.Xw, new.Xw]),
        yw=jnp.concatenate([state.yw, new.yw]),
        L=jnp.concatenate([state.L, new.L]),
        alpha=jnp.concatenate([state.alpha, new.alpha]),
        count=jnp.concatenate([state.count, new.count]))
    return merged, attach_agent(A, neighbors)


def leave(state: OnlineExperts, A, agent: int):
    """Remove agent `agent`; returns (state', A') with M-1 agents, former
    neighbors re-chained so the consensus graph stays connected."""
    M = state.num_agents
    agent = int(agent)
    if not 0 <= agent < M:
        raise ValueError(f"agent {agent} not in fleet of {M}")
    if M <= 1:
        raise ValueError("cannot remove the last agent")
    keep = np.delete(np.arange(M), agent)
    shrunk = state._replace(
        Xw=state.Xw[keep], yw=state.yw[keep], L=state.L[keep],
        alpha=state.alpha[keep], count=state.count[keep])
    A2 = remove_agent(A, agent, reconnect=True)
    assert is_connected(A2), "leave() broke graph connectivity"
    return shrunk, A2
