"""Centralized ADMM factorized GP training (paper §3): c-GP (eq. 24),
apx-GP (eq. 26, Xie et al. 2019), and the paper's proposed gapx-GP (Alg. 1).

All agent-local quantities live on a leading agent axis (M, ...) and are
vmapped; the server steps (z-update) are means over that axis. Local NLL
gradients go through the same `grad_fn` hook as the decentralized loops
(default: the cached-geometry fused path of core.training.cache; "autodiff"
restores the seed behavior; callables plug in custom local objectives).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .cache import local_nll, make_local_grad


def _z_update(thetas, psis, rho):
    """z^{s+1} = (1/M) sum_i (theta_i + psi_i / rho)   (24a)/(26a)."""
    return jnp.mean(thetas + psis / rho, axis=0)


def _central_diag(thetas, z, z_prev, resid, rho, aux):
    """Per-iteration diagnostics ys for the centralized loops (diag=True):
    primal = max_i ||theta_i - z|| (the `residuals` quantity), dual =
    rho * max|z - z_prev| (the z-update step scaled by rho, the standard
    ADMM dual residual), per-agent NLL, and the theta trajectory."""
    return {
        "residuals": resid,
        "primal_residuals": resid,
        "dual_residuals": rho * jnp.max(jnp.abs(z - z_prev)),
        "nll": jax.vmap(local_nll)(thetas, aux),
        "theta_trajectory": thetas,
    }


def _central_info(zs, ys):
    """Assemble the diag=True info dict: the v0 keys stay at the top level,
    the extended per-iteration series ride info['diagnostics']."""
    return {"z_history": zs, "residuals": ys["residuals"],
            "diagnostics": dict(ys)}


@partial(jax.jit,
         static_argnames=("iters", "nested_iters", "grad_fn", "diag"))
def train_c_gp(log_theta0, Xp, yp, rho: float = 500.0, iters: int = 100,
               nested_iters: int = 10, nested_lr: float = 1e-5, grad_fn=None,
               diag: bool = False):
    """c-GP (eq. 24): exact consensus ADMM, nested GD per agent per round.

    Returns (z, thetas, history dict). The nested problem (24b) is solved with
    `nested_iters` plain GD steps (the paper uses GD with alpha=1e-5); the
    local NLL gradient inside each step comes from the grad_fn hook, the
    penalty terms are analytic.

    `diag=True` (static) additionally carries per-iteration diagnostics
    through the scan — primal/dual residuals, per-agent NLL, and the theta
    trajectory — returned under info["diagnostics"] for `TraceRecorder`.
    The diag=False program is unchanged (no diagnostics in its carry/ys).
    """
    M = Xp.shape[0]
    D2 = log_theta0.shape[0]
    thetas = jnp.broadcast_to(log_theta0, (M, D2)).astype(Xp.dtype)
    psis = jnp.zeros_like(thetas)
    prepare, lgrad = make_local_grad(grad_fn)
    aux = prepare(Xp, yp)                        # once per fit, NOT per iter

    def nested(theta_i, z, psi_i, aux_i):
        # minimize L_i(th) + psi^T (th - z) + rho/2 ||th - z||^2
        def g(th):
            return lgrad(th, aux_i) + psi_i + rho * (th - z)

        def body(th, _):
            return th - nested_lr * g(th), None
        th, _ = jax.lax.scan(body, theta_i, None, length=nested_iters)
        return th

    def body(carry, _):
        thetas, psis = carry[0], carry[1]
        z = _z_update(thetas, psis, rho)                        # (24a)
        thetas = jax.vmap(nested, in_axes=(0, None, 0, 0))(
            thetas, z, psis, aux)                               # (24b)
        psis = psis + rho * (thetas - z)                        # (24c)
        resid = jnp.max(jnp.linalg.norm(thetas - z, axis=1))
        if not diag:
            return (thetas, psis), (z, resid)
        d = _central_diag(thetas, z, carry[2], resid, rho, aux)
        return (thetas, psis, z), (z, d)

    if not diag:
        (thetas, psis), (zs, resids) = jax.lax.scan(
            body, (thetas, psis), None, length=iters)
        return zs[-1], thetas, {"z_history": zs, "residuals": resids}
    (thetas, psis, _), (zs, ys) = jax.lax.scan(
        body, (thetas, psis, thetas[0]), None, length=iters)
    return zs[-1], thetas, _central_info(zs, ys)


@partial(jax.jit, static_argnames=("iters", "grad_fn", "diag"))
def train_apx_gp(log_theta0, Xp, yp, rho: float = 500.0, L: float = 5000.0,
                 iters: int = 100, grad_fn=None, diag: bool = False):
    """apx-GP (eq. 26): proximal ADMM with analytic theta-update.

    theta_i = z - (grad L_i(z) + psi_i) / (rho + L_i)   (26b)

    `diag=True` (static) carries per-iteration primal/dual residuals,
    per-agent NLL, and the theta trajectory through the scan, returned
    under info["diagnostics"] (see train_c_gp).
    """
    M = Xp.shape[0]
    thetas = jnp.broadcast_to(log_theta0, (M, log_theta0.shape[0])).astype(Xp.dtype)
    psis = jnp.zeros_like(thetas)
    prepare, lgrad = make_local_grad(grad_fn)
    aux = prepare(Xp, yp)                        # once per fit, NOT per iter
    shared_grads = jax.vmap(lgrad, in_axes=(None, 0))

    def body(carry, _):
        thetas, psis = carry[0], carry[1]
        z = _z_update(thetas, psis, rho)                        # (26a)
        g = shared_grads(z, aux)                                # grad L_i(z)
        thetas = z[None] - (g + psis) / (rho + L)               # (26b)
        psis = psis + rho * (thetas - z[None])                  # (26c)
        resid = jnp.max(jnp.linalg.norm(thetas - z[None], axis=1))
        if not diag:
            return (thetas, psis), (z, resid)
        d = _central_diag(thetas, z, carry[2], resid, rho, aux)
        return (thetas, psis, z), (z, d)

    if not diag:
        (thetas, psis), (zs, resids) = jax.lax.scan(
            body, (thetas, psis), None, length=iters)
        return zs[-1], thetas, {"z_history": zs, "residuals": resids}
    (thetas, psis, _), (zs, ys) = jax.lax.scan(
        body, (thetas, psis, thetas[0]), None, length=iters)
    return zs[-1], thetas, _central_info(zs, ys)


def train_gapx_gp(log_theta0, Xp_aug, yp_aug, rho: float = 500.0,
                  L: float = 5000.0, iters: int = 100, grad_fn=None,
                  diag: bool = False):
    """gapx-GP (Alg. 1): apx-GP on the augmented datasets D_{+i}.

    Callers build (Xp_aug, yp_aug) with gp.partition.communication_dataset +
    augment (sample -> flood -> union), then this is exactly apx-GP.
    """
    return train_apx_gp(log_theta0, Xp_aug, yp_aug, rho=rho, L=L, iters=iters,
                        grad_fn=grad_fn, diag=diag)
