"""GP hyperparameter training (paper §4): the ADMM family + FACT-GP.

The config-driven entry point is `repro.fleet.GPFleet.fit`, which
dispatches to these loops through the `repro.fleet.TRAINERS` registry
(names: fact | c | apx | gapx | dec-c | dec-apx | dec-gapx |
dec-apx-sharded) and forwards the FleetConfig's ADMM parameters unchanged
— facade-trained thetas are bitwise the legacy thetas
(tests/test_fleet.py). The loops below remain the public reference
surface.
"""
from .factorized import local_nlls, factorized_nll, train_fact_gp
from .admm_centralized import train_c_gp, train_apx_gp, train_gapx_gp
from .admm_decentralized import (train_dec_c_gp, train_dec_apx_gp,
                                 train_dec_gapx_gp, dec_apx_update,
                                 dec_apx_gp_sharded_step,
                                 train_dec_apx_gp_sharded)
from .cache import (TrainingCache, build_training_cache, cov_from_cache,
                    nll_from_cache, nll_grad_cached, make_local_grad)

__all__ = [
    "local_nlls", "factorized_nll", "train_fact_gp",
    "train_c_gp", "train_apx_gp", "train_gapx_gp",
    "train_dec_c_gp", "train_dec_apx_gp", "train_dec_gapx_gp",
    "dec_apx_update", "dec_apx_gp_sharded_step", "train_dec_apx_gp_sharded",
    "TrainingCache", "build_training_cache", "cov_from_cache",
    "nll_from_cache", "nll_grad_cached", "make_local_grad",
]
