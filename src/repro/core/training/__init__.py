from .factorized import local_nlls, factorized_nll, train_fact_gp
from .admm_centralized import train_c_gp, train_apx_gp, train_gapx_gp
from .admm_decentralized import (train_dec_c_gp, train_dec_apx_gp,
                                 train_dec_gapx_gp, dec_apx_update,
                                 train_dec_apx_gp_sharded)

__all__ = [
    "local_nlls", "factorized_nll", "train_fact_gp",
    "train_c_gp", "train_apx_gp", "train_gapx_gp",
    "train_dec_c_gp", "train_dec_apx_gp", "train_dec_gapx_gp",
    "dec_apx_update", "train_dec_apx_gp_sharded",
]
