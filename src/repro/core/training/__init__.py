from .factorized import local_nlls, factorized_nll, train_fact_gp
from .admm_centralized import train_c_gp, train_apx_gp, train_gapx_gp
from .admm_decentralized import (train_dec_c_gp, train_dec_apx_gp,
                                 train_dec_gapx_gp, dec_apx_update,
                                 dec_apx_gp_sharded_step,
                                 train_dec_apx_gp_sharded)
from .cache import (TrainingCache, build_training_cache, cov_from_cache,
                    nll_from_cache, nll_grad_cached, make_local_grad)

__all__ = [
    "local_nlls", "factorized_nll", "train_fact_gp",
    "train_c_gp", "train_apx_gp", "train_gapx_gp",
    "train_dec_c_gp", "train_dec_apx_gp", "train_dec_gapx_gp",
    "dec_apx_update", "dec_apx_gp_sharded_step", "train_dec_apx_gp_sharded",
    "TrainingCache", "build_training_cache", "cov_from_cache",
    "nll_from_cache", "nll_grad_cached", "make_local_grad",
]
