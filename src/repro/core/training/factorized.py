"""Factorized GP training (paper §2.3.1, P2): FACT-GP and g-FACT-GP.

Under Assumption 4 the global NLL factorizes as a sum of local NLLs. The
centralized server runs gradient descent on sum_i NLL_i with every agent
contributing its local gradient each round (Xie et al. 2019 workflow).

g-FACT-GP is FACT-GP on the augmented local datasets D_{+i} (Liu et al. 2018a),
which relaxes the block-diagonal approximation.

All local quantities are vmapped over the agent axis; this is the "simulated
network" execution mode (see DESIGN.md §2). Each vmap lane is one agent.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ...optim import adam, apply_updates
from ..gp.nll import nll


def local_nlls(log_theta: jax.Array, Xp: jax.Array, yp: jax.Array) -> jax.Array:
    """NLL_i for each agent with a *shared* theta. Xp (M, Ni, D), yp (M, Ni)."""
    return jax.vmap(lambda X, y: nll(log_theta, X, y))(Xp, yp)


def factorized_nll(log_theta: jax.Array, Xp: jax.Array, yp: jax.Array) -> jax.Array:
    """sum_i NLL_i — the P2 objective."""
    return jnp.sum(local_nlls(log_theta, Xp, yp))


@partial(jax.jit, static_argnames=("steps",))
def train_fact_gp(log_theta0: jax.Array, Xp: jax.Array, yp: jax.Array,
                  steps: int = 200, lr: float = 0.05):
    """FACT-GP: centralized GD (Adam) on the factorized objective.

    Communication per round (accounted in benchmarks, Table 1): each agent
    sends its (D+2,)-gradient to the server; the server broadcasts theta.
    """
    opt = adam(lr, state_dtype=log_theta0.dtype)
    grad_fn = jax.value_and_grad(factorized_nll)

    def body(carry, _):
        lt, st = carry
        val, g = grad_fn(lt, Xp, yp)
        upd, st = opt.update(g, st, lt)
        return (apply_updates(lt, upd), st), val

    (lt, _), vals = jax.lax.scan(body, (log_theta0, opt.init(log_theta0)),
                                 None, length=steps)
    return lt, vals
