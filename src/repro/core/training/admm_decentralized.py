"""Decentralized ADMM factorized GP training (paper §4) — the paper's central
training contribution.

Edge-formulation consensus ADMM (P4) on a strongly connected graph:
  DEC-c-GP   (eq. 30): nested local optimization per round.
  DEC-apx-GP (eq. 34): closed-form local update (Theorem 1).
  DEC-gapx-GP (Alg. 4): DEC-apx-GP on augmented datasets.

Simulated mode: agents on a leading axis, neighbor sums = adjacency matmuls —
exact reference semantics for ANY strongly connected graph.
Sharded mode: shard_map over a mesh axis with ppermute ring messages —
the TPU-native deployment (cycle graph), bitwise-same update rule.

Every loop takes a `grad_fn` hook for the local NLL gradient (default: the
cached-geometry fused path of core.training.cache — per-iteration work is
elementwise exp + Cholesky + the one-pass ops.nll_grad_fused contraction;
"autodiff" restores the seed jax.grad(nll) behavior; any callable
(log_theta, Xi, yi) -> (D+2,) plugs in custom local objectives). The
update rule of eq. (34) is identical under every hook.

Theorem 1 requires kappa_i > L_i^2/m_i^2 - rho*lambda_min(D+A); the paper uses
kappa_i = 5000, rho = 500 in all experiments and so do we by default.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..consensus.graph import axis_size

from ..gp.nll import nll
from .cache import local_nll, make_local_grad


def _graph_terms(A: jax.Array, dtype):
    """(A cast for matmul, degree vector) — static across ADMM iterations,
    computed ONCE before the scan (the seed re-derived sum(A) every round
    inside the loop bodies)."""
    return A.astype(dtype), jnp.sum(A, axis=1).astype(dtype)


def _dec_diag(thetas_next, thetas_prev, Af, rho, aux):
    """Per-iteration diagnostics ys for the decentralized loops (diag=True).

    primal = worst EDGE disagreement max_{(i,j) in E} |theta_i - theta_j|
    (the consensus constraints of P4 are edge-wise theta_i = theta_j); dual
    = rho * max |theta^{s+1} - theta^s| (the iterate step scaled by rho);
    plus per-agent NLL and the theta trajectory. The (M, M, K) edge-
    difference broadcast is fine at diagnostic fleet sizes and never runs
    in the diag=False program.
    """
    diffs = jnp.abs(thetas_next[:, None, :] - thetas_next[None, :, :])
    primal = jnp.max(diffs * Af[:, :, None])
    disagreement = jnp.max(
        jnp.abs(thetas_next - jnp.mean(thetas_next, axis=0)))
    return {
        "residuals": disagreement,
        "primal_residuals": primal,
        "dual_residuals": rho * jnp.max(jnp.abs(thetas_next - thetas_prev)),
        "nll": jax.vmap(local_nll)(thetas_next, aux),
        "theta_trajectory": thetas_next,
    }


def _dec_info(ys):
    """diag=True info dict: `residuals` stays the v0 top-level key, the
    extended per-iteration series ride info['diagnostics']."""
    return {"residuals": ys["residuals"], "diagnostics": dict(ys)}


@partial(jax.jit,
         static_argnames=("iters", "nested_iters", "grad_fn", "diag"))
def train_dec_c_gp(log_theta0, Xp, yp, A, rho: float = 500.0,
                   iters: int = 100, nested_iters: int = 10,
                   nested_lr: float = 1e-5, grad_fn=None,
                   diag: bool = False):
    """DEC-c-GP (Alg. 2, eq. 30). Nested problem solved by GD with the
    gradient of Appendix A.2 (local NLL gradient through the grad_fn hook,
    quadratic/linear terms analytic).

    `diag=True` (static) carries per-iteration diagnostics through the scan
    — edge-wise primal residuals, dual residuals, per-agent NLL, theta
    trajectory — under info["diagnostics"]; the diag=False program is
    unchanged."""
    M = Xp.shape[0]
    thetas = jnp.broadcast_to(log_theta0, (M, log_theta0.shape[0])).astype(Xp.dtype)
    p = jnp.zeros_like(thetas)
    prepare, lgrad = make_local_grad(grad_fn)
    aux = prepare(Xp, yp)
    Af, deg = _graph_terms(A, thetas.dtype)

    def nested(theta_i, theta_i_prev, nbr_sum, deg_i, p_i, aux_i):
        # obj = L_i(th) + th^T p_i + rho * sum_j ||th - (th_i^s + th_j^s)/2||^2
        # d(obj)/dth = grad L_i(th) + p_i
        #              + rho * (2 deg th - deg th_i^s - nbr_sum)
        def g(th):
            return (lgrad(th, aux_i) + p_i
                    + rho * (2.0 * deg_i * th
                             - (deg_i * theta_i_prev + nbr_sum)))

        def body(th, _):
            return th - nested_lr * g(th), None
        th, _ = jax.lax.scan(body, theta_i, None, length=nested_iters)
        return th

    def body(carry, _):
        thetas, p = carry
        nbr_sum = Af @ thetas
        p = p + rho * (deg[:, None] * thetas - nbr_sum)             # (30a)
        thetas_next = jax.vmap(nested, in_axes=(0, 0, 0, 0, 0, 0))(
            thetas, thetas, nbr_sum, deg, p, aux)                   # (30b)
        if diag:
            return (thetas_next, p), _dec_diag(thetas_next, thetas, Af,
                                               rho, aux)
        disagreement = jnp.max(jnp.abs(thetas_next - jnp.mean(thetas_next, 0)))
        return (thetas_next, p), disagreement

    (thetas, p), ys = jax.lax.scan(body, (thetas, p), None, length=iters)
    return thetas, (_dec_info(ys) if diag else {"residuals": ys})


def dec_apx_update(thetas, p, grads, nbr_sum, deg, rho, kappa):
    """One DEC-apx-GP sweep (34a)-(34b), shared by all execution modes.

    thetas (M, K), p (M, K), grads = grad L_i(theta_i) (M, K),
    nbr_sum = sum_{j in N_i} theta_j (M, K), deg (M,).
    """
    degc = deg[:, None]
    p_next = p + rho * (degc * thetas - nbr_sum)                    # (34a)
    thetas_next = (rho * nbr_sum - grads
                   + (kappa + degc * rho) * thetas - p_next) \
        / (kappa + 2.0 * degc * rho)                                # (34b)
    return thetas_next, p_next


@partial(jax.jit, static_argnames=("iters", "grad_fn", "diag"))
def train_dec_apx_gp(log_theta0, Xp, yp, A, rho: float = 500.0,
                     kappa: float = 5000.0, iters: int = 100, grad_fn=None,
                     diag: bool = False):
    """DEC-apx-GP (Alg. 3 / Theorem 1): closed-form decentralized ADMM.

    The per-iteration hot path: the cached-geometry gradient (grad_fn hook)
    vmapped across the agent axis, one adjacency matmul, the closed-form
    sweep of eq. (34).

    `diag=True` (static) carries per-iteration diagnostics through the scan
    (see train_dec_c_gp) under info["diagnostics"]; diag=False programs are
    unchanged."""
    M = Xp.shape[0]
    thetas = jnp.broadcast_to(log_theta0, (M, log_theta0.shape[0])).astype(Xp.dtype)
    p = jnp.zeros_like(thetas)
    prepare, lgrad = make_local_grad(grad_fn)
    aux = prepare(Xp, yp)                       # once per fit, NOT per iter
    fleet_grads = jax.vmap(lgrad, in_axes=(0, 0))
    Af, deg = _graph_terms(A, thetas.dtype)

    def body(carry, _):
        thetas, p = carry
        nbr_sum = Af @ thetas
        grads = fleet_grads(thetas, aux)
        thetas_next, p = dec_apx_update(thetas, p, grads, nbr_sum, deg,
                                        rho, kappa)
        if diag:
            return (thetas_next, p), _dec_diag(thetas_next, thetas, Af,
                                               rho, aux)
        disagreement = jnp.max(
            jnp.abs(thetas_next - jnp.mean(thetas_next, axis=0)))
        return (thetas_next, p), disagreement

    (thetas, p), ys = jax.lax.scan(body, (thetas, p), None, length=iters)
    return thetas, (_dec_info(ys) if diag else {"residuals": ys})


def train_dec_gapx_gp(log_theta0, Xp_aug, yp_aug, A, rho: float = 500.0,
                      kappa: float = 5000.0, iters: int = 100, grad_fn=None,
                      diag: bool = False):
    """DEC-gapx-GP (Alg. 4): sample -> flood -> augment (done by caller via
    gp.partition), then DEC-apx-GP on D_{+i}."""
    return train_dec_apx_gp(log_theta0, Xp_aug, yp_aug, A,
                            rho=rho, kappa=kappa, iters=iters,
                            grad_fn=grad_fn, diag=diag)


# ---------------------------------------------------------------------------
# Sharded execution mode: one agent per mesh-axis member, ring (cycle) graph,
# neighbor exchange via ppermute. Used by tests to prove simulated == sharded
# and by launch/ to run the GP fleet on real meshes.
# ---------------------------------------------------------------------------

def dec_apx_gp_sharded_step(theta_i, p_i, Xi, yi, axis_name: str,
                            rho: float = 500.0, kappa: float = 5000.0,
                            local_grad=None):
    """One DEC-apx-GP round for THIS agent inside shard_map (cycle graph).

    `local_grad` is the per-shard resolution of the grad_fn hook: a callable
    (theta,) -> (D+2,) already closed over this agent's cached geometry
    (train_dec_apx_gp_sharded builds the TrainingCache once per fit, outside
    the iteration scan). None falls back to autodiffing nll on (Xi, yi) so
    the step stays usable standalone."""
    M = axis_size(axis_name)
    perm_fwd = [(i, (i + 1) % M) for i in range(M)]
    perm_bwd = [(i, (i - 1) % M) for i in range(M)]
    left = jax.lax.ppermute(theta_i, axis_name, perm_fwd)
    right = jax.lax.ppermute(theta_i, axis_name, perm_bwd)
    if M == 1:
        nbr_sum = jnp.zeros_like(theta_i)      # self-permute: no neighbors
    elif M == 2:
        nbr_sum = left                          # fwd == bwd: ONE shared neighbor
    else:
        nbr_sum = left + right
    deg = jnp.asarray(float(min(M - 1, 2)), theta_i.dtype)
    if local_grad is None:
        g = jax.grad(nll)(theta_i, Xi, yi)
    else:
        g = local_grad(theta_i)
    th, p = dec_apx_update(theta_i[None], p_i[None], g[None],
                           nbr_sum[None], deg[None], rho, kappa)
    return th[0], p[0]


def train_dec_apx_gp_sharded(mesh, axis_name, log_theta0, Xp, yp,
                             rho: float = 500.0, kappa: float = 5000.0,
                             iters: int = 100, grad_fn=None):
    """Full DEC-apx-GP under shard_map on `mesh` (cycle graph over axis_name).

    Xp, yp carry the agent axis which is sharded over the mesh axis. The
    grad_fn hook resolves PER SHARD: each agent builds its own TrainingCache
    inside the shard_map body, once, before the iteration scan.

    Returns (thetas, info) with the SAME info["residuals"] series as the
    simulated loops — the per-iteration max consensus disagreement
    max_i |theta_i - mean(theta)|, computed with pmean/pmax collectives
    inside the scan (replicated across devices) — plus info["p"], the final
    dual variables. Against `train_dec_apx_gp` on the matching cycle graph
    the series agrees to reduction-order roundoff
    (tests/test_training_admm.py).
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    M = Xp.shape[0]
    thetas0 = jnp.broadcast_to(log_theta0, (M, log_theta0.shape[0])).astype(Xp.dtype)
    p0 = jnp.zeros_like(thetas0)
    prepare, lgrad = make_local_grad(grad_fn)

    @partial(shard_map, mesh=mesh,
             in_specs=(P(axis_name), P(axis_name), P(axis_name), P(axis_name)),
             out_specs=(P(axis_name), P(axis_name), P()), check_rep=False)
    def run(thetas, p, Xl, yl):
        aux = jax.tree.map(lambda a: a[0], prepare(Xl, yl))

        def local_grad(th):
            return lgrad(th, aux)

        def body(carry, _):
            th, pp = carry
            th2, pp2 = dec_apx_gp_sharded_step(
                th[0], pp[0], Xl[0], yl[0], axis_name, rho=rho, kappa=kappa,
                local_grad=local_grad)
            # the simulated loops' residual, on the ring: mean over the
            # agent (mesh) axis, worst per-agent deviation via pmax — the
            # result is replicated, so it exits through a P() out_spec
            mean = jax.lax.pmean(th2, axis_name)
            disagreement = jax.lax.pmax(jnp.max(jnp.abs(th2 - mean)),
                                        axis_name)
            return (th2[None], pp2[None]), disagreement
        (th, pp), resids = jax.lax.scan(body, (thetas, p), None,
                                        length=iters)
        return th, pp, resids

    thetas, p, resids = run(thetas0, p0, Xp, yp)
    return thetas, {"residuals": resids, "p": p}
