"""Cached-geometry training hot path — the training analogue of the
factor-cached PredictionEngine (docs/training_engine.md).

Every ADMM iteration of the paper's training methods (§3-§4) needs, per
agent, the local NLL gradient at the current theta. The seed autodiffed
`nll`, re-deriving the pairwise geometry of each agent's X (norms, the
x @ x^T Gram expansion of sq_dists, the diff^2 terms) on EVERY iteration and
paying the Cholesky VJP; the analytic alternative materialized the full
(D+2, N, N) derivative stack of `cov_grads`. But the geometry is pure data —
only theta changes across iterations. This module splits the work
accordingly:

  TrainingCache    — once per fit: the per-agent per-dimension UNSCALED
                     diff^2 stacks d2u[d] = (x_d - x'_d)^2 (a jit-able
                     pytree; `build_training_cache`).
  nll_grad_cached  — per iteration: elementwise scale + exp rebuild C,
                     one Cholesky, inner = C^-1 - alpha alpha^T, then the
                     one-pass fused contraction `ops.nll_grad_fused`
                     (Pallas on TPU, blocked jnp elsewhere) for all D+2
                     gradient components.
  make_local_grad  — resolves the `grad_fn` hook shared by every ADMM
                     training loop (admm_centralized, admm_decentralized,
                     and the sharded step).

Equivalence with autodiff is exact up to roundoff (tests/test_training_fused:
1e-6 f64, 1e-4 f32) because the effective jitter is stop_gradient'd in both
paths (gp.nll.effective_jitter).
"""
from __future__ import annotations

import warnings
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ...kernels.ops import nll_grad_fused
from ..gp.kernel import diff2_stack, unpack
from ..gp.nll import (effective_jitter, inner_from_cov, nll, nll_from_cov)


class TrainingCache(NamedTuple):
    """Per-agent training-time geometry, computed once per fit.

    Leaves carry an optional leading agent axis (M, ...) in simulated mode
    and no leading axis per shard in sharded mode.
    """
    d2u: jax.Array    # (..., D, N, N) unscaled per-dimension diff^2 stacks
    y: jax.Array      # (..., N)       local targets


def build_training_cache(Xp: jax.Array, yp: jax.Array) -> TrainingCache:
    """Precompute the iteration-invariant geometry. Xp (M, N, D) or (N, D).

    Memory: O(D N^2) per agent, held ONCE across the whole ADMM run —
    amortized against the O(D N^2) work (matmuls + elementwise) that
    sq_dists/cov_grads re-spent on it every iteration.
    """
    if Xp.ndim == 3:
        return TrainingCache(jax.vmap(diff2_stack)(Xp), yp)
    return TrainingCache(diff2_stack(Xp), yp)


def cov_from_cache(log_theta, d2u, jitter: float = 1e-8):
    """(C, K) from the cached geometry: the per-iteration covariance rebuild
    reduces to one FMA contraction over d2u, one exp, and the diagonal."""
    ls, sigma_f, sigma_eps = unpack(log_theta)
    d2s = jnp.einsum("d,dij->ij", 1.0 / ls**2, d2u)
    K = sigma_f**2 * jnp.exp(-d2s)
    n = d2u.shape[-1]
    jit_eff = effective_jitter(log_theta, d2u.dtype, jitter)
    C = K + (sigma_eps**2 + jit_eff) * jnp.eye(n, dtype=K.dtype)
    return C, K


def nll_from_cache(log_theta, d2u, y, jitter: float = 1e-8):
    """NLL value from cached geometry — matches gp.nll on the same data."""
    C, _ = cov_from_cache(log_theta, d2u, jitter)
    return nll_from_cov(C, y)


def nll_grad_cached(log_theta, d2u, y, jitter: float = 1e-8,
                    use_pallas: bool | None = None,
                    interpret: bool | None = None):
    """dNLL/dlog_theta (D+2,) via the cached-geometry fused path.

    Per-iteration cost: one Cholesky + one triangular pair for the explicit
    inverse + the single fused contraction. No autodiff, no geometry
    recompute, no (D+2, N, N) stack.
    """
    C, K = cov_from_cache(log_theta, d2u, jitter)
    inner = inner_from_cov(C, y)
    return nll_grad_fused(log_theta, d2u, inner, K=K, use_pallas=use_pallas,
                          interpret=interpret)


def local_nll(log_theta, aux, jitter: float = 1e-8):
    """Per-agent NLL VALUE from whatever aux `make_local_grad`'s prepare
    built — TrainingCache (fused path) or the raw (Xi, yi) tuple (autodiff /
    custom hooks). The diagnostics (`diag=True`) mode of the ADMM loops
    vmaps this over the agent axis to carry per-iteration NLL through the
    scan without a second geometry pass."""
    if isinstance(aux, TrainingCache):
        return nll_from_cache(log_theta, aux.d2u, aux.y, jitter=jitter)
    return nll(log_theta, *aux, jitter=jitter)


def make_local_grad(grad_fn=None, jitter: float = 1e-8,
                    cache_limit_mb: float = 4096.0):
    """Resolve the `grad_fn` hook of the ADMM training loops.

    grad_fn:
      None            — cached-geometry fused path (the default hot path):
                        `prepare` builds a TrainingCache once per fit,
                        guarded by `cache_limit_mb` (the cache is
                        O(M D N^2); fleets past the limit fall back to the
                        autodiff hook with a UserWarning at trace time, so
                        existing call sites never OOM where the seed ran —
                        same policy as fit_experts' cross-Gram guard).
      "fused"         — cached-geometry path, UNGUARDED: the explicit
                        opt-in for callers who sized the cache themselves.
      "autodiff"      — the seed behavior, jax.grad(nll) on raw (X, y).
      callable        — custom per-agent gradient (log_theta, Xi, yi) ->
                        (D+2,), e.g. for regularized or preconditioned
                        local objectives.

    Returns (prepare, grad): `prepare(Xp, yp)` -> aux pytree whose leaves
    share Xp's leading agent axis; `grad(log_theta, aux_i)` -> (D+2,) local
    NLL gradient for one agent. Training loops vmap `grad` over the agent
    axis of `aux` (simulated mode) or close it over one shard's aux
    (sharded mode) — the update rule of eq. (34) is untouched either way.
    """
    if grad_fn in (None, "fused"):
        guarded = grad_fn is None

        def prepare(Xp, yp):
            if guarded:
                n, D = Xp.shape[-2], Xp.shape[-1]
                m = Xp.shape[0] if Xp.ndim == 3 else 1
                est_mb = (m * D * n * n
                          * jnp.dtype(Xp.dtype).itemsize / 2**20)
                if est_mb > cache_limit_mb:
                    warnings.warn(
                        f"cached-geometry training would hold {est_mb:.0f} "
                        f"MB of diff^2 stacks (M={m}, N={n}, D={D}) > "
                        f"{cache_limit_mb:.0f} MB; falling back to autodiff "
                        f"gradients — pass grad_fn='fused' to force the "
                        f"cache", stacklevel=2)
                    return (Xp, yp)
            return build_training_cache(Xp, yp)

        def grad(log_theta, aux):
            if isinstance(aux, TrainingCache):
                return nll_grad_cached(log_theta, aux.d2u, aux.y,
                                       jitter=jitter)
            return jax.grad(partial(nll, jitter=jitter))(log_theta, *aux)
        return prepare, grad

    # thread the SAME jitter into the autodiff baseline — the two hooks must
    # optimize the same objective for any jitter, not just the default
    g = (jax.grad(partial(nll, jitter=jitter)) if grad_fn == "autodiff"
         else grad_fn)

    def prepare(Xp, yp):
        return (Xp, yp)

    def grad(log_theta, aux):
        return g(log_theta, *aux)
    return prepare, grad
