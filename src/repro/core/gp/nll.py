"""Negative marginal log-likelihood (paper P1) and gradients (paper eq. 4)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import cov_matrix, cov_grads

LOG_2PI = jnp.log(2.0 * jnp.pi)


def effective_jitter(log_theta: jax.Array, dtype, jitter: float = 1e-8):
    """Dtype-aware factorization jitter: relative, floored at 8*eps(dtype).

    The seed added an absolute 1e-8 to the diagonal, which is a no-op
    against float32 covariances (same failure PR 1 fixed in the NPAE
    aggregation): float32 Cholesky of a near-singular C needs a guard on
    the order of eps(float32), not eps(float64). `jitter` is now RELATIVE
    to the prior diagonal sigma_f^2 + sigma_eps^2 and floored at
    8*eps(dtype) — a deliberate semantic change: callers passing explicit
    jitters now state them as fractions of the diagonal, which makes the
    guard amplitude-invariant (float64 at the paper's O(1) signal scales
    keeps the seed's 1e-8 order; float32 training is actually guarded).
    The scale is stop_gradient'd:
    the guard is a numerical device, not part of the model, so autodiff
    and the analytic/fused trace-identity gradients agree exactly.
    """
    theta = jax.lax.stop_gradient(jnp.exp(log_theta))
    scale = theta[-2] ** 2 + theta[-1] ** 2
    return jnp.maximum(jitter, 8 * jnp.finfo(dtype).eps) * scale


def nll_from_cov(C: jax.Array, y: jax.Array) -> jax.Array:
    """NLL given an already-built covariance C — the single Cholesky body
    shared by `nll` and the cached-geometry path (core.training.cache), so
    the two can never drift apart."""
    n = y.shape[0]
    L = jnp.linalg.cholesky(C)
    alpha = jax.scipy.linalg.cho_solve((L, True), y)
    logdet = 2.0 * jnp.sum(jnp.log(jnp.diagonal(L)))
    return 0.5 * (y @ alpha + logdet + n * LOG_2PI)


def inner_from_cov(C: jax.Array, y: jax.Array) -> jax.Array:
    """inner = C^-1 - alpha alpha^T, the trace-identity operand of eq. 4 —
    shared by `nll_grad_analytic` and the fused cached path."""
    n = y.shape[0]
    L = jnp.linalg.cholesky(C)
    Cinv = jax.scipy.linalg.cho_solve((L, True), jnp.eye(n, dtype=C.dtype))
    alpha = Cinv @ y
    return Cinv - jnp.outer(alpha, alpha)


def nll(log_theta: jax.Array, X: jax.Array, y: jax.Array,
        jitter: float = 1e-8) -> jax.Array:
    """0.5 * (y^T C^-1 y + log|C| + N log 2pi), via Cholesky (Rasmussen A.4).

    `jitter` is relative with an 8*eps(dtype) floor — see effective_jitter.
    """
    C = cov_matrix(X, log_theta,
                   jitter=effective_jitter(log_theta, X.dtype, jitter))
    return nll_from_cov(C, y)


nll_value_and_grad = jax.jit(jax.value_and_grad(nll))


def nll_grad_analytic(log_theta: jax.Array, X: jax.Array, y: jax.Array,
                      jitter: float = 1e-8) -> jax.Array:
    """Gradient via the paper's trace identity (eq. 4), in log-theta coords.

    dNLL/dtheta_j = 0.5 tr{ (C^-1 - C^-1 y y^T C^-1) dC/dtheta_j }
    (the paper's eq. 4 states dL/dtheta_j for the *log-likelihood*; this is the
    negated version consistent with minimizing the NLL).

    SLOW reference path: materializes the full (D+2, N, N) derivative stack.
    Training loops use the cached-geometry fused path instead
    (core.training.cache.nll_grad_cached -> ops.nll_grad_fused).
    """
    C = cov_matrix(X, log_theta,
                   jitter=effective_jitter(log_theta, X.dtype, jitter))
    inner = inner_from_cov(C, y)
    dC = cov_grads(X, log_theta)            # (D+2, N, N) wrt raw theta
    g_raw = 0.5 * jnp.einsum("ij,kji->k", inner, dC)
    return g_raw * jnp.exp(log_theta)        # chain rule to log-theta
