"""Negative marginal log-likelihood (paper P1) and gradients (paper eq. 4)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import cov_matrix, cov_grads

LOG_2PI = jnp.log(2.0 * jnp.pi)


def nll(log_theta: jax.Array, X: jax.Array, y: jax.Array,
        jitter: float = 1e-8) -> jax.Array:
    """0.5 * (y^T C^-1 y + log|C| + N log 2pi), via Cholesky (Rasmussen A.4)."""
    n = X.shape[0]
    C = cov_matrix(X, log_theta, jitter=jitter)
    L = jnp.linalg.cholesky(C)
    alpha = jax.scipy.linalg.cho_solve((L, True), y)
    logdet = 2.0 * jnp.sum(jnp.log(jnp.diagonal(L)))
    return 0.5 * (y @ alpha + logdet + n * LOG_2PI)


nll_value_and_grad = jax.jit(jax.value_and_grad(nll))


def nll_grad_analytic(log_theta: jax.Array, X: jax.Array, y: jax.Array,
                      jitter: float = 1e-8) -> jax.Array:
    """Gradient via the paper's trace identity (eq. 4), in log-theta coords.

    dNLL/dtheta_j = 0.5 tr{ (C^-1 - C^-1 y y^T C^-1) dC/dtheta_j }
    (the paper's eq. 4 states dL/dtheta_j for the *log-likelihood*; this is the
    negated version consistent with minimizing the NLL).
    """
    C = cov_matrix(X, log_theta, jitter=jitter)
    L = jnp.linalg.cholesky(C)
    n = X.shape[0]
    Cinv = jax.scipy.linalg.cho_solve((L, True), jnp.eye(n, dtype=C.dtype))
    alpha = Cinv @ y
    inner = Cinv - jnp.outer(alpha, alpha)
    dC = cov_grads(X, log_theta)            # (D+2, N, N) wrt raw theta
    g_raw = 0.5 * jnp.einsum("ij,kji->k", inner, dC)
    return g_raw * jnp.exp(log_theta)        # chain rule to log-theta
