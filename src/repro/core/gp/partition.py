"""Dataset partitioning across agents (paper §2.3, §6: disjoint stripes).

Every agent gets N_i = N/M observations from a spatial stripe (paper Fig. 10-b).
Also builds the grBCM/gapx communication dataset D_c (paper §2.3.2): each agent
samples N_i/M points without replacement, the samples are flooded, and every
agent augments D_{+i} = D_i ∪ D_c (so |D_{+i}| = 2 N_i).
"""
from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp


def stripe_partition(X: jax.Array, y: jax.Array, M: int, axis: int = 0):
    """Sort by coordinate `axis` and split into M equal stripes.

    Returns (Xp, yp) with shapes (M, N_i, D) and (M, N_i).

    DROPPED POINTS: when M does not divide N, the last `N mod M` points in
    sort order — i.e. those with the LARGEST coordinate along `axis` — are
    silently absent from every local dataset (the paper assumes N_i = N/M
    exactly, and equal sizes are what keep the agent axis stackable /
    shardable). The drop is signalled with a UserWarning so truncation
    can't pass unnoticed; pad or subsample to a multiple of M first if
    every point must be used. Stripes are contiguous in the sort
    coordinate, which is also what makes per-shard agent blocks spatially
    coherent for CBNN query routing (docs/serving_sharded.md).
    """
    order = jnp.argsort(X[:, axis])
    n = (X.shape[0] // M) * M
    dropped = X.shape[0] - n
    if dropped:
        warnings.warn(
            f"stripe_partition: dropping {dropped} trailing point(s) of "
            f"N={X.shape[0]} to make {M} equal stripes of {n // M}",
            UserWarning, stacklevel=2)
    order = order[:n]
    Xs, ys = X[order], y[order]
    return (Xs.reshape(M, n // M, X.shape[1]), ys.reshape(M, n // M))


def communication_dataset(key: jax.Array, Xp: jax.Array, yp: jax.Array):
    """Sample N_i/M points per agent (without replacement) and flood.

    Xp (M, N_i, D), yp (M, N_i) -> (Xc, yc) with N_c = M * floor(N_i/M) <= N_i.
    """
    M, Ni, D = Xp.shape
    m = max(Ni // M, 1)
    keys = jax.random.split(key, M)

    def sample(k, Xi, yi):
        idx = jax.random.choice(k, Ni, (m,), replace=False)
        return Xi[idx], yi[idx]

    Xs, ys = jax.vmap(sample)(keys, Xp, yp)
    return Xs.reshape(M * m, D), ys.reshape(M * m)


def augment(Xp: jax.Array, yp: jax.Array, Xc: jax.Array, yc: jax.Array):
    """D_{+i} = D_i ∪ D_c for every agent. Returns (M, N_i + N_c, ...)."""
    M = Xp.shape[0]
    Xc_b = jnp.broadcast_to(Xc[None], (M,) + Xc.shape)
    yc_b = jnp.broadcast_to(yc[None], (M,) + yc.shape)
    return (jnp.concatenate([Xp, Xc_b], axis=1),
            jnp.concatenate([yp, yc_b], axis=1))
