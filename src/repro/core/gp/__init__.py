from .kernel import (se_kernel, cov_matrix, cov_grads, diff2_stack, pack,
                     unpack, sq_dists)
from .nll import (nll, nll_value_and_grad, nll_grad_analytic,
                  effective_jitter, nll_from_cov, inner_from_cov)
from .exact import train_full_gp, predict_full
from .partition import stripe_partition, communication_dataset, augment

__all__ = [
    "se_kernel", "cov_matrix", "cov_grads", "diff2_stack", "pack", "unpack",
    "sq_dists",
    "nll", "nll_value_and_grad", "nll_grad_analytic", "effective_jitter",
    "nll_from_cov", "inner_from_cov",
    "train_full_gp", "predict_full",
    "stripe_partition", "communication_dataset", "augment",
]
