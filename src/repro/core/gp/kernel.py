"""Separable squared-exponential covariance (paper eq. 2) and its derivatives.

Hyperparameters follow the paper: theta = (l_1, ..., l_D, sigma_f, sigma_eps),
all strictly positive. Per Remark 1 we optimize log(theta) (unconstrained) and
exponentiate inside the kernel, which enforces positivity exactly.

Note the paper's convention: k(x,x') = sigma_f^2 exp{ -sum_d (x_d-x'_d)^2 / l_d^2 }
(no factor of 2 in the denominator).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def unpack(log_theta: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """log_theta (D+2,) -> (lengthscales (D,), sigma_f, sigma_eps)."""
    theta = jnp.exp(log_theta)
    return theta[:-2], theta[-2], theta[-1]


def pack(lengthscales, sigma_f, sigma_eps) -> jax.Array:
    return jnp.log(jnp.concatenate([
        jnp.atleast_1d(jnp.asarray(lengthscales)),
        jnp.atleast_1d(jnp.asarray(sigma_f)),
        jnp.atleast_1d(jnp.asarray(sigma_eps)),
    ]))


def sq_dists(x1: jax.Array, x2: jax.Array, lengthscales: jax.Array) -> jax.Array:
    """Scaled squared distances sum_d (x1_d - x2_d)^2 / l_d^2, shape (N, M)."""
    a = x1 / lengthscales
    b = x2 / lengthscales
    # ||a||^2 + ||b||^2 - 2 a.b  (MXU-friendly form; mirrored in the Pallas kernel)
    d2 = (
        jnp.sum(a * a, axis=-1)[:, None]
        + jnp.sum(b * b, axis=-1)[None, :]
        - 2.0 * a @ b.T
    )
    return jnp.maximum(d2, 0.0)


def se_kernel(x1: jax.Array, x2: jax.Array, log_theta: jax.Array) -> jax.Array:
    """k(x1, x2) for x1 (N,D), x2 (M,D) -> (N,M)."""
    ls, sigma_f, _ = unpack(log_theta)
    return sigma_f**2 * jnp.exp(-sq_dists(x1, x2, ls))


def cov_matrix(X: jax.Array, log_theta: jax.Array, jitter: float = 0.0) -> jax.Array:
    """C_theta = K + sigma_eps^2 I (positive definite)."""
    _, _, sigma_eps = unpack(log_theta)
    K = se_kernel(X, X, log_theta)
    n = X.shape[0]
    return K + (sigma_eps**2 + jitter) * jnp.eye(n, dtype=K.dtype)


def diff2_stack(X: jax.Array) -> jax.Array:
    """Unscaled per-dimension squared differences (x_d - x'_d)^2, (D, N, N).

    Pure geometry — independent of theta, so training loops precompute it
    once per fit (core.training.cache) instead of rebuilding it every ADMM
    iteration. Computed as exact outer differences (each dimension is rank-1,
    so the ||x||^2/x x^T matmul expansion of `sq_dists` buys nothing here and
    the direct form avoids its cancellation error).
    """
    Xt = X.T                                          # (D, N)
    return (Xt[:, :, None] - Xt[:, None, :]) ** 2


def cov_grads(X: jax.Array, log_theta: jax.Array) -> jax.Array:
    """Analytic dC/dtheta_j, stacked (D+2, N, N)  (paper Appendix A.1).

    Derivatives are w.r.t. the *raw* theta (not log theta); chain rule for
    log-params is d/dlog_theta_j = theta_j * d/dtheta_j.

    This is the SLOW reference path — it materializes the full (D+2, N, N)
    derivative stack. The training hot path (ops.nll_grad_fused) contracts
    the same trace identity tile-by-tile without ever building it.
    """
    ls, sigma_f, sigma_eps = unpack(log_theta)
    K = se_kernel(X, X, log_theta)
    n = X.shape[0]
    g_ls = jnp.einsum("d,ij,dij->dij", 2.0 / ls**3, K, diff2_stack(X))
    return jnp.concatenate([
        g_ls,
        (2.0 * K / sigma_f)[None],
        (2.0 * sigma_eps * jnp.eye(n, dtype=K.dtype))[None],
    ])
