"""FULL-GP: exact training (P1) with multi-start Adam on log-theta, and exact
prediction (paper eq. 5-6)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ...optim import adam, apply_updates
from .kernel import cov_matrix, se_kernel, unpack
from .nll import nll


@partial(jax.jit, static_argnames=("steps", "lr"))
def _fit_one(log_theta0, X, y, steps: int = 200, lr: float = 0.05):
    opt = adam(lr, state_dtype=log_theta0.dtype)
    grad_fn = jax.value_and_grad(nll)

    def body(carry, _):
        lt, st = carry
        val, g = grad_fn(lt, X, y)
        upd, st = opt.update(g, st, lt)
        return (apply_updates(lt, upd), st), val

    (lt, _), vals = jax.lax.scan(body, (log_theta0, opt.init(log_theta0)),
                                 None, length=steps)
    return lt, nll(lt, X, y), vals


def train_full_gp(X, y, key, num_starts: int = 3, steps: int = 200,
                  lr: float = 0.05, log_theta0=None):
    """Multi-start MLE (paper Remark 6 / Chen & Wang 2018). Returns best log-theta."""
    D = X.shape[1]
    if log_theta0 is None:
        log_theta0 = jnp.zeros(D + 2, X.dtype)
    starts = [log_theta0] + [
        log_theta0 + 0.5 * jax.random.normal(k, (D + 2,), X.dtype)
        for k in jax.random.split(key, num_starts - 1)
    ]
    results = [_fit_one(s, X, y, steps=steps, lr=lr) for s in starts]
    best = min(range(len(results)), key=lambda i: float(results[i][1]))
    lt, val, history = results[best]
    return lt, {"nll": val, "history": history}


@jax.jit
def predict_full(log_theta, X, y, Xs, jitter: float = 1e-8):
    """Exact GP posterior mean/var at test inputs Xs (paper eq. 5-6)."""
    C = cov_matrix(X, log_theta, jitter=jitter)
    L = jnp.linalg.cholesky(C)
    ks = se_kernel(X, Xs, log_theta)              # (N, Nt)
    alpha = jax.scipy.linalg.cho_solve((L, True), y)
    mean = ks.T @ alpha
    v = jax.scipy.linalg.solve_triangular(L, ks, lower=True)
    _, sigma_f, _ = unpack(log_theta)
    kss = sigma_f**2
    var = kss - jnp.sum(v * v, axis=0)
    return mean, jnp.maximum(var, 1e-12)
