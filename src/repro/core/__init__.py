"""Core library: the paper's contribution — decentralized GP training (ADMM)
and decentralized GP prediction (consensus aggregation) — plus the
loss-agnostic federated consensus layer that carries the technique to
arbitrary models (see federated.py)."""
