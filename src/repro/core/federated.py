"""Loss-agnostic decentralized consensus strategies (DESIGN.md §3).

The closed-form proximal update of the paper's Theorem 1 (DEC-apx-GP, eq. 34)
only needs the local gradient at the current iterate, so it applies verbatim
to ANY differentiable local loss — including the LM losses of the assigned
architectures. Each member of the `data` (and `pod`) mesh axes is an "agent"
holding a private data shard.

Strategies (selected per-run via TrainConfig.consensus):
  allreduce : centralized baseline — psum gradients (FACT-GP server analogue).
  dec_admm  : DEC-apx-GP generalized to parameter pytrees. Agents keep a
              local parameter opinion theta_i and dual p_i; one round is
                p_i   += rho * sum_{j in N_i} (theta_i - theta_j)
                theta_i = (rho*sum_j theta_j - g_i + (kappa+|N|rho)theta_i
                           - p_i) / (kappa + 2|N|rho)
              with ring neighbors via 2x ppermute — no gradient or data ever
              crosses the network (paper Assumption 2).
  dac       : one gossip sweep of discrete-time average consensus (eq. 35)
              applied to gradients — a cheaper, inexact averaging baseline.

These functions are called INSIDE pjit/shard_map context on arrays that carry
a leading device-local view; collectives run over `axis_names`.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import jax
import jax.numpy as jnp

from .consensus.graph import axis_size


@dataclass(frozen=True)
class ConsensusConfig:
    strategy: str = "allreduce"        # allreduce | dec_admm | dac
    rho: float = 1.0                   # ADMM penalty
    kappa: float = 10.0                # proximal penalty (Theorem 1 condition)
    dac_eps: float = 1.0 / 3.0         # Perron parameter (cycle graph, Delta=2)
    dac_sweeps: int = 1


def _ring_perms(M: int):
    fwd = [(i, (i + 1) % M) for i in range(M)]
    bwd = [(i, (i - 1) % M) for i in range(M)]
    return fwd, bwd


def _neighbor_sum(tree, axis_name: str):
    """sum of ring-neighbor values of every leaf; cycle graph degree
    min(M-1, 2). On a 2-ring fwd == bwd deliver the SAME single neighbor,
    so summing both directions would double-count it."""
    M = axis_size(axis_name)
    fwd, bwd = _ring_perms(M)

    def one(x):
        left = jax.lax.ppermute(x, axis_name, fwd)
        if M == 1:
            return jnp.zeros_like(x)
        if M == 2:
            return left
        return left + jax.lax.ppermute(x, axis_name, bwd)

    return jax.tree.map(one, tree), float(min(M - 1, 2))


def allreduce_grads(grads, axis_names: Sequence[str]):
    """Baseline: mean gradients over the agent axes."""
    for ax in axis_names:
        grads = jax.tree.map(lambda g: jax.lax.pmean(g, ax), grads)
    return grads


def dac_grads(grads, axis_names: Sequence[str], cfg: ConsensusConfig):
    """Gossip-average gradients: `dac_sweeps` Perron steps on the ring."""
    for ax in axis_names:
        for _ in range(cfg.dac_sweeps):
            nbr, deg = _neighbor_sum(grads, ax)
            grads = jax.tree.map(
                lambda g, s: g + cfg.dac_eps * (s - deg * g), grads, nbr)
    return grads


def dec_admm_init(params):
    """Dual state p_i (same pytree as params), zero-initialized."""
    return jax.tree.map(jnp.zeros_like, params)


def dec_admm_update(params, duals, grads, axis_name: str,
                    cfg: ConsensusConfig):
    """One generalized DEC-apx-GP round (eq. 34a-b) on parameter pytrees.

    Returns (new_params, new_duals). `grads` are the LOCAL gradients
    grad L_i(theta_i) — never communicated.
    """
    nbr, deg = _neighbor_sum(params, axis_name)
    rho, kappa = cfg.rho, cfg.kappa

    def upd(th, p, g, s):
        p_next = p + rho * (deg * th - s)                          # (34a)
        th_next = (rho * s - g + (kappa + deg * rho) * th - p_next) \
            / (kappa + 2.0 * deg * rho)                            # (34b)
        return th_next.astype(th.dtype), p_next.astype(p.dtype)

    out = jax.tree.map(upd, params, duals, grads, nbr)
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_duals = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
    return new_params, new_duals


def consensus_disagreement(params, axis_name: str):
    """Max |theta_i - mean_j theta_j| across agents — convergence metric."""
    def one(x):
        mean = jax.lax.pmean(x, axis_name)
        return jnp.max(jnp.abs(x - mean))
    return jax.tree.reduce(jnp.maximum, jax.tree.map(one, params))
