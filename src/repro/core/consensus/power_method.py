"""Power method / inverse power method (paper eq. 37, Alg. 11) to recover the
optimal JOR relaxation factor omega* (Lemma 3) over a network.

PM estimates lambda_max(R); the spectral shift B = R - lambda_max I is fed back
through PM to get lambda_max(B), whence lambda_min(R) = |lambda_max(B) -
lambda_max(R)| for symmetric R with real spectrum.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


@partial(jax.jit, static_argnames=("iters",))
def power_method(R: jax.Array, iters: int = 200):
    """Returns (lambda_max_estimate, residual_trajectory)."""
    M = R.shape[0]
    e0 = jnp.full((M,), 1.0 / M, R.dtype)

    def body(e, _):
        g = R @ e
        ginf = jnp.max(jnp.abs(g))
        # a zero iterate (R has an empty/zero spectrum side, e.g. the
        # shifted B of a 1x1 or identity R) must report lambda = 0, not
        # propagate 0/0 = NaN through the omega* formula
        e_next = g / jnp.where(ginf > 0.0, ginf, 1.0)
        return e_next, ginf

    e, ginfs = jax.lax.scan(body, e0, None, length=iters)
    return ginfs[-1], ginfs


@partial(jax.jit, static_argnames=("iters",))
def extreme_eigs(R: jax.Array, iters: int = 200):
    """(lambda_max, lambda_min) of symmetric R via PM + spectral shift (Alg. 12)."""
    lam_max, _ = power_method(R, iters)
    B = R - lam_max * jnp.eye(R.shape[0], dtype=R.dtype)
    lam_b, _ = power_method(B, iters)
    lam_min = jnp.abs(lam_b - lam_max)
    return lam_max, lam_min


def optimal_omega(H: jax.Array, iters: int = 200):
    """omega* = 2 / (lmax(R) + lmin(R)), R = diag(H)^-1 H (Lemma 3)."""
    R = H / jnp.diagonal(H)[:, None]
    lam_max, lam_min = extreme_eigs(R, iters)
    return 2.0 / (lam_max + lam_min)
