from .graph import (path_graph, cycle_graph, complete_graph,
                    random_connected_graph, degree_matrix, laplacian,
                    max_degree, perron, diameter, is_connected,
                    connected_components, attach_agent, remove_agent)
from .dac import (dac, dac_until, dac_residual, dac_sharded,
                  dac_sharded_residual, dac_time_varying, ring_allreduce,
                  ring_allgather, ring_allsum, ring_allmax)
from .degraded import (ConsensusDiverged, dac_masked, dac_masked_sums,
                       ring_allsum_masked)
from .jor import jor, jor_sharded
from .power_method import power_method, extreme_eigs, optimal_omega
from .dale import dale, dale_sharded
from .flooding import flood, flood_sharded

__all__ = [
    "path_graph", "cycle_graph", "complete_graph", "random_connected_graph",
    "degree_matrix", "laplacian", "max_degree", "perron", "diameter",
    "is_connected", "connected_components", "attach_agent", "remove_agent",
    "dac", "dac_until", "dac_residual", "dac_sharded",
    "dac_sharded_residual", "dac_time_varying",
    "ring_allreduce", "ring_allgather", "ring_allsum", "ring_allmax",
    "ConsensusDiverged", "dac_masked", "dac_masked_sums",
    "ring_allsum_masked",
    "jor", "jor_sharded", "power_method", "extreme_eigs", "optimal_omega",
    "dale", "dale_sharded", "flood", "flood_sharded",
]
