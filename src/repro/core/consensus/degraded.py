"""Degraded-mode consensus: masked reductions over the live subgraph.

The paper's protocols assume every agent answers every round (eq. 35
iterates a FIXED Perron matrix). Under churn that assumption breaks in
two ways: a dead agent's stale state keeps getting averaged in, and a
partitioned graph silently converges per-component. This module makes
both failure modes explicit instead of silently wrong:

  dac_masked        DAC over a per-round live-agent mask (and optional
                    per-round edge-survival masks): each round rebuilds
                    the Perron update from the LIVE subgraph's degrees —
                    the edge-weight renormalization that keeps eq. 35's
                    stability condition (eps < 1/Delta_t) holding on any
                    subgraph — and dead agents freeze their state (they
                    neither send nor receive). Exchanges stay symmetric,
                    so component totals are conserved round to round.
  dac_masked_sums   the degraded counterpart of the engines' `_dac_sums`
                    readout: network sums estimated from the READOUT
                    component only. With dead-from-round-0 agents the
                    estimate equals exact masked aggregation; with
                    mid-run dropout it is an honest estimate over the
                    survivors (flagged degraded by the caller, guarded
                    by the maximin residual).
  ring_allsum_masked the exact-ring counterpart for the sharded engine's
                    collectives: dead members contribute zero instead of
                    stale values.

Convergence failures (partition the union graph never heals, residual
above tolerance, non-finite moments) surface as `ConsensusDiverged` from
the serving layer — never as silent NaN/stale results. Partition
DETECTION is host-side (`graph.connected_components` on the final live
subgraph); this module only provides the masked numerics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .dac import _maximin_residual
from .graph import max_degree


class ConsensusDiverged(RuntimeError):
    """A consensus run failed to converge (residual above tolerance) or
    produced non-finite moments; raised instead of returning them."""


def _masked_maximin(w: jax.Array, alive: jax.Array) -> jax.Array:
    """Maximin spread over the LIVE rows only: dead agents hold frozen
    state that never re-converges and must not dominate the criterion."""
    a = alive.astype(bool)[:, None] if w.ndim == 2 else alive.astype(bool)
    hi = jnp.max(jnp.where(a, w, -jnp.inf), axis=0)
    lo = jnp.min(jnp.where(a, w, jnp.inf), axis=0)
    return jnp.max(hi - lo)


def dac_masked(w0: jax.Array, A: jax.Array, alive_seq: jax.Array,
               eps: float | None = None, edge_seq: jax.Array | None = None):
    """DAC sweeps over a time-varying live subgraph.

    w0 (M,) or (M, K); A (M, M) the full-fleet adjacency; alive_seq
    (iters, M) per-round live masks (0/1); edge_seq (iters, M, M)
    optional per-round edge-survival masks (message loss). Returns
    (w_final, masked maximin residual trajectory (iters,)).

    Per round t the effective adjacency is A_t = A * alive_t outer
    alive_t (* edge_t) and the update is w + eps * (A_t @ w - d_t * w)
    with d_t the LIVE-subgraph degrees — eq. 35 renormalized to the
    round's topology. eps defaults to 1/(Delta_full + 1), valid on every
    subgraph since Delta_t <= Delta_full. Dead agents are frozen via a
    where(), so a rejoining agent resumes relaying from the value it
    held at dropout (it missed the intermediate rounds — exactly the
    stale-rejoin semantics the residual guard exists to catch).
    """
    if eps is None:
        eps = 1.0 / (max_degree(A) + 1.0)
    A = A.astype(w0.dtype)
    alive_seq = alive_seq.astype(w0.dtype)
    xs = (alive_seq,) if edge_seq is None \
        else (alive_seq, edge_seq.astype(w0.dtype))

    def body(w, x):
        alive_t = x[0]
        A_t = A * alive_t[:, None] * alive_t[None, :]
        if edge_seq is not None:
            A_t = A_t * x[1]
        d_t = jnp.sum(A_t, axis=1)
        w_next = w + eps * (A_t @ w - d_t[:, None] * w) if w.ndim == 2 \
            else w + eps * (A_t @ w - d_t * w)
        keep = alive_t[:, None] > 0 if w.ndim == 2 else alive_t > 0
        w_next = jnp.where(keep, w_next, w)
        return w_next, _masked_maximin(w_next, alive_t)

    return jax.lax.scan(body, w0, xs)


def dac_masked_sums(w0: jax.Array, A: jax.Array, alive_seq: jax.Array,
                    readout: jax.Array, n_relay: jax.Array,
                    edge_seq: jax.Array | None = None,
                    eps: float | None = None):
    """Degraded network-sums readout (the engines' `_dac_sums` under a
    fault plan).

    w0 (M, K) payload rows; readout (M,) 0/1 marks the surviving
    component members the answer is read from; n_relay the count of
    agents whose payload ever entered that component's relay (the
    conservation denominator — with dead-from-round-0 agents this is
    exactly the live member count and the estimate is exact masked
    aggregation). Returns (sums (K,), final masked residual).

    Identity at the no-fault limit: all-alive, readout all-ones,
    n_relay = M reduces to M * mean(w) — but NOT bitwise (the per-round
    masked update multiplies where the exact path matmuls a fixed
    Perron), which is why callers dispatch empty plans to `_dac_sums`.
    """
    w, res = dac_masked(w0, A, alive_seq, eps=eps, edge_seq=edge_seq)
    r = readout.astype(w0.dtype)
    comp_mean = jnp.sum(w * r[:, None], axis=0) / jnp.maximum(jnp.sum(r), 1.0)
    # the trajectory's last entry is remeasured over the READOUT members
    # only: other components legitimately settle at different values and
    # must not trip the caller's convergence guard
    res = res.at[-1].set(_masked_maximin(w, readout))
    return n_relay.astype(w0.dtype) * comp_mean, res


def ring_allsum_masked(w_local: jax.Array, axis_name: str,
                       alive: jax.Array):
    """Exact ring sum where dead members contribute zero.

    `alive` is THIS member's 0/1 liveness scalar (replicated layout:
    each shard passes its own flag). Dead members still forward ring
    messages — the ring stays intact — but their own payload is zeroed
    before entering the lap, the protocol-level hook the sharded
    engine's degraded mode builds on. Returns the sum of live
    contributions on every member.
    """
    from .dac import ring_allsum
    return ring_allsum(w_local * alive.astype(w_local.dtype), axis_name)
