"""Discrete-time average consensus (paper eq. 35, Olfati-Saber 2007).

w_i^{s+1} = w_i^s + eps * sum_{j in N_i} a_ij (w_j^s - w_i^s)

Simulated mode: one matmul with the Perron matrix per iteration; supports any
(possibly time-varying) adjacency. Lemma 1 requires eps in (0, 1/Delta).

Inside jit we run a fixed iteration count (DESIGN.md §9 item 4); the maximin
stopping criterion (Yadav & Salapaka 2007) is provided as a Python-level
wrapper `dac_until` for adaptive runs, and `dac_residual` reports the
max-min spread so callers can verify convergence post-hoc.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .graph import axis_size, max_degree, perron


def _maximin_residual(w: jax.Array) -> jax.Array:
    """Per-consensus maximin spread (Yadav & Salapaka), worst column.

    Each column of a (M, K) stack is an INDEPENDENT consensus; different
    columns settle at different values, so the spread across the whole array
    never vanishes. The stopping criterion is the max over per-column
    spreads, which does go to zero at consensus.
    """
    return jnp.max(jnp.max(w, axis=0) - jnp.min(w, axis=0))


@partial(jax.jit, static_argnames=("iters",))
def dac(w0: jax.Array, A: jax.Array, iters: int, eps: float | None = None):
    """Run `iters` DAC sweeps. w0 (M,) or (M, K) — K parallel consensuses.

    Returns (w_final, trajectory_residuals (iters,)).
    """
    if eps is None:
        eps = 1.0 / (max_degree(A) + 1.0)
    P = perron(A, eps).astype(w0.dtype)

    def body(w, _):
        w_next = P @ w
        return w_next, _maximin_residual(w_next)

    return jax.lax.scan(body, w0, None, length=iters)


def dac_residual(w: jax.Array) -> jax.Array:
    """Maximin spread: network has reached consensus when this is ~0."""
    return _maximin_residual(w)


def dac_until(w0, A, tol: float = 1e-9, max_iters: int = 100_000,
              eps: float | None = None, chunk: int = 64):
    """Adaptive DAC: run in jit-ed chunks until the maximin criterion fires.

    Returns (w, total_iters). This mirrors the distributed stopping rule the
    paper cites: every agent tracks running max and min; when they coincide the
    network has converged.
    """
    w, iters = w0, 0
    while iters < max_iters:
        w, res = dac(w, A, chunk, eps=eps)
        iters += chunk
        if float(res[-1]) < tol:
            break
    return w, iters


def dac_time_varying(w0: jax.Array, A_seq: jax.Array, eps: float):
    """DAC over a TIME-VARYING graph (paper Assumption 1): A_seq (T, M, M)
    gives the adjacency at each iteration; convergence requires the union
    over every gamma-window to be strongly connected.

    Returns (w_final, residual trajectory)."""
    def body(w, A_t):
        M = A_t.shape[0]
        P_t = jnp.eye(M, dtype=w.dtype) - eps * (
            jnp.diag(jnp.sum(A_t, axis=1)) - A_t).astype(w.dtype)
        w_next = P_t @ w
        return w_next, _maximin_residual(w_next)

    return jax.lax.scan(body, w0, A_seq)


def dac_sharded(w_local: jax.Array, axis_name: str, iters: int,
                eps: float | None = None, with_residuals: bool = False):
    """DAC on a cycle graph over a mesh axis via ppermute (sharded mode).

    Call inside shard_map; w_local is this agent's scalar/vector. Every agent
    exchanges with its ring neighbors only — this is the paper's neighbor-wise
    message pattern mapped onto the TPU ICI ring.

    `with_residuals=True` additionally returns the per-round maximin spread
    trajectory (iters,) — the sharded counterpart of `dac`'s residual ys,
    replicated across devices (pmax/pmin) like `dac_sharded_residual`. The
    diagnostic costs two extra collectives per round, so it is opt-in
    (the engines' diagnostics mode; serving paths leave it off).
    """
    M = axis_size(axis_name)
    if eps is None:
        eps = 1.0 / 3.0  # cycle graph: Delta = 2, eps < 1/Delta
    perm_fwd = [(i, (i + 1) % M) for i in range(M)]
    perm_bwd = [(i, (i - 1) % M) for i in range(M)]

    def body(w, _):
        left = jax.lax.ppermute(w, axis_name, perm_fwd)
        right = jax.lax.ppermute(w, axis_name, perm_bwd)
        nbr = (left - w) + (right - w)
        if M == 2:
            # On a 2-ring the forward and backward permutations deliver the
            # SAME single neighbor; counting it twice doubles the consensus
            # gain vs the simulated single-edge graph. Halve to match.
            nbr = 0.5 * nbr
        w_next = w + eps * nbr
        res = dac_sharded_residual(w_next, axis_name) if with_residuals \
            else None
        return w_next, res

    w, resids = jax.lax.scan(body, w_local, None, length=iters)
    return (w, resids) if with_residuals else w


def dac_sharded_residual(w_local: jax.Array, axis_name: str) -> jax.Array:
    """Maximin consensus spread ACROSS the mesh axis (sharded counterpart of
    `dac_residual`): max over devices minus min over devices, worst entry.

    The result is computed with pmax/pmin so it is replicated on every
    device — safe to emit through an unsharded shard_map out_spec.
    """
    hi = jax.lax.pmax(w_local, axis_name)
    lo = jax.lax.pmin(w_local, axis_name)
    return jnp.max(hi - lo)


def ring_allreduce(w_local: jax.Array, axis_name: str, op=jnp.add):
    """EXACT all-reduce over a mesh axis using only neighbor ring messages.

    Each of the `n - 1` steps forwards the travelling message one hop with
    ppermute and folds it into the local accumulator, so after a full lap
    every device holds op(w_0, ..., w_{n-1}) — the same neighbor-only
    message pattern as `dac_sharded`, but a finite exact protocol instead of
    an asymptotic averaging iteration. Used by the sharded serving engine
    for the reductions that must match the replicated engine bit-for-bit-ish
    (CBNN M_eff counts, global score maxima) and as its
    `consensus="exact"` mode.

    Note devices fold contributions in ring-arrival order, so different
    devices may differ in the last ulp for non-associative ops; follow with
    `jax.lax.pmean` if exact replication is required.
    """
    n = axis_size(axis_name)
    perm = [(i, (i + 1) % n) for i in range(n)]
    acc, msg = w_local, w_local
    for _ in range(n - 1):
        msg = jax.lax.ppermute(msg, axis_name, perm)
        acc = op(acc, msg)
    return acc


def ring_allgather(w_local: jax.Array, axis_name: str) -> jax.Array:
    """EXACT all-gather over a mesh axis using only neighbor ring messages.

    Returns (n,) + w_local.shape with out[i] = device i's w_local on EVERY
    device: each of the `n - 1` hops forwards the travelling message and
    index-places it at its origin slot. Placement (no reduction) means the
    result is bit-identical on every device — no pmean needed before an
    unsharded out_spec. This is the collective behind the sharded
    npae_sparse path: agents exchange their (m, q) low-rank NPAE factors
    (core.sparse.lowrank) instead of O(Ni)-sized data, and every shard
    assembles the SAME full cross-covariance.
    """
    n = axis_size(axis_name)
    perm = [(i, (i + 1) % n) for i in range(n)]
    idx = jax.lax.axis_index(axis_name)
    out = jnp.zeros((n,) + w_local.shape, w_local.dtype).at[idx].set(w_local)
    msg = w_local
    for hop in range(1, n):
        msg = jax.lax.ppermute(msg, axis_name, perm)
        out = out.at[(idx - hop) % n].set(msg)
    return out


def ring_allsum(w_local: jax.Array, axis_name: str) -> jax.Array:
    """`ring_allreduce` with addition (exact network sums on the ring)."""
    return ring_allreduce(w_local, axis_name, jnp.add)


def ring_allmax(w_local: jax.Array, axis_name: str) -> jax.Array:
    """`ring_allreduce` with elementwise max — the ring-message realization
    of max-flooding (every agent learns the global max in n-1 hops)."""
    return ring_allreduce(w_local, axis_name, jnp.maximum)
