"""Distributed algorithm for linear equations (DALE, paper eq. 38; Wang/Mou/Liu).

q_i^{s+1} = H_i^T (H_i H_i^T)^-1 b_i + (1/|N_i|) P_i sum_{j in N_i} q_j^s
P_i = I - H_i^T (H_i H_i^T)^-1 H_i   (projection onto ker H_i)

Unlike JOR, each agent maintains the FULL solution vector q_i in R^M and
exchanges only with neighbors — strongly connected suffices (Assumption 1),
which is what lets DEC-NN-NPAE drop the strongly-complete requirement.
Requires H full row rank (Assumption 10) — guaranteed post-CBNN (Lemma 6).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .graph import axis_size


@partial(jax.jit, static_argnames=("iters",))
def dale(H: jax.Array, b: jax.Array, A: jax.Array, iters: int):
    """Simulated-network DALE. H (M,M), b (M,), adjacency A (M,M).

    Returns (Q (M, M) — every agent's copy of the solution, residuals).
    """
    M = H.shape[0]
    hnorm = jnp.sum(H * H, axis=1)                      # (M,) = H_i H_i^T
    x_part = (H / hnorm[:, None]) * b[:, None]          # (M, M): H_i^T(HiHi^T)^-1 b_i
    # P_i = I - h_i h_i^T / ||h_i||^2, applied per agent
    deg = jnp.sum(A, axis=1)
    Q0 = x_part

    def proj(i_row, v):
        return v - i_row * (i_row @ v) / jnp.sum(i_row * i_row)

    def body(Q, _):
        nbr_sum = A @ Q                                  # (M, M)
        # a degree-0 agent (single-agent graph, severed node) has an all-
        # zero neighbor sum; dividing by max(deg, 1) keeps it at its local
        # solution x_part instead of 0/0 = NaN, and is exact for deg >= 1
        avg = nbr_sum / jnp.maximum(deg, 1.0)[:, None]
        proj_avg = jax.vmap(proj)(H, avg)
        Q_next = x_part + proj_avg
        return Q_next, jnp.max(jnp.abs(Q_next - Q))

    return jax.lax.scan(body, Q0, None, length=iters)


def dale_sharded(h_row: jax.Array, b_i: jax.Array, iters: int, axis_name: str):
    """Sharded DALE on a cycle graph: each member holds (row_i H, b_i), keeps a
    full-length q_i, and exchanges q with ring neighbors via ppermute."""
    M = axis_size(axis_name)
    hnorm = h_row @ h_row
    x_part = h_row * b_i / hnorm
    perm_fwd = [(i, (i + 1) % M) for i in range(M)]
    perm_bwd = [(i, (i - 1) % M) for i in range(M)]

    def body(q, _):
        left = jax.lax.ppermute(q, axis_name, perm_fwd)
        right = jax.lax.ppermute(q, axis_name, perm_bwd)
        avg = (left + right) / 2.0
        proj_avg = avg - h_row * (h_row @ avg) / hnorm
        return x_part + proj_avg, None

    q, _ = jax.lax.scan(body, x_part, None, length=iters)
    return q
