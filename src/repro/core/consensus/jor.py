"""Jacobi over-relaxation (paper eq. 36) for H q = b on strongly complete graphs.

q_i^{s+1} = (1-w) q_i^s + (w / h_ii) (b_i - sum_{j != i} h_ij q_j^s)

Each agent owns row_i{H} and b_i and updates its own entry q_i; every iteration
requires the full vector q (strongly complete topology / flooding, Remark 8).
Lemma 2: converges for symmetric PD H if omega < 2/M; Lemma 3: optimal
omega* = 2 / (lambda_max(R) + lambda_min(R)), R = diag(H)^-1 H.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


@partial(jax.jit, static_argnames=("iters",))
def jor(H: jax.Array, b: jax.Array, omega, iters: int, q0=None, mask=None):
    """Simulated-network JOR. b (M,) or (M, K). Returns (q, residuals).

    `mask` (M,) 0/1 decouples dead agents from the system: their rows and
    columns are zeroed, the diagonal replaced by 1 and their b entries by
    0, so the live block solves exactly the masked system and dead
    entries settle at 0 (the degraded-mode hook; mask=None leaves the
    system — and the compiled trace — untouched).
    """
    if mask is not None:
        mk = mask.astype(H.dtype)
        H = H * (mk[:, None] * mk[None, :]) \
            + jnp.diag(1.0 - mk).astype(H.dtype)
        b = b * (mk[:, None] if b.ndim == 2 else mk)
    d = jnp.diagonal(H)
    R_off = H - jnp.diag(d)
    if q0 is None:
        q0 = b / (d[:, None] if b.ndim == 2 else d)

    def body(q, _):
        q_next = (1 - omega) * q + (omega / (d[:, None] if b.ndim == 2 else d)) \
            * (b - R_off @ q)
        return q_next, jnp.max(jnp.abs(q_next - q))

    return jax.lax.scan(body, q0, None, length=iters)


def jor_sharded(h_row: jax.Array, b_i: jax.Array, omega, iters: int,
                axis_name: str):
    """Sharded JOR: each mesh member holds row_i{H}, b_i; all_gather = flooding.

    The all_gather is exactly the strongly-complete communication the paper
    flags as JOR's cost (Remark 8).
    """
    idx = jax.lax.axis_index(axis_name)
    h_ii = h_row[idx]
    q_i = b_i / h_ii

    def body(q_loc, _):
        q_all = jax.lax.all_gather(q_loc, axis_name)          # flooding
        off = h_row @ q_all - h_ii * q_all[idx]
        q_next = (1 - omega) * q_loc + (omega / h_ii) * (b_i - off)
        return q_next, None

    q_i, _ = jax.lax.scan(body, q_i, None, length=iters)
    return q_i
