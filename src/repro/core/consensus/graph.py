"""Algebraic graph theory foundations (paper §2.1).

Graphs are represented by dense adjacency matrices A (M, M) — the fleet sizes
of interest (M <= a few hundred) make dense algebra the right choice, and it
keeps every consensus protocol a jit-able matmul. The sharded execution mode
(shard_map + ppermute) only supports path/cycle topologies, which are the ones
that map onto the TPU ICI torus.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def path_graph(M: int) -> jnp.ndarray:
    A = np.zeros((M, M))
    for i in range(M - 1):
        A[i, i + 1] = A[i + 1, i] = 1.0
    return jnp.asarray(A)


def cycle_graph(M: int) -> jnp.ndarray:
    A = np.asarray(path_graph(M)).copy()
    if M > 2:
        A[0, M - 1] = A[M - 1, 0] = 1.0
    return jnp.asarray(A)


def complete_graph(M: int) -> jnp.ndarray:
    return jnp.asarray(np.ones((M, M)) - np.eye(M))


def random_connected_graph(M: int, p: float, seed: int = 0) -> jnp.ndarray:
    """Erdos-Renyi edges overlaid on a path (guarantees strong connectivity)."""
    rng = np.random.default_rng(seed)
    A = np.asarray(path_graph(M)).copy()
    extra = rng.random((M, M)) < p
    extra = np.triu(extra, 1)
    A = np.maximum(A, extra + extra.T)
    return jnp.asarray(A)


def attach_agent(A: jax.Array, neighbors) -> jnp.ndarray:
    """Grow A by one node wired (bidirectionally) to `neighbors`.

    The joiner must attach to at least one existing agent or the fleet
    would split into components and consensus would silently average
    per-component.
    """
    An = np.asarray(A)
    M = An.shape[0]
    neighbors = [int(n) for n in np.atleast_1d(np.asarray(neighbors))]
    if M and not neighbors:
        raise ValueError("joining agent needs at least one neighbor")
    if any(not 0 <= n < M for n in neighbors):
        raise ValueError(f"neighbors {neighbors} out of range for M={M}")
    A2 = np.zeros((M + 1, M + 1), An.dtype)
    A2[:M, :M] = An
    for n in neighbors:
        A2[M, n] = A2[n, M] = 1.0
    return jnp.asarray(A2)


def remove_agent(A: jax.Array, i: int, reconnect: bool = True) -> jnp.ndarray:
    """Delete node i from A. With `reconnect`, the removed node's former
    neighbors are chained in index order, so removing a cut vertex (e.g.
    an interior path node) cannot disconnect the graph."""
    An = np.asarray(A)
    i = int(i)
    nbrs = np.flatnonzero(An[i] > 0)
    A2 = np.delete(np.delete(An, i, axis=0), i, axis=1)
    if reconnect and len(nbrs) > 1:
        shifted = [int(n) - (n > i) for n in nbrs]
        for a, b in zip(shifted[:-1], shifted[1:]):
            A2[a, b] = A2[b, a] = 1.0
    return jnp.asarray(A2)


def degree_matrix(A: jax.Array) -> jax.Array:
    return jnp.diag(jnp.sum(A, axis=1))


def laplacian(A: jax.Array) -> jax.Array:
    return degree_matrix(A) - A


def max_degree(A: jax.Array) -> jax.Array:
    """Delta = max_i sum_{j != i} a_ij."""
    return jnp.max(jnp.sum(A, axis=1))


def perron(A: jax.Array, eps: float) -> jax.Array:
    """P = I - eps * L (paper §2.1)."""
    M = A.shape[0]
    return jnp.eye(M, dtype=A.dtype) - eps * laplacian(A)


def _all_pairs_dist(A) -> np.ndarray:
    An = np.asarray(A) > 0
    M = An.shape[0]
    dist = np.full((M, M), np.inf)
    np.fill_diagonal(dist, 0)
    dist[An] = 1
    for k in range(M):  # Floyd-Warshall
        dist = np.minimum(dist, dist[:, k:k + 1] + dist[k:k + 1, :])
    return dist


def diameter(A: jax.Array) -> float:
    """Max shortest-path distance diam(G); inf if disconnected."""
    return float(_all_pairs_dist(A).max())


def is_connected(A: jax.Array) -> bool:
    return bool(np.isfinite(_all_pairs_dist(A)).all())


def connected_components(A: jax.Array, alive=None) -> np.ndarray:
    """Component labels (M,) int: nodes i, j share a label iff connected.

    Labels are the smallest member index of each component, so they are
    stable under any traversal order. `alive` (M,) bool/0-1 restricts the
    graph to the live subgraph first: dead nodes lose every incident edge
    and come out as singleton components — this is the partition detector
    the degraded consensus readout uses (docs/robustness.md)."""
    An = np.asarray(A) > 0
    M = An.shape[0]
    if alive is not None:
        live = np.asarray(alive).astype(bool)
        An = An & live[:, None] & live[None, :]
    dist = np.full((M, M), np.inf)
    np.fill_diagonal(dist, 0)
    dist[An] = 1
    for k in range(M):  # Floyd-Warshall on the restricted graph
        dist = np.minimum(dist, dist[:, k:k + 1] + dist[k:k + 1, :])
    reach = np.isfinite(dist)
    return np.array([int(np.flatnonzero(row)[0]) for row in reach])


def axis_size(axis_name) -> int:
    """Static size of a mapped mesh axis, from inside shard_map/pmap.

    Recent jax exposes jax.lax.axis_size; releases around 0.4.37 return the
    size directly from jax.core.axis_frame, and older ones return a frame
    object carrying it as `.size`. Returns a Python int either way (the ring
    permutation tables need a concrete M).
    """
    if hasattr(jax.lax, "axis_size"):
        return int(jax.lax.axis_size(axis_name))
    frame = jax.core.axis_frame(axis_name)
    return int(getattr(frame, "size", frame))
