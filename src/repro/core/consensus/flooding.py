"""Flooding (Topkis 1985): broadcast every agent's packet to all agents.

In diam(G) rounds of neighbor-wise forwarding every agent holds every packet.
Simulated mode returns the gathered array directly and reports the round count
(= diam(G)) so communication accounting matches the paper (Remark 8). Sharded
mode is an all_gather over the mesh axis (the TPU collective that implements
exactly this semantics).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .graph import diameter


def flood(values: jax.Array, A: jax.Array):
    """values (M, ...) -> (gathered (M, ...) available to all, rounds)."""
    return values, int(diameter(A))


def flood_sharded(value_local: jax.Array, axis_name: str) -> jax.Array:
    return jax.lax.all_gather(value_local, axis_name)
