"""chatglm3-6b [dense]: 28L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=65024 — 2d RoPE (rotary on half the head dims), GQA. [arXiv:2406.12793]

kv=2 % 16 != 0 -> kv heads replicate on `model`; q heads shard 16-way.
long_500k via sliding window."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="chatglm3-6b",
    arch_type="dense",
    num_layers=28,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    rope="half",              # chatglm 2d rope: rotary on half the dims
    rope_theta=10_000.0,
)
