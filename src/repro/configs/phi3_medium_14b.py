"""phi3-medium-14b [dense]: 40L d_model=5120 40H (GQA kv=10) d_ff=17920
vocab=100352 — RoPE SwiGLU GQA. [arXiv:2404.14219]

40 q-heads / 10 kv-heads % 16 != 0 -> heads replicate on `model` and the
projections FSDP-shard on `data` via the embed axis; FFN/vocab shard on
`model`. long_500k via sliding window."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="phi3-medium-14b",
    arch_type="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=10,
    d_ff=17920,
    vocab_size=100352,
    rope="full",
    rope_theta=10_000.0,
)
