"""granite-3-8b [dense]: 40L d_model=4096 32H (GQA kv=8) d_ff=12800
vocab=49155. [hf:ibm-granite/granite-3.0-2b-base]

Vocab 49155 % 16 != 0 -> padded to 49168 for the `model`-axis shard
(sharding.py); logits for padded ids are masked. long_500k via sliding
window."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="granite-3-8b",
    arch_type="dense",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=12800,
    vocab_size=49155,
    rope="full",
    rope_theta=10_000.0,
    tie_embeddings=False,
)
