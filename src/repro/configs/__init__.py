"""Architecture registry: `get_config(arch_id)` resolves every assigned
architecture (plus smoke variants via ArchConfig.reduced())."""
from __future__ import annotations

import importlib

from ..models.config import ArchConfig

ARCH_IDS = [
    "dbrx-132b",
    "whisper-small",
    "jamba-v0.1-52b",
    "internlm2-1.8b",
    "xlstm-350m",
    "granite-3-8b",
    "phi3-medium-14b",
    "llama4-maverick-400b-a17b",
    "internvl2-76b",
    "chatglm3-6b",
]

_MODULES = {
    "dbrx-132b": "dbrx_132b",
    "whisper-small": "whisper_small",
    "jamba-v0.1-52b": "jamba_v01_52b",
    "internlm2-1.8b": "internlm2_1_8b",
    "xlstm-350m": "xlstm_350m",
    "granite-3-8b": "granite_3_8b",
    "phi3-medium-14b": "phi3_medium_14b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b",
    "internvl2-76b": "internvl2_76b",
    "chatglm3-6b": "chatglm3_6b",
}


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f".{_MODULES[arch_id]}", __package__)
    return mod.CONFIG


def gp_experiment_config():
    from .paper_gp import CONFIG
    return CONFIG
