"""internlm2-1.8b [dense]: 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92544. [arXiv:2403.17297]

long_500k runs via the sliding-window variant (DESIGN.md §5)."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="internlm2-1.8b",
    arch_type="dense",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=92544,
    rope="full",
    rope_theta=1_000_000.0,
)
