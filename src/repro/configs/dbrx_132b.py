"""dbrx-132b [moe]: 40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352,
MoE 16 experts top-4, fine-grained. [hf:databricks/dbrx-base]"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="dbrx-132b",
    arch_type="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    num_experts=16,
    experts_per_token=4,
    moe_every=1,
    rope="full",
    rope_theta=500_000.0,
)
