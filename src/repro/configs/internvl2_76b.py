"""internvl2-76b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256 — InternViT-6B vision encoder STUB + LLaMA-3-70B-style LM
backbone. [arXiv:2404.16821]

The ViT + MLP projector is the assignment's allowed stub: input_specs
supplies 256 projected patch embeddings (B, 256, 8192) prepended to the text
stream. long_500k via sliding window."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-76b",
    arch_type="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    vis_tokens=256,
    rope="full",
    rope_theta=500_000.0,
)
