"""xlstm-350m [ssm]: 24L d_model=1024 4H d_ff=0 vocab=50304 — sLSTM + mLSTM
blocks (xLSTM[7:1]: one sLSTM block per 8). [arXiv:2405.04517]

d_ff=0 -> no separate FFN on mLSTM blocks (block-internal projections); the
sLSTM block carries a GELU MLP (pf 4/3 rounding -> d_ff = 2*d). long_500k
RUNS natively (O(1) recurrent state)."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-350m",
    arch_type="ssm",
    block_type="xlstm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=2048,                # sLSTM-block MLP only (cfg d_ff=0 per brief)
    vocab_size=50304,
    rope="none",
    slstm_every=8,
    xlstm_chunk=256,
    mlp_act="gelu",
)
