"""jamba-v0.1-52b [hybrid]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, Mamba+attention 1:7 interleave, MoE 16e top-2 on half the layers.
[arXiv:2403.19887]

long_500k RUNS: only 4 attention layers carry a long KV cache (seq-sharded);
the 28 mamba layers keep O(1) recurrent state."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    arch_type="hybrid",
    block_type="jamba",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    num_experts=16,
    experts_per_token=2,
    attention_every=8,        # 1 attn : 7 mamba
    rope="none",              # jamba attention layers use no positional enc.
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
    mamba_chunk=512,
)
