"""llama4-maverick-400b-a17b [moe]: 48L d_model=5120 40H (GQA kv=8) d_ff=8192,
MoE 128 experts top-1, vocab=202048 — early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E / Maverick model card]

MoE every OTHER layer (interleaved, per the model card): a flat 48x128e
reading gives ~780B params, contradicting the 400B name; with moe_every=2 the
total is ~400B and active ~17B (DESIGN.md §5). Early fusion: the backbone here
is text-only; multimodal tokens would enter through the same embedding
stream. long_500k via sliding window (Llama-4 uses chunked attention on 3/4
of its layers; sliding window is our TPU-equivalent)."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    arch_type="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    num_experts=128,
    experts_per_token=1,
    moe_every=2,
    rope="full",
    rope_theta=500_000.0,
)
