"""whisper-small [audio]: 12L d_model=768 12H (MHA kv=12) d_ff=3072
vocab=51865 — enc-dec, conv/mel frontend STUB (input_specs supplies frame
embeddings (B, 1500, 768)). [arXiv:2212.04356]

12 heads % 16 mesh != 0 -> heads replicate on `model`; FFN/vocab shard.
long_500k skipped (enc-dec audio decoder; DESIGN.md §5)."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small",
    arch_type="audio",
    num_layers=12,            # decoder layers (encoder: enc_layers)
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    mlp_act="gelu",
    rope="none",              # whisper uses learned absolute positions
    encdec=True,
    enc_layers=12,
    enc_seq=1500,             # 30 s of audio at 50 Hz post-conv
    max_seq=40_960,           # sized for the decode_32k shape
)
