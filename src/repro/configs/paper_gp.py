"""The paper's own experiment configuration (§6): synthetic 2-D GP fields and
the SST-like prediction dataset, fleets M in {4, 10, 20, 40}, path graph."""
from dataclasses import dataclass, field


@dataclass(frozen=True)
class GPExperimentConfig:
    n_train: int = 8_100                # paper also uses 32_400
    n_test: int = 100
    input_dim: int = 2
    true_theta: tuple = (1.2, 0.3, 1.3, 0.1)   # (l1, l2, sigma_f, sigma_eps)
    theta0: tuple = (2.0, 0.5, 1.0, 1.0)
    fleets: tuple = (4, 10, 20, 40)
    graph: str = "path"                 # path | random | complete
    rho: float = 500.0
    kappa: float = 5_000.0
    lipschitz: float = 5_000.0
    admm_iters: int = 100               # paper: s_end = 100
    nested_lr: float = 1e-5
    replications: int = 10
    eta_nn: float = 0.1                 # CBNN threshold
    noise_sst: float = 0.5              # N(0, 0.25) iid


CONFIG = GPExperimentConfig()
