"""Decoder-only language model covering the dense / moe / hybrid (jamba) /
ssm (xlstm) / vlm families, with scan-over-layers, KV/SSM caches, and a
single functional API:

    defs   = param_defs(cfg)                  # ParamDef pytree (+ logical axes)
    params = common.init_tree(key, defs, dtype)
    logits, aux, cache = forward(cfg, params, tokens, ...)
    loss, aux = loss_fn(cfg, params, batch)

Layer stacking (compile-time friendly on 512 fake devices; DESIGN.md §6):
  dense/moe : scan over groups of `moe_every` layers (group = dense*(k-1) +
              one MoE layer; k == 1 -> homogeneous stack).
  jamba     : scan over superblocks of `attention_every` (=8) layers:
              attn(+dense FFN) at position 0, then (k-1)/2+? mamba+MoE layers
              and the remaining mamba+dense layers. (The real Jamba
              interleaves MoE every other layer; we run the same LAYER COUNTS
              — 4 attn / 28 mamba / 16 MoE FFNs for jamba-52b — grouped
              MoE-first within a superblock. FLOPs/memory/collectives are
              identical; only the exact function composition order differs.
              Noted in DESIGN.md §9.)
  xlstm     : scan over groups of `slstm_every` blocks (mLSTM*(k-1) + sLSTM).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .act_sharding import constrain
from .common import (ParamDef, init_tree, cross_entropy, rmsnorm, swiglu,
                     gelu_mlp)
from .attention import attn_defs, attention, init_cache
from .moe import moe_defs, moe_ffn
from .mamba import mamba_defs, mamba_layer, init_mamba_state
from .xlstm import (mlstm_defs, mlstm_layer, init_mlstm_state, slstm_defs,
                    slstm_layer, init_slstm_state)


def _stack_defs(defs, n: int):
    """Prefix every ParamDef with a scanned `layers` axis of size n."""
    return jax.tree.map(
        lambda d: ParamDef((n,) + d.shape, ("layers",) + d.axes, d.init,
                           d.scale_axis + 1),
        defs, is_leaf=lambda x: isinstance(x, ParamDef))


def _mlp_defs(cfg):
    d, f = cfg.d_model, cfg.d_ff
    if cfg.mlp_act == "swiglu":
        return {"wg": ParamDef((d, f), ("embed", "ffn")),
                "wu": ParamDef((d, f), ("embed", "ffn")),
                "wd": ParamDef((f, d), ("ffn", "embed_out"))}
    return {"w1": ParamDef((d, f), ("embed", "ffn")),
            "w2": ParamDef((f, d), ("ffn", "embed_out"))}


def _norm_def(cfg):
    return ParamDef((cfg.d_model,), ("embed_norm",), "ones")


def _attn_layer_defs(cfg, moe: bool):
    out = {"ln1": _norm_def(cfg), "attn": attn_defs(cfg), "ln2": _norm_def(cfg)}
    out["moe" if moe else "mlp"] = moe_defs(cfg) if moe else _mlp_defs(cfg)
    return out


def _mamba_layer_defs(cfg, moe: bool):
    out = {"ln1": _norm_def(cfg), "mamba": mamba_defs(cfg)}
    if moe:
        out["ln2"] = _norm_def(cfg)
        out["moe"] = moe_defs(cfg)
    return out


def _jamba_split(cfg):
    """(n_groups, n_moe_mamba, n_dense_mamba) per superblock."""
    k = cfg.attention_every
    n_groups = cfg.num_layers // k
    n_mamba = k - 1
    n_moe = (n_mamba + 1) // 2 if cfg.num_experts else 0   # 7 -> 4 (16 total)
    return n_groups, n_moe, n_mamba - n_moe


def param_defs(cfg):
    d, V = cfg.d_model, cfg.vocab_size
    defs = {
        "embed": ParamDef((V, d), ("vocab", "embed"), "small_normal"),
        "final_norm": _norm_def(cfg),
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((d, V), ("embed", "vocab"))
    bt = cfg.block_type
    if bt == "transformer":
        k = cfg.moe_every if cfg.num_experts else 1
        n_groups = cfg.num_layers // k
        group = {}
        if cfg.num_experts:
            if k > 1:
                group["dense"] = _stack_defs(_attn_layer_defs(cfg, False), k - 1)
            group["moe"] = _attn_layer_defs(cfg, True)
        else:
            group["dense"] = _stack_defs(_attn_layer_defs(cfg, False), 1)
        defs["blocks"] = _stack_defs(group, n_groups)
    elif bt == "jamba":
        n_groups, n_moe, n_dense = _jamba_split(cfg)
        group = {"attn": _attn_layer_defs(cfg, False)}
        if n_moe:
            group["mamba_moe"] = _stack_defs(_mamba_layer_defs(cfg, True), n_moe)
        if n_dense:
            group["mamba_dense"] = _stack_defs(_mamba_layer_defs(cfg, False),
                                               n_dense)
        defs["blocks"] = _stack_defs(group, n_groups)
    elif bt == "xlstm":
        k = cfg.slstm_every
        n_groups = cfg.num_layers // k
        group = {"slstm": {"ln": _norm_def(cfg), "cell": slstm_defs(cfg),
                           "ln2": _norm_def(cfg), "mlp": _mlp_defs(cfg)}}
        if k > 1:
            group["mlstm"] = _stack_defs({"ln": _norm_def(cfg),
                                          "cell": mlstm_defs(cfg)}, k - 1)
        defs["blocks"] = _stack_defs(group, n_groups)
    else:
        raise ValueError(bt)
    return defs


def init_params(cfg, key, dtype=jnp.float32):
    return init_tree(key, param_defs(cfg), dtype)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _mlp(p, x, cfg):
    if cfg.mlp_act == "swiglu":
        return swiglu(x, p["wg"], p["wu"], p["wd"])
    return gelu_mlp(x, p["w1"], p["w2"])


def _attn_block(p, x, cfg, positions, cache, moe: bool):
    h, new_cache = attention(p["attn"], rmsnorm(x, p["ln1"], cfg.norm_eps),
                             cfg, positions=positions, cache=cache)
    x = x + h
    y = rmsnorm(x, p["ln2"], cfg.norm_eps)
    if moe:
        out, aux = moe_ffn(p["moe"], y, cfg)
    else:
        out, aux = _mlp(p["mlp"], y, cfg), jnp.zeros((), jnp.float32)
    return x + out, new_cache, aux


def _mamba_block(p, x, cfg, state, moe: bool):
    h, new_state = mamba_layer(p["mamba"], rmsnorm(x, p["ln1"], cfg.norm_eps),
                               cfg, state=state)
    x = x + h
    if moe:
        out, aux = moe_ffn(p["moe"], rmsnorm(x, p["ln2"], cfg.norm_eps), cfg)
        x = x + out
    else:
        aux = jnp.zeros((), jnp.float32)
    return x, new_state, aux


def _scan_sub(fn, params_stacked, x, states_stacked):
    """Scan a stacked homogeneous sub-group.

    fn(p, x, state) -> (x, new_state, aux)."""
    def body(carry, xs):
        x, aux_acc = carry
        p, st = xs
        x, st2, aux = fn(p, x, st)
        return (x, aux_acc + aux), st2

    (x, aux), new_states = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), (params_stacked, states_stacked))
    return x, new_states, aux


def forward(cfg, params, tokens, *, embeds=None, cache=None, positions=None,
            logits_slice: int = 0):
    """tokens (B, S_text) int32; embeds (B, P, d) optional stub-frontend
    prefix (VLM patches / fused audio). Returns (logits, aux_loss, new_cache).
    """
    dt = params["embed"].dtype
    x = params["embed"][tokens].astype(dt)
    if embeds is not None:
        x = jnp.concatenate([embeds.astype(dt), x], axis=1)
    B, S, _ = x.shape
    if positions is None:
        start = cache["index"] if cache is not None else 0
        positions = start + jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))

    states = cache["blocks"] if cache is not None else _zero_states(
        cfg, B, dt, for_cache=False)
    bt = cfg.block_type
    zero = jnp.zeros((), jnp.float32)

    def group_fn(carry, xs):
        x, aux_acc = carry
        x = constrain(x, ("batch", None, None))   # pin the residual stream
        p, st = xs
        aux_total = zero
        new_st = {}
        if bt == "transformer":
            if "dense" in p:
                x, s2, aux = _scan_sub(
                    lambda pp, xx, ss: _attn_block(pp, xx, cfg, positions, ss,
                                                   False),
                    p["dense"], x, st["dense"] if st else None)
                new_st["dense"] = s2
                aux_total += aux
            if "moe" in p:
                x, c2, aux = _attn_block(p["moe"], x, cfg, positions,
                                         st["moe"] if st else None, True)
                new_st["moe"] = c2
                aux_total += aux
        elif bt == "jamba":
            x, c2, aux = _attn_block(p["attn"], x, cfg, positions,
                                     st["attn"], False)
            new_st["attn"] = c2
            aux_total += aux
            if "mamba_moe" in p:
                x, s2, aux = _scan_sub(
                    lambda pp, xx, ss: _mamba_block(pp, xx, cfg, ss, True),
                    p["mamba_moe"], x, st["mamba_moe"])
                new_st["mamba_moe"] = s2
                aux_total += aux
            if "mamba_dense" in p:
                x, s2, aux = _scan_sub(
                    lambda pp, xx, ss: _mamba_block(pp, xx, cfg, ss, False),
                    p["mamba_dense"], x, st["mamba_dense"])
                new_st["mamba_dense"] = s2
        elif bt == "xlstm":
            if "mlstm" in p:
                def fx(pp, xx, ss):
                    h, s2 = mlstm_layer(pp["cell"],
                                        rmsnorm(xx, pp["ln"], cfg.norm_eps),
                                        cfg, state=ss)
                    return xx + h, s2, zero
                x, s2, _ = _scan_sub(fx, p["mlstm"], x, st["mlstm"])
                new_st["mlstm"] = s2
            ps = p["slstm"]
            h, s2 = slstm_layer(ps["cell"], rmsnorm(x, ps["ln"], cfg.norm_eps),
                                cfg, state=st["slstm"])
            x = x + h
            x = x + _mlp(ps["mlp"], rmsnorm(x, ps["ln2"], cfg.norm_eps), cfg)
            new_st["slstm"] = s2
        return (x, aux_acc + aux_total), new_st

    if cfg.remat:
        if cfg.remat_policy == "dots":
            group_fn = jax.checkpoint(
                group_fn,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        else:
            group_fn = jax.checkpoint(group_fn)
    (x, aux), new_states = jax.lax.scan(group_fn, (x, zero),
                                        (params["blocks"], states))

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if logits_slice:
        x = x[:, -logits_slice:]
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    new_cache = ({"blocks": new_states, "index": cache["index"] + S}
                 if cache is not None else None)
    return logits, aux, new_cache


# ---------------------------------------------------------------------------
# caches / states
# ---------------------------------------------------------------------------

def _zero_states(cfg, B, dtype, for_cache: bool, max_len: int = 0):
    """Stacked per-layer states matching the block structure.

    for_cache=False (training): attention layers carry no state (None);
    recurrent layers still need zero initial states.
    """
    bt = cfg.block_type

    def attn_state():
        return init_cache(cfg, B, max_len, dtype) if for_cache else None

    def stack(tree, n):
        return jax.tree.map(
            lambda t: jnp.broadcast_to(t, (n,) + t.shape), tree)

    if bt == "transformer":
        k = cfg.moe_every if cfg.num_experts else 1
        n_groups = cfg.num_layers // k
        group = {}
        if cfg.num_experts:
            if k > 1:
                group["dense"] = stack(attn_state(), k - 1)
            group["moe"] = attn_state()
        else:
            group["dense"] = stack(attn_state(), 1)
        return stack(group, n_groups)
    if bt == "jamba":
        n_groups, n_moe, n_dense = _jamba_split(cfg)
        group = {"attn": attn_state()}
        ms = init_mamba_state(cfg, B, dtype)
        if n_moe:
            group["mamba_moe"] = stack(ms, n_moe)
        if n_dense:
            group["mamba_dense"] = stack(ms, n_dense)
        return stack(group, n_groups)
    if bt == "xlstm":
        k = cfg.slstm_every
        n_groups = cfg.num_layers // k
        group = {"slstm": init_slstm_state(cfg, B)}
        if k > 1:
            group["mlstm"] = stack(init_mlstm_state(cfg, B), k - 1)
        return stack(group, n_groups)
    raise ValueError(bt)


def init_decode_cache(cfg, batch: int, max_len: int, dtype=jnp.float32):
    return {"blocks": _zero_states(cfg, batch, dtype, True, max_len),
            "index": jnp.zeros((), jnp.int32)}


def cache_axes(cfg):
    """Logical-axes pytree mirroring init_decode_cache (for sharding.py)."""
    is_ax = lambda x: isinstance(x, tuple)
    attn_ax = {"k": ("batch", "kv_seq", "kv_heads", "head_dim"),
               "v": ("batch", "kv_seq", "kv_heads", "head_dim"),
               "index": ()}
    mamba_ax = {"conv": ("batch", "conv_k", "mamba_inner"),
                "h": ("batch", "mamba_inner", "mamba_state")}
    mlstm_ax = {"C": ("batch", "heads", "head_dim", "head_dim_r"),
                "n": ("batch", "heads", "head_dim"),
                "m": ("batch", "heads")}
    slstm_ax = {k: ("batch", "heads", "head_dim") for k in ("h", "c", "n", "m")}

    def stack(tree):
        return jax.tree.map(lambda ax: ("layers",) + ax, tree, is_leaf=is_ax)

    bt = cfg.block_type
    if bt == "transformer":
        k = cfg.moe_every if cfg.num_experts else 1
        group = {}
        if cfg.num_experts:
            if k > 1:
                group["dense"] = stack(attn_ax)
            group["moe"] = attn_ax
        else:
            group["dense"] = stack(attn_ax)
    elif bt == "jamba":
        _, n_moe, n_dense = _jamba_split(cfg)
        group = {"attn": attn_ax}
        if n_moe:
            group["mamba_moe"] = stack(mamba_ax)
        if n_dense:
            group["mamba_dense"] = stack(mamba_ax)
    elif bt == "xlstm":
        group = {"slstm": slstm_ax}
        if cfg.slstm_every > 1:
            group["mlstm"] = stack(mlstm_ax)
    else:
        raise ValueError(bt)
    return {"blocks": stack(group), "index": ()}


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def loss_fn(cfg, params, batch, aux_weight: float = 0.01):
    """batch: dict(tokens (B,S), labels (B,S), [embeds (B,P,d)]).

    labels use -1 for ignored positions; for VLM the patch-prefix positions
    are padded with -1 automatically.
    """
    logits, aux, _ = forward(cfg, params, batch["tokens"],
                             embeds=batch.get("embeds"))
    labels = batch["labels"]
    if batch.get("embeds") is not None:
        pad = -jnp.ones(batch["embeds"].shape[:2], labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    ce = cross_entropy(logits, labels)
    return ce + aux_weight * aux, {"ce": ce, "aux": aux}
