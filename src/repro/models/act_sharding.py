"""Activation sharding hints: model code calls constrain(x, logical_axes);
the launcher installs a mesh via use_mesh(); without one, it's a no-op.

This keeps model code mesh-agnostic while letting GSPMD pin the known-large
intermediates (MoE dispatch buffers, the residual stream inside scans) to the
intended layout instead of relying purely on propagation.
"""
from __future__ import annotations

import contextlib
from contextvars import ContextVar

import jax
from jax.sharding import NamedSharding

_CTX = ContextVar("act_sharding_ctx", default=None)  # (mesh, kv_seq, policy)


@contextlib.contextmanager
def use_mesh(mesh, shard_kv_seq: bool = False, policy=None):
    token = _CTX.set((mesh, shard_kv_seq, policy))
    try:
        yield
    finally:
        _CTX.reset(token)


def constrain(x, axes: tuple):
    ctx = _CTX.get()
    if ctx is None:
        return x
    mesh, shard_kv_seq, policy = ctx
    from ..launch.sharding import spec_for_axes
    spec = spec_for_axes(mesh, axes, x.shape, shard_kv_seq=shard_kv_seq,
                         policy=policy)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
