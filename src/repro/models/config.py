"""Architecture configuration: a single dataclass covers the 6 assigned
architecture families (dense / moe / ssm / hybrid / audio / vlm)."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: str                 # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_every: int = 1             # MoE FFN every k-th layer (1 = every layer)
    moe_capacity_factor: float = 1.25
    moe_group_size: int = 512      # GShard dispatch group size (tokens)

    # attention
    rope: str = "full"             # full | half | none  (half = chatglm 2d-rope)
    rope_theta: float = 10_000.0
    window: int = 0                # 0 = full causal; >0 = sliding window
    attention_every: int = 1       # hybrid (jamba): attn layer every k-th layer

    # block family
    block_type: str = "transformer"  # transformer | jamba | xlstm
    mlp_act: str = "swiglu"          # swiglu | gelu
    qkv_bias: bool = False

    # mamba (jamba)
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    mamba_chunk: int = 512

    # xlstm
    slstm_every: int = 8           # every k-th block is sLSTM (rest mLSTM)
    xlstm_chunk: int = 256

    # encoder-decoder (whisper)
    encdec: bool = False
    enc_layers: int = 0
    enc_seq: int = 0               # stub frontend frames (whisper: 1500)

    # vlm
    vis_tokens: int = 0            # stub ViT patch embeddings prepended

    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    max_seq: int = 8192            # position-emb table size where applicable

    # numerics / execution
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    use_pallas: bool = False       # pure-jnp path under pjit (CPU dry-run)
    remat: bool = False            # activation checkpoint each block
    remat_policy: str = "full"     # full | dots (save matmul outputs)

    def with_overrides(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def d_inner_mamba(self) -> int:
        return self.mamba_expand * self.d_model

    def reduced(self, layers: int = 2, d_model: int = 256,
                experts: int = 4) -> "ArchConfig":
        """Smoke-test variant: same family, tiny dims (spec: 2 layers,
        d_model<=512, <=4 experts)."""
        heads = max(2, min(self.num_heads, d_model // 64))
        kv = max(1, min(self.num_kv_heads, heads))
        while heads % kv:
            kv -= 1
        return self.with_overrides(
            name=self.name + "-smoke",
            num_layers=layers,
            d_model=d_model,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=d_model // heads,
            d_ff=0 if self.d_ff == 0 else d_model * 2,
            vocab_size=512,
            num_experts=min(self.num_experts, experts) if self.num_experts else 0,
            experts_per_token=min(self.experts_per_token, 2)
            if self.experts_per_token else 0,
            enc_layers=min(self.enc_layers, layers),
            enc_seq=min(self.enc_seq, 32) if self.enc_seq else 0,
            vis_tokens=min(self.vis_tokens, 8) if self.vis_tokens else 0,
            moe_group_size=32,
            mamba_chunk=16,
            xlstm_chunk=16,
            slstm_every=min(self.slstm_every, layers),
            attention_every=min(self.attention_every, layers),
            max_seq=256,
            window=min(self.window, 32) if self.window else 0,
        )
