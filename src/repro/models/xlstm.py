"""xLSTM blocks (Beck et al. 2024, arXiv:2405.04517): mLSTM (matrix memory,
exponential input gate, chunked-parallel training form) and sLSTM (scalar
memory with recurrent gating, inherently sequential).

TPU adaptation: mLSTM trains with the chunkwise-parallel algebra (intra-chunk
quadratic attention-like einsums + inter-chunk recurrent state), stabilized in
log space with the running max m — validated against the sequential recurrence
in tests. Heads are independent -> head axis shards over `model` with no
cross-shard traffic. sLSTM stays a lax.scan over time (it is a true RNN with
memory mixing; the paper itself gives it no parallel form).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ParamDef, rmsnorm


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_defs(cfg):
    d, H = cfg.d_model, cfg.num_heads
    hd = cfg.resolved_head_dim
    return {
        "wq": ParamDef((d, H, hd), ("embed", "heads", "head_dim")),
        "wk": ParamDef((d, H, hd), ("embed", "heads", "head_dim")),
        "wv": ParamDef((d, H, hd), ("embed", "heads", "head_dim")),
        "wi": ParamDef((d, H), ("embed", "heads"), "small_normal"),
        "wf": ParamDef((d, H), ("embed", "heads"), "small_normal"),
        "wo_gate": ParamDef((d, H, hd), ("embed", "heads", "head_dim")),
        "wo": ParamDef((H, hd, d), ("heads", "head_dim", "embed_out")),
        "ln_out": ParamDef((H, hd), ("heads", "head_dim"), "ones"),
    }


def _mlstm_chunk(q, k, v, lf, li, state):
    """One chunk, all heads. q/k/v (B,H,L,hd); lf/li (B,H,L) log gates;
    state: (C (B,H,hd,hd), n (B,H,hd), m (B,H)). Returns (h, new_state)."""
    B, H, L, hd = q.shape
    b = jnp.cumsum(lf, axis=-1)                       # (B,H,L) cumulative log f
    g = li - b                                         # log i_tau - b_tau
    # per-position stabilizer
    gmax = jax.lax.cummax(g, axis=g.ndim - 1)          # max_{tau<=t} (g_tau)
    m_intra = b + gmax
    m_inter = state["m"][..., None] + b
    m_t = jnp.maximum(m_inter, m_intra)                # (B,H,L)

    scale = 1.0 / (hd ** 0.5)
    scores = jnp.einsum("bhld,bhtd->bhlt", q, k) * scale   # l = query, t = key
    pos_q = jnp.arange(L)[:, None]
    pos_k = jnp.arange(L)[None, :]
    decay = b[..., :, None] - b[..., None, :] + li[..., None, :] \
        - m_t[..., :, None]
    w = jnp.exp(jnp.where(pos_k <= pos_q, decay, -jnp.inf))
    num_intra = jnp.einsum("bhlt,bhtd->bhld", scores * w, v)
    den_intra = jnp.sum(scores * w, axis=-1)              # n sums k/sqrt(hd)

    coef = jnp.exp(m_inter - m_t)                      # (B,H,L)
    num_inter = jnp.einsum("bhld,bhde->bhle", q, state["C"]) * coef[..., None]
    den_inter = jnp.einsum("bhld,bhd->bhl", q, state["n"]) * coef

    num = num_intra + num_inter
    den = den_intra + den_inter
    # unstabilized rule is max(|q.n|, 1); in exp(-m)-stabilized coordinates
    # that lower bound becomes exp(-m_t)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]

    # state update to end of chunk
    bL = b[..., -1:]                                   # (B,H,1)
    m_new = jnp.maximum(state["m"] + bL[..., 0],
                        (bL[..., 0] + gmax[..., -1]))
    upd_w = jnp.exp(li + bL - b - m_new[..., None])    # (B,H,L)
    C_new = jnp.exp(state["m"] + bL[..., 0] - m_new)[..., None, None] \
        * state["C"] + jnp.einsum("bhl,bhld,bhle->bhde", upd_w, k * (1.0 / hd ** 0.5), v)
    n_new = jnp.exp(state["m"] + bL[..., 0] - m_new)[..., None] * state["n"] \
        + jnp.einsum("bhl,bhld->bhd", upd_w, k * (1.0 / hd ** 0.5))
    return h, {"C": C_new, "n": n_new, "m": m_new}


def mlstm_sequential(q, k, v, lf, li, state):
    """Step-by-step oracle for tests (same stabilized recurrence)."""
    hd = q.shape[-1]
    scale = 1.0 / hd ** 0.5

    def step(st, args):
        qt, kt, vt, lft, lit = args                   # (B,H,hd)...,(B,H)
        m_new = jnp.maximum(st["m"] + lft, lit)
        fw = jnp.exp(st["m"] + lft - m_new)
        iw = jnp.exp(lit - m_new)
        C = fw[..., None, None] * st["C"] \
            + iw[..., None, None] * (kt * scale)[..., :, None] * vt[..., None, :]
        n = fw[..., None] * st["n"] + iw[..., None] * kt * scale
        num = jnp.einsum("bhd,bhde->bhe", qt, C)
        den = jnp.einsum("bhd,bhd->bh", qt, n)
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
        return {"C": C, "n": n, "m": m_new}, h

    sw = lambda t: jnp.moveaxis(t, 2, 0)
    st, hs = jax.lax.scan(step, state, (sw(q), sw(k), sw(v),
                                        jnp.moveaxis(lf, -1, 0),
                                        jnp.moveaxis(li, -1, 0)))
    return jnp.moveaxis(hs, 0, 2), st


def mlstm_layer(p, x, cfg, *, state=None):
    """x (B,S,d) -> (out, new_state). state: C/n/m dict (decode & chunks)."""
    B, S, d = x.shape
    H, hd = cfg.num_heads, cfg.resolved_head_dim
    q = jnp.einsum("bsd,dhk->bhsk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bhsk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bhsk", x, p["wv"])
    lf = jax.nn.log_sigmoid(jnp.einsum("bsd,dh->bhs", x, p["wf"])
                            .astype(jnp.float32))
    li = jnp.einsum("bsd,dh->bhs", x, p["wi"]).astype(jnp.float32)

    if state is None:
        state = init_mlstm_state(cfg, B)
    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))

    if S == 1:
        h, new_state = mlstm_sequential(qf, kf, vf, lf, li, state)
    else:
        L = cfg.xlstm_chunk if S % cfg.xlstm_chunk == 0 else S
        n_chunks = S // L

        def body(st, args):
            qc, kc, vc, lfc, lic = args
            h, st = _mlstm_chunk(qc, kc, vc, lfc, lic, st)
            return st, h

        ch = lambda t: jnp.moveaxis(
            t.reshape(B, H, n_chunks, L, -1), 2, 0)
        chg = lambda t: jnp.moveaxis(t.reshape(B, H, n_chunks, L), 2, 0)
        new_state, hs = jax.lax.scan(
            body, state, (ch(qf), ch(kf), ch(vf), chg(lf), chg(li)))
        h = jnp.moveaxis(hs, 0, 2).reshape(B, H, S, hd)

    # per-head output norm + sigmoid output gate (xLSTM block structure)
    h = rmsnorm(h, p["ln_out"][None, :, None, :], eps=cfg.norm_eps)
    h = h * jax.nn.sigmoid(jnp.einsum("bsd,dhk->bhsk", x, p["wo_gate"]))
    out = jnp.einsum("bhsk,hkd->bsd", h.astype(x.dtype), p["wo"])
    return out, new_state


def init_mlstm_state(cfg, batch: int):
    H, hd = cfg.num_heads, cfg.resolved_head_dim
    return {
        "C": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, H, hd), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
    }


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_defs(cfg):
    d, H = cfg.d_model, cfg.num_heads
    hd = cfg.resolved_head_dim
    return {
        "wz": ParamDef((d, H, hd), ("embed", "heads", "head_dim")),
        "wi": ParamDef((d, H, hd), ("embed", "heads", "head_dim"), "small_normal"),
        "wf": ParamDef((d, H, hd), ("embed", "heads", "head_dim"), "small_normal"),
        "wo_g": ParamDef((d, H, hd), ("embed", "heads", "head_dim")),
        "rz": ParamDef((H, hd, hd), ("heads", "head_dim", "head_dim_r"),
                       "small_normal"),
        "ri": ParamDef((H, hd, hd), ("heads", "head_dim", "head_dim_r"),
                       "small_normal"),
        "rf": ParamDef((H, hd, hd), ("heads", "head_dim", "head_dim_r"),
                       "small_normal"),
        "wo": ParamDef((H, hd, d), ("heads", "head_dim", "embed_out")),
    }


def slstm_layer(p, x, cfg, *, state=None):
    """sLSTM with exponential gating + per-head recurrent memory mixing.

    x (B,S,d); state dict(h,c,n,m each (B,H,hd))."""
    B, S, d = x.shape
    H, hd = cfg.num_heads, cfg.resolved_head_dim
    if state is None:
        state = init_slstm_state(cfg, B)

    zx = jnp.einsum("bsd,dhk->sbhk", x, p["wz"]).astype(jnp.float32)
    ix = jnp.einsum("bsd,dhk->sbhk", x, p["wi"]).astype(jnp.float32)
    fx = jnp.einsum("bsd,dhk->sbhk", x, p["wf"]).astype(jnp.float32)
    ox = jnp.einsum("bsd,dhk->sbhk", x, p["wo_g"]).astype(jnp.float32)

    def step(st, args):
        zt, it, ft, ot = args
        hr = st["h"]
        z = jnp.tanh(zt + jnp.einsum("bhk,hkl->bhl", hr, p["rz"]))
        i_til = it + jnp.einsum("bhk,hkl->bhl", hr, p["ri"])
        f_til = ft + jnp.einsum("bhk,hkl->bhl", hr, p["rf"])
        lf = jax.nn.log_sigmoid(f_til)
        m_new = jnp.maximum(lf + st["m"], i_til)
        i_p = jnp.exp(i_til - m_new)
        f_p = jnp.exp(lf + st["m"] - m_new)
        c = f_p * st["c"] + i_p * z
        n = f_p * st["n"] + i_p
        h = jax.nn.sigmoid(ot) * c / jnp.maximum(n, 1.0)
        return {"h": h, "c": c, "n": n, "m": m_new}, h

    new_state, hs = jax.lax.scan(step, state, (zx, ix, fx, ox))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, H, hd)    # (B,S,H,hd)
    out = jnp.einsum("bshk,hkd->bsd", h.astype(x.dtype), p["wo"])
    return out, new_state


def init_slstm_state(cfg, batch: int):
    H, hd = cfg.num_heads, cfg.resolved_head_dim
    z = lambda: jnp.zeros((batch, H, hd), jnp.float32)
    return {"h": z(), "c": z(), "n": z(),
            "m": jnp.full((batch, H, hd), -1e30, jnp.float32)}
