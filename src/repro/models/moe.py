"""Mixture-of-Experts FFN with GShard-style capacity dispatch (top-k, groups).

TPU-native expert parallelism (DESIGN.md §5-6): experts live on the `model`
mesh axis, tokens on `data`. The dispatch one-hot einsum produces expert
buffers already sharded by expert — each model-shard computes its expert
slice against locally available tokens, and the combine einsum's contraction
over experts becomes a single psum over `model` (fused with the row-parallel
down-projection reduce). No host-side gather/scatter, no dynamic shapes.

Capacity: C = ceil(k * g * capacity_factor / E) per group of g tokens;
overflow tokens drop (standard GShard semantics) — exact top-k compute would
need sort-based megablocks, kept as a perf-iteration candidate.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ParamDef
from .act_sharding import constrain


def moe_defs(cfg):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    return {
        "router": ParamDef((d, E), ("embed", "experts_logits"), "small_normal"),
        "wg": ParamDef((E, d, f), ("experts", "embed", "ffn"), scale_axis=1),
        "wu": ParamDef((E, d, f), ("experts", "embed", "ffn"), scale_axis=1),
        "wd": ParamDef((E, f, d), ("experts", "ffn", "embed_out"), scale_axis=1),
    }


def moe_ffn(p, x, cfg):
    """x (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    T = B * S
    g = min(cfg.moe_group_size, T)
    while T % g:                       # largest divisor of T <= group_size
        g -= 1
    G = T // g
    cap = int(max(1, round(k * g * cfg.moe_capacity_factor / E)))
    if S == 1:
        cap = g * k          # decode: drop-free (buffers are tiny at S=1)

    xt = x.reshape(G, g, d)
    xt = constrain(xt, ("batch", None, None))
    logits = jnp.einsum("Ggd,de->Gge", xt, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)                     # (G, g, k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    # load-balance auxiliary loss (Switch): E * sum_e f_e * p_e
    onehot = jax.nn.one_hot(top_e, E, dtype=jnp.float32)       # (G, g, k, E)
    me = jnp.mean(probs, axis=(0, 1))                          # (E,)
    ce = jnp.mean(jnp.sum(onehot, axis=2), axis=(0, 1))        # (E,)
    aux = E * jnp.sum(me * ce) / k

    # choice-major priority positions within each expert (no C dim yet)
    oh_cm = jnp.transpose(onehot, (0, 2, 1, 3)).reshape(G, k * g, E)
    pos = jnp.cumsum(oh_cm, axis=1) - oh_cm                    # (G, kg, E)
    keep = (pos < cap) * oh_cm
    pos = pos.reshape(G, k, g, E)
    keep = keep.reshape(G, k, g, E)

    cdt = x.dtype
    # loop over the k choices: one (G, g, E, C) one-hot at a time instead of
    # a k-times-larger (G, kg, E, C) tensor (memory-critical for top-4)
    disp = 0.0
    comb = 0.0
    for j in range(k):
        slot_j = jax.nn.one_hot(pos[:, j].astype(jnp.int32), cap,
                                dtype=cdt) * keep[:, j][..., None].astype(cdt)
        disp = disp + slot_j
        comb = comb + slot_j * top_w[:, :, j][..., None, None].astype(cdt)
    disp = constrain(disp, ("batch", None, "experts", None))
    comb = constrain(comb, ("batch", None, "experts", None))

    expert_in = jnp.einsum("GgEC,Ggd->GECd", disp, xt)
    expert_in = constrain(expert_in, ("batch", "experts", None, None))
    h = jax.nn.silu(jnp.einsum("GECd,Edf->GECf", expert_in, p["wg"])) \
        * jnp.einsum("GECd,Edf->GECf", expert_in, p["wu"])
    h = constrain(h, ("batch", "experts", None, "ffn"))
    expert_out = jnp.einsum("GECf,Efd->GECd", h, p["wd"])
    out = jnp.einsum("GgEC,GECd->Ggd", comb, expert_out)
    out = constrain(out, ("batch", None, None))
    return out.reshape(B, S, d), aux
