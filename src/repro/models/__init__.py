from .config import ArchConfig
from . import lm, encdec, common, attention, moe, mamba, xlstm

__all__ = ["ArchConfig", "lm", "encdec", "common", "attention", "moe",
           "mamba", "xlstm"]
