"""Encoder-decoder transformer backbone (whisper-small, arXiv:2212.04356).

The mel-spectrogram + conv feature extractor is a STUB per the assignment:
`input_specs` supplies precomputed frame embeddings (B, enc_seq, d). The
encoder is bidirectional pre-LN attention + GELU MLP; the decoder adds causal
self-attention (KV-cached) and cross-attention to the encoder states.
Whisper uses LayerNorm; we use RMSNorm uniformly (framework-wide norm — the
systems properties are identical).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ParamDef, init_tree, cross_entropy, rmsnorm, gelu_mlp
from .attention import attn_defs, attention, init_cache
from .lm import _mlp_defs, _mlp, _norm_def, _stack_defs


def param_defs(cfg):
    d, V = cfg.d_model, cfg.vocab_size
    enc_layer = {"ln1": _norm_def(cfg), "attn": attn_defs(cfg),
                 "ln2": _norm_def(cfg), "mlp": _mlp_defs(cfg)}
    dec_layer = {"ln1": _norm_def(cfg), "self_attn": attn_defs(cfg),
                 "ln_x": _norm_def(cfg), "cross_attn": attn_defs(cfg),
                 "ln2": _norm_def(cfg), "mlp": _mlp_defs(cfg)}
    return {
        "embed": ParamDef((V, d), ("vocab", "embed"), "small_normal"),
        "pos_enc": ParamDef((cfg.enc_seq, d), ("enc_seq", "embed"),
                            "small_normal"),
        "pos_dec": ParamDef((cfg.max_seq, d), ("dec_seq", "embed"),
                            "small_normal"),
        "enc_blocks": _stack_defs(enc_layer, cfg.enc_layers),
        "enc_norm": _norm_def(cfg),
        "dec_blocks": _stack_defs(dec_layer, cfg.num_layers),
        "final_norm": _norm_def(cfg),
        "lm_head": ParamDef((d, V), ("embed", "vocab")),
    }


def init_params(cfg, key, dtype=jnp.float32):
    return init_tree(key, param_defs(cfg), dtype)


def encode(cfg, params, frames):
    """frames (B, S_enc, d) stub-frontend embeddings -> encoder states."""
    B, S, d = frames.shape
    x = frames.astype(params["enc_norm"].dtype) + params["pos_enc"][None, :S]
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    cfg_norope = cfg.with_overrides(rope="none")

    def layer(carry, p):
        x, = carry
        h, _ = attention(p["attn"], rmsnorm(x, p["ln1"], cfg.norm_eps),
                         cfg_norope, positions=pos, causal=False)
        x = x + h
        x = x + _mlp(p["mlp"], rmsnorm(x, p["ln2"], cfg.norm_eps), cfg)
        return (x,), None

    (x,), _ = jax.lax.scan(layer, (x,), params["enc_blocks"])
    return rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def decode(cfg, params, tokens, enc_out, *, cache=None, positions=None,
           logits_slice: int = 0):
    """tokens (B, S); enc_out (B, S_enc, d). Returns (logits, new_cache)."""
    B, S = tokens.shape
    start = cache["index"] if cache is not None else 0
    if positions is None:
        positions = start + jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x = params["embed"][tokens].astype(enc_out.dtype)
    x = x + jnp.take(params["pos_dec"],
                     jnp.clip(positions, 0, cfg.max_seq - 1), axis=0)
    states = cache["blocks"] if cache is not None else None
    cfg_norope = cfg.with_overrides(rope="none")

    def layer(carry, xs):
        x, = carry
        p, st = xs
        h, new_c = attention(p["self_attn"],
                             rmsnorm(x, p["ln1"], cfg.norm_eps),
                             cfg_norope, positions=positions, cache=st)
        x = x + h
        h, _ = attention(p["cross_attn"], rmsnorm(x, p["ln_x"], cfg.norm_eps),
                         cfg_norope, positions=positions, kv_x=enc_out)
        x = x + h
        x = x + _mlp(p["mlp"], rmsnorm(x, p["ln2"], cfg.norm_eps), cfg)
        return (x,), new_c

    (x,), new_states = jax.lax.scan(layer, (x,),
                                    (params["dec_blocks"], states))
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if logits_slice:
        x = x[:, -logits_slice:]
    logits = x @ params["lm_head"]
    new_cache = ({"blocks": new_states, "index": start + S}
                 if cache is not None else None)
    return logits, new_cache


def init_decode_cache(cfg, batch: int, max_len: int, dtype=jnp.float32):
    c = init_cache(cfg, batch, max_len, dtype)
    blocks = jax.tree.map(
        lambda t: jnp.broadcast_to(t, (cfg.num_layers,) + t.shape), c)
    return {"blocks": blocks, "index": jnp.zeros((), jnp.int32)}


def cache_axes(cfg):
    """Logical-axes pytree mirroring init_decode_cache (for sharding.py)."""
    attn_ax = {"k": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
               "v": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
               "index": ("layers",)}
    return {"blocks": attn_ax, "index": ()}


def loss_fn(cfg, params, batch, aux_weight: float = 0.0):
    """batch: dict(frames (B,S_enc,d), tokens (B,S), labels (B,S))."""
    enc_out = encode(cfg, params, batch["frames"])
    logits, _ = decode(cfg, params, batch["tokens"], enc_out)
    ce = cross_entropy(logits, batch["labels"])
    return ce, {"ce": ce, "aux": jnp.zeros((), jnp.float32)}
