"""Multi-head / grouped-query attention with RoPE, KV cache, sliding window.

Layout: activations (B, S, d); q/k/v (B, S, H|KH, hd). The attention inner
product runs through kernels/ops.flash_attention (Pallas on TPU, jnp oracle
elsewhere). Decode (Sq == 1) always uses the jnp path — it is a GEMV, not a
kernel-worthy workload, and GSPMD handles cache-sequence sharding there.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels import ops
from .common import ParamDef, apply_rope, rope_freqs


def attn_defs(cfg, layers_axis: str = "layers"):
    d, H, KH = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    return {
        "wq": ParamDef((d, H, hd), ("embed", "heads", "head_dim")),
        "wk": ParamDef((d, KH, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamDef((d, KH, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamDef((H, hd, d), ("heads", "head_dim", "embed_out")),
    }


def attention(p, x, cfg, *, positions, cache=None, causal=True,
              kv_x=None):
    """Returns (out (B,S,d), new_cache).

    cache: dict(k, v (B, S_max, KH, hd), index scalar) for autoregressive
    decode. kv_x: cross-attention source (encoder states) — no cache, no rope.
    """
    B, S, d = x.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    src = x if kv_x is None else kv_x
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"])

    if cfg.rope != "none" and kv_x is None:
        frac = 0.5 if cfg.rope == "half" else 1.0
        cos, sin, rot = rope_freqs(hd, positions, cfg.rope_theta, frac)
        q = apply_rope(q, cos, sin, rot)
        k = apply_rope(k, cos, sin, rot)

    new_cache = None
    if cache is not None:
        idx = cache["index"]
        z = jnp.zeros((), idx.dtype)
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (z, idx, z, z))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (z, idx, z, z))
        new_cache = {"k": ck, "v": cv, "index": idx + S}
        if S == 1:
            # decode: attend over the whole (masked) cache
            k, v = ck, cv
        # prefill (S > 1, idx == 0): attend over the freshly computed k/v —
        # the padded cache tail would break right-aligned causal masking

    qt = jnp.transpose(q, (0, 2, 1, 3))
    kt = jnp.transpose(k, (0, 2, 1, 3))
    vt = jnp.transpose(v, (0, 2, 1, 3))

    window = cfg.window or None
    if cache is not None and S == 1:
        out = _decode_attention(qt, kt, vt, cache["index"], window)
    else:
        out = ops.flash_attention(
            qt, kt, vt, causal=causal and kv_x is None, window=window,
            use_pallas=cfg.use_pallas)
    out = jnp.transpose(out, (0, 2, 1, 3))          # (B, S, H, hd)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), new_cache


def _decode_attention(q, k, v, valid_len, window):
    """Single-token decode over a (possibly sequence-sharded) cache.

    q (B,H,1,hd); k/v (B,KH,Smax,hd). Masks positions >= valid_len+1 (the new
    token was just written at `valid_len`). GSPMD turns the reductions over a
    sharded S axis into partial-softmax collectives automatically.
    """
    B, H, _, hd = q.shape
    KH, S = k.shape[1], k.shape[2]
    g = H // KH
    qg = q.reshape(B, KH, g, hd).astype(jnp.float32)
    scores = jnp.einsum("bkgd,bksd->bkgs", qg,
                        k.astype(jnp.float32)) / (hd ** 0.5)
    pos = jnp.arange(S)
    mask = pos[None, None, None, :] <= valid_len
    if window:
        mask = mask & (pos[None, None, None, :] > valid_len - window)
    scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bksd->bkgd", w, v.astype(jnp.float32))
    return out.reshape(B, H, 1, hd).astype(q.dtype)


def init_cache(cfg, batch: int, max_len: int, dtype, kv_heads=None, hd=None):
    KH = kv_heads or cfg.num_kv_heads
    hd = hd or cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, max_len, KH, hd), dtype),
        "v": jnp.zeros((batch, max_len, KH, hd), dtype),
        "index": jnp.zeros((), jnp.int32),
    }
