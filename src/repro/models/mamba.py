"""Mamba (S6) block for the Jamba hybrid architecture.

TPU adaptation (DESIGN.md §2/§5): the selective scan is computed chunkwise —
`lax.scan` over chunks of `mamba_chunk` tokens, `associative_scan` within a
chunk — so the hidden-state tensor (B, chunk, d_inner, d_state) stays a small
VMEM-friendly transient. Channels (d_inner) are independent given diagonal A,
so d_inner shards over the `model` axis with zero cross-shard traffic: this is
the "recurrent-scan sharding" the assignment calls out.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ParamDef


def mamba_defs(cfg):
    d = cfg.d_model
    di = cfg.d_inner_mamba
    ds = cfg.mamba_d_state
    dc = cfg.mamba_d_conv
    dt_rank = max(d // 16, 1)
    return {
        "in_proj": ParamDef((d, 2 * di), ("embed", "mamba_inner2")),
        "conv_w": ParamDef((dc, di), ("conv_k", "mamba_inner")),
        "conv_b": ParamDef((di,), ("mamba_inner",), "zeros"),
        "x_proj": ParamDef((di, dt_rank + 2 * ds), ("mamba_inner", "mamba_low")),
        "dt_proj": ParamDef((dt_rank, di), ("mamba_low_r", "mamba_inner")),
        "dt_bias": ParamDef((di,), ("mamba_inner",), "zeros"),
        "A_log": ParamDef((di, ds), ("mamba_inner", "mamba_state"), "small_normal"),
        "D": ParamDef((di,), ("mamba_inner",), "ones"),
        "out_proj": ParamDef((di, d), ("mamba_inner", "embed_out")),
    }


def _ssm_chunk(u, dt, B_in, C_in, A, h0):
    """Selective scan over one chunk. u,dt (B,L,di); B_in,C_in (B,L,ds);
    A (di,ds); h0 (B,di,ds). Returns (y (B,L,di), hT)."""
    dA = jnp.exp(dt[..., None] * A[None, None])                 # (B,L,di,ds)
    dBu = dt[..., None] * B_in[:, :, None, :] * u[..., None]    # (B,L,di,ds)

    def combine(a, b):
        a1, b1 = a
        a2, b2 = b
        return a1 * a2, b1 * a2 + b2

    aA, hB = jax.lax.associative_scan(combine, (dA, dBu), axis=1)
    h = aA * h0[:, None] + hB                                   # (B,L,di,ds)
    y = jnp.einsum("blds,bls->bld", h, C_in)
    return y, h[:, -1]


def mamba_layer(p, x, cfg, *, state=None):
    """x (B, S, d). state (decode): dict(conv (B, dc-1, di), h (B, di, ds)).

    Returns (out, new_state)."""
    B, S, d = x.shape
    di, ds, dc = cfg.d_inner_mamba, cfg.mamba_d_state, cfg.mamba_d_conv
    dt_rank = p["dt_proj"].shape[0]

    xz = x @ p["in_proj"]
    u, z = jnp.split(xz, 2, axis=-1)                            # (B,S,di)

    # depthwise causal conv1d
    if state is not None:
        conv_in = jnp.concatenate([state["conv"], u], axis=1)   # (B, dc-1+S, di)
        new_conv = conv_in[:, -(dc - 1):]
    else:
        conv_in = jnp.pad(u, ((0, 0), (dc - 1, 0), (0, 0)))
        new_conv = conv_in[:, -(dc - 1):]
    uc = sum(conv_in[:, i:i + S] * p["conv_w"][i][None, None]
             for i in range(dc)) + p["conv_b"]
    uc = jax.nn.silu(uc)

    proj = uc @ p["x_proj"]                                     # (B,S,dtr+2ds)
    dt_low = proj[..., :dt_rank]
    B_in = proj[..., dt_rank:dt_rank + ds]
    C_in = proj[..., dt_rank + ds:]
    dt = jax.nn.softplus(dt_low @ p["dt_proj"] + p["dt_bias"])  # (B,S,di)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                # (di,ds)

    h0 = state["h"] if state is not None else jnp.zeros((B, di, ds), jnp.float32)

    if S == 1:                                                   # decode step
        dA = jnp.exp(dt[..., None] * A[None, None])[:, 0]
        dBu = (dt[..., None] * B_in[:, :, None, :] * uc[..., None])[:, 0]
        h = dA * h0 + dBu
        y = jnp.einsum("bds,bs->bd", h, C_in[:, 0])[:, None]
        hT = h
    else:
        L = cfg.mamba_chunk
        nchunks = max(S // L, 1)
        if S % L:
            nchunks, L = 1, S

        def body(h, args):
            uc_c, dt_c, B_c, C_c = args
            y_c, hT = _ssm_chunk(uc_c.astype(jnp.float32),
                                 dt_c.astype(jnp.float32),
                                 B_c.astype(jnp.float32),
                                 C_c.astype(jnp.float32), A, h)
            return hT, y_c

        resh = lambda t: jnp.moveaxis(
            t.reshape(B, nchunks, L, t.shape[-1]), 1, 0)
        hT, ys = jax.lax.scan(body, h0,
                              (resh(uc), resh(dt), resh(B_in), resh(C_in)))
        y = jnp.moveaxis(ys, 0, 1).reshape(B, S, di)

    y = (y + uc.astype(jnp.float32) * p["D"]).astype(x.dtype)
    out = (y * jax.nn.silu(z)) @ p["out_proj"]
    new_state = {"conv": new_conv, "h": hT}
    return out, new_state


def init_mamba_state(cfg, batch: int, dtype):
    return {
        "conv": jnp.zeros((batch, cfg.mamba_d_conv - 1, cfg.d_inner_mamba), dtype),
        "h": jnp.zeros((batch, cfg.d_inner_mamba, cfg.mamba_d_state),
                       jnp.float32),
    }
