"""Shared model components: parameter definition DSL (shapes + logical axes),
norms, RoPE, embeddings, MLPs.

Every parameter is declared as ParamDef(shape, logical_axes, init); logical
axes are strings ('embed', 'heads', 'kv_heads', 'head_dim', 'ffn', 'experts',
'vocab', 'layers', ...) that launch/sharding.py maps to mesh axes with
divisibility fallbacks. This keeps model code mesh-agnostic.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class ParamDef:
    shape: tuple
    axes: tuple            # logical axis names, len == len(shape)
    init: str = "normal"   # normal | zeros | ones | small_normal
    scale_axis: int = 0    # fan-in axis for normal init


def init_param(key, d: ParamDef, dtype):
    if d.init == "zeros":
        return jnp.zeros(d.shape, dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, dtype)
    fan_in = d.shape[d.scale_axis] if d.shape else 1
    scale = 0.02 if d.init == "small_normal" else (1.0 / max(fan_in, 1)) ** 0.5
    return (scale * jax.random.normal(key, d.shape, jnp.float32)).astype(dtype)


def init_tree(key, defs, dtype):
    """Materialize a pytree of ParamDef into arrays (deterministic key split)."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(key, len(leaves))
    return treedef.unflatten([init_param(k, d, dtype) for k, d in zip(keys, leaves)])


def axes_tree(defs):
    """Extract the logical-axes pytree (same structure, tuples at leaves)."""
    return jax.tree.map(lambda d: d.axes, defs,
                        is_leaf=lambda x: isinstance(x, ParamDef))


def shapes_tree(defs, dtype):
    return jax.tree.map(lambda d: jax.ShapeDtypeStruct(d.shape, dtype), defs,
                        is_leaf=lambda x: isinstance(x, ParamDef))


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------

def rmsnorm(x, w, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    x32 = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (x32 * w.astype(jnp.float32)).astype(dt)


def rope_freqs(head_dim: int, positions, theta: float = 10_000.0,
               fraction: float = 1.0):
    """cos/sin tables. fraction=0.5 -> rotary on half the dims (chatglm 2d)."""
    rot = int(head_dim * fraction)
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    ang = positions.astype(jnp.float32)[..., None] * inv  # (..., rot/2)
    return jnp.cos(ang), jnp.sin(ang), rot


def apply_rope(x, cos, sin, rot: int):
    """x (..., S, H, hd); cos/sin (..., S, rot/2) broadcast over heads."""
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    c = cos[..., None, :]
    s = sin[..., None, :]
    o1 = x1 * c - x2 * s
    o2 = x2 * c + x1 * s
    out = jnp.stack([o1, o2], axis=-1).reshape(xr.shape)
    out = jnp.concatenate([out, xp], axis=-1) if rot < x.shape[-1] else out
    return out.astype(x.dtype)


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def swiglu(x, wg, wu, wd):
    h = jax.nn.silu(x @ wg) * (x @ wu)
    return h @ wd


def gelu_mlp(x, w1, w2):
    return gelu(x @ w1) @ w2


def cross_entropy(logits, labels, mask=None):
    """Mean token CE in f32; labels < 0 are ignored."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None],
                             axis=-1)[..., 0]
    valid = labels >= 0
    if mask is not None:
        valid = valid & (mask > 0)
    nll = (lse - ll) * valid
    return jnp.sum(nll) / jnp.maximum(jnp.sum(valid), 1)
