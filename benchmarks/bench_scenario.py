"""Closed-loop scenario benchmark: accuracy-over-time + serving health.

Runs one `repro.scenario` mission (default: the "chaos" preset — dropout/
rejoin, degraded-consensus edge loss, stragglers, injected failures) and
lands its full `ScenarioResult` in BENCH_scenario.json:

  scenario.curves      RMSE / NLL vs the noiseless latent field,
                       fleet-size and degraded-batch-fraction over steps
  scenario.drift       eval NLL after each ADMM drift-retrain epoch
  scenario.serving     submitted/completed/dropped/failed + p50/p99
  scenario.invariants  hung futures, recompile steps, membership
                       timeline, replay digest

The artifact is schema-checked (`repro.scenario.validate_bench`) before it
is written — the CI smoke job re-checks it and asserts zero hung futures.

  PYTHONPATH=src python -m benchmarks.bench_scenario [--scenario NAME]
  PYTHONPATH=src python -m benchmarks.run --only scenario [--smoke]
"""
from __future__ import annotations

import json

from repro.scenario import ScenarioConfig, preset, run_scenario, \
    validate_bench

from .envtags import bench_tags, merge_json


def run(csv=print, *, smoke: bool = False, scenario: str | None = None,
        json_path: str = "BENCH_scenario.json"):
    """Run one mission and write the scenario section of `json_path`.

    `scenario` is a preset name (repro.scenario.preset) or a path to a
    ScenarioConfig JSON file; `smoke` forces the seconds-scale "smoke"
    preset unless a scenario was named explicitly.
    """
    if scenario is None:
        scenario = "smoke" if smoke else "chaos"
    if scenario.endswith(".json"):
        with open(scenario) as fh:
            cfg = ScenarioConfig.from_json(fh.read())
    else:
        cfg = preset(scenario)
    csv(f"# scenario={scenario} agents={cfg.num_agents} graph={cfg.graph} "
        f"steps={cfg.steps} seed={cfg.seed}")
    result = run_scenario(cfg, csv=csv)
    out = result.to_bench()
    out.update(bench_tags("scheduler"))
    validate_bench({"scenario": out})
    merge_json(json_path, {"scenario": out})
    csv(f"# wrote {json_path} (scenario section): "
        f"rmse {out['curves']['rmse'][0]:.3f}->{out['curves']['rmse'][-1]:.3f}"
        f", hung_futures={out['invariants']['hung_futures']}, "
        f"recompile_steps={out['invariants']['recompile_steps']}")
    return out


if __name__ == "__main__":
    import argparse

    import jax

    jax.config.update("jax_enable_x64", True)
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scenario", default=None,
                    help="preset name (smoke|mission|chaos) or a "
                         "ScenarioConfig JSON path (default: chaos)")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--json", default="BENCH_scenario.json")
    args = ap.parse_args()
    run(smoke=args.smoke, scenario=args.scenario, json_path=args.json)
