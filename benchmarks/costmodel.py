"""Analytic per-(arch x shape) cost model for the roofline analysis.

WHY ANALYTIC: XLA's HloCostAnalysis counts every while-loop body ONCE
(verified experimentally — see tests/test_costmodel.py), so the compiled
cost_analysis of a scan-over-layers model under-reports FLOPs/bytes by ~L.
We therefore derive the three roofline terms analytically from the exact
model structure and CROSS-VALIDATE against compiled cost_analysis on reduced
configs with fully unrolled scans (agreement asserted in tests).

All quantities are PER-DEVICE per step on the single-pod mesh (256 chips,
data=16 x model=16) unless stated. Hardware: TPU v5e-class —
197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

def xla_cost_analysis(compiled) -> dict:
    """Program-level cost dict from a compiled XLA executable.

    Recent JAX returns a list with one dict per HLO module from
    `Compiled.cost_analysis()`; older versions return the dict directly.
    Normalize to the (first) module's dict so callers survive the drift.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


def compiled_flops(compiled) -> float:
    """FLOPs XLA attributes to a compiled executable (see xla_cost_analysis)."""
    return float(xla_cost_analysis(compiled).get("flops", 0.0))


MESH = {"single": dict(chips=256, data=16, model=16, pod=1),
        "multi": dict(chips=512, data=16, model=16, pod=2),
        # §Perf alternatives (same 256 chips, different logical aspect)
        "single_32x8": dict(chips=256, data=32, model=8, pod=1),
        "single_64x4": dict(chips=256, data=64, model=4, pod=1),
        "single_dp": dict(chips=256, data=256, model=1, pod=1)}


# ---------------------------------------------------------------------------
# parameter counts
# ---------------------------------------------------------------------------

def param_counts(cfg):
    """(total_params, active_params_per_token)."""
    d, V = cfg.d_model, cfg.vocab_size
    hd = cfg.resolved_head_dim
    H, KH = cfg.num_heads, cfg.num_kv_heads
    attn = d * (H + 2 * KH) * hd + H * hd * d
    mlp = 3 * d * cfg.d_ff if cfg.mlp_act == "swiglu" else 2 * d * cfg.d_ff
    total = V * d + (0 if cfg.tie_embeddings else d * V) + d  # embeds + norm

    if cfg.block_type == "transformer":
        k = cfg.moe_every if cfg.num_experts else 1
        n_moe = cfg.num_layers // k if cfg.num_experts else 0
        n_dense = cfg.num_layers - n_moe
        moe = cfg.num_experts * 3 * d * cfg.d_ff + d * cfg.num_experts
        total += n_dense * (attn + mlp) + n_moe * (attn + moe)
        active = n_dense * (attn + mlp) + n_moe * (
            attn + cfg.experts_per_token * 3 * d * cfg.d_ff)
    elif cfg.block_type == "jamba":
        di, ds = cfg.d_inner_mamba, cfg.mamba_d_state
        dtr = max(d // 16, 1)
        mamba = (d * 2 * di + cfg.mamba_d_conv * di + di * (dtr + 2 * ds)
                 + dtr * di + di * ds + di + di * d)
        n_groups = cfg.num_layers // cfg.attention_every
        n_mamba = cfg.num_layers - n_groups
        n_moe = (cfg.attention_every // 2) * n_groups if cfg.num_experts else 0
        moe = cfg.num_experts * 3 * d * cfg.d_ff + d * cfg.num_experts
        total += n_groups * (attn + mlp) + n_mamba * mamba + n_moe * moe
        active = n_groups * (attn + mlp) + n_mamba * mamba + n_moe * (
            cfg.experts_per_token * 3 * d * cfg.d_ff)
    elif cfg.block_type == "xlstm":
        mlstm = d * (3 * H * hd + 2 * H + H * hd) + H * hd * d + H * hd
        slstm = 4 * d * H * hd + 3 * H * hd * hd + H * hd * d + mlp
        n_s = cfg.num_layers // cfg.slstm_every
        n_m = cfg.num_layers - n_s
        total += n_m * mlstm + n_s * slstm
        active = n_m * mlstm + n_s * slstm
    else:
        raise ValueError(cfg.block_type)

    if cfg.encdec:
        total += cfg.enc_layers * (attn + mlp) + cfg.enc_seq * d \
            + cfg.max_seq * d + cfg.num_layers * (attn + mlp)  # cross attn
        active = total - V * d - d * V
    return int(total), int(active)


# ---------------------------------------------------------------------------
# forward FLOPs per token
# ---------------------------------------------------------------------------

def _attn_flops_tok(cfg, ctx: int):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, KH = cfg.num_heads, cfg.num_kv_heads
    proj = 2 * d * (H + 2 * KH) * hd + 2 * H * hd * d
    attn = 4 * ctx * H * hd
    return proj + attn


def _mlp_flops_tok(cfg):
    return (6 if cfg.mlp_act == "swiglu" else 4) * cfg.d_model * cfg.d_ff


def _moe_flops_tok(cfg):
    d, f = cfg.d_model, cfg.d_ff
    k, E, cf = cfg.experts_per_token, cfg.num_experts, cfg.moe_capacity_factor
    return 2 * d * E + k * cf * 6 * d * f + 4 * k * cf * d


def _mamba_flops_tok(cfg):
    d, di, ds = cfg.d_model, cfg.d_inner_mamba, cfg.mamba_d_state
    dtr = max(d // 16, 1)
    return (2 * d * 2 * di + 2 * cfg.mamba_d_conv * di
            + 2 * di * (dtr + 2 * ds) + 2 * dtr * di
            + 10 * di * ds + 2 * di * d)


def _mlstm_flops_tok(cfg, chunk: int):
    d, hd, H = cfg.d_model, cfg.resolved_head_dim, cfg.num_heads
    proj = 2 * d * 4 * H * hd + 2 * H * hd * d
    intra = 4 * chunk * H * hd
    inter = 6 * H * hd * hd
    return proj + intra + inter


def _slstm_flops_tok(cfg):
    d, hd, H = cfg.d_model, cfg.resolved_head_dim, cfg.num_heads
    return 2 * d * 4 * H * hd + 6 * H * hd * hd + 2 * H * hd * d \
        + _mlp_flops_tok(cfg)


def fwd_flops_per_token(cfg, ctx: int, decode: bool = False):
    """Forward FLOPs for ONE token with attended context `ctx`."""
    d, V = cfg.d_model, cfg.vocab_size
    head = 2 * d * V
    eff_ctx = min(ctx, cfg.window) if cfg.window else ctx

    if cfg.block_type == "transformer":
        k = cfg.moe_every if cfg.num_experts else 1
        n_moe = cfg.num_layers // k if cfg.num_experts else 0
        n_dense = cfg.num_layers - n_moe
        per_attn = _attn_flops_tok(cfg, eff_ctx)
        fl = n_dense * (per_attn + _mlp_flops_tok(cfg)) \
            + n_moe * (per_attn + _moe_flops_tok(cfg))
    elif cfg.block_type == "jamba":
        n_groups = cfg.num_layers // cfg.attention_every
        n_mamba = cfg.num_layers - n_groups
        n_moe = (cfg.attention_every // 2) * n_groups if cfg.num_experts else 0
        n_md = n_mamba - n_moe
        fl = n_groups * (_attn_flops_tok(cfg, ctx) + _mlp_flops_tok(cfg)) \
            + n_mamba * _mamba_flops_tok(cfg) + n_moe * _moe_flops_tok(cfg)
    elif cfg.block_type == "xlstm":
        n_s = cfg.num_layers // cfg.slstm_every
        n_m = cfg.num_layers - n_s
        chunk = 1 if decode else min(cfg.xlstm_chunk, ctx)
        fl = n_m * _mlstm_flops_tok(cfg, chunk) + n_s * _slstm_flops_tok(cfg)
    else:
        raise ValueError(cfg.block_type)

    if cfg.encdec:
        # decoder cross-attention to enc_seq states
        fl += cfg.num_layers * (2 * d * 3 * cfg.num_heads
                                * cfg.resolved_head_dim
                                + 4 * cfg.enc_seq * cfg.num_heads
                                * cfg.resolved_head_dim)
    return fl + head


def encoder_flops(cfg, enc_tokens: int):
    if not cfg.encdec:
        return 0
    per_tok = _attn_flops_tok(cfg, cfg.enc_seq) + _mlp_flops_tok(cfg)
    return cfg.enc_layers * per_tok * enc_tokens


# ---------------------------------------------------------------------------
# the three roofline terms
# ---------------------------------------------------------------------------

@dataclass
class Roofline:
    flops: float               # per device
    hbm_bytes: float           # per device
    coll_bytes: float          # per device (through ICI)
    model_flops: float         # 6*N_active*D global (useful flops)

    def terms(self):
        return {
            "compute_s": self.flops / PEAK_FLOPS,
            "memory_s": self.hbm_bytes / HBM_BW,
            "collective_s": self.coll_bytes / ICI_BW,
        }

    @property
    def dominant(self):
        t = self.terms()
        return max(t, key=t.get)


def analyze(cfg, shape_name: str, mesh: str = "single",
            microbatch: int = 1) -> Roofline:
    from repro.launch.steps import SHAPES, cfg_for_shape
    cfg = cfg_for_shape(cfg, shape_name)
    info = SHAPES[shape_name]
    m = MESH[mesh]
    chips, dsh, msh = m["chips"], m["data"] * m["pod"], m["model"]
    B, S = info["batch"], info["seq"]
    kind = info["kind"]
    total_p, active_p = param_counts(cfg)
    p_local = total_p / chips                       # fully sharded (FSDP+TP)

    if kind == "train":
        tokens = B * S
        tokens_loc = tokens / dsh
        avg_ctx = S / 2 if not cfg.window else min(cfg.window, S)
        fwd = fwd_flops_per_token(cfg, int(avg_ctx)) * tokens \
            + encoder_flops(cfg, B * cfg.enc_seq)
        factor = 4.0 if cfg.remat else 3.0          # fwd + 2x bwd (+ remat)
        flops = fwd * factor / chips
        model_flops = 6 * active_p * tokens

        # HBM: weight reads fwd+bwd (bf16) + grad (f32) + adam m/v r+w (f32)
        w_traffic = p_local * 2 * (2 + 1) + p_local * 4 * 5
        resid = 2 * tokens_loc * cfg.d_model * 2 * cfg.num_layers  # save+read
        logits = 3 * tokens_loc * cfg.vocab_size / msh * 4
        act = 8 * tokens_loc * cfg.d_model * 2 * cfg.num_layers / microbatch
        hbm = w_traffic + resid + logits + act

        # ICI: FSDP weight all-gather (fwd+bwd) + grad reduce-scatter over the
        # data axis + 2 TP psums per layer (fwd+bwd -> x3)
        fsdp = 3 * (total_p / msh) * 2 * (dsh - 1) / dsh
        tp = 3 * 2 * cfg.num_layers * tokens_loc * cfg.d_model * 2 \
            * 2 * (msh - 1) / msh
        coll = fsdp + tp
    elif kind == "prefill":
        tokens = B * S
        tokens_loc = tokens / dsh
        avg_ctx = S / 2 if not cfg.window else min(cfg.window, S)
        flops = (fwd_flops_per_token(cfg, int(avg_ctx)) * tokens
                 + encoder_flops(cfg, B * cfg.enc_seq)) / chips
        model_flops = 2 * active_p * tokens
        w_traffic = p_local * 2
        act = 6 * tokens_loc * cfg.d_model * 2 * cfg.num_layers
        cache_w = 2 * tokens_loc * cfg.num_kv_heads \
            * cfg.resolved_head_dim * 2 * cfg.num_layers
        hbm = w_traffic + act + cache_w
        fsdp = (total_p / msh) * 2 * (dsh - 1) / dsh
        tp = 2 * cfg.num_layers * tokens_loc * cfg.d_model * 2 \
            * 2 * (msh - 1) / msh
        coll = fsdp + tp
    else:  # decode
        tokens = B
        flops = fwd_flops_per_token(cfg, S, decode=True) * tokens / chips
        model_flops = 2 * active_p * tokens
        # cache per device (sequence- and/or batch-sharded; see sharding.py)
        n_attn = (cfg.num_layers if cfg.block_type == "transformer"
                  else cfg.num_layers // cfg.attention_every
                  if cfg.block_type == "jamba" else 0)
        eff_S = min(S, cfg.window) if cfg.window else S
        cache_global = (2 * B * S * cfg.num_kv_heads * cfg.resolved_head_dim
                        * 2 * n_attn)
        cache_read = (2 * B * eff_S * cfg.num_kv_heads
                      * cfg.resolved_head_dim * 2 * n_attn) / chips
        hbm = p_local * 2 + cache_read
        if cfg.encdec:
            hbm += 2 * B * cfg.enc_seq * cfg.d_model * 2 / chips
        # decode collectives: per-layer TP psum of (B, d) + softmax partials
        tp = 2 * cfg.num_layers * B * cfg.d_model * 2 * 2 * (msh - 1) / msh \
            / dsh
        soft = n_attn * B * cfg.num_heads * cfg.resolved_head_dim * 4 * 2
        coll = tp + soft
    return Roofline(flops=flops, hbm_bytes=hbm, coll_bytes=coll,
                    model_flops=model_flops)
