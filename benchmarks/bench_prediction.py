"""Paper Fig. 11-15 + Tables 7-8: decentralized GP prediction RMSE/NLPD on
the SST-like field, all 13 methods, fleet sweep, CBNN agent reduction.

`run_serving` additionally benchmarks the factor-cached, query-tiled
PredictionEngine against the per-call path: repeated-query serving
throughput (cached vs uncached) and a large-Nt sweep that the all-at-once
(Nt, M, M) NPAE materialization could not complete under bounded memory.

`run_sharded` benchmarks agent-sharded serving (core.prediction.sharded):
replicated vs sharded fleet throughput in the micro-batch latency regime,
and CBNN query routing vs full-fleet consensus in the large-batch
throughput regime, at tight eta_nn. Run it under
XLA_FLAGS=--xla_force_host_platform_device_count=8 on CPU (or on a real
multi-device platform); results land in BENCH_serving.json.

`run_sparse` benchmarks the sparse pseudo-representation experts
(core.sparse): the accuracy-vs-m sweep (RMSE/NLPD and dense-vs-sparse
serving speedup as the per-agent inducing count m shrinks, headline
m = Ni/16) and the 100k-points-per-agent run the dense O(Ni^2) factor
path cannot hold in memory. Results land under BENCH_serving.json's
"sparse" key.

`run_scheduler` benchmarks the request-level serving scheduler
(launch.scheduler): p50/p99 latency vs offered load under the open-loop
Poisson generator (benchmarks/loadgen.py), continuous slot batching vs
the v1 fixed-batch front-door geometry, for 1 and 2 resident tenants.
The saturation curves and the sustainable-QPS comparison merge into
BENCH_serving.json under the "scheduler" key."""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gp import (pack, stripe_partition, communication_dataset,
                           augment)
from repro.core.consensus import path_graph, complete_graph
from repro.core.prediction import (local_moments, npae_terms, poe, gpoe, bcm,
                                   rbcm, grbcm, npae, dec_poe, dec_gpoe,
                                   dec_bcm, dec_rbcm, dec_grbcm, dec_npae,
                                   dec_npae_star, dec_nn_poe, dec_nn_gpoe,
                                   dec_nn_bcm, dec_nn_rbcm, dec_nn_grbcm,
                                   dec_nn_npae, fit_experts, PredictionEngine,
                                   ShardedEngine)
from repro.core.training import train_dec_gapx_gp
from repro.data import grid_inputs, sst_like_field, random_inputs


def nlpd(mean, var, y):
    return float(jnp.mean(0.5 * jnp.log(2 * jnp.pi * var)
                          + 0.5 * (y - mean) ** 2 / var))


def rmse(mean, y):
    return float(jnp.sqrt(jnp.mean((mean - y) ** 2)))


def run(n_obs=2000, n_test=100, fleets=(4, 10), reps=2, eta_nn=0.1,
        csv=print):
    csv("table,method,M,rep,rmse,nlpd,time_per_agent_s,mean_nn_agents")
    side = int(np.sqrt(n_obs * 2))
    Xall = grid_inputs(side, 0.0, 1.0)
    for rep in range(reps):
        key = jax.random.PRNGKey(100 + rep)
        f_true, y_all = sst_like_field(Xall, key=key)
        idx = jax.random.permutation(key, Xall.shape[0])
        tr, te = idx[:n_obs], idx[n_obs:n_obs + n_test]
        X, y = Xall[tr], y_all[tr]
        Xs, ys = Xall[te], f_true[te]
        for M in fleets:
            Xp, yp = stripe_partition(X, y, M)
            A, Ac = path_graph(M), complete_graph(M)
            Xc, yc = communication_dataset(jax.random.fold_in(key, 3), Xp, yp)
            Xa, ya = augment(Xp, yp, Xc, yc)
            # train with the paper's best decentralized method (§6.2 setup)
            lt0 = pack([0.5, 0.5], 1.0, 0.5)
            thetas, _ = train_dec_gapx_gp(lt0, Xa, ya, A, iters=60)
            lt = jnp.mean(thetas, axis=0)
            prior_var = float(jnp.exp(lt)[-2]) ** 2

            mu, var = local_moments(lt, Xp, yp, Xs)
            mu_a, var_a = local_moments(lt, Xa, ya, Xs)
            mu_c, var_c = local_moments(lt, Xc[None], yc[None], Xs)
            mu_n, kA, CA = npae_terms(lt, Xp, yp, Xs)

            def rec(table, name, fn, nn=""):
                t0 = time.time()
                out = fn()
                m, v = out[0], out[1]
                dt = (time.time() - t0) / M
                csv(f"{table},{name},{M},{rep},{rmse(m, ys):.4f},"
                    f"{nlpd(m, v, ys):.4f},{dt:.4f},{nn}")
                return out

            # centralized references (optimal values per paper)
            rec("fig11", "PoE", lambda: poe(mu, var))
            rec("fig11", "gPoE", lambda: gpoe(mu, var))
            rec("fig12", "BCM", lambda: bcm(mu, var, prior_var))
            rec("fig12", "rBCM", lambda: rbcm(mu, var, prior_var))
            rec("fig12", "grBCM",
                lambda: grbcm(mu_a, var_a, mu_c[0], var_c[0]))
            rec("fig13", "NPAE", lambda: npae(mu_n, kA, CA, prior_var))
            # decentralized (path graph unless noted)
            rec("fig11", "DEC-PoE", lambda: dec_poe(lt, Xp, yp, Xs, A))
            rec("fig11", "DEC-gPoE", lambda: dec_gpoe(lt, Xp, yp, Xs, A))
            rec("fig12", "DEC-BCM", lambda: dec_bcm(lt, Xp, yp, Xs, A))
            rec("fig12", "DEC-rBCM", lambda: dec_rbcm(lt, Xp, yp, Xs, A))
            rec("fig12", "DEC-grBCM",
                lambda: dec_grbcm(lt, Xa, ya, Xc, yc, Xs, A))
            rec("fig13", "DEC-NPAE",
                lambda: dec_npae(lt, Xp, yp, Xs, Ac, jor_iters=2500))
            rec("fig13", "DEC-NPAE*",
                lambda: dec_npae_star(lt, Xp, yp, Xs, Ac, jor_iters=2500))
            # CBNN nearest-neighbor family (Table 7)
            for name, fn in [
                ("DEC-NN-PoE", lambda: dec_nn_poe(lt, Xp, yp, Xs, A, eta_nn)),
                ("DEC-NN-gPoE", lambda: dec_nn_gpoe(lt, Xp, yp, Xs, A, eta_nn)),
                ("DEC-NN-BCM", lambda: dec_nn_bcm(lt, Xp, yp, Xs, A, eta_nn)),
                ("DEC-NN-rBCM", lambda: dec_nn_rbcm(lt, Xp, yp, Xs, A, eta_nn)),
                ("DEC-NN-grBCM", lambda: dec_nn_grbcm(
                    lt, Xa, ya, Xc, yc, Xs, A, eta_nn, Xp=Xp)),
                ("DEC-NN-NPAE", lambda: dec_nn_npae(
                    lt, Xp, yp, Xs, A, eta_nn, dale_iters=1500)),
            ]:
                t0 = time.time()
                m, v, info = fn()
                dt = (time.time() - t0) / M
                nn = float(info["mask"].sum(0).mean())
                csv(f"table7,{name},{M},{rep},{rmse(m, ys):.4f},"
                    f"{nlpd(m, v, ys):.4f},{dt:.4f},{nn:.1f}")


# ---------------------------------------------------------------------------
# Serving: factor-cached + query-tiled engine vs the per-call path
# ---------------------------------------------------------------------------

def _time(fn, *args, reps=1):
    jax.block_until_ready(fn(*args))           # warmup / compile
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps


def _time_best(fn, *args, reps=1, trials=3):
    """Min over `trials` timing blocks — the standard noise-robust estimate
    on shared machines (the minimum is the least-contended run)."""
    return min(_time(fn, *args, reps=reps) for _ in range(trials))


def run_serving(n_obs=8192, M=32, n_queries=4096, batch=256, chunk=256,
                dac_iters=100, jor_iters=200, reps=3, csv=print):
    """Cached-vs-uncached serving throughput + large-Nt tiled sweep.

    Repeated-query serving: requests of `batch` queries each, totalling
    `n_queries`, against an M-agent fleet with Ni = n_obs / M points/agent.
      uncached-eager : the pre-engine per-call path exactly as the per-call
                       functions execute it (op-by-op dispatch, refactorizes
                       every agent per request) — the serving status quo.
      uncached-jit   : the same per-call path under one jit (still
                       refactorizes per request).
      cached-engine  : PredictionEngine — factors computed once, query-tiled,
                       jit-cached across requests.
    The large-Nt sweep pushes all `n_queries` through the engine in ONE call:
    peak NPAE covariance memory is (chunk, M, M) instead of (Nt, M, M).
    """
    csv("table,method,M,Ni,batch,qps_eager,qps_jit,qps_cached,"
        "speedup_vs_eager,speedup_vs_jit")
    lt = pack([1.2, 0.3], 1.3, 0.1)
    key = jax.random.PRNGKey(0)
    X = random_inputs(key, n_obs)
    _, y = sst_like_field(X / jnp.max(X), key=jax.random.PRNGKey(1))
    Xp, yp = stripe_partition(X, y, M)
    A, Ac = path_graph(M), complete_graph(M)
    fitted = jax.jit(fit_experts)(lt, Xp, yp)
    eng = PredictionEngine(fitted, A, chunk=chunk, dac_iters=dac_iters,
                           jor_iters=jor_iters)
    eng_c = PredictionEngine(fitted, Ac, chunk=chunk, dac_iters=dac_iters,
                             jor_iters=jor_iters)
    Ni = Xp.shape[1]
    Xq = random_inputs(jax.random.PRNGKey(2), batch)

    legacy = {
        "poe": lambda q: dec_poe(lt, Xp, yp, q, A, iters=dac_iters)[:2],
        "rbcm": lambda q: dec_rbcm(lt, Xp, yp, q, A, iters=dac_iters)[:2],
        "npae": lambda q: dec_npae(lt, Xp, yp, q, Ac, jor_iters=jor_iters,
                                   dac_iters=dac_iters)[:2],
    }
    for name, leg in legacy.items():
        e = eng_c if name == "npae" else eng
        t_eager = _time(leg, Xq)                       # eager per-call path
        t_jit = _time(jax.jit(leg), Xq, reps=reps)
        t_cached = _time(lambda q: e.predict(name, q)[:2], Xq, reps=reps)
        qps = [batch / t for t in (t_eager, t_jit, t_cached)]
        csv(f"serving,{name},{M},{Ni},{batch},{qps[0]:.0f},{qps[1]:.0f},"
            f"{qps[2]:.0f},{t_eager/t_cached:.2f},{t_jit/t_cached:.2f}")

    # large-Nt sweep: one call, Nt queries, tiled to `chunk`
    csv("table,method,M,Ni,Nt,chunk,qps,peak_CA_MB_tiled,peak_CA_MB_dense")
    Xbig = random_inputs(jax.random.PRNGKey(3), n_queries)
    itemsize = jnp.zeros((), Xbig.dtype).dtype.itemsize
    for name in ("rbcm", "npae"):
        e = eng_c if name == "npae" else eng
        t = _time(lambda q: e.predict(name, q)[:2], Xbig)
        tiled_mb = chunk * M * M * itemsize / 2**20
        dense_mb = n_queries * M * M * itemsize / 2**20
        csv(f"sweep,{name},{M},{Ni},{n_queries},{chunk},{n_queries/t:.0f},"
            f"{tiled_mb:.1f},{dense_mb:.1f}")


# ---------------------------------------------------------------------------
# Serving: agent-sharded fleet + CBNN query routing vs the replicated engine
# ---------------------------------------------------------------------------

def run_sharded(n_obs=8192, M=32, batch=256, big_batch=2048, chunk=256,
                dac_iters=100, eta_nn=1.5, reps=10, csv=print,
                json_path="BENCH_serving.json", smoke=False):
    """Agent-sharded serving throughput (ISSUE 4 acceptance numbers).

    Two regimes, both at tight eta_nn for the CBNN rows:
      micro-batch (`batch` queries/request) — the latency-oriented front-
        door shape: replicated `PredictionEngine` vs `ShardedEngine`
        full-fleet consensus on the device ring.
      large-batch (`big_batch` queries/request) — the throughput-oriented
        shape: full-fleet nn_* consensus vs `predict_routed` (each query
        served by the single shard holding its most-correlated experts —
        1/ndev of the per-agent work and zero collectives).
    Needs >= 2 devices to be meaningful; run CPU benchmarks under
    XLA_FLAGS=--xla_force_host_platform_device_count=8. `smoke=True`
    shrinks everything to a seconds-scale CI pass (artifact marked).
    """
    from repro.launch.mesh import make_agent_mesh

    if smoke:
        n_obs, M, batch, big_batch, chunk, reps = 512, 8, 64, 256, 32, 2
    ndev = len(jax.devices())
    if ndev < 2:
        csv("# run_sharded: single device — sharded timings are not "
            "meaningful; set XLA_FLAGS=--xla_force_host_platform_"
            "device_count=8 (results below measure overhead only)")
    # short lengthscales: correlation is LOCAL, the massive-fleet regime
    # where CBNN routing is meaningful (each query has a few nearby
    # relevant agents, the rest sit below eta_nn)
    lt = pack([0.15, 0.15], 1.3, 0.1)
    X = random_inputs(jax.random.PRNGKey(0), n_obs)
    _, y = sst_like_field(X / jnp.max(X), key=jax.random.PRNGKey(1))
    Xp, yp = stripe_partition(X, y, M)
    Ni = Xp.shape[1]
    fitted = jax.jit(fit_experts)(lt, Xp, yp)
    mesh = make_agent_mesh(M)
    rep = PredictionEngine(fitted, path_graph(M), chunk=chunk,
                           dac_iters=dac_iters, eta_nn=eta_nn)
    sh = ShardedEngine(fitted, mesh, chunk=chunk, dac_iters=dac_iters,
                       eta_nn=eta_nn)
    sh_exact = ShardedEngine(fitted, mesh, chunk=chunk, eta_nn=eta_nn,
                             consensus="exact")
    out = {"devices": int(mesh.shape["agents"]), "M": M, "Ni": int(Ni),
           "eta_nn": eta_nn, "dac_iters": dac_iters, "chunk": chunk,
           "smoke": bool(smoke)}

    # regime 1: micro-batch latency shape — replicated vs sharded fleet.
    # Two sharded consensus modes: the paper-faithful ring DAC iteration
    # (matches the replicated engine's protocol) and the exact finite ring
    # all-reduce (ndev - 1 hops instead of dac_iters rounds — the mode a
    # physical device ring would deploy, and the headline speedup).
    Xq = random_inputs(jax.random.PRNGKey(2), batch)
    csv("table,regime,method,M,devices,batch,qps_replicated,"
        "qps_sharded_dac,qps_sharded_exact,speedup_dac,speedup_exact")
    rows = []
    for method in ("poe", "rbcm"):
        t_rep = _time_best(lambda q: rep.predict(method, q)[:2], Xq,
                           reps=reps)
        t_dac = _time_best(lambda q: sh.predict(method, q)[:2], Xq,
                           reps=reps)
        t_ex = _time_best(lambda q: sh_exact.predict(method, q)[:2], Xq,
                          reps=reps)
        rows.append({"method": method, "batch": batch,
                     "qps_replicated": batch / t_rep,
                     "qps_sharded_dac": batch / t_dac,
                     "qps_sharded_exact": batch / t_ex,
                     "speedup_dac": t_rep / t_dac,
                     "speedup_exact": t_rep / t_ex})
        csv(f"sharded,micro,{method},{M},{out['devices']},{batch},"
            f"{batch/t_rep:.0f},{batch/t_dac:.0f},{batch/t_ex:.0f},"
            f"{t_rep/t_dac:.2f},{t_rep/t_ex:.2f}")
    out["micro_batch"] = rows

    # regime 2: large-batch throughput shape — CBNN routing at tight eta_nn
    Xb = random_inputs(jax.random.PRNGKey(3), big_batch)
    csv("table,regime,method,M,devices,batch,qps_replicated,qps_full_fleet,"
        "qps_routed,routed_speedup_vs_full,mean_participants,"
        "max_routed_deviation")
    method = "nn_rbcm"
    r3 = max(1, reps // 3)
    t_rep = _time_best(lambda q: rep.predict(method, q)[:2], Xb, reps=r3)
    t_full = _time_best(lambda q: sh.predict(method, q)[:2], Xb, reps=r3)
    t_routed = _time_best(lambda q: sh.predict_routed(method, q)[:2], Xb,
                          reps=r3)
    m_full, _, info_full = sh.predict(method, Xb)
    m_routed, _, _ = sh.predict_routed(method, Xb)
    participants = float(np.asarray(info_full["mask"]).sum(0).mean())
    dev = np.abs(np.asarray(m_full) - np.asarray(m_routed))
    # routing is exact for queries whose participant set is shard-local;
    # report how often that holds alongside the worst boundary query
    exact_frac = float(np.mean(dev < 1e-6))
    out["routing"] = {
        "method": method, "batch": big_batch,
        "qps_replicated": big_batch / t_rep,
        "qps_full_fleet": big_batch / t_full,
        "qps_routed": big_batch / t_routed,
        "routed_speedup_vs_full": t_full / t_routed,
        "routed_speedup_vs_replicated": t_rep / t_routed,
        "mean_participants": participants,
        "routed_exact_fraction": exact_frac,
        "max_routed_deviation": float(dev.max()),
        "median_routed_deviation": float(np.median(dev)),
    }
    csv(f"sharded,routing,{method},{M},{out['devices']},{big_batch},"
        f"{big_batch/t_rep:.0f},{big_batch/t_full:.0f},"
        f"{big_batch/t_routed:.0f},{t_full/t_routed:.2f},"
        f"{participants:.2f},{dev.max():.3e}")
    csv(f"# routing agreement: {100*exact_frac:.1f}% of queries exact "
        f"(<1e-6), median deviation {np.median(dev):.2e}")

    from .envtags import bench_tags, merge_json
    out.update(bench_tags("sharded"))
    # read-modify-write: run_scheduler's "scheduler" section shares this
    # artifact and must survive a sharded re-run (and vice versa)
    merge_json(json_path, out)
    csv(f"# wrote {json_path}")
    return out


# ---------------------------------------------------------------------------
# Sparse pseudo-representation experts: accuracy vs m + the 100k/agent run
# ---------------------------------------------------------------------------

def run_sparse(n_obs=32768, M=8, ms=(64, 128, 256, 512), n_test=512,
               chunk=256, dac_iters=100, reps=3, big_ni=100_000,
               big_m=512, big_agents=4, csv=print,
               json_path="BENCH_serving.json", smoke=False):
    """Sparse pseudo-representation experts (core.sparse) benchmark.

    Part 1 — accuracy-vs-m sweep at Ni = n_obs / M (default 4096): for each
    per-agent inducing count m, fit `fit_sparse_experts` and serve rbcm and
    npae_sparse through the SAME PredictionEngine as the dense baseline.
    Reported per m: RMSE/NLPD on held-out truth, serving speedup vs the
    dense engine (the per-expert O(Ni q) triangular solves collapse to
    O(m q)), fit speedup vs `fit_experts` (O(Ni^3) -> O(Ni m^2)), and the
    mean trace correction N sigma_f^2 - tr(Qnn) (the Titsias bound gap
    diagnostic — how much variance the pseudo-representation still misses).
    Headline: m = Ni/16 must serve >= 10x faster per expert with bounded
    accuracy loss (asserted against the dense rbcm RMSE).

    Part 2 — the 100k-points-per-agent run: Ni = `big_ni` per agent is far
    past what the dense path can factorize (the O(Ni^2) Cholesky alone is
    ~80 GB f64 per agent); the sparse fit streams Kmn through the blocked
    `kmn_stats` pass (O(m bn) transients) and serves npae_sparse from the
    (m, m) factors. Reported: fit wall time, serving qps, and the dense-
    vs-sparse factor bytes that make the dense run impossible.

    `smoke=True` shrinks both parts to a seconds-scale CI pass.
    """
    from repro.core.sparse import fit_sparse_experts, select_inducing

    if smoke:
        n_obs, M, ms, n_test, chunk = 2048, 4, (32, 64), 64, 32
        big_ni, big_m, big_agents, reps = 2048, 64, 2, 2

    lt = pack([0.3, 0.3], 1.3, 0.1)
    key = jax.random.PRNGKey(0)
    X = random_inputs(key, n_obs)
    f_true, y = sst_like_field(X / jnp.max(X), key=jax.random.PRNGKey(1))
    Xp, yp = stripe_partition(X, y, M)
    Ni = int(Xp.shape[1])
    Xs = random_inputs(jax.random.PRNGKey(2), n_test)
    fs, _ = sst_like_field(Xs / jnp.max(X), key=jax.random.PRNGKey(1))
    A = path_graph(M)

    # dense baseline: factors + rbcm serving through the engine
    t_fit_dense = _time_best(
        lambda: jax.jit(fit_experts)(lt, Xp, yp), trials=1)
    fitted = jax.jit(fit_experts)(lt, Xp, yp)
    dense_eng = PredictionEngine(fitted, A, chunk=chunk,
                                 dac_iters=dac_iters)
    t_dense = _time_best(lambda q: dense_eng.predict("rbcm", q)[:2], Xs,
                         reps=reps)
    mu_d, var_d, _ = dense_eng.predict("rbcm", Xs)
    dense_rmse = rmse(mu_d, fs)
    itemsize = np.asarray(Xp).dtype.itemsize
    dense_bytes = M * Ni * Ni * itemsize
    csv(f"# sparse sweep: M={M} Ni={Ni} n_test={n_test}; dense rbcm "
        f"rmse={dense_rmse:.4f} fit={t_fit_dense*1e3:.0f}ms "
        f"serve={n_test/t_dense:.0f}qps factors={dense_bytes/2**20:.0f}MB")
    csv("table,m,Ni_over_m,rmse_rbcm,nlpd_rbcm,rmse_npae,nlpd_npae,"
        "serve_speedup,fit_speedup,mean_tr_corr,sparse_MB")
    sweep = []
    for m in ms:
        Z = select_inducing(Xp, m)
        fit_m = jax.jit(fit_sparse_experts)
        t_fit = _time_best(lambda: fit_m(lt, Xp, yp, Z), trials=1)
        sf = fit_m(lt, Xp, yp, Z)
        eng = PredictionEngine(sf, A, chunk=chunk, dac_iters=dac_iters)
        t_sparse = _time_best(lambda q: eng.predict("rbcm", q)[:2], Xs,
                              reps=reps)
        mu_s, var_s, _ = eng.predict("rbcm", Xs)
        mu_n, var_n, _ = eng.predict("npae_sparse", Xs)
        sparse_bytes = sum(int(np.prod(a.shape)) * itemsize
                           for a in (sf.Z, sf.Lmm, sf.LS, sf.c, sf.tr_corr))
        row = {
            "m": int(m), "Ni_over_m": Ni / m,
            "rmse_rbcm": rmse(mu_s, fs), "nlpd_rbcm": nlpd(mu_s, var_s, fs),
            "rmse_npae": rmse(mu_n, fs), "nlpd_npae": nlpd(mu_n, var_n, fs),
            "rmse_vs_dense": rmse(mu_s, mu_d),
            "serve_speedup": t_dense / t_sparse,
            "fit_speedup": t_fit_dense / t_fit,
            "qps_sparse": n_test / t_sparse,
            "mean_tr_corr": float(jnp.mean(sf.tr_corr)),
            "sparse_MB": sparse_bytes / 2**20,
        }
        sweep.append(row)
        csv(f"sparse,{m},{Ni/m:.0f},{row['rmse_rbcm']:.4f},"
            f"{row['nlpd_rbcm']:.4f},{row['rmse_npae']:.4f},"
            f"{row['nlpd_npae']:.4f},{row['serve_speedup']:.1f},"
            f"{row['fit_speedup']:.1f},{row['mean_tr_corr']:.3g},"
            f"{row['sparse_MB']:.2f}")
    out = {"M": M, "Ni": Ni, "n_test": n_test, "dense_rmse": dense_rmse,
           "dense_fit_s": t_fit_dense, "dense_qps": n_test / t_dense,
           "dense_factor_MB": dense_bytes / 2**20, "sweep": sweep,
           "smoke": bool(smoke)}
    # headline acceptance: m = Ni/16 serves >= 10x faster per expert with
    # bounded accuracy loss
    head = min(sweep, key=lambda r: abs(r["Ni_over_m"] - 16))
    out["headline"] = head
    csv(f"# headline m={head['m']} (Ni/m={head['Ni_over_m']:.0f}): "
        f"serve {head['serve_speedup']:.1f}x, fit "
        f"{head['fit_speedup']:.1f}x, rmse {head['rmse_rbcm']:.4f} vs "
        f"dense {dense_rmse:.4f}")

    # part 2: 100k points per agent — dense cannot factorize this
    Xb = random_inputs(jax.random.PRNGKey(7), big_agents * big_ni)
    _, yb = sst_like_field(Xb / jnp.max(Xb), key=jax.random.PRNGKey(8))
    Xbp, ybp = stripe_partition(Xb, yb, big_agents)
    Zb = select_inducing(Xbp, big_m)
    t0 = time.time()
    sfb = jax.block_until_ready(
        jax.jit(fit_sparse_experts)(lt, Xbp, ybp, Zb))
    t_fit_big = time.time() - t0
    engb = PredictionEngine(sfb, path_graph(big_agents), chunk=chunk)
    Xq = random_inputs(jax.random.PRNGKey(9), chunk)
    t_serve = _time_best(lambda q: engb.predict("npae_sparse", q)[:2], Xq,
                         reps=reps)
    mu_b, var_b, _ = engb.predict("npae_sparse", Xq)
    assert bool(jnp.all(jnp.isfinite(mu_b))) and bool(jnp.all(var_b > 0))
    dense_big = big_agents * big_ni * big_ni * itemsize
    sparse_big = sum(int(np.prod(a.shape)) * itemsize for a in
                     (sfb.Z, sfb.Lmm, sfb.LS, sfb.c, sfb.tr_corr))
    out["big"] = {
        "agents": big_agents, "Ni": int(big_ni), "m": int(big_m),
        "fit_s": t_fit_big, "qps_npae_sparse": chunk / t_serve,
        "dense_factor_GB": dense_big / 2**30,
        "sparse_factor_MB": sparse_big / 2**20,
    }
    csv(f"# 100k/agent run: {big_agents} agents x Ni={big_ni} m={big_m}: "
        f"fit {t_fit_big:.1f}s, npae_sparse {chunk/t_serve:.0f} qps; "
        f"dense factors would be {dense_big/2**30:.0f} GB vs "
        f"{sparse_big/2**20:.1f} MB sparse")

    from .envtags import bench_tags, merge_json
    out.update(bench_tags("sparse"))
    merge_json(json_path, {"sparse": out})
    csv(f"# wrote {json_path} (sparse section)")
    return out


# ---------------------------------------------------------------------------
# Serving: request-level scheduler — continuous batching vs the v1 front door
# ---------------------------------------------------------------------------

def run_scheduler(n_obs=4096, M=8, max_slot=256, chunk=32, dac_iters=150,
                  mean_rows=24, fractions=(0.15, 0.3, 0.5, 0.7, 0.85, 1.0),
                  point_duration=5.0, max_wait_ms=2.0, csv=print,
                  json_path="BENCH_serving.json", smoke=False):
    """Saturation curves for the request-level scheduler (ISSUE 6).

    Two systems over the SAME fleets, driven by the open-loop Poisson
    generator at a sweep of offered loads (fractions of the full-slot
    engine capacity):

      fixed      — one slot geometry of `max_slot` rows: the v1 FrontDoor
                   behavior (every dispatch pays the full-batch program,
                   mostly padding at partial occupancy).
      continuous — the quantized chunk*2^k slot ladder with round-down
                   packing: partial loads run right-sized compiled
                   programs, backlogs run 100%-occupied ones.

    Reported per point: offered qps (rows/s), p50/p99 request latency,
    rejected count (admission control at queue_depth — open-loop overload
    is visible, never hidden behind a blocked generator). The headline is
    SUSTAINABLE qps at equal p99: the SLO is the v1 fixed-batch door's
    p99 at its LIGHTEST offered load — its unloaded floor, the best
    service v1 ever delivers — and each system's sustainable qps is the
    highest offered load that still meets that SLO with < 1% rejections.
    Because queueing p99 is non-decreasing in offered load, each curve is
    evaluated through its monotone (cumulative-max) envelope: a single
    mid-load point whose sampled p99 dips below a lighter load's is
    quantile noise, and must not extend what a system "sustains" (the
    lightest measured point of each system always qualifies). Acceptance:
    continuous >= 1.5x fixed. Fixed/continuous run back-to-back per
    offered load so slow machine drift never lands on one system's whole
    curve, and low-rate points stretch their window until ~250 requests
    complete so the p99 estimate isn't a max-statistic. `n_obs` and `dac_iters` default near the
    paper's protocol so the full-slot program costs tens of ms — the
    regime the batch geometry is FOR; shrinking them (as --smoke does)
    drops the program into scheduling-noise territory and flattens both
    curves. The 2-tenant pass round-robins two resident
    fleets (rbcm + poe) in one process and asserts zero recompiles after
    warmup via the engines' jit-cache miss counters. CPython GC is paused
    during each measurement window (collected between points) so the p99
    tail measures the scheduler, not the garbage collector.
    """
    import gc
    from repro.fleet import FleetConfig, GPFleet
    from repro.launch.scheduler import ServingScheduler
    from .envtags import bench_tags, merge_json
    from .loadgen import TenantLoad, run_load

    if smoke:
        n_obs, M, max_slot, chunk, dac_iters = 256, 4, 64, 16, 30
        mean_rows, fractions, point_duration = 12, (0.3, 0.8), 1.0

    lt = pack([1.2, 0.3], 1.3, 0.1)
    X = random_inputs(jax.random.PRNGKey(0), n_obs)
    _, y = sst_like_field(X / jnp.max(X), key=jax.random.PRNGKey(1))
    Xp, yp = stripe_partition(X, y, M)
    dtype = np.asarray(Xp).dtype

    def build(method):
        cfg = FleetConfig(num_agents=M, method=method, chunk=chunk,
                          dac_iters=dac_iters)
        return GPFleet(cfg).fit(Xp, yp, log_theta0=lt, train=False)

    fleets = {"a": build("rbcm"), "b": build("poe")}

    # full-slot engine capacity (rows/s) anchors the offered-load sweep
    Xfull = jnp.asarray(np.zeros((max_slot, Xp.shape[-1]), dtype))
    t_full = _time_best(lambda q: fleets["a"].predict(q)[:2], Xfull, reps=3)
    cap_qps = max_slot / t_full
    max_rows = 2 * mean_rows - 1          # U[1, max_rows] -> mean_rows mean
    csv(f"# scheduler: full-slot program {t_full*1e3:.1f} ms -> capacity "
        f"{cap_qps:.0f} rows/s; request sizes U[1,{max_rows}]")
    csv("table,system,tenants,offered_frac,offered_qps,completed,rejected,"
        "p50_ms,p99_ms")

    def run_point(system, n_tenants, frac):
        continuous = system == "continuous"
        sched = ServingScheduler(max_wait_ms=max_wait_ms)
        names = list(fleets)[:n_tenants]
        for name in names:
            sched.add_fleet(name, fleets[name], max_slot=max_slot,
                            continuous=continuous, admission="reject",
                            queue_depth=8 * max_slot)
        misses0 = {n: fleets[n].jit_cache_misses for n in names}
        rate = frac * cap_qps / (n_tenants * mean_rows)   # requests/s/tenant
        loads = [TenantLoad(n, rate, max_rows=max_rows) for n in names]
        # low-rate points stretch their window toward ~250 total requests
        # so p99 isn't a max-statistic over a few dozen samples
        min_reqs = 0 if smoke else 250
        dur = max(point_duration, min_reqs / (rate * n_tenants))
        gc.collect()
        gc.disable()        # keep collector pauses out of the p99 tail
        try:
            res = run_load(sched, loads, dur, dtype=dtype,
                           seed=int(frac * 1000) + n_tenants)
            sched.close()
        finally:
            gc.enable()
        recompiles = sum(fleets[n].jit_cache_misses - misses0[n]
                         for n in names)
        assert recompiles == 0, \
            f"{system}/{n_tenants}t recompiled {recompiles}x while serving"
        point = {
            "system": system, "tenants": n_tenants, "offered_frac": frac,
            "offered_qps": sum(r.offered_qps for r in res.values()),
            "offered_rps": sum(r.offered_rps for r in res.values()),
            "completed": sum(r.completed for r in res.values()),
            "rejected": sum(r.rejected for r in res.values()),
            "submitted": sum(r.submitted for r in res.values()),
            "p50_ms": max(r.p50_ms for r in res.values()),
            "p99_ms": max(r.p99_ms for r in res.values()),
        }
        csv(f"scheduler,{system},{n_tenants},{frac},"
            f"{point['offered_qps']:.0f},{point['completed']},"
            f"{point['rejected']},{point['p50_ms']:.2f},"
            f"{point['p99_ms']:.2f}")
        return point

    curves = []
    for n_tenants in (1, 2):
        # fixed/continuous back-to-back per offered load: slow machine
        # drift over the sweep lands on both systems, not one curve
        for frac in fractions:
            for system in ("fixed", "continuous"):
                curves.append(run_point(system, n_tenants, frac))

    def sustainable(system, n_tenants, bound):
        """Highest offered qps whose monotone-envelope p99 meets `bound`
        with < 1% rejections.

        Queueing p99 is non-decreasing in offered load, so each curve is
        read through its cumulative max: a mid-load point whose sampled
        p99 dips under a lighter load's is quantile noise and must not
        extend what the system "sustains". The envelope must stay
        strictly below the bound (a curve sitting AT the SLO within
        noise isn't sustaining it) except at the system's lightest
        measured point, which defines its floor."""
        pts = sorted((c for c in curves if c["system"] == system
                      and c["tenants"] == n_tenants),
                     key=lambda c: c["offered_qps"])
        best, envelope = 0.0, 0.0
        for i, c in enumerate(pts):
            envelope = max(envelope, c["p99_ms"])
            ok_rej = c["rejected"] <= 0.01 * max(1, c["submitted"]
                                                 + c["rejected"])
            ok_p99 = envelope < bound or (i == 0 and c["p99_ms"] <= bound)
            if ok_rej and ok_p99:
                best = max(best, c["offered_qps"])
        return best

    out = {"curves": curves, "capacity_qps": cap_qps,
           "t_full_slot_ms": t_full * 1e3, "max_slot": max_slot,
           "chunk": chunk, "M": M, "mean_rows": mean_rows,
           "point_duration_s": point_duration, "smoke": bool(smoke),
           "sustainable": {}}
    out.update(bench_tags("scheduler"))
    for n_tenants in (1, 2):
        # equal-p99 SLO: the v1 fixed-batch door's p99 at its lightest
        # offered load — its unloaded floor, the best service v1 ever
        # delivers — and both systems must serve under it
        fixed_pts = sorted((c for c in curves if c["system"] == "fixed"
                            and c["tenants"] == n_tenants),
                           key=lambda c: c["offered_qps"])
        bound = fixed_pts[0]["p99_ms"]
        s_fix = sustainable("fixed", n_tenants, bound)
        s_cont = sustainable("continuous", n_tenants, bound)
        ratio = s_cont / s_fix if s_fix else float("inf")
        out["sustainable"][f"{n_tenants}_tenant"] = {
            "p99_bound_ms": bound, "fixed_qps": s_fix,
            "continuous_qps": s_cont, "ratio": ratio}
        csv(f"# {n_tenants} tenant(s): sustainable qps at p99 <= "
            f"{bound:.1f} ms -> fixed {s_fix:.0f}, continuous "
            f"{s_cont:.0f} ({ratio:.2f}x)")

    merge_json(json_path, {"scheduler": out})
    csv(f"# wrote {json_path} (scheduler section)")
    return out
