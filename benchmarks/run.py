"""Benchmark entry point: one section per paper table/figure + roofline.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only training|...]

Default sizes are CI-scale (minutes on one CPU core); --full runs the paper's
protocol (N=8100/20000, 10-15 replications) and takes hours.
"""
from __future__ import annotations

import argparse
import sys

import jax

from repro.obs import default_registry

jax.config.update("jax_enable_x64", True)


def main() -> None:
    # live metrics during benchmarks; merge_json stamps the snapshot into
    # every BENCH_*.json artifact next to the envtags
    default_registry().enable()
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale CI pass: skips every paper-protocol "
                         "sweep and runs only the smoke-capable sections "
                         "(training: fused-gradient bench with the Pallas "
                         "kernel in interpret mode + the JSON artifact; "
                         "sharded: shrunken fleet through both serving "
                         "regimes; scheduler: short saturation sweep)")
    ap.add_argument("--only", default="all",
                    choices=["all", "training", "prediction", "serving",
                             "sharded", "scheduler", "scenario", "online",
                             "sparse", "roofline", "kernels"])
    ap.add_argument("--scenario", default=None,
                    help="scenario section: preset name (smoke|mission|"
                         "chaos) or ScenarioConfig JSON path (default: "
                         "chaos, or smoke under --smoke)")
    args = ap.parse_args()
    if args.smoke and args.only not in ("all", "training", "sharded",
                                        "scheduler", "scenario", "sparse"):
        # fail loudly: a CI step combining these would otherwise stay green
        # while executing nothing
        raise SystemExit(f"--smoke: section {args.only!r} has no "
                         "seconds-scale mode; use --only training, sharded, "
                         "scheduler, scenario or sparse (or all)")

    out = sys.stdout
    def csv(line):
        print(line, file=out, flush=True)

    if args.only in ("all", "training"):
        from . import bench_training
        if not args.smoke:
            csv("# === GP training (paper Fig. 8-9, Table 6) ===")
            if args.full:
                bench_training.run(n_train=8100, fleets=(4, 10, 20, 40),
                                   reps=10, csv=csv)
            else:
                bench_training.run(n_train=1600, fleets=(4, 8), reps=2,
                                   iters=80, csv=csv)
        csv("# === training hot path (fused cached-geometry gradient) ===")
        bench_training.run_fused(csv=csv, smoke=args.smoke)

    if args.only in ("all", "sharded"):
        from . import bench_prediction
        csv("# === agent-sharded serving + CBNN query routing ===")
        bench_prediction.run_sharded(csv=csv, smoke=args.smoke)

    if args.only in ("all", "scheduler"):
        from . import bench_prediction
        csv("# === request-level scheduler (continuous batching vs v1 "
            "front door) ===")
        bench_prediction.run_scheduler(csv=csv, smoke=args.smoke)

    if args.only in ("all", "sparse"):
        from . import bench_prediction
        csv("# === sparse pseudo-representation experts (accuracy vs m; "
            "100k points/agent) ===")
        bench_prediction.run_sparse(csv=csv, smoke=args.smoke)

    if args.only in ("all", "scenario"):
        from . import bench_scenario
        csv("# === closed-loop multi-robot scenario (accuracy over time, "
            "chaos) ===")
        bench_scenario.run(csv=csv, smoke=args.smoke,
                           scenario=args.scenario)

    if args.smoke:
        # no other section has a seconds-scale mode yet; refuse to
        # silently run minutes-scale sweeps under a flag named smoke
        csv("# --smoke: skipping sections prediction serving online "
            "roofline kernels (no smoke mode)")
        return

    if args.only in ("all", "prediction"):
        from . import bench_prediction
        csv("# === GP prediction (paper Fig. 11-15, Tables 7-8) ===")
        if args.full:
            bench_prediction.run(n_obs=20000, n_test=100,
                                 fleets=(4, 10, 20, 40), reps=15, csv=csv)
        else:
            bench_prediction.run(n_obs=1800, n_test=60, fleets=(4, 8),
                                 reps=1, csv=csv)

    if args.only in ("all", "serving"):
        from . import bench_prediction
        csv("# === GP serving (factor-cached engine vs per-call path) ===")
        if args.full:
            bench_prediction.run_serving(n_obs=16384, M=32, n_queries=16384,
                                         csv=csv)
        else:
            bench_prediction.run_serving(csv=csv)

    if args.only in ("all", "online"):
        from . import bench_online
        csv("# === online GP (incremental update vs refit; live serving) ===")
        if args.full:
            bench_online.run(sizes=(128, 512, 2048, 4096), reps=5,
                             serve_rounds=64, csv=csv)
        else:
            bench_online.run(csv=csv)

    if args.only in ("all", "roofline"):
        from . import bench_roofline
        csv("# === TPU roofline (EXPERIMENTS.md par-Roofline; 40 baselines) ===")
        bench_roofline.run(csv=csv)

    if args.only in ("all", "kernels"):
        from . import bench_kernels
        csv("# === kernel micro-benchmarks ===")
        bench_kernels.run(csv=csv)


if __name__ == "__main__":
    main()
