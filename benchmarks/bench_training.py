"""Paper Fig. 8/9 + Table 6: hyperparameter estimation accuracy, computation
time per agent, and communication rounds for every GP training method across
fleet sizes. Plus `run_fused`: the fused cached-geometry NLL gradient vs the
seed autodiff path, per ADMM iteration (BENCH_training.json).

Scaled protocol (CPU CI budget): N and replications are configurable; the
full paper protocol (N=8100, 10 reps) runs with --full. Communication-round
accounting follows the paper's Tables 1/3/4 formulas.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gp import (nll, pack, stripe_partition, communication_dataset,
                           augment)
from repro.core.training import (train_fact_gp, train_c_gp, train_apx_gp,
                                 train_gapx_gp, train_dec_c_gp,
                                 train_dec_apx_gp, train_dec_gapx_gp,
                                 build_training_cache, nll_grad_cached)
from repro.core.consensus import path_graph
from repro.data import random_inputs, gp_sample_field

TRUE = (1.2, 0.3, 1.3, 0.1)
TRUE_LT = pack(TRUE[:2], TRUE[2], TRUE[3])
LT0 = pack([2.0, 0.5], 1.0, 1.0)


def run(n_train=2000, fleets=(4, 10), reps=2, iters=100, csv=print):
    csv("table,method,M,rep,l1,l2,sigma_f,sigma_eps,theta_rmse,"
        "time_per_agent_s,comm_rounds")
    for rep in range(reps):
        key = jax.random.PRNGKey(rep)
        X = random_inputs(key, n_train)
        _, y = gp_sample_field(jax.random.fold_in(key, 1), X, TRUE_LT)
        for M in fleets:
            Xp, yp = stripe_partition(X, y, M)
            A = path_graph(M)
            Xc, yc = communication_dataset(jax.random.fold_in(key, 2), Xp, yp)
            Xa, ya = augment(Xp, yp, Xc, yc)

            def record(name, fn, rounds):
                t0 = time.time()
                lt = fn()
                dt = (time.time() - t0) / M      # per agent (M-way parallel)
                th = np.exp(np.asarray(lt))
                err = float(np.sqrt(np.mean((th - np.asarray(TRUE)) ** 2)))
                csv(f"fig8,{name},{M},{rep},{th[0]:.4f},{th[1]:.4f},"
                    f"{th[2]:.4f},{th[3]:.4f},{err:.4f},{dt:.3f},{rounds}")

            record("FACT-GP",
                   lambda: train_fact_gp(LT0, Xp, yp, steps=2 * iters)[0],
                   2 * iters)
            record("apx-GP",
                   lambda: train_apx_gp(LT0, Xp, yp, iters=iters)[0], iters)
            record("gapx-GP",
                   lambda: train_gapx_gp(LT0, Xa, ya, iters=iters)[0], iters)
            if n_train <= 3000 and M <= 10:
                record("c-GP",
                       lambda: train_c_gp(LT0, Xp, yp, iters=iters // 4,
                                          nested_iters=8)[0], iters // 4)
                record("DEC-c-GP",
                       lambda: jnp.mean(train_dec_c_gp(
                           LT0, Xp, yp, A, iters=iters // 4,
                           nested_iters=8)[0], axis=0), iters // 4)
            record("DEC-apx-GP",
                   lambda: jnp.mean(train_dec_apx_gp(
                       LT0, Xp, yp, A, iters=iters)[0], axis=0), iters)
            record("DEC-gapx-GP",
                   lambda: jnp.mean(train_dec_gapx_gp(
                       LT0, Xa, ya, A, iters=iters)[0], axis=0), iters)


# ---------------------------------------------------------------------------
# Fused training hot path: cached-geometry gradient vs seed autodiff
# ---------------------------------------------------------------------------

def _aot_compile(jitted, *args, **kwargs):
    """AOT-compile once so the SAME executable serves both the timing loop
    and memory_analysis (calling the jit again would re-compile: the AOT
    cache and the __call__ cache are separate). None if lowering fails."""
    try:
        return jitted.lower(*args, **kwargs).compile()
    except Exception:
        return None


def _mem_highwater(compiled):
    """Compiled-program memory high-water (bytes): temps + outputs + args.

    XLA's memory_analysis is backend-dependent (absent or partial on some
    CPU builds) — return None rather than fail the bench."""
    try:
        ma = compiled.memory_analysis()
        return int(ma.temp_size_in_bytes + ma.output_size_in_bytes
                   + ma.argument_size_in_bytes)
    except Exception:
        return None


def _time_per_iter(fns, iters, reps):
    """{name: (best-of-`reps` wall time per iteration, result)} for a dict
    of competing fns. Reps are INTERLEAVED across the contenders so
    background load (shared CI boxes) biases every path equally rather
    than whichever happened to run during a quiet window."""
    out = {name: jax.block_until_ready(fn())     # warmup / compile
           for name, fn in fns.items()}
    best = {name: float("inf") for name in fns}
    for _ in range(reps):
        for name, fn in fns.items():
            t0 = time.time()
            out[name] = jax.block_until_ready(fn())
            best[name] = min(best[name], (time.time() - t0) / iters)
    return {name: (best[name], out[name]) for name in fns}


def run_fused(n_train=1024, M=16, D=2, iters=50, reps=5, csv=print,
              json_path="BENCH_training.json", smoke=False):
    """Per-ADMM-iteration cost of DEC-apx-GP: fused cached-geometry gradient
    (grad_fn default) vs the seed autodiff path (grad_fn="autodiff"), same
    update rule, same data. Acceptance: >= 2x at N=1024, D=2, M=16 on the
    CPU jnp reference path, trained thetas matching to 1e-6, and the Pallas
    kernel verified against the blocked jnp oracle in interpret mode.

    `smoke=True` shrinks everything to seconds for CI: the point of the
    smoke run is exercising the Pallas kernel in interpret mode and the
    JSON plumbing, not stable timings.
    """
    if smoke:
        n_train, M, iters, reps = 256, 4, 10, 2
    key = jax.random.PRNGKey(0)
    lt_true = pack([1.2] + [0.3] * (D - 1), 1.3, 0.1)
    lt0 = pack([2.0] + [0.5] * (D - 1), 1.0, 1.0)
    X = random_inputs(key, n_train, D=D)
    _, y = gp_sample_field(jax.random.fold_in(key, 1), X, lt_true)
    Xp, yp = stripe_partition(X, y, M)
    A = path_graph(M)

    rho, kappa = 500.0, 5000.0
    runs, mem = {}, {}
    for name in ("fused", "autodiff"):
        grad_fn = None if name == "fused" else name
        c = _aot_compile(train_dec_apx_gp, lt0, Xp, yp, A, rho, kappa,
                         iters=iters, grad_fn=grad_fn)
        if c is not None:
            runs[name] = lambda c=c: c(lt0, Xp, yp, A, rho, kappa)[0]
        else:        # backend without AOT support: fall back to the jit
            runs[name] = lambda g=grad_fn: train_dec_apx_gp(
                lt0, Xp, yp, A, rho, kappa, iters=iters, grad_fn=g)[0]
        mem[name] = _mem_highwater(c) if c is not None else None
    timed = _time_per_iter(runs, iters, reps)
    t = {name: tv for name, (tv, _) in timed.items()}
    speedup = t["autodiff"] / t["fused"]
    theta_diff = float(jnp.max(jnp.abs(timed["fused"][1]
                                       - timed["autodiff"][1])))

    # the gradient stage alone (the fleet-wide per-iteration hot spot the
    # fused path replaces; the loop numbers above additionally carry the
    # shared eq. (34) sweep + consensus residual)
    thetas = jnp.broadcast_to(lt0, (M, lt0.shape[0])).astype(Xp.dtype)
    d2u = jax.vmap(lambda Xi, yi: build_training_cache(Xi, yi).d2u)(Xp, yp)
    g_fused = jax.jit(jax.vmap(nll_grad_cached, in_axes=(0, 0, 0)))
    g_auto = jax.jit(jax.vmap(jax.grad(nll), in_axes=(0, 0, 0)))
    tg_timed = _time_per_iter(
        {"fused": lambda: g_fused(thetas, d2u, yp),
         "autodiff": lambda: g_auto(thetas, Xp, yp)}, 1, reps)
    tg = {name: tv for name, (tv, _) in tg_timed.items()}
    grad_speedup = tg["autodiff"] / tg["fused"]

    # Pallas kernel vs blocked jnp oracle, interpret mode (tile-unaligned N)
    ni = 70
    Xi = random_inputs(jax.random.fold_in(key, 2), ni, D=D)
    _, yi = gp_sample_field(jax.random.fold_in(key, 3), Xi, lt_true)
    d2u = build_training_cache(Xi, yi).d2u
    g_ref = nll_grad_cached(lt0, d2u, yi)                 # jnp reference path
    g_pal = nll_grad_cached(lt0, d2u, yi, use_pallas=True, interpret=True)
    pal_rel = float(jnp.max(jnp.abs(g_pal - g_ref)
                            / jnp.maximum(jnp.abs(g_ref), 1e-6)))
    pal_ok = bool(pal_rel < 1e-3)                         # f32 kernel compute

    csv("table,N,M,D,t_fused_ms_per_iter,t_autodiff_ms_per_iter,speedup,"
        "grad_speedup,theta_max_diff,mem_fused_bytes,mem_autodiff_bytes,"
        "pallas_rel_err")
    csv(f"training_fused,{n_train},{M},{D},{t['fused']*1e3:.3f},"
        f"{t['autodiff']*1e3:.3f},{speedup:.2f},{grad_speedup:.2f},"
        f"{theta_diff:.2e},{mem['fused']},{mem['autodiff']},{pal_rel:.2e}")

    out = {"fused_vs_autodiff": {
               "N": int(n_train), "M": int(M), "D": int(D),
               "iters": int(iters),
               "t_fused_ms_per_iter": t["fused"] * 1e3,
               "t_autodiff_ms_per_iter": t["autodiff"] * 1e3,
               "speedup": speedup,
               "t_grad_fused_ms": tg["fused"] * 1e3,
               "t_grad_autodiff_ms": tg["autodiff"] * 1e3,
               "grad_speedup": grad_speedup,
               "theta_max_diff": theta_diff,
               "mem_fused_bytes": mem["fused"],
               "mem_autodiff_bytes": mem["autodiff"]},
           "pallas_interpret": {"N": ni, "max_rel_err": pal_rel,
                                "ok": pal_ok},
           "smoke": bool(smoke)}
    from .envtags import bench_tags
    out.update(bench_tags("replicated"))
    with open(json_path, "w") as fh:
        json.dump(out, fh, indent=2)
    csv(f"# wrote {json_path}")
    # correctness is enforced, not just reported — a broken kernel or a
    # fused/autodiff divergence must fail the (CI) invocation
    if not pal_ok:
        raise SystemExit(f"nll_grad Pallas kernel diverged from the jnp "
                         f"oracle: rel err {pal_rel:.2e}")
    # run.py enables x64; a direct f32 invocation gets the f32-roundoff
    # tolerance (mirrors tests/test_training_fused.py)
    theta_tol = 1e-6 if Xp.dtype == jnp.float64 else 1e-3
    if not theta_diff < theta_tol:
        raise SystemExit(f"fused vs autodiff trained thetas diverged: "
                         f"{theta_diff:.2e} (tol {theta_tol:.0e})")
    return out
