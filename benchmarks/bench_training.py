"""Paper Fig. 8/9 + Table 6: hyperparameter estimation accuracy, computation
time per agent, and communication rounds for every GP training method across
fleet sizes.

Scaled protocol (CPU CI budget): N and replications are configurable; the
full paper protocol (N=8100, 10 reps) runs with --full. Communication-round
accounting follows the paper's Tables 1/3/4 formulas.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gp import (pack, stripe_partition, communication_dataset,
                           augment)
from repro.core.training import (train_fact_gp, train_c_gp, train_apx_gp,
                                 train_gapx_gp, train_dec_c_gp,
                                 train_dec_apx_gp, train_dec_gapx_gp)
from repro.core.consensus import path_graph
from repro.data import random_inputs, gp_sample_field

TRUE = (1.2, 0.3, 1.3, 0.1)
TRUE_LT = pack(TRUE[:2], TRUE[2], TRUE[3])
LT0 = pack([2.0, 0.5], 1.0, 1.0)


def run(n_train=2000, fleets=(4, 10), reps=2, iters=100, csv=print):
    csv("table,method,M,rep,l1,l2,sigma_f,sigma_eps,theta_rmse,"
        "time_per_agent_s,comm_rounds")
    for rep in range(reps):
        key = jax.random.PRNGKey(rep)
        X = random_inputs(key, n_train)
        _, y = gp_sample_field(jax.random.fold_in(key, 1), X, TRUE_LT)
        for M in fleets:
            Xp, yp = stripe_partition(X, y, M)
            A = path_graph(M)
            Xc, yc = communication_dataset(jax.random.fold_in(key, 2), Xp, yp)
            Xa, ya = augment(Xp, yp, Xc, yc)

            def record(name, fn, rounds):
                t0 = time.time()
                lt = fn()
                dt = (time.time() - t0) / M      # per agent (M-way parallel)
                th = np.exp(np.asarray(lt))
                err = float(np.sqrt(np.mean((th - np.asarray(TRUE)) ** 2)))
                csv(f"fig8,{name},{M},{rep},{th[0]:.4f},{th[1]:.4f},"
                    f"{th[2]:.4f},{th[3]:.4f},{err:.4f},{dt:.3f},{rounds}")

            record("FACT-GP",
                   lambda: train_fact_gp(LT0, Xp, yp, steps=2 * iters)[0],
                   2 * iters)
            record("apx-GP",
                   lambda: train_apx_gp(LT0, Xp, yp, iters=iters)[0], iters)
            record("gapx-GP",
                   lambda: train_gapx_gp(LT0, Xa, ya, iters=iters)[0], iters)
            if n_train <= 3000 and M <= 10:
                record("c-GP",
                       lambda: train_c_gp(LT0, Xp, yp, iters=iters // 4,
                                          nested_iters=8)[0], iters // 4)
                record("DEC-c-GP",
                       lambda: jnp.mean(train_dec_c_gp(
                           LT0, Xp, yp, A, iters=iters // 4,
                           nested_iters=8)[0], axis=0), iters // 4)
            record("DEC-apx-GP",
                   lambda: jnp.mean(train_dec_apx_gp(
                       LT0, Xp, yp, A, iters=iters)[0], axis=0), iters)
            record("DEC-gapx-GP",
                   lambda: jnp.mean(train_dec_gapx_gp(
                       LT0, Xa, ya, A, iters=iters)[0], axis=0), iters)
