"""§Roofline: three-term analysis for every (arch x shape) baseline on the
single-pod mesh, merging the compiled dry-run records (memory proof,
collective structure) with the analytic cost model (scan-corrected FLOPs;
see costmodel.py docstring for why compiled cost_analysis alone is not
usable with scan-over-layers)."""
from __future__ import annotations

import json
import os

from repro.configs import ARCH_IDS, get_config
from repro.launch.steps import SHAPES, shape_supported, MICROBATCH
from . import costmodel as cm

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def load_dryrun(arch, shape, mesh="16x16"):
    fn = os.path.join(DRYRUN_DIR, f"{arch}_{shape}_{mesh}.json")
    if os.path.exists(fn):
        with open(fn) as f:
            return json.load(f)
    return None


def run(csv=print):
    csv("table,arch,shape,compute_s,memory_s,collective_s,dominant,"
        "model_flops,hlo_flops_ratio,compiled_flops_per_dev,"
        "compiled_coll_GiB,compiled_mem_GiB,status")
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES:
            if not shape_supported(cfg, shape):
                csv(f"roofline,{arch},{shape},,,,SKIPPED,,,,,,skip")
                continue
            mb = MICROBATCH.get(arch, 1) if shape == "train_4k" else 1
            r = cm.analyze(cfg, shape, "single", microbatch=mb)
            t = r.terms()
            dom = r.dominant
            rec = load_dryrun(arch, shape) or {}
            cflops = rec.get("cost", {}).get("flops", 0)
            ccoll = rec.get("collectives", {}).get("total_bytes", 0) / 2**30
            mem = rec.get("memory", {})
            cmem = (mem.get("argument_size_in_bytes", 0)
                    + mem.get("temp_size_in_bytes", 0)) / 2**30
            ratio = r.model_flops / (r.flops * 256) if r.flops else 0
            csv(f"roofline,{arch},{shape},{t['compute_s']:.4e},"
                f"{t['memory_s']:.4e},{t['collective_s']:.4e},{dom},"
                f"{r.model_flops:.3e},{ratio:.3f},{cflops:.3e},"
                f"{ccoll:.2f},{cmem:.2f},{rec.get('status', 'missing')}")
