"""Online GP benchmarks: incremental rank-1 factor update vs full refit,
and the sustained observe+predict rate of a live serving fleet.

  PYTHONPATH=src python -m benchmarks.run --only online

Two sections:
  update-vs-refit — one agent, window Ni: time `observe` (evict + rank-1
      update/downdate + two triangular solves, O(Ni^2)) against `refit`
      (fresh Cholesky + solve, O(Ni^3)) across Ni. The gap is the point of
      the online subsystem; the acceptance bar is >= 5x at Ni = 2048.
  serving — an M-agent fleet interleaves fleet-wide observation ingestion
      with DEC-rBCM prediction micro-batches through engine factor
      hot-swaps (zero recompiles), reporting sustained obs/s and q/s.

Emits CSV on stdout like the other benches, plus machine-readable
BENCH_online.json in the working directory.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp

from repro.core.consensus import path_graph
from repro.core.gp import pack
from repro.core.online import from_batch, observe, observe_fleet, refit
from repro.core.prediction import PredictionEngine
from repro.data import gp_sample_field, random_inputs


def _time(fn, *args, reps=3):
    jax.block_until_ready(fn(*args))           # warmup / compile
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps


def run(sizes=(128, 512, 2048), reps=3, serve_agents=4, serve_window=256,
        serve_batch=256, serve_rounds=12, csv=print,
        json_path="BENCH_online.json"):
    lt = pack([1.2, 0.3], 1.3, 0.1)
    key = jax.random.PRNGKey(0)
    out = {"update_vs_refit": [], "serving": {}}

    csv("table,Ni,t_update_ms,t_refit_ms,speedup")
    seq = 8   # ring head cycles -> the sweep cost averages over slots
    for Ni in sizes:
        X = random_inputs(jax.random.fold_in(key, Ni), Ni)
        _, y = gp_sample_field(jax.random.fold_in(key, Ni + 1), X, lt)
        state0 = from_batch(lt, X[None], y[None])
        xn = random_inputs(jax.random.fold_in(key, 7), seq)
        y1 = jnp.asarray(0.3, X.dtype)
        # donate the state: the factor is updated in place, as a serving
        # loop (state = observe(state, ...)) would run it. Donate deep
        # copies so state0 (and lt, which its log_theta aliases) survive.
        upd = jax.jit(observe, donate_argnums=0)

        def run_seq(state):
            for i in range(seq):
                state = upd(state, 0, xn[i], y1)
            return state

        run_seq(jax.tree.map(jnp.copy, state0))          # warmup
        state = jax.tree.map(jnp.copy, state0)
        t0 = time.time()
        for _ in range(reps):
            state = run_seq(state)
        jax.block_until_ready(state.L)
        t_u = (time.time() - t0) / (reps * seq)
        t_r = _time(jax.jit(refit), state, reps=max(1, reps - 1))
        speedup = t_r / t_u
        csv(f"online,{Ni},{t_u*1e3:.2f},{t_r*1e3:.2f},{speedup:.1f}")
        out["update_vs_refit"].append(
            {"Ni": int(Ni), "t_update_ms": t_u * 1e3,
             "t_refit_ms": t_r * 1e3, "speedup": speedup})

    # -- sustained observe+predict serving ---------------------------------
    M, W = serve_agents, serve_window
    X = random_inputs(jax.random.fold_in(key, 99), M * W)
    _, y = gp_sample_field(jax.random.fold_in(key, 100), X, lt)
    state = from_batch(lt, X.reshape(M, W, -1), y.reshape(M, W))
    eng = PredictionEngine(state.to_fitted(), path_graph(M), chunk=128,
                           dac_iters=100)
    Xq = random_inputs(jax.random.fold_in(key, 101), serve_batch)
    ingest = jax.jit(observe_fleet)
    xs = random_inputs(jax.random.fold_in(key, 102), M)
    ys = jnp.zeros((M,), X.dtype)
    jax.block_until_ready(ingest(state, xs, ys).L)            # warmup both
    jax.block_until_ready(eng.predict("rbcm", Xq)[0])
    t0 = time.time()
    for r in range(serve_rounds):
        k = jax.random.fold_in(key, 200 + r)
        state = ingest(state, random_inputs(k, M),
                       jax.random.normal(jax.random.fold_in(k, 1), (M,),
                                         X.dtype))
        eng.swap_experts(state.to_fitted())
        mean, _, _ = eng.predict("rbcm", Xq)
    jax.block_until_ready(mean)
    dt = time.time() - t0
    n_obs = serve_rounds * M
    n_q = serve_rounds * serve_batch
    csv("table,M,W,rounds,obs_per_s,queries_per_s")
    csv(f"online_serving,{M},{W},{serve_rounds},{n_obs/dt:.0f},{n_q/dt:.0f}")
    out["serving"] = {"M": M, "window": W, "rounds": serve_rounds,
                      "obs_per_s": n_obs / dt, "queries_per_s": n_q / dt}

    from .envtags import bench_tags
    out.update(bench_tags("replicated"))
    with open(json_path, "w") as fh:
        json.dump(out, fh, indent=2)
    csv(f"# wrote {json_path}")
    return out
