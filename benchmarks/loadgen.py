"""Open-loop Poisson load generator for the serving scheduler.

Closed-loop drivers (submit, wait, repeat) pace themselves to the system
under test, so they can never show saturation — the queue length is
bounded by the driver's concurrency. This generator is OPEN-LOOP: each
tenant's requests arrive on a Poisson timeline at the OFFERED rate,
submitted on schedule regardless of completions, exactly like independent
clients. Overload therefore shows up the way it does in production: queue
depth grows, admission control starts rejecting (`SchedulerSaturated`,
counted — run the scheduler's tenants with admission="reject" so the
generator never blocks), and the p99 of what does complete blows up.

Request sizes are ragged (uniform over [1, max_rows]) so slot packing is
exercised, and request arrays are pre-generated so the submit loop spends
its time on the timeline, not on RNG.

`bench_prediction.run_scheduler` sweeps this generator over offered-load
fractions to produce the latency-vs-load saturation curves in
BENCH_serving.json.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from repro.launch.scheduler import DeadlineExceeded, SchedulerSaturated

__all__ = ["TenantLoad", "LoadResult", "poisson_timeline", "run_load"]


@dataclass
class TenantLoad:
    """One tenant's offered load: `rate` requests/s, sizes ~ U[1,
    max_rows] (mean (max_rows + 1) / 2 rows per request). `seed`
    overrides the run-level seed for THIS tenant's timeline."""
    name: str
    rate: float
    max_rows: int = 47
    priority: int = 0
    deadline_ms: float | None = None
    seed: int | None = None


@dataclass
class LoadResult:
    """Per-tenant outcome of one load run. `offered_*` describe the
    generated timeline (including rejected work); p50/p99 are request
    latencies of COMPLETED work only — read them together with
    `rejected`/`dropped`, a low p99 at high rejection is not sustained."""
    tenant: str
    offered_rps: float
    offered_qps: float
    submitted: int = 0
    completed: int = 0
    rejected: int = 0
    dropped: int = 0
    p50_ms: float = float("nan")
    p99_ms: float = float("nan")


def poisson_timeline(loads, duration: float, *, input_dim: int = 2,
                     dtype=np.float64, lo: float = 0.0, hi: float = 2.0,
                     seed: int = 0) -> list:
    """The merged arrival timeline as pure data: [(arrival_s, TenantLoad,
    Xq), ...] sorted by arrival time.

    Each tenant's stream draws from its OWN generator seeded by
    (seed-or-load.seed, tenant name), so a timeline is a pure function of
    the load configs: the same seed replays the same arrivals bit for bit
    (tests/test_scenario.py regression-tests this), and adding a tenant to
    the run never perturbs another tenant's schedule.
    """
    events = []                      # (arrival_s, TenantLoad, Xq)
    for load in loads:
        base = seed if load.seed is None else load.seed
        rng = np.random.default_rng([int(base), *load.name.encode()])
        t = rng.exponential(1.0 / load.rate)
        while t < duration:
            n = int(rng.integers(1, load.max_rows + 1))
            Xq = rng.uniform(lo, hi, (n, input_dim)).astype(dtype)
            events.append((t, load, Xq))
            t += rng.exponential(1.0 / load.rate)
    events.sort(key=lambda e: e[0])
    return events


def run_load(sched, loads, duration: float, *, input_dim: int = 2,
             dtype=np.float64, lo: float = 0.0, hi: float = 2.0,
             seed: int = 0, result_timeout: float = 600.0
             ) -> dict[str, LoadResult]:
    """Drive `sched` with the merged per-tenant Poisson timelines for
    `duration` seconds of arrivals (`poisson_timeline(seed=...)`:
    replayable), wait for every accepted Future, and return
    {tenant: LoadResult}.

    The query DTYPE must match the fleets' fitted dtype — a mismatched
    dtype is a new jit-cache geometry per slot, which would corrupt both
    the latencies and the zero-recompile story.
    """
    events = poisson_timeline(loads, duration, input_dim=input_dim,
                              dtype=dtype, lo=lo, hi=hi, seed=seed)
    offered_rows = {load.name: 0 for load in loads}
    for _, load, Xq in events:
        offered_rows[load.name] += Xq.shape[0]

    results = {
        load.name: LoadResult(load.name, offered_rps=load.rate,
                              offered_qps=offered_rows[load.name] / duration)
        for load in loads
    }
    futs = []
    t0 = time.monotonic()
    for at, load, Xq in events:
        lag = at - (time.monotonic() - t0)
        if lag > 0:
            time.sleep(lag)
        r = results[load.name]
        try:
            futs.append((load.name, sched.add_request(
                Xq, tenant=load.name, priority=load.priority,
                deadline_ms=load.deadline_ms)))
            r.submitted += 1
        except SchedulerSaturated:
            r.rejected += 1
    for name, fut in futs:
        try:
            fut.result(timeout=result_timeout)
            results[name].completed += 1
        except DeadlineExceeded:
            results[name].dropped += 1
    for name, st in sched.tenant_stats.items():
        if name in results:
            p50, p99 = st.latency_ms(50, 99)
            results[name].p50_ms = p50
            results[name].p99_ms = p99
    return results
