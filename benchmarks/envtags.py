"""Environment tags stamped into every BENCH_*.json artifact.

Benchmark JSONs accumulate across machines and backends (CPU CI today, a
real accelerator ring tomorrow). Tagging each result dict with the jax
backend and the serving topology it measured turns the artifacts into a
cross-backend trajectory instead of a set of context-free numbers.

`merge_json` additionally stamps the `repro.obs` default-registry snapshot
under "obs_metrics" (requests, padding fraction, engine traces, ... —
whatever the benchmarked run touched), so a BENCH_*.json carries the
observability counters behind its numbers next to the envtags
(docs/observability.md).
"""
from __future__ import annotations

import json

import jax

from repro.obs import default_registry


def bench_tags(topology: str) -> dict:
    """`topology` names the serving/execution layout the numbers describe:
    "replicated" (one device holds the whole fleet), "sharded" (agent axis
    over a device mesh), "routed" (sharded + CBNN query routing), or
    "scheduler" (request-level scheduler over replicated engines)."""
    return {
        "backend": jax.default_backend(),
        "device_count": len(jax.devices()),
        "topology": topology,
    }


def merge_json(json_path: str, updates: dict) -> dict:
    """Read-modify-write `json_path`: existing keys not in `updates`
    survive, so independent benchmark sections can share one artifact
    (e.g. run_sharded and run_scheduler both land in
    BENCH_serving.json). Also stamps the current `repro.obs` registry
    snapshot as "obs_metrics" when any series exist (run.py enables the
    registry so scheduler/engine counters are live during benchmarks)."""
    try:
        with open(json_path) as fh:
            full = json.load(fh)
    except (FileNotFoundError, json.JSONDecodeError):
        full = {}
    full.update(updates)
    snap = default_registry().snapshot()
    if snap:
        full["obs_metrics"] = snap
    with open(json_path, "w") as fh:
        json.dump(full, fh, indent=2)
    return full
