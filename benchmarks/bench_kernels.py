"""Kernel micro-benchmarks: rbf_gram and flash-attention jnp-path wall time
on THIS host (CPU — indicative only; the Pallas kernels target TPU) plus the
ref-vs-kernel agreement sweep used as the perf-correctness gate."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.ops import rbf_gram
from repro.kernels.flash_jnp import flash_attention_jnp


def _time(fn, *args, reps=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6


def run(csv=print):
    csv("table,kernel,config,us_per_call,max_err_vs_ref")
    key = jax.random.PRNGKey(0)
    for n in (256, 1024, 2048):
        x = jax.random.normal(key, (n, 2), jnp.float32)
        ls = jnp.array([0.7, 0.7], jnp.float32)
        f_ref = jax.jit(lambda a: ref.rbf_gram_ref(a, a, ls, 1.3))
        us = _time(f_ref, x)
        err = 0.0
        csv(f"kernels,rbf_gram_jnp,N={n},{us:.0f},{err:.1e}")
    for (s, d) in ((512, 64), (2048, 64)):
        q = jax.random.normal(key, (1, 8, s, d), jnp.float32)
        k = jax.random.normal(key, (1, 2, s, d), jnp.float32)
        v = jax.random.normal(key, (1, 2, s, d), jnp.float32)
        f = jax.jit(lambda a, b, c: flash_attention_jnp(a, b, c, True, None,
                                                        min(512, s)))
        us = _time(f, q, k, v)
        want = ref.flash_attention_ref(q, k, v)
        err = float(jnp.abs(f(q, k, v) - want).max())
        csv(f"kernels,flash_jnp,S={s} D={d},{us:.0f},{err:.1e}")
