"""Agent-sharded serving == replicated serving, CBNN mask/routing coverage,
and the async micro-batching front door.

Acceptance gate for the sharded engine: for every PoE/BCM-family method
(poe gpoe bcm rbcm grbcm + nn_* variants), running the fleet sharded over
the agent axis of a device mesh — per-agent moments shard-local, cross-agent
sums on the device ring — matches the replicated `PredictionEngine` to
<= 1e-6 in f64, with bit-identical CBNN masks. Runs on however many local
devices exist (a 1-device mesh degenerates the ring collectives to identity,
so the code path is exercised everywhere); CI re-runs this file under
--xla_force_host_platform_device_count=8.
"""
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.consensus import (path_graph, ring_allmax, ring_allsum)
from repro.core.gp import (augment, communication_dataset, pack,
                           stripe_partition)
from repro.core.prediction import (PredictionEngine, ShardedEngine,
                                   dec_bcm_from_moments,
                                   dec_grbcm_from_moments,
                                   dec_gpoe_from_moments,
                                   dec_poe_from_moments,
                                   dec_rbcm_from_moments, expert_specs,
                                   fit_experts, local_moments)
from repro.core.prediction.cbnn import _mask_from_scores
from repro.core.prediction import aggregation as agg
from repro.data import gp_sample_field, random_inputs
from repro.launch.frontdoor import FrontDoor
from repro.launch.mesh import make_agent_mesh

TRUE_LT = pack([1.2, 0.3], 1.3, 0.1)
M = 8
NT = 23          # deliberately not a multiple of the engine chunk (8)
CHUNK = 8
ITERS = 800      # enough for BOTH consensus protocols (path graph over M
#                  agents, device ring over ndev) to converge well past 1e-7
ETA = 0.1


@pytest.fixture(scope="module")
def setup():
    X = random_inputs(jax.random.PRNGKey(0), 480)
    _, y = gp_sample_field(jax.random.PRNGKey(1), X, TRUE_LT)
    Xp, yp = stripe_partition(X, y, M)
    Xs = random_inputs(jax.random.PRNGKey(2), NT)
    Xc, yc = communication_dataset(jax.random.PRNGKey(3), Xp, yp)
    Xa, ya = augment(Xp, yp, Xc, yc)
    return Xp, yp, Xs, Xc, yc, Xa, ya


@pytest.fixture(scope="module")
def fitted(setup):
    Xp, yp, Xs, Xc, yc, Xa, ya = setup
    return (fit_experts(TRUE_LT, Xp, yp), fit_experts(TRUE_LT, Xa, ya),
            fit_experts(TRUE_LT, Xc[None], yc[None]))


@pytest.fixture(scope="module")
def mesh():
    return make_agent_mesh(M)


@pytest.fixture(scope="module")
def engines(fitted, mesh):
    f, fa, fc = fitted
    rep = PredictionEngine(f, path_graph(M), chunk=CHUNK, dac_iters=ITERS,
                           eta_nn=ETA, fitted_aug=fa, fitted_comm=fc)
    sh = ShardedEngine(f, mesh, chunk=CHUNK, dac_iters=ITERS, eta_nn=ETA,
                       fitted_aug=fa, fitted_comm=fc)
    return rep, sh


def assert_close(a, b, tol=1e-6):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=tol)


# ---------------------------------------------------------------------------
# sharded == replicated, every PoE/BCM-family method
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", tuple(
    m for m in ShardedEngine.METHODS if m != "npae_sparse"))
def test_sharded_matches_replicated(engines, setup, method):
    """Full-fleet sharded serving == replicated engine to <= 1e-6 (f64).

    npae_sparse is excluded here because these fixtures carry dense
    FittedExperts; its sharded == replicated parity gate lives in
    tests/test_sparse.py with SparseExperts fixtures."""
    _, _, Xs, *_ = setup
    rep, sh = engines
    mr, vr, ir = rep.predict(method, Xs)
    ms, vs, is_ = sh.predict(method, Xs)
    assert_close(ms, mr)
    assert_close(vs, vr)
    if method.startswith("nn_"):
        # sharded routing (shard-local scores + ring max) == replicated mask
        np.testing.assert_array_equal(np.asarray(is_["mask"]),
                                      np.asarray(ir["mask"]))


@pytest.mark.parametrize("method", ("rbcm", "nn_gpoe"))
def test_exact_consensus_mode(fitted, mesh, setup, engines, method):
    """consensus='exact' (finite ring_allsum protocol) matches too."""
    f, fa, fc = fitted
    _, _, Xs, *_ = setup
    rep, _ = engines
    sh = ShardedEngine(f, mesh, chunk=CHUNK, eta_nn=ETA, consensus="exact",
                       fitted_aug=fa, fitted_comm=fc)
    mr, vr, _ = rep.predict(method, Xs)
    ms, vs, info = sh.predict(method, Xs)
    assert_close(ms, mr)
    assert_close(vs, vr)
    assert float(info["dac_residual"]) == 0.0


def test_sharded_rejects_npae_family(engines, setup):
    _, _, Xs, *_ = setup
    _, sh = engines
    with pytest.raises(ValueError, match="NPAE"):
        sh.predict("npae", Xs)
    with pytest.raises(ValueError):
        sh.predict_routed("rbcm", Xs)        # routing is CBNN-only


def test_sharded_rejects_bad_geometry(fitted, mesh):
    if mesh.shape["agents"] < 2:
        pytest.skip("a 1-device mesh divides any agent count")
    f, _, _ = fitted
    odd = f._replace(Xp=f.Xp[:5], yp=f.yp[:5], L=f.L[:5], alpha=f.alpha[:5])
    with pytest.raises(ValueError, match="shard"):
        ShardedEngine(odd, mesh)


def test_expert_specs_refuse_cross_cache(fitted):
    f, _, _ = fitted
    with pytest.raises(ValueError, match="Kcross"):
        expert_specs(f._replace(Kcross=jnp.zeros((M, M, 2, 2))), "agents")


def test_sharded_swap_experts_no_recompile(fitted, mesh, setup):
    """Factor hot-swap reuses every compiled sharded program."""
    f, _, _ = fitted
    _, _, Xs, *_ = setup
    sh = ShardedEngine(f, mesh, chunk=CHUNK, dac_iters=50)
    m1, _, _ = sh.predict("poe", Xs)
    compiled = dict(sh._compiled)
    sh.swap_experts(f._replace(yp=2.0 * f.yp, alpha=2.0 * f.alpha))
    m2, _, _ = sh.predict("poe", Xs)
    assert all(sh._compiled[k] is compiled[k] for k in compiled)
    assert_close(m2, 2.0 * np.asarray(m1), tol=1e-8)   # PoE mean is linear
    # a refit carrying the (un-shardable) NPAE cross-Gram cache is accepted:
    # the cache is stripped before the same-shape comparison
    Ni = f.Xp.shape[1]
    sh.swap_experts(f._replace(Kcross=jnp.zeros((M, M, Ni, Ni))))
    assert all(sh._compiled[k] is compiled[k] for k in compiled)


# ---------------------------------------------------------------------------
# CBNN routing
# ---------------------------------------------------------------------------

def test_routed_equals_full_when_participants_are_shard_local():
    """Shard-interior queries at tight eta_nn: the thresholded participant
    set lives inside the routed block, so CBNN-routed serving equals the
    full nn_* aggregate (the paper's subset-of-agents prediction with zero
    approximation)."""
    lt = pack([0.08, 0.08], 1.3, 0.1)      # short lengthscales: localized
    X = random_inputs(jax.random.PRNGKey(0), 640)
    _, y = gp_sample_field(jax.random.PRNGKey(1), X, lt)
    Xp, yp = stripe_partition(X, y, M)
    f = fit_experts(lt, Xp, yp)
    cents = jnp.mean(Xp, axis=1)
    noise = 0.01 * jax.random.normal(jax.random.PRNGKey(5), (3,) + cents.shape)
    Xs = jnp.concatenate([cents + n for n in noise])   # interior queries
    mesh = make_agent_mesh(M)
    rep = PredictionEngine(f, path_graph(M), chunk=CHUNK, dac_iters=1500,
                           eta_nn=0.8)
    sh = ShardedEngine(f, mesh, chunk=CHUNK, dac_iters=1500, eta_nn=0.8)
    # nn_gpoe included deliberately: its beta = m / M_eff weights need the
    # PER-QUERY participant count, which routed mode must take from the
    # local block (a ring sum would mix other shards' unrelated queries)
    for method in ("nn_rbcm", "nn_gpoe", "nn_poe"):
        mr, vr, _ = rep.predict(method, Xs)
        mt, vt, info = sh.predict_routed(method, Xs)
        assert_close(mt, mr)
        assert_close(vt, vr)
        assert info["n_selected"].shape == (Xs.shape[0],)
        assert int(jnp.min(info["n_selected"])) >= 1


def test_routed_batch_shapes_and_debatching(engines, setup):
    """Routed serving returns answers in request order with static per-shard
    batches (quantized to the chunk)."""
    _, _, Xs, *_ = setup
    _, sh = engines
    mean, var, info = sh.predict_routed("nn_rbcm", Xs)
    assert mean.shape == (NT,) and var.shape == (NT,)
    assert info["batch_per_shard"] % CHUNK == 0
    assert info["shard"].shape == (NT,)
    assert np.all(np.asarray(info["n_selected"]) >= 1)
    # permutation-invariance: shuffling requests shuffles answers with them
    perm = np.random.default_rng(0).permutation(NT)
    mean_p, _, _ = sh.predict_routed("nn_rbcm", np.asarray(Xs)[perm])
    assert_close(mean_p, np.asarray(mean)[perm], tol=1e-10)


# ---------------------------------------------------------------------------
# CBNN mask semantics (satellite coverage)
# ---------------------------------------------------------------------------

def test_mask_keeps_best_agent_at_extreme_eta():
    """>= 1 agent survives per query even when eta_nn excludes everyone."""
    scores = jnp.asarray(np.random.default_rng(0).uniform(0, 1, (5, 11)))
    mask = _mask_from_scores(scores, eta_nn=1e9)
    per_query = np.asarray(mask).sum(axis=0)
    assert np.all(per_query >= 1)
    np.testing.assert_array_equal(np.asarray(mask).argmax(axis=0),
                                  np.asarray(scores).argmax(axis=0))


def test_mask_all_pass_at_zero_eta():
    scores = jnp.asarray(np.random.default_rng(1).uniform(0.1, 1, (4, 7)))
    assert bool(jnp.all(_mask_from_scores(scores, eta_nn=0.0)))


def test_masked_aggregation_equals_dense_when_all_true(setup):
    """All-true mask == no mask, for the centralized closed forms AND the
    consensus cores."""
    Xp, yp, Xs, *_ = setup
    mu, var = local_moments(TRUE_LT, Xp, yp, Xs)
    pv = float(jnp.exp(TRUE_LT)[-2]) ** 2
    ones = jnp.ones_like(mu, dtype=bool)
    for fn in (agg.poe, agg.gpoe):
        assert_close(fn(mu, var, mask=ones)[0], fn(mu, var)[0], tol=1e-12)
    for fn in (agg.bcm, agg.rbcm):
        assert_close(fn(mu, var, pv, mask=ones)[0], fn(mu, var, pv)[0],
                     tol=1e-12)
    A = path_graph(M)
    for core in (dec_poe_from_moments, dec_gpoe_from_moments,
                 dec_bcm_from_moments, dec_rbcm_from_moments):
        masked = core(mu, var, pv, A, iters=60, mask=ones)
        dense = core(mu, var, pv, A, iters=60)
        assert_close(masked[0], dense[0], tol=1e-12)
        assert_close(masked[1], dense[1], tol=1e-12)


def test_masked_grbcm_core_all_true(setup):
    Xp, yp, Xs, Xc, yc, Xa, ya = setup
    mu_a, var_a = local_moments(TRUE_LT, Xa, ya, Xs)
    mu_c, var_c = local_moments(TRUE_LT, Xc[None], yc[None], Xs)
    A = path_graph(M)
    ones = jnp.ones_like(mu_a, dtype=bool)
    masked = dec_grbcm_from_moments(mu_a, var_a, mu_c[0], var_c[0], A,
                                    iters=60, mask=ones)
    dense = dec_grbcm_from_moments(mu_a, var_a, mu_c[0], var_c[0], A,
                                   iters=60)
    assert_close(masked[0], dense[0], tol=1e-12)


# ---------------------------------------------------------------------------
# ring collectives
# ---------------------------------------------------------------------------

def test_ring_allreduce_exact(mesh):
    """ring_allsum / ring_allmax produce exact network reductions on every
    device of the mesh."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    n = mesh.shape["agents"]
    w = 1.0 + jnp.arange(3.0 * n).reshape(n, 3)

    @partial(shard_map, mesh=mesh, in_specs=P("agents"),
             out_specs=(P("agents"), P("agents")), check_rep=False)
    def run(wl):
        return (ring_allsum(wl, "agents"), ring_allmax(wl, "agents"))

    s, m = run(w)
    np.testing.assert_allclose(np.asarray(s),
                               np.broadcast_to(w.sum(0, keepdims=True),
                                               w.shape), atol=1e-12)
    np.testing.assert_allclose(np.asarray(m),
                               np.broadcast_to(w.max(0, keepdims=True),
                                               w.shape), atol=0)


def test_make_agent_mesh_divisor():
    mesh = make_agent_mesh(M)
    assert M % mesh.shape["agents"] == 0
    assert make_agent_mesh(7, max_devices=4).shape["agents"] in (1, 7)


# ---------------------------------------------------------------------------
# async front door
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_engine(fitted):
    f, _, _ = fitted
    return PredictionEngine(f, path_graph(M), chunk=CHUNK, dac_iters=60)


def test_frontdoor_matches_direct(small_engine):
    """Ragged submits through the front door == direct engine answers."""
    rng = np.random.default_rng(3)
    requests = [random_inputs(jax.random.PRNGKey(50 + i), int(n))
                for i, n in enumerate(rng.integers(1, 9, size=7))]
    predict = partial(small_engine.predict, "rbcm")
    with FrontDoor(predict, batch=16, max_wait_ms=5.0) as door:
        futures = [door.submit(r) for r in requests]
        results = [f.result(timeout=120) for f in futures]
    for r, (mean, var) in zip(requests, results):
        ref_m, ref_v, _ = small_engine.predict("rbcm", r)
        assert mean.shape == (r.shape[0],)
        assert_close(mean, ref_m, tol=1e-8)
        assert_close(var, ref_v, tol=1e-8)
    st = door.stats
    assert st.requests == 7
    assert st.queries == sum(r.shape[0] for r in requests)
    assert st.batches >= 1


def test_frontdoor_fixed_shapes_reuse_compiled(small_engine):
    """Every dispatch hits the same compiled program (fixed batch shape)."""
    predict = partial(small_engine.predict, "poe")
    with FrontDoor(predict, batch=16, max_wait_ms=1.0) as door:
        door.submit(random_inputs(jax.random.PRNGKey(0), 5)).result(120)
        compiled = small_engine._compiled["poe"]
        door.submit(random_inputs(jax.random.PRNGKey(1), 3)).result(120)
        door.submit(random_inputs(jax.random.PRNGKey(2), 40)).result(120)
    assert small_engine._compiled["poe"] is compiled


def test_frontdoor_propagates_errors():
    def boom(_):
        raise RuntimeError("engine exploded")

    with FrontDoor(boom, batch=4, max_wait_ms=1.0) as door:
        fut = door.submit(np.zeros((2, 2)))
        with pytest.raises(RuntimeError, match="exploded"):
            fut.result(timeout=60)


def test_frontdoor_rejects_after_close(small_engine):
    door = FrontDoor(partial(small_engine.predict, "poe"), batch=8)
    door.close()
    with pytest.raises(RuntimeError):
        door.submit(np.zeros((1, 2)))


def test_frontdoor_latency_bound(small_engine):
    """A lone sub-batch request is dispatched once max_wait_ms expires
    rather than waiting for a full batch."""
    predict = partial(small_engine.predict, "poe")
    with FrontDoor(predict, batch=256, max_wait_ms=10.0) as door:
        t0 = time.monotonic()
        fut = door.submit(random_inputs(jax.random.PRNGKey(0), 2))
        mean, _ = fut.result(timeout=120)
    assert mean.shape == (2,)
    assert time.monotonic() - t0 < 60.0    # not stuck waiting for 254 more
