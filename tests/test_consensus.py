"""Consensus protocols: DAC, JOR, PM, DALE, flooding, graphs — each against
its paper lemma."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.consensus import (path_graph, cycle_graph, complete_graph,
                                  random_connected_graph, laplacian,
                                  max_degree, perron, diameter, is_connected,
                                  dac, dac_until, dac_sharded, jor,
                                  power_method, extreme_eigs, optimal_omega,
                                  dale, flood)


def _spd(M, key=0):
    B = jax.random.normal(jax.random.PRNGKey(key), (M, M))
    return B @ B.T + M * jnp.eye(M)


def test_graph_basics():
    A = path_graph(5)
    assert float(max_degree(A)) == 2
    assert diameter(A) == 4
    assert is_connected(A)
    assert diameter(complete_graph(5)) == 1
    assert diameter(cycle_graph(6)) == 3
    L = laplacian(A)
    assert np.allclose(np.asarray(L).sum(axis=1), 0)


@settings(max_examples=10, deadline=None)
@given(st.integers(3, 30), st.integers(0, 5))
def test_random_graph_connected_property(M, seed):
    assert is_connected(random_connected_graph(M, 0.2, seed))


@pytest.mark.parametrize("graph", [path_graph, cycle_graph, complete_graph])
def test_dac_converges_to_average(graph):
    """Lemma 1: DAC -> average for eps in (0, 1/Delta), any topology."""
    M = 12
    w0 = jax.random.normal(jax.random.PRNGKey(0), (M,))
    w, _ = dac(w0, graph(M), iters=2000)
    np.testing.assert_allclose(np.asarray(w), float(jnp.mean(w0)), atol=1e-8)


def test_dac_until_maximin_stopping():
    M = 8
    w0 = jax.random.normal(jax.random.PRNGKey(1), (M,))
    w, iters = dac_until(w0, path_graph(M), tol=1e-10)
    np.testing.assert_allclose(np.asarray(w), float(jnp.mean(w0)), atol=1e-8)
    assert iters < 5000


def test_dac_multichannel():
    M, K = 10, 7
    w0 = jax.random.normal(jax.random.PRNGKey(2), (M, K))
    w, _ = dac(w0, path_graph(M), iters=3000)
    want = np.broadcast_to(np.asarray(jnp.mean(w0, 0)), (M, K))
    np.testing.assert_allclose(np.asarray(w), want, atol=1e-7)


def test_jor_lemma2_and_lemma3():
    """JOR converges for omega < 2/M; omega* converges strictly faster."""
    M = 10
    H = _spd(M)
    b = jax.random.normal(jax.random.PRNGKey(3), (M,))
    q_true = jnp.linalg.solve(H, b)
    q, _ = jor(H, b, 2.0 / M * 0.999, 400)
    np.testing.assert_allclose(np.asarray(q), np.asarray(q_true), atol=1e-8)
    om = optimal_omega(H)
    assert float(om) > 2.0 / M
    q_fast, _ = jor(H, b, om, 60)
    q_slow, _ = jor(H, b, 2.0 / M * 0.999, 60)
    err_fast = float(jnp.abs(q_fast - q_true).max())
    err_slow = float(jnp.abs(q_slow - q_true).max())
    assert err_fast < err_slow * 0.1


def test_power_method_eigs():
    M = 12
    H = _spd(M, 5)
    R = H / jnp.diagonal(H)[:, None]
    lam_max, lam_min = extreme_eigs(R, iters=500)
    evals = np.linalg.eigvals(np.asarray(R))
    np.testing.assert_allclose(float(lam_max), evals.real.max(), rtol=1e-4)
    np.testing.assert_allclose(float(lam_min), evals.real.min(), rtol=1e-3,
                               atol=1e-6)


@pytest.mark.parametrize("graph", [path_graph, cycle_graph,
                                   lambda M: random_connected_graph(M, 0.3)])
def test_dale_lemma5_strongly_connected(graph):
    """Lemma 5: DALE solves Hq=b on merely strongly connected graphs, and
    every agent ends with the full solution."""
    M = 8
    H = _spd(M, 7)
    b = jax.random.normal(jax.random.PRNGKey(4), (M,))
    Q, _ = dale(H, b, graph(M), 6000)
    q_true = np.asarray(jnp.linalg.solve(H, b))
    for i in range(M):
        np.testing.assert_allclose(np.asarray(Q[i]), q_true, atol=1e-5)


def test_flooding_rounds_equal_diameter():
    A = path_graph(9)
    vals = jnp.arange(9.0)
    gathered, rounds = flood(vals, A)
    assert rounds == 8
    np.testing.assert_array_equal(np.asarray(gathered), np.asarray(vals))


def test_dac_sharded_matches_simulated():
    """Sharded (shard_map/ppermute) DAC == simulated cycle-graph DAC."""
    n_dev = jax.device_count()
    if n_dev < 4:
        pytest.skip("needs >= 4 devices (run under forced host devices)")
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from functools import partial
    M = 4
    mesh = jax.make_mesh((M,), ("agents",))
    w0 = jax.random.normal(jax.random.PRNGKey(0), (M,))

    @partial(shard_map, mesh=mesh, in_specs=P("agents"), out_specs=P("agents"))
    def run(w):
        return dac_sharded(w, "agents", iters=300)

    w_sh = run(w0)
    w_sim, _ = dac(w0, cycle_graph(M), iters=300, eps=1.0 / 3.0)
    np.testing.assert_allclose(np.asarray(w_sh), np.asarray(w_sim), atol=1e-10)


def test_dac_residual_is_per_column():
    """Maximin stopping (Yadav & Salapaka) is PER consensus column: two
    already-converged columns with different consensus values must report a
    ~zero residual, not the cross-column spread."""
    M = 5
    w0 = jnp.stack([jnp.zeros(M), 100.0 + jnp.zeros(M)], axis=1)  # (M, 2)
    w, res = dac(w0, path_graph(M), iters=3)
    assert float(res[-1]) < 1e-12          # old global criterion said 100
    np.testing.assert_allclose(np.asarray(w), np.asarray(w0), atol=1e-12)


def test_dac_until_stops_with_offset_columns():
    """dac_until must terminate when every column converges even though the
    columns settle at different values (the K parallel consensuses of the
    prediction methods always do)."""
    M = 6
    w0 = jnp.stack([jax.random.normal(jax.random.PRNGKey(5), (M,)),
                    50.0 + jax.random.normal(jax.random.PRNGKey(6), (M,))],
                   axis=1)
    w, iters = dac_until(w0, path_graph(M), tol=1e-9, max_iters=50_000)
    want = np.broadcast_to(np.asarray(jnp.mean(w0, 0)), (M, 2))
    np.testing.assert_allclose(np.asarray(w), want, atol=1e-7)
    assert iters < 50_000                  # actually fired, not exhausted


def test_dac_sharded_two_agents_matches_simulated():
    """M=2 ring regression: fwd and bwd ppermute deliver the SAME neighbor,
    which used to be double-counted (deg=1 but nbr summed twice), so sharded
    DAC diverged from the simulated single-edge graph."""
    if jax.device_count() < 2:
        pytest.skip("needs >= 2 devices (run under forced host devices)")
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from functools import partial
    M = 2
    mesh = jax.make_mesh((M,), ("agents",))
    w0 = jax.random.normal(jax.random.PRNGKey(0), (M,))

    @partial(shard_map, mesh=mesh, in_specs=P("agents"), out_specs=P("agents"))
    def run(w):
        return dac_sharded(w, "agents", iters=50, eps=1.0 / 3.0)

    w_sh = run(w0)
    w_sim, _ = dac(w0, cycle_graph(M), iters=50, eps=1.0 / 3.0)
    np.testing.assert_allclose(np.asarray(w_sh), np.asarray(w_sim),
                               atol=1e-12)
    # and both actually reach the average (sanity: not a frozen no-op)
    np.testing.assert_allclose(np.asarray(w_sh), float(jnp.mean(w0)),
                               atol=1e-6)
