"""Fused cached-geometry training hot path (docs/training_engine.md):
gradient equivalence with autodiff, the Pallas kernel vs its blocked jnp
oracle, the grad_fn hook, and trained-hyperparameter equivalence of the
cached ADMM loops vs the seed autodiff loops."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.consensus import path_graph
from repro.core.gp import nll, pack, stripe_partition
from repro.core.training import (build_training_cache, cov_from_cache,
                                 make_local_grad, nll_from_cache,
                                 nll_grad_cached, train_apx_gp, train_c_gp,
                                 train_dec_apx_gp, train_dec_c_gp)
from repro.core.training.cache import TrainingCache
from repro.data import gp_sample_field, random_inputs
from repro.kernels import ops
from repro.kernels.ref import nll_grad_fused_ref

TRUE_LT = pack([1.2, 0.3], 1.3, 0.1)
LT0 = pack([2.0, 0.5], 1.0, 1.0)


def _agent_data(n, D, key=0, dtype=jnp.float64):
    lt_true = pack([1.2] + [0.3] * (D - 1), 1.3, 0.1)
    X = random_inputs(jax.random.PRNGKey(key), n, D=D)
    _, y = gp_sample_field(jax.random.PRNGKey(key + 1), X, lt_true)
    return X.astype(dtype), y.astype(dtype)


def _inner_of(lt, d2u, y):
    n = y.shape[0]
    C, K = cov_from_cache(lt, d2u)
    L = jnp.linalg.cholesky(C)
    Cinv = jax.scipy.linalg.cho_solve((L, True), jnp.eye(n, dtype=C.dtype))
    alpha = Cinv @ y
    return Cinv - jnp.outer(alpha, alpha), K


# -- gradient equivalence ----------------------------------------------------

@pytest.mark.parametrize("D", [1, 2, 4])
@pytest.mark.parametrize("n", [33, 65])          # deliberately tile-unaligned
def test_fused_grad_matches_autodiff_f64(D, n):
    """Cached-geometry fused gradient == jax.grad(nll) to 1e-6 (f64)."""
    X, y = _agent_data(n, D, key=D)
    lt0 = pack([1.5] + [0.7] * (D - 1), 1.0, 0.5)
    cache = build_training_cache(X, y)
    g_auto = jax.grad(nll)(lt0, X, y)
    g_fused = nll_grad_cached(lt0, cache.d2u, y)
    np.testing.assert_allclose(np.asarray(g_fused), np.asarray(g_auto),
                               rtol=1e-6, atol=1e-8)


def test_fused_grad_matches_autodiff_f32():
    """Float32 training path: equivalence to 1e-4, guarded by the
    dtype-aware relative jitter (no NaNs from the f32 Cholesky)."""
    X, y = _agent_data(64, 2, key=7, dtype=jnp.float32)
    lt0 = pack([1.5, 0.7], 1.0, 0.5).astype(jnp.float32)
    cache = build_training_cache(X, y)
    g_auto = jax.grad(nll)(lt0, X, y)
    g_fused = nll_grad_cached(lt0, cache.d2u, y)
    assert np.isfinite(np.asarray(g_fused)).all()
    scale = np.max(np.abs(np.asarray(g_auto)))
    np.testing.assert_allclose(np.asarray(g_fused), np.asarray(g_auto),
                               rtol=1e-4, atol=1e-4 * scale)


def test_f32_nll_guarded_against_singular_gram():
    """Duplicated inputs make K exactly singular; the relative jitter with
    the 8*eps(f32) floor keeps the f32 Cholesky finite even when sigma_eps
    is too small to regularize (the seed's absolute 1e-8 was a no-op)."""
    X = jnp.repeat(random_inputs(jax.random.PRNGKey(0), 16), 2, axis=0)
    X = X.astype(jnp.float32)
    y = jax.random.normal(jax.random.PRNGKey(1), (32,), jnp.float32)
    lt = pack([1.0, 1.0], 1.0, 1e-4).astype(jnp.float32)
    assert np.isfinite(float(nll(lt, X, y)))
    assert np.isfinite(np.asarray(jax.grad(nll)(lt, X, y))).all()


def test_nll_from_cache_matches_nll():
    X, y = _agent_data(80, 2)
    cache = build_training_cache(X, y)
    np.testing.assert_allclose(float(nll_from_cache(LT0, cache.d2u, y)),
                               float(nll(LT0, X, y)), rtol=1e-12)


# -- kernel vs oracle --------------------------------------------------------

def test_fused_ref_blocked_matches_unblocked():
    """The lax.map row-block streaming path == the single fused einsum, and
    reusing a precomputed K changes nothing."""
    X, y = _agent_data(70, 2)
    lt0 = pack([1.5, 0.7], 1.0, 0.5)
    d2u = build_training_cache(X, y).d2u
    inner, K = _inner_of(lt0, d2u, y)
    g = nll_grad_fused_ref(lt0, d2u, inner)
    g_K = nll_grad_fused_ref(lt0, d2u, inner, K=K)
    g_blk = nll_grad_fused_ref(lt0, d2u, inner, bn=32)
    g_blk_K = nll_grad_fused_ref(lt0, d2u, inner, K=K, bn=32)
    for other in (g_K, g_blk, g_blk_K):
        np.testing.assert_allclose(np.asarray(other), np.asarray(g),
                                   rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("D", [1, 2, 4])
def test_pallas_kernel_interpret_matches_ref(D):
    """One-pass Pallas kernel (interpret mode, f32 compute, zero-padded to
    tiles) == the jnp oracle."""
    X, y = _agent_data(70, D, key=D + 3)
    lt0 = pack([1.5] + [0.7] * (D - 1), 1.0, 0.5)
    d2u = build_training_cache(X, y).d2u
    inner, _ = _inner_of(lt0, d2u, y)
    g_ref = ops.nll_grad_fused(lt0, d2u, inner, use_pallas=False)
    g_pal = ops.nll_grad_fused(lt0, d2u, inner, use_pallas=True,
                               interpret=True, bn=32, bm=32)
    scale = np.max(np.abs(np.asarray(g_ref)))
    np.testing.assert_allclose(np.asarray(g_pal), np.asarray(g_ref),
                               rtol=1e-3, atol=1e-4 * scale)


# -- the grad_fn hook --------------------------------------------------------

def test_make_local_grad_resolutions():
    X, y = _agent_data(40, 2)
    Xp, yp = X[None], y[None]
    for grad_fn in (None, "fused"):
        prepare, g = make_local_grad(grad_fn)
        aux = prepare(Xp, yp)
        assert isinstance(aux, TrainingCache)
        assert aux.d2u.shape == (1, 2, 40, 40)
        got = g(LT0, jax.tree.map(lambda a: a[0], aux))
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(jax.grad(nll)(LT0, X, y)),
                                   rtol=1e-6, atol=1e-8)
    prepare, g = make_local_grad("autodiff")
    aux = prepare(Xp, yp)
    got = g(LT0, jax.tree.map(lambda a: a[0], aux))
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(jax.grad(nll)(LT0, X, y)))


def test_cache_memory_guard_falls_back_to_autodiff():
    """The default hook estimates the O(M D N^2) diff^2 cache at trace time
    and falls back to autodiff gradients past the limit (same policy as
    fit_experts' cross-Gram guard); grad_fn='fused' is the unguarded
    opt-in. Gradients are identical either way."""
    X, y = _agent_data(40, 2)
    Xp, yp = X[None], y[None]
    prepare, g = make_local_grad(None, cache_limit_mb=1e-6)
    with pytest.warns(UserWarning, match="falling back to autodiff"):
        aux = prepare(Xp, yp)
    assert not isinstance(aux, TrainingCache)
    got = g(LT0, jax.tree.map(lambda a: a[0], aux))
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(jax.grad(nll)(LT0, X, y)),
                               rtol=1e-12, atol=1e-12)
    prepare_forced, _ = make_local_grad("fused")
    assert isinstance(prepare_forced(Xp, yp), TrainingCache)


def test_grad_fn_custom_callable():
    """A custom callable hooks straight into the ADMM loop (here: a scaled
    gradient, which must visibly change the trajectory)."""
    X, y = _agent_data(200, 2)
    Xp, yp = stripe_partition(X, y, 4)
    A = path_graph(4)

    def scaled(lt, Xi, yi):
        return 0.5 * jax.grad(nll)(lt, Xi, yi)

    th_default, _ = train_dec_apx_gp(LT0, Xp, yp, A, iters=20)
    th_custom, _ = train_dec_apx_gp(LT0, Xp, yp, A, iters=20, grad_fn=scaled)
    assert np.isfinite(np.asarray(th_custom)).all()
    assert float(jnp.max(jnp.abs(th_default - th_custom))) > 1e-6


# -- trained-hyperparameter equivalence: cached loops vs seed loops ----------

@pytest.fixture(scope="module")
def fleet_data():
    X = random_inputs(jax.random.PRNGKey(0), 600)
    _, y = gp_sample_field(jax.random.PRNGKey(1), X, TRUE_LT)
    return stripe_partition(X, y, 4)


def test_trained_equiv_dec_apx(fleet_data):
    Xp, yp = fleet_data
    A = path_graph(4)
    th_f, hist_f = train_dec_apx_gp(LT0, Xp, yp, A, iters=100)
    th_a, hist_a = train_dec_apx_gp(LT0, Xp, yp, A, iters=100,
                                    grad_fn="autodiff")
    np.testing.assert_allclose(np.asarray(th_f), np.asarray(th_a),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(hist_f["residuals"]),
                               np.asarray(hist_a["residuals"]),
                               rtol=1e-4, atol=1e-8)


def test_trained_equiv_apx(fleet_data):
    Xp, yp = fleet_data
    z_f, th_f, _ = train_apx_gp(LT0, Xp, yp, iters=100)
    z_a, th_a, _ = train_apx_gp(LT0, Xp, yp, iters=100, grad_fn="autodiff")
    np.testing.assert_allclose(np.asarray(z_f), np.asarray(z_a),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(th_f), np.asarray(th_a),
                               rtol=1e-6, atol=1e-6)


def test_trained_equiv_dec_c(fleet_data):
    Xp, yp = fleet_data
    A = path_graph(4)
    th_f, _ = train_dec_c_gp(LT0, Xp, yp, A, iters=8, nested_iters=4)
    th_a, _ = train_dec_c_gp(LT0, Xp, yp, A, iters=8, nested_iters=4,
                             grad_fn="autodiff")
    np.testing.assert_allclose(np.asarray(th_f), np.asarray(th_a),
                               rtol=1e-6, atol=1e-6)


def test_trained_equiv_c(fleet_data):
    Xp, yp = fleet_data
    z_f, _, _ = train_c_gp(LT0, Xp, yp, iters=8, nested_iters=4)
    z_a, _, _ = train_c_gp(LT0, Xp, yp, iters=8, nested_iters=4,
                           grad_fn="autodiff")
    np.testing.assert_allclose(np.asarray(z_f), np.asarray(z_a),
                               rtol=1e-6, atol=1e-6)


def test_sharded_cached_matches_simulated():
    """The per-shard TrainingCache build (inside shard_map, outside the
    scan) reproduces the simulated vmapped cache path exactly."""
    if jax.device_count() < 4:
        pytest.skip("needs >= 4 devices (run under forced host devices)")
    from repro.core.consensus import cycle_graph
    from repro.core.training import train_dec_apx_gp_sharded
    X = random_inputs(jax.random.PRNGKey(0), 400)
    _, y = gp_sample_field(jax.random.PRNGKey(1), X, TRUE_LT)
    Xp, yp = stripe_partition(X, y, 4)
    mesh = jax.make_mesh((4,), ("agents",))
    th_sh, _ = train_dec_apx_gp_sharded(mesh, "agents", LT0, Xp, yp, iters=30)
    th_sim, _ = train_dec_apx_gp(LT0, Xp, yp, cycle_graph(4), iters=30)
    np.testing.assert_allclose(np.asarray(th_sh), np.asarray(th_sim),
                               rtol=1e-6, atol=1e-8)
