"""repro.fleet: registry completeness, facade == legacy, persistence.

Acceptance gates for the lifecycle facade:

  * the method/trainer registries are COMPLETE against the engines (a
    method added to an engine without a registry entry fails here) and
    every capability flag matches reality — shardable entries serve on the
    ShardedEngine and non-shardable ones are rejected, online-safe entries
    accept `OnlineExperts.to_fitted()` hot-swaps, augmented-data entries
    get their communication experts built by the facade;
  * `GPFleet.fit().predict()` equals the legacy per-function path at
    <= 1e-6 f64 for ALL 13 methods (replicated), the DAC family sharded,
    and the routable family routed;
  * every registered trainer matches its legacy trained theta EXACTLY;
  * a fleet saved with `GPFleet.save()` and loaded back serves
    bit-identical predictions without refitting;
  * `FleetConfig()` defaults reproduce configs/paper_gp.py exactly.

Runs on 1 device in tier-1 and on 8 forced host devices in the CI
sharded-mode step (the sharded/routed cases then exercise real meshes).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_gp import CONFIG as PAPER
from repro.core.consensus import complete_graph, path_graph
from repro.core.gp import augment, communication_dataset, pack, \
    stripe_partition
from repro.core.prediction import PredictionEngine, ShardedEngine
from repro.core.training import (train_apx_gp, train_c_gp, train_dec_apx_gp,
                                 train_dec_apx_gp_sharded, train_dec_c_gp,
                                 train_dec_gapx_gp, train_fact_gp,
                                 train_gapx_gp)
from repro.data import gp_sample_field, random_inputs
from repro.fleet import (METHODS, TRAINERS, FleetConfig, GPFleet, get_method,
                         get_trainer, method_names, trainer_names,
                         validate_config)
from repro.launch.mesh import make_agent_mesh

TRUE_LT = pack([1.2, 0.3], 1.3, 0.1)
M = 4
NT = 17          # deliberately not a multiple of the engine chunk (8)
ITERS = 150
COMM_KEY = jax.random.PRNGKey(3)

BASE = dict(num_agents=M, chunk=8, dac_iters=ITERS, jor_iters=400,
            dale_iters=800, eta_nn=0.1)


@pytest.fixture(scope="module")
def data():
    X = random_inputs(jax.random.PRNGKey(0), 240)
    _, y = gp_sample_field(jax.random.PRNGKey(1), X, TRUE_LT)
    Xp, yp = stripe_partition(X, y, M)
    Xs = random_inputs(jax.random.PRNGKey(2), NT)
    Xc, yc = communication_dataset(COMM_KEY, Xp, yp)
    Xa, ya = augment(Xp, yp, Xc, yc)
    return Xp, yp, Xs, Xc, yc, Xa, ya


def _fit(cfg, data, **kw):
    Xp, yp, *_ = data
    return GPFleet(cfg).fit(Xp, yp, key=COMM_KEY, log_theta0=TRUE_LT,
                            train=False, **kw)


@pytest.fixture(scope="module")
def fleet(data):
    """Path-graph replicated fleet with augmented/communication experts."""
    return _fit(FleetConfig(method="nn_grbcm", **BASE), data)


@pytest.fixture(scope="module")
def fleet_complete(data):
    """Complete-graph fleet (the NPAE family needs strongly-complete)."""
    return _fit(FleetConfig(method="npae", graph="complete", **BASE), data)


@pytest.fixture(scope="module")
def fleet_sharded(data):
    """Agent-sharded fleet (exact ring consensus: tight equivalence)."""
    return _fit(FleetConfig(method="nn_grbcm", sharded=True,
                            consensus="exact", **BASE), data)


def assert_matches(out, ref, tol=1e-6):
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(ref[0]),
                               atol=tol)
    np.testing.assert_allclose(np.asarray(out[1]), np.asarray(ref[1]),
                               atol=tol)


# ---------------------------------------------------------------------------
# Registry completeness: one table, no silent drift
# ---------------------------------------------------------------------------


def test_method_registry_is_the_papers_13_plus_sparse():
    assert len(METHODS) == 14
    assert set(method_names()) == {
        "poe", "gpoe", "bcm", "rbcm", "grbcm", "npae", "npae_star",
        "nn_poe", "nn_gpoe", "nn_bcm", "nn_rbcm", "nn_grbcm", "nn_npae",
        "npae_sparse"}
    for name in method_names():
        spec = get_method(name)
        assert spec.name == name
        assert callable(spec.legacy) and callable(spec.legacy_call)
        assert spec.family in ("dac", "npae", "sparse")


def test_trainer_registry_is_the_papers_loops():
    assert set(trainer_names()) == {"fact", "c", "apx", "gapx", "dec-c",
                                    "dec-apx", "dec-gapx",
                                    "dec-apx-sharded", "fact-sparse",
                                    "dec-apx-sparse"}
    for name in trainer_names():
        assert callable(get_trainer(name).run)


def test_registry_matches_engines():
    """Engine method lists == registry-derived sets: drift fails here."""
    cen = {m for m in PredictionEngine.METHODS if m.startswith("cen_")}
    assert set(PredictionEngine.METHODS) == set(method_names()) | cen
    assert set(ShardedEngine.METHODS) == {
        n for n, s in METHODS.items() if s.shardable}


def test_capability_flags_internally_consistent():
    for name, s in METHODS.items():
        if s.routable:
            assert s.shardable and name.startswith("nn_")
        assert s.needs_augmented_data == ("grbcm" in name)
        assert s.online_safe == ("grbcm" not in name
                                 and s.family != "sparse")
        if s.family == "npae":
            assert not s.shardable       # strongly-complete exchange
        # exactly the dense-NPAE family cannot serve from SparseExperts
        assert s.sparse == (s.family != "npae")


def test_unknown_names_fail_loudly():
    with pytest.raises(KeyError, match="unknown prediction method"):
        get_method("nope")
    with pytest.raises(KeyError, match="unknown trainer"):
        get_trainer("sgd")


# ---------------------------------------------------------------------------
# Facade predict == legacy free-function path (all 13 methods, replicated)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(
    n for n, s in METHODS.items() if s.family != "sparse"))
def test_facade_matches_legacy(name, data, fleet, fleet_complete):
    Xp, yp, Xs, Xc, yc, Xa, ya = data
    fl = fleet_complete if name in ("npae", "npae_star") else fleet
    A = complete_graph(M) if name in ("npae", "npae_star") else path_graph(M)
    spec = get_method(name)
    ref = spec.legacy_call(fl.config, TRUE_LT, Xp, yp, Xs, A, Xc, yc, Xa, ya)
    out = fl.predict(Xs, method=name)
    assert_matches(out, ref)
    if name.startswith("nn_"):
        np.testing.assert_array_equal(np.asarray(out[2]["mask"]),
                                      np.asarray(ref[2]["mask"]))


def test_facade_centralized_reference_passthrough(data, fleet):
    Xs = data[2]
    mean, var, _ = fleet.predict(Xs, method="cen_poe")
    assert np.all(np.isfinite(np.asarray(mean)))
    assert np.all(np.asarray(var) > 0)


# ---------------------------------------------------------------------------
# Sharded / routed capability flags match reality
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(
    n for n, s in METHODS.items()
    if s.shardable and s.family != "sparse"))
def test_sharded_matches_replicated(name, data, fleet, fleet_sharded):
    Xs = data[2]
    assert_matches(fleet_sharded.predict(Xs, method=name),
                   fleet.predict(Xs, method=name))


@pytest.mark.parametrize("name", sorted(
    n for n, s in METHODS.items() if not s.shardable))
def test_sharded_rejects_npae_family(name, data, fleet_sharded):
    with pytest.raises(ValueError, match="not servable"):
        fleet_sharded.predict(data[2], method=name)


@pytest.mark.parametrize("name", sorted(
    n for n, s in METHODS.items() if s.routable))
def test_routed_matches_full_on_one_shard(name, data):
    """On a 1-device mesh the routed block IS the fleet, so CBNN routing
    must equal the full nn_* aggregate exactly (the construction the
    multi-device exactness tests in test_sharded_serving build per shard).
    """
    cfg = FleetConfig(method="nn_grbcm", sharded=True, routed=True,
                      consensus="exact", max_shard_devices=1, **BASE)
    fl = _fit(cfg, data)
    rep = FleetConfig(method="nn_grbcm", **BASE)
    fl_rep = _fit(rep, data)
    Xs = data[2]
    assert_matches(fl.predict(Xs, method=name),
                   fl_rep.predict(Xs, method=name))


# ---------------------------------------------------------------------------
# Online-safe flags match reality (OnlineExperts.to_fitted hot-swaps)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def fleet_online(data):
    Xp, yp, *_ = data
    cfg = FleetConfig(online=True, method="rbcm", **BASE)
    fl = GPFleet(cfg).fit(Xp, yp, log_theta0=TRUE_LT, train=False)
    xs = random_inputs(jax.random.PRNGKey(11), M)
    ys = jnp.zeros((M,), Xp.dtype)
    return fl.observe(xs, ys)


@pytest.mark.parametrize("name", sorted(
    n for n, s in METHODS.items() if s.online_safe))
def test_online_safe_methods_serve_live_windows(name, data, fleet_online):
    mean, var, _ = fleet_online.predict(data[2], method=name)
    assert np.all(np.isfinite(np.asarray(mean)))
    assert np.all(np.asarray(var) > 0)


def test_online_unsafe_methods_rejected():
    for name, s in METHODS.items():
        if s.online_safe:
            continue
        with pytest.raises(ValueError, match="online"):
            GPFleet(FleetConfig(online=True, method=name, **BASE))


def test_online_matches_batch_before_observing(data, fleet):
    """A freshly seeded window (no stream events yet) serves the batch
    answer: to_fitted() is a faithful FittedExperts view."""
    Xp, yp, Xs, *_ = data
    cfg = FleetConfig(online=True, method="rbcm", **BASE)
    fl = GPFleet(cfg).fit(Xp, yp, log_theta0=TRUE_LT, train=False)
    assert_matches(fl.predict(Xs), fleet.predict(Xs, method="rbcm"))


# ---------------------------------------------------------------------------
# Trainers: facade fit == legacy trained theta EXACTLY
# ---------------------------------------------------------------------------

_TRAIN = dict(num_agents=M, admm_iters=3, nested_iters=2, fact_steps=5)


def _legacy_theta(name, cfg, lt0, Xp, yp, Xa, ya):
    from repro.core.sparse import (make_sparse_grad, select_inducing,
                                   train_fact_sparse)
    if name == "fact":
        return train_fact_gp(lt0, Xp, yp, steps=cfg.fact_steps,
                             lr=cfg.fact_lr)[0]
    if name == "fact-sparse":
        Z0 = select_inducing(Xp, cfg.sparse_m, cfg.inducing_init)
        return train_fact_sparse(lt0, Xp, yp, Z0, steps=cfg.fact_steps,
                                 lr=cfg.fact_lr, jitter=cfg.jitter)[0]
    if name == "dec-apx-sparse":
        thetas, _ = train_dec_apx_gp(
            lt0, Xp, yp, path_graph(M), rho=cfg.rho, kappa=cfg.kappa,
            iters=cfg.admm_iters,
            grad_fn=make_sparse_grad(cfg.sparse_m, jitter=cfg.jitter))
        return jnp.mean(thetas, axis=0)
    if name == "c":
        return train_c_gp(lt0, Xp, yp, rho=cfg.rho, iters=cfg.admm_iters,
                          nested_iters=cfg.nested_iters,
                          nested_lr=cfg.nested_lr)[0]
    if name == "apx":
        return train_apx_gp(lt0, Xp, yp, rho=cfg.rho, L=cfg.lipschitz,
                            iters=cfg.admm_iters)[0]
    if name == "gapx":
        return train_gapx_gp(lt0, Xa, ya, rho=cfg.rho, L=cfg.lipschitz,
                             iters=cfg.admm_iters)[0]
    A = path_graph(M)
    if name == "dec-c":
        thetas, _ = train_dec_c_gp(lt0, Xp, yp, A, rho=cfg.rho,
                                   iters=cfg.admm_iters,
                                   nested_iters=cfg.nested_iters,
                                   nested_lr=cfg.nested_lr)
    elif name == "dec-apx":
        thetas, _ = train_dec_apx_gp(lt0, Xp, yp, A, rho=cfg.rho,
                                     kappa=cfg.kappa, iters=cfg.admm_iters)
    elif name == "dec-gapx":
        thetas, _ = train_dec_gapx_gp(lt0, Xa, ya, A, rho=cfg.rho,
                                      kappa=cfg.kappa, iters=cfg.admm_iters)
    else:
        assert name == "dec-apx-sharded"
        thetas, _ = train_dec_apx_gp_sharded(
            make_agent_mesh(M), "agents", lt0, Xp, yp, rho=cfg.rho,
            kappa=cfg.kappa, iters=cfg.admm_iters)
    return jnp.mean(thetas, axis=0)


@pytest.mark.parametrize("name", sorted(trainer_names()))
def test_trainer_matches_legacy_theta_exactly(name, data):
    Xp, yp, Xs, Xc, yc, Xa, ya = data
    if name == "dec-apx-sharded" and len(jax.devices()) < M:
        pytest.skip(f"dec-apx-sharded needs {M} devices (one per agent)")
    sparse = dict(sparse_m=16) if name in ("fact-sparse",
                                           "dec-apx-sparse") else {}
    cfg = FleetConfig(trainer=name, method="rbcm", **_TRAIN, **sparse)
    lt0 = pack([2.0, 0.5], 1.0, 1.0)
    fl = GPFleet(cfg).fit(Xp, yp, key=COMM_KEY, log_theta0=lt0)
    want = _legacy_theta(name, cfg, lt0, Xp, yp, Xa, ya)
    np.testing.assert_array_equal(np.asarray(fl.log_theta),
                                  np.asarray(want))
    assert fl.thetas.shape == (M, lt0.shape[0])


# ---------------------------------------------------------------------------
# Persistence: save -> load serves bit-identical predictions, no refit
# ---------------------------------------------------------------------------


def test_save_load_bit_identical(data, fleet, tmp_path):
    Xs = data[2]
    want = fleet.predict(Xs, method="nn_grbcm")
    fleet.save(str(tmp_path))
    fl2 = GPFleet.load(str(tmp_path))
    assert fl2.config == fleet.config
    got = fl2.predict(Xs, method="nn_grbcm")
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))
    # the augmented/communication experts made the trip too
    assert fl2.fitted_aug is not None and fl2.fitted_comm is not None


def test_save_load_online_state(data, fleet_online, tmp_path):
    Xs = data[2]
    want = fleet_online.predict(Xs)
    fleet_online.save(str(tmp_path))
    fl2 = GPFleet.load(str(tmp_path))
    got = fl2.predict(Xs)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    # the window state survived: the stream can continue where it stopped
    np.testing.assert_array_equal(np.asarray(fl2.window_counts),
                                  np.asarray(fleet_online.window_counts))
    fl2.observe(random_inputs(jax.random.PRNGKey(13), M),
                jnp.zeros((M,), Xs.dtype))


def test_save_load_new_process_bit_identical(data, fleet, tmp_path):
    """The acceptance criterion verbatim: a FRESH PROCESS loads the saved
    fleet and serves bit-identical predictions without refitting."""
    import os
    import subprocess
    import sys

    import repro
    Xs = data[2]
    want = np.asarray(fleet.predict(Xs, method="rbcm")[0])
    fleet.save(str(tmp_path))
    np.save(tmp_path / "Xs.npy", np.asarray(Xs))
    np.save(tmp_path / "want.npy", want)
    src = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
    script = (
        "import jax; jax.config.update('jax_enable_x64', True)\n"
        "import numpy as np\n"
        "from repro.fleet import GPFleet\n"
        f"d = {str(tmp_path)!r}\n"
        "fl = GPFleet.load(d)\n"
        "m, v, _ = fl.predict(np.load(d + '/Xs.npy'), method='rbcm')\n"
        "np.testing.assert_array_equal(np.asarray(m),\n"
        "                              np.load(d + '/want.npy'))\n")
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr


def test_load_validates_against_corruption(data, fleet, tmp_path):
    import numpy as onp
    fleet.save(str(tmp_path))
    path = tmp_path / "step_00000000.npz"
    stored = dict(onp.load(str(path)))
    stored["['fitted'].alpha"] = stored["['fitted'].alpha"][:, :-1]
    onp.savez(str(path), **stored)
    with pytest.raises(ValueError, match="does not match the template"):
        GPFleet.load(str(tmp_path))


def test_load_missing_manifest(tmp_path):
    with pytest.raises(FileNotFoundError, match="fleet.json"):
        GPFleet.load(str(tmp_path))


def test_load_with_config_override(data, fleet, tmp_path):
    fleet.save(str(tmp_path))
    cfg = fleet.config.replace(method="poe")
    fl2 = GPFleet.load(str(tmp_path), config=cfg)
    assert_matches(fl2.predict(data[2]), fleet.predict(data[2],
                                                       method="poe"))


# ---------------------------------------------------------------------------
# Config: paper defaults, validation, serialization
# ---------------------------------------------------------------------------


def test_config_defaults_reproduce_paper_gp():
    c = FleetConfig()
    assert (c.input_dim, c.theta0, c.num_agents, c.graph, c.rho, c.kappa,
            c.lipschitz, c.admm_iters, c.nested_lr, c.eta_nn) == (
        PAPER.input_dim, PAPER.theta0, PAPER.fleets[0], PAPER.graph,
        PAPER.rho, PAPER.kappa, PAPER.lipschitz, PAPER.admm_iters,
        PAPER.nested_lr, PAPER.eta_nn)


def test_config_json_roundtrip():
    c = FleetConfig(num_agents=8, method="nn_npae", trainer="dec-gapx",
                    online=True, window=64)
    assert FleetConfig.from_json(c.to_json()) == c
    with pytest.raises(ValueError, match="unknown FleetConfig fields"):
        FleetConfig.from_dict({"warp_drive": 1})


def test_config_rejects_bad_values():
    with pytest.raises(ValueError, match="graph"):
        FleetConfig(graph="torus")
    with pytest.raises(ValueError, match="consensus"):
        FleetConfig(consensus="gossip")
    with pytest.raises(ValueError, match="theta0"):
        FleetConfig(input_dim=3)


def test_capability_invalid_combos_rejected():
    with pytest.raises(ValueError, match="not servable"):
        validate_config(FleetConfig(method="npae", sharded=True))
    with pytest.raises(ValueError, match="routable|routing"):
        validate_config(FleetConfig(method="rbcm", sharded=True,
                                    routed=True))
    with pytest.raises(ValueError, match="sharded"):
        validate_config(FleetConfig(method="nn_rbcm", routed=True))
    with pytest.raises(ValueError, match="online"):
        validate_config(FleetConfig(method="grbcm", online=True))
    with pytest.raises(ValueError, match="cross-Gram"):
        validate_config(FleetConfig(method="rbcm", sharded=True,
                                    cache_cross=True))


def test_config_is_static_pytree():
    c = FleetConfig(num_agents=8)
    assert jax.tree.leaves(c) == []          # static: no array leaves

    @jax.jit
    def f(cfg, x):
        return x * cfg.num_agents

    assert float(f(c, jnp.asarray(2.0))) == 16.0


# ---------------------------------------------------------------------------
# Facade guard rails
# ---------------------------------------------------------------------------


def test_unfitted_fleet_refuses_to_serve():
    fl = GPFleet(FleetConfig(**BASE))
    with pytest.raises(RuntimeError, match="fit"):
        fl.predict(jnp.zeros((3, 2)))
    with pytest.raises(RuntimeError, match="fit"):
        fl.save("/tmp/nowhere")


def test_fit_rejects_wrong_agent_count(data):
    Xp, yp, *_ = data
    fl = GPFleet(FleetConfig(num_agents=M + 1, **{k: v for k, v in
                                                  BASE.items()
                                                  if k != "num_agents"}))
    with pytest.raises(ValueError, match="num_agents"):
        fl.fit(Xp, yp)


def test_observe_requires_online_fleet(data, fleet):
    with pytest.raises(RuntimeError, match="online"):
        fleet.observe(jnp.zeros((M, 2)), jnp.zeros(M))


def test_serve_gp_cli_rejects_invalid_combos():
    from repro.launch.serve_gp import main
    for argv in (["--method", "npae", "--sharded"],
                 ["--method", "grbcm", "--online"],
                 ["--method", "rbcm", "--routed"],
                 ["--method", "made_up"],
                 ["--trainer", "sgd"],
                 ["--method", "npae-sparse"],          # needs --sparse-m
                 ["--trainer", "fact-sparse"],         # needs --sparse-m
                 ["--method", "npae", "--sparse-m", "16"]):   # dense-only
        with pytest.raises(SystemExit):
            main(argv)
