"""Cost-model cross-validation: the analytic FLOPs used for the roofline
agree with compiled HLO cost analysis when scan trip counts are 1 (single
layer group — the regime where XLA's count-body-once limitation is exact)."""
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, ".")  # repo root for benchmarks package
from benchmarks import costmodel as cm
from repro.models.config import ArchConfig
from repro.models import lm


def _one_layer_cfg(**kw):
    base = dict(name="val", arch_type="dense", num_layers=1, d_model=256,
                num_heads=4, num_kv_heads=2, d_ff=512, vocab_size=512,
                moe_group_size=64, use_pallas=False)
    base.update(kw)
    return ArchConfig(**base)


def _compiled_fwd_flops(cfg, B, S):
    params = jax.eval_shape(lambda: lm.init_params(cfg, jax.random.PRNGKey(0)))
    toks = jax.ShapeDtypeStruct((B, S), jnp.int32)

    def f(p, t):
        logits, aux, _ = lm.forward(cfg, p, t)
        return logits

    c = jax.jit(f).lower(params, toks).compile()
    return cm.compiled_flops(c)


def test_xla_counts_scan_body_once():
    """Documents the limitation that motivates the analytic model."""
    n = 256
    W = jax.ShapeDtypeStruct((8, n, n), jnp.float32)
    x = jax.ShapeDtypeStruct((n, n), jnp.float32)

    def scanned(x, W):
        return jax.lax.scan(lambda c, w: (c @ w, None), x, W)[0]

    got = cm.compiled_flops(jax.jit(scanned).lower(x, W).compile())
    assert abs(got - 2 * n**3) / (2 * n**3) < 0.01   # 1 body, not 8


@pytest.mark.parametrize("kw,tol", [
    (dict(), 0.35),
    (dict(num_kv_heads=4), 0.35),
    (dict(arch_type="moe", num_experts=4, experts_per_token=2), 0.45),
])
def test_analytic_flops_match_compiled_single_layer(kw, tol):
    cfg = _one_layer_cfg(**kw)
    B, S = 4, 128
    got = _compiled_fwd_flops(cfg, B, S)
    want = cm.fwd_flops_per_token(cfg, S // 2) * B * S
    rel = abs(got - want) / want
    assert rel < tol, (got, want, rel)


def test_param_counts_match_real_params():
    for arch_kw in (dict(), dict(arch_type="moe", num_experts=4,
                                 experts_per_token=2),
                    dict(block_type="xlstm", slstm_every=1, mlp_act="gelu")):
        cfg = _one_layer_cfg(**arch_kw)
        params = jax.eval_shape(
            lambda: lm.init_params(cfg, jax.random.PRNGKey(0)))
        real = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
        model, _ = cm.param_counts(cfg)
        assert abs(model - real) / real < 0.1, (model, real)


def test_roofline_terms_positive_and_dominant():
    from repro.configs import get_config
    r = cm.analyze(get_config("internlm2-1.8b"), "train_4k")
    t = r.terms()
    assert all(v > 0 for v in t.values())
    assert r.dominant in t
    # training compute term must be within sane MFU range of model flops
    ratio = r.model_flops / (r.flops * 256)
    assert 0.2 < ratio <= 1.0
