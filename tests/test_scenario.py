"""Closed-loop multi-robot scenario harness: the replayable integration
pack (ISSUE 9 acceptance).

The `@pytest.mark.scenario` missions run a full closed loop — observe ->
drift-retrain -> routed predict -> chaos -> recover — across a seed x
topology matrix and assert end-state invariants:

  - no hung futures, every submitted request accounted for;
  - zero recompiles after warm-up on a clean mission, and under chaos
    recompiles ONLY at membership-change steps (leave/join retrace — the
    fleet changed shape; everything else hot-swaps);
  - health census matches the injected fault plan (alive curve follows
    the dropout window, fleet size and connectivity restored);
  - accuracy-over-time improves and drift-epoch NLL is monotone within
    tolerance (gpoe aggregation: rBCM's precision-summing is NLL-unstable
    on sparse coverage — see the mission preset note in scenario/config);
  - bit-identical replay: same config => same `replay_digest()`.

The unmarked tests are the fast tier-1 subset: config round-trip and
validation, trajectory/field determinism, bench-schema checking, and the
loadgen Poisson-timeline regression (same seed => same arrivals).
"""
import json

import numpy as np
import pytest

from benchmarks.loadgen import TenantLoad, poisson_timeline
from repro.scenario import (ScenarioConfig, agent_paths, make_field, preset,
                            run_scenario, validate_bench)

SEEDS = (0, 1, 2)
GRAPHS = ("cycle", "complete")

# one shape for every mission in the matrix: ~3 s each after warm-up
_TINY = dict(num_agents=4, method="gpoe", steps=9, warmup_obs=5, window=14,
             dac_iters=40, admm_iters=4, drift_every=3, drift_iters=3,
             eval_points=24, field_features=96, queries_per_step=1,
             query_rows=3, max_slot=8, chunk=8)
_CHAOS = dict(dropouts=((1, 2, 6),), straggle_every=3, straggle_ms=1.0,
              fail_every=5, edge_loss=0.05)


def tiny(seed=0, graph="cycle", *, chaos=True):
    extra = _CHAOS if chaos else {}
    return ScenarioConfig(seed=seed, fault_seed=seed, graph=graph,
                          **_TINY, **extra)


_cache: dict = {}


def run_cached(cfg):
    key = cfg.to_json()
    if key not in _cache:
        _cache[key] = run_scenario(cfg)
    return _cache[key]


# ---------------------------------------------------------------------------
# the mission matrix (tentpole): seeds x topologies, chaos on
# ---------------------------------------------------------------------------

@pytest.mark.scenario
@pytest.mark.parametrize("graph", GRAPHS)
@pytest.mark.parametrize("seed", SEEDS)
def test_chaos_mission_end_state_invariants(seed, graph):
    r = run_cached(tiny(seed, graph))

    # serving: nothing hung, every future accounted for, injected
    # transient failures absorbed by the retry path (fail_every=5 fired)
    assert r.hung_futures == 0
    s = r.serving
    assert s["completed"] + s["dropped"] + s["failed"] == s["submitted"]
    assert s["submitted"] == 9      # queries_per_step=1 x steps
    assert s["failed"] == 0
    assert s["retried"] >= 1

    # health census matches the fault plan: agent 1 out for steps [2, 6),
    # fleet restored to full strength and connected at mission end
    assert r.membership == [(2, "leave", 1), (6, "rejoin", 1)]
    assert r.curves["alive"] == [3 if 2 <= t < 6 else 4 for t in range(9)]
    assert r.health["num_agents"] == 4
    assert r.health["graph_connected"]

    # recompiles ONLY at membership steps (shape changes retrace; observe/
    # drift hot-swap factors into the existing compiled programs)
    assert set(r.recompile_steps) <= {2, 6}

    # degraded consensus (edge_loss) actually exercised on the scheduler
    # path, and every reported number is finite
    assert max(r.curves["degraded_fraction"]) > 0.0
    for k in ("rmse", "nll", "degraded_fraction"):
        assert np.all(np.isfinite(r.curves[k]))

    # accuracy over time: RMSE improves despite the chaos, final NLL beats
    # the start, drift-epoch NLL monotone within tolerance
    assert r.curves["rmse"][-1] < 0.8 * r.curves["rmse"][0]
    assert r.curves["nll"][-1] < r.curves["nll"][0]
    assert len(r.drift_nll) == 3
    for a, b in zip(r.drift_nll, r.drift_nll[1:]):
        assert b <= a + 0.25


@pytest.mark.scenario
def test_clean_mission_zero_recompiles_after_warmup():
    r = run_cached(tiny(0, "cycle", chaos=False))
    assert r.recompile_steps == []
    assert r.hung_futures == 0
    assert r.membership == []
    assert r.serving["failed"] == 0 and r.serving["dropped"] == 0
    assert r.serving["completed"] == r.serving["submitted"]
    assert max(r.curves["degraded_fraction"]) == 0.0
    assert r.curves["rmse"][-1] < r.curves["rmse"][0]


@pytest.mark.scenario
def test_replay_is_bit_identical_and_seed_sensitive():
    cfg = tiny(0, "cycle")
    first = run_cached(cfg)
    again = run_scenario(cfg)            # a genuinely fresh second run
    assert first.replay_digest() == again.replay_digest()
    assert first.curves["rmse"] == again.curves["rmse"]   # bitwise floats
    assert first.curves["nll"] == again.curves["nll"]
    assert first.drift_nll == again.drift_nll
    assert first.membership == again.membership
    other = run_cached(tiny(1, "cycle"))
    assert first.replay_digest() != other.replay_digest()


@pytest.mark.scenario
def test_bench_section_from_mission_is_schema_valid():
    r = run_cached(tiny(0, "cycle"))
    validate_bench({"scenario": r.to_bench()})


# ---------------------------------------------------------------------------
# fast tier-1 subset: config, determinism, schema, loadgen regression
# ---------------------------------------------------------------------------

def test_config_json_round_trip():
    cfg = preset("chaos").replace(seed=7, fault_seed=3,
                                  dropouts=((2, 1, 5), (3, 2, None)))
    blob = cfg.to_json()
    back = ScenarioConfig.from_json(blob)
    assert back == cfg
    assert back.to_json() == blob                     # idempotent
    d = json.loads(blob)
    assert d["dropouts"] == [[2, 1, 5], [3, 2, None]]
    assert d["seed"] == 7 and d["graph"] == cfg.graph


def test_config_validation_rejects_bad_scenarios():
    with pytest.raises(ValueError):
        ScenarioConfig(graph="star")
    with pytest.raises(ValueError):
        ScenarioConfig(theta0=(1.0, 1.0))             # needs D + 2 entries
    with pytest.raises(ValueError):
        ScenarioConfig(warmup_obs=30, window=24)      # evicted pre-mission
    with pytest.raises(ValueError):
        ScenarioConfig(num_agents=1)
    with pytest.raises(ValueError):                   # empty dropout window
        ScenarioConfig(dropouts=((1, 5, 5),))
    with pytest.raises(ValueError):                   # stale-index hazard
        ScenarioConfig(dropouts=((1, 0, None),), nan_agents=(2,))
    with pytest.raises(ValueError):                   # fleet must keep >= 2
        ScenarioConfig(num_agents=3, dropouts=((0, 1, 2), (1, 3, 4)))
    with pytest.raises(ValueError):                   # unknown field
        ScenarioConfig.from_dict({"seed": 0, "robots": 9})


def test_presets_construct_and_unknown_rejected():
    for name in ("smoke", "mission", "chaos"):
        cfg = preset(name)
        assert isinstance(cfg, ScenarioConfig)
        assert ScenarioConfig.from_json(cfg.to_json()) == cfg
    assert preset("chaos").dropouts                   # chaos has churn
    with pytest.raises(ValueError):
        preset("hurricane")


def test_trajectories_and_field_are_seed_deterministic():
    cfg0, cfg1 = tiny(0), tiny(1)
    p0 = agent_paths(cfg0)
    assert p0.shape == (4, _TINY["warmup_obs"] + _TINY["steps"], 2)
    assert np.array_equal(p0, agent_paths(cfg0))      # replay
    assert not np.allclose(p0, agent_paths(cfg1))     # seed-sensitive
    assert p0.min() >= cfg0.lo - 1e-12
    assert p0.max() <= cfg0.hi + 1e-12                # reflection works
    X = p0[:, 0]
    f0, f0b = make_field(cfg0), make_field(cfg0)
    assert np.array_equal(np.asarray(f0.f(X)), np.asarray(f0b.f(X)))
    assert not np.allclose(np.asarray(f0.f(X)),
                           np.asarray(make_field(cfg1).f(X)))


def _valid_bench_doc():
    curve = {"step": [0], "rmse": [0.5], "nll": [0.1], "alive": [4],
             "degraded_fraction": [0.0]}
    return {"scenario": {
        "config": ScenarioConfig().to_dict(),
        "curves": curve,
        "drift": {"step": [], "nll": []},
        "serving": {"submitted": 1, "completed": 1, "dropped": 0,
                    "failed": 0, "retried": 0, "p50_ms": 1.0, "p99_ms": 2.0},
        "invariants": {"hung_futures": 0, "recompile_steps": [],
                       "membership": [], "jit_cache_misses": 3,
                       "graph_connected": True, "final_agents": 4,
                       "replay_digest": "0" * 64},
    }}


def test_validate_bench_accepts_valid_and_rejects_malformed():
    validate_bench(_valid_bench_doc())
    with pytest.raises(ValueError):
        validate_bench({})
    doc = _valid_bench_doc()
    del doc["scenario"]["invariants"]
    with pytest.raises(ValueError):
        validate_bench(doc)
    doc = _valid_bench_doc()
    doc["scenario"]["curves"]["rmse"] = [0.5, 0.4]    # length mismatch
    with pytest.raises(ValueError):
        validate_bench(doc)
    doc = _valid_bench_doc()
    doc["scenario"]["invariants"]["replay_digest"] = "zz"
    with pytest.raises(ValueError):
        validate_bench(doc)
    doc = _valid_bench_doc()
    doc["scenario"]["config"]["robots"] = 9           # unknown config field
    with pytest.raises(ValueError):
        validate_bench(doc)


def test_poisson_timeline_same_seed_same_arrivals():
    loads = [TenantLoad("a", rate=200.0, max_rows=5),
             TenantLoad("b", rate=150.0, max_rows=7)]
    ev1 = poisson_timeline(loads, 0.5, seed=3)
    ev2 = poisson_timeline(loads, 0.5, seed=3)
    assert len(ev1) == len(ev2) > 0
    for (t1, l1, x1), (t2, l2, x2) in zip(ev1, ev2):
        assert t1 == t2 and l1.name == l2.name        # bitwise arrival times
        assert np.array_equal(x1, x2)
    ev3 = poisson_timeline(loads, 0.5, seed=4)
    assert [e[0] for e in ev3] != [e[0] for e in ev1]


def test_poisson_timeline_tenants_are_independent_streams():
    a = TenantLoad("a", rate=200.0, max_rows=5)
    b = TenantLoad("b", rate=150.0, max_rows=7)
    solo = poisson_timeline([a], 0.5, seed=3)
    merged = [e for e in poisson_timeline([a, b], 0.5, seed=3)
              if e[1].name == "a"]
    assert len(solo) == len(merged) > 0               # b never perturbs a
    for (t1, _, x1), (t2, _, x2) in zip(solo, merged):
        assert t1 == t2 and np.array_equal(x1, x2)
    # a per-load seed overrides the run seed for that tenant only
    a9 = TenantLoad("a", rate=200.0, max_rows=5, seed=9)
    override = poisson_timeline([a9], 0.5, seed=3)
    assert [e[0] for e in override] == \
        [e[0] for e in poisson_timeline([a9], 0.5, seed=777)]
    assert [e[0] for e in override] != [e[0] for e in solo]
