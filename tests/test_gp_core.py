"""GP core: kernel math, NLL + gradients, exact GP, partitioning."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.gp import (se_kernel, cov_matrix, pack, unpack, nll,
                           nll_grad_analytic, train_full_gp, predict_full,
                           stripe_partition, communication_dataset, augment)
from repro.data import random_inputs, gp_sample_field

TRUE_LT = pack([1.2, 0.3], 1.3, 0.1)


def _data(n=300, key=0):
    X = random_inputs(jax.random.PRNGKey(key), n)
    _, y = gp_sample_field(jax.random.PRNGKey(key + 1), X, TRUE_LT)
    return X, y


def test_kernel_psd_and_symmetric():
    X, _ = _data(100)
    K = se_kernel(X, X, TRUE_LT)
    assert np.allclose(K, K.T, atol=1e-12)
    evals = np.linalg.eigvalsh(np.asarray(K))
    assert evals.min() > -1e-8
    # diagonal = sigma_f^2
    assert np.allclose(np.diag(K), 1.3**2, atol=1e-10)


def test_kernel_matches_paper_form():
    # paper eq. 2: no factor 2 in the denominator
    x1 = jnp.array([[0.0, 0.0]])
    x2 = jnp.array([[0.5, 0.25]])
    ls, sf, _ = unpack(TRUE_LT)
    want = sf**2 * np.exp(-(0.5**2 / ls[0]**2 + 0.25**2 / ls[1]**2))
    got = se_kernel(x1, x2, TRUE_LT)[0, 0]
    assert np.allclose(got, want, rtol=1e-12)


def test_nll_gradient_analytic_vs_autodiff():
    X, y = _data(150)
    lt0 = pack([2.0, 0.5], 1.0, 1.0)
    g_auto = jax.grad(nll)(lt0, X, y)
    g_ana = nll_grad_analytic(lt0, X, y)
    np.testing.assert_allclose(g_auto, g_ana, rtol=1e-8, atol=1e-10)


@settings(max_examples=10, deadline=None)
@given(st.floats(0.2, 3.0), st.floats(0.2, 3.0), st.floats(0.5, 2.0))
def test_nll_gradient_property(l1, l2, sf):
    """Property: analytic trace-identity gradient == autodiff, any theta."""
    X, y = _data(60)
    lt = pack([l1, l2], sf, 0.2)
    np.testing.assert_allclose(jax.grad(nll)(lt, X, y),
                               nll_grad_analytic(lt, X, y),
                               rtol=1e-6, atol=1e-8)


def test_full_gp_hyperparameter_recovery():
    X, y = _data(800)
    lt, info = train_full_gp(X, y, jax.random.PRNGKey(2), num_starts=2,
                             steps=150)
    theta = np.exp(np.asarray(lt))
    true = np.exp(np.asarray(TRUE_LT))
    assert np.all(np.abs(np.log(theta / true)) < 0.5), theta


def test_full_gp_prediction_interpolates():
    X, y = _data(400)
    mean, var = predict_full(TRUE_LT, X, y, X[:10])
    # at observed locations the posterior mean is close to y (noise-limited)
    assert float(jnp.mean((mean - y[:10]) ** 2)) < 0.05
    assert np.all(np.asarray(var) > 0)


def test_posterior_variance_shrinks_with_data():
    X, y = _data(400)
    Xs = random_inputs(jax.random.PRNGKey(9), 20)
    _, v_small = predict_full(TRUE_LT, X[:50], y[:50], Xs)
    _, v_big = predict_full(TRUE_LT, X, y, Xs)
    assert float(jnp.mean(v_big)) < float(jnp.mean(v_small))


def test_stripe_partition_shapes_and_disjoint():
    X, y = _data(403)
    Xp, yp = stripe_partition(X, y, 4)
    assert Xp.shape == (4, 100, 2) and yp.shape == (4, 100)
    # stripes are ordered along x-axis
    maxes = np.asarray(Xp[:, :, 0].max(axis=1))
    mins = np.asarray(Xp[:, :, 0].min(axis=1))
    assert np.all(maxes[:-1] <= mins[1:] + 1e-12)


def test_communication_dataset_and_augment():
    X, y = _data(400)
    Xp, yp = stripe_partition(X, y, 4)
    Xc, yc = communication_dataset(jax.random.PRNGKey(3), Xp, yp)
    assert Xc.shape[0] == 4 * (100 // 4)
    Xa, ya = augment(Xp, yp, Xc, yc)
    assert Xa.shape == (4, 100 + Xc.shape[0], 2)
    # every agent's augmented set contains the shared communication data
    np.testing.assert_array_equal(np.asarray(Xa[0, 100:]), np.asarray(Xc))
    np.testing.assert_array_equal(np.asarray(Xa[3, 100:]), np.asarray(Xc))
