"""Per-architecture smoke tests: REDUCED variant of each assigned arch
(2 layers, d_model<=512, <=4 experts), one forward + one train step on CPU,
asserting output shapes and no NaNs — as required by the assignment."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import lm, encdec
from repro.launch.steps import make_train_step, pick_optimizer

B, S = 2, 32


def _batch(cfg, key):
    s_text = S - cfg.vis_tokens if cfg.vis_tokens else S
    toks = jax.random.randint(key, (B, s_text), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.encdec:
        batch["frames"] = 0.1 * jax.random.normal(
            key, (B, cfg.enc_seq, cfg.d_model), jnp.float32)
    if cfg.vis_tokens:
        batch["embeds"] = 0.1 * jax.random.normal(
            key, (B, cfg.vis_tokens, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    assert cfg.num_layers == 2 and cfg.d_model <= 512
    assert cfg.num_experts <= 4
    key = jax.random.PRNGKey(0)
    mod = encdec if cfg.encdec else lm
    params = mod.init_params(cfg, key, jnp.float32)

    batch = _batch(cfg, key)
    # forward
    if cfg.encdec:
        enc_out = encdec.encode(cfg, params, batch["frames"])
        logits, _ = encdec.decode(cfg, params, batch["tokens"], enc_out)
        assert logits.shape == (B, S, cfg.vocab_size)
    else:
        logits, aux, _ = lm.forward(cfg, params, batch["tokens"],
                                    embeds=batch.get("embeds"))
        assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())

    # one train step
    optimizer, _ = pick_optimizer(cfg, lr=1e-3)
    step = jax.jit(make_train_step(cfg, optimizer))
    opt_state = optimizer.init(params)
    params2, opt_state, loss, _ = step(params, opt_state, batch)
    assert np.isfinite(float(loss))
    # parameters actually changed
    delta = max(float(jnp.abs(a - b).max())
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(params2)))
    assert delta > 0

    # second step decreases loss on the same batch (sanity of gradients)
    _, _, loss2, _ = step(params2, opt_state, batch)
    assert float(loss2) < float(loss) + 0.1


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "dbrx-132b",
                                  "jamba-v0.1-52b", "xlstm-350m",
                                  "whisper-small", "internvl2-76b"])
def test_smoke_decode_matches_parallel(arch):
    """Prefill + single-token decode == parallel forward (cache correctness)
    for one representative of each block family."""
    cfg = get_config(arch).reduced().with_overrides(moe_capacity_factor=4.0)
    key = jax.random.PRNGKey(0)
    mod = encdec if cfg.encdec else lm
    params = mod.init_params(cfg, key, jnp.float32)
    batch = _batch(cfg, key)
    toks = batch["tokens"]
    P = cfg.vis_tokens
    if cfg.encdec:
        enc = encdec.encode(cfg, params, batch["frames"])
        cache = encdec.init_decode_cache(cfg, B, S + 4)
        lp, cache = encdec.decode(cfg, params, toks, enc, cache=cache,
                                  logits_slice=1)
        ld, cache = encdec.decode(cfg, params, toks[:, :1], enc, cache=cache)
        toks2 = jnp.concatenate([toks, toks[:, :1]], 1)
        lf, _ = encdec.decode(cfg, params, toks2, enc)
    else:
        cache = lm.init_decode_cache(cfg, B, S + P + 4)
        lp, _, cache = lm.forward(cfg, params, toks,
                                  embeds=batch.get("embeds"), cache=cache,
                                  logits_slice=1)
        ld, _, cache = lm.forward(cfg, params, toks[:, :1], cache=cache)
        toks2 = jnp.concatenate([toks, toks[:, :1]], 1)
        lf, _, _ = lm.forward(cfg, params, toks2, embeds=batch.get("embeds"))
    err = float(jnp.abs(ld[:, -1] - lf[:, -1]).max())
    assert err < 5e-4, err


def test_full_configs_match_assignment():
    """Exact assigned dimensions (the brief's table)."""
    expect = {
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352, 16, 4),
        "whisper-small": (12, 768, 12, 12, 3072, 51865, 0, 0),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536, 16, 2),
        "internlm2-1.8b": (24, 2048, 16, 8, 8192, 92544, 0, 0),
        "granite-3-8b": (40, 4096, 32, 8, 12800, 49155, 0, 0),
        "phi3-medium-14b": (40, 5120, 40, 10, 17920, 100352, 0, 0),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048, 128, 1),
        "internvl2-76b": (80, 8192, 64, 8, 28672, 128256, 0, 0),
        "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024, 0, 0),
    }
    for arch, (L, d, H, kv, ff, V, E, k) in expect.items():
        cfg = get_config(arch)
        assert (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                cfg.d_ff, cfg.vocab_size, cfg.num_experts,
                cfg.experts_per_token) == (L, d, H, kv, ff, V, E, k), arch
    # xlstm: d_ff=0 in the brief means no mLSTM FFN (see config docstring)
    x = get_config("xlstm-350m")
    assert (x.num_layers, x.d_model, x.num_heads, x.vocab_size) == \
        (24, 1024, 4, 50304)
