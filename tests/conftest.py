import jax
import pytest

# GP numerics (Cholesky of nearly-singular covariances) need float64; model
# code uses explicit float32/bfloat16 so this is safe globally in tests.
# NOTE: dryrun.py / production runs do NOT enable x64.
jax.config.update("jax_enable_x64", True)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
