"""Test-session config: float64 numerics and a graceful `hypothesis` fallback.

GP numerics (Cholesky of nearly-singular covariances) need float64; model
code uses explicit float32/bfloat16 so this is safe globally in tests.
NOTE: dryrun.py / production runs do NOT enable x64.

Several tier-1 modules use hypothesis property tests. The container image is
not guaranteed to ship `hypothesis` (it is a dev-only dependency, see
requirements-dev.txt), and a missing import used to kill COLLECTION of five
whole test modules. When the real package is absent we install a minimal,
deterministic stand-in that supports exactly the API surface the suite uses
(`given`, `settings`, `strategies.{integers,floats,sampled_from,booleans}`)
and runs each property on a fixed pseudo-random sample including the
strategy endpoints. Install the real package to get actual shrinking
property-based testing.
"""
import random
import sys
import types

import jax
import pytest

jax.config.update("jax_enable_x64", True)


def _install_hypothesis_fallback():
    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example_at(self, rnd, k):
            return self._draw(rnd, k)

    def integers(min_value, max_value):
        def draw(rnd, k):
            if k == 0:
                return min_value
            if k == 1:
                return max_value
            return rnd.randint(min_value, max_value)
        return _Strategy(draw)

    def floats(min_value, max_value, **_kw):
        def draw(rnd, k):
            if k == 0:
                return float(min_value)
            if k == 1:
                return float(max_value)
            return rnd.uniform(min_value, max_value)
        return _Strategy(draw)

    def sampled_from(elements):
        elements = list(elements)

        def draw(rnd, k):
            if k < len(elements):
                return elements[k]
            return rnd.choice(elements)
        return _Strategy(draw)

    def booleans():
        return sampled_from([False, True])

    _DEFAULT_MAX_EXAMPLES = 10

    def given(*strategies, **kw_strategies):
        def decorate(fn):
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_stub_max_examples",
                            _DEFAULT_MAX_EXAMPLES)
                rnd = random.Random(0)
                for k in range(n):
                    pos = tuple(s.example_at(rnd, k) for s in strategies)
                    kws = {name: s.example_at(rnd, k)
                           for name, s in kw_strategies.items()}
                    fn(*args, *pos, **kwargs, **kws)
            # NOTE: deliberately no __wrapped__ — pytest would follow it and
            # mistake the strategy parameters for fixtures.
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return decorate

    class settings:
        def __init__(self, max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None,
                     **_kw):
            self.max_examples = max_examples

        def __call__(self, fn):
            fn._stub_max_examples = self.max_examples
            return fn

    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = integers
    st_mod.floats = floats
    st_mod.sampled_from = sampled_from
    st_mod.booleans = booleans

    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = st_mod
    hyp.__version__ = "0.0-fallback"
    hyp.IS_FALLBACK_STUB = True
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st_mod


try:  # pragma: no cover - depends on the environment
    import hypothesis  # noqa: F401
except ModuleNotFoundError:  # pragma: no cover
    _install_hypothesis_fallback()


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
