"""Beyond-paper extensions: time-varying-graph DAC (Assumption 1) and the
fused rbf_matvec streaming-prediction kernel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.consensus import dac_time_varying, path_graph
from repro.kernels import ref
from repro.kernels.ops import rbf_matvec


def test_dac_time_varying_union_connectivity():
    """Assumption 1: per-step graphs may be disconnected as long as their
    gamma-window union is strongly connected — DAC still averages."""
    M = 6
    A_full = np.asarray(path_graph(M))
    # alternate between the even-edge and odd-edge halves of the path:
    # each instantaneous graph is disconnected, the union is the path
    A_even = np.zeros_like(A_full)
    A_odd = np.zeros_like(A_full)
    for i in range(M - 1):
        (A_even if i % 2 == 0 else A_odd)[i, i + 1] = 1.0
        (A_even if i % 2 == 0 else A_odd)[i + 1, i] = 1.0
    T = 4000
    A_seq = jnp.asarray(np.stack([A_even if t % 2 == 0 else A_odd
                                  for t in range(T)]))
    w0 = jax.random.normal(jax.random.PRNGKey(0), (M,))
    w, res = dac_time_varying(w0, A_seq, eps=0.3)
    np.testing.assert_allclose(np.asarray(w), float(jnp.mean(w0)), atol=1e-6)
    assert float(res[-1]) < 1e-6


def test_dac_time_varying_static_matches_dac():
    from repro.core.consensus import dac
    M, T = 5, 300
    A = path_graph(M)
    w0 = jax.random.normal(jax.random.PRNGKey(1), (M,))
    w_tv, _ = dac_time_varying(w0, jnp.broadcast_to(A, (T, M, M)), eps=0.3)
    w_st, _ = dac(w0, A, T, eps=0.3)
    np.testing.assert_allclose(np.asarray(w_tv), np.asarray(w_st), atol=1e-10)


@pytest.mark.parametrize("n,m,d", [(100, 130, 2), (256, 256, 3), (300, 70, 5),
                                   (64, 512, 1)])
def test_rbf_matvec_kernel(n, m, d):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    x1 = jax.random.normal(k1, (n, d), jnp.float32)
    x2 = jax.random.normal(k2, (m, d), jnp.float32)
    v = jax.random.normal(k3, (m,), jnp.float32)
    ls = jnp.full((d,), 0.8, jnp.float32)
    got = rbf_matvec(x1, x2, v, ls, 1.3, use_pallas=True, interpret=True)
    want = ref.rbf_matvec_ref(x1, x2, v, ls, 1.3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=6, deadline=None)
@given(st.integers(9, 120), st.integers(9, 120), st.integers(1, 4))
def test_rbf_matvec_property(n, m, d):
    """Property: fused matvec == Gram @ v, arbitrary (unaligned) shapes."""
    x1 = jax.random.normal(jax.random.PRNGKey(n), (n, d), jnp.float32)
    x2 = jax.random.normal(jax.random.PRNGKey(m + 500), (m, d), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(7), (m,), jnp.float32)
    ls = jnp.full((d,), 1.1, jnp.float32)
    got = rbf_matvec(x1, x2, v, ls, 0.9, use_pallas=True, interpret=True)
    want = ref.rbf_matvec_ref(x1, x2, v, ls, 0.9)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_streaming_prediction_mean_matches_full():
    """End-to-end: prediction mean via cached alpha + fused matvec equals
    core.gp.predict_full's mean."""
    from repro.core.gp import pack, predict_full, cov_matrix
    from repro.data import random_inputs, gp_sample_field
    lt = pack([1.2, 0.3], 1.3, 0.1)
    X = random_inputs(jax.random.PRNGKey(0), 400)
    _, y = gp_sample_field(jax.random.PRNGKey(1), X, lt)
    Xs = random_inputs(jax.random.PRNGKey(2), 50)
    mean_ref, _ = predict_full(lt, X, y, Xs)
    C = cov_matrix(X, lt, jitter=1e-8)
    alpha = jnp.linalg.solve(C, y)
    ls = jnp.exp(lt[:2]).astype(jnp.float32)
    mean_stream = rbf_matvec(Xs.astype(jnp.float32), X.astype(jnp.float32),
                             alpha.astype(jnp.float32), ls,
                             float(jnp.exp(lt[2])), use_pallas=True,
                             interpret=True)
    np.testing.assert_allclose(np.asarray(mean_stream),
                               np.asarray(mean_ref), rtol=1e-3, atol=1e-3)
