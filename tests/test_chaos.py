"""Chaos engineering: fault plans, degraded-mode consensus, and the
engine/fleet contracts under injected faults.

The acceptance bar (ISSUE 8): under a seeded FaultPlan with agents
dropping mid-prediction, every DAC-family method returns finite,
degradation-flagged results over the surviving component or raises a
typed error — no NaN, no silent wrongness — and an empty/consensus-free
plan leaves served results BITWISE unchanged.
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.chaos import (Dropout, FaultInjected, FaultPlan,
                         membership_events, wrap_predict_fn)
from repro.core.consensus import (ConsensusDiverged, complete_graph,
                                  connected_components, dac, dac_masked,
                                  dac_masked_sums, path_graph,
                                  random_connected_graph)
from repro.core.gp import pack
from repro.core.prediction.engine import PredictionEngine, fit_experts

M = 8
METHODS = ["poe", "gpoe", "bcm", "rbcm", "grbcm", "npae", "npae_star",
           "nn_poe", "nn_gpoe", "nn_bcm", "nn_rbcm", "nn_grbcm", "nn_npae"]


# ---------------------------------------------------------------------------
# FaultPlan: schedules, determinism, classification
# ---------------------------------------------------------------------------

def test_fault_plan_classification():
    assert FaultPlan().empty and FaultPlan().consensus_free
    timing = FaultPlan(straggle_every=3, straggle_ms=5.0, fail_every=7)
    assert timing.consensus_free and not timing.empty
    for plan in (FaultPlan(dropouts=(Dropout(1),)),
                 FaultPlan(edge_loss=0.1),
                 FaultPlan(nan_agents=(2,))):
        assert not plan.consensus_free and not plan.empty


def test_fault_plan_validation():
    with pytest.raises(ValueError):
        FaultPlan(edge_loss=1.0)
    with pytest.raises(ValueError):
        FaultPlan(fail_every=-1)
    with pytest.raises(ValueError):
        FaultPlan(dropouts=(Dropout(9),)).alive_schedule(M, 10)
    with pytest.raises(ValueError):
        FaultPlan(nan_agents=(-1,)).corrupt_mask(M)


def test_alive_schedule_windows():
    plan = FaultPlan(dropouts=(Dropout(1, at=0), Dropout(3, at=4, until=7)))
    alive = plan.alive_schedule(M, 10)
    assert alive.shape == (10, M)
    assert (alive[:, 1] == 0).all()                  # dead for the whole run
    assert (alive[:4, 3] == 1).all()                 # alive before `at`
    assert (alive[4:7, 3] == 0).all()                # dropped window
    assert (alive[7:, 3] == 1).all()                 # rejoined at `until`
    final = plan.final_alive(M, 10)
    assert not final[1] and final[3] and final.sum() == M - 1


def test_edge_schedule_seeded_and_symmetric():
    plan = FaultPlan(seed=11, edge_loss=0.3)
    e1 = plan.edge_schedule(M, 20)
    e2 = FaultPlan(seed=11, edge_loss=0.3).edge_schedule(M, 20)
    np.testing.assert_array_equal(e1, e2)            # replayable
    assert (e1 == np.transpose(e1, (0, 2, 1))).all()  # symmetric loss
    assert (np.diagonal(e1, axis1=1, axis2=2) == 0).all()
    e3 = FaultPlan(seed=12, edge_loss=0.3).edge_schedule(M, 20)
    assert not np.array_equal(e1, e3)                # seed actually matters
    assert FaultPlan(seed=11).edge_schedule(M, 20) is None


def test_wrap_predict_fn_faults_are_deterministic():
    naps = []
    wrapped = wrap_predict_fn(lambda Xs: Xs + 1,
                              FaultPlan(straggle_every=2, straggle_ms=4.0,
                                        fail_every=3),
                              sleep=naps.append)
    out = []
    for i in range(1, 7):
        try:
            wrapped(i)
            out.append("ok")
        except FaultInjected:
            out.append("fail")
    # 1-based call index: sleeps on 2, 4, 6; raises on 3, 6 — and the raise
    # happens BEFORE the sleep, so call 6 fails without napping
    assert out == ["ok", "ok", "fail", "ok", "ok", "fail"]
    assert naps == [4e-3, 4e-3]
    assert wrapped.calls["n"] == 6


def test_wrap_predict_fn_counter_is_thread_safe():
    plan = FaultPlan(fail_every=2)
    wrapped = wrap_predict_fn(lambda Xs: Xs, plan)
    failures = []

    def hammer():
        for _ in range(50):
            try:
                wrapped(0)
            except FaultInjected:
                failures.append(1)

    threads = [threading.Thread(target=hammer) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert wrapped.calls["n"] == 200 and len(failures) == 100


def test_membership_events():
    plan = FaultPlan(dropouts=(Dropout(2, at=5, until=8), Dropout(0, at=1)))
    assert membership_events(plan, M, 10) == [
        (1, "leave", 0), (5, "leave", 2), (8, "rejoin", 2)]
    # events past the horizon are clipped
    assert membership_events(plan, M, 3) == [(1, "leave", 0)]


# ---------------------------------------------------------------------------
# Degraded consensus numerics
# ---------------------------------------------------------------------------

def test_connected_components_with_liveness():
    A = path_graph(6)
    labels = connected_components(A)
    assert (np.asarray(labels) == 0).all()
    alive = np.ones(6, bool)
    alive[2] = False                       # path splits at the dead node
    labels = np.asarray(connected_components(A, alive=jnp.asarray(alive)))
    assert labels[0] == labels[1]
    assert labels[3] == labels[4] == labels[5]
    assert labels[0] != labels[3]


def test_dac_masked_all_alive_matches_dac():
    rng = np.random.default_rng(0)
    A = random_connected_graph(M, 0.4, seed=1)
    w0 = jnp.asarray(rng.standard_normal((M, 3)))
    alive = jnp.ones((300, M))
    w_m, _ = dac_masked(w0, A, alive)
    w_e, _ = dac(w0, A, 300)
    np.testing.assert_allclose(np.asarray(w_m), np.asarray(w_e), atol=1e-9)


def test_dac_masked_sums_round0_dropout_is_exact():
    """Dead-from-round-0 agents: the surviving component's readout equals
    the exact sum over its members (conservation of the masked update)."""
    rng = np.random.default_rng(1)
    A = complete_graph(M)
    w0 = jnp.asarray(rng.standard_normal((M, 2)))
    plan = FaultPlan(dropouts=(Dropout(2, at=0),))
    alive = jnp.asarray(plan.alive_schedule(M, 500))
    readout = jnp.asarray((plan.final_alive(M, 500)).astype(float))
    sums, res = dac_masked_sums(w0, A, alive, readout, jnp.asarray(7.0))
    ref = np.asarray(w0)[np.arange(M) != 2].sum(axis=0)
    np.testing.assert_allclose(np.asarray(sums), ref, atol=1e-6)
    assert float(res[-1]) < 1e-7


def test_dac_masked_freezes_dead_agents():
    rng = np.random.default_rng(2)
    A = complete_graph(M)
    w0 = jnp.asarray(rng.standard_normal((M,)))
    alive = jnp.asarray(FaultPlan(dropouts=(Dropout(4, at=10),))
                        .alive_schedule(M, 200))
    w, _ = dac_masked(w0, A, alive)
    # a dead agent holds the state it had at dropout, not the consensus
    w10, _ = dac_masked(w0, A, alive[:10])
    assert np.isclose(float(w[4]), float(w10[4]))
    live = np.asarray(w)[np.arange(M) != 4]
    assert np.ptp(live) < 1e-6             # survivors still reach consensus


# ---------------------------------------------------------------------------
# Engine under fault plans (the acceptance scenario)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def engine():
    rng = np.random.default_rng(0)
    Ni, D = 24, 1
    X = rng.uniform(-3, 3, (M, Ni, D))
    y = np.sin(X.sum(-1)) + 0.05 * rng.standard_normal((M, Ni))
    log_theta = pack(np.array([0.7]), 1.0, 0.1)
    A = random_connected_graph(M, 0.4, seed=1)
    f = fit_experts(log_theta, jnp.asarray(X), jnp.asarray(y))
    Xc = rng.uniform(-3, 3, (Ni, D))
    yc = np.sin(Xc.sum(-1)) + 0.05 * rng.standard_normal(Ni)
    Xa = np.concatenate([np.broadcast_to(Xc, (M, Ni, D)), X], axis=1)
    ya = np.concatenate([np.broadcast_to(yc, (M, Ni)), y], axis=1)
    fa = fit_experts(log_theta, jnp.asarray(Xa), jnp.asarray(ya))
    fc = fit_experts(log_theta, jnp.asarray(Xc)[None], jnp.asarray(yc)[None])
    eng = PredictionEngine(f, A, chunk=16, dac_iters=600, fitted_aug=fa,
                           fitted_comm=fc)
    Xs = jnp.asarray(rng.uniform(-3, 3, (37, D)))
    return eng, Xs


@pytest.mark.parametrize("method", METHODS)
def test_consensus_free_plan_is_bitwise_identical(engine, method):
    eng, Xs = engine
    m0, v0, _ = eng.predict(method, Xs)
    m1, v1, info = eng.predict(method, Xs, fault_plan=FaultPlan(
        straggle_every=2, straggle_ms=1.0, fail_every=5))
    assert np.array_equal(np.asarray(m0), np.asarray(m1))
    assert np.array_equal(np.asarray(v0), np.asarray(v1))
    assert "degraded" not in info


@pytest.mark.parametrize("method", METHODS)
def test_dropout_serves_finite_and_flagged(engine, method):
    """25% of agents drop (one before, one mid-prediction), one agent
    emits NaN payloads, 5% message loss — every method still serves
    finite moments with the degradation surface filled in."""
    eng, Xs = engine
    plan = FaultPlan(seed=7, dropouts=(Dropout(1, at=0), Dropout(3, at=240)),
                     nan_agents=(5,), edge_loss=0.05)
    mu, var, info = eng.predict(method, Xs, fault_plan=plan)
    assert np.isfinite(np.asarray(mu)).all()
    assert np.isfinite(np.asarray(var)).all()
    assert info["degraded"] is True
    assert info["alive_agents"] == M - 2
    assert info["scrubbed_agents"] >= 1          # the NaN agent was caught
    residual = info.get("dac_residual", info.get("dale_residual"))
    assert float(residual) < 1e-2


def test_round0_dropout_equals_exact_masked_aggregation(engine):
    """An agent dead before the prediction starts is EXACT exclusion, not
    an estimate: the degraded readout matches the masked centralized-
    equivalent aggregation over the survivors (float32 consensus tol)."""
    from repro.core.prediction.decentralized import dec_gpoe_from_moments
    from repro.core.prediction.local import local_moments_cached
    eng, Xs = engine
    f = eng.fitted
    mu, _, info = eng.predict("gpoe", Xs,
                              fault_plan=FaultPlan(dropouts=(Dropout(2),)))
    assert info["degraded"] is True and info["excluded_agents"] == 1
    alive = np.ones(M, bool)
    alive[2] = False
    mu_l, var_l = local_moments_cached(f.log_theta, f.Xp, f.L, f.alpha, Xs)
    mref, _, _ = dec_gpoe_from_moments(
        mu_l, var_l, f.prior_var, eng.A, iters=600,
        mask=jnp.asarray(alive, mu_l.dtype)[:, None])
    np.testing.assert_allclose(np.asarray(mu), np.asarray(mref), atol=1e-4)


def test_partition_serves_largest_component(engine):
    """A path graph losing an articulation agent splits; the engine must
    serve the LARGEST surviving component and say so — never silently
    average across a partition."""
    rng = np.random.default_rng(3)
    f = fit_experts(pack(np.array([0.7]), 1.0, 0.1),
                    jnp.asarray(rng.uniform(-3, 3, (M, 24, 1))),
                    jnp.asarray(rng.standard_normal((M, 24))))
    eng = PredictionEngine(f, path_graph(M), chunk=16, dac_iters=600)
    Xs = jnp.asarray(rng.uniform(-3, 3, (11, 1)))
    mu, var, info = eng.predict("rbcm", Xs,
                                fault_plan=FaultPlan(dropouts=(Dropout(1),)))
    assert np.isfinite(np.asarray(mu)).all()
    assert np.isfinite(np.asarray(var)).all()
    assert info["n_components"] == 2
    # agent 0 is cut off from the main component: excluded though alive
    assert info["alive_agents"] == M - 1
    assert info["excluded_agents"] == 2


def test_cen_methods_reject_consensus_faults(engine):
    eng, Xs = engine
    with pytest.raises(ValueError):
        eng.predict("cen_poe", Xs,
                    fault_plan=FaultPlan(dropouts=(Dropout(1),)))


def test_total_dropout_raises_typed_error(engine):
    eng, Xs = engine
    with pytest.raises(ConsensusDiverged):
        eng.predict("poe", Xs, fault_plan=FaultPlan(
            dropouts=tuple(Dropout(i) for i in range(M))))


def test_fault_plans_share_compiled_programs(engine):
    """Chaos schedules enter the trace as ARGUMENTS, not constants: a
    structurally identical second plan must reuse the compiled program
    (the serving scheduler's zero-recompile contract extends to chaos)."""
    eng, Xs = engine
    eng.predict("poe", Xs, fault_plan=FaultPlan(
        seed=7, dropouts=(Dropout(1),), nan_agents=(5,), edge_loss=0.05))
    n0 = eng.jit_cache_misses
    eng.predict("poe", Xs, fault_plan=FaultPlan(
        seed=9, dropouts=(Dropout(4, at=50),), nan_agents=(0,),
        edge_loss=0.05))
    assert eng.jit_cache_misses == n0


# ---------------------------------------------------------------------------
# Fleet facade: typed degradation, health
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def fleet():
    from repro.fleet import FleetConfig, GPFleet
    rng = np.random.default_rng(5)
    Xp = jnp.asarray(rng.uniform(-3, 3, (M, 24, 1)))
    yp = jnp.asarray(np.sin(np.asarray(Xp).sum(-1))
                     + 0.05 * rng.standard_normal((M, 24)))
    cfg = FleetConfig(num_agents=M, method="rbcm", chunk=16, dac_iters=600,
                      input_dim=1, theta0=(0.7, 1.0, 0.1))
    return GPFleet(cfg).fit(Xp, yp, key=jax.random.PRNGKey(0), train=False)


def test_fleet_degraded_is_opt_in(fleet):
    from repro.fleet import FleetDegraded
    Xs = jnp.linspace(-3, 3, 9)[:, None]
    plan = FaultPlan(dropouts=(Dropout(1),))
    with pytest.raises(FleetDegraded) as exc:
        fleet.predict(Xs, fault_plan=plan)
    assert exc.value.info["degraded"] is True
    assert exc.value.result is not None          # the answer rides along
    mu, var, info = fleet.predict(Xs, fault_plan=plan, allow_degraded=True)
    assert np.isfinite(np.asarray(mu)).all()
    assert info["degraded"] is True


def test_fleet_health_surface(fleet):
    Xs = jnp.linspace(-3, 3, 9)[:, None]
    fleet.predict(Xs, fault_plan=FaultPlan(dropouts=(Dropout(1),)),
                  allow_degraded=True)
    h = fleet.health()
    assert h["num_agents"] == M and h["is_fitted"]
    assert h["graph_connected"] is True
    assert h["degraded_predictions"] >= 1
    assert h["last_degraded"]["alive_agents"] == M - 1


# ---------------------------------------------------------------------------
# FaultPlan schedules: property tests (seed-replay + at/until semantics)
# ---------------------------------------------------------------------------

from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**16 - 1), st.integers(2, 9), st.integers(0, 10),
       st.integers(1, 8), st.booleans())
def test_dropout_schedule_semantics_property(seed, m, at, dur, unbounded):
    """alive_schedule honors at (inclusive) / until (exclusive) exactly,
    touches no other agent, and replays from the plan alone."""
    agent = seed % m
    until = None if unbounded else at + dur
    plan = FaultPlan(seed=seed, dropouts=(Dropout(agent, at, until),))
    iters = 12
    alive = plan.alive_schedule(m, iters)
    assert alive.shape == (iters, m)
    for t in range(iters):
        dead = at <= t and (until is None or t < until)
        assert alive[t, agent] == (0.0 if dead else 1.0)
    assert np.all(np.delete(alive, agent, axis=1) == 1.0)
    again = FaultPlan(seed=seed, dropouts=(Dropout(agent, at, until),))
    assert np.array_equal(alive, again.alive_schedule(m, iters))
    fa = plan.final_alive(m, iters)
    assert fa[agent] == bool(alive[-1, agent])


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**16 - 1), st.integers(2, 8),
       st.floats(0.05, 0.5), st.integers(1, 10))
def test_edge_schedule_replay_property(seed, m, p, iters):
    """Same seed => bitwise-identical edge masks; masks are symmetric,
    hollow, and 0/1."""
    e1 = FaultPlan(seed=seed, edge_loss=p).edge_schedule(m, iters)
    e2 = FaultPlan(seed=seed, edge_loss=p).edge_schedule(m, iters)
    assert e1.shape == (iters, m, m)
    assert np.array_equal(e1, e2)
    assert np.array_equal(e1, np.transpose(e1, (0, 2, 1)))
    assert np.all(np.diagonal(e1, axis1=1, axis2=2) == 0.0)
    assert set(np.unique(e1)) <= {0.0, 1.0}


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**16 - 1), st.integers(3, 9), st.integers(0, 10),
       st.integers(1, 6), st.booleans(), st.integers(4, 12))
def test_membership_events_match_alive_schedule_property(
        seed, m, at, dur, unbounded, steps):
    """Replaying membership_events as a leave/rejoin tape reconstructs
    alive_schedule at fleet-step granularity, event for event."""
    agent = (seed * 7 + 3) % m
    until = None if unbounded else at + dur
    plan = FaultPlan(seed=seed, dropouts=(Dropout(agent, at, until),))
    events = membership_events(plan, m, steps)
    assert events == sorted(events)
    alive = np.ones((steps, m))
    dead: set = set()
    by_step: dict = {}
    for s, kind, a in events:
        assert 0 <= s < steps
        by_step.setdefault(s, []).append((kind, a))
    for t in range(steps):
        for kind, a in by_step.get(t, []):
            (dead.add if kind == "leave" else dead.discard)(a)
        for a in dead:
            alive[t, a] = 0.0
    assert np.array_equal(alive, plan.alive_schedule(m, steps))
