"""ADMM GP training: centralized (c/apx/gapx) and decentralized
(DEC-c/apx/gapx) — convergence, consensus, accuracy vs the paper's claims."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.gp import (pack, stripe_partition, communication_dataset,
                           augment, nll)
from repro.core.training import (train_fact_gp, train_c_gp, train_apx_gp,
                                 train_gapx_gp, train_dec_c_gp,
                                 train_dec_apx_gp, train_dec_gapx_gp)
from repro.core.consensus import path_graph, random_connected_graph
from repro.data import random_inputs, gp_sample_field

TRUE_LT = pack([1.2, 0.3], 1.3, 0.1)
LT0 = pack([2.0, 0.5], 1.0, 1.0)


@pytest.fixture(scope="module")
def fleet_data():
    X = random_inputs(jax.random.PRNGKey(0), 1200)
    _, y = gp_sample_field(jax.random.PRNGKey(1), X, TRUE_LT)
    Xp, yp = stripe_partition(X, y, 4)
    return Xp, yp


def _theta_err(lt):
    return np.max(np.abs(np.asarray(lt) - np.asarray(TRUE_LT)))


def test_fact_gp_recovers_theta(fleet_data):
    Xp, yp = fleet_data
    lt, vals = train_fact_gp(LT0, Xp, yp, steps=200)
    assert float(vals[-1]) < float(vals[0])
    assert _theta_err(lt) < 0.5


def test_apx_gp_consensus_and_accuracy(fleet_data):
    Xp, yp = fleet_data
    z, thetas, hist = train_apx_gp(LT0, Xp, yp, iters=120)
    assert float(hist["residuals"][-1]) < 1e-2          # agents agree
    assert _theta_err(z) < 0.5


def test_c_gp_runs_and_descends(fleet_data):
    Xp, yp = fleet_data
    z, thetas, hist = train_c_gp(LT0, Xp, yp, iters=15, nested_iters=5)
    assert np.isfinite(np.asarray(thetas)).all()
    assert float(hist["residuals"][-1]) < 1.0


def test_gapx_gp_beats_apx_accuracy(fleet_data):
    """Paper Fig. 8: the augmented dataset improves accuracy (l1 bias)."""
    Xp, yp = fleet_data
    Xc, yc = communication_dataset(jax.random.PRNGKey(2), Xp, yp)
    Xa, ya = augment(Xp, yp, Xc, yc)
    z_apx, _, _ = train_apx_gp(LT0, Xp, yp, iters=120)
    z_gapx, _, _ = train_gapx_gp(LT0, Xa, ya, iters=120)
    assert _theta_err(z_gapx) <= _theta_err(z_apx) + 0.1


@pytest.mark.parametrize("graph_fn", [path_graph,
                                      lambda M: random_connected_graph(M, .4)])
def test_dec_apx_gp_consensus(fleet_data, graph_fn):
    """Theorem 1: closed-form decentralized updates reach consensus on any
    strongly connected graph."""
    Xp, yp = fleet_data
    A = graph_fn(4)
    thetas, hist = train_dec_apx_gp(LT0, Xp, yp, A, iters=150)
    disagreement = float(jnp.max(jnp.abs(thetas - jnp.mean(thetas, 0))))
    assert disagreement < 5e-2
    assert _theta_err(jnp.mean(thetas, 0)) < 0.7


def test_dec_gapx_gp_accuracy(fleet_data):
    """DEC-gapx-GP is the accurate decentralized method (paper §6.1)."""
    Xp, yp = fleet_data
    Xc, yc = communication_dataset(jax.random.PRNGKey(2), Xp, yp)
    Xa, ya = augment(Xp, yp, Xc, yc)
    thetas, _ = train_dec_gapx_gp(LT0, Xa, ya, path_graph(4), iters=150)
    assert _theta_err(jnp.mean(thetas, 0)) < 0.45


def test_dec_c_gp_runs(fleet_data):
    Xp, yp = fleet_data
    thetas, hist = train_dec_c_gp(LT0, Xp, yp, path_graph(4), iters=10,
                                  nested_iters=5)
    assert np.isfinite(np.asarray(thetas)).all()


def test_dec_apx_improves_nll(fleet_data):
    """Training lowers the factorized NLL vs the initial theta."""
    Xp, yp = fleet_data
    thetas, _ = train_dec_apx_gp(LT0, Xp, yp, path_graph(4), iters=150)
    lt = jnp.mean(thetas, axis=0)
    nll0 = sum(float(nll(LT0, Xp[i], yp[i])) for i in range(4))
    nll1 = sum(float(nll(lt, Xp[i], yp[i])) for i in range(4))
    assert nll1 < nll0


def test_dec_apx_sharded_matches_simulated():
    """Sharded execution (shard_map + ppermute ring) == simulated cycle."""
    if jax.device_count() < 4:
        pytest.skip("needs >= 4 devices")
    from repro.core.training import train_dec_apx_gp_sharded
    from repro.core.consensus import cycle_graph
    X = random_inputs(jax.random.PRNGKey(0), 400)
    _, y = gp_sample_field(jax.random.PRNGKey(1), X, TRUE_LT)
    Xp, yp = stripe_partition(X, y, 4)
    mesh = jax.make_mesh((4,), ("agents",))
    th_sh, _ = train_dec_apx_gp_sharded(mesh, "agents", LT0, Xp, yp, iters=40)
    th_sim, _ = train_dec_apx_gp(LT0, Xp, yp, cycle_graph(4), iters=40)
    np.testing.assert_allclose(np.asarray(th_sh), np.asarray(th_sim),
                               rtol=1e-6, atol=1e-8)


def test_dec_apx_sharded_residuals_match_simulated():
    """The sharded loop returns the SAME info["residuals"] series as the
    simulated loop (per-iteration max consensus disagreement, computed with
    pmean/pmax collectives inside the sharded scan): observability of the
    deployment path must not diverge from the reference semantics."""
    if jax.device_count() < 4:
        pytest.skip("needs >= 4 devices")
    from repro.core.training import train_dec_apx_gp_sharded
    from repro.core.consensus import cycle_graph
    X = random_inputs(jax.random.PRNGKey(0), 400)
    _, y = gp_sample_field(jax.random.PRNGKey(1), X, TRUE_LT)
    Xp, yp = stripe_partition(X, y, 4)
    mesh = jax.make_mesh((4,), ("agents",))
    th_sh, info_sh = train_dec_apx_gp_sharded(mesh, "agents", LT0, Xp, yp,
                                              iters=40)
    th_sim, info_sim = train_dec_apx_gp(LT0, Xp, yp, cycle_graph(4),
                                        iters=40)
    assert info_sh["residuals"].shape == (40,)
    assert info_sh["p"].shape == th_sh.shape          # final duals ride along
    np.testing.assert_allclose(np.asarray(info_sh["residuals"]),
                               np.asarray(info_sim["residuals"]),
                               rtol=1e-5, atol=1e-8)


def test_dec_apx_diag_mode_matches_plain(fleet_data):
    """diag=True only ADDS diagnostics: the trained thetas are bitwise the
    diag=False thetas, and the extended per-iteration series are shaped and
    finite (primal/dual residuals, per-agent NLL, theta trajectory)."""
    Xp, yp = fleet_data
    A = path_graph(4)
    th0, info0 = train_dec_apx_gp(LT0, Xp, yp, A, iters=25)
    th1, info1 = train_dec_apx_gp(LT0, Xp, yp, A, iters=25, diag=True)
    np.testing.assert_array_equal(np.asarray(th0), np.asarray(th1))
    np.testing.assert_array_equal(np.asarray(info0["residuals"]),
                                  np.asarray(info1["residuals"]))
    d = info1["diagnostics"]
    assert d["nll"].shape == (25, 4)
    assert d["theta_trajectory"].shape == (25, 4, LT0.shape[0])
    for k in ("primal_residuals", "dual_residuals"):
        assert d[k].shape == (25,)
        assert np.isfinite(np.asarray(d[k])).all()


def test_apx_diag_mode_matches_plain(fleet_data):
    """Centralized counterpart of the diag-equivalence guarantee."""
    Xp, yp = fleet_data
    z0, th0, h0 = train_apx_gp(LT0, Xp, yp, iters=25)
    z1, th1, h1 = train_apx_gp(LT0, Xp, yp, iters=25, diag=True)
    np.testing.assert_array_equal(np.asarray(z0), np.asarray(z1))
    np.testing.assert_array_equal(np.asarray(th0), np.asarray(th1))
    np.testing.assert_array_equal(np.asarray(h0["residuals"]),
                                  np.asarray(h1["residuals"]))
    d = h1["diagnostics"]
    assert d["nll"].shape == (25, 4)
    assert np.isfinite(np.asarray(d["dual_residuals"])).all()


def test_dec_apx_sharded_two_agents_matches_simulated():
    """M=2 ring regression for dec_apx_gp_sharded_step: ppermute fwd == bwd
    delivers ONE shared neighbor; summing both directions double-counted it
    (nbr_sum = 2*theta_other with deg = 1), so 2-agent sharded training
    diverged from the simulated reference."""
    if jax.device_count() < 2:
        pytest.skip("needs >= 2 devices (run under forced host devices)")
    from repro.core.training import train_dec_apx_gp_sharded
    from repro.core.consensus import cycle_graph
    X = random_inputs(jax.random.PRNGKey(0), 200)
    _, y = gp_sample_field(jax.random.PRNGKey(1), X, TRUE_LT)
    Xp, yp = stripe_partition(X, y, 2)
    mesh = jax.make_mesh((2,), ("agents",))
    th_sh, _ = train_dec_apx_gp_sharded(mesh, "agents", LT0, Xp, yp, iters=40)
    th_sim, _ = train_dec_apx_gp(LT0, Xp, yp, cycle_graph(2), iters=40)
    np.testing.assert_allclose(np.asarray(th_sh), np.asarray(th_sim),
                               rtol=1e-6, atol=1e-8)
