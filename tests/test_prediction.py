"""Decentralized prediction: all 13 methods vs centralized references and the
paper's propositions."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.gp import (pack, stripe_partition, communication_dataset,
                           augment, predict_full)
from repro.core.consensus import path_graph, complete_graph
from repro.core.prediction import (local_moments, npae_terms, poe, gpoe, bcm,
                                   rbcm, grbcm, npae, cbnn_scores, cbnn_mask,
                                   dec_poe, dec_gpoe, dec_bcm, dec_rbcm,
                                   dec_grbcm, dec_npae, dec_npae_star,
                                   dec_nn_poe, dec_nn_gpoe, dec_nn_bcm,
                                   dec_nn_rbcm, dec_nn_grbcm, dec_nn_npae)
from repro.data import random_inputs, gp_sample_field

TRUE_LT = pack([1.2, 0.3], 1.3, 0.1)
M = 8
PRIOR_VAR = 1.3**2


@pytest.fixture(scope="module")
def setup():
    X = random_inputs(jax.random.PRNGKey(0), 1600)
    _, y = gp_sample_field(jax.random.PRNGKey(1), X, TRUE_LT)
    Xp, yp = stripe_partition(X, y, M)
    Xs = random_inputs(jax.random.PRNGKey(2), 40)
    mu, var = local_moments(TRUE_LT, Xp, yp, Xs)
    return Xp, yp, Xs, mu, var


def rmse(a, b):
    return float(jnp.sqrt(jnp.mean((a - b) ** 2)))


def test_proposition_2_poe_equals_gpoe_mean(setup):
    _, _, _, mu, var = setup
    m1, _ = poe(mu, var)
    m2, _ = gpoe(mu, var)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), atol=1e-12)


def test_proposition_1_gpoe_conservative_variance(setup):
    """gPoE variance between PoE's (overconfident) and prior."""
    _, _, _, mu, var = setup
    _, v_poe = poe(mu, var)
    _, v_gpoe = gpoe(mu, var)
    assert np.all(np.asarray(v_gpoe) >= np.asarray(v_poe) - 1e-12)
    assert np.all(np.asarray(v_gpoe) <= PRIOR_VAR + 1e-9)


def test_npae_closest_to_full_gp():
    """NPAE is the consistent nested aggregation (Rulliere et al.) and should
    track the full GP at least as well as PoE — but only IN EXPECTATION. On
    individual draws PoE can win by a hair (seed 0 of this generator: 0.0314
    vs 0.0333 RMSE, regardless of solve jitter down to exactly zero), so the
    assertion is statistical: aggregate RMSE over independent fields.
    Documented tolerance: NPAE wins on aggregate, and stays within 0.1
    absolute of the full GP on every draw."""
    sq_np = sq_poe = 0.0
    for s in range(4):
        X = random_inputs(jax.random.PRNGKey(10 * s), 1600)
        _, y = gp_sample_field(jax.random.PRNGKey(10 * s + 1), X, TRUE_LT)
        Xp, yp = stripe_partition(X, y, M)
        Xs = random_inputs(jax.random.PRNGKey(10 * s + 2), 40)
        mu, var = local_moments(TRUE_LT, Xp, yp, Xs)
        m_full, _ = predict_full(TRUE_LT, Xp.reshape(-1, 2), yp.reshape(-1),
                                 Xs)
        mu_n, kA, CA = npae_terms(TRUE_LT, Xp, yp, Xs)
        m_np, _ = npae(mu_n, kA, CA, PRIOR_VAR)
        m_poe, _ = poe(mu, var)
        r_np, r_poe = rmse(m_np, m_full), rmse(m_poe, m_full)
        assert r_np < 0.1
        sq_np += r_np**2
        sq_poe += r_poe**2
    assert sq_np <= sq_poe + 1e-6


@pytest.mark.parametrize("dec_fn,cen_fn,needs_prior", [
    (dec_poe, poe, False), (dec_gpoe, gpoe, False),
    (dec_bcm, bcm, True), (dec_rbcm, rbcm, True)])
def test_dac_methods_zero_approximation_error(setup, dec_fn, cen_fn,
                                              needs_prior):
    """Paper §6.2: DAC-based decentralized methods converge to their
    centralized aggregations with (numerically) zero error."""
    Xp, yp, Xs, mu, var = setup
    args = (mu, var, PRIOR_VAR) if needs_prior else (mu, var)
    m_ref, v_ref = cen_fn(*args)
    m, v, info = dec_fn(TRUE_LT, Xp, yp, Xs, path_graph(M), iters=400)
    np.testing.assert_allclose(np.asarray(m), np.asarray(m_ref), atol=1e-8)
    np.testing.assert_allclose(np.asarray(v), np.asarray(v_ref), atol=1e-10)


def test_dec_grbcm_matches_centralized(setup):
    Xp, yp, Xs, _, _ = setup
    Xc, yc = communication_dataset(jax.random.PRNGKey(3), Xp, yp)
    Xa, ya = augment(Xp, yp, Xc, yc)
    mu_a, var_a = local_moments(TRUE_LT, Xa, ya, Xs)
    mu_c, var_c = local_moments(TRUE_LT, Xc[None], yc[None], Xs)
    m_ref, v_ref = grbcm(mu_a, var_a, mu_c[0], var_c[0])
    m, v, _ = dec_grbcm(TRUE_LT, Xa, ya, Xc, yc, Xs, path_graph(M), iters=400)
    np.testing.assert_allclose(np.asarray(m), np.asarray(m_ref), atol=1e-8)
    # grBCM is the consistent method: closest to full GP among BCM family
    X = Xp.reshape(-1, 2)
    m_full, _ = predict_full(TRUE_LT, X, yp.reshape(-1), Xs)
    assert rmse(m_ref, m_full) < 0.12


def test_dec_npae_small_approximation_error(setup):
    Xp, yp, Xs, _, _ = setup
    mu_n, kA, CA = npae_terms(TRUE_LT, Xp, yp, Xs)
    m_ref, v_ref = npae(mu_n, kA, CA, PRIOR_VAR)
    m, v, info = dec_npae(TRUE_LT, Xp, yp, Xs, complete_graph(M),
                          jor_iters=2000, dac_iters=400)
    assert rmse(m, m_ref) < 0.05
    m2, v2, info2 = dec_npae_star(TRUE_LT, Xp, yp, Xs, complete_graph(M),
                                  jor_iters=2000, dac_iters=400)
    assert rmse(m2, m_ref) < 0.05
    # omega* exceeds the conservative 2/M bound (Lemma 3 / Remark 9)
    assert float(jnp.min(info2["omega"])) > 2.0 / M


def test_cbnn_selects_nearby_agents(setup):
    """CBNN scores decay with distance from the query stripe (Lemma 6)."""
    Xp, yp, Xs, _, _ = setup
    # query inside agent 0's stripe
    q = Xp[0, :1] + 0.0
    scores = cbnn_scores(TRUE_LT, Xp, q)
    assert int(jnp.argmax(scores[:, 0])) in (0, 1)
    mask, _ = cbnn_mask(TRUE_LT, Xp, q, eta_nn=0.1)
    assert bool(mask[0, 0])
    # at least one far agent excluded for a localized query
    assert int(mask[:, 0].sum()) < M


@pytest.mark.parametrize("nn_fn,base_fn,needs_prior", [
    (dec_nn_poe, poe, False), (dec_nn_gpoe, gpoe, False),
    (dec_nn_bcm, bcm, True), (dec_nn_rbcm, rbcm, True)])
def test_nn_methods_match_masked_centralized(setup, nn_fn, base_fn,
                                             needs_prior):
    """DEC-NN-* equals the centralized aggregation restricted to the CBNN
    subset (paper Table 7: agent reduction with no approximation error)."""
    Xp, yp, Xs, mu, var = setup
    eta = 0.1
    m, v, info = nn_fn(TRUE_LT, Xp, yp, Xs, path_graph(M), eta, iters=400)
    mask = info["mask"]
    args = (mu, var, PRIOR_VAR) if needs_prior else (mu, var)
    m_ref, v_ref = base_fn(*args, mask=mask)
    np.testing.assert_allclose(np.asarray(m), np.asarray(m_ref), atol=1e-8)
    assert float(mask.sum()) < mask.size  # some agents actually excluded


def test_dec_nn_grbcm(setup):
    Xp, yp, Xs, _, _ = setup
    Xc, yc = communication_dataset(jax.random.PRNGKey(3), Xp, yp)
    Xa, ya = augment(Xp, yp, Xc, yc)
    m, v, info = dec_nn_grbcm(TRUE_LT, Xa, ya, Xc, yc, Xs, path_graph(M),
                              eta_nn=0.1, iters=400, Xp=Xp)
    assert np.isfinite(np.asarray(m)).all()
    assert np.all(np.asarray(v) > 0)


def test_dec_nn_npae_dale(setup):
    """DEC-NN-NPAE on a strongly CONNECTED (not complete) graph via DALE."""
    Xp, yp, Xs, _, _ = setup
    m, v, info = dec_nn_npae(TRUE_LT, Xp, yp, Xs[:10], path_graph(M),
                             eta_nn=0.1, dale_iters=3000)
    mu_n, kA, CA = npae_terms(TRUE_LT, Xp, yp, Xs[:10])
    m_ref, v_ref = npae(mu_n, kA, CA, PRIOR_VAR, mask=info["mask"])
    # paper Fig. 14: DEC-NN-NPAE carries a visible approximation error;
    # assert it is bounded and the variance is sane
    assert rmse(m, m_ref) < 0.5
    assert np.all(np.asarray(v) > 0)


# ---------------------------------------------------------------------------
# Degenerate inputs: every method must stay finite (robustness floor).
# Real fleets hit these constantly — sensors resampling the same location
# (duplicate rows), calm periods (zero-variance windows), fleets reduced to
# one survivor (single-agent graph) — and a silent NaN here poisons every
# downstream consensus consumer.
# ---------------------------------------------------------------------------

ALL_METHODS = ["poe", "gpoe", "bcm", "rbcm", "grbcm", "npae", "npae_star",
               "nn_poe", "nn_gpoe", "nn_bcm", "nn_rbcm", "nn_grbcm",
               "nn_npae"]


def _engine_from(X, y, num_agents):
    """A PredictionEngine (with grbcm experts) over explicit raw data."""
    from repro.core.consensus import random_connected_graph
    from repro.core.prediction.engine import PredictionEngine, fit_experts
    rng = np.random.default_rng(99)
    Ni, D = X.shape[1], X.shape[2]
    A = (jnp.zeros((1, 1)) if num_agents == 1
         else random_connected_graph(num_agents, 0.4, seed=2))
    f = fit_experts(TRUE_LT, jnp.asarray(X), jnp.asarray(y))
    Xc = rng.uniform(-1, 1, (Ni, D))
    yc = rng.standard_normal(Ni) * 0.1
    Xa = np.concatenate([np.broadcast_to(Xc, (num_agents, Ni, D)), X],
                        axis=1)
    ya = np.concatenate([np.broadcast_to(yc, (num_agents, Ni)), y], axis=1)
    fa = fit_experts(TRUE_LT, jnp.asarray(Xa), jnp.asarray(ya))
    fc = fit_experts(TRUE_LT, jnp.asarray(Xc)[None], jnp.asarray(yc)[None])
    return PredictionEngine(f, A, chunk=16, dac_iters=300, fitted_aug=fa,
                            fitted_comm=fc)


def _assert_finite(eng, method, Xs):
    mu, var, _ = eng.predict(method, Xs)
    assert np.isfinite(np.asarray(mu)).all(), method
    assert np.isfinite(np.asarray(var)).all(), method


@pytest.mark.parametrize("method", ALL_METHODS)
def test_duplicate_inputs_stay_finite(method):
    """Every agent's window holds the SAME point repeated (plus noise-free
    duplicated queries): the noise term must keep factorization and
    aggregation finite."""
    rng = np.random.default_rng(4)
    base = rng.uniform(-1, 1, (M, 1, 2))
    X = np.repeat(base, 12, axis=1)            # 12 identical rows per agent
    y = rng.standard_normal((M, 12)) * 0.1
    eng = _engine_from(X, y, M)
    Xs = jnp.asarray(np.repeat(base[0], 5, axis=0))   # duplicated queries
    _assert_finite(eng, method, Xs)


@pytest.mark.parametrize("method", ALL_METHODS)
def test_zero_variance_window_stays_finite(method):
    """Constant targets (a becalmed sensor): zero sample variance in y must
    not produce NaN moments or weights (rBCM's entropy beta is the usual
    casualty)."""
    rng = np.random.default_rng(5)
    X = rng.uniform(-1, 1, (M, 12, 2))
    y = np.zeros((M, 12))
    eng = _engine_from(X, y, M)
    Xs = jnp.asarray(rng.uniform(-1, 1, (7, 2)))
    _assert_finite(eng, method, Xs)


@pytest.mark.parametrize("method", ALL_METHODS)
def test_single_agent_graph_stays_finite(method):
    """A fleet of ONE (everyone else churned out): consensus degenerates to
    the local expert — degree-0 guards must keep DAC/JOR/DALE finite."""
    rng = np.random.default_rng(6)
    X = rng.uniform(-1, 1, (1, 16, 2))
    y = np.sin(X.sum(-1)) + 0.05 * rng.standard_normal((1, 16))
    eng = _engine_from(X, y, 1)
    Xs = jnp.asarray(rng.uniform(-1, 1, (7, 2)))
    _assert_finite(eng, method, Xs)
