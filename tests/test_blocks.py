"""Block-level consistency: mLSTM chunked == sequential, mamba chunked ==
stepwise, MoE balance/dispatch invariants, federated update == GP reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.config import ArchConfig
from repro.models.common import init_tree
from repro.models.xlstm import (mlstm_defs, mlstm_sequential, _mlstm_chunk,
                                init_mlstm_state)
from repro.models.mamba import mamba_defs, mamba_layer, init_mamba_state
from repro.models.moe import moe_defs, moe_ffn


def _cfg(**kw):
    base = dict(name="t", arch_type="dense", num_layers=2, d_model=64,
                num_heads=2, num_kv_heads=2, d_ff=128, vocab_size=128,
                xlstm_chunk=8, mamba_chunk=8, num_experts=4,
                experts_per_token=2, moe_group_size=16)
    base.update(kw)
    return ArchConfig(**base)


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 100), st.sampled_from([4, 8, 16]))
def test_mlstm_chunked_equals_sequential(seed, chunk):
    cfg = _cfg()
    key = jax.random.PRNGKey(seed)
    B, S = 2, 32
    x = 0.5 * jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
    p = init_tree(key, mlstm_defs(cfg), jnp.float32)
    q = jnp.einsum("bsd,dhk->bhsk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bhsk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bhsk", x, p["wv"])
    lf = jax.nn.log_sigmoid(jnp.einsum("bsd,dh->bhs", x, p["wf"]))
    li = jnp.einsum("bsd,dh->bhs", x, p["wi"])
    h_seq, st_seq = mlstm_sequential(q, k, v, lf, li, init_mlstm_state(cfg, B))
    st = init_mlstm_state(cfg, B)
    hs = []
    for i in range(S // chunk):
        sl = slice(i * chunk, (i + 1) * chunk)
        h, st = _mlstm_chunk(q[:, :, sl], k[:, :, sl], v[:, :, sl],
                             lf[:, :, sl], li[:, :, sl], st)
        hs.append(h)
    h_ch = jnp.concatenate(hs, axis=2)
    np.testing.assert_allclose(np.asarray(h_ch), np.asarray(h_seq),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(st["C"]), np.asarray(st_seq["C"]),
                               rtol=1e-5, atol=1e-5)


def test_mamba_chunking_invariance():
    cfg = _cfg(arch_type="hybrid")
    key = jax.random.PRNGKey(0)
    B, S = 2, 32
    x = 0.5 * jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
    p = init_tree(key, mamba_defs(cfg), jnp.float32)
    out8, _ = mamba_layer(p, x, cfg)
    out32, _ = mamba_layer(p, x, cfg.with_overrides(mamba_chunk=32))
    np.testing.assert_allclose(np.asarray(out8), np.asarray(out32),
                               rtol=1e-5, atol=1e-6)


def test_mamba_decode_equals_parallel():
    cfg = _cfg(arch_type="hybrid")
    key = jax.random.PRNGKey(1)
    B, S = 2, 16
    x = 0.5 * jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
    p = init_tree(key, mamba_defs(cfg), jnp.float32)
    out_par, _ = mamba_layer(p, x, cfg)
    st = init_mamba_state(cfg, B, jnp.float32)
    outs = []
    for t in range(S):
        o, st = mamba_layer(p, x[:, t:t + 1], cfg, state=st)
        outs.append(o)
    out_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_dec), np.asarray(out_par),
                               rtol=1e-5, atol=1e-6)


def test_moe_dispatch_capacity_invariants():
    """Each expert receives at most `cap` tokens; combine weights match the
    router's normalized top-k weights for undropped tokens."""
    cfg = _cfg(d_ff=128, moe_capacity_factor=1.0)
    key = jax.random.PRNGKey(2)
    B, S = 2, 32
    x = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
    p = init_tree(key, moe_defs(cfg), jnp.float32)
    out, aux = moe_ffn(p, x, cfg)
    assert out.shape == x.shape
    assert float(aux) >= 1.0 - 1e-6      # Switch aux loss lower bound is 1


def test_moe_aux_loss_balanced_router_is_minimal():
    """A perfectly uniform router gives aux ~= 1 (the theoretical minimum)."""
    cfg = _cfg(d_ff=128)
    key = jax.random.PRNGKey(3)
    p = init_tree(key, moe_defs(cfg), jnp.float32)
    p = dict(p)
    p["router"] = jnp.zeros_like(p["router"])  # uniform probs
    x = jax.random.normal(key, (2, 32, cfg.d_model), jnp.float32)
    out, aux = moe_ffn(p, x, cfg)
    assert abs(float(aux) - 1.0) < 0.35


def test_federated_update_equals_gp_reference():
    """launch.steps.make_federated_train_step applies the SAME eq. 34 update
    as core.training.dec_apx_update (ring graph, scalar case)."""
    from repro.core.training import dec_apx_update
    M, K = 4, 3
    key = jax.random.PRNGKey(4)
    th = jax.random.normal(key, (M, K))
    p = jax.random.normal(jax.random.PRNGKey(5), (M, K))
    g = jax.random.normal(jax.random.PRNGKey(6), (M, K))
    rho, kappa = 0.5, 10.0
    nbr = jnp.roll(th, 1, 0) + jnp.roll(th, -1, 0)
    deg = jnp.full((M,), 2.0)
    th_ref, p_ref = dec_apx_update(th, p, g, nbr, deg, rho, kappa)
    # the steps.py closure inlines the same formula
    p_next = p + rho * (2.0 * th - nbr)
    th_next = (rho * nbr - g + (kappa + 2.0 * rho) * th - p_next) \
        / (kappa + 4.0 * rho)
    np.testing.assert_allclose(np.asarray(th_ref), np.asarray(th_next),
                               atol=1e-12)
    np.testing.assert_allclose(np.asarray(p_ref), np.asarray(p_next),
                               atol=1e-12)
