"""repro.obs: metrics registry, request spans, exporters, training traces.

Acceptance gates (ISSUE 7):

  * concurrent-writer correctness: racing threads never lose counter
    increments or histogram samples;
  * histogram quantile accuracy: interpolated percentiles within the
    geometric bucket ratio of exact numpy percentiles, at O(buckets)
    memory;
  * zero overhead when disabled: a disabled registry makes every write an
    early-return whose cost is noise next to one scheduler dispatch, and
    flipping metrics on/off never changes the engines' jit trace counts;
  * end-to-end traceability: a scheduler request's span stages tile its
    lifetime exactly (sum == e2e), and the JSONL event log + Prometheus
    dump + `GPFleet.metrics()` all expose the same per-tenant counters.
"""
import json
import threading
import time
import urllib.request

import jax
import numpy as np
import pytest

from repro.core.gp import pack
from repro.core.gp import stripe_partition
from repro.data import gp_sample_field, random_inputs
from repro.fleet import FleetConfig, GPFleet
from repro.launch.scheduler import ServingScheduler
from repro.obs import (Histogram, MetricsRegistry, MetricsServer, Span,
                       SpanLog, TraceRecorder, default_latency_buckets,
                       default_registry, parse_prometheus_text,
                       prometheus_text, read_spans, start_metrics_server)

TRUE_LT = pack([1.2, 0.3], 1.3, 0.1)


def echo_predict(Xs):
    Xs = np.asarray(Xs)
    return Xs.sum(axis=-1), np.ones(Xs.shape[0])


@pytest.fixture(scope="module")
def small_fleet():
    X = random_inputs(jax.random.PRNGKey(0), 128)
    _, y = gp_sample_field(jax.random.PRNGKey(1), X, TRUE_LT)
    Xp, yp = stripe_partition(X, y, 4)
    cfg = FleetConfig(num_agents=4, trainer="dec-apx", method="poe",
                      admm_iters=5, chunk=16)
    return GPFleet(cfg).fit(Xp, yp), Xp


# ---------------------------------------------------------------------------
# registry basics
# ---------------------------------------------------------------------------

def test_counter_labels_and_monotonicity():
    reg = MetricsRegistry()
    c = reg.counter("reqs_total", "requests")
    c.inc(tenant="a")
    c.inc(2, tenant="a")
    c.inc(tenant="b", method="poe")
    assert c.value(tenant="a") == 3.0
    assert c.value(tenant="b", method="poe") == 1.0
    assert c.value(tenant="missing") == 0.0
    with pytest.raises(ValueError):
        c.inc(-1, tenant="a")


def test_registry_get_or_create_and_kind_clash():
    reg = MetricsRegistry()
    assert reg.counter("x") is reg.counter("x")
    with pytest.raises(TypeError):
        reg.gauge("x")


def test_gauge_set_and_pull_fn():
    reg = MetricsRegistry()
    g = reg.gauge("depth")
    g.set(3.0, queue="q0")
    box = {"v": 7}
    g.set_fn(lambda: float(box["v"]), queue="q1")
    assert g.value(queue="q0") == 3.0
    assert g.value(queue="q1") == 7.0
    box["v"] = 9
    assert g.value(queue="q1") == 9.0          # sampled at collection time


def test_disabled_registry_writes_are_noops_but_set_fn_registers():
    reg = MetricsRegistry(enabled=False)
    reg.counter("c").inc(5)
    reg.gauge("g").set(1.0)
    reg.histogram("h").observe(0.5)
    assert reg.counter("c").value() == 0.0
    assert reg.histogram("h").count() == 0
    # pull-gauge registration is wiring, not a hot-path write: it sticks
    reg.gauge("g").set_fn(lambda: 42.0)
    assert reg.gauge("g").value() == 42.0
    reg.enable()
    reg.counter("c").inc(5)
    assert reg.counter("c").value() == 5.0


# ---------------------------------------------------------------------------
# concurrent writers
# ---------------------------------------------------------------------------

def test_concurrent_counter_and_histogram_exact_totals():
    """8 racing writer threads, two label sets: no lost updates."""
    reg = MetricsRegistry()
    c = reg.counter("hits_total")
    h = reg.histogram("lat_seconds")
    n_threads, per_thread = 8, 2000

    def writer(i):
        tenant = "even" if i % 2 == 0 else "odd"
        for k in range(per_thread):
            c.inc(tenant=tenant)
            h.observe(1e-4 * (k % 50 + 1), tenant=tenant)

    threads = [threading.Thread(target=writer, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    expect = n_threads // 2 * per_thread
    assert c.value(tenant="even") == expect
    assert c.value(tenant="odd") == expect
    assert h.count(tenant="even") == expect
    assert h.count(tenant="odd") == expect
    assert h.sum(tenant="even") == pytest.approx(
        per_thread / 50 * sum(1e-4 * j for j in range(1, 51))
        * (n_threads // 2), rel=1e-9)


# ---------------------------------------------------------------------------
# histogram quantiles
# ---------------------------------------------------------------------------

def test_histogram_quantiles_match_numpy_within_bucket_ratio():
    """Interpolated quantiles vs exact percentiles on a lognormal latency
    sample: relative error bounded by the bucket ratio (~19% default)."""
    rng = np.random.default_rng(0)
    samples = np.exp(rng.normal(-6.0, 1.0, size=20_000))   # ~ms scale
    reg = MetricsRegistry()
    h = reg.histogram("lat")
    for v in samples:
        h.observe(float(v))
    ratio = default_latency_buckets()[1] / default_latency_buckets()[0]
    for q in (0.5, 0.9, 0.99):
        exact = float(np.percentile(samples, q * 100))
        approx = h.quantile(q)
        assert abs(approx - exact) / exact <= (ratio - 1.0) + 1e-6, \
            (q, exact, approx)


def test_histogram_edge_cases():
    reg = MetricsRegistry()
    h = reg.histogram("lat")
    assert np.isnan(h.quantile(0.5))           # empty series
    h.observe(0.004)
    # single sample: min == max tightens every quantile to the exact value
    assert h.quantile(0.0) == pytest.approx(0.004)
    assert h.quantile(1.0) == pytest.approx(0.004)
    h2 = reg.histogram("lat2", buckets=(1.0, 2.0))
    h2.observe(100.0)                          # overflow bucket
    assert h2.quantile(1.0) == pytest.approx(100.0)
    with pytest.raises(ValueError):
        h.quantile(1.5)
    with pytest.raises(ValueError):
        reg.histogram("bad", buckets=(2.0, 1.0))


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------

def test_span_stages_tile_elapsed_exactly():
    sp = Span("request", t=100.0, tenant="t")
    sp.advance("queue", 100.5)
    sp.advance("pack", 100.6)
    sp.advance("device", 101.0)
    sp.advance("queue", 101.2)                 # re-entry accumulates
    assert sp.stages["queue"] == pytest.approx(0.7)
    assert sum(sp.stages.values()) == pytest.approx(sp.elapsed)
    ev = sp.event(outcome="ok", rows=8)
    assert ev["tenant"] == "t" and ev["rows"] == 8
    assert sum(ev["stages_ms"].values()) == pytest.approx(ev["e2e_ms"])


def test_span_log_round_trip(tmp_path):
    path = str(tmp_path / "spans.jsonl")
    with SpanLog(path) as log:
        sp = Span("request", t=0.0, tenant="t")
        sp.advance("queue", 0.25)
        log.emit(sp.event())
        log.emit(sp.event(outcome="error", error="boom"))
    events = read_spans(path)
    assert len(events) == 2
    assert events[0]["event"] == "request"
    assert events[0]["stages_ms"]["queue"] == pytest.approx(250.0)
    assert events[1]["outcome"] == "error"


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------

def _seeded_registry():
    reg = MetricsRegistry()
    reg.counter("reqs_total", "reqs").inc(3, tenant="a b")
    reg.counter("reqs_total").inc(5, tenant='quo"te')
    reg.gauge("depth").set(2.5)
    h = reg.histogram("lat", buckets=(0.001, 0.01, 0.1))
    for v in (0.0005, 0.005, 0.005, 0.05, 1.0):
        h.observe(v, tenant="a b")
    return reg


def test_prometheus_text_parses_back():
    reg = _seeded_registry()
    fams = parse_prometheus_text(prometheus_text(reg))
    vals = {tuple(sorted(l.items())): v for l, v in fams["reqs_total"]}
    assert vals[(("tenant", "a b"),)] == 3.0
    assert vals[(("tenant", 'quo"te'),)] == 5.0          # escaping survives
    assert fams["depth"][0][1] == 2.5
    # histogram: cumulative le= buckets, _sum/_count
    buckets = {l["le"]: v for l, v in fams["lat_bucket"]}
    assert buckets["0.001"] == 1.0
    assert buckets["0.01"] == 3.0
    assert buckets["0.1"] == 4.0
    assert buckets["+Inf"] == 5.0
    assert fams["lat_count"][0][1] == 5.0
    assert fams["lat_sum"][0][1] == pytest.approx(1.0605)


def test_parse_prometheus_rejects_malformed():
    with pytest.raises(ValueError):
        parse_prometheus_text("lat_bucket{le=0.1} 3\n")   # unquoted label
    with pytest.raises(ValueError):
        parse_prometheus_text("novalue\n")


def test_metrics_server_serves_metrics_and_statusz():
    reg = _seeded_registry()
    with MetricsServer(port=0, registry=reg) as srv:
        base = f"http://127.0.0.1:{srv.port}"
        text = urllib.request.urlopen(f"{base}/metrics").read().decode()
        assert "reqs_total" in parse_prometheus_text(text)
        snap = json.loads(
            urllib.request.urlopen(f"{base}/statusz").read().decode())
        assert snap["reqs_total"]["kind"] == "counter"
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(f"{base}/nope")
    assert isinstance(start_metrics_server(0, registry=reg), MetricsServer)


# ---------------------------------------------------------------------------
# scheduler integration: tenant-labeled mirror + request spans
# ---------------------------------------------------------------------------

def test_scheduler_counters_mirror_into_registry(tmp_path):
    reg = MetricsRegistry()
    path = str(tmp_path / "spans.jsonl")
    sched = ServingScheduler(registry=reg, span_log=path)
    sched.add_tenant("t", echo_predict, slots=(4,))
    futs = [sched.add_request(np.full((3, 2), float(i)), tenant="t")
            for i in range(5)]
    for f in futs:
        f.result(timeout=10)
    sched.close()
    st = sched.tenant_stats["t"]
    c = {name: reg.counter(name).value(tenant="t")
         for name in ("gp_requests_total", "gp_queries_total",
                      "gp_batches_total", "gp_padded_queries_total",
                      "gp_completed_total")}
    # local counts are the authoritative surface; the registry mirror must
    # agree exactly (what exporters scrape)
    assert c["gp_requests_total"] == st.requests == 5
    assert c["gp_queries_total"] == st.queries == 15
    assert c["gp_batches_total"] == st.batches
    assert c["gp_padded_queries_total"] == st.padded_queries
    assert c["gp_completed_total"] == st.completed == 5
    assert reg.histogram("gp_request_latency_seconds").count(tenant="t") == 5
    assert reg.gauge("gp_padding_fraction").value(tenant="t") \
        == pytest.approx(st.padding_fraction)
    # per-stage histogram saw every pipeline stage
    stage_labels = {l["stage"] for l in
                    reg.histogram("gp_request_stage_seconds").labelsets()}
    assert {"queue", "pack", "dispatch", "device", "stitch"} <= stage_labels

    spans = read_spans(path)
    assert len(spans) == 5
    for s in spans:
        assert s["outcome"] == "ok" and s["tenant"] == "t"
        # contiguous stage accounting: the stages TILE the lifetime
        assert sum(s["stages_ms"].values()) \
            == pytest.approx(s["e2e_ms"], rel=0.05)


def test_scheduler_span_covers_multi_slot_request(tmp_path):
    """A request streaming across several slots keeps one span whose
    stages still sum to its end-to-end latency (queue re-entry)."""
    path = str(tmp_path / "spans.jsonl")
    sched = ServingScheduler(span_log=path, registry=MetricsRegistry())
    sched.add_tenant("t", echo_predict, slots=(4,))
    f = sched.add_request(np.ones((10, 2)), tenant="t")   # 3 slots of 4
    mean, _ = f.result(timeout=10)
    sched.close()
    assert mean.shape == (10,)
    (s,) = read_spans(path)
    assert s["slots"] >= 3
    assert sum(s["stages_ms"].values()) == pytest.approx(s["e2e_ms"],
                                                         rel=0.05)


def test_scheduler_under_threaded_load_loses_nothing(tmp_path):
    """Many client threads against one scheduler: registry totals match
    the authoritative local counters and every span is accounted for."""
    reg = MetricsRegistry()
    path = str(tmp_path / "spans.jsonl")
    sched = ServingScheduler(registry=reg, span_log=path)
    sched.add_tenant("t", echo_predict, slots=(4, 8))
    n_threads, per_thread = 6, 20
    errs = []

    def client(i):
        try:
            for k in range(per_thread):
                n = 1 + (i + k) % 7
                f = sched.add_request(np.full((n, 2), 1.0), tenant="t")
                mean, _ = f.result(timeout=30)
                assert mean.shape == (n,)
        except Exception as e:            # pragma: no cover - failure path
            errs.append(e)

    threads = [threading.Thread(target=client, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    sched.close()
    assert not errs
    total = n_threads * per_thread
    st = sched.tenant_stats["t"]
    assert st.requests == st.completed == total
    assert reg.counter("gp_requests_total").value(tenant="t") == total
    assert reg.counter("gp_completed_total").value(tenant="t") == total
    assert reg.counter("gp_queries_total").value(tenant="t") == st.queries
    assert len(read_spans(path)) == total


# ---------------------------------------------------------------------------
# zero overhead when disabled
# ---------------------------------------------------------------------------

def test_disabled_registry_write_cost_is_noise_vs_dispatch():
    """~20 metric writes ride each dispatch; with the registry disabled
    their total cost must be < 5% of one echo-engine dispatch through the
    scheduler."""
    reg = MetricsRegistry(enabled=False)
    c = reg.counter("x")
    n = 20_000
    t0 = time.perf_counter()
    for _ in range(n):
        c.inc(tenant="t")
    per_write = (time.perf_counter() - t0) / n

    sched = ServingScheduler(registry=reg, autostart=False)
    sched.add_tenant("t", echo_predict, slots=(8,))
    t0 = time.perf_counter()
    reps = 50
    for _ in range(reps):
        f = sched.add_request(np.ones((8, 2)), tenant="t")
        sched.step(force=True)
        f.result(timeout=10)
    per_dispatch = (time.perf_counter() - t0) / reps
    sched.close()
    assert 20 * per_write < 0.05 * per_dispatch, \
        (per_write, per_dispatch)


def test_metrics_toggle_never_changes_jit_traces(small_fleet):
    """Flipping the registry on/off must not interact with jit tracing:
    the engine's trace count stays flat across toggles on repeated
    predicts of the same geometry."""
    fleet, Xp = small_fleet
    reg = default_registry()
    was = reg.enabled
    try:
        reg.disable()
        fleet.predict(Xp[0][:16])
        misses = fleet.jit_cache_misses
        reg.enable()
        fleet.predict(Xp[0][:16])
        reg.disable()
        fleet.predict(Xp[0][:16])
        assert fleet.jit_cache_misses == misses
    finally:
        reg.enabled = was


# ---------------------------------------------------------------------------
# engine trace counter + training diagnostics + facade
# ---------------------------------------------------------------------------

def test_engine_trace_counter_matches_cache_misses(small_fleet):
    fleet, Xp = small_fleet
    reg = default_registry()
    was = reg.enabled
    try:
        reg.enable()
        before = reg.counter("gp_jit_traces_total").value(
            engine="replicated", method="gpoe")
        fleet.predict(Xp[0][:16], method="gpoe")       # new method: traces
        fleet.predict(Xp[0][:16], method="gpoe")       # cached: no trace
        after = reg.counter("gp_jit_traces_total").value(
            engine="replicated", method="gpoe")
        assert after == before + 1
    finally:
        reg.enabled = was


def test_engine_diagnostics_mode_captures_consensus_trajectories(
        small_fleet):
    """set_diagnostics(True) adds the per-round DAC (and, for NPAE, JOR)
    residual trajectories to info without perturbing predictions; the flag
    is baked into traces, so toggling clears the jit cache."""
    fleet, Xp = small_fleet
    eng = fleet.engine
    m0, v0, i0 = fleet.predict(Xp[0][:16], method="rbcm")
    assert "dac_residuals" not in i0
    eng.set_diagnostics(True)
    try:
        m1, v1, i1 = fleet.predict(Xp[0][:16], method="rbcm")
        np.testing.assert_array_equal(np.asarray(m0), np.asarray(m1))
        assert i1["dac_residuals"].shape == (fleet.config.dac_iters,)
        _, _, i2 = fleet.predict(Xp[0][:16], method="npae")
        assert i2["jor_residuals"].shape == (fleet.config.jor_iters,)
        assert i2["jor_residuals"][-1] == pytest.approx(
            float(i2["jor_residual"]))
    finally:
        eng.set_diagnostics(False)


def test_trace_recorder_ingests_fit_diagnostics(small_fleet, tmp_path):
    fleet, Xp = small_fleet
    X = random_inputs(jax.random.PRNGKey(3), 64)
    _, y = gp_sample_field(jax.random.PRNGKey(4), X, TRUE_LT)
    Xp2, yp2 = stripe_partition(X, y, 4)
    rec = TraceRecorder()
    f2 = GPFleet(fleet.config).fit(Xp2, yp2, trace=rec)
    assert len(rec) == 1
    t = rec.last()
    assert t["name"] == "dec-apx" and t["num_agents"] == 4
    iters = fleet.config.admm_iters
    assert t["nll"].shape == (iters, 4)
    assert t["primal_residuals"].shape == (iters,)
    assert t["theta_trajectory"].shape[0] == iters
    (s,) = rec.summary()
    assert s["iters"] == iters and np.isfinite(s["final_nll_mean"])
    # diagnostics never perturb the result
    f3 = GPFleet(fleet.config).fit(Xp2, yp2)
    np.testing.assert_array_equal(np.asarray(f2.thetas),
                                  np.asarray(f3.thetas))
    # JSONL round trip
    path = rec.to_jsonl(str(tmp_path / "trace.jsonl"))
    with open(path) as fh:
        row = json.loads(fh.readline())
    assert row["name"] == "dec-apx"
    assert len(row["residuals"]) == iters


def test_fleet_metrics_agrees_with_prometheus_endpoint(small_fleet):
    """Acceptance: GPFleet.metrics() and the /metrics endpoint expose the
    same counters with the same per-tenant labels."""
    fleet, Xp = small_fleet
    reg = default_registry()
    was = reg.enabled
    try:
        reg.enable()
        with fleet.to_server(batch=32) as sched:
            # rename the default tenant label by using a fresh scheduler
            # is overkill; the "default" tenant is unique enough here
            for _ in range(3):
                sched.submit(Xp[0][:8]).result(timeout=30)
        snap = fleet.metrics()
        assert snap["fleet"]["num_agents"] == 4
        assert snap["fleet"]["is_fitted"] is True
        snap_reqs = {tuple(sorted(s["labels"].items())): s["value"]
                     for s in snap["gp_requests_total"]["series"]}
        fams = parse_prometheus_text(prometheus_text(reg))
        prom_reqs = {tuple(sorted(l.items())): v
                     for l, v in fams["gp_requests_total"]}
        assert snap_reqs == prom_reqs
        assert snap_reqs[(("tenant", "default"),)] >= 3
        for name in ("gp_queries_total", "gp_padded_queries_total",
                     "gp_engine_seconds_total", "gp_jit_traces_total"):
            assert name in snap and name in fams, name
    finally:
        reg.enabled = was
