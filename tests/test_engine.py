"""Factor-cached, query-tiled prediction engine == the per-call paths.

Acceptance gate for the serving engine: for EVERY decentralized prediction
method (and the centralized references), fit-once + tiled serving matches the
existing fit-per-call functions to <= 1e-6, including ragged Nt (chunk does
not divide the query count), CBNN masks, and the streamed-mean path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.consensus import complete_graph, path_graph
from repro.core.gp import (augment, communication_dataset, pack,
                           stripe_partition)
from repro.core.prediction import (PredictionEngine, cbnn_scores,
                                   cbnn_scores_cached, chol_factors, dec_bcm,
                                   dec_gpoe, dec_grbcm, dec_nn_bcm,
                                   dec_nn_gpoe, dec_nn_grbcm, dec_nn_npae,
                                   dec_nn_poe, dec_nn_rbcm, dec_npae,
                                   dec_npae_star, dec_poe, dec_rbcm,
                                   fit_experts, local_moments,
                                   local_moments_cached, map_query_tiles,
                                   npae_terms, npae_terms_cached, poe)
from repro.data import gp_sample_field, random_inputs

TRUE_LT = pack([1.2, 0.3], 1.3, 0.1)
M = 6
NT = 23          # deliberately not a multiple of the engine chunk (8)
CHUNK = 8
ITERS = 150
ETA = 0.1


@pytest.fixture(scope="module")
def setup():
    X = random_inputs(jax.random.PRNGKey(0), 480)
    _, y = gp_sample_field(jax.random.PRNGKey(1), X, TRUE_LT)
    Xp, yp = stripe_partition(X, y, M)
    Xs = random_inputs(jax.random.PRNGKey(2), NT)
    Xc, yc = communication_dataset(jax.random.PRNGKey(3), Xp, yp)
    Xa, ya = augment(Xp, yp, Xc, yc)
    return Xp, yp, Xs, Xc, yc, Xa, ya


@pytest.fixture(scope="module")
def engines(setup):
    Xp, yp, Xs, Xc, yc, Xa, ya = setup
    f = fit_experts(TRUE_LT, Xp, yp)
    fa = fit_experts(TRUE_LT, Xa, ya)
    fc = fit_experts(TRUE_LT, Xc[None], yc[None])
    eng = PredictionEngine(f, path_graph(M), chunk=CHUNK, dac_iters=ITERS,
                           jor_iters=400, dale_iters=800, eta_nn=ETA,
                           fitted_aug=fa, fitted_comm=fc)
    eng_c = PredictionEngine(f, complete_graph(M), chunk=CHUNK,
                             dac_iters=ITERS, jor_iters=400, eta_nn=ETA,
                             fitted_aug=fa, fitted_comm=fc)
    return eng, eng_c


def assert_matches(engine_out, ref_out, tol=1e-6):
    np.testing.assert_allclose(np.asarray(engine_out[0]),
                               np.asarray(ref_out[0]), atol=tol)
    np.testing.assert_allclose(np.asarray(engine_out[1]),
                               np.asarray(ref_out[1]), atol=tol)


def test_cached_factors_match_per_call(setup):
    """local_moments / npae_terms == their factor-cached equivalents."""
    Xp, yp, Xs, *_ = setup
    L, alpha = chol_factors(TRUE_LT, Xp, yp)
    mu_ref, var_ref = local_moments(TRUE_LT, Xp, yp, Xs)
    mu, var = local_moments_cached(TRUE_LT, Xp, L, alpha, Xs)
    np.testing.assert_allclose(np.asarray(mu), np.asarray(mu_ref), atol=1e-10)
    np.testing.assert_allclose(np.asarray(var), np.asarray(var_ref),
                               atol=1e-10)
    for a, b in zip(npae_terms(TRUE_LT, Xp, yp, Xs),
                    npae_terms_cached(TRUE_LT, Xp, L, alpha, Xs)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-10)
    s_ref = cbnn_scores(TRUE_LT, Xp, Xs)
    s = cbnn_scores_cached(TRUE_LT, Xp, L, Xs)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), atol=1e-10)


def test_map_query_tiles_ragged():
    """Tiling pads, stitches per-query leaves, and max-reduces residuals."""
    Xs = random_inputs(jax.random.PRNGKey(9), 13)

    def tile(Xq):
        return {"q": jnp.sum(Xq, axis=1)}, {"r": jnp.max(Xq)}

    perq, red = map_query_tiles(tile, Xs, chunk=4)
    np.testing.assert_allclose(np.asarray(perq["q"]),
                               np.asarray(jnp.sum(Xs, axis=1)), atol=1e-12)
    # edge-replicated padding duplicates real queries, so reduced leaves
    # describe the real workload exactly
    assert float(red["r"]) == float(jnp.max(Xs))


@pytest.mark.parametrize("method,ref_fn", [
    ("poe", dec_poe), ("gpoe", dec_gpoe), ("bcm", dec_bcm),
    ("rbcm", dec_rbcm)])
def test_engine_dac_family(setup, engines, method, ref_fn):
    Xp, yp, Xs, *_ = setup
    eng, _ = engines
    ref = ref_fn(TRUE_LT, Xp, yp, Xs, path_graph(M), iters=ITERS)
    assert_matches(eng.predict(method, Xs), ref)


def test_engine_grbcm(setup, engines):
    Xp, yp, Xs, Xc, yc, Xa, ya = setup
    eng, _ = engines
    ref = dec_grbcm(TRUE_LT, Xa, ya, Xc, yc, Xs, path_graph(M), iters=ITERS)
    assert_matches(eng.predict("grbcm", Xs), ref)


@pytest.mark.parametrize("method,ref_fn", [
    ("npae", dec_npae), ("npae_star", dec_npae_star)])
def test_engine_npae_family(setup, engines, method, ref_fn):
    Xp, yp, Xs, *_ = setup
    _, eng_c = engines
    ref = ref_fn(TRUE_LT, Xp, yp, Xs, complete_graph(M), jor_iters=400,
                 dac_iters=ITERS)
    assert_matches(eng_c.predict(method, Xs), ref)


@pytest.mark.parametrize("method,ref_fn", [
    ("nn_poe", dec_nn_poe), ("nn_gpoe", dec_nn_gpoe),
    ("nn_bcm", dec_nn_bcm), ("nn_rbcm", dec_nn_rbcm)])
def test_engine_nn_family(setup, engines, method, ref_fn):
    Xp, yp, Xs, *_ = setup
    eng, _ = engines
    out = eng.predict(method, Xs)
    ref = ref_fn(TRUE_LT, Xp, yp, Xs, path_graph(M), ETA, iters=ITERS)
    assert_matches(out, ref)
    np.testing.assert_array_equal(np.asarray(out[2]["mask"]),
                                  np.asarray(ref[2]["mask"]))


def test_engine_nn_grbcm(setup, engines):
    Xp, yp, Xs, Xc, yc, Xa, ya = setup
    eng, _ = engines
    ref = dec_nn_grbcm(TRUE_LT, Xa, ya, Xc, yc, Xs, path_graph(M), ETA,
                       iters=ITERS, Xp=Xp)
    assert_matches(eng.predict("nn_grbcm", Xs), ref)


def test_engine_nn_npae(setup, engines):
    Xp, yp, Xs, *_ = setup
    eng, _ = engines
    ref = dec_nn_npae(TRUE_LT, Xp, yp, Xs, path_graph(M), ETA, dale_iters=800)
    assert_matches(eng.predict("nn_npae", Xs), ref)


def test_engine_centralized_refs(setup, engines):
    Xp, yp, Xs, *_ = setup
    eng, _ = engines
    mu, var = local_moments(TRUE_LT, Xp, yp, Xs)
    assert_matches(eng.predict("cen_poe", Xs), poe(mu, var))


def test_engine_stream_mean_path(setup):
    """Streamed (rbf_matvec) posterior means == the dense mean path."""
    Xp, yp, Xs, *_ = setup
    f = fit_experts(TRUE_LT, Xp, yp)
    eng = PredictionEngine(f, path_graph(M), chunk=CHUNK, dac_iters=ITERS,
                           stream_mean=True)
    ref = dec_poe(TRUE_LT, Xp, yp, Xs, path_graph(M), iters=ITERS)
    assert_matches(eng.predict("poe", Xs), ref, tol=1e-6)
    means = eng.posterior_means_streamed(Xs)
    mu, _ = local_moments(TRUE_LT, Xp, yp, Xs)
    np.testing.assert_allclose(np.asarray(means), np.asarray(mu), atol=1e-6)


def test_engine_jit_cache_reuse(setup, engines):
    """Second same-shape request reuses the compiled program (no retrace)."""
    Xp, yp, Xs, *_ = setup
    eng, _ = engines
    eng.predict("poe", Xs)
    compiled = eng._compiled["poe"]
    m1, _, _ = eng.predict("poe", Xs)
    assert eng._compiled["poe"] is compiled
    Xs2 = random_inputs(jax.random.PRNGKey(7), NT)
    m2, _, _ = eng.predict("poe", Xs2)       # same shape, different queries
    assert not np.allclose(np.asarray(m1), np.asarray(m2))


def test_engine_rejects_unknown_and_missing(setup):
    Xp, yp, Xs, *_ = setup
    f = fit_experts(TRUE_LT, Xp, yp)
    eng = PredictionEngine(f, path_graph(M))
    with pytest.raises(ValueError):
        eng.predict("nope", Xs)
    with pytest.raises(ValueError):
        eng.predict("grbcm", Xs)             # no fitted_aug/fitted_comm
