"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps +
hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.ops import rbf_gram, flash_attention
from repro.kernels.flash_jnp import flash_attention_jnp


@pytest.mark.parametrize("n,m,d", [(64, 64, 1), (100, 130, 2), (256, 256, 2),
                                   (300, 300, 5), (17, 33, 3)])
def test_rbf_gram_shapes(n, m, d):
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    x1 = jax.random.normal(k1, (n, d), jnp.float32)
    x2 = jax.random.normal(k2, (m, d), jnp.float32)
    ls = jnp.full((d,), 0.7, jnp.float32)
    got = rbf_gram(x1, x2, ls, 1.3, noise=0.1, with_noise=(n == m),
                   use_pallas=True, interpret=True)
    want = ref.rbf_gram_ref(x1, x2, ls, 1.3, noise=0.1 if n == m else 0.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=8, deadline=None)
@given(st.integers(8, 150), st.integers(8, 150), st.integers(1, 6),
       st.floats(0.3, 2.0), st.floats(0.5, 2.0))
def test_rbf_gram_property(n, m, d, ls_val, sf):
    x1 = jax.random.normal(jax.random.PRNGKey(n), (n, d), jnp.float32)
    x2 = jax.random.normal(jax.random.PRNGKey(m + 777), (m, d), jnp.float32)
    ls = jnp.full((d,), ls_val, jnp.float32)
    got = rbf_gram(x1, x2, ls, sf, use_pallas=True, interpret=True)
    want = ref.rbf_gram_ref(x1, x2, ls, sf)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-5)
    # kernel values bounded by sf^2 and positive
    assert np.all(np.asarray(got) <= sf**2 + 1e-4)
    assert np.all(np.asarray(got) >= 0)


@pytest.mark.parametrize("b,h,kh,sq,sk,d,causal,window", [
    (2, 4, 2, 128, 128, 64, True, None),
    (1, 8, 8, 256, 256, 64, False, None),
    (2, 4, 4, 1, 512, 64, True, None),          # decode shape
    (1, 4, 2, 128, 512, 64, True, 64),          # sliding window
    (1, 2, 1, 96, 96, 32, True, None),
    (1, 4, 1, 64, 64, 128, True, None),         # max GQA ratio
])
def test_flash_attention_pallas(b, h, kh, sq, sk, d, causal, window):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, h, sq, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, kh, sk, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, kh, sk, d), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          use_pallas=True, interpret=True, bq=64, bk=64)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 4, 128, 64)).astype(dtype)
    k = jax.random.normal(ks[1], (1, 2, 128, 64)).astype(dtype)
    v = jax.random.normal(ks[2], (1, 2, 128, 64)).astype(dtype)
    got = flash_attention(q, k, v, use_pallas=True, interpret=True,
                          bq=64, bk=64)
    want = ref.flash_attention_ref(q, k, v)
    assert got.dtype == dtype
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_jnp_custom_vjp_grads():
    """The chunked jnp flash backward == autodiff through the reference."""
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (2, 4, 64, 32), jnp.float32)
    k = jax.random.normal(ks[1], (2, 2, 128, 32), jnp.float32)
    v = jax.random.normal(ks[2], (2, 2, 128, 32), jnp.float32)
    f1 = lambda *a: jnp.sum(jnp.sin(flash_attention_jnp(*a, True, 64, 32)))
    f2 = lambda *a: jnp.sum(jnp.sin(
        ref.flash_attention_ref(*a, causal=True, window=64)))
    g1 = jax.grad(f1, (0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, (0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


@settings(max_examples=6, deadline=None)
@given(st.integers(1, 3), st.sampled_from([1, 2, 4]),
       st.sampled_from([32, 64, 96]), st.booleans())
def test_flash_property_softmax_rows(b, g, s, causal):
    """Property: attention output is a convex combination of values ->
    bounded by per-column min/max of v."""
    h, kh = 2 * g, 2
    ks = jax.random.split(jax.random.PRNGKey(b * 100 + s), 3)
    q = jax.random.normal(ks[0], (b, h, s, 16), jnp.float32)
    k = jax.random.normal(ks[1], (b, kh, s, 16), jnp.float32)
    v = jax.random.normal(ks[2], (b, kh, s, 16), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, use_pallas=True,
                          interpret=True, bq=32, bk=32)
    assert np.all(np.asarray(out) <= float(v.max()) + 1e-4)
    assert np.all(np.asarray(out) >= float(v.min()) - 1e-4)


def test_gp_core_uses_same_kernel_as_pallas():
    """rbf_gram (pallas) == core.gp.kernel.se_kernel — single source of truth
    for the paper's covariance."""
    from repro.core.gp import se_kernel, pack
    x1 = jax.random.normal(jax.random.PRNGKey(3), (50, 2), jnp.float32)
    lt = pack([0.9, 0.4], 1.1, 0.1)
    ls = jnp.exp(lt[:2]).astype(jnp.float32)
    got = rbf_gram(x1, x1, ls, float(jnp.exp(lt[2])), use_pallas=True,
                   interpret=True)
    want = se_kernel(x1.astype(jnp.float64), x1.astype(jnp.float64), lt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)
