"""Sparse pseudo-representation experts (core.sparse): accuracy-vs-m
convergence to the exact experts, the Titsias bound inequality, the
blocked Kmn statistics, the low-rank NPAE factors, the sharded
`npae_sparse` parity gate, fleet persistence, and the registry's sparse
capability flags.

Acceptance gates covered here (ISSUE: sparse pseudo-representation
experts):
  - sharded npae_sparse == replicated to <= 1e-6 in f64 (by construction
    it is bit-identical: both assemble the SAME cross-covariance from the
    SAME ring-allgathered factors and run the SAME aggregation.npae);
  - sparse fleets save -> load bit-identically through GPFleet;
  - every MethodSpec declares whether it can serve from SparseExperts,
    and exactly the dense-NPAE family cannot.

Runs on however many local devices exist (1-device meshes degenerate the
ring collectives to identity); CI re-runs the file under
--xla_force_host_platform_device_count=8.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.consensus import path_graph, ring_allgather
from repro.core.gp import pack, stripe_partition, unpack
from repro.core.training.factorized import local_nlls
from repro.core.prediction import (PredictionEngine, ShardedEngine,
                                   fit_experts, local_moments)
from repro.core.sparse import (SparseExperts, cross_lowrank,
                               dec_npae_sparse, fit_sparse_experts,
                               make_sparse_grad, npae_terms_lowrank,
                               select_inducing, sparse_moments_cached,
                               sparse_nll, sparse_nlls, sparse_npae_factors,
                               sparse_scores, train_fact_sparse)
from repro.data import gp_sample_field, random_inputs
from repro.fleet import (METHODS, FleetConfig, GPFleet, get_method,
                         method_names, trainer_names, validate_config)
from repro.fleet.registry import SPARSE_TRAINERS
from repro.kernels.ops import kmn_stats, rbf_gram
from repro.launch.mesh import make_agent_mesh

TRUE_LT = pack([1.2, 0.3], 1.3, 0.1)
M = 4
NI = 96
NT = 17


@pytest.fixture(scope="module")
def setup():
    X = random_inputs(jax.random.PRNGKey(0), M * NI)
    _, y = gp_sample_field(jax.random.PRNGKey(1), X, TRUE_LT)
    Xp, yp = stripe_partition(X, y, M)
    Xs = random_inputs(jax.random.PRNGKey(2), NT)
    return Xp, yp, Xs


def sparse_fit(Xp, yp, m, **kw):
    return fit_sparse_experts(TRUE_LT, Xp, yp, select_inducing(Xp, m), **kw)


# ---------------------------------------------------------------- kernels

def test_kmn_stats_matches_direct(setup):
    Xp, yp, _ = setup
    ls, sigma_f, _ = unpack(TRUE_LT)
    Z = select_inducing(Xp, 24)[0]
    K = rbf_gram(Z, Xp[0], ls, sigma_f)
    B, b = kmn_stats(Z, Xp[0], yp[0], ls, sigma_f, bn=17)  # ragged blocks
    np.testing.assert_allclose(B, K @ K.T, rtol=1e-10, atol=1e-10)
    np.testing.assert_allclose(b, K @ yp[0], rtol=1e-10, atol=1e-10)


def test_select_inducing():
    Xp = random_inputs(jax.random.PRNGKey(3), M * NI).reshape(M, NI, -1)
    Zs = select_inducing(Xp, 16, "stride")
    assert Zs.shape == (M, 16, Xp.shape[-1])
    # m = Ni recovers the full per-agent set; m > Ni clamps
    np.testing.assert_array_equal(select_inducing(Xp, NI), Xp)
    np.testing.assert_array_equal(select_inducing(Xp, NI + 50), Xp)
    Zr = select_inducing(Xp, 16, "random", seed=7)
    assert Zr.shape == (M, 16, Xp.shape[-1])
    # random draws without replacement from the agent's own points
    assert all(any(bool(jnp.all(z == x)) for x in np.asarray(Xp[0]))
               for z in np.asarray(Zr[0]))
    with pytest.raises(ValueError, match="inducing_init"):
        select_inducing(Xp, 16, "kmeans")


# ------------------------------------------------- accuracy vs m (exact GP)

def test_recovers_exact_at_m_eq_ni(setup):
    """m = Ni: the Titsias posterior IS the exact posterior (up to the
    factorization's conditioning — bounded, not bit-equal)."""
    Xp, yp, Xs = setup
    sf = sparse_fit(Xp, yp, NI)
    mu_s, var_s = sparse_moments_cached(TRUE_LT, sf.Z, sf.Lmm, sf.LS,
                                        sf.c, Xs)
    mu_e, var_e = local_moments(TRUE_LT, Xp, yp, Xs)
    assert float(jnp.max(jnp.abs(mu_s - mu_e))) < 5e-2
    assert float(jnp.max(jnp.abs(var_s - var_e))) < 1e-3
    # the Qnn diagonal-correction trace vanishes as m -> Ni
    assert float(jnp.max(sf.tr_corr)) < 1e-4


def test_accuracy_improves_with_m(setup):
    """Bounded degradation, monotone fidelity: the error against the exact
    local moments shrinks as m grows, as does tr_corr."""
    Xp, yp, Xs = setup
    mu_e, _ = local_moments(TRUE_LT, Xp, yp, Xs)
    errs, traces = [], []
    for m in (8, 32, NI):
        sf = sparse_fit(Xp, yp, m)
        mu_s, _ = sparse_moments_cached(TRUE_LT, sf.Z, sf.Lmm, sf.LS,
                                        sf.c, Xs)
        errs.append(float(jnp.max(jnp.abs(mu_s - mu_e))))
        traces.append(float(jnp.mean(sf.tr_corr)))
    assert errs[-1] <= errs[0] and traces[-1] <= traces[0]
    assert traces[-1] < 1e-4


def test_collapsed_bound_dominates_exact_nll(setup):
    """-ELBO_i >= exact NLL_i for every agent (Titsias inequality), tight
    at m = Ni."""
    Xp, yp, _ = setup
    exact = local_nlls(TRUE_LT, Xp, yp)
    loose = sparse_nlls(TRUE_LT, select_inducing(Xp, 8), Xp, yp)
    tight = sparse_nlls(TRUE_LT, select_inducing(Xp, NI), Xp, yp)
    assert bool(jnp.all(loose >= exact - 1e-6))
    assert bool(jnp.all(tight >= exact - 1e-6))
    np.testing.assert_allclose(tight, exact, rtol=1e-3)
    assert float(jnp.sum(loose - exact)) > float(jnp.sum(tight - exact))


def test_sparse_scores_match_moment_gap(setup):
    """CBNN scores are sigma_f^2 - var_i — same scale as the dense path."""
    Xp, yp, Xs = setup
    sf = sparse_fit(Xp, yp, 32)
    _, var = sparse_moments_cached(TRUE_LT, sf.Z, sf.Lmm, sf.LS, sf.c, Xs)
    sc = sparse_scores(TRUE_LT, sf.Z, sf.Lmm, sf.LS, Xs)
    np.testing.assert_allclose(sc, sf.prior_var - var, atol=1e-9)


# ------------------------------------------------------------ low-rank NPAE

def test_npae_terms_lowrank_structure(setup):
    Xp, yp, Xs = setup
    sf = sparse_fit(Xp, yp, 32)
    mu, kA, CA = npae_terms_lowrank(TRUE_LT, sf.Z, sf.Lmm, sf.LS, sf.c, Xs)
    assert mu.shape == (M, NT) and kA.shape == (M, NT)
    assert CA.shape == (NT, M, M)
    # diagonal pinned to the exact local kA; matrix symmetric
    idx = jnp.arange(M)
    np.testing.assert_allclose(CA[:, idx, idx], kA.T, atol=1e-12)
    np.testing.assert_allclose(CA, jnp.swapaxes(CA, 1, 2), atol=1e-9)


def test_dec_npae_sparse_converges_to_exact_mean(setup):
    """The sparse NPAE prediction approaches the exact-expert NPAE as m
    grows (same aggregation core, low-rank cross-covariance)."""
    Xp, yp, Xs = setup
    eng = PredictionEngine(fit_experts(TRUE_LT, Xp, yp), path_graph(M),
                           chunk=8)
    mu_e, _, _ = eng.predict("npae", Xs)
    err = []
    for m in (8, NI):
        mu, var = dec_npae_sparse(TRUE_LT, Xp, yp, Xs, m)
        assert bool(jnp.all(jnp.isfinite(mu))) and bool(jnp.all(var > 0))
        err.append(float(jnp.max(jnp.abs(mu - mu_e))))
    assert err[-1] <= err[0] and err[-1] < 5e-2


# --------------------------------------------------------- engine dispatch

def test_engine_serves_all_dac_methods_from_sparse(setup):
    """Every sparse-capable method serves from SparseExperts through the
    replicated engine, matching its own legacy per-call path."""
    Xp, yp, Xs = setup
    sf = sparse_fit(Xp, yp, 32)
    eng = PredictionEngine(sf, path_graph(M), chunk=8, dac_iters=400)
    for name, spec in METHODS.items():
        if not spec.sparse or spec.needs_augmented_data:
            continue
        mu, var, _ = eng.predict(name, Xs)
        assert mu.shape == (NT,) and bool(jnp.all(var > 0)), name


def test_engine_rejects_dense_npae_from_sparse(setup):
    Xp, yp, Xs = setup
    eng = PredictionEngine(sparse_fit(Xp, yp, 16), path_graph(M), chunk=8)
    with pytest.raises((ValueError, AttributeError)):
        eng.predict("npae", Xs)


# ------------------------------------------------- sharded parity (gate)

def test_ring_allgather_exact():
    from functools import partial

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = make_agent_mesh(len(jax.devices()))
    n = mesh.devices.size
    w = jnp.arange(n * 3, dtype=jnp.float64).reshape(n, 3)

    # check_rep=False: index-placement via .at[].set defeats the static
    # replication checker, but the gather IS bit-identical on every device
    @partial(shard_map, mesh=mesh, in_specs=P("agents"), out_specs=P(),
             check_rep=False)
    def gather(wi):
        return ring_allgather(wi[0], "agents")

    np.testing.assert_array_equal(jax.jit(gather)(w), w)


def test_sharded_npae_sparse_matches_replicated(setup):
    """THE acceptance gate: npae_sparse on the sharded engine equals the
    replicated engine to <= 1e-6 in f64 (bit-identical by construction:
    identical allgathered factors, identical assembly, identical solve)."""
    Xp, yp, Xs = setup
    sf = sparse_fit(Xp, yp, 32)
    rep = PredictionEngine(sf, path_graph(M), chunk=8)
    mu_r, var_r, _ = rep.predict("npae_sparse", Xs)
    sh = ShardedEngine(sf, make_agent_mesh(M), chunk=8)
    mu_s, var_s, _ = sh.predict("npae_sparse", Xs)
    assert float(jnp.max(jnp.abs(mu_s - mu_r))) <= 1e-6
    assert float(jnp.max(jnp.abs(var_s - var_r))) <= 1e-6


def test_sharded_poe_family_from_sparse(setup):
    """PoE/BCM methods serve sharded from sparse factors too (moment
    dispatch is representation-agnostic)."""
    Xp, yp, Xs = setup
    sf = sparse_fit(Xp, yp, 32)
    rep = PredictionEngine(sf, path_graph(M), chunk=8, dac_iters=800)
    sh = ShardedEngine(sf, make_agent_mesh(M), chunk=8, dac_iters=800)
    for name in ("rbcm", "gpoe"):
        mu_r, var_r, _ = rep.predict(name, Xs)
        mu_s, var_s, _ = sh.predict(name, Xs)
        assert float(jnp.max(jnp.abs(mu_s - mu_r))) <= 1e-6, name


def test_sharded_rejects_npae_sparse_on_dense(setup):
    Xp, yp, _ = setup
    sh = ShardedEngine(fit_experts(TRUE_LT, Xp, yp), make_agent_mesh(M),
                       chunk=8)
    with pytest.raises(ValueError, match="SparseExperts"):
        sh.predict("npae_sparse", random_inputs(jax.random.PRNGKey(5), 8))


# ----------------------------------------------------------- trainers

def test_train_fact_sparse_reduces_bound(setup):
    Xp, yp, _ = setup
    lt0 = pack([0.8, 0.8], 1.0, 0.2)
    Z0 = select_inducing(Xp, 16)
    lt, Z, vals = train_fact_sparse(lt0, Xp, yp, Z0, steps=40, lr=0.05)
    assert float(vals[-1]) < float(vals[0])
    assert Z.shape == Z0.shape and bool(jnp.any(Z != Z0))  # Z moved


def test_make_sparse_grad_matches_autodiff(setup):
    Xp, yp, _ = setup
    g = make_sparse_grad(16)(TRUE_LT, Xp[0], yp[0])
    idx = np.round(np.linspace(0, NI - 1, 16)).astype(np.int32)
    ref = jax.grad(sparse_nll)(TRUE_LT, Xp[0][idx], Xp[0], yp[0])
    np.testing.assert_allclose(g, ref, rtol=1e-10)


# --------------------------------------------------------------- fleet

def _fit_fleet(cfg, Xp, yp):
    return GPFleet(cfg).fit(Xp, yp, key=jax.random.PRNGKey(3),
                            log_theta0=TRUE_LT)


def test_fleet_sparse_end_to_end(setup, tmp_path):
    """fit -> predict -> shard -> save -> load round-trip on a sparse
    fleet: replicated == sharded, loaded == saved bit-identically."""
    Xp, yp, Xs = setup
    cfg = FleetConfig(num_agents=M, trainer="fact-sparse",
                      method="npae_sparse", sparse_m=16, fact_steps=8,
                      chunk=8)
    fl = _fit_fleet(cfg, Xp, yp)
    assert isinstance(fl.fitted, SparseExperts)
    mu_r, var_r, _ = fl.predict(Xs)
    sh = _fit_fleet(cfg.replace(sharded=True), Xp, yp)
    mu_s, var_s, _ = sh.predict(Xs)
    assert float(jnp.max(jnp.abs(mu_s - mu_r))) <= 1e-6
    assert float(jnp.max(jnp.abs(var_s - var_r))) <= 1e-6

    fl.save(tmp_path / "ck")
    fl2 = GPFleet.load(tmp_path / "ck")
    assert isinstance(fl2.fitted, SparseExperts)
    for a, b in zip(jax.tree_util.tree_leaves(fl.fitted),
                    jax.tree_util.tree_leaves(fl2.fitted)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    mu2, _, _ = fl2.predict(Xs)
    np.testing.assert_array_equal(np.asarray(mu2), np.asarray(mu_r))


def test_fleet_dec_apx_sparse_trainer(setup):
    """The decentralized sparse trainer rides the ADMM loop through the
    grad_fn hook and serves PoE-family methods from sparse factors."""
    Xp, yp, Xs = setup
    cfg = FleetConfig(num_agents=M, trainer="dec-apx-sparse",
                      method="rbcm", sparse_m=16, admm_iters=4, chunk=8)
    fl = _fit_fleet(cfg, Xp, yp)
    assert isinstance(fl.fitted, SparseExperts)
    mu, var, _ = fl.predict(Xs)
    assert bool(jnp.all(jnp.isfinite(mu))) and bool(jnp.all(var > 0))


def test_fleet_hyphen_method_normalizes():
    cfg = FleetConfig(num_agents=M, method="npae-sparse", sparse_m=8)
    assert cfg.method == "npae_sparse"
    assert get_method("npae-sparse") is get_method("npae_sparse")


# ------------------------------------------------------------- registry

def test_registry_sparse_flags_complete():
    """Every method declares sparse capability; exactly the dense-NPAE
    family (cross-Gram blocks of raw training points) cannot serve from
    pseudo-representations."""
    dense_only = {n for n, s in METHODS.items() if not s.sparse}
    assert dense_only == {"npae", "npae_star", "nn_npae"}
    spec = get_method("npae_sparse")
    assert spec.family == "sparse" and spec.shardable
    assert not spec.online_safe
    assert spec.legacy is dec_npae_sparse
    assert set(SPARSE_TRAINERS) == {"fact-sparse", "dec-apx-sparse"}
    assert set(SPARSE_TRAINERS) <= set(trainer_names())
    assert "npae_sparse" in method_names()


@pytest.mark.parametrize("cfg_kw, frag", [
    (dict(trainer="fact-sparse"), "sparse_m"),
    (dict(method="npae_sparse"), "sparse_m"),
    (dict(method="npae", sparse_m=16), "dense"),
    (dict(method="rbcm", sparse_m=16, online=True), "online"),
    (dict(method="npae", sparse_m=16, cache_cross=True), None),
])
def test_validate_config_sparse_rules(cfg_kw, frag):
    cfg = FleetConfig(num_agents=M, **cfg_kw)
    with pytest.raises(ValueError) as e:
        validate_config(cfg)
    if frag is not None:
        assert frag in str(e.value)


def test_validate_config_accepts_sparse_combos():
    validate_config(FleetConfig(num_agents=M, trainer="fact-sparse",
                                method="npae_sparse", sparse_m=16,
                                sharded=True))
    validate_config(FleetConfig(num_agents=M, trainer="dec-apx-sparse",
                                method="grbcm", sparse_m=16))
