"""Online/streaming GP subsystem: incremental numerics and live-fleet
serving equivalence.

Acceptance gates:
  - rank-1 cholupdate/downdate == full refactorization (<= 1e-6 float64,
    <= 1e-4 float32) over randomized observe/evict sequences;
  - after K interleaved observe/evict events, OnlineExperts factors match
    a fresh fit_experts on the equivalent window through EVERY
    PredictionEngine method (all 13 decentralized + centralized refs);
  - membership changes (join/leave) keep the consensus graph connected and
    the engine serving;
  - factor hot-swap (swap_experts) reuses compiled programs;
  - NPAE cross-covariance caching (fit_experts cache_cross) is exact and
    memory-guarded;
  - stripe_partition signals dropped points.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.consensus import (attach_agent, complete_graph, is_connected,
                                  path_graph, remove_agent)
from repro.core.gp import pack, stripe_partition
from repro.core.online import (evict_oldest, from_batch, init_online, join,
                               leave, observe, observe_fleet, refit)
from repro.core.prediction import (PredictionEngine, fit_experts,
                                   npae_terms_cached)
from repro.data import gp_sample_field, random_inputs
from repro.kernels import ops, ref

TRUE_LT = pack([1.2, 0.3], 1.3, 0.1)
M, W, D = 4, 12, 2
NT = 9
CHUNK = 4
ITERS = 120


# ---------------------------------------------------------------------------
# rank-1 Cholesky update/downdate kernels
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [5, 37, 130])
def test_cholupdate_matches_refactorization_f64(n):
    rng = np.random.default_rng(n)
    B = rng.standard_normal((n, n))
    A = B @ B.T + n * np.eye(n)
    x = rng.standard_normal(n)
    L = np.linalg.cholesky(A)
    up = ops.cholupdate(jnp.asarray(L), jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(up),
                               np.linalg.cholesky(A + np.outer(x, x)),
                               atol=1e-6)
    down = ops.cholupdate(up, jnp.asarray(x), downdate=True)
    np.testing.assert_allclose(np.asarray(down), L, atol=1e-6)


def test_cholupdate_f32_and_pallas_interpret():
    n = 48
    rng = np.random.default_rng(0)
    B = rng.standard_normal((n, n))
    A = B @ B.T + n * np.eye(n)
    x = rng.standard_normal(n)
    ref_up = np.linalg.cholesky(A + np.outer(x, x))
    L32 = jnp.asarray(np.linalg.cholesky(A), jnp.float32)
    x32 = jnp.asarray(x, jnp.float32)
    np.testing.assert_allclose(np.asarray(ops.cholupdate(L32, x32)), ref_up,
                               atol=1e-4)
    # Pallas kernel (interpret mode on CPU), incl. pad-to-tile no-op cols
    up_p = ops.cholupdate(L32, x32, use_pallas=True, interpret=True, bk=16)
    np.testing.assert_allclose(np.asarray(up_p), ref_up, atol=1e-4)


def test_cholupdate_zero_vector_is_noop_and_shift_evicts():
    n = 24
    rng = np.random.default_rng(1)
    B = rng.standard_normal((n, n))
    A = B @ B.T + n * np.eye(n)
    L = jnp.asarray(np.linalg.cholesky(A))
    noop = ops.cholupdate(L, jnp.zeros(n))
    np.testing.assert_allclose(np.asarray(noop), np.asarray(L), atol=0)
    # shift=1: evict the first point, result moved up-left in the sweep
    out = ops.cholupdate(L, L[:, 0], shift=1)
    np.testing.assert_allclose(np.asarray(out)[:n - 1, :n - 1],
                               np.linalg.cholesky(np.asarray(A)[1:, 1:]),
                               atol=1e-8)


# ---------------------------------------------------------------------------
# sliding-window experts: randomized event sequences vs refit
# ---------------------------------------------------------------------------

def _stream_state(dtype=jnp.float64, events=70, seed=0):
    lt = TRUE_LT.astype(dtype)
    state = init_online(lt, M, W, D, dtype=dtype)
    rng = np.random.default_rng(seed)
    obs = jax.jit(observe)
    ev = jax.jit(evict_oldest)
    for _ in range(events):
        a = int(rng.integers(0, M))
        if rng.random() < 0.25:
            state = ev(state, a)
        else:
            state = obs(state, a,
                        jnp.asarray(rng.standard_normal(D), dtype),
                        jnp.asarray(rng.standard_normal(), dtype))
    return state


def test_randomized_observe_evict_matches_refit_f64():
    state = _stream_state(events=90)
    ref_state = refit(state)
    np.testing.assert_allclose(np.asarray(state.L), np.asarray(ref_state.L),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(state.alpha),
                               np.asarray(ref_state.alpha), atol=1e-6)


def test_randomized_observe_evict_matches_refit_f32():
    state = _stream_state(dtype=jnp.float32, events=60)
    ref_state = refit(state)
    np.testing.assert_allclose(np.asarray(state.L), np.asarray(ref_state.L),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(state.alpha),
                               np.asarray(ref_state.alpha), atol=1e-4)


def test_observe_fleet_matches_refit():
    state = _stream_state(events=30)
    rng = np.random.default_rng(7)
    ingest = jax.jit(observe_fleet)
    for _ in range(2 * W):                     # wrap every window
        state = ingest(state, jnp.asarray(rng.standard_normal((M, D))),
                       jnp.asarray(rng.standard_normal(M)))
    assert np.all(np.asarray(state.count) == W)
    ref_state = refit(state)
    np.testing.assert_allclose(np.asarray(state.L), np.asarray(ref_state.L),
                               atol=1e-6)


def test_evict_on_empty_window_is_noop():
    state = init_online(TRUE_LT, M, W, D)
    out = evict_oldest(state, 1)
    assert int(out.count[1]) == 0
    np.testing.assert_allclose(np.asarray(out.L), np.asarray(state.L),
                               atol=0)
    np.testing.assert_allclose(np.asarray(out.alpha), 0.0, atol=0)


def test_window_slides_to_last_w_points():
    """Observing 2W points leaves exactly the newest W, in age order."""
    rng = np.random.default_rng(3)
    xs = rng.standard_normal((2 * W, D))
    ys = rng.standard_normal(2 * W)
    state = init_online(TRUE_LT, 1, W, D)
    obs = jax.jit(observe)
    for k in range(2 * W):
        state = obs(state, 0, jnp.asarray(xs[k]), jnp.asarray(ys[k]))
    np.testing.assert_allclose(np.asarray(state.Xw[0]), xs[W:], atol=0)
    f = fit_experts(TRUE_LT, jnp.asarray(xs[None, W:]),
                    jnp.asarray(ys[None, W:]))
    np.testing.assert_allclose(np.asarray(state.L[0]), np.asarray(f.L[0]),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(state.alpha[0]),
                               np.asarray(f.alpha[0]), atol=1e-6)


# ---------------------------------------------------------------------------
# serving equivalence: online factors through every PredictionEngine method
# ---------------------------------------------------------------------------

def _full_window_state():
    """Stream until every window is full (wraps past W)."""
    key = jax.random.PRNGKey(0)
    X = random_inputs(key, M * (W + 5))
    _, y = gp_sample_field(jax.random.PRNGKey(1), X, TRUE_LT)
    Xp = X.reshape(M, W + 5, D)
    yp = y.reshape(M, W + 5)
    state = init_online(TRUE_LT, M, W, D)
    obs = jax.jit(observe)
    for k in range(W + 5):
        for a in range(M):
            state = obs(state, a, Xp[a, k], yp[a, k])
    return state


@pytest.fixture(scope="module")
def engines_online():
    from repro.core.gp import augment, communication_dataset

    state = _full_window_state()
    f_on = state.to_fitted()
    f_ref = fit_experts(TRUE_LT, state.Xw, state.yw)
    Xc, yc = communication_dataset(jax.random.PRNGKey(5), state.Xw, state.yw)
    Xa, ya = augment(state.Xw, state.yw, Xc, yc)
    fa = fit_experts(TRUE_LT, Xa, ya)
    fc = fit_experts(TRUE_LT, Xc[None], yc[None])

    def build(f, A):
        return PredictionEngine(f, A, chunk=CHUNK, dac_iters=ITERS,
                                jor_iters=300, dale_iters=500, pm_iters=40,
                                eta_nn=0.1, fitted_aug=fa, fitted_comm=fc)

    A, Ac = path_graph(M), complete_graph(M)
    return state, {"on": build(f_on, A), "ref": build(f_ref, A),
                   "on_c": build(f_on, Ac), "ref_c": build(f_ref, Ac)}


@pytest.mark.parametrize("method", sorted(
    m for m in PredictionEngine.METHODS if m != "npae_sparse"))
def test_online_factors_serve_every_method(engines_online, method):
    # npae_sparse excluded: it serves from SparseExperts only, and the
    # sparse family is not online-safe (registry flag; validate_config
    # rejects sparse_m + online)
    """Full-window online factors == fresh fit_experts on the same window
    through every decentralized method and centralized reference."""
    _, eng = engines_online
    key = "on_c" if "npae" in method else "on"
    ref_key = "ref_c" if "npae" in method else "ref"
    Xs = random_inputs(jax.random.PRNGKey(2), NT)
    m1, v1, _ = eng[key].predict(method, Xs)
    m2, v2, _ = eng[ref_key].predict(method, Xs)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), atol=1e-6)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), atol=1e-6)


@pytest.mark.parametrize("method", ["poe", "rbcm", "nn_poe", "npae",
                                    "cen_npae"])
def test_partial_windows_serve_like_valid_subset(method):
    """Sentinel slots are invisible: a half-full fleet predicts exactly
    like fit_experts on only the valid points."""
    c = W // 2
    key = jax.random.PRNGKey(9)
    X = random_inputs(key, M * c)
    _, y = gp_sample_field(jax.random.PRNGKey(10), X, TRUE_LT)
    Xp, yp = X.reshape(M, c, D), y.reshape(M, c)
    state = from_batch(TRUE_LT, Xp, yp, window=W)
    assert np.all(np.asarray(state.count) == c)
    A = complete_graph(M) if "npae" in method else path_graph(M)
    e_on = PredictionEngine(state.to_fitted(), A, chunk=CHUNK,
                            dac_iters=ITERS, jor_iters=300, eta_nn=0.1)
    e_ref = PredictionEngine(fit_experts(TRUE_LT, Xp, yp), A, chunk=CHUNK,
                             dac_iters=ITERS, jor_iters=300, eta_nn=0.1)
    Xs = random_inputs(jax.random.PRNGKey(11), NT)
    m1, v1, _ = e_on.predict(method, Xs)
    m2, v2, _ = e_ref.predict(method, Xs)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), atol=1e-6)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), atol=1e-6)


def test_swap_experts_keeps_compiled_programs(engines_online):
    state, eng = engines_online
    e = eng["on"]
    Xs = random_inputs(jax.random.PRNGKey(4), NT)
    m1, _, _ = e.predict("poe", Xs)
    compiled = e._compiled["poe"]
    state2 = observe(state, 0, jnp.asarray([0.3, 0.4]), jnp.asarray(1.5))
    e.swap_experts(state2.to_fitted())
    m2, _, _ = e.predict("poe", Xs)
    assert e._compiled["poe"] is compiled
    assert not np.allclose(np.asarray(m1), np.asarray(m2))
    e.swap_experts(state.to_fitted())          # restore for other tests
    with pytest.raises(ValueError):
        small = init_online(TRUE_LT, M - 1, W, D).to_fitted()
        e.swap_experts(small)                  # membership change -> rewire


# ---------------------------------------------------------------------------
# dynamic membership
# ---------------------------------------------------------------------------

def test_graph_attach_and_remove_keep_connectivity():
    A = path_graph(5)
    A2 = attach_agent(A, (4,))
    assert A2.shape == (6, 6) and is_connected(A2)
    assert float(A2[5, 4]) == 1.0 and float(A2[4, 5]) == 1.0
    # removing an interior (cut) vertex re-chains its neighbors
    A3 = remove_agent(A2, 2)
    assert A3.shape == (5, 5) and is_connected(A3)
    with pytest.raises(ValueError):
        attach_agent(A, (9,))


def test_join_and_leave_rewire_live_fleet():
    state = _full_window_state()
    A = path_graph(M)
    eng = PredictionEngine(state.to_fitted(), A, chunk=CHUNK,
                           dac_iters=ITERS)
    Xs = random_inputs(jax.random.PRNGKey(2), NT)
    eng.predict("rbcm", Xs)

    kj = jax.random.PRNGKey(21)
    Xj = random_inputs(kj, W)
    _, yj = gp_sample_field(jax.random.fold_in(kj, 1), Xj, TRUE_LT)
    state2, A2 = join(state, A, Xj, yj)
    assert state2.num_agents == M + 1 and is_connected(A2)
    eng.rewire(A2, fitted=state2.to_fitted())
    m_join, v_join, _ = eng.predict("rbcm", Xs)
    # the joined fleet == a fleet built from scratch with the same windows
    e_ref = PredictionEngine(
        fit_experts(TRUE_LT, state2.Xw, state2.yw), A2, chunk=CHUNK,
        dac_iters=ITERS)
    m_ref, v_ref, _ = e_ref.predict("rbcm", Xs)
    np.testing.assert_allclose(np.asarray(m_join), np.asarray(m_ref),
                               atol=1e-6)

    state3, A3 = leave(state2, A2, 1)
    assert state3.num_agents == M and is_connected(A3)
    eng.rewire(A3, fitted=state3.to_fitted())
    m_leave, _, _ = eng.predict("rbcm", Xs)
    assert np.all(np.isfinite(np.asarray(m_leave)))
    with pytest.raises(ValueError):
        leave(state3, A3, M + 3)


def test_joiner_without_data_warms_up():
    state = init_online(TRUE_LT, 2, W, D)
    A = path_graph(2)
    state, A = join(state, A)
    assert state.num_agents == 3 and int(state.count[2]) == 0
    state = observe(state, 2, jnp.asarray([0.1, 0.2]), jnp.asarray(0.5))
    assert int(state.count[2]) == 1
    ref_state = refit(state)
    np.testing.assert_allclose(np.asarray(state.L), np.asarray(ref_state.L),
                               atol=1e-6)


# ---------------------------------------------------------------------------
# NPAE cross-covariance cache
# ---------------------------------------------------------------------------

def test_cache_cross_terms_exact_and_guarded():
    key = jax.random.PRNGKey(0)
    X = random_inputs(key, M * W)
    _, y = gp_sample_field(jax.random.PRNGKey(1), X, TRUE_LT)
    Xp, yp = X.reshape(M, W, D), y.reshape(M, W)
    f = fit_experts(TRUE_LT, Xp, yp, cache_cross=True)
    assert f.Kcross.shape == (M, M, W, W)
    Xs = random_inputs(jax.random.PRNGKey(2), NT)
    plain = npae_terms_cached(TRUE_LT, f.Xp, f.L, f.alpha, Xs)
    cached = npae_terms_cached(TRUE_LT, f.Xp, f.L, f.alpha, Xs,
                               Kcross=f.Kcross)
    for a, b in zip(plain, cached):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-12)
    # engine consumes the cache transparently
    Ac = complete_graph(M)
    e_cached = PredictionEngine(f, Ac, chunk=CHUNK, jor_iters=300,
                                dac_iters=ITERS)
    e_plain = PredictionEngine(fit_experts(TRUE_LT, Xp, yp), Ac, chunk=CHUNK,
                               jor_iters=300, dac_iters=ITERS)
    m1, v1, _ = e_cached.predict("npae", Xs)
    m2, v2, _ = e_plain.predict("npae", Xs)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), atol=1e-8)
    # memory-estimate guard fires at trace time
    with pytest.raises(ValueError, match="cross_cache_limit_mb"):
        fit_experts(TRUE_LT, Xp, yp, cache_cross=True,
                    cross_cache_limit_mb=0.001)


# ---------------------------------------------------------------------------
# stripe_partition dropped-count signal
# ---------------------------------------------------------------------------

def test_stripe_partition_warns_on_dropped_points():
    X = random_inputs(jax.random.PRNGKey(0), 10)
    y = jnp.arange(10.0)
    with pytest.warns(UserWarning, match="dropping 1 trailing"):
        Xp, yp = stripe_partition(X, y, 3)
    assert Xp.shape == (3, 3, 2) and yp.shape == (3, 3)
    with warnings.catch_warnings():
        warnings.simplefilter("error")         # exact split: no warning
        Xp, yp = stripe_partition(X[:9], y[:9], 3)
    assert Xp.shape == (3, 3, 2)


# ---------------------------------------------------------------------------
# randomized membership interleavings + mid-stream persistence (ISSUE 9)
# ---------------------------------------------------------------------------

from repro.fleet import FleetConfig, GPFleet  # noqa: E402


def _stream_fleet(seed=0, num_agents=4):
    cfg = FleetConfig(num_agents=num_agents, input_dim=2, online=True,
                      window=8, chunk=4, dac_iters=30, method="rbcm",
                      theta0=(0.8, 0.8, 1.0, 0.2))
    rng = np.random.default_rng(seed)
    Xp = rng.uniform(0.0, 1.0, (num_agents, 5, 2))
    yp = rng.standard_normal((num_agents, 5))
    return GPFleet(cfg).fit(Xp, yp, train=False), rng


@pytest.mark.parametrize("seed", [0, 1])
def test_randomized_membership_interleaving_stays_healthy(seed):
    """Any observe/leave/join/predict interleaving leaves the consensus
    graph connected and every prediction finite with positive variance."""
    fleet, rng = _stream_fleet(seed)
    Xs = rng.uniform(0.0, 1.0, (6, 2))
    for _ in range(10):
        m = fleet.num_agents
        op = rng.choice(["observe", "observe", "leave", "join", "predict"])
        if op == "observe":
            fleet.observe(rng.uniform(0.0, 1.0, (m, 2)),
                          rng.standard_normal(m))
        elif op == "leave" and m > 2:
            fleet.leave(int(rng.integers(m)))
        elif op == "join" and m < 6:
            fleet.join(rng.uniform(0.0, 1.0, (3, 2)),
                       rng.standard_normal(3))
        elif op == "predict":
            mean, var, _ = fleet.predict(Xs)
            assert np.isfinite(np.asarray(mean)).all()
            assert np.isfinite(np.asarray(var)).all()
            assert (np.asarray(var) > 0.0).all()
        assert is_connected(fleet.A)
        assert fleet.num_agents == fleet._online_state.num_agents
    mean, var, _ = fleet.predict(Xs)
    assert np.isfinite(np.asarray(mean)).all()
    h = fleet.health()
    assert h["graph_connected"] and h["graph_components"] == 1


def test_save_load_mid_stream_is_bitwise(tmp_path):
    """save() -> load() in the middle of a stream round-trips the window
    state bit for bit, and the loaded fleet continues the stream with
    bitwise-identical served predictions."""
    fleet, rng = _stream_fleet(3)
    for _ in range(4):
        fleet.observe(rng.uniform(0.0, 1.0, (4, 2)),
                      rng.standard_normal(4))
    Xs = rng.uniform(0.0, 1.0, (6, 2))
    mean0, var0, _ = fleet.predict(Xs)

    fleet.save(str(tmp_path))
    loaded = GPFleet.load(str(tmp_path))
    a, b = fleet._online_state, loaded._online_state
    for field in ("log_theta", "Xw", "yw", "L", "alpha", "count", "jitter"):
        assert np.array_equal(np.asarray(getattr(a, field)),
                              np.asarray(getattr(b, field))), field
    mean1, var1, _ = loaded.predict(Xs)
    assert np.array_equal(np.asarray(mean0), np.asarray(mean1))
    assert np.array_equal(np.asarray(var0), np.asarray(var1))

    # continue the stream on BOTH fleets with the same data: still bitwise
    xs, ys = rng.uniform(0.0, 1.0, (4, 2)), rng.standard_normal(4)
    fleet.observe(xs, ys)
    loaded.observe(xs, ys)
    m2, v2, _ = fleet.predict(Xs)
    m3, v3, _ = loaded.predict(Xs)
    assert np.array_equal(np.asarray(m2), np.asarray(m3))
    assert np.array_equal(np.asarray(v2), np.asarray(v3))
