"""core/federated.py: the loss-agnostic consensus strategies under shard_map
(requires multi-device — run via the forced-host-device pytest invocation,
see test_output.txt second section)."""
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import federated as fed

needs_devices = pytest.mark.skipif(jax.device_count() < 4,
                                   reason="needs >= 4 devices")


@needs_devices
def test_allreduce_grads_is_mean():
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    mesh = jax.make_mesh((4,), ("data",))
    g = jnp.arange(8.0).reshape(4, 2)

    @partial(shard_map, mesh=mesh, in_specs=P("data"), out_specs=P("data"))
    def run(g):
        return fed.allreduce_grads({"w": g}, ["data"])["w"]

    out = run(g)
    want = np.broadcast_to(np.asarray(g).mean(0), (4, 2))
    np.testing.assert_allclose(np.asarray(out), want, atol=1e-7)


@needs_devices
def test_dac_grads_one_sweep_matches_perron():
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.core.consensus import cycle_graph, perron
    mesh = jax.make_mesh((4,), ("data",))
    g = jnp.arange(4.0).reshape(4, 1)
    cfg = fed.ConsensusConfig(strategy="dac", dac_eps=1.0 / 3.0, dac_sweeps=1)

    @partial(shard_map, mesh=mesh, in_specs=P("data"), out_specs=P("data"))
    def run(g):
        return fed.dac_grads({"w": g}, ["data"], cfg)["w"]

    out = run(g)
    P_mat = perron(cycle_graph(4), 1.0 / 3.0)
    want = np.asarray(P_mat) @ np.asarray(g)
    np.testing.assert_allclose(np.asarray(out), want, atol=1e-6)


@needs_devices
def test_dec_admm_update_sharded_matches_reference():
    """shard_map dec_admm_update == core.training.dec_apx_update on a ring."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.core.training import dec_apx_update
    mesh = jax.make_mesh((4,), ("data",))
    key = jax.random.PRNGKey(0)
    th = jax.random.normal(key, (4, 3))
    du = jnp.zeros((4, 3))
    g = jax.random.normal(jax.random.PRNGKey(1), (4, 3))
    cfg = fed.ConsensusConfig(strategy="dec_admm", rho=0.5, kappa=10.0)

    @partial(shard_map, mesh=mesh, in_specs=(P("data"), P("data"), P("data")),
             out_specs=(P("data"), P("data")))
    def run(th, du, g):
        return fed.dec_admm_update({"w": th}, {"w": du}, {"w": g}, "data",
                                   cfg)[0]["w"], \
            fed.dec_admm_update({"w": th}, {"w": du}, {"w": g}, "data",
                                cfg)[1]["w"]

    th2, du2 = run(th, du, g)
    nbr = jnp.roll(th, 1, 0) + jnp.roll(th, -1, 0)
    deg = jnp.full((4,), 2.0)
    th_ref, du_ref = dec_apx_update(th, du, g, nbr, deg, 0.5, 10.0)
    np.testing.assert_allclose(np.asarray(th2), np.asarray(th_ref), atol=1e-6)
    np.testing.assert_allclose(np.asarray(du2), np.asarray(du_ref), atol=1e-6)


def test_policy_override_mechanics():
    """Sharding-policy override: 'dp' disables TP rules (pure function)."""
    if jax.device_count() < 4:
        pytest.skip("needs multi-device")
    from jax.sharding import PartitionSpec as P
    from repro.launch.sharding import spec_for_axes
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    dp = {"batch": ("data", "model"), "ffn": (), "heads": (),
          "embed": ("data", "model")}
    assert spec_for_axes(mesh, ("embed", "ffn"), (64, 64),
                         policy=dp) == P(("data", "model"), None)
    assert spec_for_axes(mesh, ("batch", "seq"), (8, 16),
                         policy=dp) == P(("data", "model"), None)
    # default unchanged
    assert spec_for_axes(mesh, ("embed", "ffn"), (64, 64)) == P("data", "model")
