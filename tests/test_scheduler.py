"""Front door v2: the request-level, multi-tenant serving scheduler.

Acceptance gates:

  * two resident GPFleet tenants served round-robin from ONE scheduler,
    with ZERO jit recompiles after registration warmup — asserted via the
    engines' jit-cache miss counters;
  * continuous batching semantics: ragged requests stream across
    fixed-geometry slots and come back stitched in order, a large request
    spans several slots, answers match the direct engine call;
  * scheduling policy: priority ordering, deadline drop vs deprioritize,
    admission block (backpressure) vs reject (SchedulerSaturated);
  * lifecycle: close(drain=False) cancels riders, a submitter blocked on
    backpressure is woken (not deadlocked) by close() — the v1
    submit-holds-lock-across-put bug stays dead.

Policy tests drive the scheduler manually (autostart=False + step(force=
True)) so they are deterministic; no sleeps for correctness, only for
cross-thread handoff.
"""
import threading
import time
from concurrent.futures import CancelledError

import jax
import numpy as np
import pytest

from repro.core.gp import pack
from repro.data import random_inputs
from repro.fleet import FleetConfig, GPFleet
from repro.launch.scheduler import (DeadlineExceeded, SchedulerClosed,
                                    SchedulerSaturated, ServingScheduler,
                                    Tenant, slot_ladder, pick_slot)

TRUE_LT = pack([1.2, 0.3], 1.3, 0.1)


def echo_predict(Xs):
    """Deterministic stand-in engine: mean = sum over features, var = 1."""
    Xs = np.asarray(Xs)
    return Xs.sum(axis=-1), np.ones(Xs.shape[0])


def manual_sched(**kw):
    return ServingScheduler(autostart=False, **kw)


# ---------------------------------------------------------------------------
# slot geometry
# ---------------------------------------------------------------------------

def test_slot_ladder_doubles_to_max():
    assert slot_ladder(8, 64) == (8, 16, 32, 64)
    assert slot_ladder(8, 50) == (8, 16, 32, 50)   # max always included
    assert slot_ladder(16, 16) == (16,)
    assert slot_ladder(32, 8) == (8,)              # max below align: pinned
    with pytest.raises(ValueError):
        slot_ladder(0, 64)
    with pytest.raises(ValueError):
        slot_ladder(8, -1)


def test_pick_slot_exact_round_down_bounded_round_up_pad():
    slots = (8, 16, 32)
    assert pick_slot(slots, 8) == 8        # exact ladder fit
    assert pick_slot(slots, 16) == 16
    assert pick_slot(slots, 9) == 8        # round DOWN: 8 full rows now,
    assert pick_slot(slots, 11) == 8       # remainder rides the next step
    assert pick_slot(slots, 13) == 16      # >= 75% of the slot up: round UP,
    assert pick_slot(slots, 31) == 32      # clear the backlog, bounded pad
    assert pick_slot(slots, 1) == 8        # below the smallest slot: pad
    assert pick_slot(slots, 32) == 32
    assert pick_slot(slots, 1000) == 32
    assert pick_slot(slots, 13, pad_budget=0.0) == 8   # strict round-down


def test_tenant_validates_policies():
    with pytest.raises(ValueError, match="admission"):
        Tenant("t", echo_predict, (8,), queue_depth=8, admission="maybe",
               deadline_policy="drop", max_wait_s=0.01)
    with pytest.raises(ValueError, match="deadline_policy"):
        Tenant("t", echo_predict, (8,), queue_depth=8, admission="block",
               deadline_policy="shrug", max_wait_s=0.01)
    with pytest.raises(ValueError, match="slots"):
        Tenant("t", echo_predict, (), queue_depth=8, admission="block",
               deadline_policy="drop", max_wait_s=0.01)


# ---------------------------------------------------------------------------
# continuous batching semantics (manual stepping, echo engine)
# ---------------------------------------------------------------------------

def test_ragged_requests_stitched_in_order():
    sched = manual_sched()
    sched.add_tenant("t", echo_predict, slots=(4, 8))
    rng = np.random.default_rng(0)
    reqs = [rng.uniform(size=(int(n), 3)) for n in rng.integers(1, 7, 9)]
    futs = [sched.add_request(r) for r in reqs]
    while sched.step(force=True):
        pass
    for r, fut in zip(reqs, futs):
        mean, var = fut.result(timeout=0)
        np.testing.assert_allclose(mean, r.sum(axis=-1), atol=1e-12)
        assert var.shape == (r.shape[0],)
    sched.close()


def test_large_request_spans_slots():
    """A request bigger than the largest slot streams across steps and is
    reassembled; intermediate steps leave the future unresolved."""
    sched = manual_sched()
    sched.add_tenant("t", echo_predict, slots=(4,))
    Xq = np.arange(11.0 * 2).reshape(11, 2)     # 11 rows over 4-row slots
    fut = sched.add_request(Xq)
    assert sched.step(force=True) and not fut.done()
    assert sched.step(force=True) and not fut.done()
    assert sched.step(force=True) and fut.done()
    mean, _ = fut.result(timeout=0)
    np.testing.assert_allclose(mean, Xq.sum(axis=-1), atol=1e-12)
    st = sched.stats
    assert st.batches == 3 and st.queries == 11 and st.padded_queries == 1
    sched.close()


def test_padding_fraction_counts_pad_rows():
    sched = manual_sched()
    sched.add_tenant("t", echo_predict, slots=(8,))
    sched.add_request(np.zeros((3, 2)))
    sched.step(force=True)            # 3 real rows + 5 pad rows
    st = sched.stats
    assert st.queries == 3 and st.padded_queries == 5
    assert st.padding_fraction == pytest.approx(5 / 8)
    sched.close()


def test_priority_orders_packing():
    """Higher priority packs first; FIFO within a priority level."""
    served = []

    def spy(Xs):
        served.append(int(np.asarray(Xs)[0, 0]))
        return echo_predict(Xs)

    sched = manual_sched()
    sched.add_tenant("t", spy, slots=(2,))
    tagged = lambda tag: np.full((2, 1), float(tag))
    sched.add_request(tagged(0), priority=0)
    sched.add_request(tagged(1), priority=5)
    sched.add_request(tagged(2), priority=5)
    sched.add_request(tagged(3), priority=9)
    while sched.step(force=True):
        pass
    assert served == [3, 1, 2, 0]
    sched.close()


def test_round_robin_interleaves_tenants():
    served = []
    mk = lambda name: (lambda Xs, n=name: (served.append(n),
                                           echo_predict(Xs))[1])
    sched = manual_sched()
    sched.add_tenant("a", mk("a"), slots=(4,))
    sched.add_tenant("b", mk("b"), slots=(4,))
    for _ in range(3):
        sched.add_request(np.zeros((4, 2)), tenant="a")
        sched.add_request(np.zeros((4, 2)), tenant="b")
    while sched.step(force=True):
        pass
    assert served == ["a", "b", "a", "b", "a", "b"]
    sched.close()


def test_engine_error_fails_every_rider():
    def boom(_):
        raise RuntimeError("engine exploded")

    sched = manual_sched()
    sched.add_tenant("t", boom, slots=(8,))
    futs = [sched.add_request(np.zeros((2, 2))) for _ in range(3)]
    sched.step(force=True)
    for fut in futs:
        with pytest.raises(RuntimeError, match="exploded"):
            fut.result(timeout=0)
    sched.close()


def test_request_validation():
    sched = manual_sched()
    sched.add_tenant("t", echo_predict, slots=(4,))
    with pytest.raises(ValueError, match=r"\(Nq, D\)"):
        sched.add_request(np.zeros(3))
    with pytest.raises(ValueError, match="at least one"):
        sched.add_request(np.zeros((0, 2)))
    with pytest.raises(KeyError, match="unknown tenant"):
        sched.add_request(np.zeros((1, 2)), tenant="nope")
    sched.add_tenant("u", echo_predict, slots=(4,))
    with pytest.raises(ValueError, match="tenant= is required"):
        sched.add_request(np.zeros((1, 2)))      # ambiguous: 2 tenants
    with pytest.raises(ValueError, match="single-tenant"):
        sched.stats
    sched.close()


# ---------------------------------------------------------------------------
# deadlines
# ---------------------------------------------------------------------------

def test_deadline_drop():
    sched = manual_sched()
    sched.add_tenant("t", echo_predict, slots=(4,), deadline_policy="drop")
    late = sched.add_request(np.zeros((2, 2)), deadline_ms=0.01)
    ok = sched.add_request(np.ones((2, 2)))
    time.sleep(0.005)                  # let the 10us deadline lapse
    sched.step(force=True)
    with pytest.raises(DeadlineExceeded):
        late.result(timeout=0)
    assert ok.result(timeout=0)[0].shape == (2,)
    st = sched.stats
    assert st.dropped == 1 and st.queries == 2
    sched.close()


def test_deadline_deprioritize_serves_lapsed_last():
    served = []

    def spy(Xs):
        served.append(int(np.asarray(Xs)[0, 0]))
        return echo_predict(Xs)

    sched = manual_sched()
    sched.add_tenant("t", spy, slots=(2,), deadline_policy="deprioritize")
    late = sched.add_request(np.full((2, 1), 7.0), deadline_ms=0.01,
                             priority=100)
    time.sleep(0.005)
    fresh = sched.add_request(np.full((2, 1), 1.0), priority=0)
    while sched.step(force=True):
        pass
    # the lapsed request lost its priority but was still served (after the
    # in-deadline work), not dropped
    assert served == [1, 7]
    assert fresh.result(timeout=0)[0].shape == (2,)
    assert late.result(timeout=0)[0].shape == (2,)
    st = sched.stats
    assert st.lapsed == 1 and st.dropped == 0
    sched.close()


def test_started_request_is_always_finished():
    """Deadline expiry mid-stream never abandons a partially-served
    request (policy=drop only applies before the first row dispatches)."""
    sched = manual_sched()
    sched.add_tenant("t", echo_predict, slots=(4,), deadline_policy="drop")
    fut = sched.add_request(np.zeros((6, 2)), deadline_ms=50.0)
    sched.step(force=True)             # rows 0-3 dispatched in-deadline
    time.sleep(0.06)                   # now past the deadline, 2 rows left
    sched.step(force=True)
    assert fut.result(timeout=0)[0].shape == (6,)
    assert sched.stats.dropped == 0
    sched.close()


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------

def test_admission_reject_raises_saturated():
    sched = manual_sched()
    sched.add_tenant("t", echo_predict, slots=(4,), queue_depth=8,
                     admission="reject")
    sched.add_request(np.zeros((8, 2)))
    with pytest.raises(SchedulerSaturated):
        sched.add_request(np.zeros((1, 2)))
    assert sched.stats.rejected == 1
    sched.step(force=True)             # drain one slot -> space again
    sched.step(force=True)
    sched.add_request(np.zeros((8, 2)))
    sched.close()


def test_backpressure_blocks_then_resumes():
    """admission='block': an over-depth submit parks on the condition and
    completes once a step frees queue space."""
    sched = manual_sched()
    sched.add_tenant("t", echo_predict, slots=(4,), queue_depth=4,
                     admission="block")
    sched.add_request(np.zeros((4, 2)))
    state = {}

    def blocked_submit():
        state["fut"] = sched.add_request(np.ones((4, 2)))

    th = threading.Thread(target=blocked_submit)
    th.start()
    time.sleep(0.05)
    assert th.is_alive()               # backpressure engaged
    sched.step(force=True)             # frees 4 rows -> waiter admitted
    th.join(timeout=10.0)
    assert not th.is_alive()
    sched.step(force=True)
    assert state["fut"].result(timeout=0)[0].shape == (4,)
    sched.close()


def test_close_wakes_blocked_submitter():
    """close() must wake a submitter parked on backpressure with
    SchedulerClosed — the v1 deadlock (submit holding the lifecycle lock
    across a blocking queue put) is structurally impossible."""
    sched = manual_sched()
    sched.add_tenant("t", echo_predict, slots=(4,), queue_depth=4,
                     admission="block")
    sched.add_request(np.zeros((4, 2)))
    errs = []

    def blocked_submit():
        try:
            sched.add_request(np.ones((4, 2)))
        except SchedulerClosed as e:
            errs.append(e)

    th = threading.Thread(target=blocked_submit)
    th.start()
    time.sleep(0.05)
    assert th.is_alive()
    sched.close(drain=False)           # must not deadlock
    th.join(timeout=10.0)
    assert not th.is_alive() and len(errs) == 1


def test_deadline_drops_free_queue_space():
    """A deadline drop releases its rows toward queue_depth (a waiter
    blocked on backpressure is admitted even though nothing was served)."""
    sched = manual_sched()
    sched.add_tenant("t", echo_predict, slots=(4,), queue_depth=4,
                     admission="block", deadline_policy="drop")
    doomed = sched.add_request(np.zeros((4, 2)), deadline_ms=0.01)
    time.sleep(0.005)
    admitted = []
    th = threading.Thread(
        target=lambda: admitted.append(sched.add_request(np.ones((4, 2)))))
    th.start()
    time.sleep(0.05)
    assert th.is_alive()
    sched.step(force=True)             # drops the lapsed request
    th.join(timeout=10.0)
    assert not th.is_alive() and len(admitted) == 1
    with pytest.raises(DeadlineExceeded):
        doomed.result(timeout=0)
    sched.close()


# ---------------------------------------------------------------------------
# lifecycle
# ---------------------------------------------------------------------------

def test_close_drain_false_cancels_riders():
    sched = manual_sched()
    sched.add_tenant("t", echo_predict, slots=(4,))
    futs = [sched.add_request(np.zeros((2, 2))) for _ in range(3)]
    sched.close(drain=False)
    for fut in futs:
        assert fut.cancelled()
        with pytest.raises(CancelledError):
            fut.result(timeout=0)
    with pytest.raises(SchedulerClosed):
        sched.add_request(np.zeros((1, 2)))


def test_close_drain_false_fails_partial_request_explicitly():
    """A request with rows already streamed cannot be silently cancelled —
    it gets SchedulerClosed so the caller knows rows were dispatched."""
    sched = manual_sched()
    sched.add_tenant("t", echo_predict, slots=(4,))
    fut = sched.add_request(np.zeros((6, 2)))
    sched.step(force=True)             # 4 of 6 rows served; 2 carried
    sched.close(drain=False)
    with pytest.raises(SchedulerClosed):
        fut.result(timeout=0)


def test_close_drain_serves_everything():
    sched = ServingScheduler(max_wait_ms=1.0)     # real worker thread
    sched.add_tenant("t", echo_predict, slots=(4, 8))
    futs = [sched.add_request(np.full((3, 2), float(i))) for i in range(5)]
    sched.close()                      # drain=True
    for i, fut in enumerate(futs):
        mean, _ = fut.result(timeout=0)
        np.testing.assert_allclose(mean, np.full(3, 2.0 * i), atol=1e-12)


def test_worker_thread_serves_without_stepping():
    """autostart=True: the background worker dispatches on its own once
    max_wait expires; no manual step() calls anywhere."""
    with ServingScheduler(max_wait_ms=1.0) as sched:
        sched.add_tenant("t", echo_predict, slots=(16,))
        fut = sched.add_request(np.ones((3, 2)))
        mean, _ = fut.result(timeout=60)
        np.testing.assert_allclose(mean, np.full(3, 2.0), atol=1e-12)


# ---------------------------------------------------------------------------
# two resident GPFleet tenants, zero recompiles (acceptance gate)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def two_fleets():
    M = 4
    X = random_inputs(jax.random.PRNGKey(0), 256)
    from repro.data import gp_sample_field
    from repro.core.gp import stripe_partition
    _, y = gp_sample_field(jax.random.PRNGKey(1), X, TRUE_LT)
    Xp, yp = stripe_partition(X, y, M)
    mk = lambda method: GPFleet(
        FleetConfig(num_agents=M, method=method, chunk=8, dac_iters=40)
    ).fit(Xp, yp, log_theta0=TRUE_LT, train=False)
    return mk("rbcm"), mk("poe")


def test_two_fleet_tenants_zero_recompiles(two_fleets):
    """The headline gate: two fleets resident in one scheduler, 14 ragged
    requests each, every dispatch hits a warm jit cache (miss counters are
    flat after registration warmup), answers match direct predicts."""
    fa, fb = two_fleets
    rng = np.random.default_rng(7)
    with ServingScheduler(max_wait_ms=1.0) as sched:
        sched.add_fleet("maps", fa, max_slot=32)
        sched.add_fleet("robots", fb, max_slot=32)
        misses = {"maps": fa.jit_cache_misses, "robots": fb.jit_cache_misses}
        assert misses["maps"] > 0       # warmup did trace the ladder
        futs = []
        for i in range(14):
            n = int(rng.integers(1, 40))
            Xq = random_inputs(jax.random.PRNGKey(100 + i), n)
            name = ("maps", "robots")[i % 2]
            futs.append((name, Xq, sched.add_request(Xq, tenant=name)))
        results = [(name, Xq, fut.result(timeout=300))
                   for name, Xq, fut in futs]
        assert fa.jit_cache_misses == misses["maps"]       # ZERO recompiles
        assert fb.jit_cache_misses == misses["robots"]
        stats = sched.tenant_stats
        assert stats["maps"].requests == 7
        assert stats["robots"].requests == 7
    for name, Xq, (mean, var) in results:
        fleet = fa if name == "maps" else fb
        ref_m, ref_v, _ = fleet.predict(Xq)
        np.testing.assert_allclose(mean, np.asarray(ref_m), atol=1e-8)
        np.testing.assert_allclose(var, np.asarray(ref_v), atol=1e-8)


def test_to_server_returns_scheduler(two_fleets):
    """GPFleet.to_server() is now a one-tenant scheduler keeping the v1
    FrontDoor submit/stats surface."""
    fa, _ = two_fleets
    with fa.to_server(batch=16) as srv:
        assert isinstance(srv, ServingScheduler)
        misses = fa.jit_cache_misses
        futs = [srv.submit(random_inputs(jax.random.PRNGKey(i), 1 + i))
                for i in range(4)]
        for fut in futs:
            fut.result(timeout=300)
        assert fa.jit_cache_misses == misses
        assert srv.stats.requests == 4


def test_fleet_slot_geometry(two_fleets):
    fa, _ = two_fleets
    align, max_slot = fa.slot_geometry()
    assert align == 8                       # engine chunk
    assert max_slot >= align
    # NPAE's per-query (M, M) solves cap its slot ceiling below the default
    from repro.fleet import get_method
    assert get_method("npae").max_slot < get_method("rbcm").max_slot


# ---------------------------------------------------------------------------
# fault tolerance: retries, per-rider isolation, stall watchdog, bounded close
# ---------------------------------------------------------------------------

def test_retry_recovers_transient_failure():
    calls = {"n": 0}

    def flaky(Xs):
        calls["n"] += 1
        if calls["n"] <= 2:
            raise RuntimeError("transient")
        return echo_predict(Xs)

    sched = manual_sched()
    sched.add_tenant("t", flaky, slots=(4,), retries=2,
                     retry_backoff_ms=0.1)
    fut = sched.add_request(np.ones((3, 2)))
    sched.step(force=True)
    mean, _ = fut.result(timeout=0)
    np.testing.assert_allclose(mean, np.full(3, 2.0), atol=1e-12)
    assert sched.stats.retried == 2
    sched.close()


def test_retries_exhausted_surface_last_exception():
    def boom(_):
        raise RuntimeError("permanent")

    sched = manual_sched()
    sched.add_tenant("t", boom, slots=(4,), retries=1,
                     retry_backoff_ms=0.1, isolate=False)
    fut = sched.add_request(np.zeros((2, 2)))
    sched.step(force=True)
    with pytest.raises(RuntimeError, match="permanent"):
        fut.result(timeout=0)
    assert sched.stats.retried == 1
    sched.close()


def test_isolation_fails_only_the_poisoned_rider():
    """Two requests share a slot; one carries a poisoned row. The shared
    dispatch fails, isolation re-runs each rider alone, and only the
    poisoned request sees the exception."""
    def picky(Xs):
        if np.any(np.asarray(Xs) >= 999.0):
            raise RuntimeError("poisoned payload")
        return echo_predict(Xs)

    sched = manual_sched()
    sched.add_tenant("t", picky, slots=(8,), retries=0,
                     retry_backoff_ms=0.1, isolate=True)
    good = sched.add_request(np.ones((2, 2)))
    bad = sched.add_request(np.full((2, 2), 999.0))
    sched.step(force=True)                 # both packed into one 8-slot
    mean, _ = good.result(timeout=0)
    np.testing.assert_allclose(mean, np.full(2, 2.0), atol=1e-12)
    with pytest.raises(RuntimeError, match="poisoned"):
        bad.result(timeout=0)
    assert sched.stats.isolated == 1       # the healthy rider's solo run
    sched.close()


def test_isolate_false_fails_the_whole_slot():
    def picky(Xs):
        if np.any(np.asarray(Xs) >= 999.0):
            raise RuntimeError("poisoned payload")
        return echo_predict(Xs)

    sched = manual_sched()
    sched.add_tenant("t", picky, slots=(8,), retries=0, isolate=False)
    good = sched.add_request(np.ones((2, 2)))
    bad = sched.add_request(np.full((2, 2), 999.0))
    sched.step(force=True)
    for fut in (good, bad):
        with pytest.raises(RuntimeError, match="poisoned"):
            fut.result(timeout=0)
    sched.close()


def test_watchdog_fails_stalled_dispatch_and_recovers():
    """A dispatch wedged inside predict_fn past the stall timeout: the
    watchdog fails its riders with SchedulerStalled, quarantines the
    tenant (admission rejects), respawns the worker — and when the stuck
    call finally returns, the tenant serves again."""
    from repro.launch.scheduler import SchedulerStalled
    release = threading.Event()
    wedged = {"on": True}

    def sticky(Xs):
        if wedged["on"]:
            release.wait(timeout=30)
        return echo_predict(Xs)

    sched = ServingScheduler(max_wait_ms=0.5, stall_timeout_ms=60)
    sched.add_tenant("t", sticky, slots=(4,))
    fut = sched.add_request(np.ones((2, 2)))
    with pytest.raises(SchedulerStalled):
        fut.result(timeout=30)             # watchdog fired
    assert sched.stats.stalled == 1
    # quarantined while the stuck thread is still inside predict_fn
    with pytest.raises(SchedulerStalled, match="quarantined"):
        sched.add_request(np.ones((1, 2)))
    wedged["on"] = False
    release.set()                          # stuck call returns -> recovery
    deadline = time.perf_counter() + 30
    while time.perf_counter() < deadline:
        try:
            fut2 = sched.add_request(np.ones((3, 2)))
            break
        except SchedulerStalled:
            time.sleep(0.01)
    mean, _ = fut2.result(timeout=30)
    np.testing.assert_allclose(mean, np.full(3, 2.0), atol=1e-12)
    sched.close()


def test_close_is_bounded_with_wedged_tenant():
    """close(drain=True, timeout=) must return even when a dispatch never
    comes back — the in-flight rider is failed, not stranded."""
    release = threading.Event()

    def stuck(Xs):
        release.wait(timeout=60)
        return echo_predict(Xs)

    sched = ServingScheduler(max_wait_ms=0.5)
    sched.add_tenant("t", stuck, slots=(4,))
    fut = sched.add_request(np.ones((2, 2)))
    deadline = time.perf_counter() + 10    # wait until it is in flight
    while time.perf_counter() < deadline:
        with sched._lock:
            if sched._tenants["t"].inflight:
                break
        time.sleep(0.005)
    t0 = time.perf_counter()
    sched.close(drain=True, timeout=1.0)
    assert time.perf_counter() - t0 < 8.0
    with pytest.raises(SchedulerClosed):
        fut.result(timeout=0)
    release.set()                          # let the wedged thread exit
