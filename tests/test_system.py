"""End-to-end behaviour: training improves loss (centralized AND federated),
serving decodes, checkpoints round-trip, sharding policy is sane."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.lm_data import MarkovLMData
from repro.models import lm
from repro.launch.steps import (make_train_step, make_federated_train_step,
                                make_prefill_step, make_decode_step,
                                pick_optimizer)
from repro.optim import adam, adafactor, apply_updates


def _reduced(arch="internlm2-1.8b"):
    return get_config(arch).reduced()


def _batches(cfg, n, batch=8, seq=64, agent=0):
    data = MarkovLMData(cfg.vocab_size, seed=0, agent=agent)
    for _ in range(n):
        toks, labels = data.batch(batch, seq)
        yield {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}


def test_training_loss_decreases():
    cfg = _reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    optimizer, _ = pick_optimizer(cfg, lr=3e-3)
    step = jax.jit(make_train_step(cfg, optimizer))
    opt_state = optimizer.init(params)
    losses = []
    for batch in _batches(cfg, 25):
        params, opt_state, loss, _ = step(params, opt_state, batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 1.0, losses[::6]


def test_federated_dec_admm_training_learns_and_agrees():
    """The paper's technique end-to-end on an LM: loss decreases AND agents
    reach consensus (disagreement stays bounded)."""
    cfg = _reduced("xlstm-350m")
    M = 4
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_federated_train_step(cfg, n_agents=M, rho=0.05,
                                             kappa=100.0))
    params_st = jax.tree.map(lambda t: jnp.broadcast_to(t, (M,) + t.shape),
                             params)
    duals = jax.tree.map(jnp.zeros_like, params_st)
    gens = [_batches(cfg, 40, batch=4, agent=a) for a in range(M)]
    losses = []
    for bs in zip(*gens):
        batch_st = jax.tree.map(lambda *xs: jnp.stack(xs), *bs)
        params_st, duals, loss = step(params_st, duals, batch_st)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.3, losses[::10]
    dis = max(float(jnp.max(jnp.abs(x - jnp.mean(x, 0))))
              for x in jax.tree.leaves(params_st))
    assert dis < 0.1


def test_microbatched_train_step_matches_plain():
    """Gradient accumulation == full-batch step (same optimizer update)."""
    cfg = _reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    optimizer = adam(1e-3)
    batch = next(iter(_batches(cfg, 1, batch=8)))
    s1 = make_train_step(cfg, optimizer, microbatch=1)
    s4 = make_train_step(cfg, optimizer, microbatch=4)
    p1, _, l1, _ = jax.jit(s1)(params, optimizer.init(params), batch)
    p4, _, l4, _ = jax.jit(s4)(params, optimizer.init(params), batch)
    np.testing.assert_allclose(float(l1), float(l4), rtol=1e-4)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-4)


def test_serve_prefill_decode_loop():
    cfg = _reduced("chatglm3-6b")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    B, P, G = 2, 16, 8
    prefill = jax.jit(make_prefill_step(cfg, max_len=P + G + 1))
    decode = jax.jit(make_decode_step(cfg))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0,
                              cfg.vocab_size)
    logits, cache = prefill(params, toks)
    assert logits.shape == (B, 1, cfg.vocab_size)
    for _ in range(G):
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        logits, cache = decode(params, cache, tok)
    assert int(cache["index"]) == P + G
    assert not bool(jnp.isnan(logits).any())


def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import save_checkpoint, load_checkpoint, latest_step
    cfg = _reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    save_checkpoint(str(tmp_path), 7, params)
    assert latest_step(str(tmp_path)) == 7
    restored = load_checkpoint(str(tmp_path), 7, params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_adafactor_descends():
    cfg = _reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    opt = adafactor(1e-2)
    step = jax.jit(make_train_step(cfg, opt))
    st = opt.init(params)
    losses = []
    for batch in _batches(cfg, 15):
        params, st, loss, _ = step(params, st, batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.5


def test_sharding_policy_rules():
    """Divisibility fallbacks of the logical-axis rules (DESIGN.md §6)."""
    from repro.launch.sharding import spec_for_axes
    from jax.sharding import PartitionSpec as P
    if jax.device_count() < 4:
        pytest.skip("needs multi-device")
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    s = spec_for_axes(mesh, ("embed", "heads", "head_dim"), (64, 4, 16))
    assert s == P("data", "model", None)
    s = spec_for_axes(mesh, ("embed", "heads", "head_dim"), (64, 3, 16))
    assert s == P("data", None, None)
    s = spec_for_axes(mesh, ("vocab", "embed"), (49155, 64))
    assert s == P(None, "data")
    # B=1 long decode: cache sequence takes every free axis
    s = spec_for_axes(mesh, ("batch", "kv_seq", "kv_heads", "head_dim"),
                      (1, 1024, 2, 16), shard_kv_seq=True)
    assert s == P(None, ("data", "model"), None, None)
    # batched decode: batch claims data, sequence falls back to model
    s = spec_for_axes(mesh, ("batch", "kv_seq", "kv_heads", "head_dim"),
                      (8, 1024, 2, 16), shard_kv_seq=True)
    assert s == P("data", "model", None, None)
    s = spec_for_axes(mesh, ("batch", "kv_seq", "kv_heads", "head_dim"),
                      (8, 1024, 2, 16), shard_kv_seq=False)
    assert s == P("data", None, "model", None)


def test_input_specs_cover_all_archs_and_shapes():
    """batch_structs produce consistent specs for every supported pair
    (structure-level; the heavy lower/compile proof lives in dryrun)."""
    from repro.configs import ARCH_IDS
    from repro.launch.steps import SHAPES, batch_structs, shape_supported
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES:
            if not shape_supported(cfg, shape):
                assert shape == "long_500k" and arch == "whisper-small"
                continue
            shapes, axes = batch_structs(cfg, shape)
            assert set(shapes) == set(axes)
            B = SHAPES[shape]["batch"]
            for k, sds in shapes.items():
                assert sds.shape[0] == B
