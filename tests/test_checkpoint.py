"""checkpoint.io: save -> restore round trip and loud validation failures.

The manifest stores `str(treedef)`, which cannot reconstruct a pytree — the
caller supplies a template and `restore` must guarantee the stored leaves
actually match it (names, shapes, dtypes), instead of the bare KeyError /
silent shape drift of the unvalidated `load_checkpoint` path.
"""
import json
import os
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (latest_step, load_checkpoint, restore,
                              save_checkpoint)


class Pair(NamedTuple):
    a: jax.Array
    b: jax.Array
    opt: jax.Array | None = None


def _tree():
    return {"w": jnp.arange(6.0).reshape(2, 3),
            "pair": Pair(jnp.ones((4,)), jnp.zeros((2, 2), jnp.float32)),
            "n": jnp.asarray(3, jnp.int32)}


def _template(tree):
    return jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                        tree)


def test_roundtrip_bit_identical(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 7, tree)
    out = restore(str(tmp_path), _template(tree), step=7)
    assert jax.tree.structure(out) == jax.tree.structure(tree)
    for got, want in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        assert got.dtype == want.dtype


def test_restore_defaults_to_latest_step(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"x": jnp.zeros(2)})
    save_checkpoint(str(tmp_path), 5, {"x": jnp.ones(2)})
    assert latest_step(str(tmp_path)) == 5
    out = restore(str(tmp_path), {"x": jax.ShapeDtypeStruct((2,),
                                                            jnp.zeros(2).dtype)})
    np.testing.assert_array_equal(np.asarray(out["x"]), np.ones(2))


def test_restore_missing_dir_and_step(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore(str(tmp_path / "nope"), {"x": jnp.zeros(2)})
    save_checkpoint(str(tmp_path), 0, {"x": jnp.zeros(2)})
    with pytest.raises(FileNotFoundError):
        restore(str(tmp_path), {"x": jnp.zeros(2)}, step=3)


def test_restore_rejects_missing_leaf(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 0, tree)
    bigger = dict(tree, extra=jnp.zeros(3))
    with pytest.raises(ValueError, match="missing from checkpoint"):
        restore(str(tmp_path), _template(bigger), step=0)


def test_restore_rejects_extra_leaf(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 0, tree)
    smaller = {k: v for k, v in tree.items() if k != "n"}
    with pytest.raises(ValueError, match="does not expect"):
        restore(str(tmp_path), _template(smaller), step=0)


def test_restore_rejects_shape_mismatch(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 0, tree)
    bad = dict(tree, w=jnp.zeros((3, 2)))
    with pytest.raises(ValueError, match="shape"):
        restore(str(tmp_path), _template(bad), step=0)


def test_restore_rejects_dtype_mismatch(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 0, tree)
    bad = dict(tree, n=jnp.asarray(3, jnp.int64))
    with pytest.raises(ValueError, match="dtype"):
        restore(str(tmp_path), _template(bad), step=0)


def test_manifest_records_leaves(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 2, tree)
    with open(os.path.join(str(tmp_path), "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["step"] == 2
    leaves = manifest["leaves"]
    assert leaves["['w']"] == {"shape": [2, 3],
                               "dtype": str(jnp.arange(6.0).dtype)}
    assert set(leaves) == {"['w']", "['pair'].a", "['pair'].b", "['n']"}


def test_load_checkpoint_back_compat(tmp_path):
    """The unvalidated template path still works (legacy callers)."""
    tree = _tree()
    save_checkpoint(str(tmp_path), 0, tree)
    out = load_checkpoint(str(tmp_path), 0, tree)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(tree["w"]))


# ---------------------------------------------------------------------------
# atomic publication: temp + fsync + rename, arrays before manifest
# ---------------------------------------------------------------------------

def test_save_leaves_no_temp_files(tmp_path):
    save_checkpoint(str(tmp_path), 1, _tree())
    save_checkpoint(str(tmp_path), 2, _tree())
    stray = [f for f in os.listdir(tmp_path) if ".tmp" in f]
    assert stray == []
    assert latest_step(str(tmp_path)) == 2


def test_latest_step_ignores_temp_names(tmp_path):
    """A crash can strand a temp file; step discovery must never count it
    (np.savez names temps `step_XXXXXXXX.npz.tmp.npz`)."""
    save_checkpoint(str(tmp_path), 3, _tree())
    (tmp_path / "step_00000009.npz.tmp.npz").write_bytes(b"torn write")
    (tmp_path / "manifest.json.tmp").write_text("{")
    assert latest_step(str(tmp_path)) == 3
    restore(str(tmp_path), _template(_tree()))    # still loads cleanly


def test_crash_before_manifest_keeps_previous_checkpoint(tmp_path,
                                                         monkeypatch):
    """Arrays land before the manifest: dying between the two leaves the
    PREVIOUS manifest intact, so every observable state is loadable."""
    tree = _tree()
    save_checkpoint(str(tmp_path), 1, tree)
    manifest_before = (tmp_path / "manifest.json").read_text()

    real_replace = os.replace

    def exploding_replace(src, dst):
        if str(dst).endswith("manifest.json"):
            raise OSError("simulated crash before manifest publish")
        return real_replace(src, dst)

    monkeypatch.setattr(os, "replace", exploding_replace)
    with pytest.raises(OSError, match="simulated crash"):
        save_checkpoint(str(tmp_path), 2, tree)
    monkeypatch.setattr(os, "replace", real_replace)
    # the old manifest is untouched and still describes a loadable step
    assert (tmp_path / "manifest.json").read_text() == manifest_before
    assert json.loads(manifest_before)["step"] == 1
    out = restore(str(tmp_path), _template(tree), step=1)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(tree["w"]))


def test_crash_during_array_write_keeps_previous_array_file(tmp_path,
                                                            monkeypatch):
    """Dying mid-rename of the .npz leaves the previous step's file whole
    (rename is atomic): restore of the old step still works."""
    tree = _tree()
    save_checkpoint(str(tmp_path), 5, tree)

    real_replace = os.replace

    def exploding_replace(src, dst):
        if str(dst).endswith(".npz"):
            raise OSError("simulated crash during array publish")
        return real_replace(src, dst)

    monkeypatch.setattr(os, "replace", exploding_replace)
    with pytest.raises(OSError, match="simulated crash"):
        save_checkpoint(str(tmp_path), 6, tree)
    monkeypatch.setattr(os, "replace", real_replace)
    assert latest_step(str(tmp_path)) == 5
    restore(str(tmp_path), _template(tree))
