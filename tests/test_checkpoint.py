"""checkpoint.io: save -> restore round trip and loud validation failures.

The manifest stores `str(treedef)`, which cannot reconstruct a pytree — the
caller supplies a template and `restore` must guarantee the stored leaves
actually match it (names, shapes, dtypes), instead of the bare KeyError /
silent shape drift of the unvalidated `load_checkpoint` path.
"""
import json
import os
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (latest_step, load_checkpoint, restore,
                              save_checkpoint)


class Pair(NamedTuple):
    a: jax.Array
    b: jax.Array
    opt: jax.Array | None = None


def _tree():
    return {"w": jnp.arange(6.0).reshape(2, 3),
            "pair": Pair(jnp.ones((4,)), jnp.zeros((2, 2), jnp.float32)),
            "n": jnp.asarray(3, jnp.int32)}


def _template(tree):
    return jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                        tree)


def test_roundtrip_bit_identical(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 7, tree)
    out = restore(str(tmp_path), _template(tree), step=7)
    assert jax.tree.structure(out) == jax.tree.structure(tree)
    for got, want in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        assert got.dtype == want.dtype


def test_restore_defaults_to_latest_step(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"x": jnp.zeros(2)})
    save_checkpoint(str(tmp_path), 5, {"x": jnp.ones(2)})
    assert latest_step(str(tmp_path)) == 5
    out = restore(str(tmp_path), {"x": jax.ShapeDtypeStruct((2,),
                                                            jnp.zeros(2).dtype)})
    np.testing.assert_array_equal(np.asarray(out["x"]), np.ones(2))


def test_restore_missing_dir_and_step(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore(str(tmp_path / "nope"), {"x": jnp.zeros(2)})
    save_checkpoint(str(tmp_path), 0, {"x": jnp.zeros(2)})
    with pytest.raises(FileNotFoundError):
        restore(str(tmp_path), {"x": jnp.zeros(2)}, step=3)


def test_restore_rejects_missing_leaf(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 0, tree)
    bigger = dict(tree, extra=jnp.zeros(3))
    with pytest.raises(ValueError, match="missing from checkpoint"):
        restore(str(tmp_path), _template(bigger), step=0)


def test_restore_rejects_extra_leaf(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 0, tree)
    smaller = {k: v for k, v in tree.items() if k != "n"}
    with pytest.raises(ValueError, match="does not expect"):
        restore(str(tmp_path), _template(smaller), step=0)


def test_restore_rejects_shape_mismatch(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 0, tree)
    bad = dict(tree, w=jnp.zeros((3, 2)))
    with pytest.raises(ValueError, match="shape"):
        restore(str(tmp_path), _template(bad), step=0)


def test_restore_rejects_dtype_mismatch(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 0, tree)
    bad = dict(tree, n=jnp.asarray(3, jnp.int64))
    with pytest.raises(ValueError, match="dtype"):
        restore(str(tmp_path), _template(bad), step=0)


def test_manifest_records_leaves(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 2, tree)
    with open(os.path.join(str(tmp_path), "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["step"] == 2
    leaves = manifest["leaves"]
    assert leaves["['w']"] == {"shape": [2, 3],
                               "dtype": str(jnp.arange(6.0).dtype)}
    assert set(leaves) == {"['w']", "['pair'].a", "['pair'].b", "['n']"}


def test_load_checkpoint_back_compat(tmp_path):
    """The unvalidated template path still works (legacy callers)."""
    tree = _tree()
    save_checkpoint(str(tmp_path), 0, tree)
    out = load_checkpoint(str(tmp_path), 0, tree)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(tree["w"]))
