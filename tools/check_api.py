#!/usr/bin/env python
"""Public-API snapshot check: the frozen export surface cannot drift by
accident.

`repro.fleet` is the lifecycle facade and `repro.core.prediction` is the
method surface the facade wraps — both are documented (docs/fleet_api.md,
README) and depended on by examples/launchers. This script compares each
module's ACTUAL exports (`__all__`, every name importable) against the
frozen lists below and fails with a precise diff on any change, so adding
or removing a public name is always a deliberate, reviewed edit of this
file plus the docs.

Run from the repo root (CI docs job):

    PYTHONPATH=src python tools/check_api.py
"""
import importlib
import sys

# -- the frozen surface ------------------------------------------------------
# Update DELIBERATELY: when the public API changes, change this list in the
# same PR and update docs/fleet_api.md + README accordingly.

FROZEN = {
    "repro.fleet": [
        "FleetConfig", "GPFleet", "FleetDegraded",
        "METHODS", "TRAINERS", "MethodSpec", "TrainerSpec",
        "get_method", "get_trainer", "method_names", "trainer_names",
        "validate_config",
    ],
    "repro.core.prediction": [
        "local_moments", "npae_terms", "chol_factors", "cross_gram",
        "local_moments_cached", "npae_terms_cached", "stream_means",
        "poe", "gpoe", "bcm", "rbcm", "grbcm", "npae",
        "cbnn_scores", "cbnn_mask", "cbnn_scores_cached",
        "cbnn_mask_cached",
        "dec_poe", "dec_gpoe", "dec_bcm", "dec_rbcm", "dec_grbcm",
        "dec_npae", "dec_npae_star", "dec_nn_poe", "dec_nn_gpoe",
        "dec_nn_bcm", "dec_nn_rbcm", "dec_nn_grbcm", "dec_nn_npae",
        "dec_poe_from_moments", "dec_gpoe_from_moments",
        "dec_bcm_from_moments", "dec_rbcm_from_moments",
        "dec_grbcm_from_moments", "dec_npae_from_terms",
        "dec_npae_star_from_terms", "dec_nn_npae_from_terms",
        "FittedExperts", "fit_experts", "map_query_tiles",
        "PredictionEngine",
        "ShardedEngine", "expert_specs", "replicated_specs",
        "shard_experts",
    ],
    "repro.core.sparse": [
        "SparseExperts", "select_inducing", "fit_sparse_experts",
        "sparse_moments_cached", "sparse_scores",
        "sparse_nll", "sparse_nlls", "train_fact_sparse",
        "make_sparse_grad",
        "sparse_npae_factors", "cross_lowrank", "npae_terms_lowrank",
        "dec_npae_sparse",
    ],
    "repro.checkpoint": [
        "save_checkpoint", "load_checkpoint", "latest_step", "restore",
    ],
    "repro.launch.scheduler": [
        "ServingScheduler", "Tenant", "TenantStats",
        "DeadlineExceeded", "SchedulerClosed", "SchedulerSaturated",
        "SchedulerStalled",
        "slot_ladder", "pick_slot",
    ],
    "repro.chaos": [
        "FaultPlan", "Dropout", "FaultInjected",
        "wrap_predict_fn", "membership_events",
    ],
    "repro.scenario": [
        "ScenarioConfig", "preset",
        "ScenarioResult", "run_scenario", "validate_bench",
        "LatentField", "make_field", "agent_paths",
    ],
    "repro.launch.frontdoor": [
        "FrontDoor", "FrontDoorStats",
    ],
    "repro.obs": [
        "Counter", "Gauge", "Histogram", "MetricsRegistry",
        "default_latency_buckets", "default_registry",
        "Span", "SpanLog", "read_spans",
        "TraceRecorder",
        "prometheus_text", "parse_prometheus_text",
        "MetricsServer", "start_metrics_server",
    ],
}

# registry contents are public API too: a renamed trainer/method key breaks
# saved FleetConfigs and CLI invocations
FROZEN_REGISTRY = {
    "trainers": ["fact", "c", "apx", "gapx", "dec-c", "dec-apx",
                 "dec-gapx", "dec-apx-sharded", "fact-sparse",
                 "dec-apx-sparse"],
    "methods": ["poe", "gpoe", "bcm", "rbcm", "grbcm", "npae", "npae_star",
                "nn_poe", "nn_gpoe", "nn_bcm", "nn_rbcm", "nn_grbcm",
                "nn_npae", "npae_sparse"],
}


def check_module(modname: str, frozen: list[str]) -> list[str]:
    errors = []
    mod = importlib.import_module(modname)
    actual = getattr(mod, "__all__", None)
    if actual is None:
        return [f"{modname}: no __all__ defined"]
    extra = sorted(set(actual) - set(frozen))
    missing = sorted(set(frozen) - set(actual))
    if extra:
        errors.append(f"{modname}: NEW exports not in the frozen snapshot "
                      f"(add them here + docs deliberately): {extra}")
    if missing:
        errors.append(f"{modname}: exports REMOVED from the module "
                      f"(breaks the documented surface): {missing}")
    for name in actual:
        if not hasattr(mod, name):
            errors.append(f"{modname}: __all__ lists {name!r} but the "
                          f"module does not define it")
    return errors


def check_registries() -> list[str]:
    from repro.fleet import method_names, trainer_names
    errors = []
    for kind, names, want in (("trainer", trainer_names(),
                               FROZEN_REGISTRY["trainers"]),
                              ("method", method_names(),
                               FROZEN_REGISTRY["methods"])):
        if sorted(names) != sorted(want):
            errors.append(
                f"{kind} registry keys changed: "
                f"added {sorted(set(names) - set(want))}, "
                f"removed {sorted(set(want) - set(names))}")
    return errors


def main() -> int:
    errors = []
    for modname, frozen in FROZEN.items():
        errors += check_module(modname, frozen)
    errors += check_registries()
    if errors:
        print("public-API snapshot check FAILED:", file=sys.stderr)
        for e in errors:
            print(f"  - {e}", file=sys.stderr)
        return 1
    n = sum(len(v) for v in FROZEN.values())
    print(f"public-API snapshot OK: {n} exports across "
          f"{len(FROZEN)} modules, "
          f"{len(FROZEN_REGISTRY['trainers'])} trainers, "
          f"{len(FROZEN_REGISTRY['methods'])} methods")
    return 0


if __name__ == "__main__":
    sys.exit(main())
