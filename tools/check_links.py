"""Fail on broken RELATIVE links in the repo's markdown docs.

    python tools/check_links.py [files...]      # default: README.md docs/*.md

Checks every `[text](target)` and bare `<target>` markdown link whose target
is a relative path (no URL scheme, not a pure #anchor): the referenced file
or directory must exist relative to the markdown file. External http(s)
links are NOT fetched — CI must not depend on the network — and anchors
within existing files are not resolved. Exits 1 with a list of offenders.

Stdlib only (CI runs it before any project dependency is importable).
"""
from __future__ import annotations

import glob
import re
import sys
from pathlib import Path

# [text](target "title") — target stops at whitespace or closing paren
_MD_LINK = re.compile(r"\[[^\]]*\]\(\s*([^)\s]+)(?:\s+\"[^\"]*\")?\s*\)")
_SCHEME = re.compile(r"^[a-zA-Z][a-zA-Z0-9+.-]*:")          # http:, mailto:


def relative_targets(text: str):
    for m in _MD_LINK.finditer(text):
        target = m.group(1)
        if _SCHEME.match(target) or target.startswith("#"):
            continue
        yield target.split("#", 1)[0]                        # strip anchor


def check_file(md: Path) -> list[str]:
    broken = []
    for target in relative_targets(md.read_text(encoding="utf-8")):
        if not target:                                       # "#anchor" only
            continue
        if not (md.parent / target).exists():
            broken.append(f"{md}: broken relative link -> {target}")
    return broken


def main(argv: list[str]) -> int:
    files = [Path(a) for a in argv] if argv else \
        [Path("README.md"), *map(Path, sorted(glob.glob("docs/*.md")))]
    broken = []
    for md in files:
        if not md.exists():
            broken.append(f"{md}: file listed for checking does not exist")
            continue
        broken.extend(check_file(md))
    for line in broken:
        print(line, file=sys.stderr)
    print(f"checked {len(files)} markdown file(s): "
          f"{'FAIL' if broken else 'ok'}")
    return 1 if broken else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
